"""Accuracy-contract bench: estimator calibration + ε-sweep
(DESIGN.md §13; the BlinkDB-style ε-or-deadline trade the paper's
accuracy-aware approximation implies).

Two phases, one shared :class:`AccuracyEstimator`:

1. **Calibration** — one engine per fixed refinement budget
   (``policy="fixed"``, generous deadline so the budget, not the clock,
   decides).  Every served request contributes a (raw online estimate,
   measured loss) pair; the pooled pairs fit the estimator's isotonic
   calibration layer.  The Spearman rank correlation of that training
   set is the calibration gate: below it, raw stage-1 coverage does not
   rank measured loss and no ε contract should be trusted.

2. **ε-sweep** — an ``accuracytrader``/``deadline`` baseline plus one
   ``error_bounded`` arm per ε, all serving the IDENTICAL arrival trace
   (paired comparison) under per-arm independent service noise
   (``service_seed`` — the seed-reuse bug class this PR fixed).  Checks:
   realized loss <= ε + tol per arm, p99 monotone non-increasing as ε
   grows, and the headline: at moderate ε, error_bounded beats the
   deadline baseline's p99 at matched (<= ε) measured loss.

A micro-guard times the host-side estimator ops an engine runs per step
(profile reduce + raw_loss + spread + bucket_for_epsilon) against the
median measured step wall: the estimator must stay <5% overhead.

  PYTHONPATH=src:. python -m benchmarks.run --accuracy-only \
      --json BENCH_accuracy.json
  PYTHONPATH=src:. python -m benchmarks.run --accuracy-only --smoke

CPU wall times proxy the TPU target; the *relations* — rank
correlation, ε compliance, p99 falling as ε loosens — transfer.
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, Optional, Sequence

# Ordering-only gate: with the default deterministic accuracy model the
# measured loss per budget is nearly noiseless, so the rank correlation
# of a working estimator sits near 1.0; 0.5 rejects a broken signal
# without flaking on ties from short smoke windows.
SPEARMAN_GATE = 0.5
EPS_TOL = 0.01          # realized-loss slack over the contracted ε
OVERHEAD_FRAC = 0.05    # estimator host ops vs median step wall


def calibrate(cfg, est, *, n_slots, prompt_len, max_new_tokens, impl,
              seed, rate, duration_s) -> Dict:
  """Fit ``est`` from fixed-budget arms; returns the calibration report
  (pairs + fit stats) for the JSON artifact."""
  from repro.control import calibration_pairs
  from repro.serve.engine import EngineConfig, ServingEngine, run_open_loop

  raws: list = []
  meas: list = []
  arms = {}
  buckets = None
  for ai, b in enumerate(_budget_arms(cfg, prompt_len)):
    eng = ServingEngine(cfg, EngineConfig(
        n_slots=n_slots, prompt_len=prompt_len,
        max_new_tokens=max_new_tokens,
        # Generous deadline: the fixed budget, not the clock, must decide
        # — each arm is one clean column of the raw->measured scatter.
        deadline_ms=1e6, policy="fixed", fixed_budget=int(b),
        contract="deadline_with_bound", impl=impl, seed=seed),
        estimator=est)
    buckets = list(eng.buckets)
    s = run_open_loop(eng, rate_per_s=rate, duration_s=duration_s,
                      seed=seed * 1000 + ai,
                      service_seed=seed * 1000 + ai + 500)
    r, m = calibration_pairs(eng.completed)
    raws += r
    meas += m
    arms[str(int(b))] = {
        "n": len(r),
        "raw_mean": round(float(sum(r) / len(r)), 5) if r else 0.0,
        "meas_mean": round(float(sum(m) / len(m)), 5) if m else 0.0,
        "p99": round(float(s["p99"]), 3)}
    print(f"accuracy_calib_b{int(b)},{s['mean'] * 1e3:.1f},"
          f"n={len(r)} raw={arms[str(int(b))]['raw_mean']:.4f} "
          f"meas={arms[str(int(b))]['meas_mean']:.4f}")
  stats = est.fit(raws, meas)
  print(f"accuracy_calib_fit,0.0,n={stats['n']} "
        f"spearman={stats['spearman']:.3f} resid_q={stats['resid_q']:.4f}")
  return {"buckets": buckets, "arms": arms,
          "pairs": [[round(a, 6), round(b, 6)]
                    for a, b in zip(raws, meas)],
          "spearman": round(float(stats["spearman"]), 4),
          "n": int(stats["n"]),
          "resid_q": round(float(stats["resid_q"]), 5),
          "floor": round(float(est.floor), 5)}


def _budget_arms(cfg, prompt_len: int):
  M = prompt_len // cfg.synopsis.cluster_size
  arms = [0]
  b = 1
  while b < M:
    arms.append(b)
    b *= 2
  return arms + [M]


def eps_sweep(cfg, est, *, epsilons, n_slots, prompt_len, max_new_tokens,
              deadline_ms, impl, seed, rate, duration_s) -> Dict:
  """Deadline baseline + one error_bounded arm per ε on the identical
  arrival trace (seeded once), independent service noise per arm."""
  from repro.serve.engine import EngineConfig as EC
  from repro.serve.engine import ServingEngine, run_open_loop

  out: Dict = {}

  def run(name, ecfg, arm_index):
    eng = ServingEngine(cfg, ecfg, estimator=est)
    # Arrival trace seed is SHARED across arms (paired comparison by
    # design); the service-noise seed is per-arm (seed-reuse fix).
    s = run_open_loop(eng, rate_per_s=rate, duration_s=duration_s,
                      seed=seed, service_seed=seed * 100 + arm_index + 7)
    row = {k: round(float(v), 4) for k, v in s.items()
           if not isinstance(v, dict)}
    out[name] = row
    print(f"accuracy_{name},{s['mean'] * 1e3:.1f},p99={s['p99']:.1f}ms "
          f"loss={s['accuracy_loss_pct']:.3f}% "
          f"budget={s['mean_budget']:.2f} "
          f"freed={s.get('freed_budget_mean', 0.0):.2f} "
          f"band_cov={s.get('band_cover_pct', 0.0):.0f}%")
    return eng, s

  base_cfg = dict(n_slots=n_slots, prompt_len=prompt_len,
                  max_new_tokens=max_new_tokens, deadline_ms=deadline_ms,
                  impl=impl, seed=seed)
  _, base = run("baseline_deadline",
                EC(policy="accuracytrader", contract="deadline",
                   **base_cfg), 0)
  last_eng = None
  for ei, eps in enumerate(epsilons):
    last_eng, _ = run(f"error_bounded_eps{eps}",
                      EC(policy="accuracytrader",
                         contract="error_bounded", epsilon=float(eps),
                         **base_cfg), ei + 1)
  out["epsilons"] = [float(e) for e in epsilons]
  return out, last_eng


def estimator_overhead(est, engine) -> Dict:
  """Per-step host cost of the estimator ops the engine runs, vs the
  median measured step wall of the last sweep arm."""
  import numpy as np
  M = engine.M
  n_slots = engine.ecfg.n_slots
  # Representative telemetry block: (n_prog_rows, n_slots, M+1), the
  # shape _decode_step reduces every step.
  prof = np.linspace(0.0, 1.0, M + 1)[None, None, :].repeat(
      4, axis=0).repeat(n_slots, axis=1)
  reps = 200
  t0 = time.perf_counter()
  for _ in range(reps):
    p = prof.reshape(-1, n_slots, M + 1).mean(0)
    for i in range(n_slots):
      est.raw_loss(p[i], M // 2)
      est.spread_from_profile(p[i], M // 2)
      est.bucket_for_epsilon(p[i], engine.buckets, 0.02)
  est_us = (time.perf_counter() - t0) / reps * 1e6
  walls = sorted(dt for _, dt, _ in engine.step_log)   # dt is ms
  step_us = walls[len(walls) // 2] * 1e3 if walls else 1.0
  frac = est_us / max(step_us, 1e-9)
  print(f"accuracy_estimator_overhead,{est_us:.1f},"
        f"step_median={step_us:.0f}us frac={frac * 100:.2f}%")
  return {"estimator_us": round(est_us, 2),
          "step_median_us": round(step_us, 1),
          "frac": round(frac, 5)}


def accuracy_sweep(*, smoke: bool, impl: Optional[str],
                   epsilons: Sequence[float] = (0.005, 0.02, 0.05),
                   seed: int = 3) -> Dict:
  from repro.configs.registry import get_config
  from repro.control import AccuracyEstimator

  cfg = get_config("llama3-8b", smoke=True)
  if smoke:
    knobs = dict(n_slots=2, prompt_len=64, max_new_tokens=4, impl=impl,
                 seed=seed)
    calib_rate, calib_dur = 40.0, 0.4
    sweep_rate, sweep_dur, deadline_ms = 60.0, 0.5, 120.0
  else:
    knobs = dict(n_slots=4, prompt_len=128, max_new_tokens=8, impl=impl,
                 seed=seed)
    calib_rate, calib_dur = 60.0, 1.0
    sweep_rate, sweep_dur, deadline_ms = 80.0, 1.5, 200.0

  est = AccuracyEstimator()
  calib = calibrate(cfg, est, rate=calib_rate, duration_s=calib_dur,
                    **knobs)
  sweep, last_eng = eps_sweep(cfg, est, epsilons=epsilons,
                              deadline_ms=deadline_ms, rate=sweep_rate,
                              duration_s=sweep_dur, **knobs)
  overhead = estimator_overhead(est, last_eng)

  eps_rows = [(e, sweep[f"error_bounded_eps{e}"]) for e in epsilons]
  p99s = [r["p99"] for _, r in eps_rows]
  # Monotone with slack: loosening ε must not make the tail worse
  # (short windows jitter; 15% + 2ms absorbs host noise, not trends).
  p99_ok = all(p99s[i + 1] <= p99s[i] * 1.15 + 2.0
               for i in range(len(p99s) - 1))
  eps_ok = all(r["accuracy_loss_pct"] / 100.0 <= e + EPS_TOL
               for e, r in eps_rows)
  mid_e, mid = eps_rows[len(eps_rows) // 2]
  base = sweep["baseline_deadline"]
  # Per-arm headline: does error_bounded beat the deadline baseline's
  # p99 while honoring its own ε?  Recorded per arm (not CI-gated):
  # near admission-bound saturation the queue amplifies the telemetry
  # overhead and tight-ε arms can lose — an honest negative result the
  # JSON keeps visible (EXPERIMENTS.md §Accuracy).
  beats_at = [float(e) for e, r in eps_rows
              if r["p99"] <= base["p99"]
              and r["accuracy_loss_pct"] / 100.0 <= e + EPS_TOL]
  check = {
      "spearman": calib["spearman"],
      "spearman_gate": SPEARMAN_GATE,
      "spearman_ok": bool(calib["spearman"] >= SPEARMAN_GATE),
      "eps_ok": bool(eps_ok),
      "eps_tol": EPS_TOL,
      "p99_by_eps": p99s,
      "p99_monotone_ok": bool(p99_ok),
      "moderate_eps": float(mid_e),
      "moderate_p99": mid["p99"],
      "baseline_p99": base["p99"],
      "moderate_loss_pct": mid["accuracy_loss_pct"],
      "beats_baseline_at_eps": beats_at,
      "error_bounded_beats_baseline": bool(beats_at),
      "overhead_frac": overhead["frac"],
      "overhead_ok": bool(overhead["frac"] < OVERHEAD_FRAC),
  }
  return {"calibration": calib, "eps_sweep": sweep, "overhead": overhead,
          "check": check,
          "config": {**{k: v for k, v in knobs.items()},
                     "deadline_ms": deadline_ms, "rate": sweep_rate,
                     "calib_rate": calib_rate,
                     "trace_seed_rule": "arrivals shared across ε arms; "
                                        "service_seed per arm"}}


def main(argv: Optional[Sequence[str]] = None) -> None:
  ap = argparse.ArgumentParser()
  ap.add_argument("--json", default=None, metavar="PATH",
                  help="dump the sweep as a JSON baseline "
                       "(e.g. BENCH_accuracy.json)")
  ap.add_argument("--smoke", action="store_true",
                  help="tiny calibration + sweep for CI")
  ap.add_argument("--impl", default=None,
                  choices=["auto", "pallas", "xla", "interpret"])
  args = ap.parse_args(argv)

  print("name,us_per_call,derived")
  t0 = time.perf_counter()
  res = accuracy_sweep(smoke=args.smoke, impl=args.impl)
  from benchmarks.common import bench_meta
  res["meta"] = bench_meta(wall_s=round(time.perf_counter() - t0, 1),
                           smoke=bool(args.smoke))
  if args.json:
    with open(args.json, "w") as f:
      json.dump(res, f, indent=1, sort_keys=True)
    print(f"# wrote {args.json}")
  c = res["check"]
  # Asserted AFTER the artifact is written (a failed gate must not lose
  # the sweep's data — same contract as serving_bench).
  assert c["spearman_ok"], (
      "calibration gate: raw online estimate must rank measured loss — "
      f"spearman={c['spearman']} < {c['spearman_gate']}")
  assert c["eps_ok"], (
      "error_bounded arm exceeded its contract: realized loss above "
      f"ε + {c['eps_tol']} in {res['eps_sweep']}")
  assert c["p99_monotone_ok"], (
      f"p99 must not grow as ε loosens: {c['p99_by_eps']} across "
      f"ε={res['eps_sweep']['epsilons']}")
  assert c["overhead_ok"], (
      f"estimator host overhead {c['overhead_frac'] * 100:.2f}% of the "
      f"median step wall exceeds the {OVERHEAD_FRAC * 100:.0f}% guard")


if __name__ == "__main__":
  main()
