"""Corpus-cache sweep: admission p99 + aggregate QPS under Zipf-repeated
corpora, hit-rate sweep, and the prefix-extension delta-replay ratio
(DESIGN.md §12; BENCH_cache.json).

The A/B arm serves the identical 100%-repeat trace (one corpus, every
admission after the first is an exact content hit) with the cache on vs
off, policy ``fixed`` so the budget stream — and therefore accuracy — is
deterministic: the loss delta between the arms must be exactly zero
while the hit path cuts the per-request admission wall (write-only
instead of prefill + build + write).  Admissions run serial
(``overlap_admission=False``) so each request's wall is individually
measurable; each arm is measured on its SECOND window — the first warms
the cache (and matches the off arm's thermal state), the second runs at
100% hit rate.

The hit-rate sweep varies the Zipf pool size K (K=1 -> ~100% repeats;
K > capacity -> eviction churn and a sub-1.0 hit rate) under the
accuracytrader policy — the measured hit-rate vs admission-tail curve
committed to EXPERIMENTS.md §Cache.

  PYTHONPATH=src:. python -m benchmarks.cache_bench \
      --json BENCH_cache.json            # committed baseline
  PYTHONPATH=src:. python -m benchmarks.cache_bench --smoke    # CI
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, Optional, Sequence


def _run_two_windows(eng, rate: float, duration_s: float, seed: int,
                     zipf_corpora: int) -> Dict:
  """Warm window then measured window on the identical trace seed: the
  measured window starts with every corpus resident (100% hit rate when
  the pool fits capacity), and the off arm gets the same warm host."""
  from repro.serve.engine import run_open_loop
  run_open_loop(eng, rate_per_s=rate, duration_s=duration_s,
                seed=seed, zipf_corpora=zipf_corpora)
  return run_open_loop(eng, rate_per_s=rate, duration_s=duration_s,
                       seed=seed, zipf_corpora=zipf_corpora)


def cache_sweep(*,
                rate: float = 400.0,
                pools: Sequence[int] = (1, 4, 16, 64),
                n_slots: int = 4,
                prompt_len: int = 128,
                max_new_tokens: int = 8,
                deadline_ms: float = 60.0,
                duration_s: float = 1.0,
                capacity: int = 16,
                arch: str = "llama3-8b",
                impl: Optional[str] = None,
                seed: int = 2) -> Dict:
  from repro.configs.registry import get_config
  from repro.serve.engine import CacheConfig, EngineConfig, ServingEngine

  cfg = get_config(arch, smoke=True)
  C = cfg.synopsis.cluster_size
  out: Dict = {"config": {
      "arch": arch, "n_slots": n_slots, "prompt_len": prompt_len,
      "max_new_tokens": max_new_tokens, "deadline_ms": deadline_ms,
      "duration_s": duration_s, "rate_per_s": rate, "capacity": capacity,
      "pools": list(pools), "seed": seed,
      "trace_seed_rule": "seed*1000 + pool_index"}}

  def engine(policy, cache_on):
    cache = CacheConfig(capacity=capacity, delta_unit=C) if cache_on \
        else None
    return ServingEngine(cfg, EngineConfig(
        n_slots=n_slots, prompt_len=prompt_len,
        max_new_tokens=max_new_tokens, deadline_ms=deadline_ms,
        policy=policy, fixed_budget=1, impl=impl, seed=seed,
        overlap_admission=False, cache=cache))

  # -- A/B arm: 100% repeats, deterministic budgets, cache on vs off ------
  ab = {}
  for on in (True, False):
    eng = engine("fixed", on)
    out["config"]["impl"] = eng.impl
    s = _run_two_windows(eng, rate, duration_s, seed * 1000,
                         zipf_corpora=1)
    name = "cache_on" if on else "cache_off"
    ab[name] = {k: round(float(v), 3) for k, v in s.items()
                if not isinstance(v, dict)}
    print(f"cache_ab_{name},{s['admission_p50'] * 1e3:.1f},"
          f"adm_p99={s['admission_p99']:.2f}ms p99={s['p99']:.1f}ms "
          f"goodput={s['goodput_per_s']:.1f}/s "
          f"loss={s['accuracy_loss_pct']:.3f}% "
          f"prefills={s['prefills']:.0f} served={s['served_n']:.0f}"
          + (f" hit_rate={s['cache_hit_rate']:.2f}" if on else ""))
  out["ab"] = ab

  # -- hit-rate sweep: Zipf pool size K vs admission tail -----------------
  rows = {}
  for pi, K in enumerate(pools):
    eng = engine("accuracytrader", True)
    s = _run_two_windows(eng, rate, duration_s, seed * 1000 + pi,
                         zipf_corpora=int(K))
    rows[str(K)] = {k: round(float(v), 3) for k, v in s.items()
                    if not isinstance(v, dict)}
    print(f"cache_pool{K},{s['admission_p50'] * 1e3:.1f},"
          f"hit_rate={s['cache_hit_rate']:.3f} "
          f"adm_p99={s['admission_p99']:.2f}ms p99={s['p99']:.1f}ms "
          f"loss={s['accuracy_loss_pct']:.2f}% "
          f"entries={s['cache_entries']:.0f} "
          f"evictions={s['cache_evictions']:.0f}")
  out["hit_rate_sweep"] = rows

  # -- delta replay: extend-step cost vs full rebuild ---------------------
  out["delta"] = _delta_ratio(cfg, prompt_len, impl=impl, seed=seed)

  on, off = ab["cache_on"], ab["cache_off"]
  out["check"] = {
      "admission_p99_on": on["admission_p99"],
      "admission_p99_off": off["admission_p99"],
      "goodput_on": on["goodput_per_s"],
      "goodput_off": off["goodput_per_s"],
      "loss_on": on["accuracy_loss_pct"],
      "loss_off": off["accuracy_loss_pct"],
      "hit_rate_on": on["cache_hit_rate"],
      # Hit-path admission must beat the miss path on the tail, at
      # equal-or-better aggregate QPS and an exactly-zero loss delta
      # (fixed budgets: both arms score identically by construction).
      "hit_beats_miss_p99": bool(
          on["admission_p99"] < off["admission_p99"]),
      "qps_no_worse": bool(
          on["goodput_per_s"] >= off["goodput_per_s"]),
      "zero_loss_delta": bool(
          on["accuracy_loss_pct"] == off["accuracy_loss_pct"]),
      "full_hit_rate": bool(on["cache_hit_rate"] == 1.0),
  }
  return out


def _delta_ratio(cfg, prompt_len: int, *, impl=None, seed=2,
                 iters: int = 5) -> Dict:
  """Measured wall of the prefix-extension delta replay (extend step +
  incremental build over E tokens) vs the full rebuild (prefill + build
  over P+E) it replaces — the append-only-session win."""
  import jax
  import jax.numpy as jnp
  import numpy as np

  from repro.models import common as cm
  from repro.models import transformer as tf
  from repro.serve import synopsis_kv as skv
  from repro.serve.prefill import make_extend_step, make_prefill_step

  # Half/half split: both halves keep power-of-two cluster counts, which
  # the balanced-kd clustering requires.
  E = prompt_len // 2
  P = prompt_len - E
  params, _ = cm.split(tf.init_model(jax.random.PRNGKey(seed), cfg))
  params = jax.tree.map(lambda p: p.astype(cfg.dtype), params)
  rng = np.random.default_rng(seed)
  toks = jnp.asarray(rng.integers(0, cfg.vocab, (1, prompt_len)), jnp.int32)
  prefill = jax.jit(make_prefill_step(cfg, impl=impl))
  build = jax.jit(lambda c: skv.build(c, cfg, impl=impl))
  extend = jax.jit(make_extend_step(cfg, impl=impl))
  ext_build = jax.jit(
      lambda a, k, v: skv.extend_synopsis(a, k, v, cfg, impl=impl))

  _, pre = prefill(params, toks[:, :P])
  arena = build(pre)

  def full():
    _, c = prefill(params, toks)
    return build(c)

  def delta():
    _, (k_new, v_new) = extend(params, toks[:, P:], arena["k"],
                               arena["v"], jnp.int32(P))
    return ext_build(arena, k_new, v_new)

  def timed(fn):
    jax.block_until_ready(fn())                      # compile
    ts = []
    for _ in range(iters):
      t0 = time.perf_counter()
      jax.block_until_ready(fn())
      ts.append((time.perf_counter() - t0) * 1e3)
    return float(np.median(ts))

  full_ms, delta_ms = timed(full), timed(delta)
  ratio = full_ms / delta_ms if delta_ms > 0 else 0.0
  print(f"cache_delta_replay,{delta_ms * 1e3:.1f},"
        f"full={full_ms:.2f}ms delta={delta_ms:.2f}ms "
        f"speedup={ratio:.2f}x (P={P} E={E})")
  return {"P": P, "E": E, "full_ms": round(full_ms, 3),
          "delta_ms": round(delta_ms, 3), "speedup": round(ratio, 2)}


def main(argv: Optional[Sequence[str]] = None) -> None:
  ap = argparse.ArgumentParser()
  ap.add_argument("--json", default=None, metavar="PATH",
                  help="dump the sweep as a JSON baseline "
                       "(e.g. BENCH_cache.json)")
  ap.add_argument("--smoke", action="store_true",
                  help="tiny sweep for CI: short windows, small pools")
  ap.add_argument("--impl", default=None,
                  choices=["auto", "pallas", "xla", "interpret"])
  args = ap.parse_args(argv)

  print("name,us_per_call,derived")
  t0 = time.perf_counter()
  if args.smoke:
    res = cache_sweep(rate=200.0, pools=(1, 4, 16), n_slots=2,
                      prompt_len=64, max_new_tokens=4, deadline_ms=40.0,
                      duration_s=0.5, capacity=16, impl=args.impl)
  else:
    res = cache_sweep(impl=args.impl)
  from benchmarks.common import bench_meta
  res["meta"] = bench_meta(wall_s=round(time.perf_counter() - t0, 1),
                           smoke=bool(args.smoke))
  if args.json:
    with open(args.json, "w") as f:
      json.dump(res, f, indent=1, sort_keys=True)
    print(f"# wrote {args.json}")
  c = res["check"]
  assert c["hit_beats_miss_p99"], (
      "cache-hit admissions must beat the miss path on p99: "
      f"on={c['admission_p99_on']}ms off={c['admission_p99_off']}ms")
  assert c["qps_no_worse"], (
      f"cache on must not cost QPS: on={c['goodput_on']}/s "
      f"off={c['goodput_off']}/s")
  assert c["zero_loss_delta"], (
      "cache hits must be accuracy-neutral (shared arena == fresh "
      f"build): loss on={c['loss_on']}% off={c['loss_off']}%")
  assert c["full_hit_rate"], (
      f"the 100%-repeat arm should fully hit: {c['hit_rate_on']}")


if __name__ == "__main__":
  main()
