"""Cluster-tier sweep: loss vs p99 vs component count and skew, from the
multi-component scatter-gather serving tier (DESIGN.md §9; the paper's
Tables 1-2 reproduced on actual parallel components).

Each point drives the continuous-batching engine with a
`ClusterStepBackend`: decode steps run the real kernel path across N
components (shard_map over forced host devices), stage-1 always lands,
and the frontend's deadline-driven partial gather decides per step which
components' refinements make it into the composed result.  The sweep
holds the per-component corpus share FIXED while N grows (more
components = bigger corpus, the paper's scaling regime), so the
full-gather `basic` technique waits on ever more straggler draws while
`accuracytrader` rides the stage-1 floor and `partial` sheds whole
components (and, under 3x load, whole requests).

Beyond the (policy, N) grid the sweep measures the two control-plane
levers (DESIGN.md §10) at the Zipf-hot top-N point: ``replica_sweep``
(R=1 vs R=2 hedged reissue under the exact ``basic`` gather in a
straggler-heavy interference regime — matched zero loss, the p99 delta
is the hedge; judged at the moderate rate where the per-step gather,
not the admission queue, owns the tail) and ``recirc_sweep``
(cap-and-drop vs stranded-budget recirculation at a matched FIXED mid
budget — the loss delta is purely the allocator respending what binding
caps would strand).  ``chaos_sweep`` (DESIGN.md §11) crashes one
component per window under a seed-deterministic FaultSpec and compares
the no-recovery baseline (stalls and drops) against the recovery
ladder's stage-1 fallback (R=1) and replica retry (R=2) — availability
stays 100 % and loss stays under the stage-1 floor; the replica-hedging
gate is judged on a deterministic modelled plan/account comparison, not
wall-clock p99.

  PYTHONPATH=src:. python -m benchmarks.cluster_bench \
      --json BENCH_cluster.json          # committed baseline
  PYTHONPATH=src:. python -m benchmarks.cluster_bench --smoke   # CI
  # (or python -m benchmarks.run --cluster-only --json ...)

CPU-proxy caveat (EXPERIMENTS.md §Cluster): one host executes all N
components, so per-component latencies are the measured step wall
attributed by corpus share and budget, with modelled interference /
straggler noise on top; the engine clock advances by the parallel
completion (max over gathered components).  The *relations* — basic p99
growing with N, partial's loss collapse at 3x, accuracytrader holding the
stage-1 floor — are what transfer.
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, Optional, Sequence


def _one_point(cfg, *, n_components, skew, policy, rates, n_slots,
               per_comp_clusters, max_new_tokens, deadline_ms, duration_s,
               impl, alloc, seed, replicas=1, recirculate=True,
               fixed_budget=0, interference=None, straggler_prob=None,
               faults=None, recovery=True, retries=1, fleet=False, tag=""):
  from repro.serve.cluster import ClusterConfig, ClusterStepBackend
  from repro.serve.engine import EngineConfig, ServingEngine, run_open_loop
  from repro.serve.fleet import FleetConfig, FleetStepBackend

  C = cfg.synopsis.cluster_size
  prompt_len = per_comp_clusters * C * n_components
  ckw = {}
  if interference is not None:
    ckw["interference"] = interference
  if straggler_prob is not None:
    ckw["straggler_prob"] = straggler_prob
  cfg_cls, backend_cls = (FleetConfig, FleetStepBackend) if fleet \
      else (ClusterConfig, ClusterStepBackend)
  backend = backend_cls(cfg_cls(
      n_components=n_components, skew=skew, alloc=alloc, seed=seed,
      replicas=replicas, recirculate=recirculate, faults=faults,
      recovery=recovery, retries=retries, **ckw))
  eng = ServingEngine(cfg, EngineConfig(
      n_slots=n_slots, prompt_len=prompt_len,
      max_new_tokens=max_new_tokens, deadline_ms=deadline_ms,
      policy=policy, impl=impl, seed=seed, fixed_budget=fixed_budget),
      backend=backend)
  rows = {}
  for ri, rate in enumerate(rates):
    # Seed audit (tests/test_estimator.py's seed-role split): arms that
    # share a (seed, rate) here see identical arrivals AND identical
    # modelled service draws — intentional for this bench, whose A/Bs
    # (hedging, recirculation, faults) are re-priced on the same stored
    # draws and need bit-identical noise to be exact.  Sweeps comparing
    # *contracts* must NOT inherit this coupling: pass a per-arm
    # ``service_seed`` (see benchmarks/accuracy_bench.py).
    s = run_open_loop(eng, rate_per_s=float(rate), duration_s=duration_s,
                      seed=seed * 1000 + ri)
    rows[str(rate)] = {k: round(float(v), 3) for k, v in s.items()
                       if not isinstance(v, dict)}
    print(f"cluster_{policy}_N{n_components}_skew{skew}{tag}_rate{rate},"
          f"{s['mean'] * 1e3:.1f},p99={s['p99']:.2f}ms "
          f"loss={s['accuracy_loss_pct']:.2f}% shed={s['shed_pct']:.1f}% "
          f"n={s['n']:.0f}")
  exp = backend.export()
  point = {"rates": rows, "mesh": backend.mesh is not None,
           "counts": list(backend.topo.counts), "replicas": replicas,
           "recirculate": recirculate,
           "comp_ms_full": [round(float(v), 4)
                            for v in exp.step_ms_per_component(100)]}
  if faults is not None:
    point["fault_stats"] = dict(backend.fault_stats)
  return point, exp, backend


def _modelled_hedge_cut(backend, steps: int = 48) -> Dict:
  """Deterministic replica-hedging gate (DESIGN.md §11 satellite).

  The old gate compared two *wall-clock* p99s (R=1 vs R=2), which on a
  noisy CPU proxy is at the mercy of the host scheduler.  Instead:
  re-plan ``steps`` gather steps on the measured R=2 backend and price
  each plan TWICE with the same stored draws and a fixed wall — once
  with the hedges it dispatched, once with them suppressed.  The gather
  takes the min of primary and reissue, so per step the hedged modelled
  completion can never exceed the unhedged one; the comparison is exact
  and seed-stable."""
  import dataclasses as dc

  import numpy as np

  backend.reseed(1234)
  hedged_ms, plain_ms, n_hedged = [], [], 0
  for _ in range(steps):
    plan = backend.plan_step(1, 1e-6)   # basic policy: all FULL, hedged
    bare = dc.replace(
        plan, hedged=np.zeros_like(plan.hedged),
        retries=(np.zeros_like(plan.retries)
                 if plan.retries is not None else None))
    n_hedged += int(plan.hedged.sum())
    hedged_ms.append(
        backend.account(1, 10.0, plan, {}, warming=True)["parallel_ms"])
    plain_ms.append(
        backend.account(1, 10.0, bare, {}, warming=True)["parallel_ms"])
  h99 = float(np.percentile(hedged_ms, 99))
  p99 = float(np.percentile(plain_ms, 99))
  return {"steps": steps, "n_hedged": n_hedged,
          "modelled_p99_hedged": round(h99, 4),
          "modelled_p99_unhedged": round(p99, 4),
          "per_step_never_worse": bool(all(
              h <= p + 1e-9 for h, p in zip(hedged_ms, plain_ms))),
          "cut": bool(h99 <= p99 + 1e-9)}


def cluster_sweep(*, component_counts: Sequence[int],
                  rates: Sequence[float],
                  policies: Sequence[str] = ("basic", "partial",
                                             "accuracytrader"),
                  skews: Sequence[float] = (0.0,),
                  skew_n: Optional[int] = None,
                  n_slots: int = 2,
                  per_comp_clusters: int = 4,
                  max_new_tokens: int = 4,
                  deadline_ms: float = 40.0,
                  duration_s: float = 0.5,
                  arch: str = "llama3-8b",
                  impl: Optional[str] = None,
                  alloc: str = "mass",
                  seed: int = 2) -> Dict:
  from repro.configs.registry import get_config
  from repro.serving.service import ScatterGatherService, ServiceConfig

  cfg = get_config(arch, smoke=True)
  out: Dict = {"sweep": {}, "skew_sweep": {}, "config": {
      "arch": arch, "component_counts": list(component_counts),
      "rates": list(rates), "per_comp_clusters": per_comp_clusters,
      "n_slots": n_slots, "max_new_tokens": max_new_tokens,
      "deadline_ms": deadline_ms, "duration_s": duration_s,
      "alloc": alloc, "seed": seed,
      "cluster_size": cfg.synopsis.cluster_size}}

  export = None
  for n in component_counts:
    for policy in policies:
      point, exp, _ = _one_point(
          cfg, n_components=n, skew=0.0, policy=policy, rates=rates,
          n_slots=n_slots, per_comp_clusters=per_comp_clusters,
          max_new_tokens=max_new_tokens, deadline_ms=deadline_ms,
          duration_s=duration_s, impl=impl, alloc=alloc, seed=seed)
      out["sweep"].setdefault(policy, {})[str(n)] = point
      if policy == "accuracytrader" and n == component_counts[-1]:
        export = exp

  sn = skew_n if skew_n is not None else component_counts[-1]
  for skew in skews:
    if skew == 0.0:
      continue
    for policy in ("partial", "accuracytrader"):
      point, _, _ = _one_point(
          cfg, n_components=sn, skew=skew, policy=policy, rates=rates,
          n_slots=n_slots, per_comp_clusters=per_comp_clusters,
          max_new_tokens=max_new_tokens, deadline_ms=deadline_ms,
          duration_s=duration_s, impl=impl, alloc=alloc, seed=seed)
      out["skew_sweep"].setdefault(policy, {})[str(skew)] = point

  # Hedged replica reissue (DESIGN.md §10): same Zipf-hot point, exact
  # full gather (basic — accuracy loss identically 0 on both sides, so
  # accuracy is matched by construction), R=1 vs R=2, in the
  # straggler-heavy regime reissue exists for (heavier interference +
  # straggler draws than the base sweep — Dean & Barroso's argument;
  # the seeded modelled draws then dominate host measurement noise, so
  # the A/B is stable).  The window seeds and draw counts are
  # replica-independent, so the two runs live in the same
  # interference/straggler world and the p99 delta is the hedge.
  rep_skew = next((s for s in skews if s != 0.0), 0.0)
  rep_noise = {"interference": 0.45, "straggler_prob": 0.08}
  out["replica_sweep"] = {"n_components": sn, "skew": rep_skew,
                          "policy": "basic", **rep_noise}
  for R in (1, 2):
    point, _, rep_backend = _one_point(
        cfg, n_components=sn, skew=rep_skew, policy="basic", rates=rates,
        n_slots=n_slots, per_comp_clusters=per_comp_clusters,
        max_new_tokens=max_new_tokens, deadline_ms=deadline_ms,
        duration_s=duration_s, impl=impl, alloc=alloc, seed=seed,
        replicas=R, tag=f"_R{R}", **rep_noise)
    out["replica_sweep"][f"R{R}"] = point
  # Deterministic modelled gate on the R=2 backend (replaces the old
  # wall-clock p99 comparison as the asserted check).
  out["replica_sweep"]["modelled"] = _modelled_hedge_cut(rep_backend)

  # Materialized-hedge arm (DESIGN.md §14): the fleet tier runs the SAME
  # point with R=2 rows of real replica shards — the gather reads the
  # selected holder's actual shard instead of pricing a modelled
  # reissue.  The deterministic comparison against the modelled-R2
  # backend (same seeds/draws) is the fleet bench's gate (a).
  from benchmarks.fleet_bench import materialized_hedge_cut
  point, _, fleet_backend = _one_point(
      cfg, n_components=sn, skew=rep_skew, policy="basic", rates=rates,
      n_slots=n_slots, per_comp_clusters=per_comp_clusters,
      max_new_tokens=max_new_tokens, deadline_ms=deadline_ms,
      duration_s=duration_s, impl=impl, alloc=alloc, seed=seed,
      replicas=2, fleet=True, tag="_R2mat", **rep_noise)
  out["replica_sweep"]["R2_materialized"] = point
  out["replica_sweep"]["materialized"] = materialized_hedge_cut(
      fleet_backend, rep_backend)

  # Stranded-budget recirculation: same Zipf-hot point, cap-and-drop
  # legacy allocator vs recirculation — budget a binding component cap
  # would strand is respent on the unsaturated components.  Run at a
  # FIXED per-step budget (a mid bucket) so the accuracy delta is purely
  # the allocator's: under accuracytrader the controller's budget
  # feedback on measured (noisy) wall times would confound it.
  mid_budget = max(1, per_comp_clusters * sn // 4)
  out["recirc_sweep"] = {"n_components": sn, "skew": rep_skew,
                         "policy": "fixed", "budget": mid_budget}
  for recirc in (False, True):
    point, _, _ = _one_point(
        cfg, n_components=sn, skew=rep_skew, policy="fixed",
        fixed_budget=mid_budget, rates=rates, n_slots=n_slots,
        per_comp_clusters=per_comp_clusters,
        max_new_tokens=max_new_tokens, deadline_ms=deadline_ms,
        duration_s=duration_s, impl=impl, alloc=alloc, seed=seed,
        recirculate=recirc, tag="_recirc" if recirc else "_drop")
    out["recirc_sweep"]["recirc" if recirc else "drop"] = point

  # Chaos sweep (DESIGN.md §11): crash 1 of the top-N components early in
  # every window (seed-deterministic FaultSpec) and compare three
  # gathers at the moderate rate (where the per-step gather, not the
  # admission queue, owns the outcome):
  #   baseline  — no recovery ladder: the frontend stalls on the dead
  #               shard to a hard timeout, then drops its mass;
  #   stage1    — recovery, R=1: no live replica, the dead shard
  #               terminally degrades to its stage-1 synopsis;
  #   replica   — recovery, R=2, 2 backoff retries: the ring replica
  #               serves the dead shard's refinement.
  # A dead component must cost accuracy (bounded by the stage-1 floor),
  # never availability — the baseline shows what breaks without the
  # ladder.
  from repro.serve.resilience import FaultSpec
  chaos_n = component_counts[-1]
  chaos_faults = FaultSpec(crash=((4, 1),), seed=seed)
  out["chaos_sweep"] = {"n_components": chaos_n, "rate": float(rates[0]),
                        "crash": [[4, 1]],
                        "stage1_floor_pct": 7.0}
  for name, kw in (("baseline", dict(recovery=False)),
                   ("stage1", dict(recovery=True)),
                   ("replica", dict(recovery=True, replicas=2,
                                    retries=2))):
    point, _, _ = _one_point(
        cfg, n_components=chaos_n, skew=0.0, policy="accuracytrader",
        rates=rates[:1], n_slots=n_slots,
        per_comp_clusters=per_comp_clusters,
        max_new_tokens=max_new_tokens, deadline_ms=deadline_ms,
        duration_s=duration_s, impl=impl, alloc=alloc, seed=seed,
        faults=chaos_faults, tag=f"_chaos_{name}", **kw)
    out["chaos_sweep"][name] = point

  # Round-trip: the tier's measured per-component latencies drive the
  # discrete-event simulator's components (simulated fleet, measured
  # service times — DESIGN.md §8/§9).
  if export is not None:
    svc = ScatterGatherService(
        ServiceConfig(n_components=export.n_components,
                      technique="accuracytrader", deadline_ms=deadline_ms,
                      seed=seed), step_backend=export)
    sim = svc.run_open_loop(40.0, 2.0)
    out["simulator_roundtrip"] = {
        "n_components": export.n_components,
        "comp_ms_full": [round(float(v), 4)
                         for v in export.step_ms_per_component(100)],
        **{k: round(float(v), 3) for k, v in sim.items()}}

  # Recorded, not asserted here: the caller judges after the artifact is
  # written (a noisy host must not lose the whole sweep's data).
  top = str(rates[-1])
  ns = [str(n) for n in component_counts]
  sw = out["sweep"]
  at = sw["accuracytrader"][ns[-1]]["rates"][top]["accuracy_loss_pct"] \
      if "accuracytrader" in sw else None
  pe = sw["partial"][ns[-1]]["rates"][top]["accuracy_loss_pct"] \
      if "partial" in sw else None
  checks: Dict = {"top_rate": float(rates[-1]), "n": int(ns[-1]),
                  "accuracytrader_loss_pct": at, "partial_loss_pct": pe,
                  "at_loses_less": bool(at is not None and pe is not None
                                        and at < pe),
                  "stage1_floor_pct": 7.0}
  if at is not None:
    checks["at_near_floor"] = bool(at <= 15.0)
  if "basic" in sw and len(ns) > 1:
    p99s = [sw["basic"][n]["rates"][top]["p99"] for n in ns]
    checks["basic_p99_by_n"] = p99s
    checks["basic_p99_grows"] = bool(p99s[-1] > p99s[0])
  # The hedge is judged at the MODERATE rate: at the 3x admission-bound
  # saturation point the queue, not the per-step gather, owns the tail,
  # so reissue (like the paper's) cannot help there — EXPERIMENTS.md
  # §Cluster records both points.
  mod = str(rates[0])
  rep = out["replica_sweep"]
  checks["replica_rate"] = float(rates[0])
  checks["replica_p99_unhedged"] = rep["R1"]["rates"][mod]["p99"]
  checks["replica_p99_hedged"] = rep["R2"]["rates"][mod]["p99"]
  checks["replica_loss_unhedged"] = \
      rep["R1"]["rates"][mod]["accuracy_loss_pct"]
  checks["replica_loss_hedged"] = \
      rep["R2"]["rates"][mod]["accuracy_loss_pct"]
  # Recorded for the narrative; the asserted gate is the deterministic
  # modelled comparison below (wall-clock p99 on a shared CPU proxy is
  # scheduler noise, not a property of the hedge).
  checks["hedged_p99_cut"] = bool(
      checks["replica_p99_hedged"] <= checks["replica_p99_unhedged"])
  checks["hedged_modelled_cut"] = bool(
      rep["modelled"]["cut"] and rep["modelled"]["per_step_never_worse"])
  checks["replica_p99_materialized"] = \
      rep["R2_materialized"]["rates"][mod]["p99"]
  checks["replica_loss_materialized"] = \
      rep["R2_materialized"]["rates"][mod]["accuracy_loss_pct"]
  mat = rep["materialized"]
  checks["materialized_never_worse"] = bool(all(
      v["per_step_never_worse"] and v["p99_cut"]
      for v in mat.values() if isinstance(v, dict)))
  ch = out["chaos_sweep"]
  checks["chaos_rate"] = ch["rate"]
  checks["chaos_availability_pct"] = {
      name: ch[name]["rates"][mod]["availability_pct"]
      for name in ("baseline", "stage1", "replica")}
  checks["chaos_loss_pct"] = {
      name: ch[name]["rates"][mod]["accuracy_loss_pct"]
      for name in ("baseline", "stage1", "replica")}
  checks["chaos_p99"] = {name: ch[name]["rates"][mod]["p99"]
                         for name in ("baseline", "stage1", "replica")}
  checks["chaos_recovered_available"] = bool(
      checks["chaos_availability_pct"]["stage1"] == 100.0
      and checks["chaos_availability_pct"]["replica"] == 100.0)
  checks["chaos_loss_under_floor"] = bool(
      checks["chaos_loss_pct"]["stage1"] <= ch["stage1_floor_pct"] + 1e-6
      and checks["chaos_loss_pct"]["replica"] <= ch["stage1_floor_pct"]
      + 1e-6)
  checks["chaos_baseline_stalls_and_drops"] = bool(
      ch["baseline"]["fault_stats"]["dropped"] > 0
      and checks["chaos_availability_pct"]["baseline"] < 100.0
      and checks["chaos_p99"]["baseline"]
      > max(checks["chaos_p99"]["stage1"],
            checks["chaos_p99"]["replica"]))
  rc = out["recirc_sweep"]
  checks["recirc_budget"] = rc["budget"]
  checks["recirc_loss_drop"] = rc["drop"]["rates"][mod]["accuracy_loss_pct"]
  checks["recirc_loss_recirc"] = \
      rc["recirc"]["rates"][mod]["accuracy_loss_pct"]
  checks["recirc_cuts_loss"] = bool(
      checks["recirc_loss_recirc"] < checks["recirc_loss_drop"])
  out["check"] = checks
  return out


def main(argv: Optional[Sequence[str]] = None) -> None:
  ap = argparse.ArgumentParser()
  ap.add_argument("--json", default=None, metavar="PATH",
                  help="dump the sweep as a JSON baseline "
                       "(e.g. BENCH_cluster.json)")
  ap.add_argument("--smoke", action="store_true",
                  help="tiny sweep for CI: N in {2, 8}, 2 rates")
  ap.add_argument("--impl", default=None,
                  choices=["auto", "pallas", "xla", "interpret"])
  ap.add_argument("--max-components", type=int, default=8)
  args = ap.parse_args(argv)

  # One device per component BEFORE jax initialises, so the sweep's top-N
  # point runs the real shard_map path (launch/serve.py --cluster does
  # the same).  No-op if the user already set the flag.
  from repro.dist.topology import force_host_devices
  force_host_devices(args.max_components)

  print("name,us_per_call,derived")
  t0 = time.perf_counter()
  # Rates sized to the CPU proxy: admission (prefill+build, measured wall)
  # caps throughput at a few tens of req/s, so the low rate is ~1x
  # (deadlines mostly met) and the top rate is the 3x overload where
  # partial execution's loss collapses (paper Tables 1-2).
  if args.smoke:
    res = cluster_sweep(
        component_counts=[2, min(8, args.max_components)],
        rates=[12.0, 36.0], policies=("basic", "partial",
                                      "accuracytrader"),
        skews=(1.1,), per_comp_clusters=2, max_new_tokens=3,
        deadline_ms=80.0, duration_s=0.8, impl=args.impl)
  else:
    res = cluster_sweep(
        component_counts=[2, 4, min(8, args.max_components)],
        rates=[8.0, 16.0, 24.0],
        skews=(1.1,), per_comp_clusters=2, max_new_tokens=4,
        deadline_ms=60.0, duration_s=1.2, impl=args.impl)
  from benchmarks.common import bench_meta
  res["meta"] = bench_meta(wall_s=round(time.perf_counter() - t0, 1),
                           smoke=bool(args.smoke))
  if args.json:
    with open(args.json, "w") as f:
      json.dump(res, f, indent=1, sort_keys=True)
    print(f"# wrote {args.json}")
  c = res["check"]
  assert c["at_loses_less"], (
      "AccuracyTrader should lose less accuracy than partial at the "
      f"saturated rate {c['top_rate']} (equal deadline): "
      f"at={c['accuracytrader_loss_pct']}% "
      f"partial={c['partial_loss_pct']}%")
  assert c["hedged_modelled_cut"], (
      "hedged reissue must never worsen the modelled gather completion "
      "(deterministic R=2 plan/account comparison): "
      f"{res['replica_sweep']['modelled']}")
  assert c["materialized_never_worse"], (
      "the fleet tier's hedge-on-real-shards must never fall behind the "
      "modelled hedge under the same draws (DESIGN.md §14): "
      f"{res['replica_sweep']['materialized']}")
  assert c["chaos_recovered_available"], (
      "a crashed component must cost accuracy, never availability: "
      f"{c['chaos_availability_pct']}")
  assert c["chaos_loss_under_floor"], (
      "recovered loss with one crashed component must stay under the "
      f"stage-1 floor: {c['chaos_loss_pct']}")
  assert c["chaos_baseline_stalls_and_drops"], (
      "the no-recovery baseline should stall and drop where the ladder "
      f"degrades gracefully: p99={c['chaos_p99']} "
      f"avail={c['chaos_availability_pct']}")


if __name__ == "__main__":
  main()
