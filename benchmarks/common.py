"""Shared bench-artifact metadata.

Every BENCH_*.json carries a ``schema_version`` (bumped when a bench's
JSON layout changes incompatibly) and the git revision that produced it,
so a committed baseline is always attributable to the code that measured
it and downstream readers can gate on the layout they understand.
"""
from __future__ import annotations

import os
import subprocess

SCHEMA_VERSION = 2


def git_describe() -> str:
  try:
    # Pin cwd to THIS repo: a bench launched from elsewhere (absolute
    # PYTHONPATH) must not record some other checkout's revision.
    out = subprocess.run(
        ["git", "describe", "--always", "--dirty", "--tags"],
        capture_output=True, text=True, timeout=10, check=False,
        cwd=os.path.dirname(os.path.abspath(__file__)))
    return out.stdout.strip() or "unknown"
  except Exception:
    return "unknown"


def bench_meta(**extra) -> dict:
  """Provenance block merged into every bench JSON's ``meta``:
  schema version, producing git revision, and (when jax is importable)
  the backend + device count the numbers were measured on."""
  meta = {"schema_version": SCHEMA_VERSION, "git": git_describe()}
  try:
    import jax  # noqa: PLC0415 — benches have already initialised it
    meta["backend"] = jax.default_backend()
    meta["devices"] = jax.device_count()
  except Exception:
    pass
  meta.update(extra)
  return meta
