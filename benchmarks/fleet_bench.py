"""Fleet-tier bench: materialized-replica hedging + the elastic
autoscaler's 24-hour p99/cost frontier (DESIGN.md §14).

Phase A (hedge-on-real-shards) drives the continuous-batching engine
twice through the SAME straggler-heavy interference regime as
``benchmarks.cluster_bench``'s replica sweep — once on the cluster tier
(R=2 *modelled* hedge: the step program reads the primary shard, the
reissue exists only in accounting) and once on the fleet tier (R=2
*materialized* rows: the gather reads the selected holder's actual
shard).  Both arms run the exact ``basic`` gather, so accuracy loss is
identically matched; the asserted gate is DETERMINISTIC, not wall-clock:
re-plan N steps on both backends under the same seeds and draws and
require the fleet's per-step parallel completion (every shard at its
earliest materialized holder) never to exceed the cluster's modelled
hedge — with equality when the cluster hedges every shard, since with
R=2 the two price the same min over the same two draws.

Phase B (elastic autoscaler) replays the 24-hour ``sogou_hourly``
diurnal trace: per window the analytic scan (`control.autoscaler`)
resizes the (n, r) grid against a p99 target, and the discrete-event
simulator (`ScatterGatherService` over `ScaledFleetExport` — the fleet's
own measured per-component walls rescaled to the counterfactual grid)
measures the p99 the frontend would see at that size vs static
peak sizing.  The asserted gate: autoscaled component-hours strictly
below the static peak's at the same p99 target.  Windows where even the
max grid saturates are recorded (``saturated``), as are any unsaturated
windows whose simulated p99 misses the target (``missed_unsaturated``,
documented not asserted: the analytic scan is predictive and carries no
measured-p99 feedback).

  PYTHONPATH=src:. python -m benchmarks.fleet_bench \
      --json BENCH_fleet.json            # committed baseline
  PYTHONPATH=src:. python -m benchmarks.fleet_bench --smoke   # CI
  # (or python -m benchmarks.run --fleet-only --json ...)

CPU-proxy caveat (EXPERIMENTS.md §Fleet): one host executes all R*N
lanes; per-(holder, shard) completions are the measured step wall
attributed by corpus share and budget under seeded interference /
straggler draws, and Phase B's per-window p99 comes from the simulator
driven by measured walls, not from 24 hours of wall clock.  The
*relations* — materialized hedging never behind the modelled hedge,
the autoscaler tracking the diurnal valley at lower cost — are what
transfer.
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, Optional, Sequence


def materialized_hedge_cut(fleet_backend, cluster_backend,
                           steps: int = 48,
                           deadlines: Sequence[float] = (1e-6, 4.0)) -> Dict:
  """Deterministic gate (a): under the same seeds, draws and a fixed
  wall, the fleet's realized per-step parallel time (min over its R
  materialized holders per shard) is never worse than the cluster
  tier's modelled hedge — and identical at the all-hedged deadline,
  where both take the same min over the same two draws (R=2).

  Robust by construction: both accounts re-price on the stored plan
  draws with the same wall, the fleet takes the min over ALL holders
  regardless of its plan-time selection, and the cluster's min is over
  a subset (primary, plus reissue only where it hedged) — so the
  per-step inequality is an algebraic fact, not a tuning outcome."""
  import numpy as np

  out: Dict = {"steps": steps}
  for dl in deadlines:
    fleet_backend.reseed(1234)
    cluster_backend.reseed(1234)
    f_ms, c_ms = [], []
    off_primary = hedged = 0
    for _ in range(steps):
      pf = fleet_backend.plan_step(1, dl)
      pc = cluster_backend.plan_step(1, dl)
      off_primary += int((pf.sel != 0).sum())
      hedged += int(np.asarray(pc.hedged).sum())
      f_ms.append(fleet_backend.account(
          1, 10.0, pf, {}, warming=True)["parallel_ms"])
      c_ms.append(cluster_backend.account(
          1, 10.0, pc, {}, warming=True)["parallel_ms"])
    gap = [c - f for f, c in zip(f_ms, c_ms)]
    key = "all_hedged" if dl <= 1e-3 else f"deadline_{dl:g}ms"
    out[key] = {
        "deadline_ms": dl, "off_primary": off_primary, "hedged": hedged,
        "fleet_p99": round(float(np.percentile(f_ms, 99)), 4),
        "cluster_p99": round(float(np.percentile(c_ms, 99)), 4),
        "per_step_never_worse": bool(all(g >= -1e-9 for g in gap)),
        "p99_cut": bool(np.percentile(f_ms, 99)
                        <= np.percentile(c_ms, 99) + 1e-9)}
    if dl <= 1e-3:
      # Every shard hedged on the cluster side: both arms price
      # min(primary, reissue) over identical draws — exact equality.
      out[key]["identical"] = bool(max(abs(g) for g in gap) <= 1e-9)
  return out


def _engine_arm(cfg, *, fleet, n_components, rates, n_slots,
                per_comp_clusters, max_new_tokens, deadline_ms, duration_s,
                impl, seed, tag):
  """One open-loop engine run in the straggler-heavy regime (mirrors
  cluster_bench's replica sweep: skew 1.1, basic gather, R=2)."""
  from repro.serve.cluster import ClusterConfig, ClusterStepBackend
  from repro.serve.engine import EngineConfig, ServingEngine, run_open_loop
  from repro.serve.fleet import FleetConfig, FleetStepBackend

  C = cfg.synopsis.cluster_size
  prompt_len = per_comp_clusters * C * n_components
  kw = dict(n_components=n_components, skew=1.1, seed=seed, replicas=2,
            interference=0.45, straggler_prob=0.08)
  backend = FleetStepBackend(FleetConfig(**kw)) if fleet \
      else ClusterStepBackend(ClusterConfig(**kw))
  eng = ServingEngine(cfg, EngineConfig(
      n_slots=n_slots, prompt_len=prompt_len,
      max_new_tokens=max_new_tokens, deadline_ms=deadline_ms,
      policy="basic", impl=impl, seed=seed), backend=backend)
  rows = {}
  for ri, rate in enumerate(rates):
    s = run_open_loop(eng, rate_per_s=float(rate), duration_s=duration_s,
                      seed=seed * 1000 + ri)
    rows[str(rate)] = {k: round(float(v), 3) for k, v in s.items()
                      if not isinstance(v, dict)}
    print(f"fleet_{tag}_N{n_components}_rate{rate},{s['mean'] * 1e3:.1f},"
          f"p99={s['p99']:.2f}ms loss={s['accuracy_loss_pct']:.2f}% "
          f"n={s['n']:.0f}")
  return {"rates": rows, "mesh": backend.mesh is not None,
          "counts": list(backend.topo.counts)}, backend


def fleet_sweep(*, n_components: int, rates: Sequence[float],
                n_slots: int = 2, per_comp_clusters: int = 2,
                max_new_tokens: int = 3, deadline_ms: float = 80.0,
                duration_s: float = 0.8, window_s: float = 2.0,
                p99_target_ms: float = 60.0, rate_scale: float = 0.5,
                arch: str = "llama3-8b", impl: Optional[str] = None,
                seed: int = 2) -> Dict:
  from repro.configs.registry import get_config
  from repro.control import Autoscaler, AutoscalerConfig
  from repro.serving.service import (ScaledFleetExport,
                                     ScatterGatherService, ServiceConfig)
  from repro.serving.workload import hour_rate

  cfg = get_config(arch, smoke=True)
  out: Dict = {"config": {
      "arch": arch, "n_components": n_components, "replicas": 2,
      "rates": list(rates), "per_comp_clusters": per_comp_clusters,
      "n_slots": n_slots, "max_new_tokens": max_new_tokens,
      "deadline_ms": deadline_ms, "duration_s": duration_s,
      "window_s": window_s, "p99_target_ms": p99_target_ms,
      "rate_scale": rate_scale, "seed": seed,
      "cluster_size": cfg.synopsis.cluster_size}}

  # -- Phase A: modelled hedge (cluster) vs materialized hedge (fleet) -------
  akw = dict(n_components=n_components, rates=rates, n_slots=n_slots,
             per_comp_clusters=per_comp_clusters,
             max_new_tokens=max_new_tokens, deadline_ms=deadline_ms,
             duration_s=duration_s, impl=impl, seed=seed)
  cluster_point, cluster_backend = _engine_arm(
      cfg, fleet=False, tag="modelled_R2", **akw)
  fleet_point, fleet_backend = _engine_arm(
      cfg, fleet=True, tag="materialized_R2", **akw)
  out["hedge"] = {"modelled_R2": cluster_point,
                  "materialized_R2": fleet_point,
                  "deterministic": materialized_hedge_cut(
                      fleet_backend, cluster_backend)}

  # -- Phase B: the autoscaler over the 24-hour diurnal trace ----------------
  exp = fleet_backend.export()
  n_max, r_max = n_components, 2
  acfg = AutoscalerConfig(p99_target_ms=p99_target_ms,
                          max_components=n_max, max_replicas=r_max,
                          slots=n_slots,
                          steps_per_request=float(max_new_tokens))
  asc = Autoscaler(acfg, ScaledFleetExport(exp, n_max, r_max).step_model)
  static_backend = ScaledFleetExport(exp, n_max, r_max)
  windows = []
  cost_auto = cost_static = 0
  size = None
  for h in range(24):
    rate = float(hour_rate(h)) * rate_scale
    size = asc.decide(rate, size)
    saturated = asc.p99_of(rate, size) == float("inf")
    arms = {}
    for arm, sb in (("auto", ScaledFleetExport(exp, size.n_components,
                                               size.replicas)),
                    ("static", static_backend)):
      n = n_max if arm == "static" else size.n_components
      sim = ScatterGatherService(
          ServiceConfig(n_components=n, technique="accuracytrader",
                        deadline_ms=deadline_ms, seed=seed * 100 + h),
          step_backend=sb)
      s = sim.run_open_loop(rate, window_s)
      arms[arm] = {"p99": round(float(s["p99"]), 3),
                   "loss_pct": round(float(s["accuracy_loss_pct"]), 3),
                   "n_requests": int(s["n"])}
    cost_auto += size.devices
    cost_static += n_max * r_max
    windows.append({
        "hour": h, "rate_per_s": round(rate, 2),
        "n_components": size.n_components, "replicas": size.replicas,
        "devices": size.devices, "saturated": bool(saturated),
        "action": asc.log[-1]["action"], **arms})
    print(f"fleet_autoscale_h{h:02d},{arms['auto']['p99'] * 1e3:.0f},"
          f"rate={rate:.1f}/s grid={size.n_components}x{size.replicas} "
          f"p99={arms['auto']['p99']:.1f}ms "
          f"static_p99={arms['static']['p99']:.1f}ms"
          f"{' SATURATED' if saturated else ''}")
  out["autoscale"] = {
      "windows": windows, "component_hours": cost_auto,
      "component_hours_static": cost_static,
      "decision_log": asc.log}

  # -- checks: recorded now, asserted by the caller AFTER the JSON lands -----
  det = out["hedge"]["deterministic"]
  arms = [k for k in det if isinstance(det[k], dict)]
  top = str(rates[-1])
  loss_f = fleet_point["rates"][top]["accuracy_loss_pct"]
  loss_c = cluster_point["rates"][top]["accuracy_loss_pct"]
  sat = [w["hour"] for w in windows if w["saturated"]]
  # Hours where even the static peak grid misses the target are
  # infeasible for ANY size this grid offers — de-facto saturation,
  # documented alongside the analytically-flagged windows.
  infeasible = [w["hour"] for w in windows
                if not w["saturated"] and w["static"]["p99"] > p99_target_ms]
  missed = [w["hour"] for w in windows
            if not w["saturated"] and w["hour"] not in infeasible
            and w["auto"]["p99"] > p99_target_ms]
  out["check"] = {
      # Gate (a): hedged-on-real-shard never behind the modelled hedge,
      # at equal (zero, basic-gather) loss.
      "materialized_never_worse": bool(all(
          det[k]["per_step_never_worse"] and det[k]["p99_cut"]
          for k in arms)),
      "materialized_identical_when_all_hedged": bool(
          det["all_hedged"]["identical"]),
      "equal_loss": bool(abs(loss_f - loss_c) < 1e-6),
      "loss_fleet_pct": loss_f, "loss_cluster_pct": loss_c,
      "fleet_p99_top": fleet_point["rates"][top]["p99"],
      "cluster_p99_top": cluster_point["rates"][top]["p99"],
      # Gate (b): elastic cost strictly below static peak at the same
      # p99 target.
      "component_hours_auto": cost_auto,
      "component_hours_static": cost_static,
      "autoscaled_cost_below_static": bool(cost_auto < cost_static),
      "p99_target_ms": p99_target_ms,
      "saturated_hours": sat,
      "target_infeasible_hours": infeasible,
      "missed_unsaturated_hours": missed}
  return out


def main(argv: Optional[Sequence[str]] = None) -> None:
  ap = argparse.ArgumentParser()
  ap.add_argument("--json", default=None, metavar="PATH",
                  help="dump the sweep as a JSON baseline "
                       "(e.g. BENCH_fleet.json)")
  ap.add_argument("--smoke", action="store_true",
                  help="tiny sweep for CI: N=2 x R=2, one rate")
  ap.add_argument("--impl", default=None,
                  choices=["auto", "pallas", "xla", "interpret"])
  args = ap.parse_args(argv)

  # R*N devices BEFORE jax initialises, so the fleet arm runs the real
  # 2-D shard_map path (launch/serve.py --fleet does the same).
  n_components = 2 if args.smoke else 4
  from repro.dist.topology import force_host_devices
  force_host_devices(n_components * 2)

  print("name,us_per_call,derived")
  t0 = time.perf_counter()
  if args.smoke:
    res = fleet_sweep(n_components=n_components, rates=[12.0],
                      duration_s=0.5, window_s=0.8, max_new_tokens=3)
  else:
    res = fleet_sweep(n_components=n_components, rates=[8.0, 16.0],
                      duration_s=1.0, window_s=2.0, max_new_tokens=4)
  from benchmarks.common import bench_meta
  res["meta"] = bench_meta(wall_s=round(time.perf_counter() - t0, 1),
                           smoke=bool(args.smoke))
  if args.json:
    with open(args.json, "w") as f:
      json.dump(res, f, indent=1, sort_keys=True)
    print(f"# wrote {args.json}")
  c = res["check"]
  assert c["materialized_never_worse"], (
      "gate (a): hedged-on-real-shard must never exceed the modelled "
      "hedge per step under the same draws: "
      f"{res['hedge']['deterministic']}")
  assert c["materialized_identical_when_all_hedged"], (
      "R=2 all-hedged pricing must be IDENTICAL between the fleet min "
      f"and the cluster hedge: {res['hedge']['deterministic']}")
  assert c["equal_loss"], (
      "the hedge A/B must be judged at equal loss (basic gather both "
      f"arms): fleet={c['loss_fleet_pct']}% cluster="
      f"{c['loss_cluster_pct']}%")
  assert c["autoscaled_cost_below_static"], (
      "gate (b): autoscaled component-hours must be strictly below "
      f"static peak sizing: auto={c['component_hours_auto']} "
      f"static={c['component_hours_static']}")


if __name__ == "__main__":
  main()
