"""Kernel micro-benchmarks (xla path on CPU; the Pallas path is the TPU
target, validated in interpret mode — wall times here are CPU-relative
but the *ratios* exact/synopsis and fused/unfused transfer).

Five sweeps:

  * ``decode_attention_sweep`` — the paper headline: exact O(S) decode vs
    the synopsis path, plus the fused pipeline.
  * ``fusion_sweep`` — the PR 1 tentpole: the synopsis *stage* (score +
    count-biased centroid attention) timed as two separately-jitted
    launches (the unfused kernel structure: ``k_syn`` is read twice and
    the logit matmul runs twice — on TPU these are two HBM passes with no
    cross-kernel CSE) vs the single fused launch, and the end-to-end
    fused vs unfused pipeline.
  * ``pallas_vs_xla_sweep`` — interpret-mode sanity ratio at a small
    shape (on TPU rerun with impl="pallas" for real numbers).
  * ``prefill_sweep`` — the PR 2 tentpole, prefill half: the remat'd
    chunked causal scan (the old prefill path) vs the forward-only
    facade, plus an interpret-mode smoke of the flash kernel.
  * ``build_sweep`` — synopsis build: the permute/mean chain timed as two
    separately-jitted launches (sorted cache written to HBM, then re-read
    for the mean — the structure the fused segment-build kernel replaces)
    vs the single-jit facade, plus an interpret-mode smoke.
"""
from __future__ import annotations

import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref


def _time(f, *args, iters=5):
  f(*args)  # compile + warm
  jax.block_until_ready(f(*args))
  t0 = time.perf_counter()
  for _ in range(iters):
    out = f(*args)
  jax.block_until_ready(out)
  return (time.perf_counter() - t0) / iters * 1e6   # us


def _mk(S, B=4, Hkv=8, G=4, D=128, C=128, seed=0):
  H, M = Hkv * G, S // C
  ks = jax.random.split(jax.random.PRNGKey(seed), 6)
  q = jax.random.normal(ks[0], (B, H, D), jnp.float32)
  k = jax.random.normal(ks[1], (B, Hkv, S, D), jnp.float32)
  v = jax.random.normal(ks[2], (B, Hkv, S, D), jnp.float32)
  k_syn = k.reshape(B, Hkv, M, C, D).mean(3)
  v_syn = v.reshape(B, Hkv, M, C, D).mean(3)
  counts = jnp.full((B, M), float(C))
  return q, k, v, k_syn, v_syn, counts


def decode_attention_sweep() -> Dict[str, float]:
  D = 128
  out = {}
  for S in (4096, 16384):
    q, k, v, k_syn, v_syn, counts = _mk(S)
    sm = float(1 / np.sqrt(D))

    exact = jax.jit(lambda q, k, v: ops.exact_decode_attention(
        q, k, v, sm_scale=sm, impl="xla"))
    syn = jax.jit(lambda q, k, v, ks_, vs, c: ops.synopsis_attention(
        q, k, v, ks_, vs, c, i_max=32, sm_scale=sm, impl="xla"))
    fused = jax.jit(lambda q, k, v, ks_, vs, c: ops.synopsis_attention_fused(
        q, k, v, ks_, vs, c, i_max=32, sm_scale=sm, impl="xla"))
    t_e = _time(exact, q, k, v)
    t_s = _time(syn, q, k, v, k_syn, v_syn, counts)
    t_f = _time(fused, q, k, v, k_syn, v_syn, counts)
    out[f"exact_S{S}_us"] = t_e
    out[f"synopsis_S{S}_us"] = t_s
    out[f"synopsis_fused_S{S}_us"] = t_f
    out[f"speedup_S{S}"] = t_e / t_s
    out[f"speedup_fused_S{S}"] = t_e / t_f
  return out


def fusion_sweep() -> Dict[str, float]:
  """Fused vs unfused synopsis stage + end-to-end pipeline (XLA proxy).

  The unfused stage runs as two separate jitted calls on purpose: that is
  the kernel-launch structure being replaced (scores kernel + flash
  decode kernel = two full reads of k_syn), and keeping them in one jit
  would let XLA CSE the shared logit matmul that distinct Pallas kernel
  launches cannot share."""
  # The stage's problem size is M = S/C; C=32 keeps M large enough that
  # the two-matmul-vs-one structure dominates CPU dispatch noise (the
  # paper-default C=128 shapes are what decode_attention_sweep reports).
  D, C = 128, 32
  out = {}
  for S in (4096, 16384):
    q, k, v, k_syn, v_syn, counts = _mk(S, C=C)
    sm = float(1 / np.sqrt(D))
    cbias = ops.count_bias(counts)
    B, Hkv, M, _ = k_syn.shape
    bias = jnp.broadcast_to(cbias[:, None, :], (B, Hkv, M))

    score_fn = jax.jit(lambda q, ks_: ref.synopsis_score_ref(
        q, ks_, sm_scale=sm))
    decode_fn = jax.jit(lambda q, ks_, vs, b: ref.flash_decode_ref(
        q, ks_, vs, b, sm_scale=sm))
    fused_fn = jax.jit(lambda q, ks_, vs, c: ops.synopsis_stage1(
        q, ks_, vs, c, sm_scale=sm, impl="xla"))

    def unfused_stage(q, ks_, vs, b):
      s = score_fn(q, ks_)
      p = decode_fn(q, ks_, vs, b)
      return s, p

    t_u = _time(unfused_stage, q, k_syn, v_syn, bias, iters=20)
    t_f = _time(fused_fn, q, k_syn, v_syn, counts, iters=20)
    out[f"syn_stage_unfused_S{S}_us"] = t_u
    out[f"syn_stage_fused_S{S}_us"] = t_f
    out[f"syn_stage_fused_speedup_S{S}"] = t_u / t_f

    e2e_u = jax.jit(lambda *a: ops.synopsis_attention(
        *a, i_max=32, sm_scale=sm, impl="xla"))
    e2e_f = jax.jit(lambda *a: ops.synopsis_attention_fused(
        *a, i_max=32, sm_scale=sm, impl="xla"))
    t_eu = _time(e2e_u, q, k, v, k_syn, v_syn, counts)
    t_ef = _time(e2e_f, q, k, v, k_syn, v_syn, counts)
    out[f"e2e_unfused_S{S}_us"] = t_eu
    out[f"e2e_fused_S{S}_us"] = t_ef
    out[f"e2e_fused_speedup_S{S}"] = t_eu / t_ef
  return out


def prefill_sweep(impl: str | None = None) -> Dict[str, float]:
  """Prefill attention: the remat'd chunked causal scan (training path —
  what prefill used to run) vs the forward-only prefill facade.  On CPU
  both lower to near-identical XLA; the transferable claim is structural
  (the Pallas path block-tiles with in-grid causal skip and no remat
  bookkeeping).  The interpret entry runs the real flash kernel under the
  Pallas interpreter at a small shape as a correctness/ratio smoke."""
  from repro.models.layers import causal_attention
  impl = impl or ("pallas" if jax.default_backend() == "tpu"
                  else "interpret")
  B, Hkv, G, D = 2, 4, 4, 128
  H = Hkv * G
  sm = float(1 / np.sqrt(D))
  out = {}
  for S in (1024, 4096):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, Hkv, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, Hkv, D), jnp.float32)
    chain = jax.jit(lambda q, k, v: causal_attention(
        q, k, v, sm_scale=sm, causal_skip=True))
    flash_xla = jax.jit(lambda q, k, v: ops.prefill_attention(
        q, k, v, sm_scale=sm, impl="xla"))
    t_c = _time(chain, q, k, v)
    t_x = _time(flash_xla, q, k, v)
    out[f"prefill_chain_S{S}_us"] = t_c
    out[f"prefill_xla_S{S}_us"] = t_x
    out[f"prefill_xla_speedup_S{S}"] = t_c / t_x
  # Interpret smoke: the actual Pallas kernel, small shape.
  S = 256
  ks = jax.random.split(jax.random.PRNGKey(1), 3)
  q = jax.random.normal(ks[0], (1, S, 4, 128), jnp.float32)
  k = jax.random.normal(ks[1], (1, S, 2, 128), jnp.float32)
  v = jax.random.normal(ks[2], (1, S, 2, 128), jnp.float32)
  for name, im in (("xla", "xla"), (impl, impl)):
    fn = jax.jit(lambda q, k, v, im=im: ops.prefill_attention(
        q, k, v, sm_scale=sm, impl=im))
    out[f"prefill_{name}_S{S}_us"] = _time(fn, q, k, v)
  out[f"prefill_impl_ratio_S{S}"] = (
      out[f"prefill_{impl}_S{S}_us"] / out[f"prefill_xla_S{S}_us"])
  out["prefill_impl"] = impl
  return out


def build_sweep(impl: str | None = None) -> Dict[str, float]:
  """Synopsis build: the unfused chain as two separately-jitted launches
  (permute writes the sorted cache to HBM; the segment mean reads it
  back — two full cache passes plus gather copies) vs the single-jit
  facade.  The Pallas segment-build kernel streams each row through VMEM
  once; interpret entry smokes it at a small shape."""
  impl = impl or ("pallas" if jax.default_backend() == "tpu"
                  else "interpret")
  N, Hkv, D, C = 4, 8, 128, 128
  out = {}
  for S in (4096, 16384):
    M = S // C
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    k = jax.random.normal(ks[0], (N, Hkv, S, D), jnp.float32)
    v = jax.random.normal(ks[1], (N, Hkv, S, D), jnp.float32)
    perm = jnp.stack([
        jax.random.permutation(jax.random.fold_in(ks[2], n), S)
        for n in range(N)]).astype(jnp.int32)

    permute_fn = jax.jit(lambda k, v, p: (
        jnp.take_along_axis(k, jnp.broadcast_to(
            p[:, None, :, None], (N, Hkv, S, 1)), axis=2),
        jnp.take_along_axis(v, jnp.broadcast_to(
            p[:, None, :, None], (N, Hkv, S, 1)), axis=2)))
    mean_fn = jax.jit(lambda ks_, vs: (
        ks_.reshape(N, Hkv, M, C, D).mean(3),
        vs.reshape(N, Hkv, M, C, D).mean(3)))
    fused_fn = jax.jit(lambda k, v, p: ops.synopsis_build(
        k, v, p, cluster_size=C, impl="xla"))

    def chain(k, v, p):
      ks_, vs = permute_fn(k, v, p)
      return ks_, vs, mean_fn(ks_, vs)

    t_u = _time(chain, k, v, perm)
    t_f = _time(fused_fn, k, v, perm)
    out[f"build_chain_S{S}_us"] = t_u
    out[f"build_fused_xla_S{S}_us"] = t_f
    out[f"build_fused_speedup_S{S}"] = t_u / t_f
  # Interpret smoke: the actual segment-build kernel, small shape.
  S, C_sm = 256, 64
  ks = jax.random.split(jax.random.PRNGKey(1), 3)
  k = jax.random.normal(ks[0], (1, 2, S, D), jnp.float32)
  v = jax.random.normal(ks[1], (1, 2, S, D), jnp.float32)
  perm = jax.random.permutation(ks[2], S)[None].astype(jnp.int32)
  for name, im in (("xla", "xla"), (impl, impl)):
    fn = jax.jit(lambda k, v, p, im=im: ops.synopsis_build(
        k, v, p, cluster_size=C_sm, impl=im))
    out[f"build_{name}_S{S}_us"] = _time(fn, k, v, perm)
  out[f"build_impl_ratio_S{S}"] = (
      out[f"build_{impl}_S{S}_us"] / out[f"build_xla_S{S}_us"])
  out["build_impl"] = impl
  return out


def pallas_vs_xla_sweep(impl: str | None = None) -> Dict[str, float]:
  """Fused pipeline impl ratio.  On CPU the Pallas interpreter is an
  emulator (orders of magnitude slow — the ratio is a sanity check, not a
  performance claim); on TPU pass impl="pallas"."""
  impl = impl or ("pallas" if jax.default_backend() == "tpu"
                  else "interpret")
  S, C = 2048, 128
  q, k, v, k_syn, v_syn, counts = _mk(S, B=1, Hkv=2, G=2, C=C)
  sm = float(1 / np.sqrt(q.shape[-1]))
  out = {}
  for name, im in (("xla", "xla"), (impl, impl)):
    fn = jax.jit(lambda *a: ops.synopsis_attention_fused(
        *a, i_max=8, sm_scale=sm, impl=im))
    out[f"fused_{name}_S{S}_us"] = _time(fn, q, k, v, k_syn, v_syn, counts)
  out[f"pallas_vs_xla_ratio_S{S}"] = (
      out[f"fused_{impl}_S{S}_us"] / out[f"fused_xla_S{S}_us"])
  out["pallas_impl"] = impl
  return out


def _quant_arenas(S, *, B=4, Hkv=8, G=4, D=128, C=128, qspec="int8+kv",
                  seed=3):
  """One corpus, two arenas: the f32 build 5-tuple and the quantized
  arena dict (identity permutation — rows are already cluster order)."""
  ks = jax.random.split(jax.random.PRNGKey(seed), 3)
  q = jax.random.normal(ks[0], (B, Hkv * G, D), jnp.float32)
  k = jax.random.normal(ks[1], (B, Hkv, S, D), jnp.float32)
  v = jax.random.normal(ks[2], (B, Hkv, S, D), jnp.float32)
  perm = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
  f32 = ops.synopsis_build(k, v, perm, cluster_size=C, impl="xla")
  qa = ops.synopsis_build(k, v, perm, cluster_size=C, impl="xla",
                          qconfig=qspec)
  return q, k, v, f32, qa


def _rel_dev(a, b):
  a, b = np.asarray(a, np.float64), np.asarray(b, np.float64)
  return float(np.linalg.norm(a - b) / max(np.linalg.norm(b), 1e-30))


def quant_sweep(impl: str | None = None) -> Dict[str, float]:
  """Quantized synopsis (DESIGN.md §15): predicted HBM-traffic reduction
  (the roofline accounting that IS the perf claim), the XLA-proxy
  measured times (honest caveat: the XLA lowering materializes f32
  dequantized copies, so the measured proxy ratio understates — and can
  invert — the TPU win; EXPERIMENTS.md §Quantization), accuracy
  deviation of the quantized arm vs the f32 arm and vs exact attention,
  and an interpret-mode parity smoke of the actual kernels."""
  from repro.analysis.roofline import traffic_reduction
  impl = impl or ("pallas" if jax.default_backend() == "tpu"
                  else "interpret")
  B, Hkv, G, D, C, I = 4, 8, 4, 128, 128, 32
  sm = float(1 / np.sqrt(D))
  out: Dict[str, float] = {}
  for S in (4096, 16384):
    M = S // C
    q, k, v, f32, qa = _quant_arenas(S, B=B, Hkv=Hkv, G=G, D=D, C=C)
    k_s, v_s, k_syn, v_syn, counts = f32

    f32_fn = jax.jit(lambda q, k, v, ks_, vs, c: ops.synopsis_attention_fused(
        q, k, v, ks_, vs, c, i_max=I, sm_scale=sm, impl="xla"))
    qt_fn = jax.jit(lambda q, a: ops.synopsis_attention_fused(
        q, a["k"], a["v"], a["k_syn"], a["v_syn"], a["counts"],
        a["k_syn_scale"], a["v_syn_scale"], a["k_scale"], a["v_scale"],
        i_max=I, sm_scale=sm, impl="xla"))
    exact_fn = jax.jit(lambda q, k, v: ops.exact_decode_attention(
        q, k, v, sm_scale=sm, impl="xla"))

    o_f = f32_fn(q, k_s, v_s, k_syn, v_syn, counts)
    o_q = qt_fn(q, qa)
    o_e = exact_fn(q, k, v)
    out[f"dev_quant_vs_f32_S{S}"] = _rel_dev(o_q, o_f)
    out[f"dev_f32_vs_exact_S{S}"] = _rel_dev(o_f, o_e)
    out[f"dev_quant_vs_exact_S{S}"] = _rel_dev(o_q, o_e)
    # The floor metric: how much FURTHER from exact the quantized arm
    # lands than the f32 arm at the same budget.  (At partial coverage
    # the two arms can select different clusters, so quant-vs-f32 drift
    # alone overstates the loss both arms share vs exact.)
    out[f"incremental_loss_S{S}"] = (out[f"dev_quant_vs_exact_S{S}"]
                                     - out[f"dev_f32_vs_exact_S{S}"])

    out[f"fused_f32_S{S}_us"] = _time(
        f32_fn, q, k_s, v_s, k_syn, v_syn, counts)
    out[f"fused_int8kv_S{S}_us"] = _time(qt_fn, q, qa)
    out[f"measured_proxy_ratio_S{S}"] = (
        out[f"fused_f32_S{S}_us"] / out[f"fused_int8kv_S{S}_us"])

    shape = dict(batch=B, kv_heads=Hkv, m=M, d=D, cluster_size=C, i_max=I)
    for qspec in ("int8", "int8+kv"):
      for nb, nm in ((4, "f32"), (2, "bf16")):
        red = traffic_reduction(qspec, native_bytes=nb, **shape)
        tag = qspec.replace("+", "_")
        out[f"pred_stage1_reduction_{tag}_vs_{nm}_S{S}"] = red["stage1"]
        out[f"pred_total_reduction_{tag}_vs_{nm}_S{S}"] = red["total"]

  # Interpret smoke: the actual build + fused kernels under the Pallas
  # interpreter at a small shape, vs the XLA quant reference.
  S_sm, C_sm, I_sm = 512, 64, 8
  q, k, v, _, qa = _quant_arenas(S_sm, B=1, Hkv=2, G=2, C=C_sm, seed=5)
  perm = jnp.broadcast_to(jnp.arange(S_sm, dtype=jnp.int32), (1, S_sm))
  qa_i = ops.synopsis_build(k, v, perm, cluster_size=C_sm, impl=impl,
                            qconfig="int8+kv")
  int_diff = max(int(jnp.max(jnp.abs(
      qa_i[n].astype(jnp.int32) - qa[n].astype(jnp.int32))))
      for n in ("k", "v", "k_syn", "v_syn"))
  out["interpret_build_max_int_diff"] = float(int_diff)
  run = lambda a, im: ops.synopsis_attention_fused(   # noqa: E731
      q, a["k"], a["v"], a["k_syn"], a["v_syn"], a["counts"],
      a["k_syn_scale"], a["v_syn_scale"], a["k_scale"], a["v_scale"],
      i_max=I_sm, sm_scale=sm, impl=im)
  out["interpret_fused_dev"] = _rel_dev(run(qa_i, impl), run(qa, "xla"))
  out["quant_impl"] = impl

  # Gates (CI asserts these): the bytes claim uses the CONSERVATIVE
  # bf16-native baseline; the loss claim is the quantized arm's extra
  # deviation staying inside the ~7% stage-1 accuracy floor.
  out["check_pred_reduction_ge_1_8"] = bool(
      min(out["pred_stage1_reduction_int8_vs_bf16_S4096"],
          out["pred_stage1_reduction_int8_vs_bf16_S16384"]) >= 1.8)
  out["check_loss_within_floor"] = bool(
      max(out["incremental_loss_S4096"],
          out["incremental_loss_S16384"]) <= 0.07
      and out["interpret_build_max_int_diff"] == 0
      and out["interpret_fused_dev"] < 1e-3)
  return out


def quant_serving_arm() -> Dict[str, float]:
  """The serving-level control experiment: the engine's smoke config run
  with quant="none" vs "int8" vs "int8+kv" over the same arrivals.  The
  recorded accuracy_loss_pct is the engine's own exact-vs-served metric;
  the int8 arm must stay inside the stage-1 floor (~7%)."""
  import dataclasses

  from repro.configs.registry import get_config
  from repro.serve.engine import EngineConfig, ServingEngine, make_requests

  cfg = get_config("llama3-8b", smoke=True)
  ecfg = EngineConfig(n_slots=2, prompt_len=64, max_new_tokens=4,
                      deadline_ms=60.0, policy="accuracytrader", impl="xla")
  arrivals = [0.0, 0.001, 0.002, 0.003]
  out: Dict[str, float] = {}
  for qspec in ("none", "int8", "int8+kv"):
    c = dataclasses.replace(
        cfg, synopsis=dataclasses.replace(cfg.synopsis, quant=qspec))
    eng = ServingEngine(c, ecfg)
    summary = eng.run(make_requests(arrivals, 64, 4, c.vocab, seed=7))
    tag = qspec.replace("+", "_")
    out[f"engine_{tag}_accuracy_loss_pct"] = summary["accuracy_loss_pct"]
    out[f"engine_{tag}_p99_ms"] = summary["p99"]
  out["check_serving_loss_within_floor"] = bool(
      out["engine_int8_accuracy_loss_pct"] <= 7.0
      and out["engine_int8_kv_accuracy_loss_pct"] <= 7.0)
  return out
