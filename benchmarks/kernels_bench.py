"""Kernel micro-benchmarks (xla path on CPU; the Pallas path is the TPU
target, validated in interpret mode — wall times here are CPU-relative
but the *ratios* exact/synopsis transfer)."""
from __future__ import annotations

import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops


def _time(f, *args, iters=5):
  f(*args)  # compile + warm
  jax.block_until_ready(f(*args))
  t0 = time.perf_counter()
  for _ in range(iters):
    out = f(*args)
  jax.block_until_ready(out)
  return (time.perf_counter() - t0) / iters * 1e6   # us


def decode_attention_sweep() -> Dict[str, float]:
  B, Hkv, G, D, C = 4, 8, 4, 128, 128
  H = Hkv * G
  out = {}
  for S in (4096, 16384):
    M = S // C
    ks = jax.random.split(jax.random.PRNGKey(0), 6)
    q = jax.random.normal(ks[0], (B, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, Hkv, S, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, Hkv, S, D), jnp.float32)
    k_syn = k.reshape(B, Hkv, M, C, D).mean(3)
    v_syn = v.reshape(B, Hkv, M, C, D).mean(3)
    counts = jnp.full((B, M), float(C))
    sm = float(1 / np.sqrt(D))

    exact = jax.jit(lambda q, k, v: ops.exact_decode_attention(
        q, k, v, sm_scale=sm, impl="xla"))
    syn = jax.jit(lambda q, k, v, ks_, vs, c: ops.synopsis_attention(
        q, k, v, ks_, vs, c, i_max=32, sm_scale=sm, impl="xla"))
    t_e = _time(exact, q, k, v)
    t_s = _time(syn, q, k, v, k_syn, v_syn, counts)
    out[f"exact_S{S}_us"] = t_e
    out[f"synopsis_S{S}_us"] = t_s
    out[f"speedup_S{S}"] = t_e / t_s
  return out
