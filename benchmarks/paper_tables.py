"""Benchmarks mirroring the paper's tables/figures.

table1 — p99.9 component latency (ms) by technique x arrival rate
table2 — accuracy-loss % by technique x arrival rate
fig3   — synopsis creation vs incremental update wall time
fig4   — ranked-section concentration of accuracy-relevant data
fig5   — hour-long Sogou-like trace: p99.9 per minute, 3 techniques
fig6   — accuracy loss on the same trace
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import synopsis as syn_lib
from repro.serving.apps import SearchEngine, movielens_like, webpages_like
from repro.serving.service import ScatterGatherService, ServiceConfig
from repro.serving.workload import CF_RATES, hour_trace


def table1_table2(duration_s: float = 3.0) -> Dict[str, Dict[int, dict]]:
  out: Dict[str, Dict[int, dict]] = {}
  for tech in ("basic", "reissue", "partial", "accuracytrader"):
    out[tech] = {}
    for rate in CF_RATES:
      svc = ScatterGatherService(ServiceConfig(
          n_components=24, technique=tech, deadline_ms=100.0, seed=3))
      out[tech][rate] = svc.run_open_loop(rate, duration_s)
  return out


def fig3_update_overheads() -> Dict[str, float]:
  data, mask = movielens_like(2048, 256, density=0.15, seed=0)
  t0 = time.perf_counter()
  s = syn_lib.build(data, 32, mask=mask)
  jax.block_until_ready(s.centroids)
  t_create = time.perf_counter() - t0

  res = {"create_s": t_create}
  for pct in (1, 5, 10):
    k = max(1, 2048 * pct // 100)
    rows = jnp.arange(k)
    d2 = data.at[rows].add(0.5)
    f = jax.jit(lambda d, r: syn_lib.update_changed(s, d, mask, r))
    f(d2, rows)  # compile
    t0 = time.perf_counter()
    jax.block_until_ready(f(d2, rows).centroids)
    res[f"update_changed_{pct}pct_s"] = time.perf_counter() - t0
  return res


def fig4_concentration(n_queries: int = 30) -> List[float]:
  docs = webpages_like(4096, 512, seed=2)
  se = SearchEngine(docs, num_clusters=64)
  rng = np.random.default_rng(0)
  sections = np.zeros(10)
  for qi in range(n_queries):
    qv = docs[rng.integers(0, 4096)]
    scores = np.asarray(se.syn.centroids @ qv)
    order = np.argsort(-scores)
    rank = np.empty_like(order)
    rank[order] = np.arange(len(order))
    top = np.asarray(se.search_exact(qv))
    sec = rank[np.asarray(se.syn.row_cluster)[top]] * 10 // 64
    for x in sec:
      sections[x] += 1
  return (100.0 * sections / max(sections.sum(), 1)).tolist()


def fig5_fig6_trace(hour: int = 9, sessions: int = 12) -> dict:
  rates = hour_trace(hour, sessions=sessions)
  out = {}
  for tech in ("basic", "reissue", "accuracytrader"):
    svc = ScatterGatherService(ServiceConfig(
        n_components=24, technique=tech, deadline_ms=100.0, seed=hour))
    p999, loss = [], []
    for r in rates:
      s = svc.run_open_loop(float(r), 1.0)
      p999.append(s["p999"])
      loss.append(s["accuracy_loss_pct"])
    out[tech] = {"p999_per_min": p999, "loss_per_min": loss}
  return out
