"""Benchmark harness: one function per paper table/figure + kernel bench.

Prints ``name,us_per_call,derived`` CSV rows.  The roofline benchmark
reads the dry-run artifacts (run ``python -m repro.launch.dryrun --all``
first for the full 40-cell table; missing cells are skipped here).

All five committed baselines regenerate from this one entry point:

  python -m benchmarks.run --kernels-only --json BENCH_decode.json
  python -m benchmarks.run --prefill-only --json BENCH_prefill.json
  python -m benchmarks.run --serving-only --json BENCH_serving.json
  python -m benchmarks.run --cluster-only --json BENCH_cluster.json
  python -m benchmarks.run --fleet-only   --json BENCH_fleet.json
  python -m benchmarks.run --cache-only   --json BENCH_cache.json
  python -m benchmarks.run --accuracy-only --json BENCH_accuracy.json

(``--serving-only`` / ``--cluster-only`` / ``--fleet-only`` /
``--cache-only`` / ``--accuracy-only`` pass through to
``benchmarks.serving_bench`` / ``benchmarks.cluster_bench`` /
``benchmarks.fleet_bench`` / ``benchmarks.cache_bench`` /
``benchmarks.accuracy_bench``; ``--smoke`` forwards too.)  Every JSON
carries ``meta.schema_version`` and the git revision that produced it
(benchmarks/common.py).
"""
from __future__ import annotations

import glob
import json
import os
import time


def _row(name, us, derived=""):
  print(f"{name},{us:.1f},{derived}")


def bench_table1_table2():
  from benchmarks.paper_tables import table1_table2
  t0 = time.perf_counter()
  res = table1_table2(duration_s=2.0)
  us = (time.perf_counter() - t0) * 1e6
  for rate in (20, 60, 100):
    basic = res["basic"][rate]["p999"]
    reissue = res["reissue"][rate]["p999"]
    at = res["accuracytrader"][rate]["p999"]
    _row(f"table1_p999_rate{rate}", us,
         f"basic={basic:.0f}ms reissue={reissue:.0f}ms at={at:.0f}ms "
         f"speedup_vs_reissue={reissue / max(at, 1e-9):.1f}x")
  for rate in (20, 60, 100):
    pe = res["partial"][rate]["accuracy_loss_pct"]
    at = res["accuracytrader"][rate]["accuracy_loss_pct"]
    _row(f"table2_accloss_rate{rate}", us,
         f"partial={pe:.2f}% at={at:.2f}% "
         f"reduction={pe / max(at, 1e-3):.1f}x")


def bench_fig3():
  from benchmarks.paper_tables import fig3_update_overheads
  t0 = time.perf_counter()
  res = fig3_update_overheads()
  us = (time.perf_counter() - t0) * 1e6
  _row("fig3_synopsis_update", us,
       " ".join(f"{k}={v * 1e3:.1f}ms" for k, v in res.items()))


def bench_fig4():
  from benchmarks.paper_tables import fig4_concentration
  t0 = time.perf_counter()
  sections = fig4_concentration()
  us = (time.perf_counter() - t0) * 1e6
  _row("fig4_concentration", us,
       "pct_per_decile=" + "/".join(f"{s:.0f}" for s in sections))


def bench_fig5_fig6():
  from benchmarks.paper_tables import fig5_fig6_trace
  t0 = time.perf_counter()
  res = fig5_fig6_trace(hour=9, sessions=6)
  us = (time.perf_counter() - t0) * 1e6
  for tech, d in res.items():
    _row(f"fig5_hour9_{tech}", us,
         f"max_p999={max(d['p999_per_min']):.0f}ms "
         f"mean_loss={sum(d['loss_per_min']) / len(d['loss_per_min']):.2f}%")


def bench_prefill(collect=None):
  """Prefill + synopsis-build sweeps (EXPERIMENTS.md §Prefill)."""
  from benchmarks.kernels_bench import build_sweep, prefill_sweep
  pf = prefill_sweep()
  for S in (1024, 4096):
    _row(f"kernel_prefill_S{S}", pf[f"prefill_xla_S{S}_us"],
         f"chain={pf[f'prefill_chain_S{S}_us']:.0f}us "
         f"xla_speedup={pf[f'prefill_xla_speedup_S{S}']:.2f}x")
  _row("kernel_prefill_impl_ratio", pf["prefill_xla_S256_us"],
       f"impl={pf['prefill_impl']} "
       f"ratio_vs_xla={pf['prefill_impl_ratio_S256']:.2f}x")
  bd = build_sweep()
  for S in (4096, 16384):
    _row(f"kernel_build_S{S}", bd[f"build_fused_xla_S{S}_us"],
         f"chain={bd[f'build_chain_S{S}_us']:.0f}us "
         f"fused_speedup={bd[f'build_fused_speedup_S{S}']:.2f}x")
  _row("kernel_build_impl_ratio", bd["build_xla_S256_us"],
       f"impl={bd['build_impl']} "
       f"ratio_vs_xla={bd['build_impl_ratio_S256']:.2f}x")
  if collect is not None:
    collect["prefill"] = pf
    collect["build"] = bd


def bench_kernels(collect=None):
  from benchmarks.kernels_bench import (decode_attention_sweep,
                                        fusion_sweep, pallas_vs_xla_sweep)
  t0 = time.perf_counter()
  res = decode_attention_sweep()
  us = (time.perf_counter() - t0) * 1e6
  for S in (4096, 16384):
    _row(f"kernel_decode_S{S}", res[f"synopsis_S{S}_us"],
         f"exact={res[f'exact_S{S}_us']:.0f}us "
         f"speedup={res[f'speedup_S{S}']:.2f}x "
         f"fused_speedup={res[f'speedup_fused_S{S}']:.2f}x")
  fus = fusion_sweep()
  for S in (4096, 16384):
    _row(f"kernel_fusion_S{S}", fus[f"syn_stage_fused_S{S}_us"],
         f"stage_unfused={fus[f'syn_stage_unfused_S{S}_us']:.0f}us "
         f"stage_fused_speedup={fus[f'syn_stage_fused_speedup_S{S}']:.2f}x "
         f"e2e_fused_speedup={fus[f'e2e_fused_speedup_S{S}']:.2f}x")
  pvx = pallas_vs_xla_sweep()
  _row("kernel_impl_ratio", pvx["fused_xla_S2048_us"],
       f"impl={pvx['pallas_impl']} "
       f"ratio_vs_xla={pvx['pallas_vs_xla_ratio_S2048']:.2f}x")
  if collect is not None:
    collect["decode"] = res
    collect["fusion"] = fus
    collect["impl_ratio"] = pvx


def bench_quant(collect=None):
  """Quantized-synopsis sweep + serving arm (EXPERIMENTS.md
  §Quantization; DESIGN.md §15).  The headline is the *predicted*
  stage-1 bytes reduction — the measured XLA-proxy ratio is reported
  honestly but is not the claim (the proxy materializes f32 dequant
  copies)."""
  from benchmarks.kernels_bench import quant_serving_arm, quant_sweep
  qs = quant_sweep()
  for S in (4096, 16384):
    _row(f"kernel_quant_S{S}", qs[f"fused_int8kv_S{S}_us"],
         f"f32={qs[f'fused_f32_S{S}_us']:.0f}us "
         f"proxy_ratio={qs[f'measured_proxy_ratio_S{S}']:.2f}x "
         f"pred_stage1_red_vs_bf16="
         f"{qs[f'pred_stage1_reduction_int8_vs_bf16_S{S}']:.2f}x "
         f"pred_stage1_red_vs_f32="
         f"{qs[f'pred_stage1_reduction_int8_vs_f32_S{S}']:.2f}x "
         f"inc_loss={qs[f'incremental_loss_S{S}']:.4f}")
  _row("kernel_quant_parity", 0.0,
       f"impl={qs['quant_impl']} "
       f"build_int_diff={qs['interpret_build_max_int_diff']:.0f} "
       f"fused_dev={qs['interpret_fused_dev']:.2e}")
  sv = quant_serving_arm()
  _row("serving_quant", 0.0,
       f"loss_none={sv['engine_none_accuracy_loss_pct']:.2f}% "
       f"loss_int8={sv['engine_int8_accuracy_loss_pct']:.2f}% "
       f"loss_int8_kv={sv['engine_int8_kv_accuracy_loss_pct']:.2f}%")
  checks = {k: v for k, v in {**qs, **sv}.items()
            if k.startswith("check_")}
  _row("quant_checks", 0.0,
       " ".join(f"{k}={v}" for k, v in sorted(checks.items())))
  if collect is not None:
    collect["quant"] = qs
    collect["quant_serving"] = sv
  if not all(checks.values()):
    raise SystemExit(f"quant gates failed: {checks}")


def bench_roofline():
  art = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")
  files = sorted(glob.glob(os.path.join(art, "*__single__*.json")))
  if not files:
    _row("roofline", 0.0, "no dry-run artifacts (run repro.launch.dryrun)")
    return
  worst = None
  for f in files:
    d = json.load(open(f))
    r = d["roofline"]
    name = f"{d['arch']}|{d['shape']}|{d['mode']}"
    _row(f"roofline_{name}", r["bound_s"] * 1e6,
         f"dom={r['dominant']} comp={r['compute_s']:.2e} "
         f"mem={r['memory_s']:.2e} coll={r['collective_s']:.2e} "
         f"fits={d['fits_hbm']}")
    frac = r["compute_s"] / max(r["bound_s"], 1e-30)
    if worst is None or frac < worst[1]:
      worst = (name, frac)
  if worst:
    _row("roofline_worst_compute_fraction", 0.0,
         f"{worst[0]} compute/bound={worst[1]:.3f}")


def main() -> None:
  import argparse
  ap = argparse.ArgumentParser()
  ap.add_argument("--json", default=None, metavar="PATH",
                  help="also dump the kernel-bench numbers as a JSON "
                       "baseline (e.g. BENCH_decode.json)")
  ap.add_argument("--kernels-only", action="store_true",
                  help="skip the service-simulation tables (CI smoke)")
  ap.add_argument("--prefill-only", action="store_true",
                  help="run only the prefill + synopsis-build sweeps "
                       "(BENCH_prefill.json baseline)")
  ap.add_argument("--quant-only", action="store_true",
                  help="run only the quantized-synopsis sweep + serving "
                       "arm (DESIGN.md §15) and MERGE the result into "
                       "--json if the file already exists (re-stamps "
                       "meta); exits non-zero if a quant gate fails")
  ap.add_argument("--serving-only", action="store_true",
                  help="pass through to benchmarks.serving_bench "
                       "(BENCH_serving.json baseline)")
  ap.add_argument("--cluster-only", action="store_true",
                  help="pass through to benchmarks.cluster_bench "
                       "(BENCH_cluster.json baseline; forces host "
                       "devices before jax initialises)")
  ap.add_argument("--fleet-only", action="store_true",
                  help="pass through to benchmarks.fleet_bench "
                       "(BENCH_fleet.json baseline: materialized-replica "
                       "hedge + 24-hour autoscaler frontier; forces "
                       "R*N host devices before jax initialises)")
  ap.add_argument("--cache-only", action="store_true",
                  help="pass through to benchmarks.cache_bench "
                       "(BENCH_cache.json baseline)")
  ap.add_argument("--accuracy-only", action="store_true",
                  help="pass through to benchmarks.accuracy_bench "
                       "(BENCH_accuracy.json baseline: estimator "
                       "calibration + ε-sweep)")
  ap.add_argument("--smoke", action="store_true",
                  help="forwarded to --serving-only / --cluster-only / "
                       "--fleet-only / --cache-only / --accuracy-only")
  ap.add_argument("--impl", default=None,
                  choices=["auto", "pallas", "xla", "interpret"],
                  help="forwarded to --serving-only / --cluster-only / "
                       "--fleet-only / --cache-only / --accuracy-only")
  args = ap.parse_args()

  if (args.serving_only or args.cluster_only or args.fleet_only
      or args.cache_only or args.accuracy_only):
    # Dispatch BEFORE anything imports jax: cluster_bench/fleet_bench
    # must force the per-component host devices first.
    sub = ["--json", args.json] if args.json else []
    sub += ["--smoke"] if args.smoke else []
    sub += ["--impl", args.impl] if args.impl else []
    if args.cluster_only:
      from benchmarks.cluster_bench import main as cluster_main
      return cluster_main(sub)
    if args.fleet_only:
      from benchmarks.fleet_bench import main as fleet_main
      return fleet_main(sub)
    if args.cache_only:
      from benchmarks.cache_bench import main as cache_main
      return cache_main(sub)
    if args.accuracy_only:
      from benchmarks.accuracy_bench import main as accuracy_main
      return accuracy_main(sub)
    from benchmarks.serving_bench import main as serving_main
    return serving_main(sub)

  print("name,us_per_call,derived")
  collect = {} if args.json else None
  if args.prefill_only:
    bench_prefill(collect)
  elif args.quant_only:
    bench_quant(collect)
  else:
    if not args.kernels_only:
      bench_table1_table2()
      bench_fig3()
      bench_fig4()
      bench_fig5_fig6()
    bench_kernels(collect)
    bench_prefill(collect)
    bench_quant(collect)
    bench_roofline()
  if args.json:
    from benchmarks.common import bench_meta
    meta = bench_meta()
    if args.quant_only and os.path.exists(args.json):
      # Standalone regeneration: fold the quant section into the
      # existing baseline (BENCH_decode.json) instead of clobbering the
      # kernel sweeps, and re-stamp meta to the producing revision.
      with open(args.json) as f:
        prev = json.load(f)
      prev.update(collect)
      prev["meta"] = meta
      collect = {k: v for k, v in prev.items() if k != "meta"}
    with open(args.json, "w") as f:
      json.dump({"meta": meta, **collect}, f, indent=1, sort_keys=True)
    print(f"# wrote {args.json}")


if __name__ == "__main__":
  main()
