"""Benchmark harness: one function per paper table/figure + kernel bench.

Prints ``name,us_per_call,derived`` CSV rows.  The roofline benchmark
reads the dry-run artifacts (run ``python -m repro.launch.dryrun --all``
first for the full 40-cell table; missing cells are skipped here).
"""
from __future__ import annotations

import glob
import json
import os
import time


def _row(name, us, derived=""):
  print(f"{name},{us:.1f},{derived}")


def bench_table1_table2():
  from benchmarks.paper_tables import table1_table2
  t0 = time.perf_counter()
  res = table1_table2(duration_s=2.0)
  us = (time.perf_counter() - t0) * 1e6
  for rate in (20, 60, 100):
    basic = res["basic"][rate]["p999"]
    reissue = res["reissue"][rate]["p999"]
    at = res["accuracytrader"][rate]["p999"]
    _row(f"table1_p999_rate{rate}", us,
         f"basic={basic:.0f}ms reissue={reissue:.0f}ms at={at:.0f}ms "
         f"speedup_vs_reissue={reissue / max(at, 1e-9):.1f}x")
  for rate in (20, 60, 100):
    pe = res["partial"][rate]["accuracy_loss_pct"]
    at = res["accuracytrader"][rate]["accuracy_loss_pct"]
    _row(f"table2_accloss_rate{rate}", us,
         f"partial={pe:.2f}% at={at:.2f}% "
         f"reduction={pe / max(at, 1e-3):.1f}x")


def bench_fig3():
  from benchmarks.paper_tables import fig3_update_overheads
  t0 = time.perf_counter()
  res = fig3_update_overheads()
  us = (time.perf_counter() - t0) * 1e6
  _row("fig3_synopsis_update", us,
       " ".join(f"{k}={v * 1e3:.1f}ms" for k, v in res.items()))


def bench_fig4():
  from benchmarks.paper_tables import fig4_concentration
  t0 = time.perf_counter()
  sections = fig4_concentration()
  us = (time.perf_counter() - t0) * 1e6
  _row("fig4_concentration", us,
       "pct_per_decile=" + "/".join(f"{s:.0f}" for s in sections))


def bench_fig5_fig6():
  from benchmarks.paper_tables import fig5_fig6_trace
  t0 = time.perf_counter()
  res = fig5_fig6_trace(hour=9, sessions=6)
  us = (time.perf_counter() - t0) * 1e6
  for tech, d in res.items():
    _row(f"fig5_hour9_{tech}", us,
         f"max_p999={max(d['p999_per_min']):.0f}ms "
         f"mean_loss={sum(d['loss_per_min']) / len(d['loss_per_min']):.2f}%")


def bench_kernels():
  from benchmarks.kernels_bench import decode_attention_sweep
  t0 = time.perf_counter()
  res = decode_attention_sweep()
  us = (time.perf_counter() - t0) * 1e6
  for S in (4096, 16384):
    _row(f"kernel_decode_S{S}", res[f"synopsis_S{S}_us"],
         f"exact={res[f'exact_S{S}_us']:.0f}us "
         f"speedup={res[f'speedup_S{S}']:.2f}x")


def bench_roofline():
  art = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")
  files = sorted(glob.glob(os.path.join(art, "*__single__*.json")))
  if not files:
    _row("roofline", 0.0, "no dry-run artifacts (run repro.launch.dryrun)")
    return
  worst = None
  for f in files:
    d = json.load(open(f))
    r = d["roofline"]
    name = f"{d['arch']}|{d['shape']}|{d['mode']}"
    _row(f"roofline_{name}", r["bound_s"] * 1e6,
         f"dom={r['dominant']} comp={r['compute_s']:.2e} "
         f"mem={r['memory_s']:.2e} coll={r['collective_s']:.2e} "
         f"fits={d['fits_hbm']}")
    frac = r["compute_s"] / max(r["bound_s"], 1e-30)
    if worst is None or frac < worst[1]:
      worst = (name, frac)
  if worst:
    _row("roofline_worst_compute_fraction", 0.0,
         f"{worst[0]} compute/bound={worst[1]:.3f}")


def main() -> None:
  print("name,us_per_call,derived")
  bench_table1_table2()
  bench_fig3()
  bench_fig4()
  bench_fig5_fig6()
  bench_kernels()
  bench_roofline()


if __name__ == "__main__":
  main()
