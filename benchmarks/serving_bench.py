"""Serving-engine sweep: accuracy loss vs tail latency vs load, from
MEASURED step latencies (DESIGN.md §8; the paper's Tables 1-2 shape).

Unlike ``benchmarks/paper_tables.py`` (discrete-event simulation), every
latency here is the wall time of a real dispatched program on the kernel
path — prefill, synopsis build, bucketed serve steps — driven by the
continuous-batching engine over Poisson arrival traces.  Per (policy,
rate) it reports p50/p99/p99.9 component latency, accuracy-loss %, the
deadline-miss rate and the mean refinement budget.

  PYTHONPATH=src:. python -m benchmarks.serving_bench \
      --json BENCH_serving.json          # committed baseline
  PYTHONPATH=src:. python -m benchmarks.serving_bench --smoke   # CI

``admission_sweep`` (DESIGN.md §11) A/Bs the queue-aware predictive
admission policy at the saturated top rate: FIFO-no-shed vs EDF with
predictive shed-at-admission over two SLO classes on the identical
trace — EDF+shed must beat FIFO on served p99 at equal-or-better
goodput, and shed requests must burn zero prefill.

CPU wall times are proxies for the TPU target (see ROADMAP's real-TPU
validation item); the *relations* — AccuracyTrader holding accuracy loss
near the stage-1 floor while partial execution collapses under load, at
equal deadline — are what transfer.
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, Optional, Sequence


def serving_sweep(rates: Sequence[float],
                  policies: Sequence[str] = ("basic", "partial",
                                             "accuracytrader"),
                  *,
                  n_slots: int = 4,
                  prompt_len: int = 128,
                  max_new_tokens: int = 8,
                  deadline_ms: float = 60.0,
                  duration_s: float = 1.0,
                  arch: str = "llama3-8b",
                  impl: Optional[str] = None,
                  seed: int = 2) -> Dict:
  """One engine per policy (compiled program set reused across rates; the
  calibrated latency model persists across windows, as in the simulator)."""
  from repro.configs.registry import get_config
  from repro.serve.engine import EngineConfig, ServingEngine, run_open_loop

  cfg = get_config(arch, smoke=True)
  out: Dict = {"sweep": {}, "config": {
      "arch": arch, "n_slots": n_slots, "prompt_len": prompt_len,
      "max_new_tokens": max_new_tokens, "deadline_ms": deadline_ms,
      "duration_s": duration_s, "rates": list(rates), "seed": seed,
      # Arrival traces are seeded per (policy, rate) run below, so every
      # policy sees the identical trace at each rate and re-running the
      # bench reproduces the same arrivals — JSON diffs across PRs only
      # reflect code changes, not RNG drift.  Seed audit: these engines
      # run the single-component path (no backend), so the seed drives
      # arrivals/prompts only — there is no service-noise RNG to
      # accidentally share across arms (the seed-reuse bug class;
      # backend sweeps must pass a per-arm ``service_seed``, see
      # benchmarks/accuracy_bench.py and tests/test_estimator.py).
      "trace_seed_rule": "seed*1000 + rate_index"}}
  for policy in policies:
    eng = ServingEngine(cfg, EngineConfig(
        n_slots=n_slots, prompt_len=prompt_len,
        max_new_tokens=max_new_tokens, deadline_ms=deadline_ms,
        policy=policy, impl=impl, seed=seed))
    out["config"]["impl"] = eng.impl
    out["config"]["buckets"] = list(eng.buckets)
    rows = {}
    for ri, rate in enumerate(rates):
      s = run_open_loop(eng, rate_per_s=float(rate),
                        duration_s=duration_s, seed=seed * 1000 + ri)
      rows[str(rate)] = {k: round(float(v), 3) for k, v in s.items()}
      print(f"serving_{policy}_rate{rate},{s['mean'] * 1e3:.1f},"
            f"p99={s['p99']:.1f}ms p999={s['p999']:.1f}ms "
            f"loss={s['accuracy_loss_pct']:.2f}% "
            f"shed={s['shed_pct']:.1f}% "
            f"miss={s['deadline_miss_pct']:.1f}% "
            f"budget={s['mean_budget']:.2f}")
    out["sweep"][policy] = rows
  # Admission/decode overlap A/B (ROADMAP: serialized admission was the
  # saturation point): same policy + top rate with the overlap disabled.
  ab_policy = "accuracytrader" if "accuracytrader" in policies \
      else policies[-1]
  ab = {}
  for on in (True, False):
    eng = ServingEngine(cfg, EngineConfig(
        n_slots=n_slots, prompt_len=prompt_len,
        max_new_tokens=max_new_tokens, deadline_ms=deadline_ms,
        policy=ab_policy, impl=impl, seed=seed, overlap_admission=on))
    s = run_open_loop(eng, rate_per_s=float(rates[-1]),
                      duration_s=duration_s,
                      seed=seed * 1000 + len(rates) - 1)
    ab["overlap_on" if on else "overlap_off"] = {
        k: round(float(v), 3) for k, v in s.items()}
    print(f"serving_admission_{'overlap' if on else 'serial'},"
          f"{s['mean'] * 1e3:.1f},p99={s['p99']:.1f}ms "
          f"queue_p99={s['queue_p99']:.1f}ms")
  out["admission_overlap"] = {"policy": ab_policy,
                              "rate": float(rates[-1]), **ab}
  # Queue-aware predictive admission at the saturated top rate
  # (DESIGN.md §11): two SLO classes on the identical trace — FIFO
  # ordering with no shedding vs EDF ordering with predictive
  # shed-at-admission.  At 3x saturation FIFO serves everything late;
  # EDF+shed refuses the predicted-dead at admission (before prefill, so
  # zero prefill is burned on them) and spends the reclaimed capacity on
  # requests that can still make their deadline.
  from repro.control import AdmissionConfig, SLOClass
  classes = (SLOClass("interactive", deadline_ms),
             SLOClass("batch", 5.0 * deadline_ms))
  adm = {}
  for name, acfg in (
      ("fifo", AdmissionConfig(order="fifo", shed=False, classes=classes)),
      ("edf_shed", AdmissionConfig(order="edf", shed=True,
                                   classes=classes))):
    eng = ServingEngine(cfg, EngineConfig(
        n_slots=n_slots, prompt_len=prompt_len,
        max_new_tokens=max_new_tokens, deadline_ms=deadline_ms,
        policy=ab_policy, impl=impl, seed=seed, admission=acfg))
    s = run_open_loop(eng, rate_per_s=float(rates[-1]),
                      duration_s=duration_s,
                      seed=seed * 1000 + len(rates) - 1,
                      slo_of=lambda rid: classes[rid % 2].name)
    adm[name] = {k: (v if isinstance(v, dict) else round(float(v), 3))
                 for k, v in s.items()}
    print(f"serving_admission_{name},{s['mean'] * 1e3:.1f},"
          f"p99={s['p99']:.1f}ms shed={s['shed_pct']:.1f}% "
          f"goodput={s['goodput_per_s']:.2f}/s "
          f"prefills={s['prefills']:.0f} served={s['served_n']:.0f}")
  out["admission_sweep"] = {
      "policy": ab_policy, "rate": float(rates[-1]),
      "classes": {c.name: c.deadline_ms for c in classes}, **adm}
  top = str(rates[-1])
  if {"partial", "accuracytrader"} <= set(out["sweep"]):
    at = out["sweep"]["accuracytrader"][top]["accuracy_loss_pct"]
    pe = out["sweep"]["partial"][top]["accuracy_loss_pct"]
    # Recorded, not asserted: the caller judges after the artifact is
    # written (a noisy host must not lose the whole sweep's data).
    out["check"] = {"top_rate": float(rates[-1]),
                    "accuracytrader_loss_pct": at,
                    "partial_loss_pct": pe,
                    "at_loses_less": bool(at < pe)}
  c = out.setdefault("check", {"top_rate": float(rates[-1])})
  c["admission_p99_fifo"] = adm["fifo"]["p99"]
  c["admission_p99_edf"] = adm["edf_shed"]["p99"]
  c["admission_goodput_fifo"] = adm["fifo"]["goodput_per_s"]
  c["admission_goodput_edf"] = adm["edf_shed"]["goodput_per_s"]
  c["admission_shed_pct"] = adm["edf_shed"]["shed_pct"]
  c["edf_shed_beats_fifo"] = bool(
      adm["edf_shed"]["p99"] <= adm["fifo"]["p99"]
      and adm["edf_shed"]["goodput_per_s"]
      >= adm["fifo"]["goodput_per_s"])
  # Shed requests must cost zero prefill: every prefill dispatched this
  # window belongs to a request that was actually served.
  c["shed_burns_no_prefill"] = bool(
      adm["edf_shed"]["prefills"] == adm["edf_shed"]["served_n"]
      and adm["edf_shed"]["served_n"] + adm["edf_shed"]["shed_admission_n"]
      == adm["fifo"]["served_n"] + adm["fifo"]["shed_admission_n"])
  return out


def main(argv: Optional[Sequence[str]] = None) -> None:
  ap = argparse.ArgumentParser()
  ap.add_argument("--json", default=None, metavar="PATH",
                  help="dump the sweep as a JSON baseline "
                       "(e.g. BENCH_serving.json)")
  ap.add_argument("--smoke", action="store_true",
                  help="tiny sweep for CI: 2 rates, short windows")
  ap.add_argument("--impl", default=None,
                  choices=["auto", "pallas", "xla", "interpret"])
  ap.add_argument("--rate-scale", type=float, default=None,
                  help="multiplier on the paper's cf_rates (default: 3.0 "
                       "full, 4.0 smoke — sized so the top rate saturates "
                       "the CPU proxy)")
  args = ap.parse_args(argv)

  from repro.serving.workload import CF_RATES

  print("name,us_per_call,derived")
  t0 = time.perf_counter()
  if args.smoke:
    # The top smoke rate outpaces per-request admission (prefill+build
    # ~ms) by construction, so the window saturates on any host and the
    # partial-vs-accuracytrader ordering is checkable in CI.
    scale = args.rate_scale if args.rate_scale is not None else 4.0
    res = serving_sweep(
        rates=[20 * scale, 100 * scale],
        policies=("partial", "accuracytrader"),
        n_slots=2, prompt_len=64, max_new_tokens=4, deadline_ms=40.0,
        duration_s=0.5, impl=args.impl)
  else:
    scale = args.rate_scale if args.rate_scale is not None else 3.0
    res = serving_sweep(rates=[r * scale for r in CF_RATES],
                        impl=args.impl)
  from benchmarks.common import bench_meta
  res["meta"] = bench_meta(wall_s=round(time.perf_counter() - t0, 1),
                           smoke=bool(args.smoke))
  if args.json:
    with open(args.json, "w") as f:
      json.dump(res, f, indent=1, sort_keys=True)
    print(f"# wrote {args.json}")
  if "check" in res:
    c = res["check"]
    assert c["at_loses_less"], (
        "AccuracyTrader should lose less accuracy than partial at the "
        f"saturated rate {c['top_rate']} (equal deadline): "
        f"at={c['accuracytrader_loss_pct']}% "
        f"partial={c['partial_loss_pct']}%")
    assert c["shed_burns_no_prefill"], (
        "admission-shed requests must never reach prefill: "
        f"prefills={res['admission_sweep']['edf_shed']['prefills']} "
        f"served={res['admission_sweep']['edf_shed']['served_n']}")
    assert c["edf_shed_beats_fifo"], (
        "EDF + predictive shed should beat FIFO on served p99 at equal-"
        f"or-better goodput under saturation: edf p99="
        f"{c['admission_p99_edf']}ms goodput="
        f"{c['admission_goodput_edf']}/s vs fifo p99="
        f"{c['admission_p99_fifo']}ms goodput="
        f"{c['admission_goodput_fifo']}/s")


if __name__ == "__main__":
  main()
