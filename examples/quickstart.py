"""Quickstart: train smollm-135m (the ~100M assigned arch) end to end.

Runs the real training stack — data pipeline, AdamW, remat'd scanned
blocks, checkpointing + restart — on whatever devices are available.

  # CPU demo (reduced width, ~1 min):
  PYTHONPATH=src python examples/quickstart.py

  # the real thing (full config, few hundred steps) on a TPU slice:
  PYTHONPATH=src python examples/quickstart.py --full --steps 300 \
      --batch 64 --seq 2048
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs.registry import get_config
from repro.models import common as cm
from repro.models import transformer as tf
from repro.train import checkpoint as ckpt_lib
from repro.train.data import DataConfig, TokenStream
from repro.train.optimizer import OptConfig
from repro.train.train_step import init_train_state, make_train_step


def main():
  ap = argparse.ArgumentParser()
  ap.add_argument("--full", action="store_true",
                  help="full smollm-135m config (use on real hardware)")
  ap.add_argument("--steps", type=int, default=30)
  ap.add_argument("--batch", type=int, default=8)
  ap.add_argument("--seq", type=int, default=256)
  ap.add_argument("--ckpt-dir", default="/tmp/repro_quickstart")
  ap.add_argument("--ckpt-every", type=int, default=20)
  args = ap.parse_args()

  cfg = get_config("smollm-135m", smoke=not args.full)
  opt_cfg = OptConfig(lr=1e-3, warmup_steps=10, total_steps=args.steps)

  key = jax.random.PRNGKey(0)
  state, state_axes = init_train_state(key, cfg, opt_cfg)
  n = sum(x.size for x in jax.tree.leaves(state["params"]))
  print(f"arch={cfg.name} params={n/1e6:.1f}M devices={jax.device_count()}")

  data = TokenStream(DataConfig(cfg.vocab, args.seq, args.batch))
  step_fn = jax.jit(make_train_step(cfg, opt_cfg))

  # Fault tolerance: resume from the newest checkpoint if one exists.
  start = 0
  if ckpt_lib.latest_step(args.ckpt_dir) is not None:
    state, start, extras = ckpt_lib.restore(args.ckpt_dir)
    data.load_state_dict(extras["data"])
    print(f"resumed from step {start}")
  ck = ckpt_lib.AsyncCheckpointer()

  t0 = time.time()
  for step in range(start, args.steps):
    tokens, labels = data.batch_at(step)
    state, metrics = step_fn(state, {"tokens": jnp.asarray(tokens),
                                     "labels": jnp.asarray(labels)})
    if step % 5 == 0 or step == args.steps - 1:
      print(f"step {step:4d} loss={float(metrics['loss']):.4f} "
            f"lr={float(metrics['lr']):.2e} "
            f"gnorm={float(metrics['grad_norm']):.2f} "
            f"({(time.time()-t0):.1f}s)")
    if step and step % args.ckpt_every == 0:
      ck.save_async(args.ckpt_dir, step, state,
                    extras={"data": {"step": step, "seed": 0}})
  ck.wait()
  print("done — checkpoints in", args.ckpt_dir)


if __name__ == "__main__":
  main()
