"""Paper service 1: CF recommender with AccuracyTrader (paper §3.2, §4.3).

Builds a MovieLens-scale user-item matrix, creates the per-component
synopsis (aggregated users), and reproduces the accuracy side of Table 2:
RMSE loss vs. refinement budget, compared against partial execution that
processes the same fraction of data *unranked*.

  PYTHONPATH=src python examples/recommender.py [--users 2048 --items 400]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.apps import CFRecommender, movielens_like


def main():
  ap = argparse.ArgumentParser()
  ap.add_argument("--users", type=int, default=2048)
  ap.add_argument("--items", type=int, default=400)
  ap.add_argument("--density", type=float, default=0.15)
  ap.add_argument("--clusters", type=int, default=32)
  ap.add_argument("--active-users", type=int, default=40)
  args = ap.parse_args()

  ratings, mask = movielens_like(args.users, args.items,
                                 density=args.density, seed=1)
  rec = CFRecommender(ratings, mask, num_clusters=args.clusters)
  print(f"matrix {args.users}x{args.items}, "
        f"{int(mask.sum())} ratings, {args.clusters} aggregated users "
        f"({args.users // args.clusters}x compression)")

  rng = np.random.default_rng(0)
  budgets = [0, 1, 2, 4, 8, 16, args.clusters]
  sq_err = {b: [] for b in budgets}
  sq_err["exact"] = []
  sq_err["partial_25"] = []

  for t in range(args.active_users):
    uid = int(rng.integers(0, args.users))
    q_full, qm_full = ratings[uid], mask[uid]
    rated = np.where(np.asarray(qm_full) > 0)[0]
    if len(rated) < 10:
      continue
    test = rng.choice(rated, size=min(10, len(rated) // 2), replace=False)
    qm = qm_full.at[jnp.asarray(test)].set(0.0)   # 80/20 split (paper §4.2)
    q = q_full * qm
    truth = np.asarray(q_full)[test]
    items = jnp.asarray(test)

    ex = np.asarray(rec.predict_exact(q, qm, items))
    sq_err["exact"].append((ex - truth) ** 2)
    for b in budgets:
      pr = np.asarray(rec.predict(q, qm, items, b))
      sq_err[b].append((pr - truth) ** 2)
    # partial execution analogue: an unranked 25% of users (no synopsis)
    keep = rng.random(args.users) < 0.25
    sub = CFRecommenderView(rec, keep)
    pr = np.asarray(sub.predict_exact(q, qm, items))
    sq_err["partial_25"].append((pr - truth) ** 2)

  rmse = {k: float(np.sqrt(np.mean(np.concatenate(v))))
          for k, v in sq_err.items()}
  base = rmse["exact"]
  print(f"\n{'variant':>14s}  {'RMSE':>7s}  {'accuracy loss':>13s}")
  for k in ["exact", "partial_25"] + budgets:
    name = f"budget={k}" if isinstance(k, int) else k
    loss = 100.0 * (rmse[k] - base) / base
    print(f"{name:>14s}  {rmse[k]:7.4f}  {loss:+12.2f}%")
  print("\nAccuracyTrader refines the *most correlated* clusters first, so"
        "\nsmall budgets recover most of the exact accuracy (paper Table 2).")


class CFRecommenderView:
  """Exact CF restricted to a random subset of users (partial execution)."""

  def __init__(self, rec: CFRecommender, keep: np.ndarray):
    import dataclasses
    k = jnp.asarray(keep, jnp.float32)[:, None]
    self.rec = CFRecommender.__new__(CFRecommender)
    self.rec.ratings = rec.ratings * k
    self.rec.mask = rec.mask * k
    self.rec.num_clusters = rec.num_clusters
    self.rec.syn = rec.syn

  def predict_exact(self, q, qm, items):
    return CFRecommender.predict_exact(self.rec, q, qm, items)


if __name__ == "__main__":
  main()
