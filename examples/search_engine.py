"""Paper service 2: web search with AccuracyTrader (paper §3.2, §4.2-4.3).

Synthetic Sogou-like page collection; reproduces Fig 4(b) (ranked
aggregated pages concentrate the true top-10) and the accuracy half of
Fig 6 (top-40% budget recovers ~99% of the true top-10).

  PYTHONPATH=src python examples/search_engine.py [--docs 8192]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.apps import SearchEngine, webpages_like


def main():
  ap = argparse.ArgumentParser()
  ap.add_argument("--docs", type=int, default=8192)
  ap.add_argument("--vocab", type=int, default=1024)
  ap.add_argument("--clusters", type=int, default=128)
  ap.add_argument("--queries", type=int, default=50)
  args = ap.parse_args()

  docs = webpages_like(args.docs, args.vocab, seed=2)
  se = SearchEngine(docs, num_clusters=args.clusters)
  print(f"{args.docs} pages -> {args.clusters} aggregated pages "
        f"({args.docs // args.clusters}x compression)")

  rng = np.random.default_rng(0)

  # --- Fig 4(b): where do the true top-10 pages live in the ranking? ----
  sections = np.zeros(10)
  for qi in range(args.queries):
    qv = docs[rng.integers(0, args.docs)]
    qv = qv + 0.05 * jax.random.normal(jax.random.PRNGKey(qi),
                                       (args.vocab,))
    scores_syn = np.asarray(se.syn.centroids @ qv)
    order = np.argsort(-scores_syn)                     # ranked clusters
    rank_of_cluster = np.empty_like(order)
    rank_of_cluster[order] = np.arange(len(order))
    true_top = np.asarray(se.search_exact(qv))
    cl = np.asarray(se.syn.row_cluster)[true_top]
    sec = rank_of_cluster[cl] * 10 // args.clusters
    for s in sec:
      sections[s] += 1
  sections = 100.0 * sections / sections.sum()
  print("\nFig4(b) — % of true top-10 pages per ranked-cluster decile:")
  print("  " + "  ".join(f"{s:5.1f}%" for s in sections))

  # --- Fig 6-style: accuracy vs refinement budget ------------------------
  print(f"\n{'budget':>8s} {'% clusters':>10s} {'top-10 accuracy':>16s}")
  for frac in [0.0, 0.05, 0.1, 0.2, 0.4, 1.0]:
    budget = int(frac * args.clusters)
    acc = np.mean([
        se.accuracy(docs[rng.integers(0, args.docs)]
                    + 0.05 * jax.random.normal(jax.random.PRNGKey(1000 + i),
                                               (args.vocab,)), budget)
        for i in range(args.queries)])
    print(f"{budget:8d} {100*frac:9.0f}% {100*acc:15.1f}%")
  print("\nThe paper's operating point (top-40% of ranked clusters) keeps"
        "\n~99% of the true top-10 while touching 40% of the data.")


if __name__ == "__main__":
  main()
