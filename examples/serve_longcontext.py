"""Long-context decode with synopsis attention (the long_500k cell's
mechanism, demo-sized for CPU).

Prefills a prompt with llama-family smoke config, builds the KV synopsis
(offline module), then decodes with AccuracyTrader attention at several
budgets, comparing next-token distributions against exact attention —
the LM analogue of the paper's accuracy-loss tables.

  PYTHONPATH=src python examples/serve_longcontext.py [--seq 512]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.models import common as cm
from repro.models import transformer as tf
from repro.serve import synopsis_kv as skv
from repro.serve.kv_cache import n_attn_positions
from repro.serve.prefill import make_prefill_step
from repro.serve.serve_step import make_serve_step


def main():
  ap = argparse.ArgumentParser()
  ap.add_argument("--arch", default="llama3-8b")
  ap.add_argument("--seq", type=int, default=512)
  ap.add_argument("--batch", type=int, default=2)
  ap.add_argument("--tokens", type=int, default=8)
  args = ap.parse_args()

  cfg = get_config(args.arch, smoke=True)
  assert n_attn_positions(cfg) > 0, "synopsis attention needs attention"
  key = jax.random.PRNGKey(0)
  params, _ = cm.split(tf.init_model(key, cfg))
  params = jax.tree.map(lambda p: p.astype(cfg.dtype), params)

  B, S = args.batch, args.seq
  prompt = jax.random.randint(key, (B, S), 0, cfg.vocab)
  print(f"prefill {S} tokens ({cfg.name})...")
  _, cache = jax.jit(make_prefill_step(cfg))(params, prompt)
  print("building synopsis (offline module): "
        f"C={cfg.synopsis.cluster_size}, M={S // cfg.synopsis.cluster_size}")
  syn_cache = jax.jit(lambda c: skv.build(c, cfg))(cache)

  M = S // cfg.synopsis.cluster_size
  exact_step = jax.jit(make_serve_step(cfg, mode="exact"))
  nt = jax.random.randint(jax.random.PRNGKey(7), (B, 1), 0, cfg.vocab)

  print(f"\n{'i_max':>6s} {'kv rows touched':>16s} {'TV-dist to exact':>17s} "
        f"{'argmax match':>13s}")
  lg_ex, _ = exact_step(params, cache, nt)
  p_ex = jax.nn.softmax(lg_ex.astype(jnp.float32), -1)
  for i_max in [0, 1, 2, M // 2, M]:
    step = jax.jit(make_serve_step(cfg, mode="synopsis", i_max=i_max))
    lg, _ = step(params, syn_cache, nt)
    p = jax.nn.softmax(lg.astype(jnp.float32), -1)
    tv = float(0.5 * jnp.abs(p - p_ex).sum(-1).mean())
    match = float((jnp.argmax(lg, -1) == jnp.argmax(lg_ex, -1)).mean())
    rows = M + i_max * cfg.synopsis.cluster_size
    print(f"{i_max:6d} {rows:10d}/{S:5d} {tv:17.4f} {100*match:12.0f}%")

  print("\nAt the long_500k production shape the same mechanism touches "
        "S/C + i_max*C + R\nrows instead of 524288 — see "
        "artifacts/dryrun/*long_500k* for the roofline.")


if __name__ == "__main__":
  main()
