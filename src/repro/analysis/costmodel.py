"""Analytic FLOPs/bytes model per (arch x shape x mode) cell.

``compiled.cost_analysis()`` on this host counts ``while``-loop bodies
once, so scanned-layer models under-report by ~n_layers x.  Since every
matmul in this framework is an einsum we wrote, the exact counts are
derivable in closed form; EXPERIMENTS.md §Roofline uses these, with the
raw cost_analysis kept in the artifacts for cross-checking (they agree on
loop-free modules — see tests/test_costmodel.py).

Conventions: flops = 2*M*N*K per matmul; train total = 4x forward
(backward 2x + full-remat forward re-run 1x); bytes = weight traffic +
optimizer state + activation/cache traffic (leading terms only).
"""
from __future__ import annotations

import dataclasses

from repro.configs.shapes import ShapeSpec
from repro.models import common as cm


def _attn_layer_flops(cfg: cm.ModelConfig, s_q: int, s_kv: float,
                      cross: bool = False) -> float:
  """Per-sequence forward flops of one attention layer (GQA or MLA)."""
  d, H, Hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
  if cfg.mla and not cross:
    m = cfg.mla
    qk = m.qk_nope_dim + m.qk_rope_dim
    proj = (d * m.q_lora_rank + m.q_lora_rank * H * qk) * s_q
    proj += d * (m.kv_lora_rank + m.qk_rope_dim) * s_q
    proj += m.kv_lora_rank * H * (m.qk_nope_dim + m.v_head_dim) * s_q
    proj += H * m.v_head_dim * d * s_q
    quad = s_q * s_kv * H * 2 * qk          # scores + (padded) values
  else:
    proj = d * hd * (2 * H + 2 * Hkv) * s_q
    quad = s_q * s_kv * H * 2 * hd
  return 2.0 * (proj + quad)


def _mlp_flops(cfg, s_q):
  return 2.0 * 3 * cfg.d_model * cfg.d_ff * s_q if cfg.d_ff else 0.0


def _moe_flops(cfg, s_q):
  e = cfg.moe
  per_tok = 2.0 * cfg.d_model * e.num_experts                 # router
  per_tok += 2.0 * 3 * cfg.d_model * e.d_ff_expert * (
      e.top_k * e.capacity_factor + e.num_shared)
  if e.dense_parallel:
    per_tok += 2.0 * 3 * cfg.d_model * cfg.d_ff
  return per_tok * s_q


def _ssm_flops(cfg, s_q):
  s = cfg.ssm
  d = cfg.d_model
  d_in = s.expand * d
  h = d_in // s.head_dim
  n, p, L = s.d_state, s.head_dim, min(s.chunk, max(s_q, 1))
  proj = 2.0 * d * (2 * d_in + 2 * n + h) + 2.0 * d_in * d
  if s_q == 1:                               # decode recurrence
    ssd = 2.0 * 2 * h * p * n
  else:
    ssd = 2.0 * (L * n + L * h * p + 2 * n * h * p)
  return (proj + ssd) * s_q


def _layer_flops(cfg, spec: cm.LayerSpec, s_q, s_kv) -> float:
  f = 0.0
  if spec.kind == "attn":
    f += _attn_layer_flops(cfg, s_q, s_kv)
    if spec.cross_attn:
      f += _attn_layer_flops(cfg, s_q, cfg.encoder.source_len, cross=True)
  else:
    f += _ssm_flops(cfg, s_q)
  if spec.use_moe and cfg.moe:
    f += _moe_flops(cfg, s_q)
  else:
    f += _mlp_flops(cfg, s_q)
  return f


@dataclasses.dataclass
class CellCost:
  flops_global: float          # whole step, all chips
  bytes_global: float


def cell_cost(cfg: cm.ModelConfig, shape: ShapeSpec, mode: str,
              i_max: int | None = None,
              causal_skip: bool = False) -> CellCost:
  B, S = shape.global_batch, shape.seq_len
  kind = shape.kind
  sc = cfg.synopsis
  i_max = sc.i_max if i_max is None else i_max
  text = S - (cfg.frontend_tokens if cfg.frontend == "vision_stub" else 0)

  if kind in ("train", "prefill"):
    s_q = S
    # mean causal kv length: ~S/2 with causal_skip (each q-chunk touches
    # only keys up to its position), else full S (masked-full baseline).
    s_kv = S / 2 + 256 if causal_skip else S
  else:
    s_q = 1
    if mode == "synopsis":
      s_kv = S // sc.cluster_size + i_max * sc.cluster_size + sc.recent
    else:
      s_kv = S

  per_seq = 0.0
  for spec in cfg.block_pattern:
    per_seq += _layer_flops(cfg, spec, s_q, s_kv) * cfg.n_blocks
  # gemma2-style local layers: cap kv at the window.
  if any(sp.local for sp in cfg.block_pattern) and kind == "decode":
    # recompute local layers with windowed kv
    per_seq = 0.0
    for spec in cfg.block_pattern:
      kv = min(cfg.sliding_window, S) if spec.local else s_kv
      per_seq += _layer_flops(cfg, spec, s_q, kv) * cfg.n_blocks

  if cfg.encoder is not None and kind in ("train", "prefill"):
    T = cfg.encoder.source_len
    enc_cfg = cfg
    per_seq += cfg.encoder.n_layers * (
        _attn_layer_flops(enc_cfg, T, T)
        + 2.0 * 3 * cfg.d_model * cfg.encoder.d_ff * T)

  # unembed (+ frontend proj)
  tok_out = text if kind == "train" else (1 if kind == "decode" else 1)
  per_seq += 2.0 * cfg.d_model * cfg.vocab * (
      text if kind in ("train",) else 1)
  if cfg.frontend:
    per_seq += 2.0 * cfg.frontend_dim * cfg.d_model * (
        cfg.frontend_tokens or (cfg.encoder.source_len if cfg.encoder else 0))

  fwd = per_seq * B
  mult = 4.0 if kind == "train" else 1.0       # bwd 2x + remat re-fwd 1x
  flops = fwd * mult

  # ---- bytes (leading terms) --------------------------------------------
  n_params = cfg.param_count()
  act_bytes = 2.0 * B * max(s_q, 1) * cfg.d_model * cfg.n_layers * 4
  if kind == "train":
    # bf16 weights read fwd+bwd+remat, f32 grads w, master+m+v rw.
    w_bytes = n_params * (2 * 3 + 4 + 3 * 4 * 2)
    byts = w_bytes + act_bytes * 3
  elif kind == "prefill":
    byts = n_params * 2 + act_bytes + 2.0 * B * S * cfg.n_layers * (
        _cache_row_bytes(cfg))
  else:
    byts = n_params * 2 * min(1.0, B) + _decode_cache_bytes(cfg, B, S, mode,
                                                            i_max)
    byts += n_params * 2 if B >= 1 else 0
  return CellCost(flops_global=flops, bytes_global=byts)


def _cache_row_bytes(cfg) -> float:
  Hkv, Dk, Dv = _kv_dims(cfg)
  return Hkv * (Dk + Dv) * 2.0


def _kv_dims(cfg):
  if cfg.mla:
    m = cfg.mla
    return 1, m.kv_lora_rank + m.qk_rope_dim, m.kv_lora_rank + m.qk_rope_dim
  return cfg.n_kv_heads, cfg.hd, cfg.hd


def _decode_cache_bytes(cfg, B, S, mode, i_max) -> float:
  na = sum(1 for s in cfg.block_pattern if s.kind == "attn")
  layers_attn = na * cfg.n_blocks
  row = _cache_row_bytes(cfg)
  sc = cfg.synopsis
  if mode == "synopsis":
    rows = S // sc.cluster_size + i_max * sc.cluster_size + sc.recent
  else:
    rows = S
  rd = B * layers_attn * rows * row
  # ssm state read/write
  ns = sum(1 for s in cfg.block_pattern if s.kind == "mamba")
  if ns and cfg.ssm:
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    h = d_in // s.head_dim
    rd += 2.0 * B * ns * cfg.n_blocks * h * s.head_dim * s.d_state * 4
  return rd
