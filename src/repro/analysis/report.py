"""Generate the EXPERIMENTS.md §Dry-run and §Roofline tables from the
dry-run artifacts.

  PYTHONPATH=src python -m repro.analysis.report artifacts/dryrun
"""
from __future__ import annotations

import glob
import json
import os
import sys


def fmt_b(x):
  for unit, div in (("GB", 1e9), ("MB", 1e6), ("KB", 1e3)):
    if abs(x) >= div:
      return f"{x / div:.2f}{unit}"
  return f"{x:.0f}B"


def fmt_s(x):
  if x >= 1.0:
    return f"{x:.2f}s"
  if x >= 1e-3:
    return f"{x * 1e3:.2f}ms"
  return f"{x * 1e6:.1f}us"


def load(art_dir):
  cells = {}
  for f in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
    d = json.load(open(f))
    cells[(d["arch"], d["shape"], d["mesh"], d["mode"])] = d
  return cells


def dryrun_table(cells) -> str:
  rows = ["| arch | shape | mesh | mode | compile | bytes/dev | peak/dev "
          "| fits | coll bytes/dev |",
          "|---|---|---|---|---|---|---|---|---|"]
  for (arch, shape, mesh, mode), d in sorted(cells.items()):
    m = d["memory"]
    rows.append(
        f"| {arch} | {shape} | {mesh} | {mode} | {d['compile_s']:.0f}s "
        f"| {fmt_b(m['argument_size_in_bytes'])} "
        f"| {fmt_b(m['peak_bytes_per_device'])} "
        f"| {'Y' if d['fits_hbm'] else 'N'} "
        f"| {fmt_b(d['collectives']['total'])} |")
  return "\n".join(rows)


def roofline_table(cells) -> str:
  rows = ["| arch | shape | mode | compute | memory | collective | "
          "dominant | bound | useful FLOPs |",
          "|---|---|---|---|---|---|---|---|---|"]
  for (arch, shape, mesh, mode), d in sorted(cells.items()):
    if mesh != "single":
      continue
    r = d["roofline"]
    uf = r.get("useful_flops_ratio")
    rows.append(
        f"| {arch} | {shape} | {mode} | {fmt_s(r['compute_s'])} "
        f"| {fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} "
        f"| **{r['dominant']}** | {fmt_s(r['bound_s'])} "
        f"| {uf:.2f} |" if uf else
        f"| {arch} | {shape} | {mode} | - | - | - | - | - | - |")
  return "\n".join(rows)


def summary(cells) -> str:
  total = len(cells)
  fits = sum(1 for d in cells.values() if d["fits_hbm"])
  single = sum(1 for k in cells if k[2] == "single")
  multi = sum(1 for k in cells if k[2] == "multi")
  lines = [f"- cells compiled: {total} (single-pod {single}, "
           f"multi-pod {multi}); fit in 16GB HBM: {fits}/{total}"]
  # dominant-term census (single-pod)
  census = {}
  for k, d in cells.items():
    if k[2] != "single":
      continue
    census[d["roofline"]["dominant"]] = census.get(
        d["roofline"]["dominant"], 0) + 1
  lines.append(f"- dominant terms (single-pod): {census}")
  return "\n".join(lines)


def main():
  art = sys.argv[1] if len(sys.argv) > 1 else "artifacts/dryrun"
  cells = load(art)
  print("## Summary\n")
  print(summary(cells))
  print("\n## Roofline (single-pod, 256 chips)\n")
  print(roofline_table(cells))
  print("\n## Dry-run (all cells)\n")
  print(dryrun_table(cells))


if __name__ == "__main__":
  main()
