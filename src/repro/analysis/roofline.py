"""Three-term roofline from a compiled dry-run artifact (TPU v5e targets).

  compute    = HLO_FLOPs / (chips * 197e12 bf16 FLOP/s)
  memory     = HLO_bytes / (chips * 819e9 B/s HBM)
  collective = collective_bytes / (chips * 50e9 B/s ICI per link)

``cost_analysis`` provides per-device FLOPs/bytes of the partitioned
module; collective bytes are parsed from the compiled HLO text (operand
sizes of all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute), also per device.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # B/s / chip
ICI_BW = 50e9              # B/s / link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
  n = 1
  for d in dims.split(","):
    if d:
      n *= int(d)
  return n * _DTYPE_BYTES.get(dtype, 4)


def _split_computations(text: str) -> Dict[str, list]:
  """name -> list of body lines (post-optimization HLO text)."""
  comps: Dict[str, list] = {}
  cur = None
  for line in text.splitlines():
    s = line.strip()
    # Computation headers look like:  %name (args...) -> type {   — args
    # may contain nested parens (tuple params), so match loosely.
    if s.endswith("{") and " -> " in s and "(" in s:
      m = re.match(r"(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(", s)
      if m:
        cur = m.group(1)
        comps[cur] = []
        continue
    if s == "}":
      cur = None
      continue
    if cur is not None:
      comps[cur].append(s)
  return comps


def _trip_count(cond_lines: list, comps: Optional[Dict[str, list]] = None,
                ) -> int:
  """Recover a scan's trip count from its while-condition computation.

  The loop bound appears as an s32[] constant in the condition body (the
  compare itself is often inside a fused computation, so we take the max
  integer constant — scans count 0..N-1 with an LT bound)."""
  consts = []
  for s in cond_lines:
    m = re.match(r"%?[\w\.\-]+\s*=\s*[su]\d+\[\]\s*constant\((\d+)\)", s)
    if m:
      consts.append(int(m.group(1)))
  if not consts and comps is not None:
    for s in cond_lines:
      mm = re.search(r"(?:calls|to_apply)=%?([\w\.\-]+)", s)
      if mm and mm.group(1) in comps:
        for s2 in comps[mm.group(1)]:
          m = re.match(r"%?[\w\.\-]+\s*=\s*[su]\d+\[\]\s*constant\((\d+)\)",
                       s2)
          if m:
            consts.append(int(m.group(1)))
  return max(consts) if consts else 1


def _comp_multipliers(text: str) -> Dict[str, int]:
  """Execution count of each computation (nested while bodies multiply)."""
  comps = _split_computations(text)
  calls: Dict[str, list] = {c: [] for c in comps}   # (callee, mult)
  for cname, lines in comps.items():
    for s in lines:
      mw = re.search(r"while\(.*?\),\s*condition=%?([\w\.\-]+),\s*"
                     r"body=%?([\w\.\-]+)", s)
      if mw:
        cond, body = mw.group(1), mw.group(2)
        trips = _trip_count(comps.get(cond, []), comps)
        calls[cname].append((body, trips))
        calls[cname].append((cond, trips))
        continue
      for mm in re.finditer(r"(?:calls|to_apply|condition|body)=%?"
                            r"([\w\.\-]+)", s):
        callee = mm.group(1)
        if callee in comps:
          calls[cname].append((callee, 1))

  entry = None
  for line in text.splitlines():
    m = re.match(r"ENTRY\s+%?([\w\.\-]+)", line.strip())
    if m:
      entry = m.group(1)
      break
  mult: Dict[str, int] = {c: 0 for c in comps}
  if entry is None:
    return {c: 1 for c in comps}

  import collections
  todo = collections.deque([(entry, 1)])
  seen_depth = 0
  while todo and seen_depth < 100000:
    seen_depth += 1
    cname, m_ = todo.popleft()
    mult[cname] = mult.get(cname, 0) + m_
    for callee, k in calls.get(cname, []):
      todo.append((callee, m_ * k))
  return mult


def _group_size(line: str, default: int = 1) -> int:
  m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
  if m:
    return int(m.group(2))
  m = re.search(r"replica_groups=\{\{([\d,]+)\}", line)
  if m:
    return len(m.group(1).split(","))
  return default


def collective_bytes(hlo_text: str) -> Dict[str, int]:
  """Per-device bytes moved by collectives, scan trip counts included.

  Optimized HLO omits operand types, so operand bytes are reconstructed
  from the result type: all-reduce/all-to-all/permute operand == result;
  all-gather operand = result / group; reduce-scatter operand = result *
  group.  Reported number is the *operand* byte sum (spec definition).
  """
  comps = _split_computations(hlo_text)
  mults = _comp_multipliers(hlo_text)
  out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
  for cname, lines in comps.items():
    m_ = mults.get(cname, 1) or 1
    for s in lines:
      mm = re.search(r"=\s*(\([^)]*\)|\S+)\s+(" + "|".join(_COLLECTIVES)
                     + r")(-start)?\(", s)
      if not mm:
        continue
      result_types, kind = mm.group(1), mm.group(2)
      nbytes = 0
      for dt, dims in _SHAPE_RE.findall(result_types):
        if dt in _DTYPE_BYTES:
          nbytes += _shape_bytes(dt, dims)
      g = _group_size(s)
      if kind == "all-gather":
        nbytes //= max(g, 1)
      elif kind == "reduce-scatter":
        nbytes *= max(g, 1)
      out[kind] += nbytes * m_
  out["total"] = sum(out[k] for k in _COLLECTIVES)
  return out


@dataclasses.dataclass
class Roofline:
  flops_per_device: float
  bytes_per_device: float
  coll_bytes_per_device: float
  chips: int
  model_flops: Optional[float] = None    # 6*N(active)*D for the cell

  @property
  def compute_s(self) -> float:
    return self.flops_per_device / PEAK_FLOPS

  @property
  def memory_s(self) -> float:
    return self.bytes_per_device / HBM_BW

  @property
  def collective_s(self) -> float:
    return self.coll_bytes_per_device / ICI_BW

  @property
  def dominant(self) -> str:
    terms = {"compute": self.compute_s, "memory": self.memory_s,
             "collective": self.collective_s}
    return max(terms, key=terms.get)

  @property
  def bound_s(self) -> float:
    return max(self.compute_s, self.memory_s, self.collective_s)

  @property
  def useful_flops_ratio(self) -> Optional[float]:
    if self.model_flops is None:
      return None
    total = self.flops_per_device * self.chips
    return self.model_flops / total if total else None

  def to_dict(self) -> dict:
    return {
        "flops_per_device": self.flops_per_device,
        "bytes_per_device": self.bytes_per_device,
        "coll_bytes_per_device": self.coll_bytes_per_device,
        "chips": self.chips,
        "compute_s": self.compute_s,
        "memory_s": self.memory_s,
        "collective_s": self.collective_s,
        "dominant": self.dominant,
        "bound_s": self.bound_s,
        "model_flops": self.model_flops,
        "useful_flops_ratio": self.useful_flops_ratio,
    }


# -- analytic per-stage synopsis traffic (DESIGN.md §15) ---------------------
#
# The decode step's memory floor is what it must stream from HBM each
# token: stage 1 reads the whole synopsis (k_syn/v_syn + counts), stage 2
# reads the I selected cluster blocks plus the decrement centroid rows.
# Quantization shrinks exactly those streams; the per-row / per-block
# scales ride along as f32 and are charged here so the claimed reduction
# is honest about its own overhead.

_QUANT_BYTES = {"none": None, "int8": 1, "fp8": 1}


def _quant_parts(quant: str):
  """(bytes-per-element of the quantized leaves or None, sorted_kv)."""
  q = quant or "none"
  kind, _, kv = q.partition("+")
  if kind not in _QUANT_BYTES:
    raise ValueError(f"unknown quant spec {quant!r}")
  if kv not in ("", "kv"):
    raise ValueError(f"unknown quant spec {quant!r}")
  return _QUANT_BYTES[kind], kv == "kv"


def synopsis_traffic(*, batch: int, kv_heads: int, m: int, d: int,
                     cluster_size: int, i_max: int, native_bytes: int = 4,
                     quant: str = "none") -> dict:
  """Per-decode-step HBM bytes read by each synopsis stage.

  ``native_bytes`` is the element size of the unquantized arena (4 for
  f32, 2 for bf16); ``quant`` is a spec from ``kernels.quant.QSPECS``.
  Counts and scales are f32.  The query/output traffic is O(B*H*D) —
  orders below the arena streams — and is omitted from both arms so
  ratios compare like with like.
  """
  qb, sorted_kv = _quant_parts(quant)
  syn_b = qb if qb is not None else native_bytes
  kv_b = qb if (qb is not None and sorted_kv) else native_bytes
  B, Hkv, M, D, C, I = batch, kv_heads, m, d, cluster_size, i_max

  s1 = {
      "k_syn": B * Hkv * M * D * syn_b,
      "v_syn": B * Hkv * M * D * syn_b,
      "counts": B * Hkv * M * 4,
  }
  if qb is not None:
    s1["scales"] = 2 * B * Hkv * M * 4          # k_syn_scale + v_syn_scale
  s2 = {
      "k_blocks": B * Hkv * I * C * D * kv_b,
      "v_blocks": B * Hkv * I * C * D * kv_b,
      "decrement_rows": 2 * B * Hkv * I * D * syn_b,
  }
  if qb is not None:
    s2["scales"] = 2 * B * Hkv * I * 4          # centroid-row scales
    if sorted_kv:
      s2["scales"] += 2 * B * Hkv * I * 4       # per-cluster k/v scales
  s1["total"] = sum(s1.values())
  s2["total"] = sum(s2.values())
  return {"stage1": s1, "stage2": s2,
          "total": s1["total"] + s2["total"]}


def traffic_reduction(quant: str, *, batch: int, kv_heads: int, m: int,
                      d: int, cluster_size: int, i_max: int,
                      native_bytes: int = 4) -> dict:
  """Bytes-read reduction of a quantized arm over the ``quant="none"``
  arm with the same shapes: {"stage1": x, "stage2": x, "total": x}."""
  shape = dict(batch=batch, kv_heads=kv_heads, m=m, d=d,
               cluster_size=cluster_size, i_max=i_max,
               native_bytes=native_bytes)
  base = synopsis_traffic(quant="none", **shape)
  q = synopsis_traffic(quant=quant, **shape)
  return {k: base[k]["total"] / q[k]["total"] if isinstance(base[k], dict)
          else base[k] / q[k]
          for k in ("stage1", "stage2", "total")}


def from_compiled(compiled, chips: int,
                  model_flops: Optional[float] = None) -> Roofline:
  cost = compiled.cost_analysis()
  if isinstance(cost, list):          # older jax returns [dict]
    cost = cost[0]
  coll = collective_bytes(compiled.as_text())
  return Roofline(
      flops_per_device=float(cost.get("flops", 0.0)),
      bytes_per_device=float(cost.get("bytes accessed", 0.0)),
      coll_bytes_per_device=float(coll["total"]),
      chips=chips,
      model_flops=model_flops,
  )


def memory_summary(compiled) -> dict:
  ma = compiled.memory_analysis()
  keys = ("argument_size_in_bytes", "output_size_in_bytes",
          "temp_size_in_bytes", "alias_size_in_bytes",
          "generated_code_size_in_bytes")
  out = {}
  for k in keys:
    out[k] = int(getattr(ma, k, 0) or 0)
  out["peak_bytes_per_device"] = (
      out["argument_size_in_bytes"] + out["output_size_in_bytes"]
      + out["temp_size_in_bytes"] - out["alias_size_in_bytes"])
  return out
