"""arctic-480b [moe]: 35L d=7168 56H (GQA kv=8) d_ff=4864 vocab=32000,
MoE 128e top-2 in parallel with a dense residual MLP.
[hf:Snowflake/snowflake-arctic-base; hf]
"""
from repro.models.common import (LayerSpec, ModelConfig, MoEConfig,
                                 SynopsisConfig)

CONFIG = ModelConfig(
    name="arctic-480b",
    n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=4864, vocab=32000, head_dim=128,
    rope_theta=10000.0,
    block_pattern=(LayerSpec(kind="attn", use_moe=True),),
    moe=MoEConfig(num_experts=128, top_k=2, d_ff_expert=4864,
                  dense_parallel=True),
    synopsis=SynopsisConfig(cluster_size=128, i_max=32),
)

SMOKE = ModelConfig(
    name="arctic-480b-smoke",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab=512, head_dim=32,
    rope_theta=10000.0,
    block_pattern=(LayerSpec(kind="attn", use_moe=True),),
    moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=128,
                  dense_parallel=True),
    synopsis=SynopsisConfig(cluster_size=16, i_max=2, recent=16),
)
