"""command-r-plus-104b [dense]: 64L d=12288 96H (GQA kv=8) d_ff=33792
vocab=256000 — GQA, no-bias, parallel attn+FFN blocks, tied embeddings.
[hf:CohereForAI/c4ai-command-r-v01; unverified]
"""
from repro.models.common import LayerSpec, ModelConfig, SynopsisConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b",
    n_layers=64, d_model=12288, n_heads=96, n_kv_heads=8,
    d_ff=33792, vocab=256000, head_dim=128,
    rope_theta=75000.0, parallel_block=True, tie_embeddings=True,
    block_pattern=(LayerSpec(kind="attn"),),
    synopsis=SynopsisConfig(cluster_size=128, i_max=64),
)

SMOKE = ModelConfig(
    name="command-r-plus-104b-smoke",
    n_layers=2, d_model=128, n_heads=8, n_kv_heads=2,
    d_ff=256, vocab=512, head_dim=16,
    rope_theta=75000.0, parallel_block=True, tie_embeddings=True,
    block_pattern=(LayerSpec(kind="attn"),),
    synopsis=SynopsisConfig(cluster_size=16, i_max=2, recent=16),
)
