"""deepseek-v2-236b [moe]: 60L d=5120 128H d_ff(expert)=1536 vocab=102400,
MoE 160e top-6 + 2 shared experts — MLA kv_lora=512 (the latent cache is
a learned synopsis; AccuracyTrader clusters stack on top of it).
[arXiv:2405.04434; hf]
"""
from repro.models.common import (LayerSpec, MLAConfig, ModelConfig,
                                 MoEConfig, SynopsisConfig)

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128,
    d_ff=0, vocab=102400, head_dim=128,
    rope_theta=10000.0,
    block_pattern=(LayerSpec(kind="attn", use_moe=True),),
    moe=MoEConfig(num_experts=160, top_k=6, d_ff_expert=1536, num_shared=2),
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128),
    synopsis=SynopsisConfig(cluster_size=128, i_max=32),
)

SMOKE = ModelConfig(
    name="deepseek-v2-236b-smoke",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab=512, head_dim=32,
    rope_theta=10000.0,
    block_pattern=(LayerSpec(kind="attn", use_moe=True),),
    moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=64, num_shared=1),
    mla=MLAConfig(q_lora_rank=64, kv_lora_rank=32,
                  qk_nope_dim=32, qk_rope_dim=16, v_head_dim=32),
    synopsis=SynopsisConfig(cluster_size=16, i_max=2, recent=16),
)
