"""gemma2-2b [dense]: 26L d=2304 8H (GQA kv=4) d_ff=9216 vocab=256000 —
local+global alternating attention, logit softcaps, sandwich norms,
sqrt(d) embedding scale.  [arXiv:2408.00118; hf]
"""
from repro.models.common import LayerSpec, ModelConfig, SynopsisConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    n_layers=26, d_model=2304, n_heads=8, n_kv_heads=4,
    d_ff=9216, vocab=256000, head_dim=256,
    rope_theta=10000.0, sliding_window=4096,
    attn_softcap=50.0, logit_softcap=30.0,
    sandwich_norm=True, scale_embed=True, tie_embeddings=True,
    block_pattern=(LayerSpec(kind="attn", local=True),
                   LayerSpec(kind="attn")),
    synopsis=SynopsisConfig(cluster_size=128, i_max=32),
)

SMOKE = ModelConfig(
    name="gemma2-2b-smoke",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
    d_ff=256, vocab=512, head_dim=32,
    rope_theta=10000.0, sliding_window=16,
    attn_softcap=50.0, logit_softcap=30.0,
    sandwich_norm=True, scale_embed=True, tie_embeddings=True,
    block_pattern=(LayerSpec(kind="attn", local=True),
                   LayerSpec(kind="attn")),
    synopsis=SynopsisConfig(cluster_size=16, i_max=2, recent=16),
)
