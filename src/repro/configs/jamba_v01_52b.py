"""jamba-v0.1-52b [hybrid]: 32L d=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, MoE 16e top-2 — Mamba+attention 1:7 interleave (1 attention
layer per 8), MoE every other layer.  [arXiv:2403.19887; hf]
"""
from repro.models.common import (LayerSpec, ModelConfig, MoEConfig,
                                 SSMConfig, SynopsisConfig)

_PATTERN = tuple(
    LayerSpec(kind="attn" if i == 4 else "mamba", use_moe=(i % 2 == 1))
    for i in range(8)
)

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=65536, head_dim=128,
    rope_theta=10000.0,
    block_pattern=_PATTERN,
    moe=MoEConfig(num_experts=16, top_k=2, d_ff_expert=14336),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=64, chunk=128),
    synopsis=SynopsisConfig(cluster_size=128, i_max=32),
)

_SMOKE_PATTERN = tuple(
    LayerSpec(kind="attn" if i == 0 else "mamba", use_moe=(i % 2 == 1))
    for i in range(2)
)

SMOKE = ModelConfig(
    name="jamba-v0.1-52b-smoke",
    n_layers=4, d_model=128, n_heads=4, n_kv_heads=2,
    d_ff=256, vocab=512, head_dim=32,
    rope_theta=10000.0,
    block_pattern=_SMOKE_PATTERN,
    moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=256),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=32, chunk=32),
    synopsis=SynopsisConfig(cluster_size=16, i_max=2, recent=16),
)
