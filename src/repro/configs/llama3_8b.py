"""llama3-8b [dense]: 32L d=4096 32H (GQA kv=8) d_ff=14336 vocab=128256 —
GQA, 128k vocab, rope theta 500k.  [arXiv:2407.21783; unverified]
"""
from repro.models.common import LayerSpec, ModelConfig, SynopsisConfig

CONFIG = ModelConfig(
    name="llama3-8b",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=128256, head_dim=128,
    rope_theta=500000.0,
    block_pattern=(LayerSpec(kind="attn"),),
    synopsis=SynopsisConfig(cluster_size=128, i_max=32),
)

SMOKE = ModelConfig(
    name="llama3-8b-smoke",
    n_layers=2, d_model=128, n_heads=8, n_kv_heads=2,
    d_ff=256, vocab=512, head_dim=16,
    rope_theta=500000.0,
    block_pattern=(LayerSpec(kind="attn"),),
    synopsis=SynopsisConfig(cluster_size=16, i_max=2, recent=16),
)
