"""mamba2-370m [ssm]: 48L d=1024 attention-free, vocab=50280,
ssm_state=128 — SSD (state-space duality).  AccuracyTrader's synopsis
attention is INAPPLICABLE to the sequence mixer (no KV cache to
synopsize) — see DESIGN.md §5; the arch runs without the technique and
long_500k decodes natively with O(1) state.  [arXiv:2405.21060; unverified]
"""
from repro.models.common import (LayerSpec, ModelConfig, SSMConfig,
                                 SynopsisConfig)

CONFIG = ModelConfig(
    name="mamba2-370m",
    n_layers=48, d_model=1024, n_heads=1, n_kv_heads=1,
    d_ff=0, vocab=50280,
    block_pattern=(LayerSpec(kind="mamba"),),
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk=128),
    tie_embeddings=True,
    synopsis=SynopsisConfig(cluster_size=128, i_max=0),
)

SMOKE = ModelConfig(
    name="mamba2-370m-smoke",
    n_layers=2, d_model=128, n_heads=1, n_kv_heads=1,
    d_ff=0, vocab=512,
    block_pattern=(LayerSpec(kind="mamba"),),
    ssm=SSMConfig(d_state=32, d_conv=4, expand=2, head_dim=32, chunk=32),
    tie_embeddings=True,
    synopsis=SynopsisConfig(cluster_size=16, i_max=0, recent=16),
)
