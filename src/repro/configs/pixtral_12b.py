"""pixtral-12b [vlm]: 40L d=5120 32H (GQA kv=8) d_ff=14336 vocab=131072 —
pixtral-ViT frontend (STUB: input_specs provides precomputed patch
embeddings) + mistral-nemo text backbone.
[hf:mistralai/Pixtral-12B-2409; unverified]
"""
from repro.models.common import LayerSpec, ModelConfig, SynopsisConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=131072, head_dim=128,
    rope_theta=1000000.0,
    frontend="vision_stub", frontend_tokens=256, frontend_dim=1024,
    block_pattern=(LayerSpec(kind="attn"),),
    synopsis=SynopsisConfig(cluster_size=128, i_max=32),
)

SMOKE = ModelConfig(
    name="pixtral-12b-smoke",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
    d_ff=256, vocab=512, head_dim=32,
    rope_theta=1000000.0,
    frontend="vision_stub", frontend_tokens=8, frontend_dim=32,
    block_pattern=(LayerSpec(kind="attn"),),
    synopsis=SynopsisConfig(cluster_size=16, i_max=2, recent=16),
)
