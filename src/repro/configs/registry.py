"""Architecture registry: ``--arch <id>`` -> ModelConfig (full or smoke)."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.common import ModelConfig

_MODULES: Dict[str, str] = {
    "command-r-plus-104b": "repro.configs.command_r_plus_104b",
    "gemma2-2b": "repro.configs.gemma2_2b",
    "llama3-8b": "repro.configs.llama3_8b",
    "smollm-135m": "repro.configs.smollm_135m",
    "pixtral-12b": "repro.configs.pixtral_12b",
    "jamba-v0.1-52b": "repro.configs.jamba_v01_52b",
    "deepseek-v2-236b": "repro.configs.deepseek_v2_236b",
    "arctic-480b": "repro.configs.arctic_480b",
    "whisper-medium": "repro.configs.whisper_medium",
    "mamba2-370m": "repro.configs.mamba2_370m",
}


def list_archs() -> List[str]:
  return list(_MODULES)


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
  if arch not in _MODULES:
    raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
  mod = importlib.import_module(_MODULES[arch])
  return mod.SMOKE if smoke else mod.CONFIG
