"""Assigned input shapes and per-(arch x shape) input_specs.

Four shapes per architecture (40 cells):
  train_4k     seq=4096   global_batch=256   -> train_step
  prefill_32k  seq=32768  global_batch=32    -> prefill_step
  decode_32k   seq=32768  global_batch=128   -> serve_step (1 new token,
                                                KV cache of seq_len)
  long_500k    seq=524288 global_batch=1     -> serve_step, sub-quadratic
                                                (synopsis attention / SSM)

``input_specs`` returns ShapeDtypeStructs only — no allocation — matching
the dry-run contract.  Modality frontends are stubs: whisper gets
precomputed frame embeddings, pixtral gets patch embeddings.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
  name: str
  seq_len: int
  global_batch: int
  kind: str                      # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def sds(shape, dtype):
  return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
  """ShapeDtypeStruct stand-ins for every model input of this cell."""
  B, S = shape.global_batch, shape.seq_len
  specs: dict = {}
  if shape.kind in ("train", "prefill"):
    text = S
    if cfg.frontend == "vision_stub":
      text = S - cfg.frontend_tokens
      specs["frontend_embeds"] = sds((B, cfg.frontend_tokens,
                                      cfg.frontend_dim), jnp.bfloat16)
    if cfg.encoder is not None:
      specs["frontend_embeds"] = sds((B, cfg.encoder.source_len,
                                      cfg.frontend_dim), jnp.bfloat16)
    specs["tokens"] = sds((B, text), jnp.int32)
    if shape.kind == "train":
      specs["labels"] = sds((B, text), jnp.int32)
  else:
    # Decode: one new token per sequence + a KV cache of length S (built
    # by repro.serve.kv_cache.cache_specs, model-dependent).
    specs["tokens"] = sds((B, 1), jnp.int32)
  return specs
