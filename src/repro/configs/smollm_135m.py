"""smollm-135m [dense]: 30L d=576 9H (GQA kv=3) d_ff=1536 vocab=49152 —
llama-arch small, tied embeddings.  [hf:HuggingFaceTB/SmolLM-135M; hf]
"""
from repro.models.common import LayerSpec, ModelConfig, SynopsisConfig

CONFIG = ModelConfig(
    name="smollm-135m",
    n_layers=30, d_model=576, n_heads=9, n_kv_heads=3,
    d_ff=1536, vocab=49152, head_dim=64,
    rope_theta=10000.0, tie_embeddings=True,
    block_pattern=(LayerSpec(kind="attn"),),
    synopsis=SynopsisConfig(cluster_size=128, i_max=32),
)

SMOKE = ModelConfig(
    name="smollm-135m-smoke",
    n_layers=2, d_model=96, n_heads=3, n_kv_heads=3,
    d_ff=192, vocab=512, head_dim=32,
    rope_theta=10000.0, tie_embeddings=True,
    block_pattern=(LayerSpec(kind="attn"),),
    synopsis=SynopsisConfig(cluster_size=16, i_max=2, recent=16),
)
