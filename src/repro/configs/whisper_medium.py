"""whisper-medium [audio]: enc-dec 24L each, d=1024 16H d_ff=4096
vocab=51865 — conv frontend is a STUB (input_specs provides precomputed
frame embeddings), GELU MLPs with biases.  [arXiv:2212.04356; unverified]
"""
from repro.models.common import (EncoderConfig, LayerSpec, ModelConfig,
                                 SynopsisConfig)

CONFIG = ModelConfig(
    name="whisper-medium",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab=51865,
    rope_theta=10000.0, mlp_type="gelu", attn_bias=True,
    scale_embed=False,
    block_pattern=(LayerSpec(kind="attn", cross_attn=True),),
    encoder=EncoderConfig(n_layers=24, n_heads=16, d_ff=4096,
                          source_len=1500),
    frontend="audio_stub", frontend_dim=1024,
    synopsis=SynopsisConfig(cluster_size=128, i_max=32),
)

SMOKE = ModelConfig(
    name="whisper-medium-smoke",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
    d_ff=256, vocab=512,
    rope_theta=10000.0, mlp_type="gelu", attn_bias=True,
    block_pattern=(LayerSpec(kind="attn", cross_attn=True),),
    encoder=EncoderConfig(n_layers=2, n_heads=4, d_ff=256, source_len=16),
    frontend="audio_stub", frontend_dim=32,
    synopsis=SynopsisConfig(cluster_size=16, i_max=2, recent=16),
)
