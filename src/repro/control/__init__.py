"""The latency-control plane (DESIGN.md §10–§11): pluggable per-component
latency predictors, the deadline->budget policy (with stranded-budget
recirculation), the hedged replica-gather decision and its fault-aware
recovery ladder, and the queue-aware predictive admission policy — the
ONE implementation shared by the serving engine, the scatter-gather
cluster tier and the discrete-event simulator."""
from repro.control.admission import (AdmissionConfig, AdmissionPolicy,
                                     SLOClass, TokenBucket,
                                     parse_slo_classes)
from repro.control.autoscaler import (Autoscaler, AutoscalerConfig,
                                      FleetSize, drain)
from repro.control.estimator import (AccuracyEstimator, calibration_pairs,
                                     coverage_profile, isotonic_fit,
                                     spearman)
from repro.control.policy import (CONTRACTS, MODE_DROP, MODE_FULL,
                                  MODE_STAGE1, POLICIES, BudgetController,
                                  DeadlineBudgetPolicy, allocate_budget)
from repro.control.predictors import (AffinePredictor, EwmaPredictor,
                                      QuantilePredictor, TailTracker,
                                      make_predictor, percentile)
from repro.control.recovery import (RetryPolicy, plan_recovery,
                                    realized_recovery)

__all__ = [
    "CONTRACTS", "MODE_DROP", "MODE_FULL", "MODE_STAGE1", "POLICIES",
    "BudgetController", "DeadlineBudgetPolicy", "allocate_budget",
    "AccuracyEstimator", "calibration_pairs", "coverage_profile",
    "isotonic_fit", "spearman",
    "AffinePredictor", "EwmaPredictor", "QuantilePredictor",
    "TailTracker", "make_predictor", "percentile",
    "RetryPolicy", "plan_recovery", "realized_recovery",
    "AdmissionConfig", "AdmissionPolicy", "SLOClass", "TokenBucket",
    "parse_slo_classes",
    "Autoscaler", "AutoscalerConfig", "FleetSize", "drain",
]
