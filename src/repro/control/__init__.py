"""The latency-control plane (DESIGN.md §10): pluggable per-component
latency predictors, the deadline->budget policy (with stranded-budget
recirculation), and the hedged replica-gather decision — the ONE
implementation shared by the serving engine, the scatter-gather cluster
tier and the discrete-event simulator."""
from repro.control.policy import (MODE_DROP, MODE_FULL, MODE_STAGE1,
                                  POLICIES, BudgetController,
                                  DeadlineBudgetPolicy, allocate_budget)
from repro.control.predictors import (AffinePredictor, EwmaPredictor,
                                      QuantilePredictor, TailTracker,
                                      make_predictor, percentile)

__all__ = [
    "MODE_DROP", "MODE_FULL", "MODE_STAGE1", "POLICIES",
    "BudgetController", "DeadlineBudgetPolicy", "allocate_budget",
    "AffinePredictor", "EwmaPredictor", "QuantilePredictor",
    "TailTracker", "make_predictor", "percentile",
]
