"""Queue-aware predictive admission: the overload half of the resilience
layer (DESIGN.md §11).

PR 5 recorded the honest negative result that at 3x admission-bound
saturation the queue owns the tail and hedging cannot help — by the time
a request reaches a decode slot its deadline is already spent.  PCS
(arXiv 1511.02960) shows the fix is predictive scheduling of the queue
itself: estimate each request's service demand *at arrival* from the
same wall-vs-rows predictors the decode loop already calibrates, order
the queue by urgency instead of arrival, and shed requests that are
already dead before they burn a prefill.

:class:`AdmissionPolicy` is the admission-side twin of
`DeadlineBudgetPolicy`: one object owning every queue decision —

  * **ordering** — ``fifo`` (arrival), ``edf`` (earliest absolute
    deadline first) or ``slack`` (least laxity: deadline minus now minus
    predicted demand — EDF refined by per-request demand estimates);
  * **predictive shedding** — a request whose predicted completion
    ``now + demand`` already exceeds ``arrival + deadline * shed_margin``
    is refused at admission: zero prefill, zero decode steps, the lane
    goes to a request that can still make it.  The demand estimate is a
    *lower bound* (admission cost + per-step floor), so at low load no
    feasible request is ever shed (property-tested);
  * **SLO classes** — named classes (``interactive`` vs ``batch``) with
    per-class deadlines and an optional per-class token-bucket rate
    limit, so a batch flood cannot starve the interactive class of
    admission slots.

The engine consumes this in its ``run`` loop
(`repro.serve.engine.ServingEngine`); ``AdmissionConfig(order="fifo",
shed=False)`` — or no config at all — is the legacy FIFO path,
bit-identical to the pre-resilience engine.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, Optional, Tuple

__all__ = ["SLOClass", "TokenBucket", "AdmissionConfig", "AdmissionPolicy",
           "parse_slo_classes"]

ORDERS = ("fifo", "edf", "slack")


@dataclasses.dataclass(frozen=True)
class SLOClass:
  """One service-level class: its own deadline and (optionally) its own
  admission rate.  ``rate_per_s=inf`` = no rate limit."""
  name: str
  deadline_ms: float
  rate_per_s: float = math.inf
  burst: float = 4.0

  def __post_init__(self):
    if self.deadline_ms <= 0.0:
      raise ValueError(f"class {self.name!r}: deadline {self.deadline_ms}")
    if self.rate_per_s <= 0.0:
      raise ValueError(f"class {self.name!r}: rate {self.rate_per_s}")


@dataclasses.dataclass
class TokenBucket:
  """Continuous-refill token bucket on the engine's ms clock."""
  rate_per_s: float
  burst: float = 4.0

  def __post_init__(self):
    self.tokens = float(self.burst)
    self.last_ms = 0.0

  def take(self, now_ms: float) -> bool:
    now_ms = max(now_ms, self.last_ms)
    self.tokens = min(self.burst, self.tokens + (now_ms - self.last_ms)
                      * self.rate_per_s / 1000.0)
    self.last_ms = now_ms
    if self.tokens >= 1.0:
      self.tokens -= 1.0
      return True
    return False


@dataclasses.dataclass(frozen=True)
class AdmissionConfig:
  """Admission knobs (`EngineConfig.admission`; None = legacy FIFO)."""
  order: str = "edf"             # fifo | edf | slack
  shed: bool = True              # predictive shed-at-admission
  shed_margin: float = 1.0       # shed when now+demand > arrival+ddl*margin
  classes: Tuple[SLOClass, ...] = ()

  def __post_init__(self):
    if self.order not in ORDERS:
      raise ValueError(f"order {self.order!r} not in {ORDERS}")
    if self.shed_margin <= 0.0:
      raise ValueError(f"shed_margin {self.shed_margin} <= 0")
    names = [c.name for c in self.classes]
    if len(names) != len(set(names)):
      raise ValueError(f"duplicate SLO class names {names}")


class AdmissionPolicy:
  """Queue decisions for one engine: deadline resolution, rate limiting,
  predictive shedding and ordering, per the :class:`AdmissionConfig`.

  ``demand_fn(req) -> ms`` is supplied by the engine: its lower-bound
  estimate of the request's total service demand (admission cost + steps
  at the predictor's smallest-bucket wall)."""

  def __init__(self, cfg: AdmissionConfig, default_deadline_ms: float,
               demand_fn: Callable[[object], float]):
    self.cfg = cfg
    self.default_deadline_ms = float(default_deadline_ms)
    self.demand_fn = demand_fn
    self._classes: Dict[str, SLOClass] = {c.name: c for c in cfg.classes}
    self._buckets: Dict[str, TokenBucket] = {
        c.name: TokenBucket(c.rate_per_s, c.burst)
        for c in cfg.classes if math.isfinite(c.rate_per_s)}

  def reset(self) -> None:
    for b in self._buckets.values():
      b.__post_init__()

  def deadline_for(self, req) -> float:
    """Per-request deadline: explicit override > SLO class > engine
    default."""
    if getattr(req, "deadline_ms", None) is not None:
      return float(req.deadline_ms)
    cls = self._classes.get(getattr(req, "slo", "default"))
    return cls.deadline_ms if cls is not None else self.default_deadline_ms

  def rate_admit(self, req, now_ms: float) -> bool:
    """Token-bucket gate for the request's class (True = may proceed)."""
    bucket = self._buckets.get(getattr(req, "slo", "default"))
    return bucket is None or bucket.take(now_ms)

  def predicted_dead(self, req, now_ms: float,
                     demand_ms: Optional[float] = None) -> bool:
    """True when the predicted completion already misses the deadline —
    the request would burn a prefill and decode steps only to score 0."""
    if not self.cfg.shed:
      return False
    demand = self.demand_fn(req) if demand_ms is None else demand_ms
    ddl = req.arrival_ms + self.deadline_for(req) * self.cfg.shed_margin
    return now_ms + demand > ddl

  def key(self, req, now_ms: float):
    """Queue-ordering key (smaller = first).  FIFO ties on arrival order
    via rid, as the legacy deque did."""
    if self.cfg.order == "fifo":
      return (req.arrival_ms, req.rid)
    ddl = req.arrival_ms + self.deadline_for(req)
    if self.cfg.order == "edf":
      return (ddl, req.rid)
    return (ddl - now_ms - self.demand_fn(req), req.rid)   # least slack


def parse_slo_classes(text: Optional[str]) -> Tuple[SLOClass, ...]:
  """CLI spec -> SLO classes: ``name:deadline_ms[@rate_per_s[/burst]]``
  comma-separated, e.g. ``interactive:80@60,batch:400``."""
  if not text:
    return ()
  out = []
  for part in text.split(","):
    name, _, rest = part.strip().partition(":")
    if not rest:
      raise ValueError(f"SLO class {part!r}: want name:deadline[@rate]")
    ddl, _, rate = rest.partition("@")
    kw = {"name": name, "deadline_ms": float(ddl)}
    if rate:
      r, _, burst = rate.partition("/")
      kw["rate_per_s"] = float(r)
      if burst:
        kw["burst"] = float(burst)
    out.append(SLOClass(**kw))
  return tuple(out)
