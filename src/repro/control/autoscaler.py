"""Elastic fleet autoscaler over diurnal traces (DESIGN.md §14).

The fleet tier's capacity knob is its grid: ``n`` active components
(each owning 1/n of every resident corpus — more components, shorter
steps) times ``r`` materialized replica rows (more rows, deeper
replica-selection min over the per-step straggler draws — a shorter
*tail*, not a shorter mean).  Diurnal workloads (`serving.workload`:
``sogou_hourly``, ``cf_rates``) leave a statically-peak-sized fleet
idle most of the day; the autoscaler resizes per measurement window
against a p99 target, PCS-style predictive sizing (arxiv 1511.02960)
from the shared wall predictor's measured step walls.

Sizing is a scan over an ANALYTIC queueing model (`Autoscaler.p99_of`,
M/G/1-flavored):

  service  = steps_per_request * step_ms(n, r)
  capacity = slots * 1000 / service          requests per second
  rho      = rate / capacity
  p99      = service * (1 + (tail / r) * rho / (1 - rho))

``step_ms(n, r)`` comes from the fleet's measured export rescaled to a
counterfactual size (`serving.service.ScaledFleetExport.step_model`);
the tail/r factor models replica selection trimming the straggler
excess (min over r holders).  The model is monotone — p99 falls in n
and r, rises in rate — so the scan (smallest n, then smallest r, that
meets the target) yields a component count that NEVER decreases with
load, the decision-rule property tests/test_autoscaler.py pins.

``decide`` wraps the scan with hysteresis: scale-UP adopts the target
immediately (a missed p99 target is the expensive direction), scale-
DOWN waits for ``cooldown_windows`` consecutive windows in which the
smaller size meets the target with ``headroom`` to spare — a flat trace
never flaps, and a single noisy dip never retires capacity.  Scale-down
itself is drain-before-retire (:func:`drain`): the engine steps its
resident slots to retirement without admitting new work, so resizing
never drops an in-flight request.

The counterfactual round-trip (ISSUE/ROADMAP item 4): the analytic scan
picks the size, the discrete-event simulator
(``ScatterGatherService(step_backend=ScaledFleetExport(...))``) replays
the window at that size to measure the p99 the frontend would actually
see — benchmarks/fleet_bench.py records both against static sizing.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

__all__ = ["FleetSize", "AutoscalerConfig", "Autoscaler", "drain"]


@dataclasses.dataclass(frozen=True)
class FleetSize:
  """One fleet sizing: ``n_components`` columns x ``replicas`` rows."""
  n_components: int
  replicas: int = 1

  @property
  def devices(self) -> int:
    """Cost unit: machines held for the window (component-hours/window)."""
    return self.n_components * self.replicas


@dataclasses.dataclass(frozen=True)
class AutoscalerConfig:
  """Decision-rule knobs.  ``tail_factor`` is the queueing model's
  straggler multiplier at rho -> 1 for an unreplicated row (calibrated
  loosely from the cluster tier's lognormal interference world; the
  simulator round-trip, not this constant, is the measured truth)."""
  p99_target_ms: float = 50.0
  min_components: int = 1
  max_components: int = 8
  min_replicas: int = 1
  max_replicas: int = 2
  slots: int = 2                 # concurrent decode lanes per fleet
  steps_per_request: float = 4.0
  tail_factor: float = 3.0
  headroom: float = 0.15         # shrink only if target met with margin
  cooldown_windows: int = 2      # consecutive qualifying windows to shrink


class Autoscaler:
  """Per-window fleet sizing against a p99 target.

  ``step_ms_fn(n, r)`` maps a candidate size to the predicted step wall
  (ms) — typically `ScaledFleetExport.step_model` over the fleet's
  measured export.  The instance carries the hysteresis state; one
  autoscaler per fleet, ``decide`` called once per measurement window.
  """

  def __init__(self, cfg: AutoscalerConfig,
               step_ms_fn: Callable[[int, int], float]):
    if cfg.min_components < 1 or cfg.max_components < cfg.min_components:
      raise ValueError(f"component bounds [{cfg.min_components}, "
                       f"{cfg.max_components}] invalid")
    if cfg.min_replicas < 1 or cfg.max_replicas < cfg.min_replicas:
      raise ValueError(f"replica bounds [{cfg.min_replicas}, "
                       f"{cfg.max_replicas}] invalid")
    self.cfg = cfg
    self.step_ms_fn = step_ms_fn
    self._shrink_streak = 0
    self.log: List[dict] = []

  # -- the analytic model ----------------------------------------------------
  def p99_of(self, rate_per_s: float, size: FleetSize) -> float:
    """Predicted window p99 at ``size`` (see module docstring).  Returns
    ``inf`` at or beyond saturation (rho >= 1)."""
    cfg = self.cfg
    service = cfg.steps_per_request * float(
        self.step_ms_fn(size.n_components, size.replicas))
    if service <= 0.0:
      return 0.0
    capacity = cfg.slots * 1000.0 / service
    rho = float(rate_per_s) / capacity
    if rho >= 1.0:
      return float("inf")
    tail = cfg.tail_factor / size.replicas
    return service * (1.0 + tail * rho / (1.0 - rho))

  def size_for(self, rate_per_s: float) -> FleetSize:
    """Smallest feasible size: scan n ascending, then r ascending, and
    take the first (n, r) whose predicted p99 meets the target.  p99 is
    monotone decreasing in both dims and increasing in rate, so the
    chosen n never decreases as the rate grows; nothing feasible =
    saturation -> the max grid (documented saturation window)."""
    cfg = self.cfg
    for n in range(cfg.min_components, cfg.max_components + 1):
      for r in range(cfg.min_replicas, cfg.max_replicas + 1):
        size = FleetSize(n, r)
        if self.p99_of(rate_per_s, size) <= cfg.p99_target_ms:
          return size
    return FleetSize(cfg.max_components, cfg.max_replicas)

  # -- the windowed decision rule --------------------------------------------
  def decide(self, rate_per_s: float,
             current: Optional[FleetSize] = None) -> FleetSize:
    """One measurement window's sizing decision with hysteresis:
    scale-up is immediate (elementwise max, so growing one dimension
    never silently shrinks the other), scale-down requires
    ``cooldown_windows`` consecutive windows in which the smaller target
    also meets the p99 target with ``headroom`` to spare."""
    cfg = self.cfg
    target = self.size_for(rate_per_s)
    if current is None:
      self._shrink_streak = 0
      self._record(rate_per_s, target, target, "init")
      return target
    if target.n_components > current.n_components \
        or target.replicas > current.replicas:
      self._shrink_streak = 0
      up = FleetSize(max(target.n_components, current.n_components),
                     max(target.replicas, current.replicas))
      self._record(rate_per_s, target, up, "up")
      return up
    if target == current:
      self._shrink_streak = 0
      self._record(rate_per_s, target, current, "hold")
      return current
    # target strictly within current: shrink only after the cooldown,
    # and only if the smaller size clears the target with headroom.
    margin_ok = self.p99_of(rate_per_s, target) \
        <= cfg.p99_target_ms * (1.0 - cfg.headroom)
    self._shrink_streak = self._shrink_streak + 1 if margin_ok else 0
    if self._shrink_streak >= cfg.cooldown_windows:
      self._shrink_streak = 0
      self._record(rate_per_s, target, target, "down")
      return target
    self._record(rate_per_s, target, current, "cooldown")
    return current

  def _record(self, rate, target, chosen, action) -> None:
    self.log.append({"rate": float(rate), "action": action,
                     "target": (target.n_components, target.replicas),
                     "chosen": (chosen.n_components, chosen.replicas)})


def drain(engine) -> int:
  """Drain-before-retire: step the engine's resident slots to completion
  WITHOUT admitting new work, so a scale-down never drops an in-flight
  request (every retirement happens with ``remaining == 0``, hence
  ``dropped`` False — asserted in tests/test_autoscaler.py).  Returns
  the number of requests retired by the drain."""
  before = len(engine.completed)
  while True:
    active = [i for i, s in enumerate(engine.slots) if s is not None]
    if not active:
      break
    engine._decode_step(active)
  return len(engine.completed) - before
