"""Online per-request accuracy-loss estimation (DESIGN.md §13).

Every budget decision in the repo used to trade accuracy for latency
blind: measured loss is only knowable offline against the exact
baseline.  But AccuracyTrader's own premise — the synopsis identifies
which parts of the input matter most to a request — already yields the
raw signals for an *online* estimate, from quantities the fused stage-1
kernel computes anyway:

  * :func:`coverage_profile` — the cumulative fraction of the stage-1
    probability mass (``exp(score) · count``, exactly the count-biased
    weight the synopsis partials carry) covered by the first ``b``
    clusters in refinement order.  Computed inside the traced step from
    the stage-1 ``scores`` and ``counts`` — no extra passes over KV.
  * the raw loss estimate at budget ``b`` is
    ``floor · (1 - profile[b])``: the stage-1 floor (what the synopsis
    alone loses) scaled by the mass the refinement did NOT cover.  By
    construction it is monotone decreasing in covered mass, bounded in
    [0, 1], equals the floor at zero budget and ~0 at full budget
    (property-tested in tests/test_estimator.py).
  * :meth:`AccuracyEstimator.spread_from_profile` — a BlinkDB/Verdict
    style error-propagation proxy: the unrefined remainder is a sum of
    per-cluster mass increments, so its standard-error scales like
    ``residual / sqrt(n_eff)`` with ``n_eff`` the effective count of
    unrefined clusters (centroid dispersion/counts as variance proxies).

The raw estimate lives on the synopsis' own scale; the **calibration
layer** (:meth:`AccuracyEstimator.fit`) maps it onto measured loss with
an isotonic (pool-adjacent-violators) regression — affine below 8 pairs
— fit from (raw, measured) pairs of a held-out run, and keeps the
``conf``-quantile of the absolute calibration residuals as the
confidence-band half-width (widened, never narrowed, by the per-request
spread proxy).  Rank correlation of the calibrated estimate with
measured loss is CI-gated (benchmarks/accuracy_bench.py).

Consumed by the two ε-or-deadline serving contracts
(`repro.control.policy.CONTRACTS`): ``error_bounded`` refines until
predicted loss ≤ ε and answers early (freeing budget), and
``deadline_with_bound`` attaches a confidence band to every answer.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

NEG_INF = -1e30


def coverage_profile(scores, counts, rank: str = "score"):
  """Cumulative covered-mass profile from stage-1 outputs (traced).

  ``scores`` (B, Hkv, M) stage-1 centroid scores (NEG_INF on invalid
  slots); ``counts`` (B, M) cluster token counts (0 on pads).  Returns
  (B, M+1) f32: entry ``b`` is the fraction of the total stage-1
  probability mass covered by the first ``b`` clusters in refinement
  order — ``rank="score"`` (the single-tier top-k order) or
  ``rank="mass"`` (the marginal-gain order the cluster frontend's
  ``alloc="gain"`` refines in).  Per-head profiles are averaged over
  Hkv.  ``profile[0] == 0`` and ``profile[M] == 1`` whenever any valid
  mass exists."""
  import jax.numpy as jnp  # noqa: PLC0415 — keep module import light

  valid = scores > NEG_INF / 2
  smax = jnp.max(jnp.where(valid, scores, NEG_INF), axis=-1, keepdims=True)
  smax = jnp.maximum(smax, NEG_INF / 4)          # all-invalid row guard
  w = jnp.where(valid, jnp.exp(scores - smax), 0.0)
  w = w * jnp.maximum(counts, 0.0)[:, None, :]
  key = scores if rank == "score" else w
  order = jnp.argsort(-key, axis=-1)
  ws = jnp.take_along_axis(w, order, axis=-1)
  cum = jnp.cumsum(ws, axis=-1)
  tot = jnp.maximum(cum[..., -1:], 1e-30)
  prof = jnp.concatenate(
      [jnp.zeros_like(cum[..., :1]), cum / tot], axis=-1)
  return jnp.clip(jnp.mean(prof, axis=1), 0.0, 1.0)       # (B, M+1)


def _ranks(x: np.ndarray) -> np.ndarray:
  """Average ranks (ties share their mean rank), 1-based."""
  x = np.asarray(x, np.float64)
  order = np.argsort(x, kind="mergesort")
  sx = x[order]
  ranks = np.empty(len(x), np.float64)
  i = 0
  while i < len(x):
    j = i
    while j + 1 < len(x) and sx[j + 1] == sx[i]:
      j += 1
    ranks[order[i:j + 1]] = 0.5 * (i + j) + 1.0
    i = j + 1
  return ranks


def spearman(a: Sequence[float], b: Sequence[float]) -> float:
  """Spearman rank correlation (average ranks on ties; no scipy)."""
  ra, rb = _ranks(np.asarray(a)), _ranks(np.asarray(b))
  ra = ra - ra.mean()
  rb = rb - rb.mean()
  den = float(np.sqrt((ra * ra).sum() * (rb * rb).sum()))
  if den <= 0.0:
    return 0.0
  return float((ra * rb).sum() / den)


def isotonic_fit(x, y) -> Tuple[np.ndarray, np.ndarray]:
  """Monotone non-decreasing least-squares fit of y on x
  (pool-adjacent-violators).  Returns interpolation knots ``(xk, yk)``
  with strictly increasing ``xk`` (duplicate x collapse to their block
  mean) and non-decreasing ``yk``."""
  x = np.asarray(x, np.float64)
  y = np.asarray(y, np.float64)
  order = np.argsort(x, kind="mergesort")
  xs, ys = x[order], y[order]
  vals: List[float] = []
  wts: List[float] = []
  for yi in ys:
    vals.append(float(yi))
    wts.append(1.0)
    while len(vals) > 1 and vals[-2] > vals[-1]:
      y2, w2 = vals.pop(), wts.pop()
      y1, w1 = vals.pop(), wts.pop()
      vals.append((y1 * w1 + y2 * w2) / (w1 + w2))
      wts.append(w1 + w2)
  fitted = np.concatenate(
      [np.full(int(c), v) for v, c in zip(vals, wts)]) \
      if vals else np.zeros((0,))
  ux, inv = np.unique(xs, return_inverse=True)
  uy = np.array([fitted[inv == i].mean() for i in range(len(ux))])
  return ux, np.maximum.accumulate(uy)


def calibration_pairs(requests) -> Tuple[List[float], List[float]]:
  """(raw estimate, measured loss) pairs from completed engine requests
  — the calibration layer's training set.  Only requests that were
  actually served to completion count (a shed/dropped request's
  accuracy is a policy artifact, not an estimator target)."""
  raws, measured = [], []
  for r in requests:
    if getattr(r, "est_raw", None) and not r.shed_admission \
        and not r.dropped:
      raws.append(float(np.mean(r.est_raw)))
      measured.append(1.0 - float(r.accuracy))
  return raws, measured


@dataclasses.dataclass
class AccuracyEstimator:
  """Per-request online loss estimate + held-out calibration + bands.

  ``floor`` is the stage-1 floor — the loss of the synopsis answer alone
  (``1 - accuracy_fn(0)``; the paper's ~7 %).  ``conf`` sets both the
  residual quantile kept as the band half-width and the nominal coverage
  of :meth:`band`."""
  floor: float = 0.07
  conf: float = 0.9
  _iso_x: Optional[np.ndarray] = dataclasses.field(
      default=None, repr=False)
  _iso_y: Optional[np.ndarray] = dataclasses.field(
      default=None, repr=False)
  _resid_q: float = dataclasses.field(default=0.0, repr=False)

  @property
  def calibrated(self) -> bool:
    return self._iso_x is not None

  # -- raw signals -----------------------------------------------------------
  # The raw-signal and contract methods below run on the HOST once per
  # slot per decode step under the non-deadline contracts, so they avoid
  # numpy where scalar math does (tiny-array numpy calls are dominated
  # by dispatch overhead); the accuracy bench guards the whole estimator
  # at <5% of the measured step wall.
  def raw_loss(self, profile, budget: int) -> float:
    """Raw (uncalibrated) predicted loss at ``budget`` refined clusters:
    the stage-1 floor scaled by the uncovered mass.  Monotone decreasing
    in covered mass, in [0, 1], ``floor`` at budget 0, ~0 at full."""
    p = profile if isinstance(profile, np.ndarray) \
        else np.asarray(profile, np.float64)
    idx = min(max(int(budget), 0), p.shape[-1] - 1)
    return min(max(self.floor * (1.0 - float(p[..., idx])), 0.0), 1.0)

  def spread_from_profile(self, profile, budget: int) -> float:
    """Verdict-style error propagation on the unrefined remainder: the
    residual is a sum of per-cluster mass increments, so its
    standard-error proxy is ``floor · residual / sqrt(n_eff)`` with
    ``n_eff = (Σd)² / Σd²`` the effective number of unrefined clusters
    (one dominant straggler cluster -> n_eff ~ 1 -> wide band; many
    small ones -> n_eff ~ count -> tight band)."""
    p = profile if isinstance(profile, np.ndarray) \
        else np.asarray(profile, np.float64)
    idx = min(max(int(budget), 0), p.shape[-1] - 1)
    tail = p[idx:]
    d = tail[1:] - tail[:-1]
    tot = float(tail[-1] - tail[0])
    if tot <= 0.0:
      return 0.0
    n_eff = tot * tot / max(float(d @ d), 1e-30)
    return self.floor * tot / max(math.sqrt(n_eff), 1.0)

  # -- calibration -----------------------------------------------------------
  def fit(self, raws, measured) -> Dict[str, float]:
    """Fit the calibration layer from (raw, measured-loss) pairs:
    isotonic with >= 8 pairs, affine (slope clipped non-negative) below,
    identity when the raw signal is degenerate.  Stores the ``conf``
    quantile of |residual| as the band half-width — estimated on a
    HELD-OUT interleaved quarter of the pairs when there are enough
    (in-sample isotonic residuals are biased low: PAVA interpolates the
    noise, so bands sized on them under-cover; property-tested in
    tests/test_estimator.py).  Returns fit stats including the Spearman
    rank correlation the CI gates on."""
    raws = np.asarray(raws, np.float64)
    meas = np.clip(np.asarray(measured, np.float64), 0.0, 1.0)
    if len(raws) >= 2 and float(np.ptp(raws)) > 1e-12:
      if len(raws) >= 8:
        resid = self._holdout_resid(raws, meas) if len(raws) >= 16 \
            else None
        self._iso_x, self._iso_y = isotonic_fit(raws, meas)
        if resid is None:
          resid = np.abs(self.predict(raws) - meas)
      else:
        slope, icept = np.polyfit(raws, meas, 1)
        slope = max(float(slope), 0.0)
        lo, hi = float(raws.min()), float(raws.max())
        self._iso_x = np.array([lo, hi])
        self._iso_y = np.clip(
            np.array([icept + slope * lo, icept + slope * hi]), 0.0, 1.0)
        resid = np.abs(self.predict(raws) - meas)
    else:
      resid = np.abs(self.predict(raws) - meas) if len(raws) \
          else np.zeros(1)
    self._resid_q = float(np.quantile(resid, self.conf))
    return {"n": int(len(raws)),
            "spearman": spearman(raws, meas) if len(raws) > 1 else 0.0,
            "resid_q": self._resid_q}

  @staticmethod
  def _holdout_resid(raws, meas) -> np.ndarray:
    """Honest band residuals: fit isotonic on an interleaved 3/4 of the
    raw-sorted pairs, score the held-out quarter.  Deterministic (no
    RNG) and rank-balanced — every region of the raw axis contributes
    both train and held-out points."""
    order = np.argsort(raws, kind="stable")
    held = np.zeros(len(raws), bool)
    held[order[::4]] = True
    kx, ky = isotonic_fit(raws[~held], meas[~held])
    pred = np.clip(np.interp(raws[held], kx, ky), 0.0, 1.0)
    return np.abs(pred - meas[held])

  def predict(self, raw):
    """Calibrated loss prediction (identity before :meth:`fit`)."""
    raw = np.asarray(raw, np.float64)
    if not self.calibrated or len(self._iso_x) < 2:
      out = np.clip(raw, 0.0, 1.0)
    else:
      out = np.clip(np.interp(raw, self._iso_x, self._iso_y), 0.0, 1.0)
    return float(out) if out.ndim == 0 else out

  def band(self, raw, spread: float = 0.0) -> Tuple[float, float]:
    """Confidence band around the calibrated prediction: the calibration
    residual ``conf``-quantile, widened (never narrowed) by the
    per-request spread proxy.  Uncalibrated, the half-width degrades to
    half the stage-1 floor — the widest honest claim."""
    pred = float(self.predict(raw))
    half = (self._resid_q if self.calibrated else 0.5 * self.floor) \
        + max(float(spread), 0.0)
    return max(pred - half, 0.0), min(pred + half, 1.0)

  # -- contract support ------------------------------------------------------
  def bucket_for_epsilon(self, profile, buckets: Sequence[int],
                         epsilon: float) -> int:
    """Smallest budget bucket whose calibrated predicted loss is <= ε.
    ε <= 0 demands exactness, which no *estimate* can certify — it
    returns the largest bucket (full refinement) by definition, making
    ``error_bounded`` at ε=0 reproduce the exact path.  Predicted loss
    is monotone non-increasing in the bucket (isotonic calibration of a
    coverage-monotone raw), so the first satisfying bucket is minimal;
    if none satisfies, the largest bucket is returned.

    Vectorized over the bucket set — this runs on the host once per slot
    per decode step under ``error_bounded``, and the accuracy bench
    guards the whole estimator at <5% of the step wall."""
    if epsilon <= 0.0:
      return int(buckets[-1])
    p = profile if isinstance(profile, np.ndarray) \
        else np.asarray(profile, np.float64)
    last = p.shape[-1] - 1
    idx = [min(max(int(b), 0), last) for b in buckets]
    # raw lives in [0, floor] for a clipped coverage profile; the knots
    # are clipped to [0, 1] at fit time, so no re-clip is needed here.
    raw = self.floor * (1.0 - p[..., idx])
    if self.calibrated and len(self._iso_x) >= 2:
      pred = np.interp(raw, self._iso_x, self._iso_y)
    else:
      pred = raw
    for i, ok in enumerate(pred <= epsilon):
      if ok:
        return int(buckets[i])
    return int(buckets[-1])
