"""Deadline -> budget policy: the control plane's decision layer
(DESIGN.md §10).

One object — :class:`DeadlineBudgetPolicy` — owns every budget decision
the serving stack makes, for all four techniques
(``basic`` / ``partial`` / ``accuracytrader`` / ``fixed``):

  * ``budget_for``: (deadline, queue delay) -> bucketed refinement budget,
    by scanning the static bucket set against the configured latency
    predictor (any :mod:`repro.control.predictors` implementation) — the
    hardware adaptation of the paper's in-loop ``l_ela < l_spe`` check.
  * :func:`allocate_budget`: split the step budget over components in
    proportion to synopsis relevance mass, with **stranded-budget
    recirculation**: budget a binding per-component cap would strand is
    redistributed over the unsaturated components instead of dropped.
  * ``gather_modes``: the per-component FULL / STAGE1 / DROP decision for
    the scatter-gather frontend, including the **hedged replica reissue**
    min (a component predicted to miss the step deadline is reissued to
    its replica and the earlier completion counts).

:class:`BudgetController` is the bare (predictor, buckets) -> budget
mapper, kept for callers that need no technique dispatch (the simulator,
the single-batch demo loop); ``repro.core.deadline`` re-exports it for
backwards compatibility.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import numpy as np

from repro.control.predictors import AffinePredictor

# Per-component gather modes (the fe_mode vector fed into the step).
MODE_DROP, MODE_STAGE1, MODE_FULL = 0, 1, 2

POLICIES = ("basic", "partial", "accuracytrader", "fixed")

# Serving contracts (DESIGN.md §13) — orthogonal to the POLICIES axis:
#   "deadline"            — the legacy behavior (whatever the policy says).
#   "error_bounded"       — BlinkDB-style ε-or-deadline: refine until the
#                           online estimator predicts loss <= ε, answer
#                           early, and the freed budget recirculates to
#                           requests that need it.
#   "deadline_with_bound" — legacy budgets, but every answer carries a
#                           calibrated confidence band on its loss.
CONTRACTS = ("deadline", "error_bounded", "deadline_with_bound")


def allocate_budget(mass, total: int, caps, recirculate: bool = True):
  """Split ``total`` refinement clusters over components ∝ relevance mass.

  ``mass`` (..., N) non-negative; ``caps`` (..., N) per-component valid
  cluster counts.  Largest-remainder rounding on top of the proportional
  floor; monotone in mass (more synopsis relevance mass never means a
  smaller budget).  A budget covering the whole corpus saturates every
  cap exactly (the ``basic`` full gather stays exact).

  ``recirculate=True`` (the default): budget stranded by a binding cap is
  redistributed over the still-unsaturated components — two rounds ∝
  mass (the residue almost always drains in one; the second covers a
  cascading saturation), then one round ∝ remaining *capacity* that
  provably drains whatever is left: when ``left <= sum(caps - alloc)``
  every component's capacity-proportional share (largest-remainder
  rounded) fits under its cap, so nothing clips and exactly ``left`` is
  spent.  Conservation — ``sum(alloc) == min(total, sum(caps))`` — thus
  holds even when unsaturated components carry zero mass (f32 exp
  underflow on far-from-max scores), and the unrolled work on the decode
  hot path is three fixed rounds, not N.  ``recirculate=False`` keeps
  the legacy cap-and-drop behaviour (the step simply refines less).

  **All-saturated / all-faulted component sets** (every cap 0 — e.g. all
  components degraded to STAGE1/DROP under mode-aware caps, DESIGN.md
  §11): recirculation is three *fixed* rounds, so it terminates
  unconditionally, and the final ``total >= capsum`` guard pins the
  allocation to ``caps`` itself — conservation degrades gracefully to
  ``sum(alloc) == sum(caps)`` (everything the components can still
  absorb) instead of stranding or inventing budget.  Property-tested in
  tests/test_control.py (all-zero caps, zero-cap subsets carrying all
  the mass, ``total > capsum``, exact saturation)."""
  import jax.numpy as jnp  # noqa: PLC0415 — keep module import light

  caps = caps.astype(jnp.int32)
  share = total * mass / jnp.maximum(
      jnp.sum(mass, axis=-1, keepdims=True), 1e-30)
  floor = jnp.floor(share)
  base = jnp.minimum(floor, caps).astype(jnp.int32)
  rem = share - floor
  left = total - jnp.sum(base, axis=-1, keepdims=True)
  order = jnp.argsort(-rem, axis=-1)
  rank = jnp.argsort(order, axis=-1)
  extra = (rank < left).astype(jnp.int32)
  alloc = jnp.minimum(base + extra, caps)

  if recirculate:
    def respend(alloc, weights):
      """One largest-remainder round of the residue ∝ ``weights``
      (zero-weight components sort last for the integer units)."""
      left = (total - jnp.sum(alloc, axis=-1, keepdims=True)) \
          .astype(jnp.float32)
      share = left * weights / jnp.maximum(
          jnp.sum(weights, axis=-1, keepdims=True), 1e-30)
      floor = jnp.floor(share)
      rem = jnp.where(weights > 0, share - floor, -1.0)
      order = jnp.argsort(-rem, axis=-1)
      rank = jnp.argsort(order, axis=-1)
      ints = left - jnp.sum(floor, axis=-1, keepdims=True)
      extra = floor.astype(jnp.int32) + (rank < ints).astype(jnp.int32)
      return jnp.minimum(alloc + extra, caps)

    for _ in range(2):
      alloc = respend(alloc, jnp.where(alloc < caps, mass, 0.0))
    alloc = respend(alloc, (caps - alloc).astype(jnp.float32))

  capsum = jnp.sum(caps, axis=-1, keepdims=True)
  return jnp.where(total >= capsum, caps, alloc)


@dataclasses.dataclass
class BudgetController:
  """Maps (deadline, queue delay) -> the largest static budget bucket the
  predictor expects to finish in time (always at least the smallest
  bucket: stage 1 runs no matter what)."""
  model: AffinePredictor         # any control.predictors implementation
  buckets: Sequence[int] = (0, 1, 2, 4, 8, 16, 32, 64, 128)
  i_max_cap: Optional[int] = None   # paper's i_max (e.g. top-40% clusters)

  def budget_for(self, deadline: float, queue_delay: float = 0.0) -> int:
    slack = deadline - queue_delay
    candidates = self.buckets
    if not getattr(self.model, "extrapolates", True):
      # Bucketed predictors guess an untried budget from the NEAREST
      # tried one, which makes a cold controller see the biggest bucket
      # as cheap as the smallest and blow early deadlines.  Slow-start:
      # trust tried buckets, explore at most ONE bucket above the
      # largest tried so far.  (Keys-only accessor: this runs on every
      # decode step and must not evaluate the predictions themselves.)
      seen = self.model.observed_buckets()
      top = max(seen) if seen else -1
      untried = [b for b in self.buckets if b > top]
      candidates = [b for b in self.buckets
                    if b <= top or b in untried[:1]]
    chosen = self.buckets[0]
    for b in candidates:
      if self.i_max_cap is not None and b > self.i_max_cap:
        continue
      if self.model.predict(b) <= slack and b > chosen:
        chosen = b
    return chosen

  def observe(self, budget: int, latency: float) -> None:
    self.model.observe(budget, latency)


@dataclasses.dataclass
class DeadlineBudgetPolicy:
  """Technique-aware budget + gather-mode decisions on one predictor.

  ``basic``/``partial`` always spend the full budget (``i_max_cap``);
  ``fixed`` always spends ``fixed_budget``; ``accuracytrader`` asks the
  controller for the largest bucket predicted to make the deadline."""
  policy: str
  buckets: Tuple[int, ...]
  i_max_cap: int
  predictor: AffinePredictor = dataclasses.field(
      default_factory=AffinePredictor)
  fixed_budget: int = 0
  # ε-or-deadline serving contracts (DESIGN.md §13).  ``estimator`` is an
  # `repro.control.estimator.AccuracyEstimator` (duck-typed: only
  # ``bucket_for_epsilon`` is called here); required for error_bounded.
  contract: str = "deadline"
  epsilon: float = 0.0
  estimator: Optional[object] = None

  def __post_init__(self):
    if self.policy not in POLICIES:
      raise ValueError(f"policy {self.policy!r} not in {POLICIES}")
    if self.contract not in CONTRACTS:
      raise ValueError(f"contract {self.contract!r} not in {CONTRACTS}")
    if self.contract == "error_bounded" and self.estimator is None:
      raise ValueError("contract='error_bounded' needs an estimator")
    self.controller = BudgetController(
        self.predictor, buckets=self.buckets, i_max_cap=self.i_max_cap)

  def budget_for(self, deadline: float, queue_delay: float = 0.0) -> int:
    if self.policy in ("basic", "partial"):
      return self.i_max_cap
    if self.policy == "fixed":
      return self.fixed_budget
    return self.controller.budget_for(deadline, queue_delay)

  def budget_for_contract(self, deadline: float, queue_delay: float = 0.0,
                          profiles: Sequence = ()) -> Tuple[int, int]:
    """ε-or-deadline composition (DESIGN.md §13): the step budget is the
    min of the policy's deadline-driven budget and — under the
    ``error_bounded`` contract — the smallest bucket the online
    estimator predicts meets ε for EVERY resident request's coverage
    profile (the most demanding request binds; a step is shared).
    Returns ``(granted, base)`` so the caller can account the freed
    budget ``base - granted`` that recirculates to other work."""
    base = self.budget_for(deadline, queue_delay)
    if self.contract != "error_bounded" or not len(profiles):
      return base, base
    need = max(self.estimator.bucket_for_epsilon(p, self.buckets,
                                                 self.epsilon)
               for p in profiles)
    return min(need, base), base

  def observe(self, budget: int, latency: float) -> None:
    self.predictor.observe(budget, latency)

  def gather_modes(self, t_pred, deadline_ms: float, t_hedged=None):
    """Per-component gather decision from predicted completion times.

    ``t_pred`` (N,): each component's predicted completion for this step.
    ``t_hedged`` (N,) or None: the predicted completion of the same
    shard's reissue on its replica — when given, a component flagged as
    likely to miss is hedged and the *earlier* of the two completions
    decides (and later prices) its gather.

    Returns ``(mode, hedged)``: the int32 FULL/STAGE1/DROP vector fed to
    the device step, and the bool mask of components whose reissue was
    actually dispatched."""
    t_pred = np.asarray(t_pred, np.float64)
    hedged = np.zeros(t_pred.shape, bool)
    eff = t_pred
    if t_hedged is not None:
      hedged = t_pred > deadline_ms
      eff = np.where(hedged, np.minimum(t_pred, t_hedged), t_pred)
    if self.policy == "partial":
      mode = np.where(eff <= deadline_ms, MODE_FULL, MODE_DROP)
    elif self.policy == "accuracytrader":
      mode = np.where(eff <= deadline_ms, MODE_FULL, MODE_STAGE1)
    else:                       # basic / fixed: always full gather
      mode = np.full(t_pred.shape, MODE_FULL)
    return mode.astype(np.int32), hedged

  def recover_modes(self, t_pred, deadline_ms: float, t_retry=None,
                    alive=None, retry_alive=None):
    """Fault-aware generalization of :meth:`gather_modes` — the recovery
    ladder FULL -> retry-on-replica -> STAGE1 -> DROP (DESIGN.md §11,
    `repro.control.recovery`).  ``t_retry`` (K, N) carries the predicted
    completion of each bounded backoff retry; ``alive``/``retry_alive``
    the fault world's liveness.  Returns ``(mode, retries, eff)``; with
    one zero-delay retry, all components alive, this is exactly the
    legacy hedged ``gather_modes`` decision."""
    from repro.control.recovery import plan_recovery  # noqa: PLC0415
    return plan_recovery(self.policy, t_pred, deadline_ms, t_retry=t_retry,
                         alive=alive, retry_alive=retry_alive)
