"""Per-component latency predictors — the control plane's one prediction
substrate (DESIGN.md §10).

Every place the serving stack predicts a response time — the engine's
deadline->budget controller, the cluster frontend's hedged-gather
decision, the simulator's calibrated component model — consumes exactly
one of these objects behind one duck-typed interface:

    observe(budget, latency_ms)   fold one measured (budget, wall) pair
    predict(budget) -> float      expected latency of that budget bucket
    table() -> {bucket: ms}       snapshot over the observed buckets

Implementations:

  * :class:`AffinePredictor` — exponentially-weighted least-squares fit of
    ``lat(i) = base + slope * i`` (the paper's in-loop ``l_ela < l_spe``
    calibration; previously ``core.deadline.LatencyModel``).
  * :class:`EwmaPredictor` — one EWMA cell per budget bucket with
    nearest-bucket fallback (previously the private ``wall_ewma`` dict in
    ``serve.cluster.ClusterStepBackend``).
  * :class:`QuantilePredictor` — sliding-window quantile digest per
    bucket: ``predict`` returns a configured percentile of the recent
    window, so deadlines can target e.g. the p90 step time instead of the
    mean — the conservative choice when step times are heavy-tailed
    (stragglers, interference).

:func:`make_predictor` builds one from a CLI-friendly spec string
(``"affine"`` | ``"ewma"`` | ``"quantile"`` | ``"quantile:95"``).
"""
from __future__ import annotations

import collections
import dataclasses
from typing import ClassVar, Dict, List, Sequence

import numpy as np


def percentile(xs: Sequence[float], p: float) -> float:
  if len(xs) == 0:
    return 0.0
  return float(np.percentile(np.asarray(xs), p))


class TailTracker:
  """Streaming latency percentiles per window (p50/p99/p99.9)."""

  def __init__(self):
    self.samples: List[float] = []

  def observe(self, latency: float) -> None:
    self.samples.append(latency)

  def p(self, q: float) -> float:
    return percentile(self.samples, q)

  def summary(self) -> dict:
    return {"p50": self.p(50), "p99": self.p(99), "p999": self.p(99.9),
            "mean": float(np.mean(self.samples)) if self.samples else 0.0,
            "n": len(self.samples)}


@dataclasses.dataclass
class AffinePredictor:
  """Exponentially-weighted least-squares fit of lat(i) = base + slope*i.

  Sufficient statistics decay by (1 - alpha) per observation, so the model
  tracks drifting service times (load changes, interference)."""
  base: float = 1.0
  slope: float = 0.1
  alpha: float = 0.05          # forgetting rate
  # The fitted line extrapolates soundly to budgets never tried (cost
  # grows with the positive slope); bucketed predictors do not.
  extrapolates: ClassVar[bool] = True

  def __post_init__(self):
    self._sw = self._sb = self._sl = self._sbb = self._sbl = 0.0
    self._seen: set = set()

  def observe(self, budget: int, latency: float) -> None:
    g = 1.0 - self.alpha
    b = float(budget)
    self._seen.add(int(budget))
    self._sw = self._sw * g + 1.0
    self._sb = self._sb * g + b
    self._sl = self._sl * g + latency
    self._sbb = self._sbb * g + b * b
    self._sbl = self._sbl * g + b * latency
    det = self._sw * self._sbb - self._sb * self._sb
    if det > 1e-9 and self._sw > 3.0:
      slope = (self._sw * self._sbl - self._sb * self._sl) / det
      base = (self._sl - slope * self._sb) / self._sw
      self.slope = max(slope, 1e-6)
      self.base = max(base, 1e-6)
    else:
      self.base = max(self._sl / max(self._sw, 1e-9), 1e-6)

  def predict(self, budget: int) -> float:
    return self.base + self.slope * budget

  def observed_buckets(self):
    return sorted(self._seen)

  def table(self) -> Dict[int, float]:
    return {b: self.predict(b) for b in sorted(self._seen)}


@dataclasses.dataclass
class EwmaPredictor:
  """One EWMA cell per budget bucket; unobserved buckets fall back to the
  nearest observed bucket, then to ``prior_ms``."""
  beta: float = 0.3            # weight on the newest observation
  prior_ms: float = 5.0
  # Nearest-bucket fallback makes untried budgets look as cheap as the
  # nearest tried one — the budget controller must ramp, not trust it.
  extrapolates: ClassVar[bool] = False

  def __post_init__(self):
    self._t: Dict[int, float] = {}

  def observe(self, budget: int, latency: float) -> None:
    b = int(budget)
    prev = self._t.get(b)
    self._t[b] = latency if prev is None \
        else (1.0 - self.beta) * prev + self.beta * latency

  def predict(self, budget: int) -> float:
    b = int(budget)
    if b in self._t:
      return self._t[b]
    if self._t:
      nearest = min(self._t, key=lambda x: abs(x - b))
      return self._t[nearest]
    return self.prior_ms

  def observed_buckets(self):
    return sorted(self._t)

  def table(self) -> Dict[int, float]:
    return dict(self._t)


@dataclasses.dataclass
class QuantilePredictor:
  """Sliding-window quantile digest per budget bucket.

  ``predict`` returns the ``pct`` percentile over the last ``window``
  observations of that bucket (nearest observed bucket, then ``prior_ms``,
  when unobserved).  Predictions are monotone in ``pct`` and always
  bracketed by the window's min/max, so a high percentile target makes
  the deadline controller conservative exactly when the measured step
  times are heavy-tailed."""
  pct: float = 90.0
  window: int = 64
  prior_ms: float = 5.0
  extrapolates: ClassVar[bool] = False   # same fallback rule as EWMA

  def __post_init__(self):
    if not 0.0 <= self.pct <= 100.0:
      raise ValueError(f"pct {self.pct} outside [0, 100]")
    if self.window < 1:
      raise ValueError(f"window {self.window} < 1")
    self._w: Dict[int, collections.deque] = {}

  def observe(self, budget: int, latency: float) -> None:
    self._w.setdefault(
        int(budget), collections.deque(maxlen=self.window)).append(latency)

  def predict(self, budget: int, pct: float | None = None) -> float:
    b = int(budget)
    if b not in self._w:
      if not self._w:
        return self.prior_ms
      b = min(self._w, key=lambda x: abs(x - budget))
    return percentile(self._w[b], self.pct if pct is None else pct)

  def observed_buckets(self):
    return sorted(self._w)

  def table(self) -> Dict[int, float]:
    return {b: self.predict(b) for b in sorted(self._w)}


def make_predictor(spec: str, **kw):
  """Build a predictor from a spec string: ``"affine"``, ``"ewma"``,
  ``"quantile"`` or ``"quantile:<pct>"``.  ``kw`` forwards to the class
  (e.g. ``base=/slope=/alpha=`` for affine, ``prior_ms=`` for the
  bucketed ones)."""
  name, _, arg = str(spec).partition(":")
  if name in ("affine", "ewma") and arg:
    raise ValueError(f"predictor spec {spec!r}: only quantile takes a "
                     ":<pct> argument; pass keyword overrides for "
                     f"{name} instead")
  if name == "affine":
    return AffinePredictor(**kw)
  if name == "ewma":
    return EwmaPredictor(**kw)
  if name == "quantile":
    if arg:
      kw.setdefault("pct", float(arg))
    return QuantilePredictor(**kw)
  raise ValueError(f"unknown predictor spec {spec!r} "
                   "(want affine | ewma | quantile[:pct])")
