"""Gather-side recovery ladder: timeout -> bounded retry -> stage-1
fallback (DESIGN.md §11).

PR 5's hedged reissue was a *one-shot* race: a component predicted to
miss the step deadline had its refinement reissued to the shard's ring
replica, immediately, once.  This module generalizes that into the
recovery ladder a fault-tolerant scatter-gather frontend actually runs
(Tail-Tolerant Distributed Search, arXiv 1707.07426):

  FULL  -> retry on replica (bounded, exponential backoff)
        -> STAGE1 (the frontend's cached synopsis answer stands in)
        -> DROP   (partial execution only: the shard's mass is skipped)

  * the per-component **timeout** is the control-plane predictor's
    expected completion of the primary (not a static constant), so slow
    shards get proportionally more patience than fast ones;
  * **retry r** dispatches after an exponential backoff delay
    ``timeout * backoff_base * backoff_mult^(r-1)`` (retry 0 is the
    legacy immediate hedge at delay 0) to the shard's next ring-replica
    holder, and the earliest live completion counts;
  * a component with **no live path** (primary and every tried replica
    crashed) terminally degrades by policy: ``accuracytrader`` serves
    the stage-1 synopsis (a dead component costs accuracy, never
    availability), ``partial`` drops the shard, ``basic``/``fixed``
    drop only when nothing can answer at all.

Everything here is pure array math over *predicted or realized*
completion times — the cluster backend supplies the times (with its
interference draws and fault world), `DeadlineBudgetPolicy.recover_modes`
supplies the technique dispatch, and the same functions price both the
plan-time decision and the account-time realization so they can never
drift apart (the same one-expression discipline as
``ClusterStepBackend._hedge_time``).

With ``max_retries=1``, no faults and zero delay this reproduces the
legacy ``gather_modes`` hedging decision exactly (asserted in
tests/test_resilience.py).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from repro.control.policy import MODE_DROP, MODE_FULL, MODE_STAGE1, POLICIES

__all__ = ["RetryPolicy", "plan_recovery", "realized_recovery"]


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
  """Bounded retry with exponential backoff.

  ``max_retries`` caps the reissues per shard per step; ``delays``
  converts a per-component timeout (the predictor's expected primary
  completion) into each retry's dispatch offset.  Retry 0 is the legacy
  immediate hedge (delay 0); retry r >= 1 waits
  ``timeout * backoff_base * backoff_mult^(r-1)`` — monotone
  non-decreasing for ``backoff_mult >= 1`` (asserted in tests)."""
  max_retries: int = 1
  backoff_base: float = 0.5
  backoff_mult: float = 2.0

  def __post_init__(self):
    if self.max_retries < 0:
      raise ValueError(f"max_retries {self.max_retries} < 0")
    if self.backoff_base < 0.0 or self.backoff_mult < 1.0:
      raise ValueError("backoff_base must be >= 0 and backoff_mult >= 1 "
                       f"(got {self.backoff_base}, {self.backoff_mult})")

  def delays(self, timeout_ms) -> np.ndarray:
    """Dispatch offsets of retries 0..max_retries-1: (K,) for a scalar
    timeout, (K, N) for a per-component timeout vector."""
    t = np.asarray(timeout_ms, np.float64)
    k = np.arange(self.max_retries, dtype=np.float64)
    fac = np.where(k == 0, 0.0,
                   self.backoff_base * self.backoff_mult ** (k - 1.0))
    return fac.reshape((self.max_retries,) + (1,) * t.ndim) * t[None]


def plan_recovery(policy: str, t_pred, deadline_ms: float,
                  t_retry=None, alive=None, retry_alive=None
                  ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
  """Pre-dispatch recovery decision for one step.

  ``t_pred`` (N,): predicted primary completions.  ``t_retry`` (K, N):
  predicted completion of retry r on its replica holder (backoff delay
  included).  ``alive`` / ``retry_alive``: fault-world liveness of the
  primary / each retry's holder (None = all alive).  Retries dispatch
  only while a component still has no live completion inside
  ``deadline_ms`` (dead primaries always retry — even under an infinite
  deadline there is nothing to wait for), and the earliest live
  completion decides the mode.

  Returns ``(mode, retries, eff)``: the int32 FULL/STAGE1/DROP vector,
  how many reissues each component actually dispatched (<= K, the
  bounded-retry invariant), and the effective decision time."""
  if policy not in POLICIES:
    raise ValueError(f"policy {policy!r} not in {POLICIES}")
  t_pred = np.asarray(t_pred, np.float64)
  n = t_pred.shape[0]
  alive = np.ones(n, bool) if alive is None else np.asarray(alive, bool)
  eff = np.where(alive, t_pred, np.inf)
  retries = np.zeros(n, np.int64)
  k = 0 if t_retry is None else len(t_retry)
  if k:
    t_retry = np.asarray(t_retry, np.float64)
    retry_alive = np.ones((k, n), bool) if retry_alive is None \
        else np.asarray(retry_alive, bool)
  for r in range(k):
    need = ~(np.isfinite(eff) & (eff <= deadline_ms))
    if not need.any():
      break
    cand = np.where(retry_alive[r], t_retry[r], np.inf)
    eff = np.where(need, np.minimum(eff, cand), eff)
    retries = retries + need
  ok = np.isfinite(eff) & (eff <= deadline_ms)
  if policy == "partial":
    mode = np.where(ok, MODE_FULL, MODE_DROP)
  elif policy == "accuracytrader":
    mode = np.where(ok, MODE_FULL, MODE_STAGE1)
  else:
    # basic/fixed have no deadline semantics: FULL whenever any live
    # path exists, DROP only when nothing can answer at all.
    mode = np.where(np.isfinite(eff), MODE_FULL, MODE_DROP)
  return mode.astype(np.int32), retries, eff


def realized_recovery(t_real, t_retry_real, retries, alive=None,
                      retry_alive=None) -> np.ndarray:
  """Account-time twin of :func:`plan_recovery`: the realized completion
  of each component given the retries the plan actually dispatched
  (``retries`` from ``plan_recovery`` — retry r participates only where
  ``retries > r``).  Components with no live dispatched path realize
  ``inf`` (the caller's mode already degraded them to STAGE1/DROP)."""
  t_real = np.asarray(t_real, np.float64)
  n = t_real.shape[0]
  alive = np.ones(n, bool) if alive is None else np.asarray(alive, bool)
  eff = np.where(alive, t_real, np.inf)
  k = 0 if t_retry_real is None else len(t_retry_real)
  if k:
    t_retry_real = np.asarray(t_retry_real, np.float64)
    retry_alive = np.ones((k, n), bool) if retry_alive is None \
        else np.asarray(retry_alive, bool)
  for r in range(k):
    m = retries > r
    cand = np.where(retry_alive[r], t_retry_real[r], np.inf)
    eff = np.where(m, np.minimum(eff, cand), eff)
  return eff
