"""AccuracyTrader core: synopsis management + accuracy-aware processing."""
from repro.core import cluster, deadline, engine, synopsis
from repro.core.deadline import BudgetController, LatencyModel
from repro.core.engine import ProcessResult, approximate_process, exact_process
from repro.core.synopsis import Synopsis, build, insert, needs_rebuild, update_changed

__all__ = [
    "cluster", "deadline", "engine", "synopsis",
    "BudgetController", "LatencyModel",
    "ProcessResult", "approximate_process", "exact_process",
    "Synopsis", "build", "insert", "needs_rebuild", "update_changed",
]
