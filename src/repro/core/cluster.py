"""Similarity clustering for synopsis creation (paper §2.2 steps 1-2).

The paper uses incremental SVD + R-tree. Neither maps to TPU (pointer
trees, data-dependent shapes), so we adapt the *insight*:

  step 1  (dimensionality reduction)  -> power-iteration PCA (MXU matmuls)
  step 2  (balanced similarity groups) -> equal-size clusters, either by
          Morton-order chunking of PCA coords (fast path) or by recursive
          median splits on the widest dimension ("balanced kd", quality
          path).  Equal-size clusters are the analogue of the R-tree's
          depth-balance: every aggregated point covers the same number of
          originals, i.e. the same approximation level — and they give XLA
          the static shapes it needs.

Everything here is pure JAX and jit-able.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Step 1: dimensionality reduction (paper: incremental SVD; here: PCA via
# subspace power iteration — iteration count independent of dataset size,
# matching the paper's "execution time independent of dataset size").
# ---------------------------------------------------------------------------

def pca_project(
    data: jax.Array,
    out_dim: int = 3,
    num_iters: int = 8,
    *,
    key: jax.Array | None = None,
) -> Tuple[jax.Array, jax.Array]:
  """Project ``data`` (n, v) to (n, out_dim) via top-``out_dim`` PCA.

  Returns (coords (n, j), projection (v, j)).  float32 internally for
  numerical stability of the orthogonalisation.
  """
  x = data.astype(jnp.float32)
  n, v = x.shape
  mean = jnp.mean(x, axis=0, keepdims=True)
  xc = x - mean
  if key is None:
    key = jax.random.PRNGKey(0)
  q = jax.random.normal(key, (v, out_dim), dtype=jnp.float32)
  q, _ = jnp.linalg.qr(q)

  def body(_, q):
    # One subspace iteration:  q <- orth( Cov @ q )  without forming Cov.
    z = xc.T @ (xc @ q)          # (v, j): two MXU matmuls, no (v, v) matrix
    q, _ = jnp.linalg.qr(z)
    return q

  q = jax.lax.fori_loop(0, num_iters, body, q)
  return xc @ q, q


# ---------------------------------------------------------------------------
# Step 2a: Morton (Z-order) balanced chunking — one sort, fully vectorised.
# ---------------------------------------------------------------------------

def morton_codes(coords: jax.Array, bits: int = 10) -> jax.Array:
  """Interleave ``bits`` quantised bits per dimension into a Z-order code.

  coords: (n, j) with j <= 5.  Returns uint64-ish codes packed in int64.
  """
  n, j = coords.shape
  lo = jnp.min(coords, axis=0, keepdims=True)
  hi = jnp.max(coords, axis=0, keepdims=True)
  scale = jnp.where(hi > lo, hi - lo, 1.0)
  q = jnp.clip(((coords - lo) / scale * (2**bits - 1)), 0, 2**bits - 1)
  itype = jnp.int64 if jax.config.jax_enable_x64 else jnp.int32
  if bits * j > (62 if itype == jnp.int64 else 30):
    bits = (62 if itype == jnp.int64 else 30) // j
    q = jnp.clip(q, 0, 2**bits - 1)
  q = q.astype(itype)                                         # (n, j)
  code = jnp.zeros((n,), dtype=itype)
  for b in range(bits):            # static python loop: bits is small
    for d in range(j):
      bit = (q[:, d] >> b) & 1
      code = code | (bit << (b * j + d))
  return code


def morton_cluster(coords: jax.Array, num_clusters: int) -> jax.Array:
  """Equal-size clusters by sorting on Morton codes and chunking.

  Returns ``perm`` (n,): row indices in cluster-contiguous order — cluster c
  owns ``perm[c*C:(c+1)*C]`` where C = ceil(n / num_clusters); the tail
  cluster may be conceptually short but ``perm`` is always a full
  permutation (callers mask by count).
  """
  codes = morton_codes(coords)
  return jnp.argsort(codes)


# ---------------------------------------------------------------------------
# Step 2b: recursive median splits ("balanced kd-tree") — closer in spirit
# to the R-tree: each split separates along the widest dimension, so leaf
# clusters are tight bounding boxes.  log2(m) vectorised rounds.
# ---------------------------------------------------------------------------

def balanced_kd_cluster(coords: jax.Array, num_clusters: int) -> jax.Array:
  """Equal-size clusters via recursive median splits.  num_clusters must be
  a power of two.  Returns ``perm`` as in :func:`morton_cluster`.
  """
  n, j = coords.shape
  levels = int(num_clusters).bit_length() - 1
  if (1 << levels) != num_clusters:
    raise ValueError(f"num_clusters={num_clusters} must be a power of two")

  perm = jnp.arange(n)
  x = coords.astype(jnp.float32)

  for level in range(levels):
    seg = 1 << level                 # current number of segments
    seg_len = n // seg
    # View rows in segment-major order and split each segment at its median
    # along its own widest dimension.
    xs = x[perm]                                         # (n, j)
    xs = xs[: seg * seg_len].reshape(seg, seg_len, j)
    var = jnp.var(xs, axis=1)                            # (seg, j)
    dim = jnp.argmax(var, axis=1)                        # (seg,)
    key_vals = jnp.take_along_axis(
        xs, dim[:, None, None], axis=2)[..., 0]          # (seg, seg_len)
    order = jnp.argsort(key_vals, axis=1)                # within-segment sort
    head = perm[: seg * seg_len].reshape(seg, seg_len)
    head = jnp.take_along_axis(head, order, axis=1).reshape(-1)
    perm = jnp.concatenate([head, perm[seg * seg_len:]])
  return perm


def cluster(
    coords: jax.Array,
    num_clusters: int,
    method: str = "kd",
) -> jax.Array:
  """Dispatch: 'kd' (quality, power-of-two clusters) or 'morton' (fast)."""
  if method == "kd":
    return balanced_kd_cluster(coords, num_clusters)
  if method == "morton":
    return morton_cluster(coords, num_clusters)
  raise ValueError(f"unknown cluster method {method!r}")


# ---------------------------------------------------------------------------
# Incremental assignment: place *new* points into existing clusters (paper:
# "add new leaf nodes").  Nearest centroid in PCA space.
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=())
def assign_to_nearest(
    new_coords: jax.Array,      # (b, j)  PCA coords of new points
    cluster_centers: jax.Array,  # (m, j)  PCA-space cluster centers
) -> jax.Array:
  d2 = (
      jnp.sum(new_coords**2, axis=1)[:, None]
      - 2.0 * new_coords @ cluster_centers.T
      + jnp.sum(cluster_centers**2, axis=1)[None, :]
  )
  return jnp.argmin(d2, axis=1)
