"""Backwards-compatible aliases for the latency-control plane.

The deadline->budget controller and the calibrated latency model used to
live here; they are now part of the unified control plane
(`repro.control`, DESIGN.md §10), which the serving engine, the
scatter-gather cluster tier and the discrete-event simulator all share.
``LatencyModel`` is the control plane's :class:`AffinePredictor` — one of
several predictors (EWMA, sliding-window quantile) behind one interface.
"""
from repro.control.policy import BudgetController
from repro.control.predictors import AffinePredictor as LatencyModel

__all__ = ["BudgetController", "LatencyModel"]
