"""Deadline -> refinement-budget controller (hardware adaptation of the
paper's in-loop ``l_ela < l_spe`` check).

The component latency for a dispatch is modelled as

    lat(i) = base + per_cluster * i + queue_delay

with ``base`` (synopsis/stage-1 cost) and ``per_cluster`` (stage-2 cost per
refined cluster) calibrated online by exponentially-weighted least squares
over observed (i, latency) pairs.  Given the service deadline ``l_spe`` and
the current queueing delay, the controller returns the largest budget
``i_max`` expected to finish in time — bucketed to a small static set so the
number of compiled programs stays bounded.

This reproduces the paper's behaviour (process as many ranked clusters as
the deadline allows; always at least the synopsis) while keeping device
programs static-shaped.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence


@dataclasses.dataclass
class LatencyModel:
  """Exponentially-weighted least-squares fit of lat(i) = base + slope*i.

  Sufficient statistics decay by (1 - alpha) per observation, so the model
  tracks drifting service times (load changes, interference)."""
  base: float = 1.0
  slope: float = 0.1
  alpha: float = 0.05          # forgetting rate

  def __post_init__(self):
    self._sw = self._sb = self._sl = self._sbb = self._sbl = 0.0

  def observe(self, budget: int, latency: float) -> None:
    g = 1.0 - self.alpha
    b = float(budget)
    self._sw = self._sw * g + 1.0
    self._sb = self._sb * g + b
    self._sl = self._sl * g + latency
    self._sbb = self._sbb * g + b * b
    self._sbl = self._sbl * g + b * latency
    det = self._sw * self._sbb - self._sb * self._sb
    if det > 1e-9 and self._sw > 3.0:
      slope = (self._sw * self._sbl - self._sb * self._sl) / det
      base = (self._sl - slope * self._sb) / self._sw
      self.slope = max(slope, 1e-6)
      self.base = max(base, 1e-6)
    else:
      self.base = max(self._sl / max(self._sw, 1e-9), 1e-6)

  def predict(self, budget: int) -> float:
    return self.base + self.slope * budget


@dataclasses.dataclass
class BudgetController:
  """Maps (deadline, queue delay) -> bucketed static budget i_max."""
  model: LatencyModel
  buckets: Sequence[int] = (0, 1, 2, 4, 8, 16, 32, 64, 128)
  i_max_cap: int | None = None   # paper's i_max (e.g. top-40% of clusters)

  def budget_for(self, deadline: float, queue_delay: float = 0.0) -> int:
    slack = deadline - queue_delay - self.model.base
    raw = int(slack / self.model.slope) if slack > 0 else 0
    if self.i_max_cap is not None:
      raw = min(raw, self.i_max_cap)
    # Largest bucket <= raw; always >= smallest bucket (stage 1 always runs).
    chosen = self.buckets[0]
    for b in self.buckets:
      if b <= raw:
        chosen = b
    return chosen

  def observe(self, budget: int, latency: float) -> None:
    self.model.observe(budget, latency)
