"""Online accuracy-aware approximate processing (paper §2.3, Algorithm 1).

Generic two-stage engine:

  stage 1  process the synopsis -> initial result ``ar`` and per-cluster
           correlations ``c_i`` (line 1);
  rank     descending correlation (lines 2-3);
  stage 2  refine ``ar`` with the *original* members of the top-ranked
           clusters (lines 4-10), bounded by a static budget ``i_max``.

Hardware adaptation: the paper's in-loop wall-clock deadline check
(``l_ela < l_spe``) becomes a *static* refinement budget chosen by the
scheduler's calibrated latency model (core/deadline.py) — TPU programs need
static shapes.  Two refinement modes are provided:

  * ``iterative``  — ``lax.fori_loop`` over ranked clusters: the literal
    Algorithm-1 structure (faithful baseline);
  * ``vectorized`` — gather all selected clusters' members and refine in a
    single batched call: TPU-idiomatic (MXU-dense), same result for any
    order-insensitive ``refine_fn`` (beyond-paper optimisation).

The engine is service-agnostic: CF recommendation, document search and
synopsis attention (models/) all instantiate it with their own
``score_fn`` / ``refine_fn``.
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.synopsis import Synopsis

# score_fn(query, centroids, weight) -> (initial_result_carry, scores (m,))
ScoreFn = Callable[[jax.Array, jax.Array, jax.Array], Tuple[jax.Array, jax.Array]]
# refine_fn(carry, member_rows (cap, v), member_mask (cap, v)) -> carry
RefineFn = Callable[[jax.Array, jax.Array, jax.Array], jax.Array]


class ProcessResult(NamedTuple):
  result: jax.Array        # final carry (service-specific)
  scores: jax.Array        # (m,) correlations c_i
  selected: jax.Array      # (i_max,) cluster ids actually refined
  initial: jax.Array       # stage-1 carry, pre-refinement (for diagnostics)


@functools.partial(
    jax.jit,
    static_argnames=("score_fn", "refine_fn", "i_max", "mode"),
)
def approximate_process(
    query: jax.Array,
    syn: Synopsis,
    data: jax.Array,
    mask: jax.Array,
    *,
    score_fn: ScoreFn,
    refine_fn: RefineFn,
    i_max: int,
    mode: str = "iterative",
) -> ProcessResult:
  """Run Algorithm 1 for one request against one component's subset."""
  # Line 1: process the synopsis -> initial result + correlations.
  initial, scores = score_fn(query, syn.centroids, syn.centroid_weight)

  if i_max == 0:
    return ProcessResult(initial, scores, jnp.zeros((0,), jnp.int32), initial)

  # Lines 2-3: rank clusters by correlation.
  _, selected = jax.lax.top_k(scores, i_max)
  selected = selected.astype(jnp.int32)

  def gather_members(c):
    idx = syn.member_idx[c]                          # (cap,)
    ok = (idx >= 0)
    rows = data[jnp.maximum(idx, 0)]
    msk = mask[jnp.maximum(idx, 0)] * ok[:, None].astype(mask.dtype)
    return rows, msk

  if mode == "iterative":
    # Lines 4-10: sequential improvement, most-correlated set first.
    def body(i, carry):
      rows, msk = gather_members(selected[i])
      return refine_fn(carry, rows, msk)
    result = jax.lax.fori_loop(0, i_max, body, initial)
  elif mode == "vectorized":
    rows, msk = jax.vmap(gather_members)(selected)   # (i_max, cap, v)
    v = rows.shape[-1]
    result = refine_fn(initial, rows.reshape(-1, v), msk.reshape(-1, v))
  else:
    raise ValueError(f"unknown mode {mode!r}")

  return ProcessResult(result, scores, selected, initial)


# ---------------------------------------------------------------------------
# Reference exact processing (the "Basic" technique in §4): full computation
# over the entire input data — used to measure accuracy loss.
# ---------------------------------------------------------------------------

def exact_process(
    query: jax.Array,
    data: jax.Array,
    mask: jax.Array,
    *,
    init: jax.Array,
    refine_fn: RefineFn,
) -> jax.Array:
  return refine_fn(init, data, mask)
