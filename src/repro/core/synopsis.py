"""Offline synopsis management (paper §2.2): creation + incremental update.

A synopsis holds one *aggregated data point* per cluster of similar
original points (numeric aggregation = masked mean, exactly the paper's
CF example: "the aggregated user's rating on item i is users' average
rating on i in set U_i").  The index file becomes a static-shape
``member_idx`` table (m clusters x cap members) plus the inverse
``row_cluster`` map — pointer-free, gather/scatter friendly.

Incremental updating covers the paper's two change situations:
  * :func:`update_changed` — existing points changed: re-aggregate only the
    affected clusters (the R-tree "delete + insert leaf" path).
  * :func:`insert` — new points arrive: nearest-centroid assignment into the
    slack capacity, running-mean centroid update (the "add leaf" path).
``needs_rebuild`` signals slack exhaustion -> caller re-creates (the paper
re-creates synopses periodically as well).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import cluster as _cluster


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=[
        "centroids", "centroid_weight", "member_idx", "counts",
        "row_cluster", "pca_centers", "proj", "mean",
    ],
    meta_fields=[],
)
@dataclasses.dataclass
class Synopsis:
  """Aggregated data points + index for one component's data subset."""
  centroids: jax.Array        # (m, v)   masked mean of members
  centroid_weight: jax.Array  # (m, v)   #observed entries per attribute
  member_idx: jax.Array       # (m, cap) int32 row ids, -1 padded
  counts: jax.Array           # (m,)     valid members per cluster
  row_cluster: jax.Array      # (n_cap,) int32 cluster of each row, -1 = free
  pca_centers: jax.Array      # (m, j)   cluster centers in PCA space
  proj: jax.Array             # (v, j)   PCA projection for new points
  mean: jax.Array             # (1, v)   data mean used by the projection

  @property
  def num_clusters(self) -> int:
    return self.centroids.shape[0]

  @property
  def capacity(self) -> int:
    return self.member_idx.shape[1]


def _masked_mean(rows: jax.Array, mask: jax.Array):
  """Mean over axis 0 counting only mask==1 entries; 0 where none."""
  w = jnp.sum(mask, axis=0)
  s = jnp.sum(rows * mask, axis=0)
  return jnp.where(w > 0, s / jnp.maximum(w, 1), 0.0), w


def build(
    data: jax.Array,
    num_clusters: int,
    *,
    mask: Optional[jax.Array] = None,
    method: str = "kd",
    pca_dim: int = 3,
    pca_iters: int = 8,
    slack: float = 0.5,
    key: Optional[jax.Array] = None,
) -> Synopsis:
  """Create a synopsis for ``data`` (n, v).  Steps 1-3 of paper §2.2."""
  n, v = data.shape
  if mask is None:
    mask = jnp.ones_like(data, dtype=data.dtype)
  coords, proj = _cluster.pca_project(data * mask, pca_dim, pca_iters, key=key)
  mean = jnp.mean(data * mask, axis=0, keepdims=True)
  perm = _cluster.cluster(coords, num_clusters, method=method)

  base = n // num_clusters
  cap = int(base + max(1, int(slack * base)))
  m = num_clusters

  # Cluster c owns perm[c*base:(c+1)*base]; leftovers (n % m) go to the last
  # clusters one each so counts differ by at most 1.
  counts = jnp.full((m,), base, dtype=jnp.int32)
  extra = n - base * m
  counts = counts.at[m - extra:].add(1) if extra else counts

  # Build member_idx (m, cap) from the permutation.
  starts = jnp.cumsum(counts) - counts
  offs = jnp.arange(cap)[None, :]
  take = starts[:, None] + offs                      # (m, cap)
  valid = offs < counts[:, None]
  member_idx = jnp.where(valid, perm[jnp.clip(take, 0, n - 1)], -1)
  member_idx = member_idx.astype(jnp.int32)

  row_cluster = _row_cluster_from_members(member_idx, n)

  centroids, weight = _aggregate(data, mask, member_idx)
  pca_centers = _segment_mean_coords(coords, member_idx)
  return Synopsis(
      centroids=centroids, centroid_weight=weight, member_idx=member_idx,
      counts=counts, row_cluster=row_cluster, pca_centers=pca_centers,
      proj=proj, mean=mean)


def _row_cluster_from_members(member_idx: jax.Array, n: int) -> jax.Array:
  m, cap = member_idx.shape
  flat = member_idx.reshape(-1)
  cids = jnp.repeat(jnp.arange(m, dtype=jnp.int32), cap)
  safe = jnp.where(flat >= 0, flat, n)               # park -1 pads off-array
  out = jnp.full((n + 1,), -1, jnp.int32).at[safe].set(cids, mode="drop")
  return out[:n]


def _aggregate(data, mask, member_idx):
  """Step 3: per-cluster masked mean of *original* (un-reduced) points."""
  def one(idx_row):
    ok = (idx_row >= 0)
    rows = data[jnp.maximum(idx_row, 0)]
    msk = mask[jnp.maximum(idx_row, 0)] * ok[:, None].astype(data.dtype)
    return _masked_mean(rows, msk)
  cents, w = jax.vmap(one)(member_idx)
  return cents, w


def _segment_mean_coords(coords, member_idx):
  def one(idx_row):
    ok = (idx_row >= 0).astype(coords.dtype)[:, None]
    rows = coords[jnp.maximum(idx_row, 0)] * ok
    return jnp.sum(rows, axis=0) / jnp.maximum(jnp.sum(ok), 1.0)
  return jax.vmap(one)(member_idx)


# ---------------------------------------------------------------------------
# Incremental updating (paper: two situations).
# ---------------------------------------------------------------------------

@jax.jit
def update_changed(syn: Synopsis, data: jax.Array, mask: jax.Array,
                   changed_rows: jax.Array) -> Synopsis:
  """Situation 2: attributes of existing rows changed (data already holds the
  new values).  Re-aggregates only clusters containing ``changed_rows`` —
  O(k * cap * v), independent of n."""
  affected = syn.row_cluster[changed_rows]            # (k,), may repeat
  idx_rows = syn.member_idx[affected]                 # (k, cap)

  def one(idx_row):
    ok = (idx_row >= 0)
    rows = data[jnp.maximum(idx_row, 0)]
    msk = mask[jnp.maximum(idx_row, 0)] * ok[:, None].astype(data.dtype)
    return _masked_mean(rows, msk)

  cents, w = jax.vmap(one)(idx_rows)                  # (k, v)
  centroids = syn.centroids.at[affected].set(cents)
  weight = syn.centroid_weight.at[affected].set(w)
  return dataclasses.replace(syn, centroids=centroids, centroid_weight=weight)


@jax.jit
def insert(syn: Synopsis, data: jax.Array, mask: jax.Array,
           new_rows: jax.Array) -> Synopsis:
  """Situation 1: new rows appended to ``data``; place each in the nearest
  cluster (PCA space) and update that cluster's aggregate incrementally."""
  coords = (data[new_rows] * mask[new_rows] - syn.mean) @ syn.proj
  assign = _cluster.assign_to_nearest(coords, syn.pca_centers)  # (b,)

  # Per-cluster slot offsets for simultaneous inserts into the same cluster:
  # rank of each new row within its assigned cluster.
  order = jnp.argsort(assign)
  sorted_assign = assign[order]
  ranks_sorted = jnp.arange(assign.shape[0]) - jnp.searchsorted(
      sorted_assign, sorted_assign, side="left")
  ranks = jnp.zeros_like(ranks_sorted).at[order].set(ranks_sorted)
  slots = syn.counts[assign] + ranks                  # target column per row

  in_cap = slots < syn.capacity                       # drop on overflow
  member_idx = syn.member_idx.at[
      jnp.where(in_cap, assign, 0), jnp.where(in_cap, slots, 0)
  ].set(jnp.where(in_cap, new_rows.astype(jnp.int32), syn.member_idx[0, 0]),
        mode="drop")
  member_idx = jnp.where(in_cap.any(), member_idx, syn.member_idx)

  ones = jnp.where(in_cap, 1, 0)
  counts = syn.counts.at[assign].add(ones)
  row_cluster = syn.row_cluster.at[new_rows].set(
      jnp.where(in_cap, assign.astype(jnp.int32), -1))

  # Running-mean centroid update: new_w = w + mask; new_c = (c*w + x)/new_w.
  x = data[new_rows] * mask[new_rows]
  dw = jax.ops.segment_sum(mask[new_rows] * ones[:, None].astype(mask.dtype),
                           assign, num_segments=syn.num_clusters)
  dx = jax.ops.segment_sum(x * ones[:, None].astype(x.dtype),
                           assign, num_segments=syn.num_clusters)
  new_w = syn.centroid_weight + dw
  new_c = jnp.where(new_w > 0,
                    (syn.centroids * syn.centroid_weight + dx)
                    / jnp.maximum(new_w, 1), 0.0)
  return dataclasses.replace(
      syn, centroids=new_c, centroid_weight=new_w, member_idx=member_idx,
      counts=counts, row_cluster=row_cluster)


def needs_rebuild(syn: Synopsis, headroom: int = 1) -> jax.Array:
  """True when any cluster is within ``headroom`` slots of capacity."""
  return jnp.any(syn.counts + headroom > syn.capacity)
