# Distribution layer: logical-axis sharding rules + mesh context.
