"""Logical-axis sharding: rule tables + mesh context + constraints.

Every tensor in the repo carries *logical* axis names (``"batch"``,
``"heads"``, ``"kv_seq"`` ...) instead of mesh axes.  A *rule table* maps
logical names to mesh axes; :func:`mesh_axes_for` resolves one tensor's
logical axes against a table with two safety rails:

  * divisibility — a dim that does not divide evenly over its mesh axes
    falls back to replication (trailing mesh axes are dropped first, so a
    two-axis rule can degrade to one axis before giving up);
  * no double use — a mesh axis consumed by an earlier dim of the same
    tensor is unavailable to later dims (first dim wins).

Rule tables (all derive from :data:`DEFAULT_RULES`):

  * TRAIN_RULES — TP over `model` + FSDP: the weight ``embed`` dim shards
    over `data` (ZeRO-style), gathered per layer inside the scan.
  * SERVE_RULES — decode: weights TP over `model`; the KV cache/synopsis
    ``kv_seq`` axis shards over `model` — each shard is one paper
    "component" of the scatter-gather structure.
  * LONG_RULES  — long_500k: ``kv_seq`` spreads over ``(data, model)``
    (the cache is the dominant allocation), batch keeps only `pod`.

The active (mesh, rules) pair is installed with :func:`use_mesh`;
:func:`constrain` is then a logical-axes ``with_sharding_constraint`` that
no-ops when no mesh is installed (single-device tests) or when the target
axes are currently *manual* (inside a ``shard_map`` body — see
:func:`manual_axes`).
"""
from __future__ import annotations

import contextlib
import math
import threading
from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

AxisRule = Union[None, str, Tuple[str, ...]]

DEFAULT_RULES: Dict[str, AxisRule] = {
    "batch": ("pod", "data"),
    "embed": None,            # weight FSDP dim — replicated unless training
    "heads": "model",
    "kv_heads": "model",
    "ff": "model",
    "vocab": "model",
    "expert": "model",
    "ssm_heads": "model",
    "layers": None,
    "kv_seq": None,
    "ssm_state": None,
}

TRAIN_RULES: Dict[str, AxisRule] = {**DEFAULT_RULES, "embed": "data"}

# Serving: the cache sequence axis takes `model`; the cache head axis must
# stay unsharded or it would claim `model` first (leading dims win).
SERVE_RULES: Dict[str, AxisRule] = {
    **DEFAULT_RULES, "kv_heads": None, "kv_seq": "model",
}

# long_500k: the KV cache dominates memory — spread its sequence axis over
# both data and model; batch parallelism keeps only the pod axis.
LONG_RULES: Dict[str, AxisRule] = {
    **DEFAULT_RULES, "batch": ("pod",), "kv_heads": None,
    "kv_seq": ("data", "model"),
}


class _Ctx(threading.local):

  def __init__(self):
    self.mesh = None
    self.rules: Optional[Dict[str, AxisRule]] = None
    self.manual: frozenset = frozenset()


_CTX = _Ctx()


@contextlib.contextmanager
def use_mesh(mesh, rules: Dict[str, AxisRule]):
  """Install (mesh, rules) as the ambient sharding context."""
  prev = (_CTX.mesh, _CTX.rules)
  _CTX.mesh, _CTX.rules = mesh, dict(rules)
  try:
    yield mesh
  finally:
    _CTX.mesh, _CTX.rules = prev


@contextlib.contextmanager
def manual_axes(axes):
  """Mark mesh axes as manual (inside a ``shard_map`` body): `constrain`
  stops emitting constraints that mention them."""
  prev = _CTX.manual
  _CTX.manual = prev | frozenset(axes)
  try:
    yield
  finally:
    _CTX.manual = prev


def current_mesh():
  return _CTX.mesh


def current_rules() -> Optional[Dict[str, AxisRule]]:
  return _CTX.rules


def rules_dict() -> Dict[str, AxisRule]:
  """The active rule table, or DEFAULT_RULES when none is installed."""
  return dict(_CTX.rules if _CTX.rules is not None else DEFAULT_RULES)


def tp_size(mesh) -> int:
  return int(mesh.shape.get("model", 1)) if mesh is not None else 1


def dp_size(mesh) -> int:
  if mesh is None:
    return 1
  n = 1
  for a in ("pod", "data"):
    n *= int(mesh.shape.get(a, 1))
  return n


def _axis_size(mesh, axes: Tuple[str, ...]) -> int:
  return math.prod(int(mesh.shape[a]) for a in axes)


def mesh_axes_for(logical_axes: Sequence[Optional[str]], mesh,
                  rules: Dict[str, AxisRule],
                  shape: Optional[Sequence[int]] = None) -> P:
  """Resolve logical axes -> PartitionSpec with divisibility + no-reuse
  fallbacks.  ``mesh`` only needs a ``.shape`` mapping (tests use fakes)."""
  used: set = set()
  entries = []
  for d, name in enumerate(logical_axes):
    target = rules.get(name) if name is not None else None
    if target is None:
      entries.append(None)
      continue
    axes = (target,) if isinstance(target, str) else tuple(target)
    axes = tuple(a for a in axes if a in mesh.shape and a not in used)
    # Drop trailing mesh axes until the dim divides evenly.
    while axes and shape is not None and \
        shape[d] % _axis_size(mesh, axes) != 0:
      axes = axes[:-1]
    if not axes:
      entries.append(None)
      continue
    used.update(axes)
    entries.append(axes[0] if len(axes) == 1 else axes)
  return P(*entries)


def named_sharding(logical_axes, mesh, rules,
                   shape: Optional[Sequence[int]] = None) -> NamedSharding:
  return NamedSharding(
      mesh, mesh_axes_for(logical_axes, mesh, rules, shape=shape))


def _is_axes_leaf(x: Any) -> bool:
  return x is None or (
      isinstance(x, tuple)
      and all(e is None or isinstance(e, str) for e in x))


def tree_shardings(axes_tree, mesh, rules, shapes_tree):
  """NamedSharding tree from a logical-axes tree + shape (or array) tree."""
  def one(ax, sds):
    ax = ax if ax is not None else (None,) * len(sds.shape)
    return named_sharding(ax, mesh, rules, shape=tuple(sds.shape))
  return jax.tree.map(one, axes_tree, shapes_tree, is_leaf=_is_axes_leaf)


def _strip_manual(target: AxisRule, manual: frozenset) -> AxisRule:
  if target is None or not manual:
    return target
  axes = (target,) if isinstance(target, str) else tuple(target)
  axes = tuple(a for a in axes if a not in manual)
  if not axes:
    return None
  return axes[0] if len(axes) == 1 else axes


def supports_partial_manual() -> bool:
  """Partial-manual shard_map (manual over a subset of mesh axes, GSPMD on
  the rest) hits an XLA partitioner CHECK on the legacy
  ``jax.experimental.shard_map`` builds; native ``jax.shard_map`` is the
  capability marker for a working implementation."""
  return hasattr(jax, "shard_map")


def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None,
              check_vma: bool = False):
  """``jax.shard_map`` compat shim: new API when available, else the
  ``jax.experimental.shard_map`` spelling (axis_names -> auto complement,
  check_vma -> check_rep)."""
  if hasattr(jax, "shard_map"):
    return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, axis_names=axis_names,
                         check_vma=check_vma)
  from jax.experimental.shard_map import shard_map as _sm  # noqa: PLC0415
  kwargs: Dict[str, Any] = {"check_rep": check_vma}
  if axis_names is not None:
    auto = frozenset(mesh.axis_names) - frozenset(axis_names)
    if auto:
      kwargs["auto"] = auto
  return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
             **kwargs)


def constrain(x, logical_axes, rules: Optional[Dict[str, AxisRule]] = None):
  """``with_sharding_constraint`` by logical axis names.  No-op without an
  installed mesh, and manual (shard_map) axes are stripped first."""
  mesh = _CTX.mesh
  if mesh is None:
    return x
  r = dict(rules) if rules is not None else rules_dict()
  if _CTX.manual:
    r = {k: _strip_manual(v, _CTX.manual) for k, v in r.items()}
  spec = mesh_axes_for(logical_axes, mesh, r, shape=tuple(x.shape))
  if all(e is None for e in spec):
    return x
  return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
