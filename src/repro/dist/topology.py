"""Component topology: partitioning the corpus over parallel components.

The paper's service tier fans one request out to ``n`` parallel components,
each owning a *subset of the input data* (paper §1).  For the serving tier
(`repro.serve.cluster`, DESIGN.md §9) a component owns a contiguous range
of the M synopsis clusters of every resident request's corpus:

  * :func:`ComponentTopology.plan` sizes the ranges — uniform, or skewed by
    a Zipf law so "hot" components own more of the corpus (the regime where
    partial gather and accuracy-aware budget allocation earn their keep);
  * per-component ranges are padded to a common ``m_max`` so the component
    axis is a regular array dim (shard_map-able); padded clusters carry
    ``counts == 0`` and are masked out of stage-1 by the kernels facade
    (``ops.synopsis_stage1(valid=...)``);
  * a replication factor ``replicas`` places each shard on R components —
    ``replica_owner(c, r)`` names the r-th holder of shard ``c`` (ring
    placement: component ``(c + r) % N``) — so the frontend can *hedge*
    a gather predicted to straggle by reissuing the shard's refinement to
    its replica and taking the earlier completion (DESIGN.md §10).

The fleet tier (`repro.serve.fleet`, DESIGN.md §14) promotes the ring to
a 2-D layout: :func:`plan_2d` validates an (R, N) grid where replica row
``r`` holds, in column ``j``, a *materialized* copy of shard
``shard_at(r, j) = (j - r) % N`` — the inverse of ``replica_owner`` —
and :func:`select_replica` is the per-shard replica-selection policy
(fastest-predicted / least-loaded, per Tail-Tolerant Distributed Search,
arxiv 1707.07426): the frontend serves each shard from whichever live
holder is predicted to finish first.

Mesh construction is a FUNCTION (like launch/mesh.py) so importing this
module never touches jax device state: :func:`make_component_mesh` returns
a 1-axis ``("component",)`` mesh when enough devices exist, else ``None``
— the tier then falls back to the stacked single-device execution of the
same math.  :func:`make_fleet_mesh` is the 2-axis
``("replica", "component")`` counterpart over R*N devices.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np


def zipf_weights(n: int, s: float) -> np.ndarray:
  """Normalised Zipf(s) weights over ``n`` ranks (s=0 -> uniform)."""
  ranks = np.arange(1, n + 1, dtype=np.float64)
  w = ranks ** (-float(s))
  return w / w.sum()


@dataclasses.dataclass(frozen=True)
class ComponentTopology:
  """Static partition of ``m_total`` corpus clusters over components.

  ``counts[c]`` clusters live on component ``c`` as the contiguous range
  ``[offsets[c], offsets[c] + counts[c])`` of the cluster-contiguous
  corpus; every component's slice is padded to ``m_max`` slots.
  ``replicas`` R >= 2 additionally places a copy of each shard on the
  next R-1 components of the ring (see :meth:`replica_owner`)."""
  n_components: int
  m_total: int
  counts: Tuple[int, ...]
  skew: float = 0.0
  replicas: int = 1

  def __post_init__(self):
    assert len(self.counts) == self.n_components
    assert sum(self.counts) == self.m_total, (self.counts, self.m_total)
    assert all(c >= 1 for c in self.counts), self.counts
    if not 1 <= self.replicas <= self.n_components:
      raise ValueError(f"replicas {self.replicas} outside "
                       f"[1, n_components={self.n_components}]")

  @property
  def m_max(self) -> int:
    return max(self.counts)

  @property
  def offsets(self) -> Tuple[int, ...]:
    return tuple(int(x) for x in
                 np.concatenate([[0], np.cumsum(self.counts)[:-1]]))

  @property
  def shares(self) -> np.ndarray:
    """Fraction of the corpus each component owns (accuracy weights)."""
    return np.asarray(self.counts, np.float64) / float(self.m_total)

  def cluster_owner(self) -> np.ndarray:
    """(m_total,) component id owning each global cluster index."""
    return np.repeat(np.arange(self.n_components), self.counts)

  def replica_owner(self, c: int, r: int = 1) -> int:
    """Component holding the r-th copy of shard ``c`` (r=0: the primary).
    Ring placement: copies go to the next components, so any R
    consecutive failures still leave R-1 shards each with a live holder
    and hedged reissue never targets the straggler itself."""
    if not 0 <= r < self.replicas:
      raise ValueError(f"replica index {r} outside [0, {self.replicas})")
    return (int(c) + r) % self.n_components

  def replica_owners(self) -> np.ndarray:
    """(n_components, replicas) holders of each shard; column 0 is the
    primary."""
    base = np.arange(self.n_components)[:, None]
    return (base + np.arange(self.replicas)[None, :]) % self.n_components

  def shard_at(self, r: int, j: int) -> int:
    """Shard held at 2-D mesh coordinate (replica row ``r``, component
    column ``j``) — the inverse of :meth:`replica_owner`: row r is row 0
    rolled right by r, so ``shard_at(r, replica_owner(c, r)) == c``."""
    if not 0 <= r < self.replicas:
      raise ValueError(f"replica row {r} outside [0, {self.replicas})")
    return (int(j) - int(r)) % self.n_components

  def shard_grid(self) -> np.ndarray:
    """(replicas, n_components) shard id at each 2-D mesh coordinate."""
    r = np.arange(self.replicas)[:, None]
    j = np.arange(self.n_components)[None, :]
    return (j - r) % self.n_components

  @staticmethod
  def plan(m_total: int, n_components: int, skew: float = 0.0,
           replicas: int = 1) -> "ComponentTopology":
    """Largest-remainder partition of ``m_total`` clusters by Zipf(skew)
    weights; every component owns at least one cluster."""
    n = int(n_components)
    if n < 1 or n > m_total:
      raise ValueError(f"n_components {n} outside [1, m_total={m_total}]")
    r = int(replicas)
    if not 1 <= r <= n:
      # Validated HERE, before any layout is built, with the CLI spelled
      # out: ring placement puts the R copies of a shard on R *distinct*
      # consecutive components, so R > N would silently wrap copies back
      # onto their own primary (--replicas composed with --cluster).
      raise ValueError(
          f"replicas {r} outside [1, n_components={n}]: each shard's R "
          f"ring copies need R distinct components — pass --replicas <= "
          f"--cluster")
    w = zipf_weights(n, skew)
    ideal = w * m_total
    counts = np.maximum(np.floor(ideal).astype(int), 1)
    # Largest-remainder (then lowest rank) for the leftover clusters;
    # steal from the biggest owners if the min-1 floor oversubscribed.
    while counts.sum() < m_total:
      rem = ideal - counts
      counts[int(np.argmax(rem))] += 1
    while counts.sum() > m_total:
      over = np.where(counts > 1, counts - ideal, -np.inf)
      counts[int(np.argmax(over))] -= 1
    return ComponentTopology(n, int(m_total), tuple(int(c) for c in counts),
                             skew=float(skew), replicas=int(replicas))


def plan_2d(m_total: int, n_components: int, replicas: int,
            skew: float = 0.0) -> ComponentTopology:
  """Plan the fleet tier's (R, N) grid: same largest-remainder Zipf
  partition as :meth:`ComponentTopology.plan`, but ``replicas`` is a
  required grid dimension (R >= 1) rather than an accounting factor —
  the caller owns R*N devices and every replica row holds materialized
  shards (see ``repro.serve.fleet``)."""
  r = int(replicas)
  if r < 1:
    raise ValueError(f"fleet replicas must be >= 1, got {r}")
  return ComponentTopology.plan(m_total, n_components, skew=skew, replicas=r)


def select_replica(t_pred, alive=None) -> np.ndarray:
  """Per-shard replica selection (Tail-Tolerant Distributed Search,
  arxiv 1707.07426): pick, for each shard, the live holder predicted to
  finish first.

  ``t_pred`` is the (R, N) predicted completion time of shard ``c``
  served from its r-th holder (column = shard id, NOT mesh column).
  ``alive``, if given, is an (R, N) boolean mask of holders considered
  usable; dead holders are never selected.  Ties break toward the
  lowest r — the primary — so a uniform prediction degenerates to the
  plain 1-D gather.  Returns (N,) int32 replica indices."""
  t = np.asarray(t_pred, np.float64)
  if t.ndim != 2:
    raise ValueError(f"t_pred must be (replicas, n_components), got {t.shape}")
  if alive is not None:
    mask = np.asarray(alive, bool)
    if mask.shape != t.shape:
      raise ValueError(f"alive {mask.shape} != t_pred {t.shape}")
    if not mask.any(axis=0).all():
      dead = np.where(~mask.any(axis=0))[0]
      raise ValueError(f"shards {dead.tolist()} have no live holder")
    t = np.where(mask, t, np.inf)
  # np.argmin takes the first minimum, i.e. the lowest replica index.
  return np.argmin(t, axis=0).astype(np.int32)


def force_host_devices(n: int) -> None:
  """Request ``n`` placeholder host devices via XLA_FLAGS.  Must run
  BEFORE jax initialises its backend (no-op if the flag is already set,
  whatever its count — an explicit user choice wins)."""
  import os  # noqa: PLC0415
  flags = os.environ.get("XLA_FLAGS", "")
  if "xla_force_host_platform_device_count" not in flags:
    os.environ["XLA_FLAGS"] = (
        f"{flags} --xla_force_host_platform_device_count={int(n)}").strip()


def make_component_mesh(n_components: int):
  """1-axis ``("component",)`` mesh over the first ``n`` local devices, or
  ``None`` when the host has fewer devices (the tier then runs the stacked
  fallback).  Deferred jax import keeps module import device-free."""
  import jax  # noqa: PLC0415 — deferred so module import is device-free
  from jax.sharding import Mesh  # noqa: PLC0415
  devs = jax.devices()
  if len(devs) < n_components:
    return None
  return Mesh(np.array(devs[:n_components]), ("component",))


def make_fleet_mesh(n_components: int, replicas: int):
  """2-axis ``("replica", "component")`` mesh over the first R*N local
  devices — replica rows are the *leading* mesh axis so a row is a
  contiguous device group (one host group per replica row on real
  multi-host fleets).  Returns ``None`` when the host has fewer than
  R*N devices; the fleet tier then runs the stacked fallback of the
  same math."""
  import jax  # noqa: PLC0415 — deferred so module import is device-free
  from jax.sharding import Mesh  # noqa: PLC0415
  n, r = int(n_components), int(replicas)
  devs = jax.devices()
  if len(devs) < r * n:
    return None
  grid = np.array(devs[: r * n]).reshape(r, n)
  return Mesh(grid, ("replica", "component"))
