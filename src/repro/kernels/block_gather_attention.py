"""Block-gather (cluster-sparse) flash attention Pallas kernel.

AccuracyTrader stage 2: exact attention over the *original* tokens of the
top-``i_max`` ranked clusters only.  The KV cache is stored
cluster-contiguous (cluster c = rows [c*C, (c+1)*C)), so "gather a
cluster" is an aligned block dynamic-slice — this is the index-file
adaptation that makes refinement TPU-friendly.

The selected cluster ids are **scalar-prefetched** (SMEM) so the BlockSpec
``index_map`` can steer each grid step's HBM->VMEM DMA to the right
cluster block: grid (B, Hkv, I); step (b, h, i) pulls K/V block
``selected[b, h, i]``.  Padded entries (id < 0) are clamped to block 0 and
masked with -inf inside the kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

NEG_INF = -1e30


def _kernel(sel_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
            acc, m_s, l_s, *, sm_scale: float, num_i: int):
  b, h, i = pl.program_id(0), pl.program_id(1), pl.program_id(2)

  @pl.when(i == 0)
  def _init():
    acc[...] = jnp.zeros_like(acc)
    m_s[...] = jnp.full_like(m_s, NEG_INF)
    l_s[...] = jnp.zeros_like(l_s)

  valid = sel_ref[b, h, i] >= 0

  q = q_ref[0].astype(jnp.float32)                  # (G, D)
  k = k_ref[0, 0].astype(jnp.float32)               # (C, D)
  v = v_ref[0, 0].astype(jnp.float32)

  logits = jax.lax.dot_general(
      q, k, (((1,), (1,)), ((), ())),
      preferred_element_type=jnp.float32) * sm_scale
  logits = jnp.where(valid, logits, NEG_INF)        # mask padded clusters

  m_prev = m_s[:, 0]
  m_new = jnp.maximum(m_prev, jnp.max(logits, axis=-1))
  p = jnp.exp(logits - m_new[:, None])
  alpha = jnp.exp(m_prev - m_new)
  l_new = l_s[:, 0] * alpha + jnp.sum(p, axis=-1)
  acc[...] = acc[...] * alpha[:, None] + jax.lax.dot_general(
      p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
  m_s[:, 0] = m_new
  l_s[:, 0] = l_new

  @pl.when(i == num_i - 1)
  def _flush():
    l_fin = l_s[:, 0]
    o_ref[0] = (acc[...] / jnp.maximum(l_fin, 1e-30)[:, None]).astype(
        o_ref.dtype)
    m_ref[0] = m_s[:, 0]
    l_ref[0] = l_fin


@functools.partial(
    jax.jit, static_argnames=("cluster_size", "sm_scale", "interpret"))
def block_gather_attention(
    q: jax.Array,          # (B, H, D)
    k: jax.Array,          # (B, Hkv, S, D) cluster-contiguous
    v: jax.Array,          # (B, Hkv, S, D)
    selected: jax.Array,   # (B, Hkv, I) int32, -1 padded
    *,
    cluster_size: int,
    sm_scale: float = 1.0,
    interpret: bool = False,
):
  """Returns partials (out (B,H,D), m (B,H), l (B,H)) over selected blocks."""
  B, H, D = q.shape
  _, Hkv, S, _ = k.shape
  G = H // Hkv
  C = cluster_size
  assert S % C == 0
  I = selected.shape[-1]

  grid = (B, Hkv, I)

  def _kv_index(b, h, i, sel):
    # Padded ids (-1) are clamped to block 0; the kernel masks them with
    # -inf using the raw (unclamped) scalar value.
    return (b, h, jnp.maximum(sel[b, h, i], 0), 0)

  grid_spec = pltpu.PrefetchScalarGridSpec(
      num_scalar_prefetch=1,
      grid=grid,
      in_specs=[
          pl.BlockSpec((1, G, D), lambda b, h, i, sel: (b, h, 0)),
          pl.BlockSpec((1, 1, C, D), _kv_index),
          pl.BlockSpec((1, 1, C, D), _kv_index),
      ],
      out_specs=[
          pl.BlockSpec((1, G, D), lambda b, h, i, sel: (b, h, 0)),
          pl.BlockSpec((1, G), lambda b, h, i, sel: (b, h)),
          pl.BlockSpec((1, G), lambda b, h, i, sel: (b, h)),
      ],
      scratch_shapes=[
          pltpu.VMEM((G, D), jnp.float32),
          pltpu.VMEM((G, 1), jnp.float32),
          pltpu.VMEM((G, 1), jnp.float32),
      ],
  )
  fn = pl.pallas_call(
      functools.partial(_kernel, sm_scale=sm_scale, num_i=I),
      grid_spec=grid_spec,
      out_shape=[
          jax.ShapeDtypeStruct((B, H, D), q.dtype),
          jax.ShapeDtypeStruct((B, H), jnp.float32),
          jax.ShapeDtypeStruct((B, H), jnp.float32),
      ],
      interpret=interpret,
      name="block_gather_attention",
  )
  out, m, l = fn(selected.astype(jnp.int32), q, k, v)
  return out, m, l
