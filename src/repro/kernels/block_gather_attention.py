"""Block-gather (cluster-sparse) flash attention Pallas kernel.

AccuracyTrader stage 2: exact attention over the *original* tokens of the
top-``i_max`` ranked clusters only.  The KV cache is stored
cluster-contiguous (cluster c = rows [c*C, (c+1)*C)), so "gather a
cluster" is an aligned block dynamic-slice — this is the index-file
adaptation that makes refinement TPU-friendly.

The selected cluster ids are **scalar-prefetched** (SMEM) so the BlockSpec
``index_map`` can steer each grid step's HBM->VMEM DMA to the right
cluster block: grid (B, Hkv, I); step (b, h, i) pulls K/V block
``selected[b, h, i]``.  Padded entries (id < 0) are clamped to block 0 and
masked with -inf inside the kernel.

Fused epilogue (the serving path) — two optional extensions run inside the
same grid/scratch, eliminating the separate ``_merge`` passes the serve
step used to do:

  * **decrement** ``(k_sel, v_sel, sel_bias)``: per selected cluster the
    kernel also loads its centroid row and accumulates it with *negative*
    weight ``-exp(softcap(q.k_syn)*scale + log count - m)``.  Stage 1
    (fused_synopsis) emits partials over ALL centroids (selection isn't
    known yet there); this subtraction removes exactly the selected
    centroids' terms, so ``merge(stage1, stage2)`` equals the masked-bias
    reference.  Per cluster the net mass (tokens - centroid) is >= 0 by
    Jensen when centroid = mean and no softcap (with softcap it may dip
    negative, which the signed merge handles); the flush guards the
    divide for degenerate/cancelled clusters either way.
  * **extras** ``(extras_k, extras_v, extras_bias)``: one trailing grid
    step accumulates the recent-ring-buffer tokens and the new token's
    self-KV (concatenated + padded outside; validity via the (B, E) bias).

Index maps are clamped so the inactive input keeps its previous block
index on each step — Pallas elides the re-fetch, so the epilogue costs
one small DMA, not a second pass.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels.ref import apply_softcap as _cap

NEG_INF = -1e30


def _kernel(sel_ref, q_ref, k_ref, v_ref, *rest, sm_scale: float,
            cap: Optional[float], num_i: int, num_steps: int,
            has_dec: bool, has_ext: bool, has_kq: bool):
  it = iter(rest)
  ksc_ref = vsc_ref = None
  if has_kq:                    # quantized sorted KV (DESIGN.md §15)
    ksc_ref, vsc_ref = next(it), next(it)
  kc_ref = vc_ref = cb_ref = ke_ref = ve_ref = eb_ref = None
  if has_dec:
    kc_ref, vc_ref, cb_ref = next(it), next(it), next(it)
  if has_ext:
    ke_ref, ve_ref, eb_ref = next(it), next(it), next(it)
  o_ref, m_ref, l_ref, acc, m_s, l_s = it

  b, h, j = pl.program_id(0), pl.program_id(1), pl.program_id(2)

  @pl.when(j == 0)
  def _init():
    acc[...] = jnp.zeros_like(acc)
    m_s[...] = jnp.full_like(m_s, NEG_INF)
    l_s[...] = jnp.zeros_like(l_s)

  q = q_ref[0].astype(jnp.float32)                  # (G, D)

  @pl.when(j < num_i)
  def _cluster():
    jc = jnp.minimum(j, num_i - 1)
    valid = sel_ref[b, h, jc] >= 0

    k = k_ref[0, 0].astype(jnp.float32)             # (C, D)
    v = v_ref[0, 0].astype(jnp.float32)
    raw = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    if has_kq:
      # Per-cluster scalar dequant folded into the logits: this step's
      # whole (C, D) block shares one scale, so it multiplies through
      # AFTER the matmul (never a materialized f32 block).
      raw = raw * ksc_ref[0, 0, 0].astype(jnp.float32)
    logits = _cap(raw * sm_scale, cap)
    logits = jnp.where(valid, logits, NEG_INF)      # mask padded clusters

    m_prev = m_s[:, 0]
    m_new = jnp.maximum(m_prev, jnp.max(logits, axis=-1))
    if has_dec:
      kc = kc_ref[0, 0].astype(jnp.float32)         # (1, D) centroid row
      s_c = _cap(jax.lax.dot_general(
          q, kc, (((1,), (1,)), ((), ())),
          preferred_element_type=jnp.float32) * sm_scale, cap)
      s_c = s_c + cb_ref[0, 0, 0].astype(jnp.float32)   # (G, 1)
      s_c = jnp.where(valid, s_c, NEG_INF)
      m_new = jnp.maximum(m_new, jnp.max(s_c, axis=-1))

    p = jnp.exp(logits - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_s[:, 0] * alpha + jnp.sum(p, axis=-1)
    pv = p if not has_kq else p * vsc_ref[0, 0, 0].astype(jnp.float32)
    acc_new = acc[...] * alpha[:, None] + jax.lax.dot_general(
        pv, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    if has_dec:
      vc = vc_ref[0, 0].astype(jnp.float32)         # (1, D)
      p_c = jnp.exp(s_c - m_new[:, None])           # (G, 1)
      l_new = l_new - p_c[:, 0]
      acc_new = acc_new - p_c * vc                  # negative-weight term
    acc[...] = acc_new
    m_s[:, 0] = m_new
    l_s[:, 0] = l_new

  if has_ext:
    @pl.when(j == num_i)
    def _extras():
      ke = ke_ref[0, 0].astype(jnp.float32)         # (E, D)
      ve = ve_ref[0, 0].astype(jnp.float32)
      logits = _cap(jax.lax.dot_general(
          q, ke, (((1,), (1,)), ((), ())),
          preferred_element_type=jnp.float32) * sm_scale, cap)
      logits = logits + eb_ref[0][None, :].astype(jnp.float32)

      m_prev = m_s[:, 0]
      m_new = jnp.maximum(m_prev, jnp.max(logits, axis=-1))
      p = jnp.exp(logits - m_new[:, None])
      alpha = jnp.exp(m_prev - m_new)
      l_s[:, 0] = l_s[:, 0] * alpha + jnp.sum(p, axis=-1)
      acc[...] = acc[...] * alpha[:, None] + jax.lax.dot_general(
          p, ve, (((1,), (0,)), ((), ())),
          preferred_element_type=jnp.float32)
      m_s[:, 0] = m_new

  @pl.when(j == num_steps - 1)
  def _flush():
    l_fin = l_s[:, 0]
    # The decrement can cancel a degenerate (uniform) cluster's mass to
    # ~0; keep o*l == acc finite for the downstream merge.
    safe = jnp.where(jnp.abs(l_fin) > 1e-30, l_fin, 1.0)
    o_ref[0] = (acc[...] / safe[:, None]).astype(o_ref.dtype)
    m_ref[0] = m_s[:, 0]
    l_ref[0] = l_fin


@functools.partial(
    jax.jit,
    static_argnames=("cluster_size", "sm_scale", "cap", "interpret"))
def block_gather_attention(
    q: jax.Array,          # (B, H, D)
    k: jax.Array,          # (B, Hkv, S, D) cluster-contiguous
    v: jax.Array,          # (B, Hkv, S, D)
    selected: jax.Array,   # (B, Hkv, I) int32, -1 padded
    *,
    cluster_size: int,
    sm_scale: float = 1.0,
    cap: Optional[float] = None,
    k_sel: Optional[jax.Array] = None,        # (B, Hkv, I, D) centroid keys
    v_sel: Optional[jax.Array] = None,        # (B, Hkv, I, D)
    sel_bias: Optional[jax.Array] = None,     # (B, Hkv, I) log-count bias
    extras_k: Optional[jax.Array] = None,     # (B, Hkv, E, D)
    extras_v: Optional[jax.Array] = None,     # (B, Hkv, E, D)
    extras_bias: Optional[jax.Array] = None,  # (B, E) additive log-space
    kv_k_scale: Optional[jax.Array] = None,   # (B, Hkv, M) per-cluster
    kv_v_scale: Optional[jax.Array] = None,   # dequant scales (§15)
    interpret: bool = False,
):
  """Returns partials (out (B,H,D) f32, m (B,H), l (B,H)).

  Plain call: exact attention over the selected cluster blocks.  With the
  fused epilogue inputs it additionally subtracts the selected centroids'
  stage-1 terms and folds in the recent/self extras (see module doc).
  With ``kv_k_scale``/``kv_v_scale`` the sorted KV is quantized and each
  grid step's scalar-prefetched index also steers a (1,) scale DMA —
  dequant multiplies into the logits / the p·v weights in-grid
  (DESIGN.md §15).
  """
  B, H, D = q.shape
  _, Hkv, S, _ = k.shape
  G = H // Hkv
  C = cluster_size
  assert S % C == 0
  I = selected.shape[-1]
  has_dec = k_sel is not None
  has_ext = extras_k is not None
  has_kq = kv_k_scale is not None

  num_steps = I + (1 if has_ext else 0)
  grid = (B, Hkv, num_steps)

  def _kv_index(b, h, j, sel):
    # Padded ids (-1) are clamped to block 0; the kernel masks them with
    # -inf using the raw (unclamped) scalar value.  During the extras
    # step the previous block index is reused (no DMA).
    jc = jnp.minimum(j, I - 1)
    return (b, h, jnp.maximum(sel[b, h, jc], 0), 0)

  def _sel_row(b, h, j, sel):
    return (b, h, jnp.minimum(j, I - 1), 0)

  def _scale_index(b, h, j, sel):
    # Same clamp as _kv_index, one scalar per cluster block.
    jc = jnp.minimum(j, I - 1)
    return (b, h, jnp.maximum(sel[b, h, jc], 0))

  in_specs = [
      pl.BlockSpec((1, G, D), lambda b, h, j, sel: (b, h, 0)),
      pl.BlockSpec((1, 1, C, D), _kv_index),
      pl.BlockSpec((1, 1, C, D), _kv_index),
  ]
  args = [q, k, v]
  if has_kq:
    in_specs += [
        pl.BlockSpec((1, 1, 1), _scale_index),
        pl.BlockSpec((1, 1, 1), _scale_index),
    ]
    args += [kv_k_scale.astype(jnp.float32), kv_v_scale.astype(jnp.float32)]
  if has_dec:
    in_specs += [
        pl.BlockSpec((1, 1, 1, D), _sel_row),
        pl.BlockSpec((1, 1, 1, D), _sel_row),
        pl.BlockSpec((1, 1, 1), lambda b, h, j, sel:
                     (b, h, jnp.minimum(j, I - 1))),
    ]
    args += [k_sel, v_sel, sel_bias.astype(jnp.float32)]
  if has_ext:
    E = extras_k.shape[2]
    in_specs += [
        pl.BlockSpec((1, 1, E, D), lambda b, h, j, sel: (b, h, 0, 0)),
        pl.BlockSpec((1, 1, E, D), lambda b, h, j, sel: (b, h, 0, 0)),
        pl.BlockSpec((1, E), lambda b, h, j, sel: (b, 0)),
    ]
    args += [extras_k, extras_v, extras_bias.astype(jnp.float32)]

  grid_spec = pltpu.PrefetchScalarGridSpec(
      num_scalar_prefetch=1,
      grid=grid,
      in_specs=in_specs,
      out_specs=[
          pl.BlockSpec((1, G, D), lambda b, h, j, sel: (b, h, 0)),
          pl.BlockSpec((1, G), lambda b, h, j, sel: (b, h)),
          pl.BlockSpec((1, G), lambda b, h, j, sel: (b, h)),
      ],
      scratch_shapes=[
          pltpu.VMEM((G, D), jnp.float32),
          pltpu.VMEM((G, 1), jnp.float32),
          pltpu.VMEM((G, 1), jnp.float32),
      ],
  )
  fn = pl.pallas_call(
      functools.partial(_kernel, sm_scale=sm_scale, cap=cap, num_i=I,
                        num_steps=num_steps, has_dec=has_dec,
                        has_ext=has_ext, has_kq=has_kq),
      grid_spec=grid_spec,
      out_shape=[
          jax.ShapeDtypeStruct((B, H, D), jnp.float32),
          jax.ShapeDtypeStruct((B, H), jnp.float32),
          jax.ShapeDtypeStruct((B, H), jnp.float32),
      ],
      interpret=interpret,
      name="block_gather_attention",
  )
  out, m, l = fn(selected.astype(jnp.int32), *args)
  return out, m, l
