"""Flash-decode GQA attention Pallas kernel (TPU target).

One new query token per sequence attends over a per-sequence key set of
length S — either the full KV cache (exact baseline) or the synopsis
centroid table (AccuracyTrader stage 1, with ``bias = log(count)`` for
unselected clusters and ``-inf`` for selected ones).

Tiling: grid (B, Hkv, S/block_s).  Per step the kernel holds in VMEM one
query group (G, D), one KV tile (block_s, D) and f32 accumulators; the
online-softmax state persists in scratch across the sequential S-dimension
grid (TPU grids iterate the last axis innermost), flushing normalised
output + (m, l) partials at the final step.  D and block_s should be
multiples of 128 so the q @ k^T and p @ v contractions are MXU-aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels.ref import apply_softcap

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, *rest, sm_scale: float, cap,
            has_bias: bool, num_s_blocks: int):
  if has_bias:
    bias_ref, o_ref, m_ref, l_ref, acc, m_s, l_s = rest
  else:
    o_ref, m_ref, l_ref, acc, m_s, l_s = rest
    bias_ref = None
  s_idx = pl.program_id(2)

  @pl.when(s_idx == 0)
  def _init():
    acc[...] = jnp.zeros_like(acc)
    m_s[...] = jnp.full_like(m_s, NEG_INF)
    l_s[...] = jnp.zeros_like(l_s)

  q = q_ref[0].astype(jnp.float32)                  # (G, D)
  k = k_ref[0, 0].astype(jnp.float32)               # (bs, D)
  v = v_ref[0, 0].astype(jnp.float32)               # (bs, D)

  logits = jax.lax.dot_general(                     # (G, bs) on the MXU
      q, k, (((1,), (1,)), ((), ())),
      preferred_element_type=jnp.float32) * sm_scale
  logits = apply_softcap(logits, cap)
  if bias_ref is not None:
    logits = logits + bias_ref[0, 0][None, :].astype(jnp.float32)

  m_prev = m_s[:, 0]                                # (G,)
  m_new = jnp.maximum(m_prev, jnp.max(logits, axis=-1))
  p = jnp.exp(logits - m_new[:, None])              # (G, bs)
  alpha = jnp.exp(m_prev - m_new)                   # (G,)
  l_new = l_s[:, 0] * alpha + jnp.sum(p, axis=-1)
  acc[...] = acc[...] * alpha[:, None] + jax.lax.dot_general(
      p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
  m_s[:, 0] = m_new
  l_s[:, 0] = l_new

  @pl.when(s_idx == num_s_blocks - 1)
  def _flush():
    l_fin = l_s[:, 0]
    o_ref[0] = (acc[...] / jnp.maximum(l_fin, 1e-30)[:, None]).astype(
        o_ref.dtype)
    m_ref[0] = m_s[:, 0]
    l_ref[0] = l_fin


@functools.partial(
    jax.jit,
    static_argnames=("sm_scale", "cap", "block_s", "interpret"))
def flash_decode(
    q: jax.Array,                 # (B, H, D)
    k: jax.Array,                 # (B, Hkv, S, D)
    v: jax.Array,                 # (B, Hkv, S, D)
    bias: jax.Array | None = None,  # (B, Hkv, S) additive log-space bias
    *,
    sm_scale: float = 1.0,
    cap: float | None = None,     # attention softcap (pre-bias)
    block_s: int = 512,
    interpret: bool = False,
):
  """Returns partials (out (B,H,D), m (B,H), l (B,H))."""
  B, H, D = q.shape
  _, Hkv, S, _ = k.shape
  G = H // Hkv
  assert H == Hkv * G and k.shape == v.shape
  block_s = min(block_s, S)
  assert S % block_s == 0, (S, block_s)
  ns = S // block_s

  grid = (B, Hkv, ns)
  in_specs = [
      pl.BlockSpec((1, G, D), lambda b, h, s: (b, h, 0)),
      pl.BlockSpec((1, 1, block_s, D), lambda b, h, s: (b, h, s, 0)),
      pl.BlockSpec((1, 1, block_s, D), lambda b, h, s: (b, h, s, 0)),
  ]
  args = [q.reshape(B, H, D), k, v]
  if bias is not None:
    in_specs.append(pl.BlockSpec((1, 1, block_s), lambda b, h, s: (b, h, s)))
    args.append(bias)

  # Partials stay f32 regardless of input dtype: they feed merge_partials
  # (self-KV, shard compose) and rounding mid-merge would accumulate.
  out_shape = [
      jax.ShapeDtypeStruct((B, H, D), jnp.float32),
      jax.ShapeDtypeStruct((B, H), jnp.float32),
      jax.ShapeDtypeStruct((B, H), jnp.float32),
  ]
  out_specs = [
      pl.BlockSpec((1, G, D), lambda b, h, s: (b, h, 0)),
      pl.BlockSpec((1, G), lambda b, h, s: (b, h)),
      pl.BlockSpec((1, G), lambda b, h, s: (b, h)),
  ]
  scratch = [
      pltpu.VMEM((G, D), jnp.float32),
      pltpu.VMEM((G, 1), jnp.float32),
      pltpu.VMEM((G, 1), jnp.float32),
  ]
  fn = pl.pallas_call(
      functools.partial(_kernel, sm_scale=sm_scale, cap=cap,
                        has_bias=bias is not None, num_s_blocks=ns),
      grid=grid,
      in_specs=in_specs,
      out_specs=out_specs,
      out_shape=out_shape,
      scratch_shapes=scratch,
      interpret=interpret,
      name="flash_decode",
  )
  out, m, l = fn(*args)
  return out, m, l
