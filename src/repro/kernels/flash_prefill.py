"""Flash-style prefill attention Pallas kernel (TPU target).

The full-prompt half of the serving path: every query position attends
causally over the prompt's keys — the stage that feeds the synopsis build
(paper's offline module) and therefore bounds time-to-first-approximate
-token.  Covers GQA (grouped queries share one KV head), the logit
softcap (gemma2) and sliding windows (local layers).

Tiling: grid (B, Hkv, S/block_q, S/block_k) with the KV axis innermost.
Per step the kernel holds one (block_q, G, D) query tile — flattened to
(block_q*G, D) so the q @ k^T contraction is a single MXU matmul — one
(block_k, D) KV tile and f32 online-softmax state in VMEM scratch that
persists across the sequential KV axis, flushing the normalised output at
the final KV step.  Fully-masked KV blocks (k_start past the causal
frontier, or wholly behind the sliding window) are predicated off with
``pl.when`` — the causal-skip optimisation lives *inside* the grid rather
than as a separate chunked scan (models/layers.causal_attention keeps the
XLA form for training, which needs the remat'd backward).

Ragged shapes: S is padded up to the block size outside the kernel; the
in-kernel position iota masks padded keys with -inf and padded query rows
flush zeros (sliced off by the wrapper), so any (S, block_q, block_k)
combination is legal — the ragged final block costs one partial tile.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels.ref import apply_softcap

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc, m_s, l_s, *, sm_scale: float,
            cap: Optional[float], window: Optional[int], seq_len: int,
            block_q: int, block_k: int, num_k_blocks: int):
  qi, ki = pl.program_id(2), pl.program_id(3)
  q_start = qi * block_q
  k_start = ki * block_k

  @pl.when(ki == 0)
  def _init():
    acc[...] = jnp.zeros_like(acc)
    m_s[...] = jnp.full_like(m_s, NEG_INF)
    l_s[...] = jnp.zeros_like(l_s)

  # Causal skip: the whole KV block is in the masked future.  Window
  # skip: the whole KV block is behind every query row's window.
  run = k_start <= q_start + block_q - 1
  if window is not None:
    run &= k_start + block_k - 1 >= q_start - (window - 1)

  @pl.when(run)
  def _step():
    G = q_ref.shape[3]
    q = q_ref[0, 0].astype(jnp.float32).reshape(block_q * G, -1)
    k = k_ref[0, 0].astype(jnp.float32)               # (bk, D)
    v = v_ref[0, 0].astype(jnp.float32)

    logits = jax.lax.dot_general(                     # (bq*G, bk) on the MXU
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * sm_scale
    logits = apply_softcap(logits, cap)

    qpos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                              (block_q, block_k), 0)
    kpos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                              (block_q, block_k), 1)
    mask = (qpos >= kpos) & (kpos < seq_len)          # causal + key padding
    if window is not None:
      mask &= (qpos - kpos) < window
    logits = logits.reshape(block_q, G, block_k)
    logits = jnp.where(mask[:, None, :], logits, NEG_INF)
    logits = logits.reshape(block_q * G, block_k)

    m_prev = m_s[:, 0]
    m_new = jnp.maximum(m_prev, jnp.max(logits, axis=-1))
    p = jnp.exp(logits - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_s[:, 0] = l_s[:, 0] * alpha + jnp.sum(p, axis=-1)
    acc[...] = acc[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_s[:, 0] = m_new

  @pl.when(ki == num_k_blocks - 1)
  def _flush():
    G = q_ref.shape[3]
    l_fin = l_s[:, 0]
    out = acc[...] / jnp.maximum(l_fin, 1e-30)[:, None]
    o_ref[0, 0] = out.reshape(block_q, G, -1).astype(o_ref.dtype)


def _round_up(x: int, m: int) -> int:
  return -(-x // m) * m


@functools.partial(
    jax.jit,
    static_argnames=("sm_scale", "cap", "window", "block_q", "block_k",
                     "interpret"))
def flash_prefill(
    q: jax.Array,                 # (B, S, H, D)   model layout
    k: jax.Array,                 # (B, S, Hkv, D)
    v: jax.Array,                 # (B, S, Hkv, D)
    *,
    sm_scale: float = 1.0,
    cap: Optional[float] = None,          # attention softcap
    window: Optional[int] = None,         # sliding window (local layers)
    block_q: int = 256,
    block_k: int = 256,
    interpret: bool = False,
) -> jax.Array:
  """Returns the causal attention output (B, S, H, D) in ``q.dtype``."""
  B, S, H, D = q.shape
  Hkv = k.shape[2]
  G = H // Hkv
  assert H == Hkv * G and k.shape == v.shape

  block_q = min(block_q, _round_up(S, 8))
  block_k = min(block_k, _round_up(S, 8))
  Sq = _round_up(S, block_q)
  Sk = _round_up(S, block_k)
  nq, nk = Sq // block_q, Sk // block_k

  # Kernel layout: queries grouped per KV head, sequence padded to the
  # block grid (padded keys masked in-kernel, padded query rows sliced).
  q5 = jnp.moveaxis(q.reshape(B, S, Hkv, G, D), 1, 2)   # (B, Hkv, S, G, D)
  q5 = jnp.pad(q5, [(0, 0), (0, 0), (0, Sq - S), (0, 0), (0, 0)])
  k4 = jnp.pad(jnp.moveaxis(k, 1, 2),
               [(0, 0), (0, 0), (0, Sk - S), (0, 0)])
  v4 = jnp.pad(jnp.moveaxis(v, 1, 2),
               [(0, 0), (0, 0), (0, Sk - S), (0, 0)])

  fn = pl.pallas_call(
      functools.partial(_kernel, sm_scale=sm_scale, cap=cap, window=window,
                        seq_len=S, block_q=block_q, block_k=block_k,
                        num_k_blocks=nk),
      grid=(B, Hkv, nq, nk),
      in_specs=[
          pl.BlockSpec((1, 1, block_q, G, D),
                       lambda b, h, i, j: (b, h, i, 0, 0)),
          pl.BlockSpec((1, 1, block_k, D), lambda b, h, i, j: (b, h, j, 0)),
          pl.BlockSpec((1, 1, block_k, D), lambda b, h, i, j: (b, h, j, 0)),
      ],
      out_specs=pl.BlockSpec((1, 1, block_q, G, D),
                             lambda b, h, i, j: (b, h, i, 0, 0)),
      out_shape=jax.ShapeDtypeStruct((B, Hkv, Sq, G, D), q.dtype),
      scratch_shapes=[
          pltpu.VMEM((block_q * G, D), jnp.float32),
          pltpu.VMEM((block_q * G, 1), jnp.float32),
          pltpu.VMEM((block_q * G, 1), jnp.float32),
      ],
      interpret=interpret,
      name="flash_prefill",
  )
  o5 = fn(q5, k4, v4)                                   # (B, Hkv, Sq, G, D)
  return jnp.moveaxis(o5[:, :, :S], 2, 1).reshape(B, S, H, D)
