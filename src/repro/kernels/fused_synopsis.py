"""Fused synopsis score + stage-1 attention Pallas kernel.

Algorithm 1 lines 1 + 4 in ONE pass over the centroid tables: each grid
step loads one (block_m, D) tile of ``k_syn``/``v_syn``, computes the
(G, block_m) centroid logits once on the MXU, and uses them TWICE —

  * reduced over the GQA group by max -> the correlation scores ``c_i``
    that feed ``lax.top_k`` ranking (uncapped, scale-only: ranking is
    invariant under the monotone softcap);
  * softcapped + count-bias -> online-softmax partials of the stage-1
    synopsis attention over ALL centroids.

The unfused path reads ``k_syn`` twice (score kernel + flash decode) and
``v_syn`` once in a separate kernel launch; this kernel reads each exactly
once and shares the logit matmul.  The selected-cluster mask cannot be
applied here (selection *depends* on the scores this kernel emits), so the
partials are over all centroids with the ``log(count)`` bias; the
refinement kernel subtracts the selected centroids' terms exactly
(decremental masking — see block_gather_attention's fused epilogue and
EXPERIMENTS.md §Fusion).

Tiling: grid (B, Hkv, M/block_m); online-softmax state lives in VMEM
scratch across the sequential last grid axis, flushing (o, m, l) at the
final step.  ``cbias`` is the precomputed ``log(max(counts, 1))`` (B, M).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels.ref import apply_softcap

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, cb_ref, *rest, sm_scale: float,
            cap: Optional[float], num_m_blocks: int, has_scale: bool):
  it = iter(rest)
  ks_ref = vs_ref = None
  if has_scale:                 # quantized synopsis (DESIGN.md §15)
    ks_ref, vs_ref = next(it), next(it)
  s_ref, o_ref, m_ref, l_ref, acc, m_s, l_s = it
  m_idx = pl.program_id(2)

  @pl.when(m_idx == 0)
  def _init():
    acc[...] = jnp.zeros_like(acc)
    m_s[...] = jnp.full_like(m_s, NEG_INF)
    l_s[...] = jnp.zeros_like(l_s)

  q = q_ref[0].astype(jnp.float32)                  # (G, D)
  k = k_ref[0, 0].astype(jnp.float32)               # (bm, D)
  v = v_ref[0, 0].astype(jnp.float32)               # (bm, D)

  logits = jax.lax.dot_general(                     # (G, bm) — computed ONCE
      q, k, (((1,), (1,)), ((), ())),
      preferred_element_type=jnp.float32)
  if has_scale:
    # Dequantize in the accumulator: the per-centroid k-scale (>= 0, so
    # the score ranking is preserved) multiplies the raw logits; k_syn
    # itself is never materialized in f32.
    logits = logits * ks_ref[0, 0][None, :].astype(jnp.float32)
  logits = logits * sm_scale

  # Use 1: correlation scores (uncapped — softcap is monotone, ranking
  # unchanged; matches ref.synopsis_score_ref).
  s_ref[0, 0] = jnp.max(logits, axis=0)             # (bm,)

  # Use 2: stage-1 attention partials over the same tile.
  logits = apply_softcap(logits, cap)
  logits = logits + cb_ref[0][None, :].astype(jnp.float32)

  m_prev = m_s[:, 0]
  m_new = jnp.maximum(m_prev, jnp.max(logits, axis=-1))
  p = jnp.exp(logits - m_new[:, None])
  alpha = jnp.exp(m_prev - m_new)
  l_new = l_s[:, 0] * alpha + jnp.sum(p, axis=-1)
  # v-scale weights p entering the p·v matmul; l stays unscaled (the
  # softmax weights are scale-free — only the value rows are quantized).
  pv = p if not has_scale else p * vs_ref[0, 0][None, :].astype(jnp.float32)
  acc[...] = acc[...] * alpha[:, None] + jax.lax.dot_general(
      pv, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
  m_s[:, 0] = m_new
  l_s[:, 0] = l_new

  @pl.when(m_idx == num_m_blocks - 1)
  def _flush():
    l_fin = l_s[:, 0]
    o_ref[0] = acc[...] / jnp.maximum(l_fin, 1e-30)[:, None]
    m_ref[0] = m_s[:, 0]
    l_ref[0] = l_fin


@functools.partial(
    jax.jit, static_argnames=("sm_scale", "cap", "block_m", "interpret"))
def fused_synopsis_score_attention(
    q: jax.Array,        # (B, H, D)
    k_syn: jax.Array,    # (B, Hkv, M, D) centroid keys
    v_syn: jax.Array,    # (B, Hkv, M, D) centroid values
    cbias: jax.Array,    # (B, M) f32 log(count) bias (additive, log-space)
    *,
    sm_scale: float = 1.0,
    cap: Optional[float] = None,
    block_m: int = 512,
    k_scale: Optional[jax.Array] = None,   # (B, Hkv, M) per-centroid-row
    v_scale: Optional[jax.Array] = None,   # dequant scales (DESIGN.md §15)
    interpret: bool = False,
):
  """Returns (scores (B,Hkv,M) f32, o (B,H,D) f32, m (B,H), l (B,H))."""
  B, H, D = q.shape
  _, Hkv, M, _ = k_syn.shape
  G = H // Hkv
  assert H == Hkv * G and k_syn.shape == v_syn.shape
  has_scale = k_scale is not None
  block_m = min(block_m, M)
  if M % block_m != 0:          # ragged centroid table: one whole-M tile
    block_m = M
  nm = M // block_m

  in_specs = [
      pl.BlockSpec((1, G, D), lambda b, h, m: (b, h, 0)),
      pl.BlockSpec((1, 1, block_m, D), lambda b, h, m: (b, h, m, 0)),
      pl.BlockSpec((1, 1, block_m, D), lambda b, h, m: (b, h, m, 0)),
      pl.BlockSpec((1, block_m), lambda b, h, m: (b, m)),
  ]
  args = [q, k_syn, v_syn, cbias.astype(jnp.float32)]
  if has_scale:
    in_specs += [
        pl.BlockSpec((1, 1, block_m), lambda b, h, m: (b, h, m)),
        pl.BlockSpec((1, 1, block_m), lambda b, h, m: (b, h, m)),
    ]
    args += [k_scale.astype(jnp.float32), v_scale.astype(jnp.float32)]

  fn = pl.pallas_call(
      functools.partial(_kernel, sm_scale=sm_scale, cap=cap,
                        num_m_blocks=nm, has_scale=has_scale),
      grid=(B, Hkv, nm),
      in_specs=in_specs,
      out_specs=[
          pl.BlockSpec((1, 1, block_m), lambda b, h, m: (b, h, m)),
          pl.BlockSpec((1, G, D), lambda b, h, m: (b, h, 0)),
          pl.BlockSpec((1, G), lambda b, h, m: (b, h)),
          pl.BlockSpec((1, G), lambda b, h, m: (b, h)),
      ],
      out_shape=[
          jax.ShapeDtypeStruct((B, Hkv, M), jnp.float32),
          jax.ShapeDtypeStruct((B, H, D), jnp.float32),
          jax.ShapeDtypeStruct((B, H), jnp.float32),
          jax.ShapeDtypeStruct((B, H), jnp.float32),
      ],
      scratch_shapes=[
          pltpu.VMEM((G, D), jnp.float32),
          pltpu.VMEM((G, 1), jnp.float32),
          pltpu.VMEM((G, 1), jnp.float32),
      ],
      interpret=interpret,
      name="fused_synopsis_score_attention",
  )
  scores, o, m, l = fn(*args)
  return scores, (o, m, l)
