"""Public jit'd ops over the Pallas kernels, with an ``impl`` switch:

  * ``impl="pallas"``     — real TPU lowering (pl.pallas_call)
  * ``impl="interpret"``  — Pallas interpreter (CPU validation)
  * ``impl="xla"``        — pure-jnp reference path, mathematically
    identical; used by the multi-pod dry-run and CPU tests (Pallas cannot
    lower to the CPU backend, and inlining the interpreter into a
    512-device SPMD program is not meaningful).

Two generations of the AccuracyTrader decode op live here:

  * :func:`synopsis_attention` — the original *unfused* composition
    (score kernel + biased flash decode + block gather + merges).  Kept
    as the benchmark baseline and the "paper algebra" oracle.
  * the **fused pipeline** — :func:`synopsis_stage1` (one pass over
    ``k_syn``/``v_syn`` emits scores AND count-biased stage-1 partials),
    ``lax.top_k``, :func:`refine_stage2` (selected clusters' tokens +
    decremental centroid masking + recent/self extras in one kernel), one
    final merge.  :func:`synopsis_cache_attention` is the end-to-end op
    the serving path calls; the sharded serve body composes the two
    stages directly around its score all-gather.

The fused pipeline reads the synopsis tables once instead of twice and
replaces the serve step's materialized (B,Hkv,I*C,D) gather copies with
scalar-prefetch-steered block DMAs on the Pallas path (the XLA impl keeps
the gather — XLA cannot express the streaming form).

The prefill half of the system lives here too (DESIGN.md §6):
:func:`prefill_attention` (flash-style causal GQA over the prompt) and
:func:`synopsis_build` (fused permute + segment-mean that turns the
prefilled cache into the synopsis) — both behind the same ``impl``
switch, called from ``serve/prefill.py`` / ``serve/synopsis_kv.py``.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import quant as qt
from repro.kernels import ref
from repro.kernels.block_gather_attention import block_gather_attention
from repro.kernels.flash_decode import flash_decode
from repro.kernels.flash_prefill import flash_prefill
from repro.kernels.fused_synopsis import fused_synopsis_score_attention
from repro.kernels.synopsis_build import segment_build
from repro.kernels.synopsis_score import synopsis_score

NEG_INF = ref.NEG_INF
merge_partials = ref.merge_partials


def resolve_impl(impl: Optional[str] = None) -> str:
  """"auto"/None -> Pallas kernels on TPU, XLA reference elsewhere."""
  if impl in ("pallas", "xla", "interpret"):
    return impl
  return "pallas" if jax.default_backend() == "tpu" else "xla"


def _scores(q, k_syn, sm_scale, impl):
  if impl == "xla":
    return ref.synopsis_score_ref(q, k_syn, sm_scale=sm_scale)
  return synopsis_score(q, k_syn, sm_scale=sm_scale,
                        interpret=(impl == "interpret"))


def _decode(q, k, v, bias, sm_scale, impl, block_s=512, cap=None):
  if impl == "xla":
    return ref.flash_decode_ref(q, k, v, bias, sm_scale=sm_scale, cap=cap)
  S = k.shape[2]
  block_s = min(block_s, S)
  while S % block_s != 0:       # ragged seq (e.g. whisper cross T=1500):
    block_s -= 1                # largest divisor <= block_s, not one
                                # whole-S tile that could blow VMEM
  return flash_decode(q, k, v, bias, sm_scale=sm_scale, cap=cap,
                      block_s=block_s, interpret=(impl == "interpret"))


def _gather(q, k, v, selected, cluster_size, sm_scale, impl, cap=None):
  if impl == "xla":
    return ref.block_gather_attention_ref(
        q, k, v, selected, cluster_size=cluster_size, sm_scale=sm_scale)
  return block_gather_attention(
      q, k, v, selected, cluster_size=cluster_size, sm_scale=sm_scale,
      cap=cap, interpret=(impl == "interpret"))


def count_bias(counts: jax.Array) -> jax.Array:
  """log(count) stand-in weight of an unselected cluster's centroid."""
  return jnp.log(jnp.maximum(counts, 1.0)).astype(jnp.float32)


# ---------------------------------------------------------------------------
# Prefill-side ops (DESIGN.md §6): flash prefill attention + the fused
# synopsis build that turns the prefilled cache into the synopsis.
# ---------------------------------------------------------------------------

@functools.partial(
    jax.jit,
    static_argnames=("sm_scale", "cap", "window", "block_q", "block_k",
                     "impl"))
def prefill_attention(
    q: jax.Array,        # (B, S, H, D)   model layout
    k: jax.Array,        # (B, S, Hkv, D)
    v: jax.Array,        # (B, S, Hkv, D)
    *,
    sm_scale: float = 1.0,
    cap: Optional[float] = None,
    window: Optional[int] = None,
    block_q: int = 256,
    block_k: int = 256,
    impl: str = "pallas",
) -> jax.Array:
  """Causal GQA prefill attention; returns (B, S, H, D) in ``q.dtype``.

  The Pallas path block-tiles query x KV with causal/window block skip
  inside the grid; the XLA path is the chunked reference (no remat — for
  the *forward-only* prefill step; training keeps
  ``models.layers.causal_attention``)."""
  if impl == "xla":
    return ref.flash_prefill_ref(q, k, v, sm_scale=sm_scale, cap=cap,
                                 window=window)
  return flash_prefill(q, k, v, sm_scale=sm_scale, cap=cap, window=window,
                       block_q=block_q, block_k=block_k,
                       interpret=(impl == "interpret"))


@functools.partial(
    jax.jit, static_argnames=("cluster_size", "impl", "qconfig"))
def synopsis_build(
    k: jax.Array,        # (N, Hkv, S, D) exact cache, flat leading dims
    v: jax.Array,        # (N, Hkv, S, D)
    perm: jax.Array,     # (N, S) int32 cluster-contiguous permutation
    *,
    cluster_size: int,
    impl: str = "pallas",
    qconfig: Optional[str] = None,
):
  """Permute the cache cluster-contiguous AND aggregate mean centroids in
  one pass.  Returns (k_sorted, v_sorted, k_syn, v_syn, counts (N, M)),
  or — with a quantizing ``qconfig`` spec (DESIGN.md §15) — the arena
  dict including the quantized tables + per-block scales, emitted in the
  same streaming pass.

  The Pallas path streams each row through VMEM exactly once
  (scalar-prefetch-steered row DMA); the XLA path keeps the
  take_along_axis -> reshape-mean chain (two passes + gather copies)."""
  qc = qt.parse_qconfig(qconfig)
  if impl == "xla":
    if qc.enabled:
      return ref.synopsis_build_quant_ref(
          k, v, perm, cluster_size=cluster_size, qc=qc)
    return ref.synopsis_build_ref(k, v, perm, cluster_size=cluster_size)
  return segment_build(k, v, perm, cluster_size=cluster_size,
                       quant=qc.spec if qc.enabled else None,
                       interpret=(impl == "interpret"))


# ---------------------------------------------------------------------------
# Fused pipeline stages (plain functions: they run inside the serve step's
# layer scan and the sharded body, which are already traced/jitted).
# ---------------------------------------------------------------------------

def synopsis_stage1(q, k_syn, v_syn, counts, *, sm_scale: float,
                    cap: Optional[float] = None, impl: str = "pallas",
                    valid: Optional[jax.Array] = None,
                    syn_scales: Optional[Tuple[jax.Array,
                                               jax.Array]] = None):
  """One pass over the synopsis: (scores (B,Hkv,M), partials over ALL
  centroids with log-count bias).  Selection masking happens
  decrementally in stage 2.

  ``valid`` (B, M) bool optionally masks *padding* centroid slots — the
  cluster tier pads every component's shard to a common ``m_max``
  (DESIGN.md §9).  Invalid slots get a NEG_INF bias (excluded from the
  stage-1 partial inside the kernel) and NEG_INF scores (never ranked by
  the frontend's top-k).

  ``syn_scales`` = (k_syn_scale, v_syn_scale) (B, Hkv, M) when the
  synopsis is quantized (DESIGN.md §15); dequant folds into the kernel."""
  cbias = count_bias(counts)
  if valid is not None:
    cbias = jnp.where(valid, cbias, NEG_INF)
  ks, vs = syn_scales if syn_scales is not None else (None, None)
  if impl == "xla":
    scores, part = ref.fused_synopsis_score_attention_ref(
        q, k_syn, v_syn, cbias, sm_scale=sm_scale, cap=cap,
        k_scale=ks, v_scale=vs)
  else:
    scores, part = fused_synopsis_score_attention(
        q, k_syn, v_syn, cbias, sm_scale=sm_scale, cap=cap,
        k_scale=ks, v_scale=vs, interpret=(impl == "interpret"))
  if valid is not None:
    scores = jnp.where(valid[:, None, :], scores, NEG_INF)
  return scores, part


def refine_stage2(q, k, v, selected, k_syn, v_syn, counts, *,
                  cluster_size: int, sm_scale: float,
                  cap: Optional[float] = None, impl: str = "pallas",
                  extras: Optional[Tuple[jax.Array, jax.Array,
                                         jax.Array]] = None,
                  valid: Optional[jax.Array] = None,
                  syn_scales: Optional[Tuple[jax.Array,
                                             jax.Array]] = None,
                  kv_scales: Optional[Tuple[jax.Array,
                                            jax.Array]] = None):
  """Selected clusters' original tokens (+), their centroid stage-1 terms
  (-), and the recent/self extras (+) — one fused partial.

  ``selected`` may contain -1 padding (skipped).  ``valid`` optionally
  masks entries of ``selected`` that are in-range but not owned (sharded
  path); centroid rows are gathered here (tiny: I rows, not I*C).

  Quantized arenas (DESIGN.md §15): ``syn_scales`` dequantizes the I
  gathered centroid decrement rows here (tiny — outside the kernel);
  ``kv_scales`` = (k_scale, v_scale) (B, Hkv, M) rides into the kernel,
  whose scalar-prefetched cluster index steers the per-block scale."""
  B, H, _ = q.shape
  Hkv = k.shape[1]
  sel = selected
  if valid is not None:
    sel = jnp.where(valid, selected, -1)
  safe = jnp.maximum(sel, 0)
  k_sel = jnp.take_along_axis(k_syn, safe[..., None], axis=2)
  v_sel = jnp.take_along_axis(v_syn, safe[..., None], axis=2)
  if syn_scales is not None:
    ks, vs = syn_scales
    k_sel = k_sel.astype(jnp.float32) * jnp.take_along_axis(
        ks.astype(jnp.float32), safe, axis=2)[..., None]
    v_sel = v_sel.astype(jnp.float32) * jnp.take_along_axis(
        vs.astype(jnp.float32), safe, axis=2)[..., None]
  cb = count_bias(counts)                                     # (B, M)
  sel_bias = jnp.take_along_axis(
      jnp.broadcast_to(cb[:, None, :], (B, Hkv, cb.shape[-1])), safe,
      axis=2)
  ek, ev, eb = extras if extras is not None else (None, None, None)
  kq, vq = kv_scales if kv_scales is not None else (None, None)
  if impl == "xla":
    return ref.fused_gather_attention_ref(
        q, k, v, sel, cluster_size=cluster_size, sm_scale=sm_scale,
        cap=cap, k_sel=k_sel, v_sel=v_sel, sel_bias=sel_bias,
        extras_k=ek, extras_v=ev, extras_bias=eb,
        kv_k_scale=kq, kv_v_scale=vq)
  return block_gather_attention(
      q, k, v, sel, cluster_size=cluster_size, sm_scale=sm_scale, cap=cap,
      k_sel=k_sel, v_sel=v_sel, sel_bias=sel_bias,
      extras_k=ek, extras_v=ev, extras_bias=eb,
      kv_k_scale=kq, kv_v_scale=vq,
      interpret=(impl == "interpret"))


def build_extras(recent_k=None, recent_v=None, recent_len=None,
                 self_kv=None, *, pad_to: int = 16):
  """Concatenate the recent ring buffer and the new token's self-KV into
  one small (B, Hkv, E, D) extras block + (B, E) validity bias, padded so
  the kernel tile is sublane-aligned.  Returns None when there is
  nothing to fold in."""
  ks, vs, biases = [], [], []
  if recent_k is not None:
    B, _, R, _ = recent_k.shape
    ks.append(recent_k)
    vs.append(recent_v)
    if recent_len is None:
      biases.append(jnp.zeros((B, R), jnp.float32))
    else:
      biases.append(jnp.where(
          jnp.arange(R)[None, :] < recent_len[:, None], 0.0, NEG_INF))
  if self_kv is not None:
    k1, v1 = self_kv                                          # (B,Hkv,1,D)
    ks.append(k1)
    vs.append(v1)
    biases.append(jnp.zeros((k1.shape[0], k1.shape[2]), jnp.float32))
  if not ks:
    return None
  ke = jnp.concatenate(ks, axis=2) if len(ks) > 1 else ks[0]
  ve = jnp.concatenate(vs, axis=2) if len(vs) > 1 else vs[0]
  eb = jnp.concatenate(biases, axis=1) if len(biases) > 1 else biases[0]
  E = ke.shape[2]
  Ep = -(-E // pad_to) * pad_to
  if Ep != E:
    pad = [(0, 0), (0, 0), (0, Ep - E), (0, 0)]
    ke = jnp.pad(ke, pad)
    ve = jnp.pad(ve, pad)
    eb = jnp.pad(eb, [(0, 0), (0, Ep - E)], constant_values=NEG_INF)
  return ke, ve, eb.astype(jnp.float32)


@functools.partial(
    jax.jit,
    static_argnames=("i_max", "cluster_size", "sm_scale", "cap", "impl"))
def synopsis_cache_attention(
    q: jax.Array,        # (B, H, D)   one decode step's queries
    k: jax.Array,        # (B, Hkv, S, D) cluster-contiguous original keys
    v: jax.Array,        # (B, Hkv, S, D)
    k_syn: jax.Array,    # (B, Hkv, M, D) centroid keys
    v_syn: jax.Array,    # (B, Hkv, M, D) centroid values
    counts: jax.Array,   # (B, M)
    recent_k: Optional[jax.Array] = None,   # (B, Hkv, R, D)
    recent_v: Optional[jax.Array] = None,
    recent_len: Optional[jax.Array] = None,  # (B,)
    self_k: Optional[jax.Array] = None,      # (B, Hkv, 1, D)
    self_v: Optional[jax.Array] = None,
    k_syn_scale: Optional[jax.Array] = None,  # (B, Hkv, M) — quantized
    v_syn_scale: Optional[jax.Array] = None,  # synopsis (DESIGN.md §15);
    kv_k_scale: Optional[jax.Array] = None,   # (B, Hkv, M) — quantized
    kv_v_scale: Optional[jax.Array] = None,   # sorted KV
    *,
    i_max: int,
    cluster_size: int,
    sm_scale: float = 1.0,
    cap: Optional[float] = None,
    impl: str = "pallas",
):
  """End-to-end fused AccuracyTrader decode attention over a serve-step
  cache slice: O(M + i_max*C + R) with k_syn/v_syn read ONCE.  Returns
  the normalised output (B, H, D) f32.  All-None scales keep the
  bit-identical unquantized path."""
  B, H, _ = q.shape
  Hkv, M = k_syn.shape[1], k_syn.shape[2]
  syn_scales = (None if k_syn_scale is None
                else (k_syn_scale, v_syn_scale))
  kv_scales = None if kv_k_scale is None else (kv_k_scale, kv_v_scale)
  scores, p_syn = synopsis_stage1(q, k_syn, v_syn, counts,
                                  sm_scale=sm_scale, cap=cap, impl=impl,
                                  syn_scales=syn_scales)
  if i_max > 0:
    _, selected = jax.lax.top_k(scores, min(i_max, M))
    selected = selected.astype(jnp.int32)
  else:
    selected = jnp.full((B, Hkv, 1), -1, jnp.int32)
  self_kv = (self_k, self_v) if self_k is not None else None
  extras = build_extras(recent_k, recent_v, recent_len, self_kv)
  p_ref = refine_stage2(
      q, k, v, selected, k_syn, v_syn, counts, cluster_size=cluster_size,
      sm_scale=sm_scale, cap=cap, impl=impl, extras=extras,
      syn_scales=syn_scales, kv_scales=kv_scales)
  out, _, _ = merge_partials(p_syn, p_ref)
  return out


@functools.partial(
    jax.jit,
    static_argnames=("i_max", "sm_scale", "impl", "return_diag"))
def synopsis_attention_fused(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    k_syn: jax.Array,
    v_syn: jax.Array,
    counts: jax.Array,
    k_syn_scale: Optional[jax.Array] = None,   # quantized-arena scales
    v_syn_scale: Optional[jax.Array] = None,   # (DESIGN.md §15)
    kv_k_scale: Optional[jax.Array] = None,
    kv_v_scale: Optional[jax.Array] = None,
    *,
    i_max: int,
    sm_scale: float = 1.0,
    impl: str = "pallas",
    return_diag: bool = False,
):
  """Fused drop-in for :func:`synopsis_attention` (same contract): one
  synopsis pass + decremental refinement instead of score + masked decode
  + gather + merge."""
  M = k_syn.shape[2]
  syn_scales = (None if k_syn_scale is None
                else (k_syn_scale, v_syn_scale))
  kv_scales = None if kv_k_scale is None else (kv_k_scale, kv_v_scale)
  scores, p_syn = synopsis_stage1(q, k_syn, v_syn, counts,
                                  sm_scale=sm_scale, impl=impl,
                                  syn_scales=syn_scales)
  _, selected = jax.lax.top_k(scores, min(i_max, M))
  selected = selected.astype(jnp.int32)
  C = k.shape[2] // M
  p_ref = refine_stage2(q, k, v, selected, k_syn, v_syn, counts,
                        cluster_size=C, sm_scale=sm_scale, impl=impl,
                        syn_scales=syn_scales, kv_scales=kv_scales)
  out, m, l = merge_partials(p_syn, p_ref)
  if return_diag:
    return out, (scores, selected, m, l)
  return out


# ---------------------------------------------------------------------------
# Unfused composition (benchmark baseline + paper-algebra oracle).
# ---------------------------------------------------------------------------

@functools.partial(
    jax.jit,
    static_argnames=("i_max", "sm_scale", "impl", "return_diag"))
def synopsis_attention(
    q: jax.Array,        # (B, H, D)   one decode step's queries
    k: jax.Array,        # (B, Hkv, S, D) cluster-contiguous original keys
    v: jax.Array,        # (B, Hkv, S, D)
    k_syn: jax.Array,    # (B, Hkv, M, D) centroid keys
    v_syn: jax.Array,    # (B, Hkv, M, D) centroid values
    counts: jax.Array,   # (B, M)
    *,
    i_max: int,
    sm_scale: float = 1.0,
    impl: str = "pallas",
    return_diag: bool = False,
):
  """AccuracyTrader attention: O(M + i_max*C) instead of O(S).

  Unselected clusters contribute count-weighted centroid terms (stage 1);
  the top-``i_max`` clusters contribute their original tokens exactly
  (stage 2).  With ``i_max == M`` this equals exact attention.

  Unfused: the synopsis is read twice (scores, then masked decode) and
  the three partials merge separately — the baseline the fused pipeline
  is benchmarked against.
  """
  M = k_syn.shape[2]
  scores = _scores(q, k_syn, sm_scale, impl)            # (B, Hkv, M)
  _, selected = jax.lax.top_k(scores, i_max)
  selected = selected.astype(jnp.int32)

  sel_onehot = jnp.any(jax.nn.one_hot(selected, M, dtype=jnp.bool_), axis=2)
  syn_bias = jnp.where(
      sel_onehot, NEG_INF,
      jnp.log(jnp.maximum(counts, 1)).astype(jnp.float32)[:, None, :])

  part_syn = _decode(q, k_syn, v_syn, syn_bias, sm_scale, impl,
                     block_s=min(512, M))
  C = k.shape[2] // M
  part_ref = _gather(q, k, v, selected, C, sm_scale, impl)
  out, m, l = merge_partials(part_syn, part_ref)
  if return_diag:
    return out, (scores, selected, m, l)
  return out


@functools.partial(jax.jit, static_argnames=("sm_scale", "cap", "impl"))
def exact_decode_attention(q, k, v, bias=None, *, sm_scale: float = 1.0,
                           cap: Optional[float] = None,
                           impl: str = "pallas"):
  """Exact GQA decode (baseline); returns normalised output only."""
  out, _, _ = _decode(q, k, v, bias, sm_scale, impl, cap=cap)
  return out


def decode_partials(q, k, v, bias=None, *, sm_scale: float = 1.0,
                    cap: Optional[float] = None,
                    impl: str = "pallas") -> Tuple[jax.Array, ...]:
  """Exact decode returning (out, m, l) — for cross-shard (SP) merging."""
  return _decode(q, k, v, bias, sm_scale, impl, cap=cap)
