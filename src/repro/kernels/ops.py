"""Public jit'd ops over the Pallas kernels, with an ``impl`` switch:

  * ``impl="pallas"``     — real TPU lowering (pl.pallas_call)
  * ``impl="interpret"``  — Pallas interpreter (CPU validation)
  * ``impl="xla"``        — pure-jnp reference path, mathematically
    identical; used by the multi-pod dry-run and CPU tests (Pallas cannot
    lower to the CPU backend, and inlining the interpreter into a
    512-device SPMD program is not meaningful).

``synopsis_attention`` is the end-to-end AccuracyTrader decode op:
stage-1 centroid scoring + initial result, top-k ranking, stage-2
block-gather refinement, exact online-softmax merge.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.block_gather_attention import block_gather_attention
from repro.kernels.flash_decode import flash_decode
from repro.kernels.synopsis_score import synopsis_score

NEG_INF = ref.NEG_INF
merge_partials = ref.merge_partials


def _scores(q, k_syn, sm_scale, impl):
  if impl == "xla":
    return ref.synopsis_score_ref(q, k_syn, sm_scale=sm_scale)
  return synopsis_score(q, k_syn, sm_scale=sm_scale,
                        interpret=(impl == "interpret"))


def _decode(q, k, v, bias, sm_scale, impl, block_s=512):
  if impl == "xla":
    return ref.flash_decode_ref(q, k, v, bias, sm_scale=sm_scale)
  return flash_decode(q, k, v, bias, sm_scale=sm_scale, block_s=block_s,
                      interpret=(impl == "interpret"))


def _gather(q, k, v, selected, cluster_size, sm_scale, impl):
  if impl == "xla":
    return ref.block_gather_attention_ref(
        q, k, v, selected, cluster_size=cluster_size, sm_scale=sm_scale)
  return block_gather_attention(
      q, k, v, selected, cluster_size=cluster_size, sm_scale=sm_scale,
      interpret=(impl == "interpret"))


@functools.partial(
    jax.jit,
    static_argnames=("i_max", "sm_scale", "impl", "return_diag"))
def synopsis_attention(
    q: jax.Array,        # (B, H, D)   one decode step's queries
    k: jax.Array,        # (B, Hkv, S, D) cluster-contiguous original keys
    v: jax.Array,        # (B, Hkv, S, D)
    k_syn: jax.Array,    # (B, Hkv, M, D) centroid keys
    v_syn: jax.Array,    # (B, Hkv, M, D) centroid values
    counts: jax.Array,   # (B, M)
    *,
    i_max: int,
    sm_scale: float = 1.0,
    impl: str = "pallas",
    return_diag: bool = False,
):
  """AccuracyTrader attention: O(M + i_max*C) instead of O(S).

  Unselected clusters contribute count-weighted centroid terms (stage 1);
  the top-``i_max`` clusters contribute their original tokens exactly
  (stage 2).  With ``i_max == M`` this equals exact attention.
  """
  M = k_syn.shape[2]
  scores = _scores(q, k_syn, sm_scale, impl)            # (B, Hkv, M)
  _, selected = jax.lax.top_k(scores, i_max)
  selected = selected.astype(jnp.int32)

  sel_onehot = jnp.any(jax.nn.one_hot(selected, M, dtype=jnp.bool_), axis=2)
  syn_bias = jnp.where(
      sel_onehot, NEG_INF,
      jnp.log(jnp.maximum(counts, 1)).astype(jnp.float32)[:, None, :])

  part_syn = _decode(q, k_syn, v_syn, syn_bias, sm_scale, impl,
                     block_s=min(512, M))
  C = k.shape[2] // M
  part_ref = _gather(q, k, v, selected, C, sm_scale, impl)
  out, m, l = merge_partials(part_syn, part_ref)
  if return_diag:
    return out, (scores, selected, m, l)
  return out


@functools.partial(jax.jit, static_argnames=("sm_scale", "impl"))
def exact_decode_attention(q, k, v, bias=None, *, sm_scale: float = 1.0,
                           impl: str = "pallas"):
  """Exact GQA decode (baseline); returns normalised output only."""
  out, _, _ = _decode(q, k, v, bias, sm_scale, impl)
  return out


def decode_partials(q, k, v, bias=None, *, sm_scale: float = 1.0,
                    impl: str = "pallas") -> Tuple[jax.Array, ...]:
  """Exact decode returning (out, m, l) — for cross-shard (SP) merging."""
  return _decode(q, k, v, bias, sm_scale, impl)
