"""Quantized synopsis representation (DESIGN.md §15).

The synopsis is *already* a lossy summary of the corpus (per-cluster mean
centroids), so it tolerates further compression: ``k_syn``/``v_syn`` are
stored int8 (or fp8-e4m3 where the jax build has the dtype) with one f32
scale per centroid row, and optionally the sorted corpus KV is stored
int8 with one f32 scale per C-row cluster block.  The roofline module
predicts the fused stage-1 scan is HBM-bandwidth-bound, so the byte
reduction translates near-linearly into stage-1 speedup — see
``analysis/roofline.py`` and EXPERIMENTS.md §Quantization.

Scale convention (symmetric, zero-point-free):

  scale = amax(block) / qmax        (qmax: int8 -> 127, fp8-e4m3 -> 448)
  q     = encode(x / scale)         (deterministic round-to-nearest for
                                     int8 — NOT stochastic: the XLA
                                     reference and the kernel must agree
                                     bit-for-bit on the encoded values)
  x̂     = q.astype(f32) * scale

Dequantization is folded into the attention kernels (never a
materialized f32 copy of the arena on the Pallas path): the k-scale
multiplies the logits right after the q·k matmul (valid because the
per-row scale is >= 0, so ranking by scores is preserved), and the
v-scale multiplies the softmax weights entering the p·v matmul (the
softmax denominator ``l`` stays unscaled).  All helpers here are pure
jnp so the same ``encode_scaled`` traces inside a Pallas kernel and in
the XLA reference path.

Scale leaves ride the arena (``kv_cache.ARENA_LEAVES``) with uniform
(..., M) f32 shape — one slot per centroid/cluster — which keeps every
downstream concat/scatter/replicate rule identical to ``counts``-style
leaves.  Overhead: 4 bytes per D*qbytes block, ~3% at D=128.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp

# spec string -> (kind, quantize sorted KV too?)
QSPECS = {
    "none": ("none", False),
    "int8": ("int8", False),
    "fp8": ("fp8", False),
    "int8+kv": ("int8", True),
    "fp8+kv": ("fp8", True),
}

# Arena scale leaves introduced by quantization (all (..., M) f32):
#   k_syn_scale / v_syn_scale — one scale per centroid row,
#   k_scale / v_scale         — one scale per C-row sorted-KV cluster block.
SCALE_LEAVES = ("k_syn_scale", "v_syn_scale", "k_scale", "v_scale")
SYN_SCALE_LEAVES = ("k_syn_scale", "v_syn_scale")
KV_SCALE_LEAVES = ("k_scale", "v_scale")


@dataclasses.dataclass(frozen=True)
class QuantConfig:
  """Parsed qconfig: numeric kind x which arenas it covers."""
  kind: str = "none"           # "none" | "int8" | "fp8"
  sorted_kv: bool = False      # also quantize the sorted corpus KV

  @property
  def enabled(self) -> bool:
    return self.kind != "none"

  @property
  def spec(self) -> str:
    if not self.enabled:
      return "none"
    return self.kind + ("+kv" if self.sorted_kv else "")


def parse_qconfig(spec: Union[None, str, QuantConfig]) -> QuantConfig:
  """"none"/"int8"/"fp8"/"int8+kv"/"fp8+kv" -> QuantConfig."""
  if spec is None:
    return QuantConfig()
  if isinstance(spec, QuantConfig):
    return spec
  if spec not in QSPECS:
    raise ValueError(f"unknown quant spec {spec!r}; one of {list(QSPECS)}")
  kind, skv = QSPECS[spec]
  return QuantConfig(kind=kind, sorted_kv=skv)


def fp8_supported() -> bool:
  return hasattr(jnp, "float8_e4m3fn")


def qdtype(kind: str):
  if kind == "int8":
    return jnp.int8
  if kind == "fp8":
    if not fp8_supported():
      raise ValueError("fp8 requested but jnp.float8_e4m3fn is unavailable")
    return jnp.float8_e4m3fn
  raise ValueError(f"no quantized dtype for kind {kind!r}")


def qmax(kind: str) -> float:
  if kind == "int8":
    return 127.0
  if kind == "fp8":
    return 448.0               # float8_e4m3fn finite max
  raise ValueError(f"no qmax for kind {kind!r}")


def encode_scaled(y: jax.Array, kind: str) -> jax.Array:
  """Encode already-scaled values y = x/scale into the storage dtype.

  Pure jnp — traces inside Pallas kernels.  int8 uses deterministic
  round-to-nearest-even (matches the XLA reference exactly on identical
  inputs); fp8 is a dtype cast (hardware rounding).
  """
  if kind == "int8":
    return jnp.clip(jnp.round(y), -127.0, 127.0).astype(jnp.int8)
  if kind == "fp8":
    return jnp.clip(y, -qmax(kind), qmax(kind)).astype(qdtype(kind))
  raise ValueError(f"cannot encode kind {kind!r}")


def block_scale(x: jax.Array, kind: str, axis=-1,
                keepdims: bool = True) -> jax.Array:
  """Symmetric scale over ``axis``: amax/qmax, 0 for an all-zero block."""
  amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=axis,
                 keepdims=keepdims)
  return amax / qmax(kind)


def quantize_rows(x: jax.Array, kind: str,
                  block: int = 1) -> Tuple[jax.Array, jax.Array]:
  """Quantize (..., R, D) with one scale per ``block`` rows.

  Returns (q (..., R, D) in the storage dtype, scales (..., R//block) f32).
  ``block=1`` is the per-centroid-row granularity; ``block=C`` the
  per-cluster sorted-KV granularity.
  """
  *lead, R, D = x.shape
  assert R % block == 0, (R, block)
  xb = x.astype(jnp.float32).reshape(*lead, R // block, block * D)
  scale = block_scale(xb, kind)                      # (..., R//block, 1)
  inv = jnp.where(scale > 0, 1.0 / jnp.maximum(scale, 1e-30), 0.0)
  q = encode_scaled(xb * inv, kind).reshape(*lead, R, D)
  return q, scale[..., 0]


def dequantize_rows(q: jax.Array, scales: jax.Array,
                    block: int = 1) -> jax.Array:
  """Inverse of :func:`quantize_rows` — f32 (..., R, D)."""
  *lead, R, D = q.shape
  s = jnp.repeat(scales.astype(jnp.float32), block, axis=-1)  # (..., R)
  return q.astype(jnp.float32) * s[..., None]
