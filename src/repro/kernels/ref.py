"""Pure-jnp oracles for every kernel in this package.

All attention kernels return *partials* ``(out, m, l)``:
  out (B, H, D)  — softmax-normalised partial output
  m   (B, H)     — running max logit
  l   (B, H)     — sum of exp(logit - m)
so that results from disjoint key sets (centroids vs. refined clusters vs.
recent tokens vs. sequence shards) merge exactly via
:func:`merge_partials` — this online-softmax algebra is what lets
AccuracyTrader's stage-1 (synopsis) and stage-2 (refinement) results
combine without double counting, and lets the KV cache shard over the
`model` mesh axis (each shard = one paper "component").

KV layout is batched: (B, Hkv, S, D) — every sequence has its own cache.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

Partials = Tuple[jax.Array, jax.Array, jax.Array]

NEG_INF = -1e30


def apply_softcap(logits: jax.Array, cap: Optional[float]) -> jax.Array:
  """Attention logit softcap — the one shared definition; the Pallas
  kernels import it too (pure jnp, traces fine inside a kernel)."""
  if cap is None:
    return logits
  return cap * jnp.tanh(logits / cap)


_softcap = apply_softcap


def flash_decode_ref(
    q: jax.Array,            # (B, H, D)
    k: jax.Array,            # (B, Hkv, S, D)
    v: jax.Array,            # (B, Hkv, S, D)
    bias: Optional[jax.Array] = None,   # (B, Hkv, S) additive (log-space)
    *,
    sm_scale: float = 1.0,
    cap: Optional[float] = None,
) -> Partials:
  """Exact GQA decode attention over the whole key set."""
  B, H, D = q.shape
  _, Hkv, S, _ = k.shape
  G = H // Hkv
  qg = q.reshape(B, Hkv, G, D).astype(jnp.float32)
  logits = _softcap(
      jnp.einsum("bhgd,bhsd->bhgs", qg, k.astype(jnp.float32)) * sm_scale,
      cap)
  if bias is not None:
    logits = logits + bias[:, :, None, :].astype(jnp.float32)
  m = jnp.max(logits, axis=-1)                               # (B,Hkv,G)
  m_safe = jnp.maximum(m, NEG_INF)
  p = jnp.exp(logits - m_safe[..., None])
  l = jnp.sum(p, axis=-1)
  out = jnp.einsum("bhgs,bhsd->bhgd", p, v.astype(jnp.float32))
  out = out / jnp.maximum(l, 1e-30)[..., None]
  return (out.reshape(B, H, D), m_safe.reshape(B, H), l.reshape(B, H))


def flash_prefill_ref(
    q: jax.Array,            # (B, S, H, D)   full-prompt queries
    k: jax.Array,            # (B, S, Hkv, D)
    v: jax.Array,            # (B, S, Hkv, D)
    *,
    sm_scale: float = 1.0,
    cap: Optional[float] = None,
    window: Optional[int] = None,
    q_chunk: int = 512,
) -> jax.Array:
  """Causal GQA prefill attention oracle (model layout, DESIGN.md §6).

  Chunked over query blocks so the (S, S) logit matrix never materialises
  (mirrors the flash kernel's tiling); f32 math throughout, output cast
  back to ``q.dtype``.  This is the ``impl="xla"`` path of
  ``ops.prefill_attention``.
  """
  B, S, H, D = q.shape
  Hkv = k.shape[2]
  G = H // Hkv
  qg = q.reshape(B, S, Hkv, G, D)
  chunk = min(q_chunk, S)
  while S % chunk != 0:            # largest divisor of S at most q_chunk
    chunk -= 1
  nq = S // chunk
  kf = k.astype(jnp.float32)
  vf = v.astype(jnp.float32)
  kpos = jnp.arange(S)

  def one_chunk(i):
    qi = jax.lax.dynamic_slice_in_dim(qg, i * chunk, chunk, axis=1)
    qpos = i * chunk + jnp.arange(chunk)
    logits = _softcap(
        jnp.einsum("bqhgd,bkhd->bhgqk", qi.astype(jnp.float32), kf)
        * sm_scale, cap)
    mask = qpos[:, None] >= kpos[None, :]
    if window is not None:
      mask &= (qpos[:, None] - kpos[None, :]) < window
    logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    oi = jnp.einsum("bhgqk,bkhd->bqhgd", p, vf)
    return oi.reshape(B, chunk, H, D).astype(q.dtype)

  if nq == 1:
    return one_chunk(0)
  chunks = jax.lax.map(one_chunk, jnp.arange(nq))    # (nq, B, chunk, H, D)
  return jnp.moveaxis(chunks, 0, 1).reshape(B, S, H, D)


def synopsis_build_ref(
    k: jax.Array,            # (N, Hkv, S, D) exact cache (flat batch)
    v: jax.Array,            # (N, Hkv, S, D)
    perm: jax.Array,         # (N, S) int32 cluster-contiguous permutation
    *,
    cluster_size: int,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array, jax.Array]:
  """Synopsis-build oracle: the unfused permute -> segment-mean chain
  (paper §2.2 step 3, DESIGN.md §6).  Gathers the cache into
  cluster-contiguous order and aggregates per-cluster mean centroids.
  Returns (k_sorted, v_sorted, k_syn, v_syn, counts (N, M) f32)."""
  N, Hkv, S, D = k.shape
  C = cluster_size
  M = S // C
  idx = jnp.broadcast_to(perm[:, None, :, None], (N, Hkv, S, 1))
  k_sorted = jnp.take_along_axis(k, idx, axis=2)
  v_sorted = jnp.take_along_axis(v, idx, axis=2)
  k_syn = k_sorted.reshape(N, Hkv, M, C, D).mean(3).astype(k.dtype)
  v_syn = v_sorted.reshape(N, Hkv, M, C, D).mean(3).astype(v.dtype)
  counts = jnp.full((N, M), float(C), jnp.float32)
  return k_sorted, v_sorted, k_syn, v_syn, counts


def synopsis_build_quant_ref(
    k: jax.Array,            # (N, Hkv, S, D) exact cache (flat batch)
    v: jax.Array,            # (N, Hkv, S, D)
    perm: jax.Array,         # (N, S) int32 cluster-contiguous permutation
    *,
    cluster_size: int,
    qc,                      # quant.QuantConfig with qc.enabled
) -> dict:
  """Quantized-build oracle (DESIGN.md §15): same permute + segment-mean
  chain, but the centroids are quantized from their *f32* means (the
  kernel accumulates in f32 and quantizes at the flush — never through a
  bf16 round-trip) with one scale per centroid row; with ``qc.sorted_kv``
  the sorted cache is quantized per C-row cluster block too.  Returns
  the arena dict {k, v, k_syn, v_syn, counts, k_syn_scale, v_syn_scale
  [, k_scale, v_scale]}."""
  from repro.kernels import quant
  N, Hkv, S, D = k.shape
  C = cluster_size
  M = S // C
  idx = jnp.broadcast_to(perm[:, None, :, None], (N, Hkv, S, 1))
  k_sorted = jnp.take_along_axis(k, idx, axis=2)
  v_sorted = jnp.take_along_axis(v, idx, axis=2)
  k_mean = k_sorted.astype(jnp.float32).reshape(N, Hkv, M, C, D).mean(3)
  v_mean = v_sorted.astype(jnp.float32).reshape(N, Hkv, M, C, D).mean(3)
  k_syn, ks = quant.quantize_rows(k_mean, qc.kind)
  v_syn, vs = quant.quantize_rows(v_mean, qc.kind)
  out = {"k_syn": k_syn, "v_syn": v_syn,
         "k_syn_scale": ks, "v_syn_scale": vs,
         "counts": jnp.full((N, M), float(C), jnp.float32)}
  if qc.sorted_kv:
    out["k"], out["k_scale"] = quant.quantize_rows(
        k_sorted, qc.kind, block=C)
    out["v"], out["v_scale"] = quant.quantize_rows(
        v_sorted, qc.kind, block=C)
  else:
    out["k"], out["v"] = k_sorted, v_sorted
  return out


def synopsis_score_ref(
    q: jax.Array,            # (B, H, D)
    k_syn: jax.Array,        # (B, Hkv, M, D) centroid keys
    *,
    sm_scale: float = 1.0,
) -> jax.Array:
  """Correlation c_i of every aggregated point to the query (paper line 1):
  max over the GQA group's query heads of the centroid logit.  (B, Hkv, M).
  """
  B, H, D = q.shape
  _, Hkv, M, _ = k_syn.shape
  G = H // Hkv
  qg = q.reshape(B, Hkv, G, D).astype(jnp.float32)
  logits = jnp.einsum("bhgd,bhmd->bhgm", qg, k_syn.astype(jnp.float32))
  return jnp.max(logits, axis=2) * sm_scale                  # (B, Hkv, M)


def block_gather_attention_ref(
    q: jax.Array,            # (B, H, D)
    k: jax.Array,            # (B, Hkv, S, D) cluster-contiguous originals
    v: jax.Array,            # (B, Hkv, S, D)
    selected: jax.Array,     # (B, Hkv, I) int32 cluster ids (pad: -1)
    *,
    cluster_size: int,
    sm_scale: float = 1.0,
) -> Partials:
  """Stage-2 refinement: exact attention over the selected clusters only."""
  B, H, D = q.shape
  _, Hkv, S, _ = k.shape
  C = cluster_size

  def one_bh(qb, kh, vh, sel_row):
    # qb (G, D); kh/vh (S, D); sel_row (I,)
    starts = jnp.maximum(sel_row, 0) * C
    idx = (starts[:, None] + jnp.arange(C)[None, :]).reshape(-1)   # (I*C,)
    kk = kh[idx]
    vv = vh[idx]
    valid = jnp.repeat(sel_row >= 0, C)
    bias = jnp.where(valid, 0.0, NEG_INF)
    logits = (qb.astype(jnp.float32) @ kk.astype(jnp.float32).T) * sm_scale
    logits = logits + bias[None, :]
    m = jnp.maximum(jnp.max(logits, axis=-1), NEG_INF)
    p = jnp.exp(logits - m[:, None])
    l = jnp.sum(p, axis=-1)
    out = (p @ vv.astype(jnp.float32)) / jnp.maximum(l, 1e-30)[:, None]
    return out, m, l

  G = H // Hkv
  qg = q.reshape(B, Hkv, G, D)
  out, m, l = jax.vmap(jax.vmap(one_bh))(qg, k, v, selected)
  return (out.reshape(B, H, D), m.reshape(B, H), l.reshape(B, H))


def fused_synopsis_score_attention_ref(
    q: jax.Array,            # (B, H, D)
    k_syn: jax.Array,        # (B, Hkv, M, D)
    v_syn: jax.Array,        # (B, Hkv, M, D)
    cbias: jax.Array,        # (B, M) f32 log(count) bias
    *,
    sm_scale: float = 1.0,
    cap: Optional[float] = None,
    k_scale: Optional[jax.Array] = None,   # (B, Hkv, M) per-centroid scales
    v_scale: Optional[jax.Array] = None,   # (DESIGN.md §15)
) -> Tuple[jax.Array, Partials]:
  """Single-read oracle for the fused score+stage-1 kernel: the centroid
  logits are computed ONCE and reused for both the correlation scores
  (max over the GQA group, uncapped) and the count-biased stage-1
  partials over ALL centroids (the selected-cluster mask is applied
  decrementally downstream — see fused_gather_attention_ref).

  When ``k_scale``/``v_scale`` are given, ``k_syn``/``v_syn`` hold
  quantized values and dequantization folds into the math exactly where
  the kernel does it: the k-scale multiplies the logits after the q·k
  contraction (scale >= 0 keeps the score ranking), the v-scale weights
  ``p`` entering the p·v contraction (``l`` stays unscaled)."""
  B, H, D = q.shape
  _, Hkv, M, _ = k_syn.shape
  G = H // Hkv
  qg = q.reshape(B, Hkv, G, D).astype(jnp.float32)
  raw = jnp.einsum("bhgd,bhmd->bhgm", qg, k_syn.astype(jnp.float32))
  if k_scale is not None:
    raw = raw * k_scale[:, :, None, :].astype(jnp.float32)
  raw = raw * sm_scale
  scores = jnp.max(raw, axis=2)                              # (B, Hkv, M)
  logits = _softcap(raw, cap) + cbias[:, None, None, :].astype(jnp.float32)
  m = jnp.maximum(jnp.max(logits, axis=-1), NEG_INF)
  p = jnp.exp(logits - m[..., None])
  l = jnp.sum(p, axis=-1)
  pv = p if v_scale is None else p * v_scale[:, :, None, :].astype(
      jnp.float32)
  out = jnp.einsum("bhgs,bhsd->bhgd", pv, v_syn.astype(jnp.float32))
  out = out / jnp.maximum(l, 1e-30)[..., None]
  return scores, (out.reshape(B, H, D), m.reshape(B, H), l.reshape(B, H))


def fused_gather_attention_ref(
    q: jax.Array,            # (B, H, D)
    k: jax.Array,            # (B, Hkv, S, D) cluster-contiguous originals
    v: jax.Array,
    selected: jax.Array,     # (B, Hkv, I) int32 cluster ids (pad: -1)
    *,
    cluster_size: int,
    sm_scale: float = 1.0,
    cap: Optional[float] = None,
    k_sel: Optional[jax.Array] = None,        # (B, Hkv, I, D) centroids
    v_sel: Optional[jax.Array] = None,
    sel_bias: Optional[jax.Array] = None,     # (B, Hkv, I) log-count bias
    extras_k: Optional[jax.Array] = None,     # (B, Hkv, E, D)
    extras_v: Optional[jax.Array] = None,
    extras_bias: Optional[jax.Array] = None,  # (B, E)
    kv_k_scale: Optional[jax.Array] = None,   # (B, Hkv, M) per-cluster
    kv_v_scale: Optional[jax.Array] = None,   # scales (DESIGN.md §15)
) -> Partials:
  """Oracle for the fused stage-2 epilogue: selected clusters' tokens
  (positive), their centroid stage-1 terms (negative — decremental
  masking), and recent/self extras (positive), in one signed softmax
  accumulation.  The XLA impl of the serving path IS this function (it
  keeps the materialized gather; only the Pallas path streams blocks).

  ``kv_k_scale``/``kv_v_scale``: per-cluster-block scales when ``k``/``v``
  hold the int8 sorted arena — dequant folds into the logits / the p·v
  weights exactly like the kernel (one scalar per cluster block)."""
  B, H, D = q.shape
  _, Hkv, S, _ = k.shape
  C = cluster_size
  G = H // Hkv
  qg = q.reshape(B, Hkv, G, D).astype(jnp.float32)

  starts = jnp.maximum(selected, 0) * C                       # (B,Hkv,I)
  idx = starts[..., None] + jnp.arange(C)[None, None, None]   # (B,Hkv,I,C)
  idx = idx.reshape(B, Hkv, -1)
  kg = jnp.take_along_axis(k, idx[..., None], axis=2)
  vg = jnp.take_along_axis(v, idx[..., None], axis=2)
  valid = jnp.repeat(selected >= 0, C, axis=-1)               # (B,Hkv,I*C)
  raw = jnp.einsum("bhgd,bhsd->bhgs", qg, kg.astype(jnp.float32))
  if kv_k_scale is not None:
    ksc = jnp.take_along_axis(kv_k_scale.astype(jnp.float32),
                              jnp.maximum(selected, 0), axis=2)  # (B,Hkv,I)
    raw = raw * jnp.repeat(ksc, C, axis=-1)[:, :, None, :]
  if kv_v_scale is not None:
    vsc = jnp.take_along_axis(kv_v_scale.astype(jnp.float32),
                              jnp.maximum(selected, 0), axis=2)
    vg = vg.astype(jnp.float32) * jnp.repeat(vsc, C, axis=-1)[..., None]
  lt = _softcap(raw * sm_scale, cap)
  lt = jnp.where(valid[:, :, None, :], lt, NEG_INF)

  pieces = [(lt, vg, 1.0)]
  if k_sel is not None:
    lc = _softcap(jnp.einsum("bhgd,bhid->bhgi", qg,
                             k_sel.astype(jnp.float32)) * sm_scale, cap)
    lc = lc + sel_bias[:, :, None, :].astype(jnp.float32)
    lc = jnp.where((selected >= 0)[:, :, None, :], lc, NEG_INF)
    pieces.append((lc, v_sel, -1.0))
  if extras_k is not None:
    le = _softcap(jnp.einsum("bhgd,bhed->bhge", qg,
                             extras_k.astype(jnp.float32)) * sm_scale, cap)
    le = le + extras_bias[:, None, None, :].astype(jnp.float32)
    pieces.append((le, extras_v, 1.0))

  m = jnp.maximum(
      _max_over([p[0].max(axis=-1) for p in pieces]), NEG_INF)
  l = jnp.zeros_like(m)
  acc = jnp.zeros((B, Hkv, G, D), jnp.float32)
  for logits, values, sign in pieces:
    p = jnp.exp(logits - m[..., None])
    l = l + sign * jnp.sum(p, axis=-1)
    acc = acc + sign * jnp.einsum("bhgs,bhsd->bhgd", p,
                                  values.astype(jnp.float32))
  safe = jnp.where(jnp.abs(l) > 1e-30, l, 1.0)
  out = acc / safe[..., None]
  return (out.reshape(B, H, D), m.reshape(B, H), l.reshape(B, H))


def _max_over(xs):
  m = xs[0]
  for x in xs[1:]:
    m = jnp.maximum(m, x)
  return m


def merge_partials(a: Partials, b: Partials) -> Partials:
  """Exact online-softmax merge of two disjoint-key partials."""
  oa, ma, la = a
  ob, mb, lb = b
  m = jnp.maximum(ma, mb)
  wa = la * jnp.exp(ma - m)
  wb = lb * jnp.exp(mb - m)
  l = wa + wb
  o = (oa * wa[..., None] + ob * wb[..., None]) / jnp.maximum(l, 1e-30)[..., None]
  return (o.astype(oa.dtype), m, l)


def synopsis_attention_ref(
    q: jax.Array,            # (B, H, D)
    k: jax.Array,            # (B, Hkv, S, D) cluster-contiguous originals
    v: jax.Array,
    k_syn: jax.Array,        # (B, Hkv, M, D) centroid keys  (M = S / C)
    v_syn: jax.Array,        # (B, Hkv, M, D) centroid values
    counts: jax.Array,       # (B, M) members per cluster
    *,
    i_max: int,
    sm_scale: float = 1.0,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
  """End-to-end AccuracyTrader decode attention oracle.

  stage 1: score centroids; each *unselected* centroid stands in for its
  cluster with weight count*exp(logit) (log-space bias log(count));
  stage 2: the top-``i_max`` clusters contribute their original tokens
  exactly.  Returns (out (B,H,D), scores (B,Hkv,M), selected (B,Hkv,I)).
  """
  scores = synopsis_score_ref(q, k_syn, sm_scale=sm_scale)
  _, selected = jax.lax.top_k(scores, i_max)
  selected = selected.astype(jnp.int32)

  M = k_syn.shape[2]
  sel_onehot = jnp.any(
      jax.nn.one_hot(selected, M, dtype=jnp.bool_), axis=2)   # (B,Hkv,M)
  syn_bias = jnp.where(sel_onehot, NEG_INF,
                       jnp.log(jnp.maximum(counts, 1))[:, None, :])
  part_syn = flash_decode_ref(q, k_syn, v_syn, syn_bias, sm_scale=sm_scale)
  C = k.shape[2] // M
  part_ref = block_gather_attention_ref(
      q, k, v, selected, cluster_size=C, sm_scale=sm_scale)
  out, _, _ = merge_partials(part_syn, part_ref)
  return out, scores, selected


def exact_attention_ref(q, k, v, *, sm_scale: float = 1.0) -> jax.Array:
  out, _, _ = flash_decode_ref(q, k, v, sm_scale=sm_scale)
  return out
