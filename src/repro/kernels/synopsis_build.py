"""Fused synopsis-build (permute + segment-mean) Pallas kernel.

Paper §2.2 step 3 specialised to KV caches (DESIGN.md §6): given the
cluster-contiguous permutation produced by the clustering stage
(``repro.core.cluster``), reorder the exact cache and aggregate each
C-token cluster into its mean-centroid row — in ONE streaming pass.

The unfused XLA chain (``ref.synopsis_build_ref``) materialises the
sorted cache with ``take_along_axis`` (HBM write), then re-reads it for
the reshape-mean (HBM read) — two full passes over the cache plus the
gather's scatter traffic.  Here the permutation is **scalar-prefetched**
(SMEM) so the BlockSpec ``index_map`` steers each grid step's HBM->VMEM
DMA straight to source row ``perm[n, m*C + c]``; the step emits the
permuted row to its destination slot and folds it into the f32 centroid
accumulator, flushing ``k_syn``/``v_syn``/``counts`` at the last member
of each cluster.  Every cache row moves through VMEM exactly once.

Grid (N, Hkv, M, C) — one row per step; Pallas double-buffers the row
DMAs across steps so the gather pipeline stays latency-hidden.  ``counts``
is emitted per (N, Hkv, M) (the wrapper returns the h=0 slice — clusters
are shared across KV heads by construction).

``absorb_recent`` reuses the same kernel with the identity permutation:
the recent ring buffer's R tokens become R/C new clusters appended to the
originals + centroid tables (the paper's "situation 1" incremental
update).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu


def _kernel(perm_ref, k_ref, v_ref, ks_ref, vs_ref, ksyn_ref, vsyn_ref,
            cnt_ref, kacc, vacc, *, cluster_size: int):
  c = pl.program_id(3)

  @pl.when(c == 0)
  def _init():
    kacc[...] = jnp.zeros_like(kacc)
    vacc[...] = jnp.zeros_like(vacc)

  krow = k_ref[0, 0].astype(jnp.float32)              # (1, D)
  vrow = v_ref[0, 0].astype(jnp.float32)
  ks_ref[0, 0] = krow.astype(ks_ref.dtype)            # permuted cache row
  vs_ref[0, 0] = vrow.astype(vs_ref.dtype)
  kacc[...] += krow
  vacc[...] += vrow

  @pl.when(c == cluster_size - 1)
  def _flush():
    inv = jnp.float32(1.0 / cluster_size)
    ksyn_ref[0, 0] = (kacc[...] * inv).astype(ksyn_ref.dtype)
    vsyn_ref[0, 0] = (vacc[...] * inv).astype(vsyn_ref.dtype)
    cnt_ref[0, 0, 0] = jnp.float32(cluster_size)


@functools.partial(jax.jit, static_argnames=("cluster_size", "interpret"))
def segment_build(
    k: jax.Array,          # (N, Hkv, S, D) exact cache, flat leading dims
    v: jax.Array,          # (N, Hkv, S, D)
    perm: jax.Array,       # (N, S) int32: row s of the output reads
                           # source row perm[n, s]; cluster m owns rows
                           # [m*C, (m+1)*C)
    *,
    cluster_size: int,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array, jax.Array]:
  """Returns (k_sorted, v_sorted, k_syn, v_syn, counts (N, M) f32)."""
  N, Hkv, S, D = k.shape
  C = cluster_size
  assert S % C == 0, (S, C)
  M = S // C

  def _src(n, h, m, c, perm):
    return (n, h, perm[n, m * C + c], 0)

  grid_spec = pltpu.PrefetchScalarGridSpec(
      num_scalar_prefetch=1,
      grid=(N, Hkv, M, C),
      in_specs=[
          pl.BlockSpec((1, 1, 1, D), _src),
          pl.BlockSpec((1, 1, 1, D), _src),
      ],
      out_specs=[
          pl.BlockSpec((1, 1, 1, D),
                       lambda n, h, m, c, perm: (n, h, m * C + c, 0)),
          pl.BlockSpec((1, 1, 1, D),
                       lambda n, h, m, c, perm: (n, h, m * C + c, 0)),
          pl.BlockSpec((1, 1, 1, D), lambda n, h, m, c, perm: (n, h, m, 0)),
          pl.BlockSpec((1, 1, 1, D), lambda n, h, m, c, perm: (n, h, m, 0)),
          pl.BlockSpec((1, 1, 1), lambda n, h, m, c, perm: (n, h, m)),
      ],
      scratch_shapes=[
          pltpu.VMEM((1, D), jnp.float32),
          pltpu.VMEM((1, D), jnp.float32),
      ],
  )
  fn = pl.pallas_call(
      functools.partial(_kernel, cluster_size=C),
      grid_spec=grid_spec,
      out_shape=[
          jax.ShapeDtypeStruct((N, Hkv, S, D), k.dtype),
          jax.ShapeDtypeStruct((N, Hkv, S, D), v.dtype),
          jax.ShapeDtypeStruct((N, Hkv, M, D), k.dtype),
          jax.ShapeDtypeStruct((N, Hkv, M, D), v.dtype),
          jax.ShapeDtypeStruct((N, Hkv, M), jnp.float32),
      ],
      interpret=interpret,
      name="segment_build",
  )
  ks, vs, ksyn, vsyn, cnt = fn(perm.astype(jnp.int32), k, v)
  return ks, vs, ksyn, vsyn, cnt[:, 0]
