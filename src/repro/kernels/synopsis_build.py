"""Fused synopsis-build (permute + segment-mean) Pallas kernel.

Paper §2.2 step 3 specialised to KV caches (DESIGN.md §6): given the
cluster-contiguous permutation produced by the clustering stage
(``repro.core.cluster``), reorder the exact cache and aggregate each
C-token cluster into its mean-centroid row — in ONE streaming pass.

The unfused XLA chain (``ref.synopsis_build_ref``) materialises the
sorted cache with ``take_along_axis`` (HBM write), then re-reads it for
the reshape-mean (HBM read) — two full passes over the cache plus the
gather's scatter traffic.  Here the permutation is **scalar-prefetched**
(SMEM) so the BlockSpec ``index_map`` steers each grid step's HBM->VMEM
DMA straight to source row ``perm[n, m*C + c]``; the step emits the
permuted row to its destination slot and folds it into the f32 centroid
accumulator, flushing ``k_syn``/``v_syn``/``counts`` at the last member
of each cluster.  Every cache row moves through VMEM exactly once.

Grid (N, Hkv, M, C) — one row per step; Pallas double-buffers the row
DMAs across steps so the gather pipeline stays latency-hidden.  ``counts``
is emitted per (N, Hkv, M) (the wrapper returns the h=0 slice — clusters
are shared across KV heads by construction).

``absorb_recent`` reuses the same kernel with the identity permutation:
the recent ring buffer's R tokens become R/C new clusters appended to the
originals + centroid tables (the paper's "situation 1" incremental
update).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels import quant as qt


def _kernel(perm_ref, k_ref, v_ref, *rest, cluster_size: int,
            quant: Optional[str], quant_kv: bool):
  it = iter(rest)
  ks_ref, vs_ref, ksyn_ref, vsyn_ref, cnt_ref = (
      next(it), next(it), next(it), next(it), next(it))
  kss_ref = vss_ref = kvs_ref = vvs_ref = None
  if quant:                     # per-centroid synopsis scales (§15)
    kss_ref, vss_ref = next(it), next(it)
    if quant_kv:                # per-cluster-block sorted-KV scales
      kvs_ref, vvs_ref = next(it), next(it)
  kacc, vacc = next(it), next(it)
  kblk = vblk = None
  if quant_kv:                  # buffer the cluster block for one-shot
    kblk, vblk = next(it), next(it)   # amax + encode at the flush

  c = pl.program_id(3)

  @pl.when(c == 0)
  def _init():
    kacc[...] = jnp.zeros_like(kacc)
    vacc[...] = jnp.zeros_like(vacc)

  krow = k_ref[0, 0].astype(jnp.float32)              # (1, D)
  vrow = v_ref[0, 0].astype(jnp.float32)
  if quant_kv:
    kblk[pl.ds(c, 1), :] = krow
    vblk[pl.ds(c, 1), :] = vrow
  else:
    ks_ref[0, 0] = krow.astype(ks_ref.dtype)          # permuted cache row
    vs_ref[0, 0] = vrow.astype(vs_ref.dtype)
  kacc[...] += krow
  vacc[...] += vrow

  def _q(x, s_ref, o_ref):
    # Quantize from the f32 accumulator/block: scale = amax/qmax, the
    # encode is the same deterministic round the XLA reference uses.
    scale = jnp.max(jnp.abs(x)) / qt.qmax(quant)
    s_ref[0, 0, 0] = scale
    inv = jnp.where(scale > 0, 1.0 / jnp.maximum(scale, 1e-30), 0.0)
    o_ref[0, 0] = qt.encode_scaled(x * inv, quant)

  @pl.when(c == cluster_size - 1)
  def _flush():
    inv = jnp.float32(1.0 / cluster_size)
    if quant:
      _q(kacc[...] * inv, kss_ref, ksyn_ref)
      _q(vacc[...] * inv, vss_ref, vsyn_ref)
    else:
      ksyn_ref[0, 0] = (kacc[...] * inv).astype(ksyn_ref.dtype)
      vsyn_ref[0, 0] = (vacc[...] * inv).astype(vsyn_ref.dtype)
    if quant_kv:
      _q(kblk[...], kvs_ref, ks_ref)
      _q(vblk[...], vvs_ref, vs_ref)
    cnt_ref[0, 0, 0] = jnp.float32(cluster_size)


@functools.partial(
    jax.jit, static_argnames=("cluster_size", "quant", "interpret"))
def segment_build(
    k: jax.Array,          # (N, Hkv, S, D) exact cache, flat leading dims
    v: jax.Array,          # (N, Hkv, S, D)
    perm: jax.Array,       # (N, S) int32: row s of the output reads
                           # source row perm[n, s]; cluster m owns rows
                           # [m*C, (m+1)*C)
    *,
    cluster_size: int,
    quant: Optional[str] = None,   # qconfig spec ("int8", "int8+kv", ...)
    interpret: bool = False,
) -> Union[Tuple[jax.Array, ...], dict]:
  """Returns (k_sorted, v_sorted, k_syn, v_syn, counts (N, M) f32).

  With ``quant`` the same streaming pass also emits the quantized arenas
  + scales (DESIGN.md §15) and returns the arena dict instead: centroids
  are quantized from the f32 accumulator at each cluster's flush (one
  scale per centroid row); with the ``+kv`` specs the sorted cache block
  is buffered in VMEM and quantized whole at the flush (one scale per
  cluster block), so no f32 sorted copy ever lands in HBM."""
  N, Hkv, S, D = k.shape
  C = cluster_size
  assert S % C == 0, (S, C)
  M = S // C
  qc = qt.parse_qconfig(quant)
  qdt = qt.qdtype(qc.kind) if qc.enabled else None

  def _src(n, h, m, c, perm):
    return (n, h, perm[n, m * C + c], 0)

  _syn = lambda n, h, m, c, perm: (n, h, m, 0)
  _scl = lambda n, h, m, c, perm: (n, h, m)
  if qc.sorted_kv:
    # Whole-cluster block output, written once at the flush.
    sorted_spec = pl.BlockSpec((1, 1, C, D), _syn)
  else:
    sorted_spec = pl.BlockSpec(
        (1, 1, 1, D), lambda n, h, m, c, perm: (n, h, m * C + c, 0))

  out_specs = [
      sorted_spec,
      sorted_spec,
      pl.BlockSpec((1, 1, 1, D), _syn),
      pl.BlockSpec((1, 1, 1, D), _syn),
      pl.BlockSpec((1, 1, 1), _scl),
  ]
  out_shape = [
      jax.ShapeDtypeStruct((N, Hkv, S, D), qdt if qc.sorted_kv else k.dtype),
      jax.ShapeDtypeStruct((N, Hkv, S, D), qdt if qc.sorted_kv else v.dtype),
      jax.ShapeDtypeStruct((N, Hkv, M, D), qdt if qc.enabled else k.dtype),
      jax.ShapeDtypeStruct((N, Hkv, M, D), qdt if qc.enabled else v.dtype),
      jax.ShapeDtypeStruct((N, Hkv, M), jnp.float32),
  ]
  scratch = [
      pltpu.VMEM((1, D), jnp.float32),
      pltpu.VMEM((1, D), jnp.float32),
  ]
  if qc.enabled:
    out_specs += [pl.BlockSpec((1, 1, 1), _scl)] * 2
    out_shape += [jax.ShapeDtypeStruct((N, Hkv, M), jnp.float32)] * 2
    if qc.sorted_kv:
      out_specs += [pl.BlockSpec((1, 1, 1), _scl)] * 2
      out_shape += [jax.ShapeDtypeStruct((N, Hkv, M), jnp.float32)] * 2
      scratch += [pltpu.VMEM((C, D), jnp.float32)] * 2

  grid_spec = pltpu.PrefetchScalarGridSpec(
      num_scalar_prefetch=1,
      grid=(N, Hkv, M, C),
      in_specs=[
          pl.BlockSpec((1, 1, 1, D), _src),
          pl.BlockSpec((1, 1, 1, D), _src),
      ],
      out_specs=out_specs,
      scratch_shapes=scratch,
  )
  fn = pl.pallas_call(
      functools.partial(_kernel, cluster_size=C,
                        quant=qc.kind if qc.enabled else None,
                        quant_kv=qc.sorted_kv),
      grid_spec=grid_spec,
      out_shape=out_shape,
      interpret=interpret,
      name="segment_build",
  )
  outs = fn(perm.astype(jnp.int32), k, v)
  ks, vs, ksyn, vsyn, cnt = outs[:5]
  if not qc.enabled:
    return ks, vs, ksyn, vsyn, cnt[:, 0]
  res = {"k": ks, "v": vs, "k_syn": ksyn, "v_syn": vsyn,
         "counts": cnt[:, 0],
         "k_syn_scale": outs[5], "v_syn_scale": outs[6]}
  if qc.sorted_kv:
    res["k_scale"], res["v_scale"] = outs[7], outs[8]
  return res
