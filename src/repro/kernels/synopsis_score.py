"""Synopsis scoring Pallas kernel: correlation c_i per aggregated point.

Paper line 1 of Algorithm 1 — "process S to obtain ... c_1 to c_m".  For
attention the correlation of cluster i to the request is the centroid
logit, reduced over the GQA group's query heads by max.  The output feeds
``lax.top_k`` ranking (lines 2-3).

Tiling: grid (B, Hkv, M/block_m); each step does a (G, D) x (D, block_m)
MXU matmul and a G-way max reduce, writing one (1, 1, block_m) score tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(q_ref, k_ref, out_ref, *, sm_scale: float):
  q = q_ref[0].astype(jnp.float32)                  # (G, D)
  k = k_ref[0, 0].astype(jnp.float32)               # (bm, D)
  logits = jax.lax.dot_general(
      q, k, (((1,), (1,)), ((), ())),
      preferred_element_type=jnp.float32) * sm_scale
  out_ref[0, 0] = jnp.max(logits, axis=0)           # (bm,)


@functools.partial(
    jax.jit, static_argnames=("sm_scale", "block_m", "interpret"))
def synopsis_score(
    q: jax.Array,        # (B, H, D)
    k_syn: jax.Array,    # (B, Hkv, M, D) centroid keys
    *,
    sm_scale: float = 1.0,
    block_m: int = 512,
    interpret: bool = False,
) -> jax.Array:
  """Returns scores (B, Hkv, M) = max over group of centroid logits."""
  B, H, D = q.shape
  _, Hkv, M, _ = k_syn.shape
  G = H // Hkv
  block_m = min(block_m, M)
  assert M % block_m == 0, (M, block_m)

  fn = pl.pallas_call(
      functools.partial(_kernel, sm_scale=sm_scale),
      grid=(B, Hkv, M // block_m),
      in_specs=[
          pl.BlockSpec((1, G, D), lambda b, h, m: (b, h, 0)),
          pl.BlockSpec((1, 1, block_m, D), lambda b, h, m: (b, h, m, 0)),
      ],
      out_specs=pl.BlockSpec((1, 1, block_m), lambda b, h, m: (b, h, m)),
      out_shape=jax.ShapeDtypeStruct((B, Hkv, M), jnp.float32),
      interpret=interpret,
      name="synopsis_score",
  )
  return fn(q, k_syn)
