import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
os.environ["REPRO_MIXED_DOTS"] = "1"   # TPU-target bf16 collectives

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves on placeholder devices that the distribution
config is coherent: shardings compose, the compiled module fits HBM
(memory_analysis) and yields the FLOP/byte/collective numbers the
roofline analysis (EXPERIMENTS.md §Roofline) reads.

  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b \
      --shape decode_32k --mesh single --out artifacts/dryrun
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

``--all`` runs each cell in a subprocess (isolation against OOM; fresh
compile cache).  Artifacts: one JSON per cell with memory analysis, cost
analysis, collective-byte breakdown and roofline terms.
"""
import argparse        # noqa: E402
import json            # noqa: E402
import subprocess      # noqa: E402
import sys             # noqa: E402
import time            # noqa: E402


def model_flops(cfg, shape, mode: str) -> float:
  """MODEL_FLOPS = 6*N(active)*D train / 2*N*D inference (roofline spec)."""
  n = cfg.param_count(active=True)
  n -= cfg.vocab * cfg.d_model * (1 if cfg.tie_embeddings else 2)  # non-matmul embeds
  if shape.kind == "train":
    return 6.0 * n * shape.global_batch * shape.seq_len
  if shape.kind == "prefill":
    return 2.0 * n * shape.global_batch * shape.seq_len
  return 2.0 * n * shape.global_batch           # decode: 1 token/seq


def run_cell(arch: str, shape_name: str, multi_pod: bool, mode: str,
             out_dir: str, causal_skip: bool = False) -> dict:
  import jax  # noqa: PLC0415
  import jax.numpy as jnp  # noqa: PLC0415

  from repro.analysis import roofline as rl  # noqa: PLC0415
  from repro.configs import shapes as shp  # noqa: PLC0415
  from repro.configs.registry import get_config  # noqa: PLC0415
  from repro.dist import sharding as shd  # noqa: PLC0415
  from repro.launch.mesh import make_production_mesh  # noqa: PLC0415
  from repro.models import common as cm  # noqa: PLC0415
  from repro.models import transformer as tf  # noqa: PLC0415
  from repro.serve import kv_cache as kvc  # noqa: PLC0415
  from repro.serve.prefill import make_prefill_step  # noqa: PLC0415
  from repro.serve.serve_step import make_serve_step  # noqa: PLC0415
  from repro.train.optimizer import OptConfig  # noqa: PLC0415
  from repro.train.train_step import make_train_step  # noqa: PLC0415

  cfg = get_config(arch)
  shape = shp.SHAPES[shape_name]
  mesh = make_production_mesh(multi_pod=multi_pod)
  chips = mesh.devices.size

  # Memory-driven weight-sharding policy: big models FSDP their weights
  # over `data` even when serving (a v5e chip has 16 GB).
  big = cfg.param_count() * 2 / shd.tp_size(mesh) > 10e9
  if shape.kind == "train":
    # FSDP only pays when replicated f32 master+Adam state would not fit
    # comfortably (~12 B/param); small models replicate weights and avoid
    # per-layer gather/reshard collectives entirely (§Perf cell 3).
    rules = dict(shd.TRAIN_RULES)
    if cfg.param_count() * 12 < 2e9:
      rules["embed"] = None
  elif shape_name == "long_500k":
    rules = dict(shd.LONG_RULES)
    if big:
      rules["embed"] = ("data",)
  else:
    rules = dict(shd.SERVE_RULES)
    if big:
      rules["embed"] = ("data",)

  # Resolve mode per cell.
  has_attn = kvc.n_attn_positions(cfg) > 0
  if mode == "auto":
    if shape.kind == "decode":
      mode = "synopsis" if has_attn else "exact"
      if shape_name == "decode_32k":
        mode = "exact"              # baseline cell; synopsis via --mode
    else:
      mode = "n/a"
  if mode == "synopsis" and not has_attn:
    mode = "exact"                  # technique inapplicable (DESIGN.md §5)

  t0 = time.time()
  # --- abstract params + axes (eval_shape: no 100B allocations) ----------
  captured = {}

  def init_fn(key):
    boxed = tf.init_model(key, cfg)
    params, axes = cm.split(boxed)
    captured["axes"] = axes
    return params

  params_sds = jax.eval_shape(init_fn, jax.random.PRNGKey(0))
  axes = captured["axes"]

  with shd.use_mesh(mesh, rules):
    if shape.kind == "train":
      opt_cfg = OptConfig()
      state_sds = {
          "params": jax.tree.map(
              lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32),
              params_sds),
      }
      state_sds["opt"] = {
          "m": state_sds["params"], "v": state_sds["params"],
          "step": jax.ShapeDtypeStruct((), jnp.int32),
      }
      state_axes = {"params": axes,
                    "opt": {"m": axes, "v": axes, "step": ()}}
      compress = multi_pod
      if compress:
        state_sds["err"] = state_sds["params"]
        state_axes["err"] = axes
      batch_sds = shp.input_specs(cfg, shape)
      batch_axes = {k: ("batch",) + (None,) * (len(v.shape) - 1)
                    for k, v in batch_sds.items()}
      in_sh = (shd.tree_shardings(state_axes, mesh, rules, state_sds),
               shd.tree_shardings(batch_axes, mesh, rules, batch_sds))
      # Adaptive microbatching (§Perf cell 3 side-finding): collectives
      # scale with microbatch count (weight re-gathers + activation
      # reductions per microbatch), so use the smallest count whose
      # activation residuals fit: est = B_local*S*d*2B*L against a ~6 GB
      # budget, rounded to a power of two.
      b_local = shape.global_batch // max(shd.dp_size(mesh), 1)
      est = b_local * shape.seq_len * cfg.d_model * 2 * cfg.n_layers
      mb = 1
      while mb < 16 and est / mb > 6e9:
        mb *= 2
      while shape.global_batch % (mb * shd.dp_size(mesh)) != 0 and mb > 1:
        mb //= 2
      step = make_train_step(cfg, opt_cfg, microbatches=mb,
                             compress_pods=compress, mesh=mesh,
                             param_axes=axes, causal_skip=causal_skip)
      jitted = jax.jit(step, in_shardings=in_sh,
                       out_shardings=(in_sh[0], None), donate_argnums=0)
      lowered = jitted.lower(state_sds, batch_sds)
    elif shape.kind == "prefill":
      batch_sds = shp.input_specs(cfg, shape)
      batch_axes = {k: ("batch",) + (None,) * (len(v.shape) - 1)
                    for k, v in batch_sds.items()}
      p_bf16 = jax.tree.map(
          lambda s: jax.ShapeDtypeStruct(s.shape, cfg.dtype), params_sds)
      p_sh = shd.tree_shardings(axes, mesh, rules, p_bf16)
      b_sh = shd.tree_shardings(batch_axes, mesh, rules, batch_sds)
      step = make_prefill_step(cfg)
      arg_names = ["tokens"] + (["frontend_embeds"]
                                if "frontend_embeds" in batch_sds else [])
      jitted = jax.jit(
          lambda p, t, f=None: step(p, t, f),
          in_shardings=(p_sh,) + tuple(b_sh[k] for k in arg_names))
      lowered = jitted.lower(p_bf16, *[batch_sds[k] for k in arg_names])
    else:  # decode
      B, S = shape.global_batch, shape.seq_len
      syn = (mode == "synopsis")
      cache_sds = kvc.cache_specs(cfg, B, S, synopsis=syn)
      c_axes = kvc.cache_axes(cfg, B, S, synopsis=syn)
      tok_sds = jax.ShapeDtypeStruct((B, 1), jnp.int32)
      p_bf16 = jax.tree.map(
          lambda s: jax.ShapeDtypeStruct(s.shape, cfg.dtype), params_sds)
      in_sh = (shd.tree_shardings(axes, mesh, rules, p_bf16),
               shd.tree_shardings(c_axes, mesh, rules, cache_sds),
               shd.named_sharding(("batch", None), mesh, rules, (B, 1)))
      step = make_serve_step(cfg, mode="synopsis" if syn else "exact")
      jitted = jax.jit(step, in_shardings=in_sh)
      lowered = jitted.lower(p_bf16, cache_sds, tok_sds)

    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

  mem = rl.memory_summary(compiled)
  coll = rl.collective_bytes(compiled.as_text())
  # FLOPs/bytes from the analytic cost model (cost_analysis counts scan
  # bodies once — see analysis/costmodel.py); raw numbers kept below.
  from repro.analysis import costmodel as cmod  # noqa: PLC0415
  cost = cmod.cell_cost(cfg, shape, mode, causal_skip=causal_skip)
  raw_ca = compiled.cost_analysis()
  if isinstance(raw_ca, list):
    raw_ca = raw_ca[0]
  roof = rl.Roofline(
      flops_per_device=cost.flops_global / chips,
      bytes_per_device=cost.bytes_global / chips,
      coll_bytes_per_device=float(coll["total"]),
      chips=chips,
      model_flops=model_flops(cfg, shape, mode),
  )

  result = {
      "arch": arch, "shape": shape_name,
      "mesh": "multi" if multi_pod else "single", "chips": chips,
      "mode": mode,
      "microbatches": locals().get("mb"),
      "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
      "memory": mem,
      "fits_hbm": mem["peak_bytes_per_device"] < 16e9,
      "collectives": coll,
      "roofline": roof.to_dict(),
      "raw_cost_analysis": {
          "flops_per_device_scan_body_once": float(raw_ca.get("flops", 0)),
          "bytes_accessed_scan_body_once":
              float(raw_ca.get("bytes accessed", 0)),
      },
  }
  print(compiled.memory_analysis())
  if out_dir:
    os.makedirs(out_dir, exist_ok=True)
    tag = f"{arch}__{shape_name}__{result['mesh']}__{mode.replace('/', '_')}"
    with open(os.path.join(out_dir, tag + ".json"), "w") as f:
      json.dump(result, f, indent=1)
  return result


CELLS_MODES = {          # decode cells run baseline AND synopsis variants
    "decode_32k": ["exact", "synopsis"],
    "long_500k": ["auto"],
    "train_4k": ["auto"],
    "prefill_32k": ["auto"],
}


def main():
  ap = argparse.ArgumentParser()
  ap.add_argument("--arch", default=None)
  ap.add_argument("--shape", default=None)
  ap.add_argument("--mesh", default="single",
                  choices=["single", "multi", "both"])
  ap.add_argument("--mode", default="auto")
  ap.add_argument("--out", default="artifacts/dryrun")
  ap.add_argument("--all", action="store_true")
  ap.add_argument("--timeout", type=int, default=1800)
  ap.add_argument("--causal-skip", action="store_true",
                  help="beyond-paper: restrict each q-chunk's KV range")
  args = ap.parse_args()

  if not args.all:
    res = run_cell(args.arch, args.shape, args.mesh == "multi", args.mode,
                   args.out, causal_skip=args.causal_skip)
    r = res["roofline"]
    print(json.dumps({k: v for k, v in res.items() if k != "memory"},
                     indent=1))
    print(f"DOMINANT={r['dominant']} bound={r['bound_s']:.4e}s "
          f"fits={res['fits_hbm']}")
    return

  from repro.configs.registry import list_archs  # noqa: PLC0415
  meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
  failures = []
  for arch in list_archs():
    for shape, modes in CELLS_MODES.items():
      for mode in modes:
        for m in meshes:
          tag = f"{arch} {shape} {m} {mode}"
          out_file = os.path.join(
              args.out, f"{arch}__{shape}__{m}__{mode}.json")
          cmd = [sys.executable, "-m", "repro.launch.dryrun",
                 "--arch", arch, "--shape", shape, "--mesh", m,
                 "--mode", mode, "--out", args.out]
          t0 = time.time()
          try:
            p = subprocess.run(cmd, capture_output=True, text=True,
                               timeout=args.timeout)
            ok = p.returncode == 0
          except subprocess.TimeoutExpired:
            ok, p = False, None
          dt = time.time() - t0
          status = "OK" if ok else "FAIL"
          print(f"[{status}] {tag} ({dt:.0f}s)", flush=True)
          if not ok:
            failures.append(tag)
            if p is not None:
              print((p.stderr or "")[-2000:])
  print(f"\n{'ALL CELLS PASS' if not failures else failures}")
  sys.exit(1 if failures else 0)


if __name__ == "__main__":
  main()
