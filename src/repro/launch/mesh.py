"""Production meshes.  Defined as FUNCTIONS so importing this module never
touches jax device state (jax locks the backend on first device query).

Single pod: (data=16, model=16) = 256 chips (TPU v5e-256 topology).
Multi-pod: (pod=2, data=16, model=16) = 512 chips; the `pod` axis carries
pure DP + the compressed cross-pod gradient reduction.
"""
from __future__ import annotations

import math

import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
  import jax  # noqa: PLC0415 — deferred so module import is device-free

  shape = (2, 16, 16) if multi_pod else (16, 16)
  axes = ("pod", "data", "model") if multi_pod else ("data", "model")
  n = math.prod(shape)
  devs = jax.devices()
  if len(devs) == n:
    return jax.make_mesh(shape, axes)
  if len(devs) < n:
    raise RuntimeError(
        f"need {n} devices for mesh {dict(zip(axes, shape))}, have "
        f"{len(devs)} — run under XLA_FLAGS="
        f"--xla_force_host_platform_device_count={n} (see launch/dryrun.py)")
  # More devices than the mesh needs (e.g. 512-device dry-run host building
  # the single-pod mesh): use the first n.
  from jax.sharding import Mesh  # noqa: PLC0415
  return Mesh(np.array(devs[:n]).reshape(shape), axes)


def make_debug_mesh(shape=(2, 2), axes=("data", "model")):
  """Small mesh for tests (requires matching host device count)."""
  import jax  # noqa: PLC0415
  from jax.sharding import Mesh  # noqa: PLC0415
  n = math.prod(shape)
  return Mesh(np.array(jax.devices()[:n]).reshape(shape), axes)
