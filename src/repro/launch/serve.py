"""Serving driver: prefill -> synopsis build -> deadline-budgeted decode.

The AccuracyTrader loop: each decode batch picks its refinement budget
from the calibrated latency model and the configured deadline; new tokens
accumulate in the recent buffer and are absorbed into the synopsis when
it fills (the paper's low-priority incremental update).

  PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --smoke \
      --prompt-len 256 --tokens 32 --deadline-ms 50
"""
from __future__ import annotations

import argparse
import time


def main():
  ap = argparse.ArgumentParser()
  ap.add_argument("--arch", default="llama3-8b")
  ap.add_argument("--smoke", action="store_true", default=True)
  ap.add_argument("--batch", type=int, default=2)
  ap.add_argument("--prompt-len", type=int, default=256)
  ap.add_argument("--tokens", type=int, default=32)
  ap.add_argument("--mode", default="synopsis",
                  choices=["exact", "synopsis"])
  ap.add_argument("--impl", default=None,
                  choices=["auto", "pallas", "xla", "interpret"],
                  help="decode-attention implementation; default: the "
                       "config's synopsis.impl (auto = fused Pallas "
                       "kernels on TPU, XLA reference elsewhere)")
  ap.add_argument("--deadline-ms", type=float, default=50.0)
  args = ap.parse_args()

  import jax
  import jax.numpy as jnp

  from repro.configs.registry import get_config
  from repro.core.deadline import BudgetController, LatencyModel
  from repro.models import common as cm
  from repro.models import transformer as tf
  from repro.serve import synopsis_kv as skv
  from repro.serve.kv_cache import n_attn_positions
  from repro.serve.prefill import make_prefill_step
  from repro.serve.serve_step import make_serve_step

  cfg = get_config(args.arch, smoke=args.smoke)
  key = jax.random.PRNGKey(0)
  params, _ = cm.split(tf.init_model(key, cfg))
  params = jax.tree.map(lambda p: p.astype(cfg.dtype), params)

  B, S = args.batch, args.prompt_len
  prompt = jax.random.randint(key, (B, S), 0, cfg.vocab)
  t0 = time.time()
  logits, cache = jax.jit(make_prefill_step(cfg))(params, prompt)
  jax.block_until_ready(logits)
  print(f"[prefill] {S} tokens in {time.time() - t0:.2f}s")

  from repro.serve.serve_step import resolve_impl
  impl = resolve_impl(args.impl if args.impl else cfg.synopsis.impl)
  print(f"[impl] decode attention via {impl!r}")

  mode = args.mode if n_attn_positions(cfg) else "exact"
  if mode == "synopsis":
    cache = jax.jit(lambda c: skv.build(c, cfg))(cache)
    M = S // cfg.synopsis.cluster_size
    print(f"[synopsis] M={M} clusters of C={cfg.synopsis.cluster_size}")
  ctrl = BudgetController(LatencyModel(base=5.0, slope=1.0, alpha=0.1),
                          buckets=(0, 1, 2, 4, 8, 16, 32),
                          i_max_cap=cfg.synopsis.i_max or 32)

  steps = {}
  tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
  out_tokens = [tok]
  for i in range(args.tokens):
    budget = ctrl.budget_for(args.deadline_ms) if mode == "synopsis" else 0
    if (mode, budget) not in steps:
      steps[(mode, budget)] = jax.jit(
          make_serve_step(cfg, mode=mode, i_max=budget, impl=impl))
    t0 = time.time()
    logits, st = steps[(mode, budget)](params, cache, tok)
    jax.block_until_ready(logits)
    dt = (time.time() - t0) * 1e3
    if mode == "synopsis":
      ctrl.observe(budget, dt)
      cache = skv.append_recent(cache, st["k_delta"], st["v_delta"])
      cache["pos"] = st["pos"]
      if int(cache["recent_len"][0]) >= cfg.synopsis.recent:
        cache = jax.jit(lambda c: skv.absorb_recent(c, cfg))(cache)
        print(f"[update] absorbed recent buffer -> "
              f"M={cache['k_syn'].shape[4]}")
    else:
      cache["pos"] = st["pos"]
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    out_tokens.append(tok)
    print(f"[decode {i:3d}] budget={budget:3d} {dt:7.1f}ms")
  print("generated:", jnp.concatenate(out_tokens, 1)[0].tolist())


if __name__ == "__main__":
  main()
