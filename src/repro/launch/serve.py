"""Serving driver: prefill -> synopsis build -> deadline-budgeted decode.

The AccuracyTrader loop: each decode batch picks its refinement budget
from the calibrated latency model and the configured deadline; new tokens
accumulate in the recent buffer and are absorbed into the synopsis when
it fills (the paper's low-priority incremental update).

All three stages run through the kernel suite behind one ``--impl``
switch (prefill attention, synopsis build, decode attention — DESIGN.md
§4/§6).  With ``--batches N --pipeline`` the driver overlaps batch i's
synopsis build with batch i+1's prefill: both stages are single jitted
programs and the loop never calls ``jax.block_until_ready`` between
dispatches, so the runtime's async dispatch queue pipelines them (the
paper's low-priority offline module running behind the online path).

  PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --smoke \
      --prompt-len 256 --tokens 32 --deadline-ms 50

  # pipelined prefill/build over 4 prompt batches:
  PYTHONPATH=src python -m repro.launch.serve --batches 4 --pipeline

With ``--engine`` the driver instead runs the deadline-driven
continuous-batching engine (`repro.serve.engine`, DESIGN.md §8) over an
arrival trace: requests admit/retire in shared cache slots mid-flight and
every budget decision is calibrated by measured step latencies.

  # paper Tables 1-2 load sweep (measured):
  PYTHONPATH=src python -m repro.launch.serve --engine --trace cf_rates

  # diurnal Sogou-shaped hours (Fig 7a):
  PYTHONPATH=src python -m repro.launch.serve --engine \
      --trace sogou_hourly --hours 3,9,21
"""
from __future__ import annotations

import argparse
import time


def _apply_quant(cfg, quant: str):
  """Swap the synopsis quantization spec into the model config
  (DESIGN.md §15).  "none" returns cfg unchanged — the bit-identical
  control arm."""
  if not quant or quant == "none":
    return cfg
  import dataclasses
  return dataclasses.replace(
      cfg, synopsis=dataclasses.replace(cfg.synopsis, quant=quant))


def _engine_main(args):
  """Continuous-batching engine over an arrival trace (DESIGN.md §8);
  with ``--cluster N`` the decode steps run the multi-component
  scatter-gather tier (DESIGN.md §9) across N components."""
  import json

  from repro.configs.registry import get_config
  from repro.control import AdmissionConfig, parse_slo_classes
  from repro.serve.engine import EngineConfig, ServingEngine, run_open_loop
  from repro.serve.resilience import parse_fault_spec
  from repro.serving.workload import CF_RATES, hour_rate

  cfg = get_config(args.arch, smoke=args.smoke)
  cfg = _apply_quant(cfg, args.quant)
  C = cfg.synopsis.cluster_size
  prompt_len = max(C, (args.prompt_len // C) * C)
  max_new = min(args.tokens, cfg.synopsis.recent)
  faults = parse_fault_spec(args.faults)
  backend = None
  if args.fleet:
    from repro.serve.fleet import FleetConfig, FleetStepBackend
    backend = FleetStepBackend(FleetConfig(
        n_components=args.cluster, skew=args.skew, alloc=args.alloc,
        route=args.route, replicas=max(1, args.replicas),
        predictor=args.predictor or "ewma"))
  elif args.cluster:
    from repro.serve.cluster import ClusterConfig, ClusterStepBackend
    backend = ClusterStepBackend(ClusterConfig(
        n_components=args.cluster, skew=args.skew, alloc=args.alloc,
        route=args.route, replicas=args.replicas,
        predictor=args.predictor or "ewma",
        faults=faults, recovery=not args.no_recovery,
        retries=args.retries))
  admission = None
  if args.admission != "off":
    admission = AdmissionConfig(
        order=args.admission, shed=not args.no_shed,
        shed_margin=args.shed_margin,
        classes=parse_slo_classes(args.slo_classes))
  cache = None
  if args.cache_capacity > 0 and not args.no_cache:
    from repro.serve.corpus_cache import CacheConfig
    cache = CacheConfig(capacity=args.cache_capacity, delta_unit=C)
  eng = ServingEngine(cfg, EngineConfig(
      n_slots=args.n_slots, prompt_len=prompt_len, max_new_tokens=max_new,
      deadline_ms=args.deadline_ms, policy=args.policy, impl=args.impl,
      predictor=args.predictor or "affine", admission=admission,
      cache=cache, contract=args.contract, epsilon=args.epsilon),
      backend=backend)
  print(f"[engine] impl={eng.impl!r} policy={args.policy} "
        f"slots={args.n_slots} prompt={prompt_len} tokens={max_new} "
        f"M={eng.M} buckets={eng.buckets} deadline={args.deadline_ms}ms"
        + (f" contract={args.contract} eps={args.epsilon}"
           if args.contract != "deadline" else "")
        + (f" cache={args.cache_capacity}" if cache is not None else ""))
  if backend is not None:
    import jax
    mesh = "mesh" if backend.mesh is not None else "stacked"
    tier = "fleet" if args.fleet else "cluster"
    print(f"[{tier}] N={args.cluster} ({mesh}, {len(jax.devices())} "
          f"devices) counts={backend.topo.counts} alloc={args.alloc} "
          f"route={args.route} skew={args.skew} R={args.replicas} "
          f"predictor={args.predictor or 'ewma'}")

  if args.trace == "cf_rates":
    points = [(f"rate{r}", r * args.rate_scale) for r in CF_RATES]
  else:
    hours = [int(h) for h in args.hours.split(",")]
    points = [(f"hour{h:02d}", hour_rate(h) * args.rate_scale)
              for h in hours]
  slo_of = None
  if admission is not None and admission.classes:
    names = [c.name for c in admission.classes]
    slo_of = lambda rid: names[rid % len(names)]  # noqa: E731
  results = {}
  for name, rate in points:
    s = run_open_loop(eng, rate_per_s=rate, duration_s=args.duration,
                      seed=0, slo_of=slo_of,
                      zipf_corpora=args.zipf_corpora)
    results[name] = {
        "rate_per_s": rate,
        **{k: round(float(v), 3) for k, v in s.items()
           if not isinstance(v, dict)},
        **({"classes": s["classes"]} if "classes" in s else {})}
    print(f"[{name}] rate={rate:6.1f}/s n={s['n']:4.0f} "
          f"p50={s['p50']:7.1f}ms p99={s['p99']:7.1f}ms "
          f"p999={s['p999']:7.1f}ms loss={s['accuracy_loss_pct']:5.2f}% "
          f"miss={s['deadline_miss_pct']:5.1f}% "
          f"budget={s['mean_budget']:.2f}"
        + (f" shed={s['shed_pct']:.1f}% goodput={s['goodput_per_s']:.1f}/s"
           if "shed_pct" in s else "")
        + (f" pred={s.get('pred_loss_mean', 0.0):.4f} "
           f"band_cov={s.get('band_cover_pct', 0.0):.0f}% "
           f"freed={s.get('freed_budget_mean', 0.0):.2f}"
           if args.contract != "deadline" else ""))
    if backend is not None and getattr(backend, "fault_stats", None) \
        and any(backend.fault_stats.values()):
      print(f"  [faults] {backend.fault_stats}")
  out = {"trace": args.trace, "policy": args.policy, "results": results}
  if backend is not None:
    exp = backend.export()
    out["cluster"] = {
        "n_components": args.cluster, "skew": args.skew,
        "alloc": args.alloc, "route": args.route,
        "counts": list(backend.topo.counts),
        "comp_ms_full": [round(float(v), 4)
                         for v in exp.step_ms_per_component(100)],
    }
    print(f"[cluster] measured per-component ms at full budget: "
          f"{out['cluster']['comp_ms_full']}")
  if args.autoscale:
    out["autoscale"] = _autoscale_main(args, backend)
  if args.json:
    with open(args.json, "w") as f:
      json.dump(out, f, indent=1, sort_keys=True)
    print(f"# wrote {args.json}")


def _autoscale_main(args, backend):
  """Elastic sizing over the 24-hour diurnal trace (DESIGN.md §14): the
  autoscaler decides each hour's (components, replicas) grid from the
  fleet's measured export, and the discrete-event simulator replays the
  window at that size (the counterfactual round-trip) — cheap enough to
  cover all 24 hours where real engine windows would not be."""
  if backend is None:
    raise SystemExit("--autoscale requires --fleet (or --cluster N)")
  from repro.control import Autoscaler, AutoscalerConfig
  from repro.serving.service import (ScaledFleetExport, ScatterGatherService,
                                     ServiceConfig)
  from repro.serving.workload import hour_rate

  exp = backend.export()
  n_max, r_max = args.cluster, max(1, args.replicas)
  asc = Autoscaler(AutoscalerConfig(
      p99_target_ms=args.p99_target, max_components=n_max,
      max_replicas=r_max, slots=args.n_slots),
      ScaledFleetExport(exp, n_max, r_max).step_model)
  print(f"[autoscale] p99 target {args.p99_target}ms, grid up to "
        f"{n_max}x{r_max}, 24 sogou hours x rate_scale={args.rate_scale}")
  size = None
  windows = []
  cost_auto = cost_static = 0
  for h in range(24):
    rate = hour_rate(h) * args.rate_scale
    size = asc.decide(rate, size)
    sim = ScatterGatherService(
        ServiceConfig(n_components=size.n_components,
                      deadline_ms=args.deadline_ms, seed=h),
        step_backend=ScaledFleetExport(exp, size.n_components,
                                       size.replicas))
    s = sim.run_open_loop(rate, args.duration)
    cost_auto += size.devices
    cost_static += n_max * r_max
    windows.append({"hour": h, "rate_per_s": round(rate, 2),
                    "n": size.n_components, "r": size.replicas,
                    "p99_ms": round(float(s["p99"]), 2)})
    print(f"[hour{h:02d}] rate={rate:6.1f}/s grid="
          f"{size.n_components}x{size.replicas} p99={s['p99']:7.1f}ms")
  print(f"[autoscale] component-hours: autoscaled={cost_auto} "
        f"static-peak={cost_static}")
  return {"p99_target_ms": args.p99_target, "windows": windows,
          "component_hours": cost_auto,
          "component_hours_static": cost_static}


def main():
  ap = argparse.ArgumentParser()
  ap.add_argument("--arch", default="llama3-8b")
  ap.add_argument("--smoke", action="store_true", default=True)
  ap.add_argument("--batch", type=int, default=2)
  ap.add_argument("--prompt-len", type=int, default=256)
  ap.add_argument("--tokens", type=int, default=32)
  ap.add_argument("--batches", type=int, default=1,
                  help="number of sequence batches to prefill + build")
  ap.add_argument("--pipeline", action="store_true",
                  help="overlap batch i's synopsis build with batch i+1's "
                       "prefill (block-free dispatch, one jitted program "
                       "per stage)")
  ap.add_argument("--mode", default="synopsis",
                  choices=["exact", "synopsis"])
  ap.add_argument("--impl", default=None,
                  choices=["auto", "pallas", "xla", "interpret"],
                  help="kernel implementation for prefill, synopsis build "
                       "and decode attention; default: the config's "
                       "synopsis.impl (auto = Pallas kernels on TPU, XLA "
                       "reference elsewhere)")
  ap.add_argument("--deadline-ms", type=float, default=50.0)
  ap.add_argument("--contract", default="deadline",
                  choices=["deadline", "error_bounded",
                           "deadline_with_bound"],
                  help="serving contract (DESIGN.md §13): error_bounded "
                       "answers early once the online estimator predicts "
                       "loss <= --epsilon; deadline_with_bound attaches "
                       "a calibrated loss band to every answer")
  ap.add_argument("--epsilon", type=float, default=0.02,
                  help="error_bounded loss target ε (0 = exact path)")
  ap.add_argument("--engine", action="store_true",
                  help="run the deadline-driven continuous-batching "
                       "engine over an arrival trace (DESIGN.md §8) "
                       "instead of the single-batch demo loop")
  ap.add_argument("--cluster", type=int, default=0, metavar="N",
                  help="run decode steps on the N-component scatter-"
                       "gather tier (DESIGN.md §9; implies --engine): "
                       "shard_map over a component mesh when N host "
                       "devices exist (forced automatically on CPU), "
                       "stacked execution of the same math otherwise")
  ap.add_argument("--fleet", action="store_true",
                  help="run the materialized-replica fleet tier "
                       "(DESIGN.md §14; implies --engine, needs "
                       "--cluster N): a (replica, component) 2-D mesh "
                       "where each of --replicas rows holds a real copy "
                       "of every shard and the gather reads each "
                       "shard's fastest-predicted holder")
  ap.add_argument("--autoscale", action="store_true",
                  help="after the trace sweep, run the elastic "
                       "autoscaler over the 24-hour sogou trace "
                       "(DESIGN.md §14): per hour, size the "
                       "(components, replicas) grid against "
                       "--p99-target using the measured export + the "
                       "simulator counterfactual, and report "
                       "component-hours vs static peak sizing")
  ap.add_argument("--p99-target", type=float, default=50.0,
                  help="autoscaler latency target (ms)")
  ap.add_argument("--skew", type=float, default=0.0,
                  help="Zipf exponent over component corpus shares "
                       "(hot components own more clusters)")
  ap.add_argument("--alloc", default="mass",
                  choices=["mass", "topk", "gain"],
                  help="frontend refinement-budget allocation across "
                       "components: proportional to synopsis relevance "
                       "mass, or pure global top-k")
  ap.add_argument("--route", default="fixed", choices=["fixed", "rotate"],
                  help="per-slot cluster->component routing (rotate "
                       "spreads skewed ranges across components)")
  ap.add_argument("--replicas", type=int, default=1, metavar="R",
                  help="shard copies on the component ring (R >= 2 "
                       "enables hedged reissue: a gather predicted to "
                       "straggle is reissued to the shard's replica and "
                       "the earlier completion counts — DESIGN.md §10)")
  ap.add_argument("--faults", default=None, metavar="SPEC",
                  help="inject component faults into the cluster tier "
                       "(DESIGN.md §11): comma-separated key=value pairs, "
                       "e.g. 'crash=1@8,stall_rate=0.02,seed=3' (crash "
                       "entries are comp@step joined by +); default: none")
  ap.add_argument("--no-recovery", action="store_true",
                  help="disable the gather-side recovery ladder (retry to "
                       "replica, stage-1 fallback): a dead component's "
                       "shard stalls and is dropped — the baseline a "
                       "resilient tier is compared against")
  ap.add_argument("--retries", type=int, default=1, metavar="K",
                  help="max gather-side retries per component per step "
                       "(exponential backoff to ring replicas; 1 = the "
                       "legacy single zero-delay hedge)")
  ap.add_argument("--admission", default="off",
                  choices=["off", "fifo", "edf", "slack"],
                  help="queue-aware predictive admission for --engine "
                       "(DESIGN.md §11): ready-queue ordering (edf = "
                       "earliest deadline first, slack = least "
                       "predicted slack) with predictive shedding; "
                       "off = the legacy FIFO queue, no shedding")
  ap.add_argument("--slo-classes", default=None, metavar="SPEC",
                  help="SLO classes for --admission, "
                       "'name:deadline_ms[@rate_per_s[/burst]]' joined "
                       "by commas, e.g. 'interactive:80@60,batch:400'; "
                       "requests round-robin across classes")
  ap.add_argument("--shed-margin", type=float, default=1.0,
                  help="shed a request at admission when its predicted "
                       "completion exceeds deadline * margin")
  ap.add_argument("--no-shed", action="store_true",
                  help="keep the admission ordering but never shed")
  ap.add_argument("--predictor", default=None,
                  help="control-plane latency predictor: affine | ewma | "
                       "quantile[:pct] (quantile makes deadlines target "
                       "a percentile of the measured per-bucket step "
                       "times; default: affine for the engine "
                       "controller, ewma for the cluster tier)")
  ap.add_argument("--trace", default="cf_rates",
                  choices=["cf_rates", "sogou_hourly"],
                  help="arrival-rate source for --engine")
  ap.add_argument("--policy", default="accuracytrader",
                  choices=["basic", "partial", "accuracytrader", "fixed"])
  ap.add_argument("--n-slots", type=int, default=2,
                  help="engine batch lanes (max resident requests)")
  ap.add_argument("--duration", type=float, default=1.0,
                  help="seconds of arrivals per engine measurement window")
  ap.add_argument("--rate-scale", type=float, default=1.0,
                  help="multiplier on the trace's req/s rates (size the "
                       "load to the host: the paper's rates target a "
                       "110-VM cluster)")
  ap.add_argument("--hours", default="3,9,21",
                  help="comma-separated hours of day for --trace "
                       "sogou_hourly (0-23; 24 aliases 0)")
  ap.add_argument("--cache-capacity", type=int, default=0, metavar="K",
                  help="corpus-cache resident-arena target (DESIGN.md "
                       "§12): admission consults a content-addressed "
                       "synopsis cache before prefill; 0 disables "
                       "(bit-identical control arm)")
  ap.add_argument("--no-cache", action="store_true",
                  help="force the cache off regardless of "
                       "--cache-capacity (the true control arm)")
  ap.add_argument("--zipf-corpora", type=int, default=0, metavar="K",
                  help="draw --engine prompts from a pool of K corpora "
                       "with Zipf popularity instead of fresh random "
                       "prompts (the workload the corpus cache serves); "
                       "0 = unique corpora")
  ap.add_argument("--quant", default="none",
                  choices=["none", "int8", "fp8", "int8+kv", "fp8+kv"],
                  help="quantize the synopsis arena (DESIGN.md §15): "
                       "int8/fp8 centroids with per-centroid scales; the "
                       "'+kv' variants also store the sorted corpus KV "
                       "quantized with per-cluster-block scales — scales "
                       "ride into the stage-1/stage-2 kernels, no f32 "
                       "copies; none = bit-identical control arm")
  ap.add_argument("--json", default=None, metavar="PATH",
                  help="write the --engine sweep results as JSON")
  args = ap.parse_args()

  if args.fleet and not args.cluster:
    ap.error("--fleet needs --cluster N (the component count; "
             "--replicas R sets the replica rows)")
  if args.cluster:
    # The mesh wants one device per component — times the replica rows
    # under --fleet (the 2-D grid) — so on a CPU host force placeholder
    # devices BEFORE jax initialises (same mechanism as launch/dryrun.py).
    # No-op if the user already set the flag.
    from repro.dist.topology import force_host_devices
    force_host_devices(args.cluster * (max(1, args.replicas)
                                       if args.fleet else 1))
    return _engine_main(args)

  if args.engine:
    return _engine_main(args)

  import jax
  import jax.numpy as jnp

  from repro.configs.registry import get_config
  from repro.control import BudgetController, make_predictor
  from repro.kernels.ops import resolve_impl
  from repro.models import common as cm
  from repro.models import transformer as tf
  from repro.serve import synopsis_kv as skv
  from repro.serve.kv_cache import n_attn_positions
  from repro.serve.prefill import make_prefill_step
  from repro.serve.serve_step import make_serve_step

  cfg = get_config(args.arch, smoke=args.smoke)
  cfg = _apply_quant(cfg, args.quant)
  key = jax.random.PRNGKey(0)
  params, _ = cm.split(tf.init_model(key, cfg))
  params = jax.tree.map(lambda p: p.astype(cfg.dtype), params)

  impl = resolve_impl(args.impl if args.impl else cfg.synopsis.impl)
  print(f"[impl] prefill/build/decode kernels via {impl!r}")

  B, S = args.batch, args.prompt_len
  mode = args.mode if n_attn_positions(cfg) else "exact"
  prompts = [jax.random.randint(jax.random.fold_in(key, bi), (B, S), 0,
                                cfg.vocab) for bi in range(args.batches)]
  prefill_fn = jax.jit(make_prefill_step(cfg, impl=impl))
  build_fn = jax.jit(lambda c: skv.build(c, cfg, impl=impl))

  # Prefill -> synopsis-build over all batches.  Pipelined: dispatch the
  # next prefill, then enqueue the previous batch's build behind it —
  # no block_until_ready until every stage of every batch is in flight.
  t0 = time.time()
  logits_per_batch, cache_per_batch = [], []
  if args.pipeline and mode == "synopsis":
    pending = None
    for bi in range(args.batches):
      lg, cache = prefill_fn(params, prompts[bi])         # async dispatch
      if pending is not None:
        cache_per_batch.append(build_fn(pending))         # overlaps prefill
      logits_per_batch.append(lg)
      pending = cache
    cache_per_batch.append(build_fn(pending))
    jax.block_until_ready((logits_per_batch, cache_per_batch))
  else:
    for bi in range(args.batches):
      lg, cache = prefill_fn(params, prompts[bi])
      if mode == "synopsis":
        cache = build_fn(cache)
      jax.block_until_ready((lg, cache))
      logits_per_batch.append(lg)
      cache_per_batch.append(cache)
  dt = time.time() - t0
  stages = "prefill+build" if mode == "synopsis" else "prefill"
  lane = "pipelined" if (args.pipeline and mode == "synopsis") else "serial"
  print(f"[{stages}] {args.batches} batch(es) x {S} tokens in {dt:.2f}s "
        f"({lane})")
  if mode == "synopsis":
    M = S // cfg.synopsis.cluster_size
    print(f"[synopsis] M={M} clusters of C={cfg.synopsis.cluster_size}")

  # The decode demo below consumes batch 0 only — drop the other
  # batches' caches so N full KV caches don't stay resident for the
  # whole generation loop.
  logits, cache = logits_per_batch[0], cache_per_batch[0]
  del logits_per_batch, cache_per_batch
  # --predictor applies here too (the demo loop's budget controller);
  # the affine default keeps the old demo calibration constants.
  pspec = args.predictor or "affine"
  pkw = {"base": 5.0, "slope": 1.0, "alpha": 0.1} \
      if pspec.startswith("affine") else {}
  ctrl = BudgetController(make_predictor(pspec, **pkw),
                          buckets=(0, 1, 2, 4, 8, 16, 32),
                          i_max_cap=cfg.synopsis.i_max or 32)

  steps = {}
  tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
  out_tokens = [tok]
  for i in range(args.tokens):
    budget = ctrl.budget_for(args.deadline_ms) if mode == "synopsis" else 0
    if (mode, budget) not in steps:
      steps[(mode, budget)] = jax.jit(
          make_serve_step(cfg, mode=mode, i_max=budget, impl=impl))
    t0 = time.time()
    logits, st = steps[(mode, budget)](params, cache, tok)
    jax.block_until_ready(logits)
    dt = (time.time() - t0) * 1e3
    if mode == "synopsis":
      ctrl.observe(budget, dt)
      cache = skv.append_recent(cache, st["k_delta"], st["v_delta"])
      cache["pos"] = st["pos"]
      if int(cache["recent_len"][0]) >= cfg.synopsis.recent:
        cache = jax.jit(lambda c: skv.absorb_recent(c, cfg, impl=impl))(
            cache)
        print(f"[update] absorbed recent buffer -> "
              f"M={cache['k_syn'].shape[4]}")
    else:
      cache["pos"] = st["pos"]
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    out_tokens.append(tok)
    print(f"[decode {i:3d}] budget={budget:3d} {dt:7.1f}ms")
  print("generated:", jnp.concatenate(out_tokens, 1)[0].tolist())


if __name__ == "__main__":
  main()
