"""Training driver: checkpoint/restart fault tolerance + elastic re-mesh.

On real hardware the mesh comes from the slice topology; on this host it
is whatever jax.devices() provides (run under
XLA_FLAGS=--xla_force_host_platform_device_count=N to emulate).
Restore is mesh-agnostic (checkpoints store logical axes), so restarting
on a different device count re-shards automatically — elastic scaling.

  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
      --smoke --steps 50 --batch 8 --seq 256
"""
from __future__ import annotations

import argparse
import time


def main():
  ap = argparse.ArgumentParser()
  ap.add_argument("--arch", default="smollm-135m")
  ap.add_argument("--smoke", action="store_true")
  ap.add_argument("--steps", type=int, default=100)
  ap.add_argument("--batch", type=int, default=8)
  ap.add_argument("--seq", type=int, default=256)
  ap.add_argument("--microbatches", type=int, default=1)
  ap.add_argument("--lr", type=float, default=3e-4)
  ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
  ap.add_argument("--ckpt-every", type=int, default=25)
  args = ap.parse_args()

  import jax
  import jax.numpy as jnp

  from repro.configs.registry import get_config
  from repro.dist import sharding as shd
  from repro.train import checkpoint as ck
  from repro.train.data import DataConfig, TokenStream
  from repro.train.optimizer import OptConfig
  from repro.train.train_step import init_train_state, make_train_step

  cfg = get_config(args.arch, smoke=args.smoke)
  opt_cfg = OptConfig(lr=args.lr, total_steps=args.steps)
  key = jax.random.PRNGKey(0)
  state, state_axes = init_train_state(key, cfg, opt_cfg)
  data = TokenStream(DataConfig(cfg.vocab, args.seq, args.batch))

  start = 0
  if ck.latest_step(args.ckpt_dir) is not None:
    # Elastic restart: leaves re-shard onto the *current* device set.
    state, start, extras = ck.restore(args.ckpt_dir)
    data.load_state_dict(extras.get("data", {"step": start, "seed": 0}))
    print(f"[restore] resumed at step {start} on "
          f"{jax.device_count()} devices")

  step_fn = jax.jit(make_train_step(cfg, opt_cfg,
                                    microbatches=args.microbatches))
  saver = ck.AsyncCheckpointer()
  t0 = time.time()
  for step in range(start, args.steps):
    tokens, labels = data.batch_at(step)
    state, m = step_fn(state, {"tokens": jnp.asarray(tokens),
                               "labels": jnp.asarray(labels)})
    if step % 10 == 0 or step == args.steps - 1:
      print(f"step {step:5d} loss {float(m['loss']):.4f} "
            f"gnorm {float(m['grad_norm']):.2f} "
            f"({time.time() - t0:.1f}s)", flush=True)
    if step and step % args.ckpt_every == 0:
      saver.save_async(args.ckpt_dir, step, state,
                       extras={"data": data.state_dict()})
  saver.wait()
  print("done")


if __name__ == "__main__":
  main()
