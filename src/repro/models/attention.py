"""Attention blocks: GQA (+rope, softcap, sliding window) and MLA.

Train/prefill paths produce full-sequence outputs (chunked causal
attention).  Decode paths live in ``repro.serve`` (they need KV caches);
this module also exposes the projection helpers they reuse.

MLA (deepseek-v2): the latent KV cache *is itself a learned synopsis* —
decode uses the absorbed form where the per-token cache is just
(kv_lora + rope) dims shared by all 128 heads, i.e. attention becomes GQA
with one 576-wide "kv head"; AccuracyTrader's cluster synopsis then stacks
on top of the latent cache.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import constrain
from repro.kernels import ops
from repro.models import common as cm
from repro.models.layers import causal_attention, einsum, proj_pe, rope


def causal_mix(q, k, v, *, sm_scale, window=None, cap=None,
               causal_skip=False, impl: Optional[str] = None):
  """Causal self-attention dispatch for full-sequence (train/prefill)
  passes.  ``impl=None`` (training) keeps the remat'd chunked XLA scan of
  ``layers.causal_attention`` — it has the memory-cheap backward.  A
  concrete ``impl`` (the forward-only prefill step) routes through
  ``kernels.ops.prefill_attention``: flash-tiled Pallas on TPU /
  interpret, or the chunked XLA reference (DESIGN.md §6)."""
  if impl is not None:
    return ops.prefill_attention(q, k, v, sm_scale=sm_scale, cap=cap,
                                 window=window, impl=impl).astype(q.dtype)
  return causal_attention(q, k, v, sm_scale=sm_scale, window=window,
                          attn_softcap=cap, causal_skip=causal_skip)


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------

def init_attention(key, cfg: cm.ModelConfig, cross: bool = False) -> dict:
  d, H, Hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
  ks = jax.random.split(key, 4)
  p = {
      "wq": cm.param(ks[0], (d, H, hd), ("embed", "heads", None)),
      "wk": cm.param(ks[1], (d, Hkv, hd), ("embed", "kv_heads", None)),
      "wv": cm.param(ks[2], (d, Hkv, hd), ("embed", "kv_heads", None)),
      "wo": cm.param(ks[3], (H, hd, d), ("heads", None, "embed"),
                     scale=(H * hd) ** -0.5),
  }
  if cfg.attn_bias:
    p["bq"] = cm.zeros((H, hd), ("heads", None))
    p["bo"] = cm.zeros((d,), ("embed",))
  return p


def qkv(x, p, cfg: cm.ModelConfig, positions, *, use_rope=True):
  # bf16-out projections: keeps fwd partial-sum ARs *and* their backward
  # dx all-reduces in bf16 (cotangent dtype follows the primal output).
  pe = dict(preferred_element_type=proj_pe(x))
  q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype),
                 **pe).astype(x.dtype)
  k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype),
                 **pe).astype(x.dtype)
  v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype),
                 **pe).astype(x.dtype)
  if "bq" in p:
    q = q + p["bq"][None, None].astype(x.dtype)
  if use_rope:
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
  q = constrain(q, ("batch", None, "heads", None))
  k = constrain(k, ("batch", None, "kv_heads", None))
  return q, k, v


def out_proj(o, p, x_dtype):
  # bf16 output so the TP (heads-sharded) all-reduce moves bf16.
  y = jnp.einsum("bshk,hkd->bsd", o.astype(x_dtype),
                 p["wo"].astype(x_dtype),
                 preferred_element_type=proj_pe(o)
                 if o.dtype == x_dtype else jnp.float32)
  if "bo" in p:
    y = y + p["bo"][None, None].astype(x_dtype)
  return y.astype(x_dtype)


def attention_train(
    x: jax.Array,              # (B, S, d)
    p: dict,
    cfg: cm.ModelConfig,
    positions: jax.Array,      # (S,)
    *,
    local: bool = False,
    enc_out: Optional[jax.Array] = None,   # cross-attention source (B,T,d)
    causal_skip: bool = False,
    return_kv: bool = False,
    impl: Optional[str] = None,
):
  sm_scale = cfg.hd ** -0.5
  if enc_out is not None:
    # Cross attention (whisper decoder): full, non-causal, no rope.
    q = einsum("bsd,dhk->bshk", x, p["wq"]).astype(x.dtype)
    k = einsum("btd,dhk->bthk", enc_out, p["wk"]).astype(x.dtype)
    v = einsum("btd,dhk->bthk", enc_out, p["wv"]).astype(x.dtype)
    B, S, H, D = q.shape
    G = H // cfg.n_kv_heads
    qg = q.reshape(B, S, cfg.n_kv_heads, G, D)
    logits = einsum("bshgd,bthd->bhgst", qg.astype(jnp.float32),
                    k.astype(jnp.float32)) * sm_scale
    pr = jax.nn.softmax(logits, axis=-1)
    o = einsum("bhgst,bthd->bshgd", pr, v.astype(jnp.float32))
    o = o.reshape(B, S, H, D).astype(x.dtype)
  else:
    q, k, v = qkv(x, p, cfg, positions)
    o = causal_mix(
        q, k, v, sm_scale=sm_scale,
        window=cfg.sliding_window if local else None,
        cap=cfg.attn_softcap,
        causal_skip=causal_skip, impl=impl)
  y = out_proj(o, p, x.dtype)
  if return_kv:
    # (B, Hkv, S, D) decode-cache layout.
    return y, (jnp.moveaxis(k, 1, 2), jnp.moveaxis(v, 1, 2))
  return y


# ---------------------------------------------------------------------------
# MLA (deepseek-v2)
# ---------------------------------------------------------------------------

def init_mla(key, cfg: cm.ModelConfig) -> dict:
  m = cfg.mla
  d, H = cfg.d_model, cfg.n_heads
  ks = jax.random.split(key, 8)
  return {
      "wq_a": cm.param(ks[0], (d, m.q_lora_rank), ("embed", "qlora")),
      "q_norm": cm.zeros((m.q_lora_rank,), ("qlora",)),
      "wq_b": cm.param(ks[1], (m.q_lora_rank, H, m.qk_nope_dim + m.qk_rope_dim),
                       ("qlora", "heads", None)),
      "wkv_a": cm.param(ks[2], (d, m.kv_lora_rank + m.qk_rope_dim),
                        ("embed", "kvlora")),
      "kv_norm": cm.zeros((m.kv_lora_rank,), ("kvlora",)),
      "wk_b": cm.param(ks[3], (m.kv_lora_rank, H, m.qk_nope_dim),
                       ("kvlora", "heads", None)),
      "wv_b": cm.param(ks[4], (m.kv_lora_rank, H, m.v_head_dim),
                       ("kvlora", "heads", None)),
      "wo": cm.param(ks[5], (H, m.v_head_dim, d), ("heads", None, "embed"),
                     scale=(H * m.v_head_dim) ** -0.5),
  }


def mla_latent(x, p, cfg, positions):
  """Compute the latent KV cache entries: (c_kv (B,S,r), k_pe (B,S,dr))."""
  m = cfg.mla
  kv = jnp.einsum("bsd,dk->bsk", x, p["wkv_a"].astype(x.dtype),
                  preferred_element_type=proj_pe(x)).astype(x.dtype)
  c_kv, k_pe = kv[..., :m.kv_lora_rank], kv[..., m.kv_lora_rank:]
  from repro.models.layers import rms_norm  # noqa: PLC0415
  c_kv = rms_norm(c_kv, p["kv_norm"], cfg.norm_eps)
  k_pe = rope(k_pe[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
  return c_kv, k_pe


def mla_queries(x, p, cfg, positions):
  """(q_nope (B,S,H,dn), q_pe (B,S,H,dr))."""
  m = cfg.mla
  from repro.models.layers import rms_norm  # noqa: PLC0415
  ql = jnp.einsum("bsd,dr->bsr", x, p["wq_a"].astype(x.dtype),
                  preferred_element_type=proj_pe(x)).astype(x.dtype)
  ql = rms_norm(ql, p["q_norm"], cfg.norm_eps)
  q = jnp.einsum("bsr,rhk->bshk", ql, p["wq_b"].astype(x.dtype),
                 preferred_element_type=proj_pe(x)).astype(x.dtype)
  q_nope, q_pe = q[..., :m.qk_nope_dim], q[..., m.qk_nope_dim:]
  q_pe = rope(q_pe, positions, cfg.rope_theta)
  return q_nope, q_pe


def mla_train(x, p, cfg: cm.ModelConfig, positions,
              causal_skip: bool = False, return_kv: bool = False,
              impl: Optional[str] = None):
  """Naive (non-absorbed) MLA for training: materialise per-head k/v."""
  m = cfg.mla
  q_nope, q_pe = mla_queries(x, p, cfg, positions)
  c_kv, k_pe = mla_latent(x, p, cfg, positions)
  k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, p["wk_b"].astype(x.dtype),
                      preferred_element_type=proj_pe(x)).astype(x.dtype)
  v = jnp.einsum("bsr,rhk->bshk", c_kv, p["wv_b"].astype(x.dtype),
                 preferred_element_type=proj_pe(x)).astype(x.dtype)
  q = jnp.concatenate([q_nope, q_pe], axis=-1)
  k = jnp.concatenate(
      [k_nope, jnp.broadcast_to(k_pe[:, :, None], q_pe.shape[:2]
                                + (cfg.n_heads, m.qk_rope_dim))], axis=-1)
  sm_scale = (m.qk_nope_dim + m.qk_rope_dim) ** -0.5
  # Pad v to q/k head dim for the shared kernel, then slice back.
  o = causal_mix(q, k, v_pad(v, q.shape[-1]), sm_scale=sm_scale,
                 causal_skip=causal_skip, impl=impl)[..., :m.v_head_dim]
  y = jnp.einsum("bshk,hkd->bsd", o.astype(x.dtype),
                 p["wo"].astype(x.dtype),
                 preferred_element_type=proj_pe(x)).astype(x.dtype)
  if return_kv:
    # MLA latent cache: one 'kv head' of width kv_lora + rope.
    lat = jnp.transpose(jnp.concatenate([c_kv, k_pe], axis=-1)[:, :, None],
                        (0, 2, 1, 3))                      # (B,1,S,Dk)
    return y, (lat, lat)
  return y


def v_pad(v, dim):
  if v.shape[-1] == dim:
    return v
  pad = [(0, 0)] * (v.ndim - 1) + [(0, dim - v.shape[-1])]
  return jnp.pad(v, pad)
