"""Model substrate: configs, parameter trees with logical sharding axes.

Parameters are plain nested dicts of arrays.  Every init function builds a
tree whose leaves are :class:`Box` (value + logical axes); ``split`` turns
it into (params, axes) twin trees.  The axes tree drives NamedShardings
(dist/sharding.py) and mesh-agnostic checkpoints.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class Box:
  value: Any                      # jax.Array | ShapeDtypeStruct
  axes: Tuple[Optional[str], ...]


def is_box(x) -> bool:
  return isinstance(x, Box)


def split(tree):
  params = jax.tree.map(lambda b: b.value, tree, is_leaf=is_box)
  axes = jax.tree.map(lambda b: b.axes, tree, is_leaf=is_box)
  return params, axes


def box_like(params, axes):
  return jax.tree.map(Box, params, axes,
                      is_leaf=lambda x: not isinstance(x, dict))


def param(key, shape, axes, scale=None, dtype=jnp.float32):
  """Truncated-normal init with 1/sqrt(fan_in) default scale."""
  if scale is None:
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    scale = fan_in ** -0.5
  return Box(scale * jax.random.truncated_normal(key, -2, 2, shape, dtype),
             axes)


def zeros(shape, axes, dtype=jnp.float32):
  return Box(jnp.zeros(shape, dtype), axes)


def ones(shape, axes, dtype=jnp.float32):
  return Box(jnp.ones(shape, dtype), axes)


# ---------------------------------------------------------------------------
# Configs
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LayerSpec:
  kind: str = "attn"              # "attn" | "mamba"
  local: bool = False             # sliding-window attention (gemma2)
  use_moe: bool = False
  cross_attn: bool = False        # whisper decoder


@dataclasses.dataclass(frozen=True)
class MoEConfig:
  num_experts: int
  top_k: int
  d_ff_expert: int
  num_shared: int = 0             # always-on shared experts (deepseek)
  dense_parallel: bool = False    # dense MLP residual in parallel (arctic)
  capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class MLAConfig:
  q_lora_rank: int = 1536
  kv_lora_rank: int = 512
  qk_nope_dim: int = 128
  qk_rope_dim: int = 64
  v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
  d_state: int = 128
  d_conv: int = 4
  expand: int = 2
  head_dim: int = 64
  chunk: int = 128


@dataclasses.dataclass(frozen=True)
class SynopsisConfig:
  """AccuracyTrader serving config for this model."""
  cluster_size: int = 128         # C: original tokens per aggregated point
  i_max: int = 32                 # default refinement budget (clusters)
  recent: int = 128               # exact-attention ring buffer (new tokens)
  # Decode-attention implementation: "auto" resolves to the fused Pallas
  # kernel suite on TPU and the XLA reference path elsewhere; "interpret"
  # runs the Pallas kernels under the interpreter (CPU validation).
  impl: str = "auto"              # "auto" | "pallas" | "xla" | "interpret"
  # Quantized synopsis (DESIGN.md §15): "none" keeps the bit-identical
  # f32/native arena; "int8"/"fp8" quantize k_syn/v_syn with per-centroid
  # scales; the "+kv" variants also quantize the sorted corpus KV with
  # per-cluster-block scales.
  quant: str = "none"             # "none"|"int8"|"fp8"|"int8+kv"|"fp8+kv"


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
  n_layers: int
  n_heads: int
  d_ff: int
  source_len: int = 1500          # whisper: 30 s of 20 ms frames


@dataclasses.dataclass(frozen=True)
class ModelConfig:
  name: str
  n_layers: int
  d_model: int
  n_heads: int
  n_kv_heads: int
  d_ff: int
  vocab: int
  head_dim: int = 0               # 0 -> d_model // n_heads
  rope_theta: float = 1e4
  norm_eps: float = 1e-6
  block_pattern: Tuple[LayerSpec, ...] = (LayerSpec(),)
  sliding_window: int = 4096
  logit_softcap: Optional[float] = None   # gemma2 final-logit softcap
  attn_softcap: Optional[float] = None    # gemma2 attention softcap
  parallel_block: bool = False            # attn + ffn in parallel (command-r)
  sandwich_norm: bool = False             # post-block norms (gemma2)
  scale_embed: bool = False               # sqrt(d) embedding scale (gemma2/whisper-style)
  mlp_type: str = "swiglu"                # "swiglu" | "gelu" (whisper)
  tie_embeddings: bool = False
  attn_bias: bool = False
  moe: Optional[MoEConfig] = None
  mla: Optional[MLAConfig] = None
  ssm: Optional[SSMConfig] = None
  encoder: Optional[EncoderConfig] = None  # whisper
  frontend: Optional[str] = None           # "audio_stub" | "vision_stub"
  frontend_tokens: int = 0                 # prefix tokens from the frontend
  frontend_dim: int = 0                    # stub embedding dim
  synopsis: SynopsisConfig = SynopsisConfig()
  dtype: Any = jnp.bfloat16

  @property
  def hd(self) -> int:
    return self.head_dim or self.d_model // self.n_heads

  @property
  def n_blocks(self) -> int:
    assert self.n_layers % len(self.block_pattern) == 0, (
        self.name, self.n_layers, len(self.block_pattern))
    return self.n_layers // len(self.block_pattern)

  def param_count(self, active: bool = False) -> int:
    """Approximate parameters; ``active=True`` counts only routed-active
    experts (for the MoE 6*N_active*D roofline MODEL_FLOPS)."""
    c = self
    total = c.vocab * c.d_model * (1 if c.tie_embeddings else 2)
    for spec in c.block_pattern:
      per = 0
      if spec.kind == "attn":
        if c.mla:
          m = c.mla
          qk = m.qk_nope_dim + m.qk_rope_dim
          per += c.d_model * m.q_lora_rank + m.q_lora_rank * c.n_heads * qk
          per += c.d_model * (m.kv_lora_rank + m.qk_rope_dim)
          per += m.kv_lora_rank * c.n_heads * (m.qk_nope_dim + m.v_head_dim)
          per += c.n_heads * m.v_head_dim * c.d_model
        else:
          per += c.d_model * c.hd * (c.n_heads * 2 + c.n_kv_heads * 2)
        if spec.cross_attn:
          per += c.d_model * c.hd * (c.n_heads * 2 + c.n_kv_heads * 2)
      else:
        s = c.ssm
        d_in = s.expand * c.d_model
        per += c.d_model * (2 * d_in + 2 * s.d_state) + d_in * c.d_model
      if spec.use_moe and c.moe:
        e = c.moe
        per += c.d_model * e.num_experts  # router
        n_ffn = (e.top_k if active else e.num_experts) + e.num_shared
        per += 3 * c.d_model * e.d_ff_expert * n_ffn
        if e.dense_parallel:
          per += 3 * c.d_model * c.d_ff
      elif c.d_ff:
        per += 3 * c.d_model * c.d_ff
      total += per * c.n_blocks
    if c.encoder:
      e = c.encoder
      per = c.d_model * (c.d_model // max(c.n_heads, 1)) * e.n_heads * 4
      per += 3 * c.d_model * e.d_ff
      total += per * e.n_layers
    return total
