"""Shared neural building blocks (pure JAX, GSPMD-partitionable).

All matmuls go through einsum with f32 accumulation
(``preferred_element_type``); activations carry logical sharding
constraints so pjit can partition train/prefill without shard_map.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import constrain
from repro.models import common as cm


def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
  dt = x.dtype
  x = x.astype(jnp.float32)
  x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
  return (x * (1.0 + w.astype(jnp.float32))).astype(dt)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
  """Rotary embedding.  x (..., S, H, D), positions (..., S)."""
  d = x.shape[-1]
  half = d // 2
  freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
  ang = positions[..., None].astype(jnp.float32) * freq        # (..., S, half)
  cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
  x1, x2 = x[..., :half], x[..., half:]
  out = jnp.concatenate(
      [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
  return out.astype(x.dtype)


def softcap(x: jax.Array, cap: Optional[float]) -> jax.Array:
  if cap is None:
    return x
  return cap * jnp.tanh(x / cap)


def einsum(eq: str, *args) -> jax.Array:
  return jnp.einsum(eq, *args, preferred_element_type=jnp.float32)


def proj_pe(x) -> "jnp.dtype":
  """Output dtype for projection einsums.  bf16 keeps the TP all-reduces
  (and their backward cotangents) in bf16 — the TPU-target lowering used
  by the dry-run (REPRO_MIXED_DOTS=1).  The CPU runtime cannot execute
  mixed bf16 dots (DotThunk limitation), so tests/examples default to
  f32 accumulation; the math is identical up to rounding."""
  import os  # noqa: PLC0415
  if os.environ.get("REPRO_MIXED_DOTS") == "1":
    return x.dtype
  return jnp.float32


# ---------------------------------------------------------------------------
# Attention (training / prefill): chunked causal, memory O(S * q_chunk).
# ---------------------------------------------------------------------------

def causal_attention(
    q: jax.Array,              # (B, S, H, D)
    k: jax.Array,              # (B, S, Hkv, D)
    v: jax.Array,              # (B, S, Hkv, D)
    *,
    sm_scale: float,
    window: Optional[int] = None,      # sliding window (gemma2 local)
    attn_softcap: Optional[float] = None,
    q_chunk: int = 512,
    causal_skip: bool = False,          # skip fully-masked KV chunks
) -> jax.Array:
  """Blockwise causal attention: scan over query chunks, never materialise
  the full S x S matrix.  ``causal_skip`` additionally restricts each query
  chunk's KV range to [lo, hi) — the beyond-paper compute optimisation
  (halves attention FLOPs; see EXPERIMENTS.md §Perf)."""
  B, S, H, D = q.shape
  Hkv = k.shape[2]
  G = H // Hkv
  q_chunk = min(q_chunk, S)
  assert S % q_chunk == 0
  nq = S // q_chunk

  qg = q.reshape(B, S, Hkv, G, D)
  pos = jnp.arange(S)

  def one_chunk(i):
    qi = jax.lax.dynamic_slice_in_dim(qg, i * q_chunk, q_chunk, axis=1)
    qpos = i * q_chunk + jnp.arange(q_chunk)
    if causal_skip:
      # keys in [lo, hi): hi = (i+1)*q_chunk; lo = window clip (static size).
      hi = (i + 1) * q_chunk
      if window is not None:
        span = min(S, ((window + q_chunk - 1) // q_chunk + 1) * q_chunk)
      else:
        span = S
      lo = jnp.maximum(hi - span, 0)
      ki = jax.lax.dynamic_slice_in_dim(k, lo, span, axis=1)
      vi = jax.lax.dynamic_slice_in_dim(v, lo, span, axis=1)
      kpos = lo + jnp.arange(span)
    else:
      ki, vi, kpos = k, v, pos
    logits = einsum("bqhgd,bkhd->bhgqk", qi.astype(jnp.float32),
                    ki.astype(jnp.float32)) * sm_scale
    logits = softcap(logits, attn_softcap)
    mask = qpos[:, None] >= kpos[None, :]
    if window is not None:
      mask &= (qpos[:, None] - kpos[None, :]) < window
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    oi = einsum("bhgqk,bkhd->bqhgd", p, vi.astype(jnp.float32))
    return oi.reshape(B, q_chunk, H, D).astype(q.dtype)

  if nq == 1:
    return one_chunk(0)
  # Remat per q-chunk: the backward pass re-derives each chunk's softmax
  # instead of keeping (B, H, S, S)-worth of residuals live.
  chunks = jax.lax.map(jax.checkpoint(one_chunk),
                       jnp.arange(nq))              # (nq, B, qc, H, D)
  return jnp.moveaxis(chunks, 0, 1).reshape(B, S, H, D)


def decode_attention(
    q: jax.Array,              # (B, 1, H, D) new-token queries
    k_cache: jax.Array,        # (B, S, Hkv, D)
    v_cache: jax.Array,        # (B, S, Hkv, D)
    *,
    sm_scale: float,
    length_bias: Optional[jax.Array] = None,   # (B, S) 0/-inf valid mask
    attn_softcap: Optional[float] = None,
) -> jax.Array:
  """Exact decode attention (GSPMD path: XLA partitions the S reduction
  when the cache is kv_seq-sharded; the softmax max/sum become
  all-reduces — the paper's n-component scatter-gather merge)."""
  B, _, H, D = q.shape
  Hkv = k_cache.shape[2]
  G = H // Hkv
  qg = q.reshape(B, Hkv, G, D)
  logits = einsum("bhgd,bshd->bhgs", qg.astype(jnp.float32),
                  k_cache.astype(jnp.float32)) * sm_scale
  logits = softcap(logits, attn_softcap)
  if length_bias is not None:
    logits = logits + length_bias[:, None, None, :]
  p = jax.nn.softmax(logits, axis=-1)
  o = einsum("bhgs,bshd->bhgd", p, v_cache.astype(jnp.float32))
  return o.reshape(B, 1, H, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# FFN
# ---------------------------------------------------------------------------

def swiglu(x: jax.Array, w1, w3, w2) -> jax.Array:
  pe = dict(preferred_element_type=proj_pe(x))
  h = jnp.einsum("bsd,df->bsf", x, w1.astype(x.dtype), **pe)
  g = jnp.einsum("bsd,df->bsf", x, w3.astype(x.dtype), **pe)
  h = (jax.nn.silu(h.astype(jnp.float32)) * g.astype(jnp.float32))
  h = constrain(h.astype(x.dtype), ("batch", None, "ff"))
  # Row-parallel projection: emit in the activation dtype so the TP
  # partial-sum all-reduce moves bf16, not f32 (halves collective bytes;
  # EXPERIMENTS.md §Perf).
  return jnp.einsum("bsf,fd->bsd", h, w2.astype(x.dtype),
                    preferred_element_type=proj_pe(x)).astype(x.dtype)


def gelu_mlp(x: jax.Array, w1, b1, w2, b2) -> jax.Array:
  h = jnp.einsum("bsd,df->bsf", x, w1.astype(x.dtype),
                 preferred_element_type=proj_pe(x)).astype(x.dtype) \
      + b1.astype(x.dtype)
  h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
  return (jnp.einsum("bsf,fd->bsd", h, w2.astype(x.dtype),
                     preferred_element_type=proj_pe(x)).astype(x.dtype)
          + b2.astype(x.dtype)).astype(x.dtype)
