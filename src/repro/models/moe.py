"""Mixture-of-Experts with token-choice routing + per-expert capacity.

Routing semantics: every token picks its top-k experts; every expert then
keeps its top-``capacity`` routed tokens (standard dropping), selected
*per data-parallel shard*.  The DP-locality is expressed by reshaping the
token stream to an explicit leading ``(dp, tokens/dp)`` dim that carries
the (pod, data) sharding: routing, top-C selection, gather and combine
all become batched ops over that parallel dim, so GSPMD never needs to
all-gather the token stream; expert weights/compute shard over `model`
(EP) and the combine scatter-add is the layer's model-axis all-reduce.

(An earlier shard_map formulation hit an XLA:CPU partial-auto bug inside
scanned layers; this reshape formulation is equivalent and pure GSPMD.)

Shared experts (deepseek-v2) and a parallel dense MLP (arctic) are folded
in at the call site.  Decode works with S=1 (capacity >= 1 guaranteed).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.sharding import constrain
from repro.models import common as cm
from repro.models.layers import einsum, proj_pe, swiglu


def init_moe(key, cfg: cm.ModelConfig) -> dict:
  m = cfg.moe
  d, f, e = cfg.d_model, m.d_ff_expert, m.num_experts
  ks = jax.random.split(key, 8)
  p = {
      "router": cm.param(ks[0], (d, e), ("embed", "expert")),
      "w1": cm.param(ks[1], (e, d, f), ("expert", "embed", "ff"), d ** -0.5),
      "w3": cm.param(ks[2], (e, d, f), ("expert", "embed", "ff"), d ** -0.5),
      "w2": cm.param(ks[3], (e, f, d), ("expert", "ff", "embed"), f ** -0.5),
  }
  if m.num_shared:
    fs = f * m.num_shared
    p["shared"] = {
        "w1": cm.param(ks[4], (d, fs), ("embed", "ff")),
        "w3": cm.param(ks[5], (d, fs), ("embed", "ff")),
        "w2": cm.param(ks[6], (fs, d), ("ff", "embed")),
    }
  return p


def _dp_size(B: int) -> int:
  from repro.dist import sharding as shd  # noqa: PLC0415
  mesh = shd.current_mesh()
  if mesh is None:
    return 1
  n = 1
  for a in ("pod", "data"):
    n *= mesh.shape.get(a, 1)
  return n if n > 1 and B % n == 0 else 1


def moe_ffn(x: jax.Array, p: dict,
            cfg: cm.ModelConfig) -> Tuple[jax.Array, jax.Array]:
  """Returns (output (B,S,d), aux load-balance loss)."""
  m = cfg.moe
  B, S, d = x.shape
  T = B * S
  E, K = m.num_experts, m.top_k
  g = _dp_size(B)                                # DP shards
  Tl = T // g                                    # local tokens per shard
  xf = x.reshape(g, Tl, d)
  xf = constrain(xf, ("batch", None, None))

  logits = einsum("gtd,de->gte", xf, p["router"])          # f32
  probs = jax.nn.softmax(logits, axis=-1)
  topv, topi = jax.lax.top_k(probs, K)                     # (g,Tl,K)
  topv = topv / jnp.maximum(jnp.sum(topv, axis=-1, keepdims=True), 1e-9)
  in_topk = jnp.zeros((g, Tl, E), topv.dtype)
  gi = jnp.arange(g)[:, None, None]
  ti = jnp.arange(Tl)[None, :, None]
  in_topk = in_topk.at[gi, ti, topi].set(topv)             # gate or 0

  # Load-balance aux loss (Switch-style): E * sum_e f_e * p_e.
  frac_routed = jnp.mean((in_topk > 0).astype(jnp.float32), axis=(0, 1))
  mean_prob = jnp.mean(probs, axis=(0, 1))
  aux = E * jnp.sum(frac_routed * mean_prob)

  cap = max(1, int(Tl * K / E * m.capacity_factor))
  # Expert-side top-C token selection per DP shard.
  masked = jnp.where(in_topk > 0, in_topk, -1.0)
  masked = jnp.swapaxes(masked, 1, 2)                      # (g,E,Tl)
  masked = constrain(masked, ("batch", "expert", None))
  gate_ec, tok_ec = jax.lax.top_k(masked, cap)             # (g,E,C)
  keep = gate_ec > 0
  gate_ec = jnp.where(keep, gate_ec, 0.0)

  xg = jnp.take_along_axis(
      xf[:, None], tok_ec[..., None], axis=2)              # (g,E,C,d)
  xg = constrain(xg, ("batch", "expert", None, None))
  # proj_pe: bf16 batched dots on TPU (mixed mode); f32 on the CPU
  # runtime, whose DotThunk lacks batched bf16->f32.
  pe = proj_pe(x)
  xg = xg.astype(pe)
  h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xg, p["w1"].astype(pe),
                             preferred_element_type=pe))
  h = h * jnp.einsum("gecd,edf->gecf", xg, p["w3"].astype(pe),
                     preferred_element_type=pe)
  h = constrain(h, ("batch", "expert", None, "ff"))
  y_ec = jnp.einsum("gecf,efd->gecd", h.astype(pe), p["w2"].astype(pe),
                    preferred_element_type=pe
                    ).astype(x.dtype)                        # (g,E,C,d)
  y_ec = y_ec * gate_ec[..., None].astype(x.dtype)

  def combine(tok, y):
    # tok (E,C) indices into Tl; y (E,C,d) — bf16 combine so the EP
    # all-reduce moves bf16
    return jnp.zeros((Tl, d), x.dtype).at[tok.reshape(-1)].add(
        y.reshape(-1, d))

  yf = jax.vmap(combine)(tok_ec, y_ec)                     # (g,Tl,d)
  y = yf.reshape(B, S, d).astype(x.dtype)
  y = constrain(y, ("batch", None, None))

  if m.num_shared:
    s = p["shared"]
    y = y + swiglu(x, s["w1"], s["w3"], s["w2"])
  return y, aux
