"""Mamba-2 SSD (state-space duality, arXiv:2405.21060) sequence mixer.

Training/prefill uses the chunked SSD algorithm (quadratic within chunks of
length ``chunk``, linear state passing between chunks — MXU-dense einsums
plus one small scan).  Decode is the O(1)-state recurrence, which is what
makes `long_500k` native for mamba2/jamba.

Layout: d_inner = expand * d_model; h = d_inner/head_dim heads ("ssm_heads"
sharded over `model`), state n per head.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import constrain
from repro.models import common as cm
from repro.models.layers import einsum, rms_norm


def init_ssm(key, cfg: cm.ModelConfig) -> dict:
  s = cfg.ssm
  d = cfg.d_model
  d_in = s.expand * d
  h = d_in // s.head_dim
  ks = jax.random.split(key, 8)
  conv_dim = d_in + 2 * s.d_state
  return {
      # projections: [z, x, B, C, dt]
      "in_proj": cm.param(ks[0], (d, 2 * d_in + 2 * s.d_state + h),
                          ("embed", "ssm_heads")),
      "conv_w": cm.param(ks[1], (s.d_conv, conv_dim), (None, "ssm_heads"),
                         scale=0.5),
      "conv_b": cm.zeros((conv_dim,), ("ssm_heads",)),
      "A_log": cm.Box(jnp.log(jnp.linspace(1.0, 16.0, h)), ("ssm_heads",)),
      "D": cm.ones((h,), ("ssm_heads",)),
      "dt_bias": cm.zeros((h,), ("ssm_heads",)),
      "norm": cm.zeros((d_in,), ("ssm_heads",)),
      "out_proj": cm.param(ks[2], (d_in, d), ("ssm_heads", "embed")),
  }


def _split_proj(zxbcdt, cfg):
  s = cfg.ssm
  d_in = s.expand * cfg.d_model
  h = d_in // s.head_dim
  z, x, Bs, Cs, dt = jnp.split(
      zxbcdt, [d_in, 2 * d_in, 2 * d_in + s.d_state,
               2 * d_in + 2 * s.d_state], axis=-1)
  return z, x, Bs, Cs, dt, d_in, h


def _causal_conv(u: jax.Array, w: jax.Array, b: jax.Array,
                 state: Optional[jax.Array] = None):
  """Depthwise causal conv1d.  u (B,S,C), w (K,C).  Returns (y, new_state)
  where state is the last K-1 inputs (for decode)."""
  K = w.shape[0]
  if state is None:
    pad = jnp.zeros((u.shape[0], K - 1, u.shape[2]), u.dtype)
  else:
    pad = state
  ext = jnp.concatenate([pad, u], axis=1)                  # (B, S+K-1, C)
  y = sum(ext[:, i:i + u.shape[1]] * w[i][None, None] for i in range(K))
  y = y + b[None, None]
  new_state = ext[:, -(K - 1):]
  return jax.nn.silu(y), new_state


def ssd_chunked(x, dt, A, Bs, Cs, chunk: int):
  """Chunked SSD scan.  x (b,s,h,p), dt (b,s,h) [post-softplus],
  A (h,) [negative], Bs/Cs (b,s,n).  Returns y (b,s,h,p), final state
  (b,h,p,n)."""
  b, s, h, p = x.shape
  n = Bs.shape[-1]
  L = min(chunk, s)
  assert s % L == 0
  nc = s // L
  xc = x.reshape(b, nc, L, h, p)
  dtc = dt.reshape(b, nc, L, h)
  Bc = Bs.reshape(b, nc, L, n)
  Cc = Cs.reshape(b, nc, L, n)

  dA = dtc * A[None, None, None]                           # (b,nc,L,h) <= 0
  cum = jnp.cumsum(dA, axis=2)                             # within-chunk
  total = cum[:, :, -1]                                    # (b,nc,h)

  # Intra-chunk (quadratic in L): y_ij = C_i . B_j * exp(cum_i - cum_j) dt_j
  # Mask INSIDE the exponent: future pairs have positive exponents whose
  # exp() overflows and poisons the backward through the where (NaN grads).
  diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]      # (b,nc,L,L,h)
  mask = jnp.tril(jnp.ones((L, L), bool))
  decay = jnp.exp(jnp.where(mask[None, None, :, :, None], diff, -jnp.inf))
  cb = einsum("bcin,bcjn->bcij", Cc, Bc)                   # (b,nc,L,L)
  w = cb[..., None] * decay * dtc[:, :, None]              # (b,nc,L,L,h)
  y_intra = einsum("bcijh,bcjhp->bcihp", w, xc)

  # Chunk states: S_c = sum_j exp(total - cum_j) dt_j B_j x_j
  sdec = jnp.exp(total[:, :, None] - cum)                  # (b,nc,L,h)
  states = einsum("bcln,bclh,bclhp->bchpn",
                  Bc, sdec * dtc, xc)                      # (b,nc,h,p,n)

  # Inter-chunk recurrence: S_prev[c] = sum_{c'<c} exp(sum totals) S_{c'}
  def scan_fn(carry, inp):
    st, tot = inp                                          # (b,h,p,n),(b,h)
    prev = carry
    new = prev * jnp.exp(tot)[:, :, None, None] + st
    return new, prev
  init = jnp.zeros((b, h, p, n), jnp.float32)
  final, prevs = jax.lax.scan(
      scan_fn, init,
      (jnp.moveaxis(states, 1, 0), jnp.moveaxis(total, 1, 0)))
  prevs = jnp.moveaxis(prevs, 0, 1)                        # (b,nc,h,p,n)

  y_inter = einsum("bcln,bclh,bchpn->bclhp", Cc, jnp.exp(cum), prevs)
  y = (y_intra + y_inter).reshape(b, s, h, p)
  return y, final


def ssm_forward(
    x: jax.Array,              # (B, S, d)
    p: dict,
    cfg: cm.ModelConfig,
    *,
    decode_state: Optional[Tuple[jax.Array, jax.Array]] = None,
):
  """Returns (y (B,S,d), new_decode_state).  decode_state = (conv_state,
  ssd_state); pass it for S==1 incremental decoding."""
  s = cfg.ssm
  zxbcdt = einsum("bsd,dk->bsk", x, p["in_proj"]).astype(x.dtype)
  z, xin, Bs, Cs, dt, d_in, h = _split_proj(zxbcdt, cfg)

  conv_in = jnp.concatenate([xin, Bs, Cs], axis=-1)
  conv_state = decode_state[0] if decode_state else None
  conv_out, new_conv = _causal_conv(conv_in, p["conv_w"].astype(jnp.float32),
                                    p["conv_b"].astype(jnp.float32),
                                    conv_state)
  xin, Bs, Cs = jnp.split(conv_out, [d_in, d_in + s.d_state], axis=-1)

  B_, S_, _ = x.shape
  xh = xin.reshape(B_, S_, h, s.head_dim)
  A = -jnp.exp(p["A_log"].astype(jnp.float32))             # (h,)
  dt = jax.nn.softplus(dt.astype(jnp.float32)
                       + p["dt_bias"].astype(jnp.float32))  # (B,S,h)

  if decode_state is None:
    y, ssd_state = ssd_chunked(xh.astype(jnp.float32), dt, A,
                               Bs.astype(jnp.float32), Cs.astype(jnp.float32),
                               s.chunk)
  else:
    st = decode_state[1]                                   # (B,h,p,n)
    dA = jnp.exp(dt[:, 0] * A[None])                       # (B,h)
    dBx = einsum("bn,bh,bhp->bhpn", Bs[:, 0], dt[:, 0], xh[:, 0])
    ssd_state = st * dA[:, :, None, None] + dBx
    y = einsum("bn,bhpn->bhp", Cs[:, 0], ssd_state)[:, None]  # (B,1,h,p)

  y = y + xh.astype(jnp.float32) * p["D"][None, None, :, None]
  y = y.reshape(B_, S_, d_in)
  y = y * jax.nn.silu(z.astype(jnp.float32))
  y = rms_norm(y.astype(x.dtype), p["norm"], cfg.norm_eps)
  out = einsum("bsk,kd->bsd", y, p["out_proj"]).astype(x.dtype)
  return out, (new_conv, ssd_state)
