"""Unified decoder model covering all 10 assigned architectures.

Layers are grouped into *super-blocks* following ``cfg.block_pattern``
(e.g. jamba's [7x mamba, 1x attn], gemma2's [local, global]); parameters
are stacked per pattern position and the model body is one
``lax.scan`` over super-blocks with full remat — this keeps the lowered
HLO size O(pattern) instead of O(n_layers), which matters when compiling
60-layer x 160-expert graphs for a 512-device mesh.

Loss is computed with a sequence-chunked logsumexp so the (B, S, vocab)
logits tensor never materialises (command-r has a 256k vocab).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import constrain
from repro.models import attention as attn
from repro.models import common as cm
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.layers import gelu_mlp, rms_norm, softcap, swiglu


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_mlp(key, cfg: cm.ModelConfig) -> dict:
  d, f = cfg.d_model, cfg.d_ff
  ks = jax.random.split(key, 4)
  if cfg.mlp_type == "gelu":
    return {
        "w1": cm.param(ks[0], (d, f), ("embed", "ff")),
        "b1": cm.zeros((f,), ("ff",)),
        "w2": cm.param(ks[1], (f, d), ("ff", "embed")),
        "b2": cm.zeros((d,), ("embed",)),
    }
  return {
      "w1": cm.param(ks[0], (d, f), ("embed", "ff")),
      "w3": cm.param(ks[1], (d, f), ("embed", "ff")),
      "w2": cm.param(ks[2], (f, d), ("ff", "embed")),
  }


def _init_layer(key, cfg: cm.ModelConfig, spec: cm.LayerSpec) -> dict:
  ks = jax.random.split(key, 8)
  p = {"ln1": cm.zeros((cfg.d_model,), ("embed",))}
  if spec.kind == "attn":
    p["attn"] = (attn.init_mla(ks[0], cfg) if cfg.mla
                 else attn.init_attention(ks[0], cfg))
  else:
    p["ssm"] = ssm_lib.init_ssm(ks[0], cfg)
  if spec.cross_attn:
    p["ln_cross"] = cm.zeros((cfg.d_model,), ("embed",))
    p["cross"] = attn.init_attention(ks[1], cfg, cross=True)
  has_ffn = cfg.d_ff > 0 or (spec.use_moe and cfg.moe)
  if has_ffn and not cfg.parallel_block:
    p["ln2"] = cm.zeros((cfg.d_model,), ("embed",))
  if spec.use_moe and cfg.moe:
    p["moe"] = moe_lib.init_moe(ks[2], cfg)
    if cfg.moe.dense_parallel:
      p["mlp"] = _init_mlp(ks[3], cfg)
  elif cfg.d_ff > 0:
    p["mlp"] = _init_mlp(ks[3], cfg)
  if cfg.sandwich_norm:
    p["ln1_post"] = cm.zeros((cfg.d_model,), ("embed",))
    if has_ffn:
      p["ln2_post"] = cm.zeros((cfg.d_model,), ("embed",))
  return p


def _stack_layers(key, cfg, spec, n: int):
  """Stack n copies of one pattern position; prepend the 'layers' axis."""
  keys = jax.random.split(key, n)
  trees = [_init_layer(k, cfg, spec) for k in keys]
  def stack(*boxes):
    return cm.Box(jnp.stack([b.value for b in boxes]),
                  ("layers",) + boxes[0].axes)
  return jax.tree.map(stack, *trees, is_leaf=cm.is_box)


def init_model(key, cfg: cm.ModelConfig):
  """Returns a Box tree (use common.split to get params + axes trees)."""
  ks = jax.random.split(key, 16)
  p = {
      "embed": cm.param(ks[0], (cfg.vocab, cfg.d_model), ("vocab", "embed"),
                        scale=1.0),
      "final_norm": cm.zeros((cfg.d_model,), ("embed",)),
      "blocks": {
          f"pos{i}": _stack_layers(ks[1 + i], cfg, spec, cfg.n_blocks)
          for i, spec in enumerate(cfg.block_pattern)
      },
  }
  if not cfg.tie_embeddings:
    p["unembed"] = cm.param(ks[12], (cfg.d_model, cfg.vocab),
                            ("embed", "vocab"))
  if cfg.frontend:
    p["frontend_proj"] = cm.param(
        ks[13], (cfg.frontend_dim, cfg.d_model), (None, "embed"))
  if cfg.encoder:
    e = cfg.encoder
    enc_cfg = _encoder_cfg(cfg)
    p["encoder"] = {
        "blocks": _stack_layers(ks[14], enc_cfg, cm.LayerSpec(), e.n_layers),
        "final_norm": cm.zeros((cfg.d_model,), ("embed",)),
    }
  return p


def _encoder_cfg(cfg: cm.ModelConfig) -> cm.ModelConfig:
  e = cfg.encoder
  import dataclasses  # noqa: PLC0415
  return dataclasses.replace(
      cfg, n_layers=e.n_layers, n_heads=e.n_heads, n_kv_heads=e.n_heads,
      d_ff=e.d_ff, moe=None, mla=None, ssm=None, encoder=None,
      block_pattern=(cm.LayerSpec(),))


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------

def _ffn(x, lp, cfg, spec):
  if spec.use_moe and cfg.moe:
    y, aux = moe_lib.moe_ffn(x, lp["moe"], cfg)
    if cfg.moe.dense_parallel:
      y = y + _dense_mlp(x, lp["mlp"], cfg)
    return y, aux
  if cfg.d_ff > 0:
    return _dense_mlp(x, lp["mlp"], cfg), 0.0
  return jnp.zeros_like(x), 0.0


def _dense_mlp(x, mp, cfg):
  if cfg.mlp_type == "gelu":
    return gelu_mlp(x, mp["w1"], mp["b1"], mp["w2"], mp["b2"])
  return swiglu(x, mp["w1"], mp["w3"], mp["w2"])


def _layer_forward(x, lp, cfg: cm.ModelConfig, spec: cm.LayerSpec,
                   positions, enc_out, causal_skip, collect_kv=False,
                   impl=None):
  """One layer: mixer (attn/ssm/cross) + ffn, pre-norm residual."""
  aux = 0.0
  kv = {}
  h = rms_norm(x, lp["ln1"], cfg.norm_eps)
  if spec.kind == "attn":
    if cfg.mla:
      mix = attn.mla_train(h, lp["attn"], cfg, positions, causal_skip,
                           return_kv=collect_kv, impl=impl)
    else:
      mix = attn.attention_train(h, lp["attn"], cfg, positions,
                                 local=spec.local, causal_skip=causal_skip,
                                 return_kv=collect_kv, impl=impl)
    if collect_kv:
      mix, (k_, v_) = mix
      kv["k"], kv["v"] = k_, v_
  else:
    mix, st = ssm_lib.ssm_forward(h, lp["ssm"], cfg)
    if collect_kv:
      kv["conv_state"], kv["ssd_state"] = st
  if cfg.sandwich_norm:
    mix = rms_norm(mix, lp["ln1_post"], cfg.norm_eps)

  if cfg.parallel_block:
    f, aux = _ffn(h, lp, cfg, spec)
    x = x + mix + f
  else:
    x = x + mix
    if spec.cross_attn:
      hc = rms_norm(x, lp["ln_cross"], cfg.norm_eps)
      cross = attn.attention_train(hc, lp["cross"], cfg, positions,
                                   enc_out=enc_out, return_kv=collect_kv)
      if collect_kv:
        cross, (ck, cv) = cross
        kv["cross_k"], kv["cross_v"] = ck, cv
      x = x + cross
    if "ln2" in lp:
      h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
      f, aux = _ffn(h2, lp, cfg, spec)
      if cfg.sandwich_norm:
        f = rms_norm(f, lp["ln2_post"], cfg.norm_eps)
      x = x + f
  x = constrain(x, ("batch", None, None))
  return x, aux, kv


def _gather_fsdp(stacked, axes):
  """Per-layer FSDP weight gather: inside the scan body, constrain each
  weight slice to be *replicated over the FSDP (data) axis* while keeping
  its TP (model) sharding — this pins GSPMD to the all-gather-weights
  plan instead of partial-contraction + activation all-reduces (400 GB/step
  on pixtral before this; see EXPERIMENTS.md §Perf)."""
  from repro.dist import sharding as shd  # noqa: PLC0415
  rules = dict(shd.current_rules() or shd.rules_dict())
  rules["embed"] = None                      # gather the FSDP dim
  def one(leaf, ax):
    return shd.constrain(leaf, ax[1:], rules=rules)   # drop 'layers'
  return jax.tree.map(one, stacked, axes, is_leaf=lambda x: False)


def _body(params_blocks, cfg, x, positions, enc_out, causal_skip,
          pattern=None, collect_kv=False, param_axes=None, impl=None):
  """Scan over super-blocks, unrolling the pattern inside each step."""
  pattern = pattern or cfg.block_pattern

  def superblock(carry, stacked):
    x, aux = carry
    if param_axes is not None:
      stacked = _gather_fsdp(stacked, param_axes)
    ys = {}
    for i, spec in enumerate(pattern):
      x, a, kv = _layer_forward(x, stacked[f"pos{i}"], cfg, spec, positions,
                                enc_out, causal_skip, collect_kv, impl)
      aux = aux + a
      for kk, vv in kv.items():
        ys.setdefault(kk, []).append(vv)
    ys = {kk: jnp.stack(vv) for kk, vv in ys.items()} if collect_kv else None
    return (x, aux), ys

  superblock = jax.checkpoint(
      superblock, policy=jax.checkpoint_policies.nothing_saveable)
  (x, aux), ys = jax.lax.scan(superblock, (x, jnp.float32(0.0)),
                              params_blocks)
  return (x, aux, ys) if collect_kv else (x, aux)


def encode(params, cfg: cm.ModelConfig, frames: jax.Array) -> jax.Array:
  """Whisper-style encoder over precomputed frame embeddings (stub
  frontend projects them to d_model; sinusoid-free, rope positions)."""
  x = jnp.einsum("btf,fd->btd", frames, params["frontend_proj"]
                 ).astype(cfg.dtype)
  T = x.shape[1]
  positions = jnp.arange(T)
  enc_cfg = _encoder_cfg(cfg)
  # Bidirectional: reuse attention_train with cross path (enc_out=x itself
  # gives full non-causal attention over the source).
  def superblock(carry, stacked):
    x, _ = carry
    h = rms_norm(x, stacked["ln1"], cfg.norm_eps)
    mix = attn.attention_train(h, stacked["attn"], enc_cfg,
                               positions, enc_out=h)
    x = x + mix
    h2 = rms_norm(x, stacked["ln2"], cfg.norm_eps)
    f, _ = _ffn(h2, stacked, enc_cfg, cm.LayerSpec())
    return (x + f, 0.0), None

  superblock = jax.checkpoint(
      superblock, policy=jax.checkpoint_policies.nothing_saveable)
  (x, _), _ = jax.lax.scan(superblock, (x, 0.0), params["encoder"]["blocks"])
  return rms_norm(x, params["encoder"]["final_norm"], cfg.norm_eps)


def embed_tokens(params, cfg, tokens, frontend_embeds=None):
  x = params["embed"][tokens].astype(cfg.dtype)
  if cfg.scale_embed:
    x = x * jnp.asarray(cfg.d_model ** 0.5, cfg.dtype)
  if cfg.frontend == "vision_stub" and frontend_embeds is not None:
    prefix = jnp.einsum("bpf,fd->bpd", frontend_embeds,
                        params["frontend_proj"]).astype(cfg.dtype)
    x = jnp.concatenate([prefix, x], axis=1)
  return constrain(x, ("batch", None, None))


def hidden_states(params, cfg: cm.ModelConfig, tokens: jax.Array,
                  frontend_embeds=None, causal_skip: bool = False,
                  collect_kv: bool = False, param_axes=None, impl=None):
  """Token ids -> final hidden states (B, S, d) + moe aux loss.

  ``impl`` selects the causal-attention implementation for forward-only
  (prefill) passes — see ``attention.causal_mix``; ``None`` keeps the
  remat'd training path."""
  enc_out = None
  if cfg.encoder is not None and frontend_embeds is not None:
    enc_out = encode(params, cfg, frontend_embeds)
  x = embed_tokens(params, cfg, tokens,
                   None if cfg.encoder else frontend_embeds)
  positions = jnp.arange(x.shape[1])
  out = _body(params["blocks"], cfg, x, positions, enc_out, causal_skip,
              collect_kv=collect_kv,
              param_axes=param_axes["blocks"] if param_axes else None,
              impl=impl)
  if collect_kv:
    x, aux, kv = out
    return rms_norm(x, params["final_norm"], cfg.norm_eps), aux, kv
  x, aux = out
  return rms_norm(x, params["final_norm"], cfg.norm_eps), aux


def logits_fn(params, cfg, h):
  w = params["embed"].T if cfg.tie_embeddings else params["unembed"]
  lg = jnp.einsum("bsd,dv->bsv", h.astype(jnp.float32),
                  w.astype(jnp.float32))
  return softcap(lg, cfg.logit_softcap)


def chunked_loss(params, cfg: cm.ModelConfig, h: jax.Array,
                 labels: jax.Array, chunk: int = 1024) -> jax.Array:
  """Cross entropy without materialising (B, S, vocab) logits."""
  B, S, d = h.shape
  chunk = min(chunk, S)
  while S % chunk != 0:          # largest divisor of S at most `chunk`
    chunk -= 1
  w = params["embed"].T if cfg.tie_embeddings else params["unembed"]

  def one(i):
    hc = jax.lax.dynamic_slice_in_dim(h, i * chunk, chunk, axis=1)
    lc = jax.lax.dynamic_slice_in_dim(labels, i * chunk, chunk, axis=1)
    lg = jnp.einsum("bsd,dv->bsv", hc.astype(jnp.float32),
                    w.astype(jnp.float32))
    lg = softcap(lg, cfg.logit_softcap)
    lg = constrain(lg, ("batch", None, "vocab"))
    lse = jax.nn.logsumexp(lg, axis=-1)
    gold = jnp.take_along_axis(lg, lc[..., None], axis=-1)[..., 0]
    return jnp.sum(lse - gold)

  one = jax.checkpoint(one)
  total = jax.lax.map(one, jnp.arange(S // chunk))
  return jnp.sum(total) / (B * S)


def forward_loss(params, cfg, tokens, labels, frontend_embeds=None,
                 causal_skip: bool = False, param_axes=None):
  h, aux = hidden_states(params, cfg, tokens, frontend_embeds, causal_skip,
                         param_axes=param_axes)
  if cfg.frontend == "vision_stub" and frontend_embeds is not None:
    h = h[:, frontend_embeds.shape[1]:]          # loss on text positions
  loss = chunked_loss(params, cfg, h, labels)
  return loss + 0.01 * aux, {"ce": loss, "aux": aux}
