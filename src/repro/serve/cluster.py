"""Multi-component scatter-gather serving tier (DESIGN.md §9).

The paper's architecture — a frontend scatter-gathering over massively
parallel components, each answering instantly from its local synopsis and
then refining the corpus parts most related to the request — realised
over the kernel serve path:

  * the corpus KV of every resident request is partitioned across N
    *components* laid out on the device mesh
    (`repro.dist.topology.ComponentTopology`; a ``("component",)`` mesh
    when the host has enough devices, a stacked single-device execution
    of the same math otherwise);
  * stage 1 runs the fused synopsis scoring on **all** components in
    parallel — one ``shard_map``-ed ``ops.synopsis_stage1`` over each
    component's ``k_syn``/``v_syn``/``counts`` shard;
  * the *frontend aggregator* merges the per-component score partials
    with a global top-k and allocates the per-step refinement budget
    across components proportionally to their synopsis relevance mass
    (:func:`allocate_budget`) — the paper's accuracy-aware part
    selection, generalized from clusters-within-a-component to
    components-within-a-cluster-of-machines;
  * the gather is *deadline-driven*: per step, each component is marked
    FULL (stage 1 + refinement), STAGE1 (its refinement is predicted to
    miss the step deadline — the synopsis answer, which always returns
    instantly, stands in) or DROP (partial execution: the component's
    entire contribution is skipped), and the online-softmax result
    composer folds exactly the granted partials;
  * with a replication factor R >= 2 (``ClusterConfig.replicas``,
    `ComponentTopology.replica_owner`) the gather additionally *hedges*:
    a component the predictor flags as likely to miss the step deadline
    has its refinement reissued to the shard's replica, the earlier of
    the two completions counts, and only when BOTH are predicted to miss
    does the stage-1 answer (or DROP, under partial execution) stand in.

  All latency prediction and budget decisions go through the shared
  control plane (`repro.control`, DESIGN.md §10): a pluggable per-bucket
  latency predictor (EWMA by default, sliding-window quantile via
  ``ClusterConfig.predictor``) and one `DeadlineBudgetPolicy` that owns
  the FULL/STAGE1/DROP decision and the mass-proportional
  `allocate_budget` with stranded-budget recirculation.

`ClusterStepBackend` plugs the tier into `ServingEngine` as a drop-in
step backend: admission scatters each slot's built synopsis across the
components (per-slot routing, optionally rotated for balance), decode
steps run one compiled program per budget bucket, and the backend keeps a
measured-latency attribution per component (`ClusterMeasuredExport`)
that round-trips into the discrete-event simulator
(``ScatterGatherService(step_backend=...)`` /
``ComponentModel.submit(service_ms=<per-component vector>)``).

CPU-proxy caveat (EXPERIMENTS.md §Cluster): on a single host the N
components execute as one program, so the *total* step wall time is
measured and attributed to components in proportion to their corpus
share and allocated budget (``l_c = base·share_c + slope·b_c``); the
per-step interference noise and straggler draws model the co-located
jobs the measurement cannot see, exactly as `serving.latency
.ComponentModel` does for the simulator.  The engine clock then advances
by the *parallel* completion time (max over gathered components), which
is what the frontend of a real N-machine deployment would observe.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.control import (MODE_DROP, MODE_FULL, MODE_STAGE1, RetryPolicy,
                           allocate_budget, make_predictor,
                           realized_recovery)
from repro.control.estimator import coverage_profile
from repro.dist import sharding as shd
from repro.dist.topology import ComponentTopology, make_component_mesh
from repro.kernels import ops
from repro.serve import kv_cache as kvc
from repro.serve.resilience import FaultPlan, FaultSpec
from repro.serve.serve_step import make_serve_step

NEG_INF = ops.NEG_INF

__all__ = ["MODE_DROP", "MODE_STAGE1", "MODE_FULL", "allocate_budget",
           "ClusterConfig", "ClusterStepBackend", "ClusterMeasuredExport",
           "make_cluster_attention", "gain_rank", "gain_budgets"]


@dataclasses.dataclass
class ClusterConfig:
  """Scatter-gather tier knobs (model shape comes from the ModelConfig)."""
  n_components: int = 4
  skew: float = 0.0            # Zipf exponent over component corpus shares
  alloc: str = "mass"          # "mass" (∝ relevance mass) | "topk" (global
                               # by raw score) | "gain" (global by marginal
                               # accuracy gain: count-biased score,
                               # DESIGN.md §13)
  route: str = "fixed"         # per-slot cluster routing; "rotate" balances
  replicas: int = 1            # shard copies; R >= 2 enables hedged reissue
  predictor: str = "ewma"      # control-plane wall predictor ("quantile:90"
                               # makes hedging target a tail percentile)
  recirculate: bool = True     # stranded-budget recirculation in allocate
  interference: float = 0.25   # lognormal sigma (co-located jobs, per step)
  straggler_prob: float = 0.02
  straggler_scale: float = 8.0
  use_mesh: Optional[bool] = None   # None -> auto (mesh iff devices >= N)
  seed: int = 0
  # -- resilience (DESIGN.md §11; all off by default: faults=None and
  # retries=1 take the exact legacy plan/account path, bit-identical) ----
  faults: Optional[FaultSpec] = None   # injected fault world (resilience.py)
  recovery: bool = True        # False: no retry / no stage-1 fallback —
                               # a dead shard stalls the gather and its
                               # mass is dropped (the chaos baseline)
  retries: int = 1             # bounded reissues per shard per step over
                               # the replica ring (1 = legacy one-shot
                               # hedge; needs replicas >= 2)
  retry_backoff: float = 0.5   # retry r waits timeout*backoff*mult^(r-1)
  retry_backoff_mult: float = 2.0
  fault_stall_wait: float = 3.0   # no-recovery: gather waits this many
                                  # step deadlines on a dead shard


# ---------------------------------------------------------------------------
# Frontend aggregator: global ranking + budget allocation across components
# (the allocation itself — mass-proportional with stranded-budget
# recirculation — lives in the control plane: repro.control.allocate_budget).
# ---------------------------------------------------------------------------

def _frontend_rank(sc_all: jax.Array, i_max: int):
  """Global ranking over the gathered per-component scores.

  sc_all (B, Hkv, N, Mp) with padded slots at NEG_INF.  Returns
  (gsel (B, Hkv, K) flat cluster ids with -1 pads — or None at budget 0 —
  and the per-component relevance mass (B, Hkv, N))."""
  B, Hkv, N, Mp = sc_all.shape
  flat = sc_all.reshape(B, Hkv, N * Mp)
  gmax = jnp.max(flat, axis=-1)                               # (B, Hkv)
  mass = jnp.sum(jnp.exp(sc_all - gmax[:, :, None, None]), axis=-1)
  if i_max <= 0:
    return None, mass
  K = min(i_max, N * Mp)
  tsc, gsel = jax.lax.top_k(flat, K)
  gsel = jnp.where(tsc > NEG_INF / 2, gsel.astype(jnp.int32), -1)
  return gsel, mass


def gain_rank(sc_all: jax.Array, counts: jax.Array, i_max: int):
  """Marginal-accuracy-gain global ranking (DESIGN.md §13).

  Refining cluster m removes its synopsis approximation error, and the
  share of the answer it owns — hence the loss the refinement recovers —
  is its stage-1 probability mass ``exp(score_m) · count_m``.  Greedy
  top-k on ``score + log(count)`` is therefore the budget split that
  maximizes the predicted covered mass per cluster refined, vs "mass"
  allocation which spreads budget ∝ per-*component* totals even when one
  component's clusters individually dominate.  ``sc_all`` (B, Hkv, N,
  Mp) padded scores, ``counts`` (B, N, Mp).  Returns flat global ids
  (B, Hkv, K) with -1 pads — a drop-in for `_frontend_rank`'s gsel."""
  B, Hkv, N, Mp = sc_all.shape
  bias = jnp.log(jnp.maximum(counts, 1e-30))[:, None, :, :]
  g = jnp.where(sc_all > NEG_INF / 2, sc_all + bias, NEG_INF)
  flat = g.reshape(B, Hkv, N * Mp)
  K = min(i_max, N * Mp)
  tsc, gsel = jax.lax.top_k(flat, K)
  return jnp.where(tsc > NEG_INF / 2, gsel.astype(jnp.int32), -1)


def gain_budgets(gsel: jax.Array, Mp: int, N: int) -> jax.Array:
  """Per-component budget vector implied by a global selection: how many
  of the selected flat ids land on each component.  Conserves the spend
  by construction — ``sum == number of non-pad selections`` — which the
  conservation tests check against `allocate_budget`'s invariant."""
  comp_of = jnp.where(gsel >= 0, gsel // Mp, -1)
  onehot = comp_of[..., None] == jnp.arange(N)[None, None, None, :]
  return jnp.sum(onehot.astype(jnp.int32), axis=2)          # (B, Hkv, N)


def _select_local(c, sc_local, gsel, budgets, alloc, i_max, Mp):
  """Per-component stage-2 selection (local cluster ids, -1 pads).

  ``alloc="topk"`` / ``alloc="gain"``: the component refines exactly the
  globally top-ranked clusters it owns (two-level top-k — "topk" equals
  the single-component reference; "gain" ranks by count-biased score,
  see :func:`gain_rank`).  ``alloc="mass"``: the component refines its
  own top-scored clusters up to the budget the frontend allocated it."""
  if alloc in ("topk", "gain"):
    comp_of = jnp.where(gsel >= 0, gsel // Mp, -1)
    return jnp.where(comp_of == c, gsel % Mp, -1).astype(jnp.int32)
  Kc = min(i_max, Mp)
  tsc, sel = jax.lax.top_k(sc_local, Kc)
  b_c = jnp.take(budgets, c, axis=-1)[..., None]              # (B, Hkv, 1)
  keep = (jnp.arange(Kc)[None, None, :] < b_c) & (tsc > NEG_INF / 2)
  return jnp.where(keep, sel.astype(jnp.int32), -1)


def _pick_mode(mode, full, syn):
  """Deadline-driven partial gather: FULL -> merged stage-1+2 partial,
  STAGE1 -> the synopsis answer alone, DROP -> a zero-weight partial."""
  drop = (jnp.zeros_like(full[0]), jnp.full_like(full[1], NEG_INF),
          jnp.zeros_like(full[2]))
  return tuple(
      jnp.where(mode == MODE_FULL, f,
                jnp.where(mode == MODE_STAGE1, s, d))
      for f, s, d in zip(full, syn, drop))


def _extras_partial(q, csl, self_kv, *, sm_scale, cap, impl):
  """Frontend-owned recent-ring + self-KV partial, merged exactly once at
  the composer (never routed to a component, so partial gather can never
  lose the new token)."""
  extras = ops.build_extras(csl.get("recent_k"), csl.get("recent_v"),
                            csl.get("recent_len"), self_kv)
  if extras is None:
    return None
  ek, ev, eb = extras
  bias = jnp.broadcast_to(eb[:, None, :],
                          (eb.shape[0], ek.shape[1], eb.shape[1]))
  return ops.decode_partials(q, ek, ev, bias, sm_scale=sm_scale, cap=cap,
                             impl=impl)


# ---------------------------------------------------------------------------
# The scatter-gather attention body (stacked + shard_map executions of the
# same math).  Plugged into make_serve_step(attention_fn=...).
# ---------------------------------------------------------------------------

def make_cluster_attention(topo: ComponentTopology, alloc: str = "mass",
                           mesh=None, recirculate: bool = True,
                           mode_caps: bool = False,
                           telemetry: bool = False):
  """Returns ``attention_fn(q, cache_sl, ...) -> (ctx, aux)`` over the
  component-partitioned cache layout (DESIGN.md §9):

    k/v          (B, Hkv, N, m_max*C, D)   per-component corpus shards
    k_syn/v_syn  (B, Hkv, N, m_max, D)     per-component centroid tables
    counts       (B, N, m_max)             0 on padded slots
    fe_mode      (N,) int32                per-component gather mode

  ``aux`` carries per-layer telemetry: ``fe_cover`` (N,) mean refined
  clusters per component and ``fe_mass`` (N,) mean relevance-mass share;
  with ``telemetry=True`` (the ε-or-deadline contracts, DESIGN.md §13)
  also ``est_profile`` (B, N*Mp+1) — the stage-1 coverage profile over
  the GLOBAL cluster ranking, the online loss estimator's raw signal.
  Off by default so contract="deadline" step programs stay bit-identical.

  ``mode_caps`` (resilience, DESIGN.md §11): a component gathered as
  STAGE1/DROP never folds its refinement, so budget allocated to it is
  wasted — with mode-aware caps its allocation cap is zeroed and
  `allocate_budget`'s recirculation respends that budget on the live FULL
  components instead.  Off by default: it changes the default path's
  allocation, so only the resilient backend enables it.
  """
  N, Mp = topo.n_components, topo.m_max

  def attention(q, csl, *, i_max, cluster_size, sm_scale, cap=None,
                self_kv=None, impl="xla"):
    if mesh is not None:
      return _cluster_sharded(
          q, csl, topo, alloc, mesh, i_max=i_max,
          cluster_size=cluster_size, sm_scale=sm_scale, cap=cap,
          self_kv=self_kv, impl=impl, recirculate=recirculate,
          mode_caps=mode_caps, telemetry=telemetry)
    return _cluster_stacked(
        q, csl, topo, alloc, i_max=i_max, cluster_size=cluster_size,
        sm_scale=sm_scale, cap=cap, self_kv=self_kv, impl=impl,
        recirculate=recirculate, mode_caps=mode_caps, telemetry=telemetry)

  return attention


def _cluster_stacked(q, csl, topo, alloc, *, i_max, cluster_size, sm_scale,
                     cap, self_kv, impl, recirculate=True, mode_caps=False,
                     telemetry=False):
  """Single-device execution: the N components run as an unrolled loop
  over the component axis — identical math to the shard_map body."""
  k, v = csl["k"], csl["v"]
  k_syn, v_syn, counts = csl["k_syn"], csl["v_syn"], csl["counts"]
  fe_mode = csl["fe_mode"]
  N, Mp = k_syn.shape[2], k_syn.shape[3]

  def _slice_scales(names, c):
    # Quantized-arena dequant scales (§15) per component, when present.
    if names[0] not in csl:
      return None
    return tuple(csl[n][:, :, c] for n in names)

  scs, psyns = [], []
  for c in range(N):
    sc_c, p_c = ops.synopsis_stage1(
        q, k_syn[:, :, c], v_syn[:, :, c], counts[:, c],
        sm_scale=sm_scale, cap=cap, impl=impl, valid=counts[:, c] > 0,
        syn_scales=_slice_scales(("k_syn_scale", "v_syn_scale"), c))
    scs.append(sc_c)
    psyns.append(p_c)
  sc_all = jnp.stack(scs, axis=2)                         # (B, Hkv, N, Mp)
  gsel, mass = _frontend_rank(sc_all, i_max)
  if gsel is not None and alloc == "gain":
    gsel = gain_rank(sc_all, counts, i_max)
  budgets = None
  if gsel is not None and alloc == "mass":
    caps = jnp.sum(sc_all > NEG_INF / 2, axis=-1)         # (B, Hkv, N)
    if mode_caps:
      caps = jnp.where(fe_mode[None, None, :] == MODE_FULL, caps, 0)
    budgets = allocate_budget(mass, i_max, caps, recirculate=recirculate)

  acc = None
  cover = []
  for c in range(N):
    if gsel is None:
      p_full = psyns[c]
      cover.append(jnp.float32(0.0))
    else:
      sel = _select_local(c, scs[c], gsel, budgets, alloc, i_max, Mp)
      p_ref = ops.refine_stage2(
          q, k[:, :, c], v[:, :, c], sel, k_syn[:, :, c], v_syn[:, :, c],
          counts[:, c], cluster_size=cluster_size, sm_scale=sm_scale,
          cap=cap, impl=impl,
          syn_scales=_slice_scales(("k_syn_scale", "v_syn_scale"), c),
          kv_scales=_slice_scales(("k_scale", "v_scale"), c))
      p_full = ops.merge_partials(psyns[c], p_ref)
      cover.append(jnp.mean(jnp.sum((sel >= 0).astype(jnp.float32), -1)))
    contrib = _pick_mode(fe_mode[c], p_full, psyns[c])
    acc = contrib if acc is None else ops.merge_partials(acc, contrib)

  p_ex = _extras_partial(q, csl, self_kv, sm_scale=sm_scale, cap=cap,
                         impl=impl)
  if p_ex is not None:
    acc = ops.merge_partials(acc, p_ex)
  mass_frac = mass / jnp.maximum(jnp.sum(mass, -1, keepdims=True), 1e-30)
  aux = {"fe_cover": jnp.stack(cover),
         "fe_mass": jnp.mean(mass_frac, axis=(0, 1))}
  if telemetry:
    B = sc_all.shape[0]
    aux["est_profile"] = coverage_profile(
        sc_all.reshape(B, sc_all.shape[1], N * Mp),
        counts.reshape(B, N * Mp),
        rank="mass" if alloc == "gain" else "score")
  return acc[0], aux


def _cluster_sharded(q, csl, topo, alloc, mesh, *, i_max, cluster_size,
                     sm_scale, cap, self_kv, impl, recirculate=True,
                     mode_caps=False, telemetry=False):
  """shard_map execution over the ``("component",)`` mesh: every device is
  one component; the score all-gather + replicated frontend logic is the
  aggregator, the partials all-gather + fold is the result composer."""
  from jax.sharding import PartitionSpec as P  # noqa: PLC0415
  N, Mp = topo.n_components, topo.m_max
  corpus = P(None, None, "component", None, None)
  specs = {"k": corpus, "v": corpus, "k_syn": corpus, "v_syn": corpus,
           "counts": P(None, "component", None),
           "fe_mode": P("component")}
  for name in ("k_syn_scale", "v_syn_scale", "k_scale", "v_scale"):
    if name in csl:          # quantized arena (§15)
      specs[name] = P(None, None, "component", None)
  for name in ("recent_k", "recent_v"):
    if name in csl:
      specs[name] = P(None, None, None, None)
  if "recent_len" in csl:
    specs["recent_len"] = P(None)
  csl = {kk: csl[kk] for kk in specs}
  q_spec = P(None, None, None)
  self_spec = (P(None, None, None, None),) * 2 if self_kv is not None \
      else P()

  def body(q, cache, self_kv):
    with shd.manual_axes({"component"}):
      sid = jax.lax.axis_index("component")
      k_l, v_l = cache["k"][:, :, 0], cache["v"][:, :, 0]
      ks_l, vs_l = cache["k_syn"][:, :, 0], cache["v_syn"][:, :, 0]
      counts_l = cache["counts"][:, 0]
      mode_l = cache["fe_mode"][0]
      syn_scales = (None if "k_syn_scale" not in cache else
                    (cache["k_syn_scale"][:, :, 0],
                     cache["v_syn_scale"][:, :, 0]))
      kv_scales = (None if "k_scale" not in cache else
                   (cache["k_scale"][:, :, 0], cache["v_scale"][:, :, 0]))

      sc_l, p_syn = ops.synopsis_stage1(
          q, ks_l, vs_l, counts_l, sm_scale=sm_scale, cap=cap, impl=impl,
          valid=counts_l > 0, syn_scales=syn_scales)
      sc = jax.lax.all_gather(sc_l, "component", axis=2, tiled=True)
      B, Hkv = sc.shape[:2]
      sc_all = sc.reshape(B, Hkv, N, Mp)
      gsel, mass = _frontend_rank(sc_all, i_max)
      counts_g = None
      if alloc == "gain" or telemetry:
        # One extra small (B, Mp) all-gather: the global counts the
        # count-biased gain ranking and the coverage profile both need.
        counts_g = jax.lax.all_gather(cache["counts"][:, 0], "component",
                                      axis=1, tiled=True)    # (B, N*Mp)
      if gsel is not None and alloc == "gain":
        gsel = gain_rank(sc_all, counts_g.reshape(B, N, Mp), i_max)

      if gsel is None:
        p_full = p_syn
        cover_l = jnp.zeros((1,), jnp.float32)
      else:
        budgets = None
        if alloc == "mass":
          caps = jnp.sum(sc_all > NEG_INF / 2, axis=-1)    # (B, Hkv, N)
          if mode_caps:
            modes = jax.lax.all_gather(cache["fe_mode"], "component",
                                       tiled=True)          # (N,)
            caps = jnp.where(modes[None, None, :] == MODE_FULL, caps, 0)
          budgets = allocate_budget(mass, i_max, caps,
                                    recirculate=recirculate)
        sel = _select_local(sid, sc_l, gsel, budgets, alloc, i_max, Mp)
        p_ref = ops.refine_stage2(
            q, k_l, v_l, sel, ks_l, vs_l, counts_l,
            cluster_size=cluster_size, sm_scale=sm_scale, cap=cap,
            impl=impl, syn_scales=syn_scales, kv_scales=kv_scales)
        p_full = ops.merge_partials(p_syn, p_ref)
        cover_l = jnp.mean(
            jnp.sum((sel >= 0).astype(jnp.float32), -1))[None]
      contrib = _pick_mode(mode_l, p_full, p_syn)

      gathered = [jax.lax.all_gather(x[None], "component", axis=0,
                                     tiled=True) for x in contrib]
      og, mg, lg = gathered
      acc = (og[0], mg[0], lg[0])
      for i in range(1, N):
        acc = ops.merge_partials(acc, (og[i], mg[i], lg[i]))
      p_ex = _extras_partial(q, cache, self_kv, sm_scale=sm_scale,
                             cap=cap, impl=impl)
      if p_ex is not None:
        acc = ops.merge_partials(acc, p_ex)
      cover = jax.lax.all_gather(cover_l, "component", axis=0, tiled=True)
      mass_frac = mass / jnp.maximum(jnp.sum(mass, -1, keepdims=True),
                                     1e-30)
      outs = (acc[0], cover, jnp.mean(mass_frac, axis=(0, 1)))
      if telemetry:
        outs = outs + (coverage_profile(
            sc_all.reshape(B, Hkv, N * Mp), counts_g,
            rank="mass" if alloc == "gain" else "score"),)
      return outs

  n_out = 4 if telemetry else 3
  res = shd.shard_map(
      body, mesh=mesh, in_specs=(q_spec, specs, self_spec),
      out_specs=(P(),) * n_out, axis_names=("component",),
      check_vma=False)(q, csl, self_kv)
  aux = {"fe_cover": res[1], "fe_mass": res[2]}
  if telemetry:
    aux["est_profile"] = res[3]
  return res[0], aux


# ---------------------------------------------------------------------------
# ServingEngine step backend: per-slot routing, plan/account around each
# dispatched step, measured-latency attribution per component.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _StepPlan:
  """One step's pre-dispatch gather decision + this step's noise draws
  (the same draws price the realized completion once the wall time is
  measured, so decision and accounting see one consistent world).

  The resilience fields (default None = legacy path, DESIGN.md §11)
  carry this step's fault world and the recovery ladder's decisions so
  ``account`` realizes exactly the retries ``plan_step`` dispatched."""
  fe_mode: jax.Array           # (N,) int32 device array fed into the step
  mode: np.ndarray             # same, host-side
  noise: np.ndarray            # per-component interference multipliers
  noise2: np.ndarray           # independent draws for the replica reissues
  hedged: np.ndarray           # (N,) bool: shard c's refinement reissued
  b_est: np.ndarray            # frontend's expected per-component budget
  deadline_ms: float
  retries: Optional[np.ndarray] = None   # (N,) reissues dispatched
  noise_r: Optional[np.ndarray] = None   # (K, N) per-retry draws
  delays: Optional[np.ndarray] = None    # (K, N) backoff dispatch offsets
  alive: Optional[np.ndarray] = None     # (N,) fault world: primary alive
  slow: Optional[np.ndarray] = None      # (N,) fault slowdown multipliers


class ClusterStepBackend:
  """Drop-in `ServingEngine` step backend running the scatter-gather tier.

  The engine calls ``plan_step`` (frontend gather decision from the
  calibrated per-component latency attribution + this step's interference
  draws), dispatches the returned program, and calls ``account`` with the
  measured wall time — which recalibrates the attribution, computes the
  per-request accuracy contribution and the *parallel* completion time
  the engine clock advances by (see module docstring, CPU-proxy note)."""

  def __init__(self, ccfg: ClusterConfig):
    self.ccfg = ccfg
    self.engine = None

  # -- binding ---------------------------------------------------------------
  def bind(self, engine) -> None:
    """Called by ServingEngine.__init__ once shapes are known."""
    cc = self.ccfg
    self.engine = engine
    self.cfg = engine.cfg
    self.impl = engine.impl
    self.M = engine.M
    self.n_slots = engine.ecfg.n_slots
    self.prompt_len = engine.ecfg.prompt_len
    self.accuracy_fn = engine.accuracy_fn
    if cc.alloc not in ("mass", "topk", "gain"):
      raise ValueError(
          f"alloc {cc.alloc!r} not in ('mass', 'topk', 'gain')")
    if cc.route not in ("fixed", "rotate"):
      raise ValueError(f"route {cc.route!r} not in ('fixed', 'rotate')")
    self.topo = ComponentTopology.plan(self.M, cc.n_components,
                                       skew=cc.skew, replicas=cc.replicas)
    use_mesh = cc.use_mesh
    self.mesh = make_component_mesh(cc.n_components) \
        if use_mesh or use_mesh is None else None
    if use_mesh and self.mesh is None:
      raise RuntimeError(
          f"use_mesh=True but < {cc.n_components} devices; run under "
          f"XLA_FLAGS=--xla_force_host_platform_device_count="
          f"{cc.n_components}")
    # Resilience (DESIGN.md §11): the fault world, the bounded-retry
    # policy over the replica ring, and mode-aware allocation caps.  The
    # default config (faults=None, recovery=True, retries=1) keeps
    # ``resilient`` False and every fault/recovery branch below is
    # skipped — the legacy plan/account path runs bit-identically.
    if cc.retries < 0:
      raise ValueError(f"retries {cc.retries} < 0")
    self.faults = FaultPlan(cc.faults, cc.n_components)
    self.resilient = self.faults.enabled or cc.retries != 1 \
        or not cc.recovery
    self.retry_policy = RetryPolicy(max_retries=cc.retries,
                                    backoff_base=cc.retry_backoff,
                                    backoff_mult=cc.retry_backoff_mult)
    self.n_retries = cc.retries if cc.replicas > 1 and cc.recovery else 0
    if self.n_retries:
      # Retry r's holder: walk the shard's replica ring (retries beyond
      # the materialized copies re-ask earlier holders after backoff).
      self.retry_of = np.asarray(
          [[self.topo.replica_owner(c, 1 + r % (cc.replicas - 1))
            for c in range(cc.n_components)]
           for r in range(self.n_retries)])
    else:
      self.retry_of = None
    self.step_idx = 0
    self.fault_stats = {"crash_steps": 0, "retries": 0,
                        "stage1_fallbacks": 0, "dropped": 0}
    # ε-or-deadline contracts (DESIGN.md §13): the coverage-profile
    # telemetry the online estimator reads.  Gated on the engine's
    # contract so contract="deadline" step programs stay bit-identical.
    self.telemetry = engine.ecfg.contract != "deadline"
    self.attention = make_cluster_attention(self.topo, alloc=cc.alloc,
                                            mesh=self.mesh,
                                            recirculate=cc.recirculate,
                                            mode_caps=self.resilient,
                                            telemetry=self.telemetry)
    # Per-component corpus share: the latency/accuracy attribution
    # weights.  Rotation mixes ownership across slots via shifts
    # 0..n_slots-1, so the attribution is the mean of exactly those
    # rotations of the plan — uniform only once n_slots covers the
    # component ring (fewer slots leave a skewed corpus genuinely
    # concentrated on the first components, and the attribution must
    # say so or plan_step underpredicts the hot components).
    if cc.route == "rotate":
      self.comp_share = np.mean(
          [np.roll(self.topo.shares, s) for s in range(self.n_slots)],
          axis=0)
    else:
      self.comp_share = np.asarray(self.topo.shares)
    # Control plane: one pluggable wall-time predictor per backend (the
    # attribution base — pre-dispatch predictions AND the hedging
    # decision read it).  Gather-mode decisions go through the engine's
    # DeadlineBudgetPolicy (`engine.controller.gather_modes`): one
    # policy object per engine owns budgets AND modes.
    self.predictor = make_predictor(cc.predictor)
    # Primary -> first-replica holder, per shard (ring placement).
    self.replica_of = np.asarray(
        [self.topo.replica_owner(c, 1) for c in range(cc.n_components)]) \
        if cc.replicas > 1 else None
    self.mass_ewma = self.comp_share.copy()
    self.reseed(cc.seed)
    self._write = self._make_write()

  def reseed(self, seed: int) -> None:
    """Re-seed the interference/straggler draw stream.  Called per
    measurement window (`run_open_loop`) so a window's draw sequence is a
    pure function of (config seed, window seed) — warmup and prior
    windows cannot shift it, and BENCH_cluster.json regenerates with the
    same noise world every time."""
    self.rng = np.random.default_rng(
        np.random.SeedSequence([int(self.ccfg.seed),
                                int(seed) & 0x7fffffff]))
    # The injected fault world and the step counter rewind with the draw
    # stream: a window's faults are a pure function of (spec seed,
    # window seed, step index), independent of warmup history.
    self.step_idx = 0
    if getattr(self, "faults", None) is not None:
      self.faults.reseed(seed)

  # -- cache layout ----------------------------------------------------------
  def zeros_cache(self) -> Dict[str, jax.Array]:
    """The engine slot pool with corpus leaves in component layout."""
    base = kvc.zeros_cache(self.cfg, self.n_slots, self.prompt_len,
                           synopsis=True)
    nb, na, B, Hkv, S, D = base["k"].shape
    C = self.cfg.synopsis.cluster_size
    N, Mp = self.topo.n_components, self.topo.m_max
    base["k"] = jnp.zeros((nb, na, B, Hkv, N, Mp * C, D),
                          base["k"].dtype)
    base["v"] = jnp.zeros_like(base["k"])
    base["k_syn"] = jnp.zeros((nb, na, B, Hkv, N, Mp, D),
                              base["k_syn"].dtype)
    base["v_syn"] = jnp.zeros_like(base["k_syn"])
    base["counts"] = jnp.zeros((nb, na, B, N, Mp), jnp.float32)
    for name in ("k_syn_scale", "v_syn_scale", "k_scale", "v_scale"):
      if name in base:       # quantized arena (§15): component layout too
        base[name] = jnp.zeros((nb, na, B, Hkv, N, Mp), jnp.float32)
    return base

  def _scatter(self, syn: Dict[str, jax.Array]) -> Dict[str, jax.Array]:
    """Route one request's built synopsis cache (B=1, cluster-contiguous)
    into per-component shards padded to m_max (counts 0 on pads)."""
    C = self.cfg.synopsis.cluster_size
    topo = self.topo
    Mp = topo.m_max

    def split(x, axis, unit):
      parts = []
      for c in range(topo.n_components):
        off, cnt = topo.offsets[c] * unit, topo.counts[c] * unit
        sl = jax.lax.slice_in_dim(x, off, off + cnt, axis=axis)
        pad = Mp * unit - cnt
        if pad:
          widths = [(0, 0)] * x.ndim
          widths[axis] = (0, pad)
          sl = jnp.pad(sl, widths)
        parts.append(sl)
      return jnp.stack(parts, axis=axis)

    # The shared-immutable half (kvc.ARENA_LEAVES) is what scatters —
    # private leaves (recent ring, pos, SSM state) pass through slot-
    # local.  A corpus-cache arena is pre-scatter canonical state, so a
    # shared arena scatters bit-identically to a privately built one
    # (tests/test_cluster.py).
    out = dict(syn)
    for name in kvc.ARENA_LEAVES:
      if name not in syn:    # scale leaves exist only under quantization
        continue
      if name == "counts":
        out[name] = split(syn[name], axis=3, unit=1)
      else:
        out[name] = split(syn[name], axis=4,
                          unit=C if name in ("k", "v") else 1)
    return out

  def _make_write(self):
    bx = kvc.slot_batch_axes(self.cfg, self.n_slots, self.prompt_len,
                             synopsis=True)
    rotate = self.ccfg.route == "rotate"

    def write(cache, syn, slot):
      sub = self._scatter(syn)
      if rotate:
        # Per-slot routing: slot s's cluster range r lands on component
        # (r + s) % N, spreading skewed ranges across components.
        for name in kvc.ARENA_LEAVES:
          if name not in sub:
            continue
          sub[name] = jnp.roll(sub[name], slot,
                               axis=3 if name == "counts" else 4)
      return kvc.write_slot(cache, sub, slot, bx)

    return jax.jit(write)

  def write_slot(self, cache, syn, slot):
    return self._write(cache, syn, slot)

  # -- the compiled step -----------------------------------------------------
  def step_fn(self, budget: int):
    """One jitted program per budget bucket; ``fe_mode`` is a traced
    input, so gather decisions never recompile."""
    step = make_serve_step(self.cfg, mode="synopsis", i_max=budget,
                           impl=self.impl, attention_fn=self.attention)

    @jax.jit
    def run(params, cache, tok, fe_mode):
      cache = dict(cache)
      cache["fe_mode"] = fe_mode
      return step(params, cache, tok)

    return run

  def full_mode(self) -> jax.Array:
    return jnp.full((self.topo.n_components,), MODE_FULL, jnp.int32)

  # -- frontend plan / account ----------------------------------------------
  def _units(self, b_vec: np.ndarray) -> np.ndarray:
    """Rows-read compute attribution per component: stage 1 streams the
    component's ``share_c * M`` centroids, refinement streams ``b_c``
    clusters of C original tokens each."""
    C = self.cfg.synopsis.cluster_size
    return self.comp_share * self.M + np.maximum(b_vec, 0.0) * C

  def _draw_noise(self) -> np.ndarray:
    """One (N,) interference + straggler multiplier draw.  Two draws per
    step (primary + replica path) are consumed regardless of the
    replication factor, so R=1 and R=2 runs with the same seeds see the
    same primary noise world."""
    cc = self.ccfg
    N = self.topo.n_components
    noise = self.rng.lognormal(0.0, cc.interference, N)
    return np.where(self.rng.random(N) < cc.straggler_prob,
                    noise * cc.straggler_scale, noise)

  def _hedge_time(self, wall: float, u: np.ndarray, usum: float,
                  noise: np.ndarray, noise2: np.ndarray) -> np.ndarray:
    """Completion of shard c's reissue on its replica j = replica_of[c]:
    the replica first finishes its own shard — u[j] at noise[j], the
    SAME draw that prices j's own completion this step, so a reissue can
    never finish before the machine it queues behind is free — then
    streams c's stage-1 + granted clusters again (u[c]) under the
    reissue's independent draw noise2[j].  ONE expression shared by the
    hedging decision (plan_step) and the realized accounting (account),
    so they can never drift apart."""
    j = self.replica_of
    return wall * (u[j] * noise[j] + u * noise2[j]) / usum

  def _retry_times(self, wall: float, u: np.ndarray, usum: float,
                   noise: np.ndarray, noise_r: np.ndarray,
                   slow: np.ndarray, delays: np.ndarray) -> np.ndarray:
    """Completion of shard c's retry r on holder jr = retry_of[r, c]:
    dispatched after the backoff delay, the holder first finishes its
    own shard — u[jr] at its fault slowdown and the SAME noise draw that
    prices jr's own completion this step — then streams c's stage-1 +
    granted clusters again under the retry's independent draw.  The
    K=1 / delay-0 / no-fault row is exactly ``_hedge_time``.  ONE
    expression shared by plan_step and account (DESIGN.md §11)."""
    jr = self.retry_of                                        # (K, N)
    nr = np.take_along_axis(noise_r, jr, axis=1)              # (K, N)
    return delays + wall * (u[jr] * slow[jr] * noise[jr]
                            + u[None, :] * slow[jr] * nr) / usum

  def plan_step(self, budget: int, step_deadline_ms: float) -> _StepPlan:
    """Pre-dispatch gather decision: predict each component's completion
    (control-plane wall predictor for this bucket, attributed by rows
    read, times this step's interference / straggler draws), hedge the
    predicted stragglers onto their shard replicas (R >= 2: the reissue
    queues behind the replica's own work and the earlier completion
    counts), and let the policy mark the components that still cannot
    make the step deadline STAGE1 (accuracytrader: the synopsis answer
    stands in) or DROP (partial execution: the result is skipped).

    With resilience on (injected faults and/or retries != 1) the single
    hedge generalizes to the control plane's recovery ladder
    (``recover_modes``, DESIGN.md §11): dead primaries and predicted
    stragglers retry on the replica ring with exponential backoff, and a
    shard with no live path inside the deadline terminally degrades to
    its stage-1 synopsis (accuracytrader) or is dropped (partial)."""
    massf = self.mass_ewma / max(self.mass_ewma.sum(), 1e-30)
    b_est = float(budget) * massf
    u = self._units(b_est)
    usum = max(u.sum(), 1e-30)
    noise, noise2 = self._draw_noise(), self._draw_noise()
    wall = self.predictor.predict(budget)
    if not self.resilient:
      t_pred = wall * (u / usum) * noise
      t_hedged = None
      if self.replica_of is not None:
        t_hedged = self._hedge_time(wall, u, usum, noise, noise2)
      mode, hedged = self.engine.controller.gather_modes(
          t_pred, step_deadline_ms, t_hedged)
      return _StepPlan(fe_mode=jnp.asarray(mode), mode=mode, noise=noise,
                       noise2=noise2, hedged=hedged, b_est=b_est,
                       deadline_ms=step_deadline_ms)
    fstate = self.faults.at(self.step_idx)
    alive, slow = fstate.alive, fstate.slow
    t_base = wall * (u / usum)           # per-component predictor timeout
    t_pred = t_base * noise * slow
    k = self.n_retries
    t_retry = retry_alive = delays = noise_r = None
    if k:
      noise_r = np.stack([noise2] + [self._draw_noise()
                                     for _ in range(k - 1)])
      delays = self.retry_policy.delays(t_base)               # (K, N)
      t_retry = self._retry_times(wall, u, usum, noise, noise_r, slow,
                                  delays)
      retry_alive = alive[self.retry_of]
    mode, retries, _ = self.engine.controller.recover_modes(
        t_pred, step_deadline_ms, t_retry=t_retry, alive=alive,
        retry_alive=retry_alive)
    if not self.ccfg.recovery:
      # Chaos baseline: no retries and no synopsis fallback — a dead
      # shard's mass simply drops (its stall is priced in account).
      mode = np.where(alive, mode, MODE_DROP).astype(np.int32)
      retries = np.zeros_like(retries)
    return _StepPlan(fe_mode=jnp.asarray(mode), mode=mode, noise=noise,
                     noise2=noise2, hedged=retries > 0, b_est=b_est,
                     deadline_ms=step_deadline_ms, retries=retries,
                     noise_r=noise_r, delays=delays, alive=alive,
                     slow=slow)

  def account(self, budget: int, wall_ms: float, plan: _StepPlan, st,
              warming: bool = False) -> Dict[str, float]:
    """Post-step accounting: fold the measured wall into the control-plane
    predictor, attribute it to components by the *actually refined* rows,
    take the hedged min for reissued shards (the same draws that made the
    hedging decision price the realized completions), and return the
    parallel completion time (max over the gathered components' effective
    times — what the frontend of a real N-machine deployment would wait
    for) plus the step's accuracy contribution."""
    full = plan.mode == MODE_FULL
    if not warming:
      self.predictor.observe(budget, wall_ms)
      if "fe_mass" in st:
        m = np.asarray(st["fe_mass"]).mean(axis=(0, 1))
        mix = 0.7 * self.mass_ewma + 0.3 * m
        self.mass_ewma = mix / max(mix.sum(), 1e-30)
    cover = np.asarray(st["fe_cover"]).mean(axis=(0, 1)) \
        if "fe_cover" in st else np.zeros_like(self.comp_share)
    u = self._units(np.where(full, cover, 0.0))
    usum = max(u.sum(), 1e-30)
    f = u / usum
    u0 = self._units(np.zeros_like(cover))       # stage-1-only compute
    f0 = u0 / usum
    if plan.alive is None:                       # legacy (non-resilient)
      t_real = wall_ms * f * plan.noise
      if self.replica_of is not None and plan.hedged.any():
        # A hedged shard completes at the earlier of the primary and its
        # replica's reissue — same pricing as the plan-time decision.
        t_hedge = self._hedge_time(wall_ms, u, usum, plan.noise,
                                   plan.noise2)
        t_real = np.where(plan.hedged, np.minimum(t_real, t_hedge),
                          t_real)
      done_full = t_real
    else:
      # Resilient realization: the SAME fault world, draws and backoff
      # delays that made the plan-time decision price the completions —
      # retry r participates only where the plan dispatched it.
      slow = plan.slow
      t_real = wall_ms * f * plan.noise * slow
      t_retry_real = retry_alive = None
      if plan.noise_r is not None:
        t_retry_real = self._retry_times(wall_ms, u, usum, plan.noise,
                                         plan.noise_r, slow, plan.delays)
        retry_alive = plan.alive[self.retry_of]
      done_full = realized_recovery(t_real, t_retry_real, plan.retries,
                                    plan.alive, retry_alive)
    t_stage1 = wall_ms * f0 * plan.noise
    done = np.where(full, done_full,
                    np.where(plan.mode == MODE_STAGE1, t_stage1, 0.0))
    if plan.alive is not None and not self.ccfg.recovery \
        and not plan.alive.all():
      # No-recovery baseline: the frontend has no ladder, so it WAITS on
      # a dead shard until a hard timeout (fault_stall_wait step
      # deadlines) before giving up on its mass — the gather both stalls
      # and drops.
      wait = plan.deadline_ms if np.isfinite(plan.deadline_ms) else wall_ms
      done = np.where(plan.alive, done,
                      self.ccfg.fault_stall_wait * max(wait, wall_ms))
    valid = np.maximum(self.comp_share * self.M, 1.0)
    frac = np.minimum(cover / valid, 1.0)
    acc_c = np.where(
        full, [self.accuracy_fn(x) for x in frac],
        np.where(plan.mode == MODE_STAGE1, self.accuracy_fn(0.0), 0.0))
    step_acc = float(np.sum(self.comp_share * acc_c))
    parallel_ms = float(max(done.max(), 1e-3))
    sharesum = max(self.comp_share.sum(), 1e-30)
    drop_share = float(np.sum(np.where(plan.mode == MODE_DROP,
                                       self.comp_share, 0.0)) / sharesum)
    retried = int(plan.retries.sum()) if plan.retries is not None \
        else int(plan.hedged.sum())
    if plan.alive is not None and not warming:
      self.fault_stats["crash_steps"] += int(not plan.alive.all())
      self.fault_stats["retries"] += retried
      self.fault_stats["stage1_fallbacks"] += int(np.sum(
          (plan.mode == MODE_STAGE1) & ~plan.alive))
      self.fault_stats["dropped"] += int(np.sum(plan.mode == MODE_DROP))
    self.step_idx += 1
    return {"parallel_ms": parallel_ms, "step_acc": step_acc,
            "wall_ms": wall_ms, "gathered": int(full.sum()),
            "hedged": int(plan.hedged.sum()), "comp_ms": done,
            "drop_share": drop_share, "retried": retried}

  def export(self, full_items: int = 100) -> "ClusterMeasuredExport":
    return ClusterMeasuredExport(self, full_items=full_items)


class ClusterMeasuredExport:
  """Measured per-component step latencies for the discrete-event
  simulator — the cluster-tier counterpart of
  `repro.serve.engine.MeasuredStepBackend`.

  ``step_ms_per_component(budget)`` returns the (N,) vector the simulator
  feeds straight into ``ComponentModel.submit(service_ms=...)`` (each
  simulated component indexes its own entry), so hot components serve in
  the time the real tier attributed to them; ``step_ms(budget)`` is the
  frontend-observed parallel completion (max over components).  Budget
  conversion follows MeasuredStepBackend: a simulator budget out of
  ``full_items`` rescales onto the tier's M clusters; the nearest
  measured bucket's predicted wall (a snapshot of the backend's
  control-plane predictor) is attributed by rows read."""

  def __init__(self, backend: ClusterStepBackend, full_items: int = 100):
    self.share = backend.comp_share.copy()
    self.massf = backend.mass_ewma / max(backend.mass_ewma.sum(), 1e-30)
    self.walls = backend.predictor.table() or {0: 5.0}
    self.M = backend.M
    self.cluster_size = backend.cfg.synopsis.cluster_size
    self.full_items = full_items
    self.n_components = backend.topo.n_components

  def step_ms_per_component(self, budget: int) -> np.ndarray:
    b = budget / max(self.full_items, 1) * self.M
    nearest = min(self.walls, key=lambda x: abs(x - b))
    u = self.share * self.M + b * self.massf * self.cluster_size
    return self.walls[nearest] * u / max(u.sum(), 1e-30)

  def step_ms(self, budget: int) -> float:
    return float(self.step_ms_per_component(budget).max())
