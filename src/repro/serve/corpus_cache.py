"""Content-addressed synopsis cache: cross-request corpus sharing
(DESIGN.md §12).

At millions of users most requests consult the *same* corpora (shared
indexes, shared system context, per-tenant document sets), yet every
admission used to prefill and rebuild its synopsis into a private slot —
re-doing the one cost the paper's offline module exists to amortise (the
synopsis is built once per corpus, then reused for arbitrary requests).
This module keys that work by **corpus identity**: the sha-256 of the
token ids plus a model/config fingerprint (same tokens under different
weights, kernel impls or shapes are different corpora).

Each entry holds a refcounted, **immutable** arena: the shared half of a
slot's synopsis cache (`kv_cache.ARENA_LEAVES` — sorted corpus k/v,
centroid tables, counts) plus the first decode token the prefill
produced.  Admission that hits the cache skips prefill and synopsis
build entirely and maps its slot to the shared arena; copy-on-write
applies only to the private half (`kv_cache.PRIVATE_LEAVES` — the
per-slot recent ring, position and SSM state), which `write_slot`
re-zeros into the lane so resident decode never touches shared state.

Append-only sessions ride the same structure: a corpus that strictly
prefix-extends a cached entry replays only the KV **delta** — a partial
prefill of the extension tokens against the cached arena's exact KV
(`prefill.make_extend_step`; sound because softmax over cached keys is
permutation-invariant and rope is applied before caching) followed by an
`absorb_recent`-style incremental build (`synopsis_kv.extend_synopsis`)
— instead of re-prefilling the whole prefix.

Eviction is LRU over refcount-zero entries only: an arena some slot
still maps stays resident whatever its age, so the cache can transiently
overshoot ``capacity`` while every entry is live (it re-converges as
slots retire).  ``CacheConfig(capacity=0)`` is the disabled no-op —
``enabled`` is False and callers guard every cache branch on it, so the
disabled path is bit-identical to a stack without the cache at all
(the `FaultPlan(None)` idiom; regression-tested in
tests/test_corpus_cache.py).
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, Optional, Tuple

import numpy as np

from repro.serve import kv_cache as kvc

__all__ = ["CacheConfig", "CacheEntry", "CorpusCache", "corpus_key",
           "corpus_fingerprint", "supports_delta"]


@dataclasses.dataclass(frozen=True)
class CacheConfig:
  """Corpus-cache knobs.

  ``capacity`` is the resident-entry target (0 = disabled no-op);
  ``capacity_bytes`` optionally bounds the arenas' total footprint too
  (0 = entries-only accounting).  ``delta_unit`` > 0 enables
  prefix-extension lookups whose extension length is a multiple of it
  (the synopsis cluster size, so the delta builds whole clusters);
  0 = exact hits only."""
  capacity: int = 0
  capacity_bytes: int = 0
  delta_unit: int = 0


@dataclasses.dataclass
class CacheEntry:
  """One published corpus: immutable shared arena + admission outputs.

  ``arena`` is the full B=1 synopsis-cache dict straight out of
  ``synopsis_kv.build`` — the shared-immutable leaves carry the data,
  the private leaves are zeros that ``write_slot`` copies into the lane
  as that slot's fresh copy-on-write half.  Callers must never mutate
  it (jax arrays are immutable; the dict is shared by reference)."""
  key: str
  tokens: np.ndarray              # (L,) int32 — the corpus identity
  arena: Dict[str, object]        # B=1 synopsis cache (shared by ref)
  first_token: object             # (1,) int32 array from the prefill
  nbytes: int                     # shared-arena footprint (ARENA_LEAVES)
  refcount: int = 0               # live slot mappings
  last_use: int = 0               # LRU tick


def corpus_key(tokens, fingerprint: str = "") -> str:
  """Content address: sha-256 over the token ids + the fingerprint."""
  t = np.ascontiguousarray(np.asarray(tokens, np.int32))
  h = hashlib.sha256()
  h.update(fingerprint.encode())
  h.update(t.shape[0].to_bytes(8, "little"))
  h.update(t.tobytes())
  return h.hexdigest()


def corpus_fingerprint(cfg, impl: str, prompt_len: int, seed: int) -> str:
  """Model/config identity folded into every key: the same token ids
  under different weights (seed), kernel impls, cluster shapes or slot
  geometry must not collide."""
  sc = cfg.synopsis
  # The quantization spec changes the arena's leaf dtypes and contents
  # (DESIGN.md §15) — int8 and f32 arenas for the same tokens must not
  # alias in the content-addressed store.
  return (f"{cfg.name}|dt={np.dtype(cfg.dtype).name if cfg.dtype is not None else cfg.dtype}"
          f"|C={sc.cluster_size}|R={sc.recent}|impl={impl}"
          f"|S={prompt_len}|seed={seed}|q={getattr(sc, 'quant', 'none')}")


def supports_delta(cfg) -> bool:
  """Prefix-extension delta replay needs attention whose cached KV is
  position-complete and order-free: plain GQA with global rope attention.
  SSM state, MLA latents, sliding windows, cross attention and frontend
  prefixes all couple the extension to un-cached prefix internals, so
  those archs fall back to the full build on a prefix-extension miss."""
  return (kvc.n_ssm_positions(cfg) == 0 and cfg.mla is None
          and cfg.encoder is None and cfg.frontend is None
          and all(s.kind == "attn" and not s.local and not s.cross_attn
                  for s in cfg.block_pattern))


class CorpusCache:
  """Content-addressed, refcounted synopsis/sorted-KV arena cache.

  Lifecycle per admission: ``lookup`` classifies the corpus (hit /
  extend / miss and bumps the counters), ``acquire`` pins the mapped
  entry for the slot's residency, ``release`` unpins at retirement, and
  a miss (or completed delta replay) ``publish``-es the freshly built
  arena — which starts at refcount 1, held by the publishing slot.
  Eviction (LRU, refcount-zero only) runs at publish time."""

  def __init__(self, config: Optional[CacheConfig] = None,
               fingerprint: str = ""):
    self.config = config or CacheConfig()
    if self.config.capacity < 0:
      raise ValueError(f"capacity {self.config.capacity} < 0")
    self.fingerprint = fingerprint
    self.entries: Dict[str, CacheEntry] = {}
    self._tick = 0
    self.reset_stats()

  # -- introspection --------------------------------------------------------
  @property
  def enabled(self) -> bool:
    return self.config.capacity > 0

  @property
  def nbytes(self) -> int:
    return sum(e.nbytes for e in self.entries.values())

  def stats(self) -> Dict[str, int]:
    """Cumulative counters since the last ``reset_stats`` (exported by
    the engine summary into benches and the simulator round-trip)."""
    looks = self._hits + self._delta_hits + self._misses
    return {"hits": self._hits, "misses": self._misses,
            "delta_hits": self._delta_hits, "evictions": self._evictions,
            "entries": len(self.entries), "bytes": self.nbytes,
            "hit_rate": (self._hits + self._delta_hits) / looks
            if looks else 0.0}

  def reset_stats(self) -> None:
    self._hits = self._misses = self._delta_hits = self._evictions = 0

  # -- lookup ---------------------------------------------------------------
  def _touch(self, e: CacheEntry) -> None:
    self._tick += 1
    e.last_use = self._tick

  def lookup(self, tokens, allow_extend: bool = True
             ) -> Tuple[str, Optional[CacheEntry]]:
    """Classify a corpus: ("hit", entry) — exact content match;
    ("extend", entry) — the longest cached strict prefix whose extension
    length divides ``delta_unit``; ("miss", None) otherwise."""
    if not self.enabled:
      return "miss", None
    t = np.asarray(tokens, np.int32)
    key = corpus_key(t, self.fingerprint)
    e = self.entries.get(key)
    if e is not None:
      self._hits += 1
      self._touch(e)
      return "hit", e
    unit = self.config.delta_unit
    if allow_extend and unit > 0:
      best = None
      for cand in self.entries.values():
        L = cand.tokens.shape[0]
        if L < t.shape[0] and (t.shape[0] - L) % unit == 0 \
            and np.array_equal(cand.tokens, t[:L]) \
            and (best is None or L > best.tokens.shape[0]):
          best = cand
      if best is not None:
        self._delta_hits += 1
        self._touch(best)
        return "extend", best
    self._misses += 1
    return "miss", None

  # -- refcounts ------------------------------------------------------------
  def acquire(self, entry: CacheEntry, n: int = 1) -> CacheEntry:
    """Pin an entry for ``n`` mappings (a ``lookup`` hit does not pin by
    itself — the caller decides whether it maps the arena).  The fleet
    tier pins R at once: one admission maps the arena onto every replica
    row, and each mapping holds its own pin so retiring one replica's
    mapping can never free an arena another replica still reads."""
    if n < 1:
      raise ValueError(f"acquire of {n} pins")
    entry.refcount += int(n)
    self._touch(entry)
    return entry

  def release(self, key: str, n: int = 1) -> None:
    """Unpin ``n`` slot mappings; the entry stays resident (warm) until
    capacity pressure evicts it.  Releasing more pins than are held
    raises — an arena must never be freed while any replica maps it."""
    if n < 1:
      raise ValueError(f"release of {n} pins")
    e = self.entries.get(key)
    if e is None:
      return                       # already evicted config change / reset
    if e.refcount < n:
      raise ValueError(
          f"release of {n} pins on entry {key[:12]} holding {e.refcount}")
    e.refcount -= int(n)

  # -- publish / evict ------------------------------------------------------
  def publish(self, tokens, arena: Dict[str, object],
              first_token) -> CacheEntry:
    """Insert a freshly built arena (refcount starts at 1 — the
    publishing slot holds the first mapping).  Publishing an already
    cached corpus pins the existing entry instead (two concurrent
    misses on one corpus converge on a single arena)."""
    if not self.enabled:
      raise ValueError("publish on a disabled cache")
    t = np.ascontiguousarray(np.asarray(tokens, np.int32)).copy()
    key = corpus_key(t, self.fingerprint)
    e = self.entries.get(key)
    if e is not None:
      return self.acquire(e)
    nbytes = sum(jax_nbytes(arena[name])
                 for name in kvc.ARENA_LEAVES if name in arena)
    e = CacheEntry(key=key, tokens=t, arena=arena,
                   first_token=first_token, nbytes=nbytes, refcount=1)
    self.entries[key] = e
    self._touch(e)
    self._evict()
    return e

  def _over_capacity(self) -> bool:
    cfg = self.config
    if len(self.entries) > cfg.capacity:
      return True
    return bool(cfg.capacity_bytes and self.nbytes > cfg.capacity_bytes)

  def _evict(self) -> None:
    """LRU over refcount-zero entries ONLY: a live arena is never
    evicted, so the cache transiently overshoots capacity when every
    entry is pinned and re-converges as slots retire."""
    while self._over_capacity():
      dead = [e for e in self.entries.values() if e.refcount == 0]
      if not dead:
        return
      victim = min(dead, key=lambda e: e.last_use)
      del self.entries[victim.key]
      self._evictions += 1

  def clear(self) -> None:
    """Drop every unpinned entry (measurement-window hygiene in benches;
    pinned entries survive — their slots still map them)."""
    for key in [k for k, e in self.entries.items() if e.refcount == 0]:
      del self.entries[key]


def jax_nbytes(x) -> int:
  """Leaf footprint for either jax or plain numpy arrays (property tests
  exercise the cache core with numpy arenas)."""
  nb = getattr(x, "nbytes", None)
  if nb is not None:
    return int(nb)
  return int(np.asarray(x).nbytes)
