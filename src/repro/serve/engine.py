"""Deadline-driven continuous-batching serving engine (DESIGN.md §8).

This closes the loop the discrete-event simulator (`repro.serving.service`)
only *models*: requests from an arrival trace (`repro.serving.workload`)
occupy slots in a shared synopsis-KV cache, and each decode step picks its
refinement budget with the same `repro.control` latency-control plane the
simulator uses (`DeadlineBudgetPolicy` over a pluggable predictor,
DESIGN.md §10) — except here the predictor is calibrated by **measured**
step wall times, so the accuracy-vs-tail-latency trade comes from the real
kernel path, not a latency model.

Slot lifecycle (DESIGN.md §8): a request is admitted to a free batch lane
(prefill -> synopsis build -> `kv_cache.write_slot`), decodes through
budgeted serve steps shared with the other resident slots (stage 1 always
runs; stage 2 refines the budget's clusters), accumulates its new tokens
in its own recent-ring position (`synopsis_kv.append_recent_slots`), and
retires when its token target is reached — freeing the lane mid-flight
for the next queued request, no lockstep batches.

Compiled-program count stays bounded the same way the simulator assumes:
budgets are bucketed (`DeadlineBudgetPolicy.buckets`), so the engine jits one
serve step per bucket plus one prefill and one build program, all warmed
before the first measured step.

Policies (the simulator's techniques, re-grounded in measured time):

  * ``basic``          — full budget every step, nothing dropped.
  * ``partial``        — full budget, but a request still resident at its
                         deadline is dropped mid-flight (lane freed, its
                         accuracy contribution lost — the paper's skipped
                         partial results), and one finishing late scores 0.
  * ``accuracytrader`` — per-step bucketed budget from the deadline
                         controller against the most urgent resident
                         request's remaining time; stage 1 always lands.
  * ``fixed``          — constant budget (tests/parity runs; ``reissue``
                         only exists in the simulator — replicating a
                         component has no single-host analogue).

`MeasuredStepBackend` exports the engine's measured per-bucket step
latencies back to the simulator (`ScatterGatherService(step_backend=...)`)
so the fleet-scale simulation runs on real component service times.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.control import (CONTRACTS, POLICIES, AccuracyEstimator,
                           AdmissionConfig, AdmissionPolicy,
                           DeadlineBudgetPolicy, TailTracker,
                           make_predictor)
from repro.control.estimator import coverage_profile
from repro.kernels import ops
from repro.models import common as cm
from repro.models import transformer as tf
from repro.serve import corpus_cache as ccache
from repro.serve import kv_cache as kvc
from repro.serve import synopsis_kv as skv
from repro.serve.corpus_cache import CacheConfig
from repro.serve.prefill import make_extend_step, make_prefill_step
from repro.serve.serve_step import make_serve_step, resolve_impl
from repro.serving.service import _default_concentration
from repro.serving.workload import poisson_arrivals


@dataclasses.dataclass
class EngineConfig:
  """Engine knobs (model shape comes from the ModelConfig)."""
  n_slots: int = 4                 # batch lanes == max resident requests
  prompt_len: int = 128            # tokens per admitted prompt
  max_new_tokens: int = 8          # decode steps per request (<= recent)
  deadline_ms: float = 80.0        # per-request service deadline
  policy: str = "accuracytrader"
  fixed_budget: int = 0            # for policy="fixed"
  impl: Optional[str] = None       # kernel impl; None -> cfg.synopsis.impl
  buckets: Optional[Sequence[int]] = None   # None -> {0, 1, 2, 4, ..., M}
  # Latency-predictor spec for the budget controller (repro.control):
  # "affine" (EW least-squares lat = base + slope*i), "ewma", or
  # "quantile[:pct]" (deadlines target a percentile of the measured
  # per-bucket step times instead of the mean).
  predictor: str = "affine"
  seed: int = 0
  # Overlap admission (prefill+build+write) of new requests with the
  # resident slots' decode step: both are dispatched without an
  # intervening block, so the runtime's async dispatch queue pipelines
  # them (ROADMAP: serialized admission was the saturation point).
  overlap_admission: bool = True
  # Queue-aware predictive admission (DESIGN.md §11,
  # `repro.control.admission`): EDF/least-slack ordering, predictive
  # shed-at-admission and SLO classes.  None = the legacy FIFO queue,
  # bit-identical to the pre-resilience engine.
  admission: Optional[AdmissionConfig] = None
  # Content-addressed corpus cache (DESIGN.md §12,
  # `repro.serve.corpus_cache`): admission consults it before prefill —
  # a hit maps the slot to a shared refcounted arena and skips
  # prefill+build entirely; a strict prefix-extension replays only the
  # KV delta.  None (or capacity 0) = disabled, bit-identical to the
  # pre-cache admission path.
  cache: Optional[CacheConfig] = None
  # ε-or-deadline serving contracts (DESIGN.md §13, `repro.control`
  # CONTRACTS): "deadline" is the legacy behavior (no estimator
  # telemetry, bit-identical to the pre-contract engine);
  # "error_bounded" refines until the online estimator predicts loss
  # <= epsilon and answers early; "deadline_with_bound" keeps the
  # legacy budgets but attaches a calibrated loss confidence band to
  # every answer.
  contract: str = "deadline"
  epsilon: float = 0.02
  band_conf: float = 0.9           # nominal coverage of the loss bands


@dataclasses.dataclass
class EngineRequest:
  rid: int
  arrival_ms: float
  prompt: np.ndarray               # (prompt_len,) int32
  max_new_tokens: int
  # Filled by the engine:
  admit_ms: float = -1.0
  finish_ms: float = -1.0
  # Measured wall of this request's own (blocking) admission — prefill
  # + build + write, or the cache hit's write-only path.  0.0 on the
  # overlapped path, where admissions share one block with the decode
  # step and have no individual wall.
  admit_wall_ms: float = 0.0
  tokens: List[int] = dataclasses.field(default_factory=list)
  budgets: List[int] = dataclasses.field(default_factory=list)
  # Per-step accuracy contributions from a cluster step backend (the
  # scatter-gather tier reports corpus-share-weighted coverage per step;
  # empty on the single-component path, which derives accuracy from
  # ``budgets``).
  step_acc: List[float] = dataclasses.field(default_factory=list)
  accuracy: float = 0.0
  dropped: bool = False            # shed mid-flight (partial execution)
  # -- resilience (DESIGN.md §11) ------------------------------------------
  slo: str = "default"             # SLO class name (admission policy)
  deadline_ms: Optional[float] = None   # per-request deadline override
  shed_admission: bool = False     # refused at admission (zero prefill)
  # Per-step dropped shard-mass fraction from a cluster backend (0 on
  # every step = the request's full corpus answered: available).
  step_drop: List[float] = dataclasses.field(default_factory=list)
  # -- serving contracts (DESIGN.md §13) -----------------------------------
  # Per-step raw online loss estimates + Verdict-style spread proxies
  # (empty under contract="deadline", where no telemetry runs).
  est_raw: List[float] = dataclasses.field(default_factory=list)
  est_spread: List[float] = dataclasses.field(default_factory=list)
  pred_loss: float = -1.0          # calibrated predicted loss at retire
  band_lo: float = 0.0             # loss confidence band (deadline_with_
  band_hi: float = 0.0             # bound / error_bounded)

  @property
  def latency_ms(self) -> float:
    return self.finish_ms - self.arrival_ms

  @property
  def queue_ms(self) -> float:
    return self.admit_ms - self.arrival_ms


@dataclasses.dataclass
class _Slot:
  req: EngineRequest
  remaining: int


class ServingEngine:
  """Continuous-batching AccuracyTrader engine over the kernel serve path.

  ``accuracy_fn`` maps the fraction of ranked clusters refined in a step
  to result accuracy; the default is the simulator's fig-4 concentration
  curve, so engine and simulator report on the same scale."""

  def __init__(self, cfg: cm.ModelConfig, ecfg: EngineConfig,
               params=None,
               accuracy_fn: Optional[Callable[[float], float]] = None,
               backend=None, estimator: Optional[AccuracyEstimator] = None):
    if kvc.n_attn_positions(cfg) == 0:
      raise ValueError(f"{cfg.name}: no attention positions — nothing to "
                       "synopsize (DESIGN.md §5); use mode='exact' serving")
    C = cfg.synopsis.cluster_size
    if ecfg.prompt_len % C != 0:
      raise ValueError(f"prompt_len {ecfg.prompt_len} % cluster_size {C}")
    if ecfg.max_new_tokens > cfg.synopsis.recent:
      raise ValueError(
          f"max_new_tokens {ecfg.max_new_tokens} > recent ring "
          f"{cfg.synopsis.recent}: a slot's decode residency must fit the "
          "ring (absorb_recent is a whole-cache offline program)")
    if ecfg.policy not in POLICIES:
      raise ValueError(f"policy {ecfg.policy!r} not in {POLICIES}")
    self.cfg = cfg
    self.ecfg = ecfg
    self.M = ecfg.prompt_len // C
    self.impl = resolve_impl(ecfg.impl if ecfg.impl is not None
                             else cfg.synopsis.impl)
    if ecfg.buckets is not None:
      buckets = tuple(sorted({int(b) for b in ecfg.buckets}))
    else:
      buckets = [0]
      b = 1
      while b < self.M:
        buckets.append(b)
        b *= 2
      buckets = tuple(buckets + [self.M])
    if any(b < 0 or b > self.M for b in buckets):
      raise ValueError(f"buckets {buckets} outside [0, M={self.M}]")
    self.buckets = buckets
    if ecfg.policy == "fixed" and ecfg.fixed_budget not in buckets:
      self.buckets = tuple(sorted(set(buckets) | {ecfg.fixed_budget}))
    self.accuracy_fn = accuracy_fn or _default_concentration
    # ε-or-deadline serving contracts (DESIGN.md §13): the online
    # accuracy estimator and the step telemetry feeding it.  One
    # estimator instance per engine unless the caller shares one (the
    # calibration bench fits a single estimator across fixed-budget
    # arms and then serves error_bounded from the same knots).
    if ecfg.contract not in CONTRACTS:
      raise ValueError(f"contract {ecfg.contract!r} not in {CONTRACTS}")
    self.contract = ecfg.contract
    self.estimator = estimator if estimator is not None else \
        AccuracyEstimator(
            floor=max(1.0 - float(self.accuracy_fn(0.0)), 0.0),
            conf=ecfg.band_conf)
    # Telemetry (the stage-1 coverage profile threaded out of the step)
    # only runs under the new contracts: contract="deadline" keeps every
    # legacy step program bit-identical to the pre-contract engine.
    self._telemetry = self.contract != "deadline"
    self._profile_prior: Optional[np.ndarray] = None
    # Optional scatter-gather step backend (repro.serve.cluster,
    # DESIGN.md §9): owns the component cache layout, the per-step gather
    # plan and the measured per-component latency attribution.  Bound
    # BEFORE the policy is built: the budget controller shares the
    # backend's wall predictor (one predictor, one truth — see
    # _make_policy).
    self.backend = backend
    if backend is not None:
      backend.bind(self)
    self.controller = self._make_policy()
    # Queue-aware predictive admission (DESIGN.md §11): deadline
    # resolution, EDF/least-slack ordering, token buckets and
    # shed-at-admission.  None = the legacy FIFO path.
    self.admission = None
    if ecfg.admission is not None:
      self.admission = AdmissionPolicy(ecfg.admission, ecfg.deadline_ms,
                                       self._demand_ms)
    self._admit_ms_ewma = 0.0
    self.prefills = 0
    # Content-addressed corpus cache (DESIGN.md §12): shared arenas keyed
    # on token ids + a model/config fingerprint.  Disabled (capacity 0 /
    # None) it is a pure no-op — every branch below guards on `enabled`.
    self.corpus_cache = ccache.CorpusCache(
        ecfg.cache,
        fingerprint=ccache.corpus_fingerprint(cfg, self.impl,
                                              ecfg.prompt_len, ecfg.seed))
    from repro.kernels.quant import parse_qconfig  # noqa: PLC0415
    # Delta replay re-attends over the cached corpus k/v; the "+kv"
    # quantized arenas store those rows as int8 blocks whose scales are
    # cluster-granular, so the extension path would need a dequantized
    # materialization — disable extends and take plain hits/misses.
    self._delta_ok = (ccache.supports_delta(cfg)
                      and not parse_qconfig(
                          getattr(cfg.synopsis, "quant", "none")).sorted_kv)
    self._slot_entry: List[Optional[str]] = [None] * ecfg.n_slots
    # Fleet tier (DESIGN.md §14): one admission maps the arena onto R
    # replica rows and each mapping holds its own pin, so retiring one
    # replica's mapping can never free an arena another still reads.
    self._map_count = int(getattr(backend, "replica_mappings", 1)) \
        if backend is not None else 1

    if params is None:
      params, _ = cm.split(tf.init_model(jax.random.PRNGKey(ecfg.seed), cfg))
      params = jax.tree.map(lambda p: p.astype(cfg.dtype), params)
    self.params = params

    self._prefill = jax.jit(make_prefill_step(cfg, impl=self.impl))
    self._build = jax.jit(lambda c: skv.build(c, cfg, impl=self.impl))
    # Delta-replay programs (prefix-extension cache hits): jitted lazily
    # on the first extend admission; jax re-specializes per (P, E) shape.
    self._extend = jax.jit(make_extend_step(cfg, impl=self.impl)) \
        if self._delta_ok else None
    self._extend_build = jax.jit(
        lambda a, k, v: skv.extend_synopsis(a, k, v, cfg, impl=self.impl))
    self._bx = kvc.slot_batch_axes(cfg, ecfg.n_slots, ecfg.prompt_len,
                                   synopsis=True)
    bx = self._bx
    if backend is not None:
      self._write = backend.write_slot
    else:
      self._write = jax.jit(
          lambda cache, sub, slot: kvc.write_slot(cache, sub, slot, bx))
    self._append = jax.jit(skv.append_recent_slots)
    self._step_cache: Dict[int, Callable] = {}
    self._warming = False
    self._warm_syn = None

    self.reset()
    self._warmup()

  def _make_policy(self) -> DeadlineBudgetPolicy:
    """The engine's slice of the control plane: one DeadlineBudgetPolicy
    whose predictor is calibrated by measured step wall times.

    With a cluster backend the policy REUSES the backend's wall
    predictor instead of fitting its own affine model (one predictor,
    one truth): the backend observes the raw program wall per bucket in
    ``account`` — a conservative upper bound on the parallel completion
    the clock advances by — and the budget controller's slow-start
    handles its non-extrapolating bucket table.  The engine then never
    observes the predictor itself (see ``_decode_step``): one
    observation stream, no double counting."""
    e = self.ecfg
    shared = getattr(self.backend, "predictor", None) \
        if self.backend is not None else None
    if shared is not None:
      pred = shared
    else:
      kw = {"base": 2.0, "slope": 0.5, "alpha": 0.1} \
          if e.predictor.startswith("affine") else {}
      pred = make_predictor(e.predictor, **kw)
    return DeadlineBudgetPolicy(
        policy=e.policy, buckets=self.buckets, i_max_cap=self.M,
        predictor=pred, fixed_budget=e.fixed_budget,
        contract=self.contract, epsilon=e.epsilon,
        estimator=self.estimator)

  # -- state ----------------------------------------------------------------
  def reset(self, reset_controller: bool = False) -> None:
    """Fresh slots/cache/clock for a new measurement window.  The latency
    model persists across windows by default (as in the simulator's
    ``run_open_loop``)."""
    e = self.ecfg
    if self.backend is not None:
      self.cache = self.backend.zeros_cache()
    else:
      self.cache = kvc.zeros_cache(self.cfg, e.n_slots, e.prompt_len,
                                   synopsis=True)
    self.tok = jnp.zeros((e.n_slots, 1), jnp.int32)
    self.slots: List[Optional[_Slot]] = [None] * e.n_slots
    self.now_ms = 0.0
    self.completed: List[EngineRequest] = []
    self.events: List[Tuple[str, int, int, float]] = []
    self.step_log: List[Tuple[int, float, int]] = []   # (budget, ms, active)
    self.prefills = 0
    # The corpus cache persists across windows like the latency model
    # (warm arenas are the point); only the per-window counters and the
    # retiring slots' pins reset.
    for key in getattr(self, "_slot_entry", []):
      if key is not None:
        self.corpus_cache.release(key, self._map_count)
    self._slot_entry = [None] * e.n_slots
    self.corpus_cache.reset_stats()
    # Per-window contract telemetry resets; the estimator's calibration
    # and the coverage-profile prior persist like the latency model.
    self._slot_profile: List[Optional[np.ndarray]] = [None] * e.n_slots
    self._freed_log: List[int] = []
    if getattr(self, "admission", None) is not None:
      self.admission.reset()
    if reset_controller:
      self.controller = self._make_policy()

  def _step_fn(self, budget: int):
    if budget not in self._step_cache:
      if self.backend is not None:
        self._step_cache[budget] = self.backend.step_fn(budget)
      else:
        attn = self._telemetry_attention if self._telemetry else None
        self._step_cache[budget] = jax.jit(make_serve_step(
            self.cfg, mode="synopsis", i_max=budget, impl=self.impl,
            attention_fn=attn))
    return self._step_cache[budget]

  def _telemetry_attention(self, q, csl, *, i_max, cluster_size, sm_scale,
                           cap=None, self_kv=None, impl="xla"):
    """Single-component synopsis decode attention with the stage-1
    coverage profile (DESIGN.md §13) threaded out as aux telemetry.
    Mirrors `ops.synopsis_cache_attention` stage for stage — same
    kernels, same selection, same merge — so tokens stay bit-identical
    to the non-telemetry path (ε=0 parity is asserted in
    tests/test_estimator.py); the profile reuses the stage-1 scores the
    step already computed, no extra passes over KV."""
    B = q.shape[0]
    Hkv, M = csl["k_syn"].shape[1], csl["k_syn"].shape[2]
    scores, p_syn = ops.synopsis_stage1(
        q, csl["k_syn"], csl["v_syn"], csl["counts"], sm_scale=sm_scale,
        cap=cap, impl=impl)
    if i_max > 0:
      _, selected = jax.lax.top_k(scores, min(i_max, M))
      selected = selected.astype(jnp.int32)
    else:
      selected = jnp.full((B, Hkv, 1), -1, jnp.int32)
    extras = ops.build_extras(csl.get("recent_k"), csl.get("recent_v"),
                              csl.get("recent_len"), self_kv)
    p_ref = ops.refine_stage2(
        q, csl["k"], csl["v"], selected, csl["k_syn"], csl["v_syn"],
        csl["counts"], cluster_size=cluster_size, sm_scale=sm_scale,
        cap=cap, impl=impl, extras=extras)
    out, _, _ = ops.merge_partials(p_syn, p_ref)
    return out, {"est_profile": coverage_profile(scores, csl["counts"])}

  def _warm_buckets(self) -> Sequence[int]:
    p = self.ecfg.policy
    # error_bounded can answer early at ANY bucket (the estimator's
    # min with the policy base), so every bucket's program must be warm.
    if p == "accuracytrader" or self.contract == "error_bounded":
      return self.buckets
    if p == "fixed":
      return (self.ecfg.fixed_budget,)
    return (self.M,)

  def _warmup(self) -> None:
    """Compile every program the run can dispatch (one serve step per
    bucket + prefill + build + the slot writes) by driving the *real*
    admit/step paths on a dummy request, so measured latencies are
    steady-state from the first trace request; warmup state is then
    discarded and never observed by the controller.

    Each bucket is driven TWICE, re-writing the warm slot in between:
    a step consuming a freshly *written* cache and one consuming the
    previous step's *append*-produced cache are distinct jit signatures
    (output shardings/layouts differ, especially with a shard_map-ing
    backend), and an unwarmed signature would recompile mid-window and
    pollute the first measured latencies."""
    self._warming = True
    warm = self._warm_buckets()
    req = EngineRequest(rid=-1, arrival_ms=0.0,
                        prompt=np.zeros((self.ecfg.prompt_len,), np.int32),
                        max_new_tokens=2 * len(warm) + 1)
    self._admit(req, 0)
    for i, b in enumerate(warm):
      self._decode_step([0], budget=b)     # post-write cache lineage
      self._decode_step([0], budget=b)     # post-append cache lineage
      if i < len(warm) - 1:
        self.cache = self._write(self.cache, self._warm_syn, 0)
    # A throwaway mini-window through the real run() loop: admission
    # bursts, retire/re-admit and the post-retire step compose cache
    # lineages the enumeration above cannot, and any leftover signature
    # must compile NOW, not inside the first measured window.  Arrivals
    # are STAGGERED (not all at t=0) so later requests land while a
    # resident slot is decoding — that drives the overlapped-admission
    # path, whose step-reads-pre-admission-cache / append-onto-written-
    # cache composition is its own jit signature.
    self.reset()
    mini = [EngineRequest(
        rid=-2 - i, arrival_ms=float(i),
        prompt=np.zeros((self.ecfg.prompt_len,), np.int32),
        max_new_tokens=min(2, self.ecfg.max_new_tokens))
        for i in range(min(2, self.ecfg.n_slots) + 1)]
    self.run(mini)
    self._warm_syn = None
    self._warming = False
    self.reset()

  # -- scheduling -----------------------------------------------------------
  def _dispatch_admission(self, req: EngineRequest, slot: int, cache):
    """Dispatch one admission's prefill -> build -> slot-write chain
    WITHOUT blocking; returns (first-token array, written cache).  Both
    the serial and the overlapped admission paths go through here.

    With the corpus cache enabled (DESIGN.md §12) the chain is consulted
    first: an exact hit skips prefill AND build — only the slot write is
    dispatched, mapping the lane onto the shared arena (the private
    recent-ring half is zeros in the arena, so the lane starts its own
    copy-on-write decode state); a strict prefix-extension replays only
    the extension's KV delta; a miss runs the full chain and publishes
    the arena for subsequent admissions.  Warmup bypasses the cache
    entirely — its dummy all-zero prompts would otherwise alias one
    corpus and skip compiling the prefill/build programs."""
    cc = self.corpus_cache
    use_cache = cc.enabled and not self._warming
    if use_cache:
      kind, entry = cc.lookup(req.prompt, allow_extend=self._delta_ok)
      if kind == "hit":
        cc.acquire(entry, self._map_count)
        self._slot_entry[slot] = entry.key
        return entry.first_token, self._write(cache, entry.arena, slot)
      if kind == "extend":
        first, new_entry = self._delta_admit(entry, req.prompt)
        if self._map_count > 1:       # publish holds the first mapping
          cc.acquire(new_entry, self._map_count - 1)
        self._slot_entry[slot] = new_entry.key
        return first, self._write(cache, new_entry.arena, slot)
    prompt = jnp.asarray(req.prompt, jnp.int32)[None]
    self.prefills += 1
    logits, cache1 = self._prefill(self.params, prompt)
    syn = self._build(cache1)
    if self._warming:
      self._warm_syn = syn       # reused to warm re-write cache lineages
    first = jnp.argmax(logits, -1).astype(jnp.int32)          # (1,)
    if use_cache:
      entry = cc.publish(req.prompt, syn, first)
      if self._map_count > 1:         # publish holds the first mapping
        cc.acquire(entry, self._map_count - 1)
      self._slot_entry[slot] = entry.key
    cache = self._write(cache, syn, slot)
    return first, cache

  def _delta_admit(self, entry, prompt) -> Tuple[jax.Array, object]:
    """Prefix-extension replay: run only the extension tokens against the
    cached arena's sorted prefix KV (`prefill.make_extend_step`), grow
    the synopsis incrementally (`synopsis_kv.extend_synopsis`), publish
    the extended corpus as its own entry.  No full prefill is dispatched
    — ``self.prefills`` does not move; the cache counts it as a
    delta hit."""
    t = np.asarray(prompt, np.int32)
    L = int(entry.tokens.shape[0])
    ext = jnp.asarray(t[L:], jnp.int32)[None]
    logits, (k_new, v_new) = self._extend(
        self.params, ext, entry.arena["k"], entry.arena["v"],
        jnp.int32(L))
    arena = self._extend_build(entry.arena, k_new, v_new)
    first = jnp.argmax(logits, -1).astype(jnp.int32)
    return first, self.corpus_cache.publish(t, arena, first)

  def _admit(self, req: EngineRequest, slot: int) -> None:
    # queue_ms measures pure waiting: the clock *before* this request's
    # own prefill+build advances it.
    req.admit_ms = self.now_ms
    t0 = time.perf_counter()
    first, self.cache = self._dispatch_admission(req, slot, self.cache)
    self.tok = self.tok.at[slot, 0].set(first[0])
    jax.block_until_ready((self.cache, self.tok))
    dt = (time.perf_counter() - t0) * 1e3
    self.now_ms += dt
    req.admit_wall_ms = dt
    # Admission-cost EWMA: the fixed part of the demand estimate the
    # predictive shed uses (_demand_ms).
    if not self._warming:
      self._admit_ms_ewma = dt if self._admit_ms_ewma == 0.0 \
          else 0.7 * self._admit_ms_ewma + 0.3 * dt
    req.tokens.append(int(first[0]))
    self._slot_profile[slot] = None
    self.slots[slot] = _Slot(req, req.max_new_tokens)
    self.events.append(("admit", req.rid, slot, self.now_ms))

  def _pick_budget(self, active: Sequence[int],
                   extra: Sequence[EngineRequest] = ()) -> int:
    """``extra``: requests being admitted concurrently with this step
    (admission overlap) — not decoding yet, but the step stands between
    them and their first token, so their deadlines clamp the budget the
    same way they would on the serial path."""
    e = self.ecfg
    remaining = 0.0
    if e.policy == "accuracytrader":
      remaining = min(
          [self._abs_deadline(self.slots[i].req) - self.now_ms
           for i in active] +
          [self._abs_deadline(r) - self.now_ms for r in extra])
    if self.contract == "error_bounded":
      granted, base = self.controller.budget_for_contract(
          max(remaining, 0.0),
          profiles=[self._request_profile(i) for i in active])
      if not self._warming:
        self._freed_log.append(base - granted)
      return granted
    return self.controller.budget_for(max(remaining, 0.0))

  def _request_profile(self, slot: int) -> np.ndarray:
    """Latest measured coverage profile for the request in ``slot``: its
    own last step's profile when one exists, else the EWMA prior over
    recent steps (a freshly admitted request has not scored its synopsis
    yet), else the uniform profile — every cluster equally useful, the
    most conservative monotone assumption."""
    p = self._slot_profile[slot]
    if p is not None:
      return p
    if self._profile_prior is not None:
      return self._profile_prior
    return np.linspace(0.0, 1.0, self.M + 1)

  def _deadline_of(self, req: EngineRequest) -> float:
    """Per-request deadline: explicit override > SLO class (admission
    policy) > the engine default — one resolution rule everywhere
    (budget, step deadline, partial shed, summary accounting)."""
    if self.admission is not None:
      return self.admission.deadline_for(req)
    if req.deadline_ms is not None:
      return float(req.deadline_ms)
    return self.ecfg.deadline_ms

  def _abs_deadline(self, req: EngineRequest) -> float:
    return req.arrival_ms + self._deadline_of(req)

  def _demand_ms(self, req: EngineRequest) -> float:
    """Lower-bound service-demand estimate at arrival (the predictive
    shed's input): the admission-cost EWMA plus one smallest-bucket
    (stage-1-only) predicted step wall per decode token.  A lower bound
    by construction — real steps only refine MORE — so at low load no
    feasible request is ever shed (tests/test_resilience.py)."""
    floor = self.controller.predictor.predict(self.buckets[0])
    return self._admit_ms_ewma + req.max_new_tokens * floor

  def _retire(self, slot: int) -> None:
    s = self.slots[slot]
    req = s.req
    req.finish_ms = self.now_ms
    # Unpin the slot's shared-arena mapping (the entry stays resident,
    # warm for the next admission, until capacity pressure evicts it).
    if self._slot_entry[slot] is not None:
      self.corpus_cache.release(self._slot_entry[slot], self._map_count)
      self._slot_entry[slot] = None
    req.dropped = s.remaining > 0      # shed mid-flight, not finished
    e = self.ecfg
    # With a cluster backend, each step reported the corpus-share-weighted
    # accuracy of its gather (components refined / stage-1 floor / skipped).
    stepwise = float(np.mean(req.step_acc)) if req.step_acc else None
    if e.policy == "basic":
      req.accuracy = stepwise if stepwise is not None else 1.0
    elif e.policy == "partial":
      # Partial execution: a result missing at the deadline is skipped —
      # its entire accuracy contribution is lost (paper §5).
      if req.dropped or req.latency_ms > self._deadline_of(req):
        req.accuracy = 0.0
      else:
        req.accuracy = stepwise if stepwise is not None else 1.0
    elif stepwise is not None:
      req.accuracy = stepwise
    else:
      # Stage 1 always landed; each step covered budget/M of the ranked
      # clusters exactly plus the synopsis estimate of the rest.
      fr = [min(b, self.M) / self.M for b in req.budgets] or [0.0]
      req.accuracy = float(np.mean([self.accuracy_fn(f) for f in fr]))
    # Contract outputs (DESIGN.md §13): the calibrated loss prediction
    # and its confidence band, from the request's own step telemetry.
    if self._telemetry and req.est_raw:
      raw = float(np.mean(req.est_raw))
      req.pred_loss = float(self.estimator.predict(raw))
      req.band_lo, req.band_hi = self.estimator.band(
          raw, spread=float(np.mean(req.est_spread)))
    self._slot_profile[slot] = None
    self.slots[slot] = None
    self.completed.append(req)
    self.events.append(("retire", req.rid, slot, self.now_ms))

  def _step_deadline(self, active: Sequence[int]) -> float:
    """Per-step deadline slice for the cluster frontend's gather decision:
    the most urgent resident request's remaining time, spread over its
    remaining decode steps."""
    vals = [max(self._abs_deadline(self.slots[i].req) - self.now_ms,
                0.0) / max(self.slots[i].remaining, 1) for i in active]
    return min(vals) if vals else float("inf")

  def _decode_step(self, active: Sequence[int],
                   budget: Optional[int] = None,
                   write_cache=None) -> None:
    """One budgeted decode step for the ``active`` slots.  ``write_cache``
    (admission overlap) supplies the cache the step's updates land on:
    the step itself reads the pre-admission cache — active lanes are
    identical in both — while freshly admitted lanes ride in via the
    write chain, all blocked once."""
    if budget is None:
      budget = self._pick_budget(active)
    e = self.ecfg
    plan = None
    if self.backend is not None:
      deadline = self._step_deadline(active) if not self._warming \
          else float("inf")
      plan = self.backend.plan_step(budget, deadline)
    step = self._step_fn(budget)
    t0 = time.perf_counter()
    if plan is not None:
      logits, st = step(self.params, self.cache, self.tok, plan.fe_mode)
    else:
      logits, st = step(self.params, self.cache, self.tok)
    new_tok = jnp.argmax(logits, -1).astype(jnp.int32)        # (n_slots,)
    mask = np.zeros((self.ecfg.n_slots,), bool)
    mask[list(active)] = True
    amask = jnp.asarray(mask)
    target = write_cache if write_cache is not None else self.cache
    self.cache = self._append(target, st["k_delta"], st["v_delta"],
                              amask)
    self.cache["pos"] = jnp.where(amask, st["pos"], self.cache["pos"])
    # Hybrid archs: SSM decode state advances every step too (per-slot).
    for name in ("conv_state", "ssd_state"):
      if name in st:
        shape = [1] * self.cache[name].ndim
        shape[self._bx[name]] = self.ecfg.n_slots
        m = amask.reshape(shape)
        self.cache[name] = jnp.where(m, st[name], self.cache[name])
    self.tok = jnp.where(amask[:, None], new_tok[:, None], self.tok)
    jax.block_until_ready((self.cache, self.tok))
    dt = (time.perf_counter() - t0) * 1e3
    step_acc = None
    step_drop = None
    if plan is not None:
      info = self.backend.account(budget, dt, plan, st,
                                  warming=self._warming)
      dt = info["parallel_ms"]       # the frontend-observed completion
      step_acc = info["step_acc"]
      step_drop = info.get("drop_share")
    self.now_ms += dt
    # With a cluster backend the shared predictor was already calibrated
    # inside account (one predictor, one observation stream); the engine
    # only observes its own predictor on the single-component path.
    if self.ecfg.policy == "accuracytrader" and not self._warming \
        and write_cache is None and self.backend is None:
      self.controller.observe(budget, dt)
    self.step_log.append((budget, dt, len(active)))
    # Contract telemetry (DESIGN.md §13): the per-layer coverage
    # profiles threaded out of the scan, averaged over layers — this
    # step's measured signal for next step's ε decision and for each
    # request's running raw-loss estimate.
    prof = None
    if self._telemetry and "est_profile" in st:
      prof = np.asarray(st["est_profile"], np.float64)
      prof = prof.reshape(-1, self.ecfg.n_slots, prof.shape[-1]).mean(0)
      for i in active:
        self._slot_profile[i] = prof[i]
      mean_prof = prof[list(active)].mean(0)
      self._profile_prior = mean_prof if self._profile_prior is None \
          else 0.7 * self._profile_prior + 0.3 * mean_prof
    toks = np.asarray(new_tok)
    for i in active:
      s = self.slots[i]
      s.req.tokens.append(int(toks[i]))
      s.req.budgets.append(budget)
      if step_acc is not None:
        s.req.step_acc.append(step_acc)
      if step_drop is not None:
        s.req.step_drop.append(step_drop)
      if prof is not None:
        s.req.est_raw.append(self.estimator.raw_loss(prof[i], budget))
        s.req.est_spread.append(
            self.estimator.spread_from_profile(prof[i], budget))
      s.remaining -= 1
      if s.remaining <= 0:
        self._retire(i)

  # -- driving --------------------------------------------------------------
  def run(self, requests: Sequence[EngineRequest]) -> Dict[str, float]:
    """Drive the engine over an arrival trace; returns the window summary.

    The clock is hybrid: arrivals advance on the trace's clock, service
    advances by *measured* wall time of each dispatched program — so
    queueing delay under load is real, not modelled."""
    pending = collections.deque(
        sorted(requests, key=lambda r: (r.arrival_ms, r.rid)))
    if self.admission is not None:
      return self._run_admission(pending)
    while pending or any(s is not None for s in self.slots):
      if self.ecfg.policy == "partial":
        # Partial execution sheds unfinished work AT the deadline: the
        # result is skipped (accuracy 0 via _retire) and the lane frees
        # for the queue — a doomed request must not keep burning steps.
        for i, s in enumerate(self.slots):
          if s is not None and self.now_ms >= self._abs_deadline(s.req):
            self._retire(i)
      # Every arrived request that fits a free lane is admitted this
      # iteration — overlapped with the residents' decode step when
      # possible, else serially.
      free = [i for i, s in enumerate(self.slots) if s is None]
      admissions = []
      while free and pending and pending[0].arrival_ms <= self.now_ms:
        admissions.append((pending.popleft(), free.pop(0)))
      active = [i for i, s in enumerate(self.slots) if s is not None]
      # Overlap applies to the local single-component path only: the
      # cluster backend advances the clock by the *modelled parallel*
      # step completion, which would hide the admissions' real wall time
      # if they were folded into the same measured window.
      if admissions and active and self.ecfg.overlap_admission \
          and self.backend is None:
        self._admit_overlapped(admissions, active)
        continue
      for req, slot in admissions:
        self._admit(req, slot)
      active = [i for i, s in enumerate(self.slots) if s is not None]
      if not active:
        if not pending:
          break
        # Idle: jump to the next arrival.
        self.now_ms = max(self.now_ms, pending[0].arrival_ms)
        continue
      self._decode_step(active)
    return self.summary()

  def _shed(self, req: EngineRequest) -> None:
    """Refuse a request at admission (predicted dead, DESIGN.md §11):
    zero prefill, zero decode steps, the lane goes to a request that can
    still make its deadline.  Scores 0 accuracy and counts as dropped —
    the same book-keeping as a mid-flight partial-execution shed, minus
    all the burned work."""
    req.finish_ms = max(self.now_ms, req.arrival_ms)
    req.dropped = True
    req.shed_admission = True
    req.accuracy = 0.0
    self.completed.append(req)
    self.events.append(("shed", req.rid, -1, self.now_ms))

  def _run_admission(self, pending) -> Dict[str, float]:
    """The ``run`` loop under an :class:`AdmissionPolicy` (DESIGN.md
    §11): arrivals land in a *ready* queue; each iteration rate-gates
    them (token bucket per SLO class — over-rate requests WAIT, they are
    not shed), sheds the predicted-dead (now + estimated demand already
    past the deadline), orders the survivors by the configured key
    (EDF / least-slack / FIFO) and admits into free lanes.  Everything
    downstream (decode, retire, overlap) is the standard path."""
    ready: List[EngineRequest] = []
    while pending or ready or any(s is not None for s in self.slots):
      if self.ecfg.policy == "partial":
        for i, s in enumerate(self.slots):
          if s is not None and self.now_ms >= self._abs_deadline(s.req):
            self._retire(i)
      while pending and pending[0].arrival_ms <= self.now_ms:
        ready.append(pending.popleft())
      kept, gated = [], []
      for r in ready:
        if not self.admission.rate_admit(r, self.now_ms):
          gated.append(r)           # waits for its class's token bucket
        elif self.admission.predicted_dead(r, self.now_ms):
          self._shed(r)
        else:
          kept.append(r)
      kept.sort(key=lambda r: self.admission.key(r, self.now_ms))
      free = [i for i, s in enumerate(self.slots) if s is None]
      admissions = []
      while free and kept:
        admissions.append((kept.pop(0), free.pop(0)))
      ready = kept + gated
      active = [i for i, s in enumerate(self.slots) if s is not None]
      if admissions and active and self.ecfg.overlap_admission \
          and self.backend is None:
        self._admit_overlapped(admissions, active)
        continue
      for req, slot in admissions:
        self._admit(req, slot)
      active = [i for i, s in enumerate(self.slots) if s is not None]
      if not active:
        if ready:
          # Only rate-gated requests remain resident (every eligible one
          # was admitted — all lanes were free): advance until their
          # token bucket refills (1 ms quanta keep this deterministic).
          self.now_ms += 1.0
        elif pending:
          self.now_ms = max(self.now_ms, pending[0].arrival_ms)
        else:
          break
        continue
      self._decode_step(active)
    return self.summary()

  def _admit_overlapped(self, admissions, active: Sequence[int]) -> None:
    """Admission/decode overlap (ROADMAP Perf): dispatch the admitted
    requests' prefill + synopsis build + slot writes WITHOUT blocking,
    dispatch the residents' decode step behind them (the step reads the
    pre-admission cache; its updates land on the written one), and block
    once for the whole window inside ``_decode_step`` — the runtime's
    async dispatch queue pipelines admission with decode instead of
    serializing a blocking admit per request."""
    t_admit = self.now_ms
    budget = self._pick_budget(active, extra=[r for r, _ in admissions])
    cache_adm = self.cache
    firsts = []
    for req, slot in admissions:
      req.admit_ms = t_admit
      first, cache_adm = self._dispatch_admission(req, slot, cache_adm)
      firsts.append(first)
    self._decode_step(active, budget=budget, write_cache=cache_adm)
    for (req, slot), first in zip(admissions, firsts):
      self.tok = self.tok.at[slot, 0].set(first[0])
      req.tokens.append(int(first[0]))
      self._slot_profile[slot] = None
      self.slots[slot] = _Slot(req, req.max_new_tokens)
      self.events.append(("admit", req.rid, slot, self.now_ms))

  def _class_stats(self, reqs: Sequence[EngineRequest]) -> Dict[str, float]:
    """Accounting over one request subset; latency percentiles and
    accuracy cover *served* requests only (an admission-shed request has
    no service latency — it was never served), while shed/goodput cover
    the whole subset, so per-class stats sum to the aggregate."""
    served = [r for r in reqs if not r.shed_admission]
    tracker = TailTracker()
    for r in served:
      tracker.observe(r.latency_ms)
    s = tracker.summary()
    accs = [r.accuracy for r in served]
    s["accuracy_loss_pct"] = 100.0 * (1.0 - float(np.mean(accs))) \
        if accs else 0.0
    s["deadline_miss_pct"] = 100.0 * float(np.mean(
        [r.latency_ms > self._deadline_of(r) for r in served])) \
        if served else 0.0
    s["queue_p99"] = float(np.percentile(
        [r.queue_ms for r in served], 99)) if served else 0.0
    s["shed_pct"] = 100.0 * float(np.mean(
        [r.dropped for r in reqs])) if reqs else 0.0
    s["shed_admission_n"] = sum(r.shed_admission for r in reqs)
    s["served_n"] = len(served)
    # Goodput: requests actually answered within their own deadline.
    s["goodput_n"] = sum(1 for r in served if not r.dropped
                         and r.latency_ms <= self._deadline_of(r))
    # Availability: a served request whose every step answered its full
    # shard mass (no component dropped — stage-1 fallback still counts
    # as answered; DESIGN.md §11).
    s["availability_pct"] = 100.0 * float(np.mean(
        [not r.dropped and all(d <= 0.0 for d in r.step_drop)
         for r in served])) if served else 100.0
    for p in (10, 50, 90):
      s[f"acc_p{p}"] = float(np.percentile(accs, p)) if accs else 0.0
    return s

  def summary(self) -> Dict[str, float]:
    s = self._class_stats(self.completed)
    s["mean_budget"] = float(np.mean([b for b, _, _ in self.step_log])) \
        if self.step_log else 0.0
    s["steps"] = len(self.step_log)
    s["prefills"] = self.prefills
    # Per-request admission wall percentiles (serial admissions only —
    # the overlapped path shares one block with the decode step and has
    # no per-request wall).  The hit-vs-miss gap here is the corpus
    # cache's headline number (BENCH_cache.json).
    walls = [r.admit_wall_ms for r in self.completed
             if not r.shed_admission and r.admit_wall_ms > 0.0]
    s["admission_p50"] = float(np.percentile(walls, 50)) if walls else 0.0
    s["admission_p99"] = float(np.percentile(walls, 99)) if walls else 0.0
    if self.corpus_cache.enabled:
      cst = self.corpus_cache.stats()
      for name in ("hits", "misses", "delta_hits", "evictions", "entries",
                   "bytes"):
        s[f"cache_{name}"] = float(cst[name])
      s["cache_hit_rate"] = float(cst["hit_rate"])
    s["goodput_per_s"] = s["goodput_n"] / (self.now_ms / 1e3) \
        if self.now_ms > 0 else 0.0
    # Contract accounting (DESIGN.md §13): prediction quality against
    # the measured loss, band coverage at the stated confidence, and the
    # budget error_bounded freed per step vs the policy's base grant.
    if self._telemetry:
      served = [r for r in self.completed
                if not r.shed_admission and r.est_raw]
      preds = np.asarray([r.pred_loss for r in served], np.float64)
      meas = np.asarray([1.0 - r.accuracy for r in served], np.float64)
      s["pred_loss_mean"] = float(preds.mean()) if len(preds) else 0.0
      s["pred_loss_mae"] = float(np.abs(preds - meas).mean()) \
          if len(preds) else 0.0
      s["band_cover_pct"] = 100.0 * float(np.mean(
          [r.band_lo - 1e-9 <= m <= r.band_hi + 1e-9
           for r, m in zip(served, meas)])) if served else 0.0
      s["freed_budget_mean"] = float(np.mean(self._freed_log)) \
          if self._freed_log else 0.0
    # Per-SLO-class breakdown (DESIGN.md §11): every completed request
    # belongs to exactly one class, so the per-class counts partition the
    # aggregate (tests/test_resilience.py asserts the sums).
    names = sorted({r.slo for r in self.completed})
    if names != ["default"] and names:
      s["classes"] = {
          name: self._class_stats([r for r in self.completed
                                   if r.slo == name])
          for name in names}
    return s

  # -- probes ---------------------------------------------------------------
  def probe_step_ms(self, budget: int, iters: int = 3) -> float:
    """Median measured latency of one bucketed serve step on the current
    resident cache (state is not mutated) — the calibration source for
    :class:`MeasuredStepBackend`."""
    if budget not in self.buckets:
      raise ValueError(f"budget {budget} not a bucket {self.buckets}")
    step = self._step_fn(budget)
    args = (self.params, self.cache, self.tok)
    if self.backend is not None:
      args = args + (self.backend.full_mode(),)
    jax.block_until_ready(step(*args))
    ts = []
    for _ in range(iters):
      t0 = time.perf_counter()
      jax.block_until_ready(step(*args))
      ts.append((time.perf_counter() - t0) * 1e3)
    return float(np.median(ts))


class MeasuredStepBackend:
  """Measured per-bucket step latencies for the discrete-event simulator.

  The simulator's ``accuracytrader`` technique can delegate component
  service times to this table (``ScatterGatherService(step_backend=...)``):
  a component "processing i ranked clusters" then costs what the real
  kernel path *measured* for the corresponding budget bucket, closing the
  simulated-time -> measured-time loop (DESIGN.md §8).

  Budget units differ between the stacks: the simulator budgets clusters
  out of ``ServiceConfig.full_items`` (default 100), the engine out of
  its M = prompt_len / cluster_size.  ``full_items`` sets the conversion
  — a simulator budget of ``i`` costs what the engine measured at the
  bucket nearest ``i / full_items * M``, so the measured latency *slope*
  over the budget range survives the translation instead of collapsing
  onto the top engine bucket."""

  def __init__(self, engine: ServingEngine, iters: int = 3,
               full_items: int = 100):
    self.buckets = engine.buckets
    self.M = engine.M
    self.full_items = full_items
    self.table = {b: engine.probe_step_ms(b, iters=iters)
                  for b in self.buckets}

  def step_ms(self, budget: int) -> float:
    scaled = budget / max(self.full_items, 1) * self.M
    nearest = min(self.buckets, key=lambda b: abs(b - scaled))
    return self.table[nearest]


def make_requests(arrivals_ms: Sequence[float], prompt_len: int,
                  max_new_tokens: int, vocab: int,
                  seed: int = 0) -> List[EngineRequest]:
  """Random-prompt requests at the given arrival offsets (ms)."""
  rng = np.random.default_rng(seed)
  return [EngineRequest(rid=i, arrival_ms=float(t),
                        prompt=rng.integers(0, vocab, prompt_len,
                                            dtype=np.int32),
                        max_new_tokens=max_new_tokens)
          for i, t in enumerate(arrivals_ms)]


def make_zipf_requests(arrivals_ms: Sequence[float], prompt_len: int,
                       max_new_tokens: int, vocab: int,
                       n_corpora: int = 8, alpha: float = 1.1,
                       seed: int = 0) -> List[EngineRequest]:
  """Zipf-repeated-corpora requests: each arrival draws its prompt from
  a fixed pool of ``n_corpora`` distinct corpora with Zipf(``alpha``)
  popularity — the shared-index / per-tenant-document workload shape the
  corpus cache exists for (DESIGN.md §12).  ``n_corpora=1`` is the
  100%-repeat arm (every admission after the first hits)."""
  rng = np.random.default_rng(seed)
  pool = [rng.integers(0, vocab, prompt_len, dtype=np.int32)
          for _ in range(n_corpora)]
  w = np.arange(1, n_corpora + 1, dtype=np.float64) ** -alpha
  picks = rng.choice(n_corpora, size=len(arrivals_ms), p=w / w.sum())
  return [EngineRequest(rid=i, arrival_ms=float(t),
                        prompt=pool[picks[i]],
                        max_new_tokens=max_new_tokens)
          for i, t in enumerate(arrivals_ms)]


def run_open_loop(engine: ServingEngine, rate_per_s: float,
                  duration_s: float, seed: int = 0,
                  slo_of=None, zipf_corpora: int = 0,
                  service_seed: Optional[int] = None) -> Dict[str, float]:
  """One measurement window of Poisson arrivals at ``rate_per_s`` — the
  engine-side mirror of ``ScatterGatherService.run_open_loop``.

  The window is draw-deterministic: the backend's interference/straggler
  RNG and injected fault plan (if any) are reseeded, so a re-run
  reproduces the same noise and fault sequence regardless of warmup or
  prior-window history (only the measured wall times themselves vary run
  to run).  ``slo_of(rid) -> str`` optionally assigns each request an
  SLO class (DESIGN.md §11).

  ``service_seed`` splits the two RNG roles ``seed`` used to play at
  once: arrivals and prompts ALWAYS derive from ``seed``, while the
  backend's service-side noise reseeds from ``service_seed`` when given
  (else ``seed``, the legacy coupling).  Sweep arms that must see the
  SAME arrival trace under independent service draws — the (contract,
  ε, rate) grids in benchmarks — pass a distinct ``service_seed`` per
  arm; sharing one seed across arms correlates the comparison's noise
  (the seed-reuse bug class; regression-tested in
  tests/test_estimator.py)."""
  engine.reset()
  if engine.backend is not None and hasattr(engine.backend, "reseed"):
    engine.backend.reseed(seed if service_seed is None else service_seed)
  arrivals = poisson_arrivals(rate_per_s, duration_s, seed=seed)
  if zipf_corpora > 0:
    reqs = make_zipf_requests(arrivals, engine.ecfg.prompt_len,
                              engine.ecfg.max_new_tokens, engine.cfg.vocab,
                              n_corpora=zipf_corpora, seed=seed)
  else:
    reqs = make_requests(arrivals, engine.ecfg.prompt_len,
                         engine.ecfg.max_new_tokens, engine.cfg.vocab,
                         seed=seed)
  if slo_of is not None:
    for r in reqs:
      r.slo = slo_of(r.rid)
  return engine.run(reqs)
