"""Fleet tier: materialized-replica 2-D mesh serving (DESIGN.md §14).

The cluster tier (DESIGN.md §9/§10) replicates in the latency/accounting
plane only: a hedged gather is priced against a *modelled* replica while
the step program still reads the primary shard.  The fleet tier makes
replication real.  Components lay out on a ``("replica", "component")``
2-D mesh (`repro.dist.topology.plan_2d` / `make_fleet_mesh`): replica
row ``r`` holds, at mesh column ``j``, a **materialized** copy of shard
``shard_at(r, j) = (j - r) % N`` — row r is row 0 ring-rotated by r.

Materialization is free of any numerical caveat because the synopsis is
small (the paper's deployment premise) and the copy is pure data
movement: admission writes ONE arena (`kv_cache.ARENA_LEAVES`, shared
via the content-addressed corpus cache, DESIGN.md §12) and
`kv_cache.replicate_leaf` stacks R ring-rotated views of the scattered
shards — every replica copy is bit-identical to its primary, and each
mapping holds its own corpus-cache pin (`CorpusCache.acquire(n=R)`) so
retiring one replica's mapping can never free an arena another still
reads.

Per step the frontend runs *replica selection* (Tail-Tolerant
Distributed Search, arxiv 1707.07426; `topology.select_replica`): each
shard is served from whichever holder is predicted to finish first
under this step's interference/straggler draws, and the gather reads
the selected holder's **actual** shard — `make_fleet_attention` gathers
the selected (row, column) lane of every shard and folds the partials
in fixed shard order, so the result is bit-identical to the all-primary
gather whatever the selection (property-tested in tests/test_fleet.py).

Accounting prices shard c at the EARLIEST completion among its holders
(all R×N lanes execute in the CPU proxy, exactly as both sides of a
real hedge do): with R=2 and the same seed the fleet's per-shard time
equals the cluster tier's modelled-hedge min *identically*, which is
the deterministic CI gate — hedged-on-real-shard p99 can never exceed
modelled-hedge p99 at equal loss (benchmarks/fleet_bench.py).

The draw stream is unchanged from the cluster tier — exactly two noise
draws per step whatever R (rows r >= 1 share the reissue draw), so R=1,
cluster-R=2 and fleet-R=2 runs with the same seeds live in the same
noise world.

CPU-proxy caveat (EXPERIMENTS.md §Fleet): one host executes all R*N
lanes as one program; the measured wall is attributed per component by
corpus share + refined rows, and replica queueing is modelled by the
same draw discipline the cluster tier uses.  On a real fleet each mesh
row is a host group and the selection policy reads per-holder load.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.control import MODE_DROP, MODE_FULL, MODE_STAGE1
from repro.control.estimator import coverage_profile
from repro.dist import sharding as shd
from repro.dist.topology import make_fleet_mesh, plan_2d, select_replica
from repro.kernels import ops
from repro.serve import kv_cache as kvc
from repro.serve.cluster import (ClusterConfig, ClusterStepBackend, _StepPlan,
                                 _cluster_stacked, _extras_partial,
                                 _frontend_rank, _pick_mode, _select_local,
                                 allocate_budget, gain_rank)
from repro.serve.serve_step import make_serve_step

NEG_INF = ops.NEG_INF

__all__ = ["FleetConfig", "FleetStepBackend", "make_fleet_attention"]


@dataclasses.dataclass
class FleetConfig(ClusterConfig):
  """Fleet-tier knobs: a `ClusterConfig` whose ``replicas`` is a real
  mesh dimension (R >= 1 rows of materialized shards) instead of an
  accounting factor.  The resilience knobs must stay at their defaults
  — fault injection and the retry ladder ride the 1-D cluster tier;
  the fleet tier composes with them upstream (admission/shedding), not
  inside the gather."""
  replicas: int = 2


# ---------------------------------------------------------------------------
# The 2-D scatter-gather attention body.  Same math as the cluster tier:
# the ONLY new degree of freedom is WHICH materialized copy of each shard
# the gather reads (``fe_replica``), and every copy is bit-identical.
# ---------------------------------------------------------------------------

def _select_lanes(sel: jax.Array, N: int):
  """Mesh coordinates of each shard's selected holder: shard ``c`` served
  from replica row ``sel[c]`` lives at column ``(c + sel[c]) % N``."""
  cols = (jnp.arange(N, dtype=sel.dtype) + sel) % N
  return sel, cols


def make_fleet_attention(topo, alloc: str = "mass", mesh=None,
                         recirculate: bool = True, telemetry: bool = False):
  """Returns ``attention_fn(q, cache_sl, ...) -> (ctx, aux)`` over the
  replica-materialized cache layout (DESIGN.md §14):

    k/v          (B, Hkv, R, N, m_max*C, D)   ring-rotated shard copies
    k_syn/v_syn  (B, Hkv, R, N, m_max, D)
    counts       (B, R, N, m_max)
    fe_mode      (N,) int32                   per-shard gather mode
    fe_replica   (N,) int32                   per-shard selected holder

  Row 0 is exactly the cluster tier's 1-D layout; row r is row 0 rolled
  right by r along the component axis (`kv_cache.replicate_leaf`).

  Stacked execution gathers each shard's leaves from its selected
  (row, column) lane — pure indexing into bit-identical copies — and
  delegates to the cluster tier's `_cluster_stacked` fold.  Under a
  2-D mesh the shard_map body computes each lane's stage-1 + refinement
  locally, all-gathers scores and partials over both axes, and folds the
  selected lanes in fixed shard order — the same merge order as the
  stacked path, so both executions are bit-identical to the all-primary
  gather whatever ``fe_replica`` says."""

  def attention(q, csl, *, i_max, cluster_size, sm_scale, cap=None,
                self_kv=None, impl="xla"):
    if mesh is not None:
      return _fleet_sharded(
          q, csl, topo, alloc, mesh, i_max=i_max,
          cluster_size=cluster_size, sm_scale=sm_scale, cap=cap,
          self_kv=self_kv, impl=impl, recirculate=recirculate,
          telemetry=telemetry)
    return _fleet_stacked(
        q, csl, topo, alloc, i_max=i_max, cluster_size=cluster_size,
        sm_scale=sm_scale, cap=cap, self_kv=self_kv, impl=impl,
        recirculate=recirculate, telemetry=telemetry)

  return attention


def _fleet_stacked(q, csl, topo, alloc, *, i_max, cluster_size, sm_scale,
                   cap, self_kv, impl, recirculate=True, telemetry=False):
  """Single-device execution: gather every shard's leaves from its
  selected replica lane, then run the cluster tier's stacked body on the
  resulting 1-D component layout.  Selection is pure indexing into
  bit-identical copies, so the output cannot depend on it."""
  N = topo.n_components
  rows, cols = _select_lanes(csl["fe_replica"], N)
  flat = {kk: vv for kk, vv in csl.items() if kk != "fe_replica"}
  for name in ("k", "v", "k_syn", "v_syn", "k_syn_scale", "v_syn_scale",
               "k_scale", "v_scale"):
    if name not in csl:
      continue
    # Advanced indices at adjacent axes (replica, component) collapse to
    # one shard axis in shard order: entry c is shard c read from lane
    # (sel[c], (c + sel[c]) % N).
    flat[name] = csl[name][:, :, rows, cols]
  flat["counts"] = csl["counts"][:, rows, cols]
  return _cluster_stacked(
      q, flat, topo, alloc, i_max=i_max, cluster_size=cluster_size,
      sm_scale=sm_scale, cap=cap, self_kv=self_kv, impl=impl,
      recirculate=recirculate, mode_caps=False, telemetry=telemetry)


def _fleet_sharded(q, csl, topo, alloc, mesh, *, i_max, cluster_size,
                   sm_scale, cap, self_kv, impl, recirculate=True,
                   telemetry=False):
  """shard_map execution over the ``("replica", "component")`` mesh:
  device (r, j) holds shard ``(j - r) % N`` and runs its stage-1 +
  refinement locally; the frontend logic (rank, budgets, selection) runs
  replicated from the score all-gather, and the composer folds the
  SELECTED lane of every shard in fixed shard order — the same merge
  order as `_cluster_stacked`, hence bit-identical output."""
  from jax.sharding import PartitionSpec as P  # noqa: PLC0415
  N, Mp = topo.n_components, topo.m_max
  corpus = P(None, None, "replica", "component", None, None)
  specs = {"k": corpus, "v": corpus, "k_syn": corpus, "v_syn": corpus,
           "counts": P(None, "replica", "component", None),
           "fe_mode": P(), "fe_replica": P()}
  for name in ("k_syn_scale", "v_syn_scale", "k_scale", "v_scale"):
    if name in csl:
      specs[name] = P(None, None, "replica", "component", None)
  for name in ("recent_k", "recent_v"):
    if name in csl:
      specs[name] = P(None, None, None, None)
  if "recent_len" in csl:
    specs["recent_len"] = P(None)
  csl = {kk: csl[kk] for kk in specs}
  q_spec = P(None, None, None)
  self_spec = (P(None, None, None, None),) * 2 if self_kv is not None \
      else P()

  def body(q, cache, self_kv):
    with shd.manual_axes({"replica", "component"}):
      rid = jax.lax.axis_index("replica")
      k_l, v_l = cache["k"][:, :, 0, 0], cache["v"][:, :, 0, 0]
      ks_l, vs_l = cache["k_syn"][:, :, 0, 0], cache["v_syn"][:, :, 0, 0]
      counts_l = cache["counts"][:, 0, 0]
      syn_scales = None if "k_syn_scale" not in cache else (
          cache["k_syn_scale"][:, :, 0, 0], cache["v_syn_scale"][:, :, 0, 0])
      kv_scales = None if "k_scale" not in cache else (
          cache["k_scale"][:, :, 0, 0], cache["v_scale"][:, :, 0, 0])
      mode = cache["fe_mode"]                       # (N,) replicated
      sel_arr = cache["fe_replica"]                 # (N,) replicated
      # The shard this lane holds: column j of row r is shard (j - r) % N.
      c_loc = (jax.lax.axis_index("component") - rid) % N

      sc_l, p_syn = ops.synopsis_stage1(
          q, ks_l, vs_l, counts_l, sm_scale=sm_scale, cap=cap, impl=impl,
          valid=counts_l > 0, syn_scales=syn_scales)
      # Scores within a row cover all N shards (a row is a rotation of
      # the full partition), in mesh-column order; rotate back to shard
      # order so every lane sees the same sc_all — copies are
      # bit-identical, so no cross-row gather is needed.
      sc = jax.lax.all_gather(sc_l, "component", axis=2, tiled=True)
      B, Hkv = sc.shape[:2]
      to_shard = (jnp.arange(N) + rid) % N
      sc_all = jnp.take(sc.reshape(B, Hkv, N, Mp), to_shard, axis=2)
      gsel, mass = _frontend_rank(sc_all, i_max)
      counts_g = None
      if alloc == "gain" or telemetry:
        cg = jax.lax.all_gather(cache["counts"][:, 0, 0], "component",
                                axis=1, tiled=True)
        counts_g = jnp.take(cg.reshape(B, N, Mp), to_shard, axis=1)
      if gsel is not None and alloc == "gain":
        gsel = gain_rank(sc_all, counts_g, i_max)

      if gsel is None:
        p_full = p_syn
        cover_l = jnp.zeros((1,), jnp.float32)
      else:
        budgets = None
        if alloc == "mass":
          caps = jnp.sum(sc_all > NEG_INF / 2, axis=-1)    # (B, Hkv, N)
          budgets = allocate_budget(mass, i_max, caps,
                                    recirculate=recirculate)
        sel = _select_local(c_loc, sc_l, gsel, budgets, alloc, i_max, Mp)
        p_ref = ops.refine_stage2(
            q, k_l, v_l, sel, ks_l, vs_l, counts_l,
            cluster_size=cluster_size, sm_scale=sm_scale, cap=cap,
            impl=impl, syn_scales=syn_scales, kv_scales=kv_scales)
        p_full = ops.merge_partials(p_syn, p_ref)
        cover_l = jnp.mean(
            jnp.sum((sel >= 0).astype(jnp.float32), -1))[None]
      contrib = _pick_mode(mode[c_loc], p_full, p_syn)

      def gather2(x):
        x = jax.lax.all_gather(x[None], "component", axis=0, tiled=True)
        return jax.lax.all_gather(x[None], "replica", axis=0, tiled=True)

      og, mg, lg = [gather2(x) for x in contrib]
      cols = (jnp.arange(N, dtype=sel_arr.dtype) + sel_arr) % N
      acc = None
      for c in range(N):
        # Fixed shard order c = 0..N-1 — the SAME merge order as the
        # stacked/cluster fold — reading shard c's selected lane.
        part = (og[sel_arr[c], cols[c]], mg[sel_arr[c], cols[c]],
                lg[sel_arr[c], cols[c]])
        acc = part if acc is None else ops.merge_partials(acc, part)
      p_ex = _extras_partial(q, cache, self_kv, sm_scale=sm_scale,
                             cap=cap, impl=impl)
      if p_ex is not None:
        acc = ops.merge_partials(acc, p_ex)
      cover2 = gather2(cover_l)[..., 0]              # (R, N) mesh coords
      cover = cover2[sel_arr, cols]                  # (N,) shard order
      mass_frac = mass / jnp.maximum(jnp.sum(mass, -1, keepdims=True),
                                     1e-30)
      outs = (acc[0], cover, jnp.mean(mass_frac, axis=(0, 1)))
      if telemetry:
        outs = outs + (coverage_profile(
            sc_all.reshape(B, Hkv, N * Mp), counts_g.reshape(B, N * Mp),
            rank="mass" if alloc == "gain" else "score"),)
      return outs

  n_out = 4 if telemetry else 3
  res = shd.shard_map(
      body, mesh=mesh, in_specs=(q_spec, specs, self_spec),
      out_specs=(P(),) * n_out, axis_names=("replica", "component"),
      check_vma=False)(q, csl, self_kv)
  aux = {"fe_cover": res[1], "fe_mass": res[2]}
  if telemetry:
    aux["est_profile"] = res[3]
  return res[0], aux


# ---------------------------------------------------------------------------
# ServingEngine step backend.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _FleetPlan(_StepPlan):
  """Cluster step plan + this step's per-shard replica selection."""
  sel: Optional[np.ndarray] = None       # (N,) int32 selected replica row


class FleetStepBackend(ClusterStepBackend):
  """Drop-in `ServingEngine` step backend running the fleet tier.

  Inherits the cluster tier's scatter/write/plan/account machinery and
  swaps in: a 2-D mesh (`make_fleet_mesh`), the replica-materializing
  slot write (`kv_cache.replicate_leaf` after scatter+route), the
  selection-aware attention body, and plan/account that price every
  shard at the earliest completion among its R materialized holders."""

  def bind(self, engine) -> None:
    super().bind(engine)
    cc = self.ccfg
    if self.resilient:
      raise ValueError(
          "fleet tier is non-resilient by construction (faults=None, "
          "retries=1, recovery=True): fault injection and the retry "
          "ladder ride the 1-D cluster tier")
    # Re-plan through the fleet entry point (validates R as a grid dim)
    # and upgrade the mesh to 2-D.  R*N devices make replication real;
    # with fewer the stacked fallback executes the same math.
    self.topo = plan_2d(self.M, cc.n_components, cc.replicas, skew=cc.skew)
    use_mesh = cc.use_mesh
    self.mesh = make_fleet_mesh(cc.n_components, cc.replicas) \
        if use_mesh or use_mesh is None else None
    if use_mesh and self.mesh is None:
      raise RuntimeError(
          f"use_mesh=True but < {cc.replicas * cc.n_components} devices "
          f"for the (replica={cc.replicas}, component={cc.n_components}) "
          f"mesh; run under XLA_FLAGS=--xla_force_host_platform_device_"
          f"count={cc.replicas * cc.n_components}")
    self.attention = make_fleet_attention(self.topo, alloc=cc.alloc,
                                          mesh=self.mesh,
                                          recirculate=cc.recirculate,
                                          telemetry=self.telemetry)
    self._write = self._make_write()

  @property
  def replica_mappings(self) -> int:
    """Pins per slot admission: each replica row maps the arena once
    (`ServingEngine` acquires/releases this many per slot)."""
    return self.topo.replicas

  # -- cache layout ----------------------------------------------------------
  def zeros_cache(self) -> Dict[str, jax.Array]:
    """Component layout with a replica axis: k-like leaves
    (nb, na, B, Hkv, R, N, ...) and counts (nb, na, B, R, N, Mp).  The
    batch/slot axis stays at 2, so the engine's admit/retire write path
    is untouched."""
    base = super().zeros_cache()
    R = self.topo.replicas
    for name in kvc.ARENA_LEAVES:
      if name not in base:
        continue
      x = base[name]
      ax = 3 if name == "counts" else 4
      base[name] = jnp.zeros(x.shape[:ax] + (R,) + x.shape[ax:], x.dtype)
    return base

  def _make_write(self):
    bx = kvc.slot_batch_axes(self.cfg, self.n_slots, self.prompt_len,
                             synopsis=True)
    rotate = self.ccfg.route == "rotate"
    R = self.ccfg.replicas

    def write(cache, syn, slot):
      # One arena write backs R replica mappings: scatter to components,
      # route (optional per-slot rotation), then stack the R ring-rotated
      # copies — pure data movement, bit-identical per copy.
      sub = self._scatter(syn)
      for name in kvc.ARENA_LEAVES:
        if name not in sub:
          continue
        ax = 3 if name == "counts" else 4
        if rotate:
          sub[name] = jnp.roll(sub[name], slot, axis=ax)
        sub[name] = kvc.replicate_leaf(sub[name], R, axis=ax)
      return kvc.write_slot(cache, sub, slot, bx)

    return jax.jit(write)

  # -- the compiled step -----------------------------------------------------
  def step_fn(self, budget: int):
    """The frontend vector is packed (2, N) int32 — row 0 the gather
    mode, row 1 the selected replica — so the engine's step dispatch
    signature is unchanged from the cluster tier."""
    step = make_serve_step(self.cfg, mode="synopsis", i_max=budget,
                           impl=self.impl, attention_fn=self.attention)

    @jax.jit
    def run(params, cache, tok, fe_mode):
      cache = dict(cache)
      cache["fe_mode"] = fe_mode[0]
      cache["fe_replica"] = fe_mode[1]
      return step(params, cache, tok)

    return run

  def full_mode(self) -> jax.Array:
    N = self.topo.n_components
    return jnp.stack([jnp.full((N,), MODE_FULL, jnp.int32),
                      jnp.zeros((N,), jnp.int32)])

  # -- frontend plan / account ----------------------------------------------
  def _replica_times(self, wall: float, u: np.ndarray, usum: float,
                     noise: np.ndarray, noise2: np.ndarray) -> np.ndarray:
    """(R, N) completion of shard c served from its r-th holder.  Row 0
    is the primary's own completion; row r >= 1 at holder j = (c+r)%N
    queues behind j's own shard (u[j] at noise[j] — the SAME draw that
    prices j's row-0 completion) then streams c's stage-1 + granted
    clusters (u[c]) under the reissue draw noise2[j].  Row 1 is exactly
    the cluster tier's `_hedge_time`, so fleet and cluster runs with the
    same seeds price the same world — rows share the two per-step draws
    whatever R."""
    N = self.topo.n_components
    c = np.arange(N)
    rows = [wall * (u / usum) * noise]
    for r in range(1, self.topo.replicas):
      j = (c + r) % N
      rows.append(wall * (u[j] * noise[j] + u * noise2[j]) / usum)
    return np.stack(rows)

  def plan_step(self, budget: int, step_deadline_ms: float) -> _FleetPlan:
    """Pre-dispatch decision: predict every (shard, holder) completion
    under this step's draws, select each shard's fastest holder
    (`select_replica` — ties to the primary), and let the policy mark
    shards whose BEST completion still misses the deadline STAGE1/DROP.
    The step program then reads the selected holders' actual shards."""
    massf = self.mass_ewma / max(self.mass_ewma.sum(), 1e-30)
    b_est = float(budget) * massf
    u = self._units(b_est)
    usum = max(u.sum(), 1e-30)
    noise, noise2 = self._draw_noise(), self._draw_noise()
    wall = self.predictor.predict(budget)
    t_rc = self._replica_times(wall, u, usum, noise, noise2)
    sel = select_replica(t_rc)
    t_best = t_rc.min(axis=0)
    mode, _ = self.engine.controller.gather_modes(t_best, step_deadline_ms)
    fe = jnp.asarray(np.stack([mode.astype(np.int32), sel]))
    return _FleetPlan(fe_mode=fe, mode=mode, noise=noise, noise2=noise2,
                      hedged=sel != 0, b_est=b_est,
                      deadline_ms=step_deadline_ms, sel=sel)

  def account(self, budget: int, wall_ms: float, plan: _FleetPlan, st,
              warming: bool = False) -> Dict[str, float]:
    """Post-step accounting: re-price the (R, N) completions with the
    measured wall and the actually-refined rows, and take each shard at
    its EARLIEST holder — every lane executes in the CPU proxy, exactly
    as both sides of a real hedge do, and the plan-time selection was
    argmin over the same expression, so the realized time can never be
    worse than the cluster tier's modelled hedge under the same draws
    (the deterministic gate in benchmarks/fleet_bench.py)."""
    full = plan.mode == MODE_FULL
    if not warming:
      self.predictor.observe(budget, wall_ms)
      if "fe_mass" in st:
        m = np.asarray(st["fe_mass"]).mean(axis=(0, 1))
        mix = 0.7 * self.mass_ewma + 0.3 * m
        self.mass_ewma = mix / max(mix.sum(), 1e-30)
    cover = np.asarray(st["fe_cover"]).mean(axis=(0, 1)) \
        if "fe_cover" in st else np.zeros_like(self.comp_share)
    u = self._units(np.where(full, cover, 0.0))
    usum = max(u.sum(), 1e-30)
    u0 = self._units(np.zeros_like(cover))
    f0 = u0 / usum
    t_rc = self._replica_times(wall_ms, u, usum, plan.noise, plan.noise2)
    done_full = t_rc.min(axis=0)
    t_stage1 = wall_ms * f0 * plan.noise
    done = np.where(full, done_full,
                    np.where(plan.mode == MODE_STAGE1, t_stage1, 0.0))
    valid = np.maximum(self.comp_share * self.M, 1.0)
    frac = np.minimum(cover / valid, 1.0)
    acc_c = np.where(
        full, [self.accuracy_fn(x) for x in frac],
        np.where(plan.mode == MODE_STAGE1, self.accuracy_fn(0.0), 0.0))
    step_acc = float(np.sum(self.comp_share * acc_c))
    parallel_ms = float(max(done.max(), 1e-3))
    sharesum = max(self.comp_share.sum(), 1e-30)
    drop_share = float(np.sum(np.where(plan.mode == MODE_DROP,
                                       self.comp_share, 0.0)) / sharesum)
    self.step_idx += 1
    off_primary = int((plan.sel != 0).sum()) if plan.sel is not None else 0
    return {"parallel_ms": parallel_ms, "step_acc": step_acc,
            "wall_ms": wall_ms, "gathered": int(full.sum()),
            "hedged": off_primary, "comp_ms": done,
            "drop_share": drop_share, "retried": 0,
            "off_primary": off_primary}
