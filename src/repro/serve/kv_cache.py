"""Decode caches for all architectures + the synopsis KV structure.

Three cache families (all leading-stacked over super-blocks so serve_step
scans them exactly like the parameters):

  * ExactKV     — (nb, npos, B, Hkv, S, D) keys/values (GQA archs) or the
                  MLA latent cache (nb, npos, B, 1, S, r+rope).
  * SynopsisKV  — AccuracyTrader: cluster-contiguous originals + centroid
                  tables + counts + a small exact "recent" ring buffer for
                  tokens generated since the last synopsis update.
  * SSMState    — (conv_state, ssd_state) for mamba blocks.

``cache_specs`` returns ShapeDtypeStructs (dry-run contract) and
``init_cache`` real zeros (tests).  For continuous batching the batch
axis doubles as a *slot* axis: ``zeros_cache`` allocates the shared slot
pool and ``write_slot`` admits one request's B=1 cache into a lane
mid-flight (DESIGN.md §8).  Sharding axes follow the same logical
names as params; under SERVE_RULES the sequence axis of caches/synopses
shards over `model` — each shard is one paper "component" and the
online-softmax merge is the result composer.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import common as cm

# Logical axes per cache leaf (leading 'layers' for the scan stack).
KV_AXES = ("layers", None, "batch", "kv_heads", "kv_seq", None)

# Shared-immutable vs private-mutable split of a synopsis slot
# (DESIGN.md §12).  ARENA_LEAVES are a pure function of the corpus —
# sorted corpus KV, centroid tables, counts — so the corpus cache can
# share one arena across every slot serving the same corpus.
# PRIVATE_LEAVES hold per-request decode state (the recent ring, the
# position, SSM/cross state): the copy-on-write half, always written
# fresh per slot and never aliased.
ARENA_LEAVES = ("k", "v", "k_syn", "v_syn", "counts",
                # Quantized-arena dequant scales (DESIGN.md §15) — pure
                # functions of the corpus like the tables they scale,
                # present only when cfg.synopsis.quant != "none".
                "k_syn_scale", "v_syn_scale", "k_scale", "v_scale")
PRIVATE_LEAVES = ("recent_k", "recent_v", "recent_len", "pos",
                  "conv_state", "ssd_state", "cross_k", "cross_v")
SYN_AXES = KV_AXES
COUNT_AXES = ("layers", None, "batch", "kv_seq")
SCALE_AXES = ("layers", None, "batch", "kv_heads", "kv_seq")
RECENT_AXES = ("layers", None, "batch", "kv_heads", None, None)
SSM_CONV_AXES = ("layers", None, "batch", None, "ssm_heads")
SSM_STATE_AXES = ("layers", None, "batch", "ssm_heads", None, "ssm_state")
CROSS_AXES = ("layers", None, "batch", "kv_heads", None, None)


def _kv_dims(cfg: cm.ModelConfig) -> Tuple[int, int, int]:
  """(Hkv, key_dim, value_dim) of the decode cache entries."""
  if cfg.mla:
    m = cfg.mla
    return 1, m.kv_lora_rank + m.qk_rope_dim, m.kv_lora_rank + m.qk_rope_dim
  return cfg.n_kv_heads, cfg.hd, cfg.hd


def n_attn_positions(cfg: cm.ModelConfig) -> int:
  return sum(1 for s in cfg.block_pattern if s.kind == "attn")


def n_ssm_positions(cfg: cm.ModelConfig) -> int:
  return sum(1 for s in cfg.block_pattern if s.kind == "mamba")


def cache_struct(cfg: cm.ModelConfig, B: int, S: int, *,
                 synopsis: bool) -> Dict[str, Any]:
  """Shapes + logical axes of the decode cache for (cfg, batch, seq)."""
  nb = cfg.n_blocks
  na = n_attn_positions(cfg)
  ns = n_ssm_positions(cfg)
  Hkv, Dk, _ = _kv_dims(cfg)
  out: Dict[str, Any] = {}
  dt = cfg.dtype

  if na:
    if synopsis:
      sc = cfg.synopsis
      C = sc.cluster_size
      assert S % C == 0, (S, C)
      M = S // C
      R = sc.recent
      # Quantized synopsis (DESIGN.md §15): the centroid tables (and,
      # with "+kv", the sorted corpus KV) store the low-precision dtype
      # plus per-block f32 scale leaves shaped like one scalar per
      # centroid/cluster.
      from repro.kernels.quant import parse_qconfig, qdtype
      qc = parse_qconfig(getattr(sc, "quant", "none"))
      syn_dt = qdtype(qc.kind) if qc.enabled else dt
      kv_dt = qdtype(qc.kind) if qc.enabled and qc.sorted_kv else dt
      out["k"] = ((nb, na, B, Hkv, S, Dk), kv_dt, KV_AXES)
      out["v"] = ((nb, na, B, Hkv, S, Dk), kv_dt, KV_AXES)
      out["k_syn"] = ((nb, na, B, Hkv, M, Dk), syn_dt, SYN_AXES)
      out["v_syn"] = ((nb, na, B, Hkv, M, Dk), syn_dt, SYN_AXES)
      out["counts"] = ((nb, na, B, M), jnp.float32, COUNT_AXES)
      if qc.enabled:
        out["k_syn_scale"] = ((nb, na, B, Hkv, M), jnp.float32, SCALE_AXES)
        out["v_syn_scale"] = ((nb, na, B, Hkv, M), jnp.float32, SCALE_AXES)
        if qc.sorted_kv:
          out["k_scale"] = ((nb, na, B, Hkv, M), jnp.float32, SCALE_AXES)
          out["v_scale"] = ((nb, na, B, Hkv, M), jnp.float32, SCALE_AXES)
      out["recent_k"] = ((nb, na, B, Hkv, R, Dk), dt, RECENT_AXES)
      out["recent_v"] = ((nb, na, B, Hkv, R, Dk), dt, RECENT_AXES)
      out["recent_len"] = ((B,), jnp.int32, ("batch",))
    else:
      out["k"] = ((nb, na, B, Hkv, S, Dk), dt, KV_AXES)
      out["v"] = ((nb, na, B, Hkv, S, Dk), dt, KV_AXES)
  if ns:
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    h = d_in // s.head_dim
    conv_dim = d_in + 2 * s.d_state
    out["conv_state"] = ((nb, ns, B, s.d_conv - 1, conv_dim), dt,
                         SSM_CONV_AXES)
    out["ssd_state"] = ((nb, ns, B, h, s.head_dim, s.d_state), jnp.float32,
                        SSM_STATE_AXES)
  if cfg.encoder is not None:
    T = cfg.encoder.source_len
    out["cross_k"] = ((nb, na, B, cfg.n_kv_heads, T, cfg.hd), dt, CROSS_AXES)
    out["cross_v"] = ((nb, na, B, cfg.n_kv_heads, T, cfg.hd), dt, CROSS_AXES)
  out["pos"] = ((B,), jnp.int32, ("batch",))
  return out


def cache_specs(cfg, B, S, *, synopsis: bool):
  """ShapeDtypeStruct tree (no allocation) for the dry-run."""
  return {k: jax.ShapeDtypeStruct(sh, dt)
          for k, (sh, dt, _) in cache_struct(cfg, B, S,
                                             synopsis=synopsis).items()}


def cache_axes(cfg, B, S, *, synopsis: bool):
  return {k: ax
          for k, (sh, dt, ax) in cache_struct(cfg, B, S,
                                              synopsis=synopsis).items()}


def zeros_cache(cfg, B, S, *, synopsis: bool):
  """All-zeros cache — the continuous-batching engine's shared slot pool
  (DESIGN.md §8).  Each batch lane is one request *slot*; admission writes
  a freshly prefilled+built B=1 cache into a lane (`write_slot`) and
  retirement simply frees the lane (a zeroed lane attends over zeros,
  which is numerically safe and ignored by the engine)."""
  return {name: jnp.zeros(sh, dt)
          for name, (sh, dt, _) in cache_struct(cfg, B, S,
                                                synopsis=synopsis).items()}


def slot_batch_axes(cfg, B, S, *, synopsis: bool) -> Dict[str, int]:
  """Per-leaf index of the batch ("slot") axis, derived from the logical
  axis names in ``cache_struct`` — the admit/retire write path uses it so
  slot updates work for every cache family (GQA, MLA, SSM, cross)."""
  return {k: ax.index("batch")
          for k, ax in cache_axes(cfg, B, S, synopsis=synopsis).items()}


def write_slot(cache: Dict[str, jax.Array], sub: Dict[str, jax.Array],
               slot, batch_axes: Dict[str, int]) -> Dict[str, jax.Array]:
  """Write a B=1 per-request cache ``sub`` into lane ``slot`` of the
  shared slot cache (continuous-batching admission, DESIGN.md §8).

  ``slot`` may be a traced scalar (the engine jits this once); leaves of
  ``cache`` with no counterpart in ``sub`` pass through untouched."""
  out = {}
  for name, dst in cache.items():
    if name not in sub:
      out[name] = dst
      continue
    ax = batch_axes[name]
    upd = sub[name].astype(dst.dtype)
    out[name] = jax.lax.dynamic_update_slice_in_dim(dst, upd, slot, axis=ax)
  return out


def init_cache(cfg, B, S, *, synopsis: bool, key=None):
  """Real cache (randomised contents for tests/benchmarks)."""
  key = key if key is not None else jax.random.PRNGKey(0)
  out = {}
  for name, (sh, dt, _) in cache_struct(cfg, B, S, synopsis=synopsis).items():
    if name in ("pos",):
      out[name] = jnp.full(sh, S, dt)
    elif name == "recent_len":
      out[name] = jnp.zeros(sh, dt)
    elif name == "counts":
      C = cfg.synopsis.cluster_size
      out[name] = jnp.full(sh, C, dt)
    elif dt in (jnp.float32, cfg.dtype, jnp.bfloat16):
      key, sub = jax.random.split(key)
      out[name] = 0.1 * jax.random.normal(sub, sh, jnp.float32)
      out[name] = out[name].astype(dt)
    else:
      out[name] = jnp.zeros(sh, dt)
  return out


def replicate_leaf(x: jax.Array, replicas: int, axis: int) -> jax.Array:
  """Materialize the fleet tier's replica rows from one arena write
  (DESIGN.md §14): stack R ring-rotated copies of a component-stacked
  leaf, inserting a new replica axis at ``axis`` (the component axis
  shifts to ``axis + 1``).

  Row r is row 0 rolled right by r along the component axis, so mesh
  column ``j`` of row ``r`` holds shard ``(j - r) % N`` — exactly
  ``ComponentTopology.shard_grid()``.  ``jnp.roll`` is pure data
  movement: every replica copy is bit-identical to its primary shard,
  which is what makes "one arena write backs R replica mappings" free
  of any numerical caveat (property-tested in tests/test_fleet.py)."""
  r = int(replicas)
  if r < 1:
    raise ValueError(f"replicas must be >= 1, got {r}")
  # After stacking, the old component axis sits at axis+1.
  return jnp.stack([jnp.roll(x, shift, axis=axis) for shift in range(r)],
                   axis=axis)


def arena_nbytes(arena: Dict[str, Any]) -> int:
  """Footprint of the shared-immutable half only (capacity accounting in
  the corpus cache; the private leaves live in the slot pool, not the
  arena)."""
  return sum(int(arena[name].nbytes) for name in ARENA_LEAVES
             if name in arena)


def build_synopsis_from_cache(k_cache: jax.Array, v_cache: jax.Array,
                              cluster_size: int):
  """Aggregate a (.., S, D) exact cache into centroid tables (paper step 3:
  mean aggregation).  Contiguous C-token clusters — the permutation to
  similarity order is applied upstream by repro.serve.synopsis_kv."""
  *lead, S, D = k_cache.shape
  M = S // cluster_size
  ks = k_cache.reshape(*lead, M, cluster_size, D)
  vs = v_cache.reshape(*lead, M, cluster_size, D)
  return (ks.mean(axis=-2).astype(k_cache.dtype),
          vs.mean(axis=-2).astype(v_cache.dtype),
          jnp.full((*k_cache.shape[:-3], M), cluster_size, jnp.float32))
