"""Prefill step: run the full prompt, emit (last-token logits, decode cache).

The cache comes out in the decode layout (nb, na, B, Hkv, S, D); for
AccuracyTrader serving, ``repro.serve.synopsis_kv.build`` then clusters it
into the synopsis structure (offline module of the paper — runs once per
sequence after prefill and incrementally thereafter).

Attention runs through the kernel suite (``repro.kernels.ops
.prefill_attention``) behind the same ``impl`` switch as decode:
``"auto"``/None resolves to the flash-tiled Pallas kernel on TPU and the
chunked XLA reference elsewhere (DESIGN.md §6).  ``launch/serve.py
--pipeline`` overlaps this step with the previous batch's synopsis build.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import constrain
from repro.kernels import ops
from repro.models import attention as attn_lib
from repro.models import common as cm
from repro.models import transformer as tf
from repro.models.layers import rms_norm, softcap


def make_prefill_step(cfg: cm.ModelConfig, *, impl: Optional[str] = None):
  """``impl`` overrides ``cfg.synopsis.impl``; both default to "auto"
  (flash Pallas prefill on TPU, chunked XLA reference elsewhere)."""
  impl = ops.resolve_impl(impl if impl is not None else cfg.synopsis.impl)

  def prefill_step(params, tokens, frontend_embeds=None):
    h, _, kv = tf.hidden_states(params, cfg, tokens, frontend_embeds,
                                collect_kv=True, impl=impl)
    last = h[:, -1]                                           # (B, d)
    w = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    logits = jnp.einsum("bd,dv->bv", last.astype(jnp.float32),
                        w.astype(jnp.float32))
    logits = softcap(logits, cfg.logit_softcap)
    logits = constrain(logits, ("batch", "vocab"))

    cache: Dict[str, jax.Array] = {}
    B = tokens.shape[0]
    S = h.shape[1]
    for name in ("k", "v", "cross_k", "cross_v", "conv_state", "ssd_state"):
      if kv and name in kv:
        cache[name] = kv[name]
    cache["pos"] = jnp.full((B,), S, jnp.int32)
    return logits, cache

  return prefill_step


def _extend_layer(x, lp, cfg: cm.ModelConfig, spec: cm.LayerSpec,
                  positions, pk, pv):
  """One decoder layer over E extension tokens attending [prefix; ext].

  Mirrors ``transformer._layer_forward`` for the archs
  ``corpus_cache.supports_delta`` admits (plain global GQA rope
  attention, optionally sandwich/parallel-block) — the difference is
  the KV source: the prefix half comes from the cached arena's sorted
  KV ``pk``/``pv`` (B, Hkv, P, D) instead of being recomputed.  Sound
  because softmax over cached keys is permutation-invariant and rope
  was applied at true positions before caching, so the sorted order of
  the arena does not change any extension token's attention output.

  Returns (x, k_new, v_new) with the new KV in decode layout
  (B, Hkv, E, D)."""
  h = rms_norm(x, lp["ln1"], cfg.norm_eps)
  q, k, v = attn_lib.qkv(h, lp["attn"], cfg, positions)
  k_new = jnp.moveaxis(k, 1, 2)                        # (B, Hkv, E, D)
  v_new = jnp.moveaxis(v, 1, 2)
  k_all = jnp.concatenate([pk.astype(k_new.dtype), k_new], axis=2)
  v_all = jnp.concatenate([pv.astype(v_new.dtype), v_new], axis=2)

  B, E, H, D = q.shape
  Hkv = k_all.shape[1]
  P = pk.shape[2]
  G = H // Hkv
  qg = jnp.moveaxis(q, 1, 2).reshape(B, Hkv, G, E, D)
  logits = jnp.einsum("bhged,bhsd->bhges", qg.astype(jnp.float32),
                      k_all.astype(jnp.float32)) * cfg.hd ** -0.5
  logits = softcap(logits, cfg.attn_softcap)
  # Every prefix key (s < P, any sorted order) is causally visible to
  # every extension query; among extension keys plain causality applies.
  vis = (jnp.arange(P + E)[None, :] - P) <= jnp.arange(E)[:, None]
  logits = jnp.where(vis[None, None, None], logits, -1e30)
  w = jax.nn.softmax(logits, axis=-1)
  o = jnp.einsum("bhges,bhsd->bhged", w, v_all.astype(jnp.float32))
  o = jnp.moveaxis(o.reshape(B, H, E, D), 1, 2).astype(x.dtype)
  mix = attn_lib.out_proj(o, lp["attn"], x.dtype)
  if cfg.sandwich_norm:
    mix = rms_norm(mix, lp["ln1_post"], cfg.norm_eps)

  if cfg.parallel_block:
    f, _ = tf._ffn(h, lp, cfg, spec)
    x = x + mix + f
  else:
    x = x + mix
    if "ln2" in lp:
      h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
      f, _ = tf._ffn(h2, lp, cfg, spec)
      if cfg.sandwich_norm:
        f = rms_norm(f, lp["ln2_post"], cfg.norm_eps)
      x = x + f
  return x, k_new, v_new


def make_extend_step(cfg: cm.ModelConfig, *, impl: Optional[str] = None):
  """Delta prefill for prefix-extended corpora (DESIGN.md §12): run only
  the E extension tokens against a cached arena's sorted prefix KV,
  skipping the prefix's O(P) recompute entirely.

  Gate on ``corpus_cache.supports_delta(cfg)`` before building this —
  SSM/MLA/local/cross archs couple the extension to prefix internals the
  arena does not cache.  ``impl`` is accepted for signature symmetry with
  ``make_prefill_step``; the extension attention itself is plain XLA
  (E is small — one or a few clusters — so there is no kernel to win).

  extend_step(params, ext_tokens (B, E), prefix_k, prefix_v
  (nb, na, B, Hkv, P, D), pos0) -> (last-token logits, ext KV
  (nb, na, B, Hkv, E, D) pair) — feed the KV to
  ``synopsis_kv.extend_synopsis``."""
  del impl

  def extend_step(params, ext_tokens, prefix_k, prefix_v, pos0):
    x = tf.embed_tokens(params, cfg, ext_tokens)
    E = x.shape[1]
    positions = pos0 + jnp.arange(E)

    def superblock(x, xs):
      stacked, pk, pv = xs            # pk/pv: (na, B, Hkv, P, D)
      ks, vs = [], []
      for i, spec in enumerate(cfg.block_pattern):
        x, k_, v_ = _extend_layer(x, stacked[f"pos{i}"], cfg, spec,
                                  positions, pk[i], pv[i])
        ks.append(k_)
        vs.append(v_)
      return x, (jnp.stack(ks), jnp.stack(vs))

    x, (k_new, v_new) = jax.lax.scan(
        superblock, x, (params["blocks"], prefix_k, prefix_v))
    h = rms_norm(x, params["final_norm"], cfg.norm_eps)
    last = h[:, -1]
    w = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    logits = jnp.einsum("bd,dv->bv", last.astype(jnp.float32),
                        w.astype(jnp.float32))
    logits = softcap(logits, cfg.logit_softcap)
    return logits, (k_new, v_new)

  return extend_step
