"""Prefill step: run the full prompt, emit (last-token logits, decode cache).

The cache comes out in the decode layout (nb, na, B, Hkv, S, D); for
AccuracyTrader serving, ``repro.serve.synopsis_kv.build`` then clusters it
into the synopsis structure (offline module of the paper — runs once per
sequence after prefill and incrementally thereafter).

Attention runs through the kernel suite (``repro.kernels.ops
.prefill_attention``) behind the same ``impl`` switch as decode:
``"auto"``/None resolves to the flash-tiled Pallas kernel on TPU and the
chunked XLA reference elsewhere (DESIGN.md §6).  ``launch/serve.py
--pipeline`` overlaps this step with the previous batch's synopsis build.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import constrain
from repro.kernels import ops
from repro.models import common as cm
from repro.models import transformer as tf
from repro.models.layers import softcap


def make_prefill_step(cfg: cm.ModelConfig, *, impl: Optional[str] = None):
  """``impl`` overrides ``cfg.synopsis.impl``; both default to "auto"
  (flash Pallas prefill on TPU, chunked XLA reference elsewhere)."""
  impl = ops.resolve_impl(impl if impl is not None else cfg.synopsis.impl)

  def prefill_step(params, tokens, frontend_embeds=None):
    h, _, kv = tf.hidden_states(params, cfg, tokens, frontend_embeds,
                                collect_kv=True, impl=impl)
    last = h[:, -1]                                           # (B, d)
    w = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    logits = jnp.einsum("bd,dv->bv", last.astype(jnp.float32),
                        w.astype(jnp.float32))
    logits = softcap(logits, cfg.logit_softcap)
    logits = constrain(logits, ("batch", "vocab"))

    cache: Dict[str, jax.Array] = {}
    B = tokens.shape[0]
    S = h.shape[1]
    for name in ("k", "v", "cross_k", "cross_v", "conv_state", "ssd_state"):
      if kv and name in kv:
        cache[name] = kv[name]
    cache["pos"] = jnp.full((B,), S, jnp.int32)
    return logits, cache

  return prefill_step
