"""Deterministic component fault injection for the serving stack
(DESIGN.md §11).

AccuracyTrader's premise is graceful degradation — every component can
always answer from its synopsis — yet a serving tier that only degrades
along the refinement-budget axis silently assumes components never
*fail*.  This module provides the fault model both the cluster tier
(`repro.serve.cluster.ClusterStepBackend`) and the discrete-event
simulator (`repro.serving.service`) inject:

  * **crash** — the component stops serving (its primary shard and any
    replica shard it holds) either forever or for ``down_steps`` steps;
    scheduled deterministically (``FaultSpec.crash``) or drawn at a
    per-component per-step rate (``crash_rate``);
  * **transient stall** — one step where the component's completion is
    multiplied by ``stall_scale`` (a GC pause, a page fault storm);
  * **persistent slowdown** — ``slow_scale`` × for ``slow_steps``
    consecutive steps (a co-located job landing on the machine).

Everything is **seed-deterministic**: the fault state of step ``t`` is a
pure function of ``(spec.seed, window_seed, t)`` — each step's draws come
from their own ``SeedSequence([seed, window, step])`` stream, so replays,
warmup length, and query order cannot shift the injected faults, and a
re-run of a benchmark window reproduces the same fault world
(``FaultPlan.reseed`` is called per measurement window exactly like the
backend's interference stream).

``FaultPlan(None, n)`` is the **disabled** plan: ``enabled`` is False,
``at(step)`` returns the all-alive state, and callers guard their fault
branches on ``enabled`` so the disabled path is bit-identical to a stack
without fault injection at all (property-tested in
tests/test_resilience.py).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

__all__ = ["FaultSpec", "FaultState", "FaultPlan", "parse_fault_spec"]


@dataclasses.dataclass(frozen=True)
class FaultSpec:
  """Declarative fault world for one serving run.

  ``crash`` schedules deterministic crashes as ``(step, component)``
  pairs (the component is dead from that step on, or for ``down_steps``
  steps when > 0); the ``*_rate`` knobs draw additional faults per
  component per step.  All randomness is derived from ``seed`` (plus the
  per-window reseed), never from the backend's interference stream."""
  crash: Tuple[Tuple[int, int], ...] = ()   # (step, component) schedule
  crash_rate: float = 0.0                   # per component per step
  down_steps: int = 0                       # 0 = crashed forever
  stall_rate: float = 0.0                   # transient one-step stall
  stall_scale: float = 25.0
  slow_rate: float = 0.0                    # persistent slowdown onset
  slow_scale: float = 4.0
  slow_steps: int = 16
  seed: int = 0

  def __post_init__(self):
    for name in ("crash_rate", "stall_rate", "slow_rate"):
      v = getattr(self, name)
      if not 0.0 <= v <= 1.0:
        raise ValueError(f"{name} {v} outside [0, 1]")
    for s, c in self.crash:
      if s < 0 or c < 0:
        raise ValueError(f"crash entry ({s}, {c}) must be non-negative")


@dataclasses.dataclass(frozen=True)
class FaultState:
  """One step's injected world: ``alive[c]`` is False while component c
  is crashed; ``slow[c]`` multiplies its completion time (1.0 = clean)."""
  alive: np.ndarray            # (N,) bool
  slow: np.ndarray             # (N,) float64

  @property
  def clean(self) -> bool:
    return bool(self.alive.all() and (self.slow == 1.0).all())


class FaultPlan:
  """Seed-deterministic fault schedule over ``n_components``.

  ``at(step)`` returns the :class:`FaultState` of that step.  States are
  derived sequentially (a crash at step t shadows steps t..t+down) but
  each step's *draws* are a pure function of ``(seed, window, step)``,
  so the schedule is independent of when or how often it is queried.
  ``FaultPlan(None, n)`` is the disabled no-op plan."""

  def __init__(self, spec: Optional[FaultSpec], n_components: int):
    self.spec = spec
    self.n = int(n_components)
    self.enabled = spec is not None
    self._window = 0
    self._reset()

  def _reset(self) -> None:
    self._states: List[FaultState] = []
    self._down_until = np.full(self.n, -1, np.int64)   # last dead step
    self._slow_until = np.full(self.n, -1, np.int64)

  def reseed(self, window_seed: int) -> None:
    """New measurement window: fresh fault world keyed by the window seed
    (mirrors ``ClusterStepBackend.reseed`` — the engine's ``run_open_loop``
    calls both, so a window's faults regenerate bit-identically)."""
    self._window = int(window_seed) & 0x7FFFFFFF
    self._reset()

  def _advance(self) -> FaultState:
    step = len(self._states)
    sp = self.spec
    rng = np.random.default_rng(
        np.random.SeedSequence([int(sp.seed), self._window, step]))
    # Scheduled crashes fire regardless of rates.
    for s, c in sp.crash:
      if s == step and c < self.n:
        self._down_until[c] = (step + sp.down_steps - 1) if sp.down_steps \
            else np.iinfo(np.int64).max
    # Drawn faults: one uniform vector per fault kind per step, so the
    # kinds' draws never alias each other.
    if sp.crash_rate > 0.0:
      hit = rng.random(self.n) < sp.crash_rate
      until = (step + sp.down_steps - 1) if sp.down_steps \
          else np.iinfo(np.int64).max
      self._down_until = np.where(hit, np.maximum(self._down_until, until),
                                  self._down_until)
    slow = np.ones(self.n, np.float64)
    if sp.slow_rate > 0.0:
      onset = rng.random(self.n) < sp.slow_rate
      self._slow_until = np.where(
          onset, np.maximum(self._slow_until, step + sp.slow_steps - 1),
          self._slow_until)
    slow = np.where(self._slow_until >= step, sp.slow_scale, slow)
    if sp.stall_rate > 0.0:
      slow = np.where(rng.random(self.n) < sp.stall_rate,
                      slow * sp.stall_scale, slow)
    alive = self._down_until < step
    state = FaultState(alive=alive, slow=slow)
    self._states.append(state)
    return state

  def at(self, step: int) -> FaultState:
    if not self.enabled:
      return FaultState(alive=np.ones(self.n, bool),
                        slow=np.ones(self.n, np.float64))
    step = int(step)
    while len(self._states) <= step:
      self._advance()
    return self._states[step]


def parse_fault_spec(text: Optional[str]) -> Optional[FaultSpec]:
  """CLI spec -> :class:`FaultSpec` (None / "" / "none" -> None).

  Comma-separated ``key=value`` pairs; ``crash`` takes ``comp@step``
  entries joined by ``+``:

      crash=1@8,down_steps=0,stall_rate=0.02,seed=3
      crash=0@4+3@10,slow_rate=0.01,slow_scale=6
  """
  if not text or text.lower() == "none":
    return None
  kw = {}
  for part in text.split(","):
    key, _, val = part.partition("=")
    key = key.strip()
    if key == "crash":
      entries = []
      for ent in val.split("+"):
        comp, _, step = ent.partition("@")
        entries.append((int(step), int(comp)))
      kw["crash"] = tuple(entries)
    elif key in ("down_steps", "slow_steps", "seed"):
      kw[key] = int(val)
    elif key in ("crash_rate", "stall_rate", "slow_rate",
                 "stall_scale", "slow_scale"):
      kw[key] = float(val)
    else:
      raise ValueError(f"unknown fault spec key {key!r} in {text!r}")
  return FaultSpec(**kw)
