"""Decode (serve) step: one new token against a KV cache of length S.

``mode="exact"``    — full attention over the cache (baseline; O(S)).
``mode="synopsis"`` — AccuracyTrader: stage-1 centroid scoring + initial
result, top-``i_max`` cluster refinement, exact attention over the recent
ring buffer and the new token, all merged by online-softmax partials
(O(S/C + i_max*C + R)).  This is what makes `long_500k` runnable for
attention architectures.

The attention math lives in the kernel suite (``repro.kernels.ops``)
behind an ``impl`` switch plumbed from the serve config / launcher down
through the layer scan:

  * ``impl="pallas"`` (the default on TPU) — the fused kernels:
    `fused_synopsis_score_attention` reads ``k_syn``/``v_syn`` ONCE for
    both the stage-1 scores and the count-biased partials, and
    `block_gather_attention`'s fused epilogue streams the selected
    clusters by scalar-prefetched block DMA (no materialized
    (B,Hkv,I*C,D) gather copies), subtracts the selected centroids'
    stage-1 terms (decremental masking) and folds the recent-ring +
    self-KV partials into the same grid — one merge per layer instead of
    three.
  * ``impl="xla"`` — mathematically identical pure-jnp path (CPU tests,
    multi-pod dry-run); ``impl="interpret"`` — Pallas interpreter.

The layer loop mirrors training: one ``lax.scan`` over super-blocks whose
xs are (stacked params, stacked cache slices); only *changed* state (SSM
states, per-layer KV deltas) is emitted as ys, so the big caches are
read-only inside the step (no 2x cache live range at compile).

Sharding (SERVE_RULES / LONG_RULES): cache seq axes shard over `model`
(and `data` for long_500k) — each shard is one paper "component"; the
partial-merge all-reduces are the result composer.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import constrain
from repro.kernels import ops
from repro.models import attention as attn_lib
from repro.models import common as cm
from repro.models import ssm as ssm_lib
from repro.models import transformer as tf
from repro.models.layers import einsum, rms_norm, softcap

NEG_INF = -1e30

# Canonical impl resolution lives with the kernel suite; re-exported here
# for the launcher and tests that historically import it from serve_step.
resolve_impl = ops.resolve_impl


def _seq_axes():
  """Mesh axes the KV cache sequence dim is sharded over (rule table)."""
  from repro.dist import sharding as shd  # noqa: PLC0415
  rules = shd.current_rules() or dict(shd.DEFAULT_RULES)
  t = rules.get("kv_seq")
  if t is None:
    return ()
  return (t,) if isinstance(t, str) else tuple(t)


# ---------------------------------------------------------------------------
# Decode attention over a layer's cache slice — thin wrappers over the
# kernel-suite ops (all partial algebra now lives in repro.kernels).
# ---------------------------------------------------------------------------

def synopsis_decode_attention(
    q: jax.Array,            # (B, H, Dk) rope'd new-token queries
    cache: Dict[str, jax.Array],   # slice for this layer (no nb/na dims)
    *,
    i_max: int,
    cluster_size: int,
    sm_scale: float,
    cap: Optional[float] = None,
    self_kv: Optional[Tuple[jax.Array, jax.Array]] = None,
    impl: str = "xla",
):
  """AccuracyTrader Algorithm 1 on a KV cache; returns (B, H, Dv).

  Quantized-arena scale leaves (DESIGN.md §15) ride along when present
  in the cache slice; absent they keep the bit-identical f32 path."""
  self_k, self_v = self_kv if self_kv is not None else (None, None)
  return ops.synopsis_cache_attention(
      q, cache["k"], cache["v"], cache["k_syn"], cache["v_syn"],
      cache["counts"], cache.get("recent_k"), cache.get("recent_v"),
      cache.get("recent_len"), self_k, self_v,
      cache.get("k_syn_scale"), cache.get("v_syn_scale"),
      cache.get("k_scale"), cache.get("v_scale"),
      i_max=i_max, cluster_size=cluster_size, sm_scale=sm_scale, cap=cap,
      impl=impl)


def sharded_synopsis_attention(
    q, cache, *, i_max, cluster_size, sm_scale, cap=None, self_kv=None,
    seq_axes=("model",), impl="xla",
):
  """AccuracyTrader decode attention with the KV cache + synopsis sharded
  over ``seq_axes`` — the paper's n-component scatter-gather, made
  explicit: every shard ("component") scores its own centroids, the
  *global* ranking comes from one small score all-gather, each shard
  refines only the selected clusters it owns, and the online-softmax merge
  of shard partials is the result composer.  Collectives per layer: one
  (B,Hkv,M) f32 all-gather + one (B,H,D+2) partials all-gather — vs. the
  GSPMD fallback which all-gathers the whole cache shard (see
  EXPERIMENTS.md §Perf iteration 1).

  The shard-local body is the same two fused kernel stages as the
  single-device path (stage-1 fused score+attention over the local
  centroids, decremental stage-2 over locally-owned selected clusters);
  the recent/self extras fold into shard 0's stage-2 launch so they are
  counted exactly once."""
  from repro.dist import sharding as shd  # noqa: PLC0415
  from jax.sharding import PartitionSpec as P  # noqa: PLC0415
  mesh = shd.current_mesh()
  axes = tuple(a for a in seq_axes if mesh is not None and a in mesh.shape)
  M = cache["k_syn"].shape[2]
  B = q.shape[0]
  nshards = 1
  for a in axes:
    nshards *= mesh.shape[a]
  if not axes or M % nshards != 0 or nshards == 1:
    return synopsis_decode_attention(
        q, cache, i_max=i_max, cluster_size=cluster_size,
        sm_scale=sm_scale, cap=cap, self_kv=self_kv, impl=impl)

  # The batch dim stays DP-sharded: it must be *manual* too, else the
  # shard_map boundary would force-replicate it (a (B,Hkv,S/16,D) gather).
  dp = tuple(a for a in ("pod", "data")
             if a in mesh.shape and a not in axes)
  dp_n = 1
  for a in dp:
    dp_n *= mesh.shape[a]
  if B % max(dp_n, 1) != 0:
    dp, dp_n = (), 1
  bspec = dp if dp else None

  manual = set(axes) | set(dp)
  if (set(mesh.axis_names) - manual) and not shd.supports_partial_manual():
    # Partial-manual shard_map (manual over a subset of mesh axes) hits
    # an XLA partitioner CHECK on legacy jax builds; fall back to the
    # replicated body rather than crash (same result, GSPMD collectives).
    return synopsis_decode_attention(
        q, cache, i_max=i_max, cluster_size=cluster_size,
        sm_scale=sm_scale, cap=cap, self_kv=self_kv, impl=impl)

  kv_spec = P(bspec, None, axes, None)
  specs = {"k": kv_spec, "v": kv_spec, "k_syn": kv_spec, "v_syn": kv_spec,
           "counts": P(bspec, axes)}
  for name in ("k_syn_scale", "v_syn_scale", "k_scale", "v_scale"):
    if name in cache:        # quantized arena (§15): shard like counts
      specs[name] = P(bspec, None, axes)
  for name in ("recent_k", "recent_v"):
    if name in cache:
      specs[name] = P(bspec, None, None, None)
  if "recent_len" in cache:
    specs["recent_len"] = P(bspec)
  cache = {k_: cache[k_] for k_ in specs}
  M_local = M // nshards
  q_spec = P(bspec, None, None)
  self_spec = (P(bspec, None, None, None),) * 2 if self_kv is not None \
      else P()

  def body(q, cache, self_kv):
    with shd.manual_axes(manual):
      # Combined shard index along the sequence axes.
      sid = jnp.int32(0)
      for a in axes:
        sid = sid * mesh.shape[a] + jax.lax.axis_index(a)
      k_syn = cache["k_syn"]

      syn_scales = (None if "k_syn_scale" not in cache else
                    (cache["k_syn_scale"], cache["v_syn_scale"]))
      kv_scales = (None if "k_scale" not in cache else
                   (cache["k_scale"], cache["v_scale"]))

      # Stage 1 (fused): local scores + local count-biased partials in
      # one pass; then one small all-gather for the global ranking.
      sc_local, p_syn = ops.synopsis_stage1(
          q, k_syn, cache["v_syn"], cache["counts"], sm_scale=sm_scale,
          cap=cap, impl=impl, syn_scales=syn_scales)
      sc = sc_local
      for a in reversed(axes):
        sc = jax.lax.all_gather(sc, a, axis=2, tiled=True)   # (B,Hkv,M)
      _, selected = jax.lax.top_k(sc, min(i_max, M))
      selected = selected.astype(jnp.int32)

      # Stage 2 (fused epilogue): refine only the clusters this shard
      # owns; the decrement removes their centroid terms from p_syn.
      lo = sid * M_local
      sel_rel = selected - lo
      mine = (sel_rel >= 0) & (sel_rel < M_local)
      sel_local = jnp.where(mine, sel_rel, -1)

      extras = ops.build_extras(
          cache.get("recent_k"), cache.get("recent_v"),
          cache.get("recent_len"), self_kv)
      if extras is not None:
        ek, ev, eb = extras
        eb = jnp.where(sid == 0, eb, NEG_INF)   # count extras once
        extras = (ek, ev, eb)
      p_ref = ops.refine_stage2(
          q, cache["k"], cache["v"], sel_local, k_syn, cache["v_syn"],
          cache["counts"], cluster_size=cluster_size, sm_scale=sm_scale,
          cap=cap, impl=impl, extras=extras, syn_scales=syn_scales,
          kv_scales=kv_scales)
      part = ops.merge_partials(p_syn, p_ref)

      # Compose shard partials (the paper's result composer).
      o, m_, l_ = part
      gathered = [o[None], m_[None], l_[None]]
      for a in reversed(axes):
        gathered = [jax.lax.all_gather(g, a, axis=0, tiled=True)
                    for g in gathered]
      og, mg, lg = gathered
      acc = (og[0], mg[0], lg[0])
      for i in range(1, og.shape[0]):
        acc = ops.merge_partials(acc, (og[i], mg[i], lg[i]))
      return acc[0]

  return shd.shard_map(
      body, mesh=mesh, in_specs=(q_spec, specs, self_spec),
      out_specs=q_spec if dp else P(),
      axis_names=manual, check_vma=False,
  )(q, cache, self_kv)


def exact_decode_attention(q, k, v, *, sm_scale, cap=None, self_kv=None,
                           window: Optional[int] = None, impl="xla"):
  if window is not None and window < k.shape[2]:
    k = k[:, :, -window:]
    v = v[:, :, -window:]
  out = ops.decode_partials(q, k, v, sm_scale=sm_scale, cap=cap, impl=impl)
  if self_kv is not None:
    # One-token self partial: always the jnp path (a (B,Hkv,1,D) einsum
    # is cheaper than a kernel launch and tile-shape agnostic).
    out = ops.merge_partials(
        out, ops.decode_partials(q, self_kv[0], self_kv[1],
                                 sm_scale=sm_scale, cap=cap, impl="xla"))
  return out[0]


# ---------------------------------------------------------------------------
# Per-layer decode
# ---------------------------------------------------------------------------

def _attn_decode_layer(x, lp, cfg: cm.ModelConfig, spec, cache_sl, pos,
                       mode, i_max, impl, attention_fn=None):
  """x (B,1,d); cache_sl: this layer's cache slice.
  Returns (y, delta, aux) — ``aux`` is None unless an ``attention_fn``
  override (the cluster tier, DESIGN.md §9) reports per-component
  telemetry to thread out of the layer scan."""
  B = x.shape[0]
  aux = None

  def synopsis_attn(q, csl, *, sm_scale, cap=None, self_kv=None):
    nonlocal aux
    if attention_fn is None:
      return sharded_synopsis_attention(
          q, csl, i_max=i_max, cluster_size=cfg.synopsis.cluster_size,
          sm_scale=sm_scale, cap=cap, self_kv=self_kv,
          seq_axes=_seq_axes(), impl=impl)
    ctx, aux = attention_fn(
        q, csl, i_max=i_max, cluster_size=cfg.synopsis.cluster_size,
        sm_scale=sm_scale, cap=cap, self_kv=self_kv, impl=impl)
    return ctx
  positions = pos[:, None]                                    # (B,1)
  if cfg.mla:
    m = cfg.mla
    q_nope, q_pe = attn_lib.mla_queries(x, lp, cfg, positions)
    c_kv, k_pe = attn_lib.mla_latent(x, lp, cfg, positions)
    # Absorbed: q_lat[h] = q_nope[h] @ wk_b[:,h,:]^T  -> latent space.
    q_lat = einsum("bshk,rhk->bshr", q_nope, lp["wk_b"])[:, 0]  # (B,H,r)
    q_eff = jnp.concatenate([q_lat, q_pe[:, 0]], axis=-1)     # (B,H,r+rope)
    lat_new = jnp.concatenate([c_kv, k_pe], axis=-1)          # (B,1,Dk)
    self_kv = (lat_new[:, None], lat_new[:, None])            # (B,1,1,Dk)
    sm_scale = (m.qk_nope_dim + m.qk_rope_dim) ** -0.5
    if mode == "synopsis":
      ctx = synopsis_attn(q_eff, cache_sl, sm_scale=sm_scale,
                          self_kv=self_kv)
    else:
      ctx = exact_decode_attention(q_eff, cache_sl["k"], cache_sl["v"],
                                   sm_scale=sm_scale, self_kv=self_kv,
                                   impl=impl)
    # ctx is a latent-space context (B, H, r+rope); drop the rope part and
    # decompress per head via wv_b.
    ctx_lat = ctx[..., :m.kv_lora_rank]
    o = einsum("bhr,rhk->bhk", ctx_lat, lp["wv_b"])           # (B,H,v_dim)
    y = einsum("bhk,hkd->bd", o, lp["wo"])[:, None].astype(x.dtype)
    delta = (lat_new[:, None], lat_new[:, None])
  else:
    q, k_new, v_new = attn_lib.qkv(x, lp, cfg, positions)
    q = q[:, 0]                                               # (B,H,D)
    kd = jnp.moveaxis(k_new, 1, 2)                            # (B,Hkv,1,D)
    vd = jnp.moveaxis(v_new, 1, 2)
    sm_scale = cfg.hd ** -0.5
    if spec.local:
      ctx = exact_decode_attention(
          q, cache_sl["k"], cache_sl["v"], sm_scale=sm_scale,
          cap=cfg.attn_softcap, self_kv=(kd, vd),
          window=cfg.sliding_window, impl=impl)
    elif mode == "synopsis":
      ctx = synopsis_attn(q, cache_sl, sm_scale=sm_scale,
                          cap=cfg.attn_softcap, self_kv=(kd, vd))
    else:
      ctx = exact_decode_attention(
          q, cache_sl["k"], cache_sl["v"], sm_scale=sm_scale,
          cap=cfg.attn_softcap, self_kv=(kd, vd), impl=impl)
    y = attn_lib.out_proj(ctx[:, None].astype(x.dtype), lp, x.dtype)
    delta = (kd, vd)
  return y, delta, aux


def _cross_decode_layer(x, lp, cfg, cache_sl, impl):
  q = einsum("bsd,dhk->bshk", x, lp["wq"]).astype(x.dtype)
  if "bq" in lp:
    q = q + lp["bq"][None, None].astype(x.dtype)
  ctx = exact_decode_attention(q[:, 0], cache_sl["cross_k"],
                               cache_sl["cross_v"],
                               sm_scale=cfg.hd ** -0.5, impl=impl)
  return attn_lib.out_proj(ctx[:, None].astype(x.dtype), lp, x.dtype)


# ---------------------------------------------------------------------------
# Full serve step
# ---------------------------------------------------------------------------

def make_serve_step(cfg: cm.ModelConfig, *, mode: str = "exact",
                    i_max: Optional[int] = None,
                    impl: Optional[str] = None,
                    attention_fn=None):
  """Returns serve_step(params, cache, tokens) ->
  (logits (B, vocab), new_state dict with ssm/kv deltas).

  ``impl`` overrides ``cfg.synopsis.impl``; both default to "auto"
  (fused Pallas kernels on TPU, XLA reference elsewhere).

  ``attention_fn`` optionally replaces the synopsis decode attention with
  a custom scatter-gather body (the multi-component cluster tier,
  DESIGN.md §9).  It is called as ``attention_fn(q, cache_sl, i_max=...,
  cluster_size=..., sm_scale=..., cap=..., self_kv=..., impl=...)`` and
  must return ``(ctx, aux)`` where ``aux`` is a dict of small per-layer
  telemetry arrays threaded out of the scan as extra ``new_state``
  entries.  Cache keys starting with ``"fe_"`` (frontend inputs, e.g. the
  per-component gather-mode vector) are broadcast to every layer instead
  of scanned."""
  i_max = cfg.synopsis.i_max if i_max is None else i_max
  impl = resolve_impl(impl if impl is not None else cfg.synopsis.impl)
  pattern = cfg.block_pattern

  def serve_step(params, cache, tokens):
    B = tokens.shape[0]
    x = params["embed"][tokens[:, 0]][:, None].astype(cfg.dtype)   # (B,1,d)
    if cfg.scale_embed:
      x = x * jnp.asarray(cfg.d_model ** 0.5, cfg.dtype)
    pos = cache["pos"]

    attn_i = [i for i, s in enumerate(pattern) if s.kind == "attn"]
    ssm_i = [i for i, s in enumerate(pattern) if s.kind == "mamba"]

    def superblock(carry, xs):
      x, = carry
      blk, csl = xs
      deltas: Dict[str, Any] = {}
      ai = si = 0
      for i, spec in enumerate(pattern):
        lp = blk[f"pos{i}"]
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        if spec.kind == "attn":
          layer_cache = {kk: csl[kk][ai] for kk in csl
                         if kk not in ("conv_state", "ssd_state",
                                       "recent_len")
                         and not kk.startswith("fe_")}
          for kk in csl:
            if kk == "recent_len" or kk.startswith("fe_"):
              layer_cache[kk] = csl[kk]
          mix, delta, aux = _attn_decode_layer(h, lp["attn"], cfg, spec,
                                               layer_cache, pos, mode,
                                               i_max, impl, attention_fn)
          deltas.setdefault("k_delta", []).append(delta[0])
          deltas.setdefault("v_delta", []).append(delta[1])
          if aux:
            for ak, av in aux.items():
              deltas.setdefault(ak, []).append(av)
          ai += 1
        else:
          st = (csl["conv_state"][si], csl["ssd_state"][si])
          mix, new_st = ssm_lib.ssm_forward(h, lp["ssm"], cfg,
                                            decode_state=st)
          deltas.setdefault("conv_state", []).append(new_st[0])
          deltas.setdefault("ssd_state", []).append(new_st[1])
          si += 1
        if cfg.sandwich_norm:
          mix = rms_norm(mix, lp["ln1_post"], cfg.norm_eps)
        if cfg.parallel_block:
          f, _ = tf._ffn(h, lp, cfg, spec)
          x = x + mix + f
        else:
          x = x + mix
          if spec.cross_attn:
            hc = rms_norm(x, lp["ln_cross"], cfg.norm_eps)
            ccache = {"cross_k": csl["cross_k"][ai - 1],
                      "cross_v": csl["cross_v"][ai - 1]}
            x = x + _cross_decode_layer(hc, lp["cross"], cfg, ccache, impl)
          if "ln2" in lp:
            h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
            f, _ = tf._ffn(h2, lp, cfg, spec)
            if cfg.sandwich_norm:
              f = rms_norm(f, lp["ln2_post"], cfg.norm_eps)
            x = x + f
      ys = {kk: jnp.stack(vv) for kk, vv in deltas.items()}
      return (x,), ys

    cache_xs = {kk: vv for kk, vv in cache.items()
                if kk not in ("pos", "recent_len")
                and not kk.startswith("fe_")}
    (x,), ys = jax.lax.scan(
        functools.partial(_scan_body, superblock, cache, cfg),
        (x,), (params["blocks"], cache_xs))

    h = rms_norm(x, params["final_norm"], cfg.norm_eps)[:, 0]   # (B,d)
    w = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    logits = jnp.einsum("bd,dv->bv", h.astype(jnp.float32),
                        w.astype(jnp.float32))
    logits = softcap(logits, cfg.logit_softcap)
    logits = constrain(logits, ("batch", "vocab"))
    new_state = dict(ys)
    new_state["pos"] = pos + 1
    return logits, new_state

  return serve_step


def _scan_body(superblock, cache, cfg, carry, xs):
  blk, csl = xs
  bcast = [kk for kk in cache if kk == "recent_len" or kk.startswith("fe_")]
  if bcast:
    csl = dict(csl)
    for kk in bcast:
      csl[kk] = cache[kk]
  return superblock(carry, (blk, csl))
