"""Synopsis construction/update over decode KV caches — the paper's
offline module specialised to attention memories.

* ``build``: cluster each (block, sequence)'s S cached tokens into M = S/C
  equal-size similarity clusters (PCA -> balanced kd / Morton over the
  concatenated kv-head key features), permute the cache cluster-contiguous,
  and aggregate per-cluster mean keys/values (centroids) — steps 1-3 of
  paper §2.2 with the R-tree replaced by balanced splits (DESIGN.md §3).
  The permute + aggregate runs through ``kernels.ops.synopsis_build``
  behind the ``impl`` switch: one fused streaming pass on the Pallas path
  vs. the take_along_axis -> reshape-mean chain on XLA (DESIGN.md §6).

* ``absorb_recent``: the incremental update (paper "situation 1"): tokens
  accumulated in the recent ring buffer become *new* clusters appended to
  the originals + centroid tables, recent buffer resets.  Runs as its own
  jitted program between serving batches (the paper's low-priority
  updating), reusing the same build kernel with the identity permutation.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.core import cluster as cl
from repro.kernels import ops
from repro.kernels import quant as qt
from repro.models import common as cm


def _qspec(cfg: cm.ModelConfig) -> Optional[str]:
  """cfg -> synopsis_build qconfig spec (None when unquantized)."""
  qc = qt.parse_qconfig(getattr(cfg.synopsis, "quant", "none"))
  return qc.spec if qc.enabled else None


def _cluster_perm(keys_flat: jax.Array, num_clusters: int,
                  method: str = "kd") -> jax.Array:
  """keys_flat (S, F) -> permutation (S,) in cluster-contiguous order."""
  coords, _ = cl.pca_project(keys_flat, out_dim=3, num_iters=4)
  return cl.cluster(coords, num_clusters, method=method)


def build(cache: Dict[str, jax.Array], cfg: cm.ModelConfig,
          method: str = "kd",
          impl: Optional[str] = None) -> Dict[str, jax.Array]:
  """Exact-cache -> synopsis-cache.  cache: k/v (nb, na, B, Hkv, S, D).

  ``impl`` overrides ``cfg.synopsis.impl`` for the permute + segment-mean
  aggregation (clustering itself is pure XLA — PCA and sorts have no
  kernel to win)."""
  impl = ops.resolve_impl(impl if impl is not None else cfg.synopsis.impl)
  k, v = cache["k"], cache["v"]
  nb, na, B, Hkv, S, D = k.shape
  C = cfg.synopsis.cluster_size
  assert S % C == 0
  M = S // C

  # One permutation per (block, layer, sequence): tokens are the data
  # points; features concat all kv heads (paper: one R-tree per subset).
  feats = jnp.moveaxis(k, 3, 4).reshape(nb * na * B, S, Hkv * D)
  perms = jax.vmap(lambda f: _cluster_perm(f.astype(jnp.float32), M,
                                           method))(feats)

  N = nb * na * B
  qspec = _qspec(cfg)
  built = ops.synopsis_build(
      k.reshape(N, Hkv, S, D), v.reshape(N, Hkv, S, D),
      perms.reshape(N, S).astype(jnp.int32), cluster_size=C, impl=impl,
      qconfig=qspec)
  if qspec is None:
    k_sorted, v_sorted, k_syn, v_syn, counts = built
    built = {"k": k_sorted, "v": v_sorted, "k_syn": k_syn,
             "v_syn": v_syn, "counts": counts}
  R = cfg.synopsis.recent

  out = {
      "k": built["k"].reshape(nb, na, B, Hkv, S, D),
      "v": built["v"].reshape(nb, na, B, Hkv, S, D),
      "k_syn": built["k_syn"].reshape(nb, na, B, Hkv, M, D),
      "v_syn": built["v_syn"].reshape(nb, na, B, Hkv, M, D),
      "counts": built["counts"].reshape(nb, na, B, M),
      "recent_k": jnp.zeros((nb, na, B, Hkv, R, D), k.dtype),
      "recent_v": jnp.zeros((nb, na, B, Hkv, R, D), v.dtype),
      "recent_len": jnp.zeros((B,), jnp.int32),
      "pos": cache["pos"],
  }
  for name in qt.SCALE_LEAVES:
    if name in built:
      out[name] = built[name].reshape(nb, na, B, Hkv, M)
  for extra in ("cross_k", "cross_v", "conv_state", "ssd_state"):
    if extra in cache:
      out[extra] = cache[extra]
  return out


def append_recent(cache: Dict[str, jax.Array], k_delta, v_delta):
  """Write one decode step's new kv (nb,na,B,Hkv,1,D) into the recent ring
  buffer at recent_len (same position for every sequence in the batch —
  batched serving steps advance in lockstep)."""
  rl = cache["recent_len"][0]
  rk = jax.lax.dynamic_update_slice_in_dim(cache["recent_k"], k_delta, rl,
                                           axis=4)
  rv = jax.lax.dynamic_update_slice_in_dim(cache["recent_v"], v_delta, rl,
                                           axis=4)
  return {**cache, "recent_k": rk, "recent_v": rv,
          "recent_len": cache["recent_len"] + 1}


def append_recent_slots(cache: Dict[str, jax.Array], k_delta, v_delta,
                        active: jax.Array):
  """Per-slot recent-ring write for the continuous-batching engine
  (DESIGN.md §8): slot ``b``'s new KV lands at its *own* ``recent_len[b]``
  and only ``active`` slots advance — unlike :func:`append_recent`, slots
  need not move in lockstep.  ``active``: (B,) bool.  Slots whose ring is
  full neither write nor advance (the engine bounds residency so this is
  unreachable; the guard keeps the op total)."""
  rl = cache["recent_len"]                                    # (B,)
  R = cache["recent_k"].shape[4]
  ok = active & (rl < R)
  hit = (jnp.arange(R)[None, :] == rl[:, None]) & ok[:, None]   # (B, R)
  sel = hit[None, None, :, None, :, None]                     # (1,1,B,1,R,1)
  rk = jnp.where(sel, k_delta, cache["recent_k"])
  rv = jnp.where(sel, v_delta, cache["recent_v"])
  return {**cache, "recent_k": rk, "recent_v": rv,
          "recent_len": rl + ok.astype(rl.dtype)}


def absorb_recent(cache: Dict[str, jax.Array], cfg: cm.ModelConfig,
                  impl: Optional[str] = None) -> Dict[str, jax.Array]:
  """Incremental synopsis update: recent tokens -> new clusters appended
  to the originals and centroid tables (paper situation 1: new data points
  -> new leaf nodes).  Shapes grow by R tokens / R/C clusters; this is the
  offline-module program, re-jitted per growth step.  The aggregation is
  the same fused build kernel with the identity permutation (the ring
  buffer is already time-contiguous)."""
  impl = ops.resolve_impl(impl if impl is not None else cfg.synopsis.impl)
  R = cache["recent_k"].shape[4]
  C = cfg.synopsis.cluster_size
  assert R % C == 0
  newM = R // C
  nb, na, B, Hkv, _, D = cache["recent_k"].shape

  rk, rv = cache["recent_k"], cache["recent_v"]
  N = nb * na * B
  ident = jnp.broadcast_to(jnp.arange(R, dtype=jnp.int32), (N, R))
  qspec = _qspec(cfg)
  built = ops.synopsis_build(
      rk.reshape(N, Hkv, R, D), rv.reshape(N, Hkv, R, D), ident,
      cluster_size=C, impl=impl, qconfig=qspec)
  if qspec is None:
    _, _, k_new, v_new, cnt_new = built
    built = {"k_syn": k_new, "v_syn": v_new, "counts": cnt_new,
             "k": rk.reshape(N, Hkv, R, D), "v": rv.reshape(N, Hkv, R, D)}
  # The identity-permuted sorted output == the ring rows (quantized under
  # the "+kv" specs), so concatenating the build's output covers both.
  k = jnp.concatenate(
      [cache["k"], built["k"].reshape(nb, na, B, Hkv, R, D).astype(
          cache["k"].dtype)], axis=4)
  v = jnp.concatenate(
      [cache["v"], built["v"].reshape(nb, na, B, Hkv, R, D).astype(
          cache["v"].dtype)], axis=4)
  k_syn = jnp.concatenate(
      [cache["k_syn"],
       built["k_syn"].reshape(nb, na, B, Hkv, newM, D)], axis=4)
  v_syn = jnp.concatenate(
      [cache["v_syn"],
       built["v_syn"].reshape(nb, na, B, Hkv, newM, D)], axis=4)
  counts = jnp.concatenate(
      [cache["counts"], built["counts"].reshape(nb, na, B, newM)], axis=3)
  out = {**cache, "k": k, "v": v, "k_syn": k_syn, "v_syn": v_syn,
         "counts": counts,
         "recent_k": jnp.zeros_like(rk), "recent_v": jnp.zeros_like(rv),
         "recent_len": jnp.zeros_like(cache["recent_len"])}
  for name in qt.SCALE_LEAVES:
    if name in cache:
      out[name] = jnp.concatenate(
          [cache[name], built[name].reshape(nb, na, B, Hkv, newM)], axis=4)
  return out


def extend_synopsis(arena: Dict[str, jax.Array], ext_k: jax.Array,
                    ext_v: jax.Array, cfg: cm.ModelConfig,
                    method: str = "kd",
                    impl: Optional[str] = None) -> Dict[str, jax.Array]:
  """Prefix-extension delta build (DESIGN.md §12): append E new prefill
  tokens' KV to an already-built arena without rebuilding the prefix.

  Unlike :func:`absorb_recent` (decode tokens, time-contiguous, identity
  permutation), the extension is E prefill tokens large enough to carry
  structure, so it gets its own similarity clustering — E/C clusters over
  the extension alone, appended after the prefix's M clusters.  The
  prefix's sorted KV, centroids and counts are untouched, which is what
  makes the cached arena reusable: build(prefix) + extend(ext) and the
  delta-replayed admission agree exactly on the prefix half.

  ext_k/ext_v: (nb, na, B, Hkv, E, D) from ``prefill.make_extend_step``.
  Returns a new arena (pos advanced by E; recent ring passthrough)."""
  impl = ops.resolve_impl(impl if impl is not None else cfg.synopsis.impl)
  nb, na, B, Hkv, E, D = ext_k.shape
  C = cfg.synopsis.cluster_size
  assert E % C == 0, (E, C)
  newM = E // C

  feats = jnp.moveaxis(ext_k, 3, 4).reshape(nb * na * B, E, Hkv * D)
  perms = jax.vmap(lambda f: _cluster_perm(f.astype(jnp.float32), newM,
                                           method))(feats)
  N = nb * na * B
  qspec = _qspec(cfg)
  built = ops.synopsis_build(
      ext_k.reshape(N, Hkv, E, D), ext_v.reshape(N, Hkv, E, D),
      perms.reshape(N, E).astype(jnp.int32), cluster_size=C, impl=impl,
      qconfig=qspec)
  if qspec is None:
    k_sorted, v_sorted, k_new, v_new, cnt_new = built
    built = {"k": k_sorted, "v": v_sorted, "k_syn": k_new,
             "v_syn": v_new, "counts": cnt_new}
  out = {**arena,
         "k": jnp.concatenate(
             [arena["k"], built["k"].reshape(nb, na, B, Hkv, E, D).astype(
                 arena["k"].dtype)], axis=4),
         "v": jnp.concatenate(
             [arena["v"], built["v"].reshape(nb, na, B, Hkv, E, D).astype(
                 arena["v"].dtype)], axis=4),
         "k_syn": jnp.concatenate(
             [arena["k_syn"],
              built["k_syn"].reshape(nb, na, B, Hkv, newM, D)], axis=4),
         "v_syn": jnp.concatenate(
             [arena["v_syn"],
              built["v_syn"].reshape(nb, na, B, Hkv, newM, D)], axis=4),
         "counts": jnp.concatenate(
             [arena["counts"], built["counts"].reshape(nb, na, B, newM)],
             axis=3),
         "pos": arena["pos"] + E}
  for name in qt.SCALE_LEAVES:
    if name in arena:
      out[name] = jnp.concatenate(
          [arena[name], built[name].reshape(nb, na, B, Hkv, newM)], axis=4)
  return out
