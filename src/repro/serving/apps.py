"""The paper's two example services on top of the core engine.

* :class:`CFRecommender` — user-based collaborative filtering on a
  user-item rating matrix (paper §3.2).  Synopsis = aggregated users
  (masked mean ratings per cluster); correlation c_i = Pearson weight
  between the active user and the aggregated user; refinement processes
  the original users of top-ranked clusters.  Accuracy = RMSE vs the
  exact full-computation prediction.

* :class:`SearchEngine` — document retrieval over term-frequency vectors.
  Synopsis = aggregated documents (merged cluster contents); correlation
  = aggregated page's similarity score to the query; accuracy = overlap
  of retrieved top-10 with the exact top-10.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import synopsis as syn_lib


def _pearson_rows(rows: jax.Array, row_mask: jax.Array, q: jax.Array,
                  q_mask: jax.Array) -> jax.Array:
  """Pearson correlation of each row with q over co-rated items."""
  both = row_mask * q_mask[None, :]
  n = jnp.maximum(jnp.sum(both, axis=1), 1.0)
  rm = jnp.sum(rows * both, axis=1) / n
  qm = jnp.sum(q[None] * both, axis=1) / n
  dr = (rows - rm[:, None]) * both
  dq = (q[None] - qm[:, None]) * both
  cov = jnp.sum(dr * dq, axis=1)
  var = jnp.sqrt(jnp.sum(dr * dr, axis=1) * jnp.sum(dq * dq, axis=1))
  return jnp.where(var > 1e-9, cov / jnp.maximum(var, 1e-9), 0.0)


@dataclasses.dataclass
class CFRecommender:
  ratings: jax.Array          # (n_users, n_items), 0 where unrated
  mask: jax.Array             # (n_users, n_items) in {0,1}
  num_clusters: int = 64

  def __post_init__(self):
    self.syn = syn_lib.build(self.ratings, self.num_clusters,
                             mask=self.mask)

  def correlations(self, q, q_mask) -> jax.Array:
    """c_i per aggregated user (paper: Pearson weight)."""
    return jnp.abs(_pearson_rows(self.syn.centroids,
                                 (self.syn.centroid_weight > 0).astype(
                                     self.ratings.dtype), q, q_mask))

  def predict(self, q: jax.Array, q_mask: jax.Array, items: jax.Array,
              budget: int) -> jax.Array:
    """Predict q's ratings on ``items`` processing the synopsis + the
    original users of the top-``budget`` clusters (Algorithm 1)."""
    c = self.correlations(q, q_mask)
    w_syn = _pearson_rows(self.syn.centroids,
                          (self.syn.centroid_weight > 0).astype(
                              self.ratings.dtype), q, q_mask)
    cm = (self.syn.centroid_weight > 0).astype(self.ratings.dtype)
    num = jnp.einsum("m,mi->i", w_syn,
                     (self.syn.centroids - _user_mean(
                         self.syn.centroids, cm)[:, None]) * cm)
    den = jnp.einsum("m,mi->i", jnp.abs(w_syn), cm)

    if budget > 0:
      _, sel = jax.lax.top_k(c, budget)
      rows_idx = self.syn.member_idx[sel].reshape(-1)
      ok = rows_idx >= 0
      rows = self.ratings[jnp.maximum(rows_idx, 0)]
      rmask = self.mask[jnp.maximum(rows_idx, 0)] * ok[:, None].astype(
          self.ratings.dtype)
      w = _pearson_rows(rows, rmask, q, q_mask)
      dev = (rows - _user_mean(rows, rmask)[:, None]) * rmask
      num = num + jnp.einsum("u,ui->i", w, dev)
      den = den + jnp.einsum("u,ui->i", jnp.abs(w), rmask)

    qbar = jnp.sum(q * q_mask) / jnp.maximum(jnp.sum(q_mask), 1.0)
    pred = qbar + num / jnp.maximum(den, 1e-6)
    return pred[items]

  def predict_exact(self, q, q_mask, items) -> jax.Array:
    w = _pearson_rows(self.ratings, self.mask, q, q_mask)
    dev = (self.ratings - _user_mean(self.ratings, self.mask)[:, None]) \
        * self.mask
    num = jnp.einsum("u,ui->i", w, dev)
    den = jnp.einsum("u,ui->i", jnp.abs(w), self.mask)
    qbar = jnp.sum(q * q_mask) / jnp.maximum(jnp.sum(q_mask), 1.0)
    return (qbar + num / jnp.maximum(den, 1e-6))[items]


def _user_mean(rows, mask):
  return jnp.sum(rows * mask, axis=1) / jnp.maximum(jnp.sum(mask, axis=1),
                                                    1.0)


@dataclasses.dataclass
class SearchEngine:
  docs: jax.Array             # (n_docs, vocab) tf vectors (l2-normalised)
  num_clusters: int = 64
  top_k: int = 10

  def __post_init__(self):
    norm = jnp.linalg.norm(self.docs, axis=1, keepdims=True)
    self.docs = self.docs / jnp.maximum(norm, 1e-9)
    self.syn = syn_lib.build(self.docs, self.num_clusters)

  def search(self, query_vec: jax.Array, budget: int) -> jax.Array:
    """Approximate top-k doc ids via Algorithm 1."""
    scores_syn = self.syn.centroids @ query_vec          # c_i (m,)
    n = self.docs.shape[0]
    doc_scores = jnp.full((n,), -jnp.inf)
    if budget > 0:
      _, sel = jax.lax.top_k(scores_syn, budget)
      idx = self.syn.member_idx[sel].reshape(-1)
      ok = idx >= 0
      rows = self.docs[jnp.maximum(idx, 0)]
      sc = rows @ query_vec
      sc = jnp.where(ok, sc, -jnp.inf)
      doc_scores = doc_scores.at[jnp.maximum(idx, 0)].max(sc)
    else:
      # stage 1 only: every doc inherits its aggregated page's score
      doc_scores = scores_syn[self.syn.row_cluster]
    _, top = jax.lax.top_k(doc_scores, self.top_k)
    return top

  def search_exact(self, query_vec: jax.Array) -> jax.Array:
    _, top = jax.lax.top_k(self.docs @ query_vec, self.top_k)
    return top

  def accuracy(self, query_vec: jax.Array, budget: int) -> float:
    """Fraction of the true top-10 present in the retrieved top-10."""
    approx = set(np.asarray(self.search(query_vec, budget)).tolist())
    exact = set(np.asarray(self.search_exact(query_vec)).tolist())
    return len(approx & exact) / max(len(exact), 1)


# ---------------------------------------------------------------------------
# Synthetic datasets shaped like the paper's (MovieLens / Sogou pages).
# ---------------------------------------------------------------------------

def movielens_like(n_users=4000, n_items=1000, density=0.0675, seed=0,
                   n_taste=8):
  """Low-rank user-taste structure + noise, ~0.27M ratings/subset scale."""
  rng = np.random.default_rng(seed)
  u = rng.normal(0, 1, (n_users, n_taste))
  v = rng.normal(0, 1, (n_items, n_taste))
  full = u @ v.T
  full = 3.0 + 1.2 * (full / full.std())
  full = np.clip(np.round(full * 2) / 2, 0.5, 5.0)
  mask = (rng.random((n_users, n_items)) < density).astype(np.float32)
  return (jnp.asarray(full * mask, jnp.float32),
          jnp.asarray(mask, jnp.float32))


def webpages_like(n_docs=20000, vocab=2000, n_topics=32, seed=0):
  rng = np.random.default_rng(seed)
  topics = rng.dirichlet(np.full(vocab, 0.05), n_topics)
  doc_topic = rng.dirichlet(np.full(n_topics, 0.2), n_docs)
  tf = doc_topic @ topics
  tf += rng.gamma(0.3, 0.02, tf.shape)
  return jnp.asarray(tf, jnp.float32)
