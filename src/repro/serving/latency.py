"""Component behaviour model for the discrete-event simulator.

The paper's testbed (110 Xen VMs, Storm, co-located MapReduce) is modelled
as a discrete-event simulation whose *component service times* follow the
calibrated two-part form the deadline controller assumes:

    t_service = base + per_item * items_processed

with multiplicative performance-interference noise (lognormal, heavy
tail) standing in for the co-located MapReduce jobs, plus an M/G/1-style
FIFO queue per component.  The synopsis/refinement *compute costs* fed in
come from real measured timings of the JAX engine (benchmarks/) so the
simulation's accuracy numbers are real, only the wall clock is modelled.

Latency *tracking and prediction* live in the shared control plane
(`repro.control`, DESIGN.md §10); ``TailTracker`` / ``percentile`` are
re-exported here for backwards compatibility.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.control.predictors import TailTracker, percentile  # noqa: F401


@dataclasses.dataclass
class ComponentModel:
  """Service-time model of one parallel component.

  ``comp_id`` names this component inside its service: a *measured*
  ``service_ms`` may be a per-component vector (the cluster tier's
  ``ClusterMeasuredExport.step_ms_per_component``), from which the
  component picks its own entry.  ``work_scale`` multiplies the service
  time — the Zipf component-skew knob (hot components own more of the
  corpus and serve slower)."""
  base_ms: float = 2.0            # synopsis / fixed overhead
  per_item_ms: float = 0.15       # per refined cluster (or per data part)
  full_items: int = 100           # items for exact full computation
  interference: float = 0.35      # lognormal sigma (MapReduce co-location)
  straggler_prob: float = 0.02    # chance of a severe slowdown
  straggler_scale: float = 8.0
  seed: int = 0
  comp_id: int = 0
  work_scale: float = 1.0

  def __post_init__(self):
    self.rng = np.random.default_rng(self.seed)
    self.busy_until = 0.0

  def _resolve_base(self, base_ms) -> Optional[float]:
    if base_ms is None:
      return None
    arr = np.asarray(base_ms, dtype=np.float64).ravel()
    if arr.size == 1:
      return float(arr[0])
    return float(arr[self.comp_id % arr.size])

  def service_time(self, items: int, base_ms: Optional[float] = None,
                   scale: float = 1.0) -> float:
    """Service time for ``items``; ``base_ms`` replaces the modelled
    ``base + per_item * items`` with an externally *measured* duration
    (the engine's per-bucket step latency — a scalar, or a per-component
    vector indexed by ``comp_id``) — interference noise and stragglers
    still apply on top (they model the co-located jobs, which the
    single-host measurement cannot see).  ``scale`` multiplies the
    pre-noise time — the injected fault slowdown (DESIGN.md §11)."""
    base = self._resolve_base(base_ms)
    t = base if base is not None \
        else self.base_ms + self.per_item_ms * items
    t *= self.work_scale * scale
    t *= float(self.rng.lognormal(0.0, self.interference))
    if self.rng.random() < self.straggler_prob:
      t *= self.straggler_scale
    return t

  def submit(self, arrival_ms: float, items: int,
             service_ms=None, scale: float = 1.0) -> float:
    """FIFO queue: returns completion time.  ``service_ms`` optionally
    pins the pre-noise service duration to a measured value (scalar or
    per-component vector, see ``service_time``); ``scale`` injects a
    fault slowdown on this submission."""
    start = max(arrival_ms, self.busy_until)
    done = start + self.service_time(items, base_ms=service_ms,
                                     scale=scale)
    self.busy_until = done
    return done

  def peek_completion(self, arrival_ms: float, items: int,
                      quantile_extra: float = 0.0) -> float:
    start = max(arrival_ms, self.busy_until)
    return start + self.base_ms + self.per_item_ms * items + quantile_extra
