"""Scatter-gather online service with the paper's three techniques.

One request fans out to ``n_components`` parallel components (each owns a
subset of input data); the request completes when the *composer* has what
it needs, so the p99.9 of component latency is the service latency
(paper §1).  Techniques:

  * ``basic``           — exact processing on every component.
  * ``reissue``         — exact + request reissue: if a component exceeds
                          the p95 of its class, a replica is sent to the
                          least-loaded component and the quicker wins
                          (Dean & Barroso tail-at-scale).
  * ``partial``         — partial execution: exact everywhere, but results
                          missing at the deadline are *skipped* (their
                          accuracy contribution is lost).
  * ``accuracytrader``  — stage 1 on the synopsis (fast, always returns)
                          then refine top-ranked clusters within the
                          budget chosen by the deadline controller.

Components are the discrete-event models in serving/latency.py; accuracy
accounting is exact (fractions of accuracy-relevant data actually
processed come from the real engine's correlation ranking).

``step_backend`` (optional) closes the loop with the real kernel path
(DESIGN.md §8): when set, the ``accuracytrader`` technique's component
service times come from the serving engine's *measured* per-bucket decode
latencies (`repro.serve.engine.MeasuredStepBackend`) instead of the
modelled ``base + slope * items`` — simulated time, measured step time.
The simulator and the engine share the `repro.control` latency-control
plane (predictors + BudgetController, DESIGN.md §10) and the fig-4
concentration curve; budget units differ
(clusters out of ``full_items`` here vs the engine's M), which the
backend converts (see ``MeasuredStepBackend.full_items``).
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.control import AffinePredictor, BudgetController, TailTracker
from repro.serving.latency import ComponentModel


@dataclasses.dataclass
class Request:
  rid: int
  arrival_ms: float
  # Per-component fraction of this request's accuracy mass concentrated in
  # the top-ranked clusters (from fig4-style measurement); accuracy of an
  # approximate answer = coverage of processed clusters weighted by this.
  accuracy_profile: Optional[np.ndarray] = None   # (n_sections,) weights


@dataclasses.dataclass
class ServiceConfig:
  n_components: int = 108
  technique: str = "accuracytrader"
  deadline_ms: float = 100.0
  full_items: int = 100            # clusters per component (exact = all)
  i_max_cap: int = 40              # paper: top-40% ranked sets
  reissue_pct: float = 95.0
  # Zipf exponent over per-component work: skew > 0 makes low-rank
  # components "hot" (they own more of the corpus and serve slower) —
  # the regime where partial execution's skipped stragglers carry the
  # most accuracy mass.  0 = the paper's uniform components.
  skew: float = 0.0
  seed: int = 0
  # -- resilience round-trip (DESIGN.md §11; all off by default) ---------
  faults: Optional["object"] = None   # repro.serve.resilience.FaultSpec
  replicas: int = 1            # >= 2: a dead component's shard is served
                               # by its ring replica (queueing behind it)
  shed: bool = False           # predictive shed-at-admission
  shed_margin: float = 1.0     # shed when backlog+service > ddl*margin
  # -- ε-or-deadline contracts (DESIGN.md §13) ---------------------------
  # In the simulator the "online estimator" IS the accuracy model (there
  # are no stage-1 scores to read), so error_bounded clamps the budget to
  # the smallest bucket the model says meets ε and the predicted loss is
  # exact by construction — calibration quality is an ENGINE property
  # (tests/test_estimator.py); the simulator only mirrors the contract's
  # budget semantics for fleet-scale what-ifs.
  contract: str = "deadline"
  epsilon: float = 0.02


class ScatterGatherService:
  def __init__(self, cfg: ServiceConfig,
               accuracy_fn: Optional[Callable[[float], float]] = None,
               step_backend=None):
    from repro.dist.topology import zipf_weights  # noqa: PLC0415
    from repro.control import CONTRACTS  # noqa: PLC0415
    if cfg.contract not in CONTRACTS:
      raise ValueError(f"contract {cfg.contract!r} not in {CONTRACTS}")
    self.cfg = cfg
    self.pred_tracker: List[float] = []
    # Measured per-budget step latencies (engine.MeasuredStepBackend, or
    # the cluster tier's ClusterMeasuredExport with per-component
    # vectors) — accuracytrader components serve in measured, not
    # modelled, time.
    self.step_backend = step_backend
    self.per_component_ms = step_backend is not None and hasattr(
        step_backend, "step_ms_per_component")
    # Component skew: hot components carry proportionally more work.  A
    # per-component measured export already encodes the real tier's
    # skew, so the modelled multiplier stays 1 in that case.
    if cfg.skew and not self.per_component_ms:
      scales = zipf_weights(cfg.n_components, cfg.skew) * cfg.n_components
    else:
      scales = np.ones((cfg.n_components,))
    self.components = [
        ComponentModel(seed=cfg.seed * 1000 + i, comp_id=i,
                       work_scale=float(scales[i]),
                       full_items=cfg.full_items)
        for i in range(cfg.n_components)
    ]
    self.tracker = TailTracker()
    self.acc_tracker: List[float] = []
    self.controller = BudgetController(
        AffinePredictor(base=2.0, slope=0.15),
        buckets=tuple(sorted({0, 1, 2, 4, 8, 16, 24, 32, 40,
                              cfg.i_max_cap})),
        i_max_cap=cfg.i_max_cap)
    self.class_latencies: List[float] = []
    # accuracy_fn: fraction_of_ranked_clusters_processed -> accuracy in
    # [0,1].  Default: fig4-style concentration curve (top-ranked clusters
    # carry most of the mass).
    self.accuracy_fn = accuracy_fn or _default_concentration
    self.rng = np.random.default_rng(cfg.seed)
    # Resilience round-trip (DESIGN.md §11): the same seed-deterministic
    # fault world the cluster tier injects, keyed here by request id.
    from repro.serve.resilience import FaultPlan  # noqa: PLC0415
    self.fault_plan = FaultPlan(cfg.faults, cfg.n_components)
    self.shed_n = 0
    self.total_n = 0
    self.avail_tracker: List[float] = []

  # -- one request -----------------------------------------------------------
  def submit(self, req: Request) -> Dict[str, float]:
    cfg = self.cfg
    tech = cfg.technique
    done_times = []
    processed_frac = []
    self.total_n += 1
    fstate = self.fault_plan.at(req.rid)

    queue_delay = float(np.mean([
        max(0.0, c.busy_until - req.arrival_ms) for c in self.components]))
    if cfg.shed:
      # Predictive shed-at-admission (DESIGN.md §11): the mean backlog
      # plus the predictor's stage-1 floor already misses the deadline —
      # refuse before any component burns work on a dead request.
      demand = queue_delay + self.controller.model.predict(0)
      if demand > cfg.deadline_ms * cfg.shed_margin:
        self.shed_n += 1
        self.acc_tracker.append(0.0)
        return {"latency_ms": 0.0, "accuracy": 0.0, "shed": True}
    if tech == "accuracytrader":
      budget = self.controller.budget_for(cfg.deadline_ms, queue_delay)
      if cfg.contract == "error_bounded":
        budget = min(budget, self._epsilon_budget())
      measured = None
      if self.step_backend is not None:
        # Per-component vector when the backend exports one (the cluster
        # tier's measured attribution); each ComponentModel indexes its
        # own entry by comp_id.
        measured = (self.step_backend.step_ms_per_component(budget)
                    if self.per_component_ms
                    else self.step_backend.step_ms(budget))
    lost_mass = 0
    for i, comp in enumerate(self.components):
      if tech in ("basic", "partial", "reissue"):
        items = cfg.full_items
        service_ms = None
      else:
        items = budget
        service_ms = measured
      if not fstate.alive[i]:
        # The fault round-trip (DESIGN.md §11): AccuracyTrader's ladder
        # fails a dead component's shard over to its ring replica (the
        # reissue queues behind the replica's own work) and terminally
        # degrades to the frontend-cached stage-1 synopsis; the other
        # techniques have no ladder — the composer waits out a hard
        # timeout and the shard's contribution is lost.
        j = (i + 1) % cfg.n_components
        if tech == "accuracytrader" and cfg.replicas > 1 \
            and fstate.alive[j]:
          t_done = self.components[j].submit(
              req.arrival_ms, items, service_ms=service_ms,
              scale=float(fstate.slow[j]))
          done_times.append(t_done)
          processed_frac.append(items / cfg.full_items)
        elif tech == "accuracytrader":
          done_times.append(req.arrival_ms + comp.base_ms)
          processed_frac.append(0.0)
        else:
          done_times.append(req.arrival_ms + 3.0 * cfg.deadline_ms)
          processed_frac.append(0.0)
          lost_mass += 1
        continue
      t_done = comp.submit(req.arrival_ms, items, service_ms=service_ms,
                           scale=float(fstate.slow[i]))
      done_times.append(t_done)
      processed_frac.append(items / cfg.full_items)

    if tech == "reissue" and self.class_latencies:
      thresh = np.percentile(self.class_latencies, cfg.reissue_pct)
      order = np.argsort([c.busy_until for c in self.components])
      spare = list(order)
      budget_replicas = max(1, cfg.n_components // 10)
      for i, t_done in enumerate(done_times):
        lat_i = t_done - req.arrival_ms
        if lat_i > thresh and spare and budget_replicas > 0:
          # replica on the least-loaded component, issued when the
          # straggler is detected; only if expected to finish sooner
          j = int(spare.pop(0))
          est = self.components[j].peek_completion(
              req.arrival_ms + thresh, cfg.full_items)
          if est < t_done:
            t_replica = self.components[j].submit(
                req.arrival_ms + thresh, cfg.full_items)
            done_times[i] = min(t_done, t_replica)
            budget_replicas -= 1

    lat = [t - req.arrival_ms for t in done_times]
    for v in lat:
      self.class_latencies.append(v)
    if len(self.class_latencies) > 5000:
      del self.class_latencies[:1000]

    deadline_abs = req.arrival_ms + cfg.deadline_ms
    if tech == "partial":
      # Components missing the deadline are SKIPPED: their subset's entire
      # accuracy contribution is lost (paper §5) — unlike AccuracyTrader,
      # where stage 1 always lands.
      acc = float(np.mean([1.0 if t <= deadline_abs else 0.0
                           for t in done_times]))
      comp_lat = min(max(lat), cfg.deadline_ms)
    elif tech == "accuracytrader":
      comp_lat = max(lat)
      self.controller.observe(budget, comp_lat)
      acc = float(np.mean([self.accuracy_fn(u) for u in processed_frac]))
      if cfg.contract != "deadline":
        # Model-is-truth (see ServiceConfig): predicted == realized loss.
        self.pred_tracker.append(1.0 - acc)
    else:
      # Exact techniques: a lost shard's contribution is simply missing
      # from the exact answer.
      acc = 1.0 - lost_mass / cfg.n_components
      comp_lat = max(lat)

    self.tracker.observe(comp_lat)
    self.acc_tracker.append(acc)
    self.avail_tracker.append(0.0 if lost_mass else 1.0)
    return {"latency_ms": comp_lat, "accuracy": acc}

  def _epsilon_budget(self) -> int:
    """Smallest controller bucket whose modelled loss meets ε.  ε <= 0
    demands exactness, which only the full ``i_max_cap`` spend gives —
    the same ε=0-is-the-exact-path rule as
    `AccuracyEstimator.bucket_for_epsilon` (DESIGN.md §13)."""
    cfg = self.cfg
    if cfg.epsilon <= 0.0:
      return cfg.i_max_cap
    for b in self.controller.buckets:
      if 1.0 - self.accuracy_fn(b / cfg.full_items) <= cfg.epsilon:
        return int(b)
    return cfg.i_max_cap

  def run_open_loop(self, arrival_rate_per_s: float, duration_s: float,
                    accuracy_profile=None) -> Dict[str, float]:
    """Poisson arrivals for one measurement window.  Queues and the
    calibrated latency model persist across windows; the percentile
    tracker resets (each call = one reported session, as in Fig 5)."""
    self.tracker = TailTracker()
    self.acc_tracker = []
    self.avail_tracker = []
    self.pred_tracker = []
    self.shed_n = 0
    self.total_n = 0
    t = max((c.busy_until for c in self.components), default=0.0)
    end = t + duration_s * 1000.0
    rid = 0
    while t < end:
      gap = self.rng.exponential(1000.0 / arrival_rate_per_s)
      t += gap
      self.submit(Request(rid, t))
      rid += 1
    s = self.tracker.summary()
    s["accuracy_loss_pct"] = 100.0 * (1.0 - float(np.mean(self.acc_tracker)))
    s["shed_pct"] = 100.0 * self.shed_n / max(1, self.total_n)
    s["availability_pct"] = (100.0 * float(np.mean(self.avail_tracker))
                             if self.avail_tracker else 0.0)
    if self.cfg.contract != "deadline":
      s["pred_loss_mean"] = float(np.mean(self.pred_tracker)) \
          if self.pred_tracker else 0.0
    return s


class ScaledFleetExport:
  """Rescale a fleet tier's measured per-component export onto a
  counterfactual (n, r) size — the autoscaler's simulator round-trip
  (DESIGN.md §14).

  The export was measured at ``n0`` components each owning ~1/n0 of
  every corpus; at ``n`` active components each owns ~1/n, so the
  per-component service time scales by n0/n.  Replica selection serves
  every shard from the fastest of its ``r`` materialized holders, which
  trims the measured per-component *excess over the mean* (the
  imbalance + straggler part — the min over r draws) by 1/r; the mean
  work itself is irreducible.  The result is a drop-in
  ``step_ms_per_component`` backend for
  ``ScatterGatherService(step_backend=...)``, and :meth:`step_model`
  is the ``step_ms_fn(n, r)`` the analytic `control.Autoscaler` scans.
  """

  def __init__(self, export, n_components: int, replicas: int = 1,
               model_budget: int = 8):
    if n_components < 1 or replicas < 1:
      raise ValueError(f"fleet size ({n_components}, {replicas}) invalid")
    self.export = export
    self.n_components = int(n_components)
    self.replicas = int(replicas)
    self.model_budget = int(model_budget)    # operating point of step_model

  def step_ms_per_component(self, budget: int) -> np.ndarray:
    v0 = np.asarray(self.export.step_ms_per_component(budget), np.float64)
    total = float(v0.sum())
    mean = total / self.n_components
    imbalance = float(v0.max()) / max(total / max(v0.size, 1), 1e-30) - 1.0
    per = mean * (1.0 + max(imbalance, 0.0) / self.replicas)
    return np.full(self.n_components, per)

  def step_ms(self, budget: int) -> float:
    return float(self.step_ms_per_component(budget).max())

  def step_model(self, n_components: int, replicas: int) -> float:
    """`Autoscaler` hook: predicted step wall at a candidate size (the
    frontend waits on the slowest component, so the per-component time
    IS the step wall)."""
    return ScaledFleetExport(self.export, n_components,
                             replicas).step_ms(self.model_budget)


def _default_concentration(frac: float) -> float:
  """Fig-4-style curve, calibrated to the paper's operating points: the
  synopsis stage alone recovers ~93 % of result accuracy, and the top-40 %
  ranked clusters recover ~99.9 % ("over 98.83 % of the actual top-10
  pages live in the top-40 % ranked sets")."""
  if frac <= 0.0:
    return 0.93
  return 0.93 + 0.07 * min(1.0, (frac / 0.45) ** 0.6)
