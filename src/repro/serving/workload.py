"""Workload generators mirroring the paper's two evaluations.

* ``cf_rates``      — the synthetic recommender workload: constant arrival
                      rates {20, 40, 60, 80, 100} req/s (Tables 1-2).
* ``sogou_hourly``  — a 24-hour diurnal arrival-rate profile shaped like
                      the Sogou query log (Fig 7a): low 2-8 am, morning
                      ramp (hour 9 increasing), midday plateau (hour 10
                      steady), evening peak, midnight decay (hour 24
                      decreasing).
* ``hour_trace``    — within-hour 60 x 1-minute sessions with the hour's
                      trend (increasing / steady / decreasing) — Fig 5/6.
"""
from __future__ import annotations

from typing import List

import numpy as np

CF_RATES = (20, 40, 60, 80, 100)

# req/s per hour-of-day, shaped like Fig 7(a) (peak ~ 90 req/s at 21:00).
SOGOU_HOURLY: List[float] = [
    35, 22, 14, 10, 8, 8, 10, 16, 28, 45, 55, 60,
    62, 58, 56, 58, 60, 62, 66, 74, 84, 90, 70, 50,
]


def hour_trend(hour: int) -> str:
  if hour in (9,):
    return "increasing"
  if hour in (24, 23):
    return "decreasing"
  return "steady"


def hour_trace(hour: int, sessions: int = 60, seed: int = 0) -> np.ndarray:
  """Per-minute arrival rates (req/s) for one hour."""
  rng = np.random.default_rng(seed + hour)
  base = SOGOU_HOURLY[(hour - 1) % 24]
  trend = hour_trend(hour)
  t = np.linspace(0, 1, sessions)
  if trend == "increasing":
    shape = 0.55 + 0.9 * t
  elif trend == "decreasing":
    shape = 1.25 - 0.75 * t
  else:
    shape = np.ones_like(t)
  noise = rng.lognormal(0, 0.08, sessions)
  return base * shape * noise
