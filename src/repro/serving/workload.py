"""Workload generators mirroring the paper's two evaluations.

* ``cf_rates``      — the synthetic recommender workload: constant arrival
                      rates {20, 40, 60, 80, 100} req/s (Tables 1-2).
* ``sogou_hourly``  — a 24-hour diurnal arrival-rate profile shaped like
                      the Sogou query log (Fig 7a): low 2-8 am, morning
                      ramp (hour 9 increasing), midday plateau, evening
                      peak (~90 req/s at 21:00), midnight decay.
* ``hour_trace``    — within-hour 60 x 1-minute sessions with the hour's
                      trend (increasing / steady / decreasing) — Fig 5/6.
* ``poisson_arrivals`` — request arrival offsets for one open-loop window
                      (the engine's arrival source; the simulator draws
                      its own equivalent stream inline).

Hour convention: ``SOGOU_HOURLY[h]`` is the rate at *0-based* hour of day
``h`` (index 21 = 21:00, the peak).  ``canonical_hour`` is the single
place both conventions meet: callers may pass 0..23 or the 1-based 1..24,
and hour 24 — the 1-based name for midnight — aliases hour 0 (same rate,
same trend, same trace).
"""
from __future__ import annotations

from typing import List

import numpy as np

CF_RATES = (20, 40, 60, 80, 100)

# req/s at 0-based hour-of-day h (peak ~ 90 req/s at 21:00, Fig 7a).
SOGOU_HOURLY: List[float] = [
    35, 22, 14, 10, 8, 8, 10, 16, 28, 45, 55, 60,
    62, 58, 56, 58, 60, 62, 66, 74, 84, 90, 70, 50,
]


def canonical_hour(hour: int) -> int:
  """Normalise an hour in either the 0-based (0..23) or 1-based (1..24)
  convention to the 0-based index into ``SOGOU_HOURLY``; 24 == 0."""
  return hour % 24


def hour_rate(hour: int) -> float:
  """Arrival rate (req/s) at the given hour of day (either convention)."""
  return SOGOU_HOURLY[canonical_hour(hour)]


def hour_trend(hour: int) -> str:
  h = canonical_hour(hour)
  if h == 9:
    return "increasing"
  if h in (23, 0):        # 23:00 decay into midnight (hour 24 == hour 0)
    return "decreasing"
  return "steady"


def hour_trace(hour: int, sessions: int = 60, seed: int = 0) -> np.ndarray:
  """Per-minute arrival rates (req/s) for one hour.  ``hour`` follows
  ``canonical_hour``, so ``hour_trace(0)`` and ``hour_trace(24)`` are the
  same trace."""
  h = canonical_hour(hour)
  rng = np.random.default_rng(seed + h)
  base = SOGOU_HOURLY[h]
  trend = hour_trend(h)
  t = np.linspace(0, 1, sessions)
  if trend == "increasing":
    shape = 0.55 + 0.9 * t
  elif trend == "decreasing":
    shape = 1.25 - 0.75 * t
  else:
    shape = np.ones_like(t)
  noise = rng.lognormal(0, 0.08, sessions)
  return base * shape * noise


def poisson_arrivals(rate_per_s: float, duration_s: float,
                     seed: int = 0) -> np.ndarray:
  """Arrival offsets (ms, sorted, starting at 0) of an open-loop Poisson
  process at ``rate_per_s`` over one ``duration_s`` window."""
  rng = np.random.default_rng(seed)
  out, t = [], 0.0
  end = duration_s * 1000.0
  while True:
    t += rng.exponential(1000.0 / max(rate_per_s, 1e-9))
    if t >= end:
      break
    out.append(t)
  return np.asarray(out)
