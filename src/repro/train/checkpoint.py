"""Fault-tolerant checkpointing: atomic, async, mesh-agnostic.

Layout:  <dir>/step_<n>/
           manifest.json   — tree structure, logical axes, dtypes, extras
           <leaf-path>.npy — one file per array leaf

* **Atomic**: written to ``step_<n>.tmp`` then os.rename'd — a crash never
  leaves a half checkpoint visible; restore picks the newest complete dir.
* **Async**: ``save_async`` snapshots to host memory synchronously (cheap)
  and writes on a background thread — training continues during the write.
* **Mesh-agnostic / elastic**: leaves are saved *unsharded* with their
  logical axes; ``restore`` re-shards onto whatever mesh/rule table the
  restarted job uses (elastic re-scale = restore on a different mesh).
"""
from __future__ import annotations

import json
import os
import re
import threading
from typing import Any, Optional

import jax
import numpy as np

SEP = "/"


def _flatten(tree, prefix=""):
  out = {}
  if isinstance(tree, dict):
    for k, v in tree.items():
      out.update(_flatten(v, f"{prefix}{k}{SEP}"))
  else:
    out[prefix.rstrip(SEP)] = tree
  return out


def _unflatten(flat):
  tree: dict = {}
  for path, v in flat.items():
    parts = path.split(SEP)
    node = tree
    for p in parts[:-1]:
      node = node.setdefault(p, {})
    node[parts[-1]] = v
  return tree


def save(ckpt_dir: str, step: int, tree: Any, extras: Optional[dict] = None):
  """Synchronous atomic save."""
  flat = _flatten(tree)
  final = os.path.join(ckpt_dir, f"step_{step:08d}")
  tmp = final + ".tmp"
  os.makedirs(tmp, exist_ok=True)
  manifest = {"step": step, "leaves": {}, "extras": extras or {}}
  for path, arr in flat.items():
    arr = np.asarray(jax.device_get(arr))
    fname = path.replace(SEP, "__") + ".npy"
    np.save(os.path.join(tmp, fname), arr)
    manifest["leaves"][path] = {"file": fname, "dtype": str(arr.dtype),
                                "shape": list(arr.shape)}
  with open(os.path.join(tmp, "manifest.json"), "w") as f:
    json.dump(manifest, f)
  if os.path.exists(final):
    os.rename(final, final + ".old")
  os.rename(tmp, final)
  old = final + ".old"
  if os.path.exists(old):
    import shutil
    shutil.rmtree(old)
  return final


class AsyncCheckpointer:
  """Snapshot-to-host synchronously, write on a daemon thread."""

  def __init__(self):
    self._thread: Optional[threading.Thread] = None

  def wait(self):
    if self._thread is not None:
      self._thread.join()
      self._thread = None

  def save_async(self, ckpt_dir: str, step: int, tree: Any,
                 extras: Optional[dict] = None):
    self.wait()
    host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
    self._thread = threading.Thread(
        target=save, args=(ckpt_dir, step, host_tree, extras), daemon=True)
    self._thread.start()


def latest_step(ckpt_dir: str) -> Optional[int]:
  if not os.path.isdir(ckpt_dir):
    return None
  steps = [int(m.group(1)) for d in os.listdir(ckpt_dir)
           if (m := re.fullmatch(r"step_(\d+)", d))]
  return max(steps) if steps else None


def restore(ckpt_dir: str, step: Optional[int] = None,
            shardings: Optional[Any] = None):
  """Load a checkpoint; optionally re-shard each leaf onto ``shardings``
  (same tree structure).  Returns (tree, step, extras)."""
  if step is None:
    step = latest_step(ckpt_dir)
    if step is None:
      raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
  d = os.path.join(ckpt_dir, f"step_{step:08d}")
  with open(os.path.join(d, "manifest.json")) as f:
    manifest = json.load(f)
  flat = {}
  for path, meta in manifest["leaves"].items():
    arr = np.load(os.path.join(d, meta["file"]))
    flat[path] = arr
  tree = _unflatten(flat)
  if shardings is not None:
    tree = jax.tree.map(
        lambda a, s: jax.device_put(a, s), tree, shardings)
  return tree, step, manifest.get("extras", {})
