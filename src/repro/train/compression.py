"""Error-feedback int8 gradient compression for the cross-pod all-reduce.

Within a pod, gradients reduce over the fast `data` axis uncompressed
(GSPMD).  *Across pods* the ICI/DCN link is the scarce resource, so the
pod-axis reduction is done manually under ``shard_map`` with per-leaf int8
quantisation + local error feedback (the residual is re-added next step),
cutting cross-pod gradient bytes 4x with no bias in expectation.

This is the "gradient compression / distributed-optimization trick"
integration point; it composes with any optimizer because it happens
before ``adamw_update``.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def _quantise(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
  scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
  q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
  return q, scale


def compressed_pod_psum(grads, err, axis_name: str = "pod"):
  """Per-leaf: q = int8(g + err); AR(q); err' = (g + err) - deq(q).

  Must run inside shard_map with ``axis_name`` manual.  Returns
  (reduced_grads, new_err).  Gradient bytes on the wire: 1 byte/param
  (+ one f32 scale per leaf) instead of 4.
  """
  def one(g, e):
    g32 = g.astype(jnp.float32) + e
    q, scale = _quantise(g32)
    deq = q.astype(jnp.float32) * scale
    new_e = g32 - deq
    # The wire transfer is the *int8* all-gather (1 byte/param/pod) plus a
    # scalar scale; dequantise-and-sum happens locally, so cross-pod bytes
    # drop 4x vs an f32 all-reduce.  (Scales differ per pod, so a plain
    # int8 psum would be invalid.)
    q_all = jax.lax.all_gather(q, axis_name)            # (npods, ...)
    s_all = jax.lax.all_gather(scale, axis_name)        # (npods,)
    summed = jnp.tensordot(s_all, q_all.astype(jnp.float32), axes=(0, 0))
    return summed, new_e

  pairs = jax.tree.map(one, grads, err)
  is2 = lambda x: isinstance(x, tuple) and len(x) == 2
  return (jax.tree.map(lambda t: t[0], pairs, is_leaf=is2),
          jax.tree.map(lambda t: t[1], pairs, is_leaf=is2))


def local_quantise_feedback(grads, err):
  """Quantise-dequantise + error feedback WITHOUT the manual collective —
  the numerical behaviour of :func:`compressed_pod_psum` when the runtime
  cannot lower partial-manual shard_map (GSPMD then carries the already
  -reduced gradients; the wire stays f32 but optimizer numerics match)."""
  def one(g, e):
    g32 = g.astype(jnp.float32) + e
    q, scale = _quantise(g32)
    deq = q.astype(jnp.float32) * scale
    return deq, g32 - deq

  pairs = jax.tree.map(one, grads, err)
  is2 = lambda x: isinstance(x, tuple) and len(x) == 2
  return (jax.tree.map(lambda t: t[0], pairs, is_leaf=is2),
          jax.tree.map(lambda t: t[1], pairs, is_leaf=is2))


def init_error_feedback(params) -> Any:
  return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
