"""Deterministic, checkpointable data pipeline.

Synthetic-but-structured token streams (Zipf-distributed n-gram chains so
the loss actually decreases) generated on the fly from a PRNG whose state
is just (seed, step) — restoring a checkpoint resumes the stream exactly.
A byte-level corpus reader is provided for real-text runs.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class DataConfig:
  vocab: int
  seq_len: int
  global_batch: int
  seed: int = 0
  corpus_path: Optional[str] = None   # byte-level real text if set


class TokenStream:
  """Stateless-per-step pipeline: batch(step) is a pure function."""

  def __init__(self, cfg: DataConfig):
    self.cfg = cfg
    self.step = 0
    self._corpus = None
    if cfg.corpus_path:
      with open(cfg.corpus_path, "rb") as f:
        self._corpus = np.frombuffer(f.read(), dtype=np.uint8)

  # -- checkpointable state ------------------------------------------------
  def state_dict(self) -> dict:
    return {"step": self.step, "seed": self.cfg.seed}

  def load_state_dict(self, d: dict) -> None:
    self.step = int(d["step"])

  # -- batches ---------------------------------------------------------------
  def batch_at(self, step: int) -> Tuple[np.ndarray, np.ndarray]:
    cfg = self.cfg
    rng = np.random.default_rng((cfg.seed << 20) + step)
    B, S = cfg.global_batch, cfg.seq_len
    if self._corpus is not None:
      starts = rng.integers(0, len(self._corpus) - S - 1, size=B)
      tok = np.stack([self._corpus[s:s + S + 1] for s in starts]).astype(
          np.int32) % cfg.vocab
    else:
      # Zipf unigrams chained with a deterministic bigram successor map so
      # that next-token prediction is learnable.
      base = rng.zipf(1.3, size=(B, S + 1)).astype(np.int64) % cfg.vocab
      succ = (base[:, :-1] * 2654435761 % cfg.vocab).astype(np.int64)
      mix = rng.random((B, S)) < 0.5
      tok = np.concatenate(
          [base[:, :1], np.where(mix, succ, base[:, 1:])], axis=1
      ).astype(np.int32)
    return tok[:, :-1], tok[:, 1:]

  def __iter__(self) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    while True:
      yield self.batch_at(self.step)
      self.step += 1
