"""AdamW + cosine schedule, built from scratch (no optax in this image).

Mixed precision: f32 master weights + Adam moments; the forward runs on a
bf16 cast.  ZeRO-1-style optimizer-state sharding falls out of the train
rule table (param "embed" dims shard over the `data` axis = FSDP; moments
inherit the same sharding).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
  lr: float = 3e-4
  b1: float = 0.9
  b2: float = 0.95
  eps: float = 1e-8
  weight_decay: float = 0.1
  warmup_steps: int = 100
  total_steps: int = 10000
  clip_norm: float = 1.0


def schedule(cfg: OptConfig, step: jax.Array) -> jax.Array:
  warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
  t = jnp.clip((step - cfg.warmup_steps)
               / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
  cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
  return cfg.lr * warm * (0.1 + 0.9 * cos)


def init_opt_state(params) -> Dict[str, Any]:
  zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
  return {
      "m": jax.tree.map(zeros, params),
      "v": jax.tree.map(zeros, params),
      "step": jnp.zeros((), jnp.int32),
  }


def global_norm(tree) -> jax.Array:
  return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                      for x in jax.tree.leaves(tree)))


def adamw_update(
    grads, opt_state, params, cfg: OptConfig,
) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
  """One AdamW step.  params/grads f32.  Returns (params', opt', metrics)."""
  step = opt_state["step"] + 1
  lr = schedule(cfg, step)

  gnorm = global_norm(grads)
  scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
  grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

  b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
  b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

  def upd(p, g, m, v):
    m = cfg.b1 * m + (1 - cfg.b1) * g
    v = cfg.b2 * v + (1 - cfg.b2) * g * g
    mhat = m / b1c
    vhat = v / b2c
    new_p = p - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                      + cfg.weight_decay * p)
    return new_p, m, v

  flat = jax.tree.map(upd, params, grads, opt_state["m"], opt_state["v"])
  # Unzip the 3-tuples.
  is3 = lambda x: isinstance(x, tuple) and len(x) == 3
  new_params = jax.tree.map(lambda t: t[0], flat, is_leaf=is3)
  new_m = jax.tree.map(lambda t: t[1], flat, is_leaf=is3)
  new_v = jax.tree.map(lambda t: t[2], flat, is_leaf=is3)
  new_opt = {"m": new_m, "v": new_v, "step": step}
  return new_params, new_opt, {"grad_norm": gnorm, "lr": lr}
