"""pjit train step: microbatched grad accumulation + AdamW (+ optional
error-feedback int8 cross-pod gradient reduction).

TrainState:
  params_f32  — master weights (FSDP-sharded via TRAIN_RULES)
  opt         — Adam moments + step (same sharding: ZeRO-1/3 hybrid)
  err         — compression error feedback (only when pod-compression on)

The step consumes a *global* batch (sharded over pod x data), splits it
into ``microbatches`` slices scanned sequentially (activation memory /
overlap knob), computes bf16 forward/backward with full remat, and
applies AdamW in f32.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.dist import sharding as shd
from repro.models import common as cm
from repro.models import transformer as tf
from repro.train import compression as comp
from repro.train import optimizer as opt_lib


def init_train_state(key, cfg: cm.ModelConfig, opt_cfg, *, compress=False):
  boxed = tf.init_model(key, cfg)
  params, axes = cm.split(boxed)
  params = jax.tree.map(lambda p: p.astype(jnp.float32), params)
  state = {"params": params, "opt": opt_lib.init_opt_state(params)}
  if compress:
    state["err"] = comp.init_error_feedback(params)
  state_axes = {
      "params": axes,
      "opt": {"m": axes, "v": axes, "step": ()},
  }
  if compress:
    state_axes["err"] = axes
  return state, state_axes


def make_train_step(cfg: cm.ModelConfig, opt_cfg: opt_lib.OptConfig, *,
                    microbatches: int = 1, compress_pods: bool = False,
                    mesh=None, causal_skip: bool = False, param_axes=None):
  """Returns train_step(state, batch) -> (state, metrics); pjit-ready.

  ``param_axes`` (the logical-axes tree) enables per-layer FSDP weight
  gathering inside the scanned blocks."""

  def grads_of(params_f32, batch):
    params_bf16 = jax.tree.map(lambda p: p.astype(cfg.dtype), params_f32)

    def loss_fn(p, mb):
      loss, metrics = tf.forward_loss(
          p, cfg, mb["tokens"], mb["labels"], mb.get("frontend_embeds"),
          causal_skip=causal_skip, param_axes=param_axes)
      return loss, metrics

    if microbatches == 1:
      (loss, metrics), grads = jax.value_and_grad(
          loss_fn, has_aux=True)(params_bf16, batch)
      return loss, metrics, grads

    def split_mb(x):
      B = x.shape[0]
      return x.reshape(microbatches, B // microbatches, *x.shape[1:])

    mbs = jax.tree.map(split_mb, batch)

    def acc_fn(carry, mb):
      gacc, lacc = carry
      (loss, metrics), g = jax.value_and_grad(
          loss_fn, has_aux=True)(params_bf16, mb)
      gacc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), gacc, g)
      return (gacc, lacc + loss), metrics

    g0 = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params_bf16)
    (grads, loss), metrics = jax.lax.scan(acc_fn, (g0, 0.0), mbs)
    grads = jax.tree.map(lambda g: g / microbatches, grads)
    metrics = jax.tree.map(lambda m: m[-1], metrics)
    return loss / microbatches, metrics, grads

  def train_step(state, batch):
    loss, metrics, grads = grads_of(state["params"], batch)

    if compress_pods and mesh is not None and "pod" in mesh.shape:
      if shd.supports_partial_manual():
        # Cross-pod reduction by hand (int8 + error feedback); within-pod
        # reductions stay in GSPMD.  shard_map manual only on 'pod'.
        def red(g, e):
          return comp.compressed_pod_psum(g, e, "pod")

        from jax.sharding import PartitionSpec as P  # noqa: PLC0415
        spec = jax.tree.map(lambda _: P(), grads)
        grads, new_err = shd.shard_map(
            red, mesh=mesh, in_specs=(spec, spec), out_specs=(spec, spec),
            check_vma=False, axis_names={"pod"},
        )(grads, state["err"])
        state = {**state, "err": new_err}
        grads = jax.tree.map(lambda g: g / mesh.shape["pod"], grads)
      else:
        # Legacy runtime: same quantisation numerics, GSPMD reduction.
        grads, new_err = comp.local_quantise_feedback(grads, state["err"])
        state = {**state, "err": new_err}

    new_params, new_opt, om = opt_lib.adamw_update(
        grads, state["opt"], state["params"], opt_cfg)
    new_state = {**state, "params": new_params, "opt": new_opt}
    out_metrics = {"loss": loss, **metrics, **om}
    return new_state, out_metrics

  return train_step


def state_shardings(state_axes, mesh, state_shapes):
  return shd.tree_shardings(state_axes, mesh, shd.TRAIN_RULES, state_shapes)
