"""Autoscaler decision-rule tests (DESIGN.md §14): monotone sizing in
load, hysteresis (a flat trace never flaps), and drain-before-retire
(a scale-down never drops an in-flight request)."""
import numpy as np
import pytest

from repro.control import Autoscaler, AutoscalerConfig, FleetSize, drain
from repro.serving.service import ScaledFleetExport
from repro.serving.workload import SOGOU_HOURLY, hour_rate


def _step_ms(n, r):
  """Synthetic but shaped like the measured model: step wall falls with
  the component count (shorter shards) and the straggler excess falls
  with the replica rows."""
  return (24.0 / n) * (1.0 + 0.6 / r)


def _cfg(**kw):
  kw.setdefault("p99_target_ms", 60.0)
  kw.setdefault("max_components", 6)
  kw.setdefault("max_replicas", 2)
  return AutoscalerConfig(**kw)


def test_bounds_validation():
  with pytest.raises(ValueError, match="component bounds"):
    Autoscaler(_cfg(min_components=0), _step_ms)
  with pytest.raises(ValueError, match="replica bounds"):
    Autoscaler(_cfg(min_replicas=3, max_replicas=2), _step_ms)


def test_p99_model_shape():
  asc = Autoscaler(_cfg(), _step_ms)
  s = FleetSize(2, 1)
  # Monotone increasing in rate; infinite at/over saturation.
  rates = [1.0, 5.0, 10.0, 15.0]
  p99s = [asc.p99_of(r, s) for r in rates]
  assert all(a < b for a, b in zip(p99s, p99s[1:]))
  service = 4.0 * _step_ms(2, 1)
  cap = asc.cfg.slots * 1000.0 / service
  assert asc.p99_of(cap, s) == float("inf")
  # More components and more replicas both strictly help under load.
  assert asc.p99_of(10.0, FleetSize(4, 1)) < asc.p99_of(10.0, FleetSize(2, 1))
  assert asc.p99_of(10.0, FleetSize(2, 2)) < asc.p99_of(10.0, FleetSize(2, 1))


def test_size_monotone_in_load():
  """The scan's component count (and the total device cost) never
  decreases as the offered rate grows — including over the real diurnal
  trace's sorted rates."""
  asc = Autoscaler(_cfg(), _step_ms)
  rates = sorted(set(list(np.linspace(0.5, 400.0, 120))
                     + [float(hour_rate(h)) for h in range(24)]))
  sizes = [asc.size_for(r) for r in rates]
  for a, b in zip(sizes, sizes[1:]):
    assert b.n_components >= a.n_components
    assert b.devices >= a.devices
  # Saturation falls back to the max grid, not an error.
  assert asc.size_for(1e9) == FleetSize(6, 2)
  assert all(asc.p99_of(r, s) <= 60.0 for r, s in zip(rates, sizes)
             if s != FleetSize(6, 2))


def test_flat_trace_never_flaps():
  """Hysteresis: on a constant-rate trace the size settles at the first
  decision and every later window holds it — zero up/down actions."""
  asc = Autoscaler(_cfg(), _step_ms)
  size = None
  for _ in range(50):
    size = asc.decide(30.0, size)
  actions = [e["action"] for e in asc.log]
  assert actions[0] == "init"
  assert set(actions[1:]) == {"hold"}


def test_scale_up_immediate_and_elementwise_max():
  asc = Autoscaler(_cfg(), _step_ms)
  # A grown replica dimension never silently shrinks the component one.
  up = asc.decide(5.0, FleetSize(5, 2))
  # rate 5 wants a small grid; 5x2 is already >= it, so hold, not shrink.
  assert up == FleetSize(5, 2)
  asc2 = Autoscaler(_cfg(), _step_ms)
  want = asc2.size_for(300.0)
  got = asc2.decide(300.0, FleetSize(1, 2))
  assert got.n_components == max(want.n_components, 1)
  assert got.replicas == max(want.replicas, 2)
  assert asc2.log[-1]["action"] == "up"


def test_shrink_requires_cooldown_and_headroom():
  """A single low window never retires capacity; ``cooldown_windows``
  consecutive windows clearing the target WITH headroom do."""
  asc = Autoscaler(_cfg(cooldown_windows=2, headroom=0.05), _step_ms)
  big = FleetSize(6, 2)
  # One dip: cooldown, hold the big grid.  (0.1/s: the small target size
  # clears the target with real slack, so only the cooldown gates it.)
  s1 = asc.decide(0.1, big)
  assert s1 == big and asc.log[-1]["action"] == "cooldown"
  # A spike resets the streak.
  s2 = asc.decide(300.0, s1)
  assert s2 == big
  s3 = asc.decide(0.1, s2)
  assert s3 == big and asc.log[-1]["action"] == "cooldown"
  # The second consecutive qualifying window shrinks.
  s4 = asc.decide(0.1, s3)
  assert s4.devices < big.devices and asc.log[-1]["action"] == "down"
  # Target met but WITHOUT the headroom margin: the streak never starts
  # (rate 2.0's first-feasible size sits just under the target).
  asc4 = Autoscaler(_cfg(cooldown_windows=1, headroom=0.05), _step_ms)
  tgt = asc4.size_for(2.0)
  assert 60.0 * (1.0 - 0.05) < asc4.p99_of(2.0, tgt) <= 60.0
  assert asc4.decide(2.0, big) == big
  assert asc4.log[-1]["action"] == "cooldown" and asc4._shrink_streak == 0
  # Without margin (target met but inside the headroom band) the streak
  # never qualifies: find a rate whose p99 at the small size sits
  # between (1-headroom)*target and target.
  asc5 = Autoscaler(_cfg(cooldown_windows=1, headroom=0.9), _step_ms)
  small = asc5.size_for(10.0)
  assert asc5.p99_of(10.0, small) > 60.0 * (1.0 - 0.9)
  held = asc5.decide(10.0, FleetSize(6, 2))
  assert held == FleetSize(6, 2) and asc5._shrink_streak == 0


def test_diurnal_trace_tracks_and_saves_cost():
  """Over the 24-hour sogou trace the autoscaled fleet meets the p99
  target wherever feasible and holds strictly fewer component-hours than
  static peak sizing — the shape benchmarks/fleet_bench.py measures."""
  asc = Autoscaler(_cfg(headroom=0.05), _step_ms)
  size = None
  cost_auto = 0
  static = FleetSize(6, 2)
  for h in range(24):
    rate = float(SOGOU_HOURLY[h])
    size = asc.decide(rate, size)
    cost_auto += size.devices
    assert asc.p99_of(rate, size) <= 60.0 or size == static
  assert cost_auto < 24 * static.devices


def test_scaled_fleet_export_model():
  class _Export:
    def step_ms_per_component(self, budget):
      return np.array([4.0, 2.0, 2.0, 2.0]) * (1.0 + 0.01 * budget)

  exp = ScaledFleetExport(_Export(), 4, replicas=1)
  # Same grid as measured: total work conserved, imbalance kept.
  v = exp.step_ms_per_component(8)
  assert v.shape == (4,)
  base = _Export().step_ms_per_component(8)
  assert float(v.max()) == pytest.approx(float(base.max()))
  # Counterfactuals: more components shrink the per-component wall;
  # more replicas shave exactly the imbalance excess.
  assert exp.step_model(8, 1) < exp.step_model(4, 1) < exp.step_model(2, 1)
  assert exp.step_model(4, 2) < exp.step_model(4, 1)
  bal = ScaledFleetExport(_Export(), 4, replicas=10 ** 6)
  mean = float(base.sum()) / 4
  assert bal.step_ms(8) == pytest.approx(mean, rel=1e-3)
  with pytest.raises(ValueError):
    ScaledFleetExport(_Export(), 0)
  with pytest.raises(ValueError):
    ScaledFleetExport(_Export(), 2, replicas=0)


def test_drain_before_retire_drops_nothing():
  """Scale-down protocol: drain steps the resident slots to completion
  without admitting, so every retirement lands with remaining == 0 and
  no request is marked dropped."""
  from repro.configs.registry import get_config
  from repro.serve.engine import (EngineConfig, ServingEngine,
                                  make_requests)
  from repro.serve.fleet import FleetConfig, FleetStepBackend
  cfg = get_config("llama3-8b", smoke=True)
  backend = FleetStepBackend(FleetConfig(
      n_components=2, replicas=2, seed=0, use_mesh=False))
  eng = ServingEngine(cfg, EngineConfig(
      n_slots=2, prompt_len=64, max_new_tokens=3, deadline_ms=1e6,
      policy="accuracytrader", impl="xla"), backend=backend)
  eng.reset()
  reqs = make_requests([0.0, 0.0], 64, 3, cfg.vocab, seed=4)
  eng._admit(reqs[0], 0)
  eng._admit(reqs[1], 1)
  retired = drain(eng)
  assert retired == 2
  assert all(s is None for s in eng.slots)
  assert len(eng.completed) == 2
  assert not any(r.dropped for r in eng.completed)
  # Ran to completion, not cut short: every decode step happened.
  assert all(len(r.budgets) == r.max_new_tokens for r in eng.completed)
  # Idempotent on an empty engine.
  assert drain(eng) == 0
