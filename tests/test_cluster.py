"""Multi-component scatter-gather serving tier (DESIGN.md §9): topology
partition laws, budget-allocation monotonicity in relevance mass, the
global-top-k merge equalling the single-component reference on a
concatenated corpus, the partial-gather stage-1 fallback, per-slot corpus
routing round-trips, and the cluster engine end to end (incl. the
measured per-component export feeding the simulator)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.dist.topology import ComponentTopology, zipf_weights
from repro.serve.cluster import (MODE_DROP, MODE_FULL, MODE_STAGE1,
                                 ClusterConfig, ClusterStepBackend,
                                 allocate_budget, make_cluster_attention)
from repro.serve.engine import EngineConfig, ServingEngine, run_open_loop
from repro.serving.latency import ComponentModel
from repro.serving.service import ScatterGatherService, ServiceConfig

B, Hkv, G, D, S, C = 2, 2, 2, 16, 256, 16
H, M = Hkv * G, S // C
SM = float(1.0 / np.sqrt(D))


# -- topology ----------------------------------------------------------------

def test_topology_partition_laws():
  for n, skew in [(1, 0.0), (4, 0.0), (4, 1.2), (7, 0.9), (16, 2.0)]:
    topo = ComponentTopology.plan(16, n, skew)
    assert sum(topo.counts) == 16
    assert all(c >= 1 for c in topo.counts)
    assert topo.m_max == max(topo.counts)
    assert len(topo.offsets) == n and topo.offsets[0] == 0
    owner = topo.cluster_owner()
    assert owner.shape == (16,)
    assert (np.diff(owner) >= 0).all()          # contiguous ranges
  # Zipf skew: rank-0 owns the most; uniform when skew == 0.
  skewed = ComponentTopology.plan(32, 4, 1.2)
  assert list(skewed.counts) == sorted(skewed.counts, reverse=True)
  assert skewed.counts[0] > skewed.counts[-1]
  assert set(ComponentTopology.plan(32, 4, 0.0).counts) == {8}
  w = zipf_weights(5, 1.0)
  assert w.sum() == pytest.approx(1.0) and (np.diff(w) < 0).all()
  with pytest.raises(ValueError):
    ComponentTopology.plan(4, 8)               # more components than corpus


def test_allocate_budget_monotone_in_mass():
  rng = np.random.default_rng(0)
  for _ in range(20):
    mass = jnp.asarray(rng.uniform(0.1, 10.0, (1, 1, 6)))
    caps = jnp.full((1, 1, 6), 8)
    out = np.asarray(allocate_budget(mass, 12, caps))[0, 0]
    m = np.asarray(mass)[0, 0]
    order = np.argsort(m)
    assert (np.diff(out[order]) >= 0).all(), (m, out)   # monotone in mass
    assert out.sum() <= 12 and (out <= 8).all() and (out >= 0).all()
  # Exactly proportional when it divides evenly.
  out = np.asarray(allocate_budget(
      jnp.asarray([[1.0, 2.0, 1.0]]), 8, jnp.full((1, 3), 8)))[0]
  assert list(out) == [2, 4, 2]
  # A budget covering the whole corpus saturates every cap, however
  # skewed the mass — the `basic` full gather must stay exact.
  out = np.asarray(allocate_budget(
      jnp.asarray([[10.0, 1.0]]), 8, jnp.asarray([[4, 4]])))[0]
  assert list(out) == [4, 4]


# -- attention parity --------------------------------------------------------

def _mk_inputs(seed=0):
  ks = jax.random.split(jax.random.PRNGKey(seed), 8)
  q = jax.random.normal(ks[0], (B, H, D), jnp.float32)
  cache = {
      "k": jax.random.normal(ks[1], (B, Hkv, S, D), jnp.float32),
      "v": jax.random.normal(ks[2], (B, Hkv, S, D), jnp.float32),
      "recent_k": jax.random.normal(ks[3], (B, Hkv, 16, D), jnp.float32),
      "recent_v": jax.random.normal(ks[4], (B, Hkv, 16, D), jnp.float32),
      "recent_len": jnp.full((B,), 5, jnp.int32),
      "counts": jnp.full((B, M), float(C)),
  }
  cache["k_syn"] = cache["k"].reshape(B, Hkv, M, C, D).mean(3)
  cache["v_syn"] = cache["v"].reshape(B, Hkv, M, C, D).mean(3)
  self_kv = jax.random.normal(ks[5], (B, Hkv, 1, D), jnp.float32)
  return q, cache, (self_kv, self_kv)


def _scatter(cache, topo):
  """Reference host-side scatter of a (B, Hkv, S, D) corpus slice into the
  padded per-component layout the tier uses."""
  Mp = topo.m_max
  out = {k: cache[k] for k in ("recent_k", "recent_v", "recent_len")}
  for name, unit in (("k", C), ("v", C), ("k_syn", 1), ("v_syn", 1)):
    parts = []
    for c in range(topo.n_components):
      off, cnt = topo.offsets[c] * unit, topo.counts[c] * unit
      sl = cache[name][:, :, off:off + cnt]
      if Mp * unit - cnt:
        sl = jnp.pad(sl, [(0, 0), (0, 0), (0, Mp * unit - cnt), (0, 0)])
      parts.append(sl)
    out[name] = jnp.stack(parts, axis=2)
  parts = []
  for c in range(topo.n_components):
    sl = cache["counts"][:, topo.offsets[c]:topo.offsets[c] + topo.counts[c]]
    if Mp - topo.counts[c]:
      sl = jnp.pad(sl, [(0, 0), (0, Mp - topo.counts[c])])
    parts.append(sl)
  out["counts"] = jnp.stack(parts, axis=1)
  return out


@pytest.mark.parametrize("impl", ["xla", "interpret"])
@pytest.mark.parametrize("n,skew", [(2, 0.0), (4, 0.0), (4, 1.2)])
def test_global_topk_merge_equals_single_component(impl, n, skew):
  """alloc="topk" with every component gathered must reproduce the
  single-component reference on the concatenated corpus: the two-level
  top-k selects the same global clusters and the per-component partial
  merges compose to the same online softmax (<= 1e-5 f32)."""
  from repro.serve.serve_step import synopsis_decode_attention
  q, cache, self_kv = _mk_inputs()
  ref = synopsis_decode_attention(q, cache, i_max=4, cluster_size=C,
                                  sm_scale=SM, self_kv=self_kv, impl="xla")
  topo = ComponentTopology.plan(M, n, skew)
  csl = _scatter(cache, topo)
  csl["fe_mode"] = jnp.full((n,), MODE_FULL, jnp.int32)
  attn = make_cluster_attention(topo, alloc="topk", mesh=None)
  got, aux = attn(q, csl, i_max=4, cluster_size=C, sm_scale=SM,
                  self_kv=self_kv, impl=impl)
  np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-5)
  # The global top-4 is fully covered across components.
  assert float(np.asarray(aux["fe_cover"]).sum()) == pytest.approx(4.0)
  assert np.asarray(aux["fe_mass"]).sum() == pytest.approx(1.0, abs=1e-5)


def test_partial_gather_stage1_floor_for_skipped():
  """A component marked STAGE1 contributes exactly its synopsis partial
  (manual composition check); a DROPped component contributes nothing."""
  q, cache, self_kv = _mk_inputs(seed=3)
  n = 4
  topo = ComponentTopology.plan(M, n, 0.0)
  csl = _scatter(cache, topo)
  attn = make_cluster_attention(topo, alloc="topk", mesh=None)

  def run(mode):
    c = dict(csl)
    c["fe_mode"] = jnp.asarray(mode, jnp.int32)
    out, _ = attn(q, c, i_max=4, cluster_size=C, sm_scale=SM,
                  self_kv=self_kv, impl="xla")
    return out

  # Skipping component 1's refinement really changes the result (its
  # stage-1 partial stands in for the refined clusters it owned).
  mode = np.full((n,), MODE_FULL)
  mode[1] = MODE_STAGE1
  got = run(mode)
  full = run(np.full((n,), MODE_FULL))
  assert float(jnp.abs(got - full).max()) > 1e-6   # refinement really lost
  # Budget 0 on every component == stage-1-only on every component.
  got_b0 = run(np.full((n,), MODE_STAGE1))
  c0 = dict(csl)
  c0["fe_mode"] = jnp.full((n,), MODE_FULL, jnp.int32)
  out0, _ = attn(q, c0, i_max=0, cluster_size=C, sm_scale=SM,
                 self_kv=self_kv, impl="xla")
  np.testing.assert_allclose(np.asarray(got_b0), np.asarray(out0),
                             atol=1e-5)
  # DROP removes a component's contribution entirely: dropping ALL
  # components leaves exactly the frontend-owned extras — exact attention
  # over the valid recent-ring tokens + the new token's self-KV.
  from repro.kernels import ref as kref
  got_d = run(np.full((n,), MODE_DROP))
  rl = int(cache["recent_len"][0])
  ke = jnp.concatenate([cache["recent_k"][:, :, :rl], self_kv[0]], axis=2)
  ve = jnp.concatenate([cache["recent_v"][:, :, :rl], self_kv[1]], axis=2)
  ref_d, _, _ = kref.flash_decode_ref(q, ke, ve, sm_scale=SM)
  np.testing.assert_allclose(np.asarray(got_d), np.asarray(ref_d),
                             atol=1e-5)


# -- engine integration ------------------------------------------------------

@pytest.fixture(scope="module")
def cluster_engine():
  cfg = get_config("llama3-8b", smoke=True)
  backend = ClusterStepBackend(ClusterConfig(
      n_components=2, seed=0, use_mesh=False))
  eng = ServingEngine(cfg, EngineConfig(
      n_slots=2, prompt_len=64, max_new_tokens=3, deadline_ms=60.0,
      policy="accuracytrader", impl="xla"), backend=backend)
  return eng, backend


def test_cluster_engine_end_to_end(cluster_engine):
  eng, backend = cluster_engine
  s = run_open_loop(eng, rate_per_s=30.0, duration_s=0.4, seed=5)
  assert s["n"] > 0 and s["n"] == len(eng.completed)
  for r in eng.completed:
    assert len(r.step_acc) == len(r.budgets)
    assert all(0.0 <= a <= 1.0 for a in r.step_acc)
    assert 0.0 <= r.accuracy <= 1.0
  assert backend.predictor.table()              # calibrated something
  assert all(v > 0 for v in backend.predictor.table().values())


def test_cluster_export_feeds_simulator(cluster_engine):
  eng, backend = cluster_engine
  if not backend.predictor.table():
    run_open_loop(eng, rate_per_s=30.0, duration_s=0.3, seed=5)
  exp = backend.export()
  vec = exp.step_ms_per_component(50)
  assert vec.shape == (2,) and (vec > 0).all()
  assert exp.step_ms(50) == pytest.approx(float(vec.max()))
  # More budget never means a smaller attributed parallel time.
  assert exp.step_ms(100) >= exp.step_ms(0) - 1e-9

  # ComponentModel indexes its own entry from a per-component vector.
  comp = ComponentModel(seed=0, comp_id=1, interference=0.0,
                        straggler_prob=0.0)
  done = comp.submit(10.0, 5, service_ms=np.asarray([3.0, 7.5]))
  assert done == pytest.approx(17.5)

  svc = ScatterGatherService(
      ServiceConfig(n_components=2, technique="accuracytrader",
                    deadline_ms=100.0, seed=0), step_backend=exp)
  s = svc.run_open_loop(20.0, 1.0)
  assert s["n"] > 0 and 0.0 <= s["accuracy_loss_pct"] <= 100.0


def test_cluster_partial_policy_sheds_components():
  """Under an impossible deadline the partial tier drops components (and
  requests), while accuracytrader's stage-1 floor keeps accuracy near
  the synopsis level — the Tables 1-2 ordering, in miniature."""
  cfg = get_config("llama3-8b", smoke=True)
  losses = {}
  for policy in ("partial", "accuracytrader"):
    backend = ClusterStepBackend(ClusterConfig(
        n_components=2, seed=0, use_mesh=False))
    eng = ServingEngine(cfg, EngineConfig(
        n_slots=1, prompt_len=64, max_new_tokens=3, deadline_ms=0.5,
        policy=policy, impl="xla"), backend=backend)
    s = run_open_loop(eng, rate_per_s=30.0, duration_s=0.3, seed=5)
    losses[policy] = s["accuracy_loss_pct"]
  assert losses["partial"] > losses["accuracytrader"]
  floor = 100.0 * (1.0 - 0.93)
  assert losses["accuracytrader"] <= floor + 1.0


def test_scatter_route_roundtrip():
  """The backend's jitted scatter+write routes every cluster of a slot's
  corpus to exactly one component (counts conserved), for both fixed and
  rotated routing."""
  cfg = get_config("llama3-8b", smoke=True)
  for route in ("fixed", "rotate"):
    backend = ClusterStepBackend(ClusterConfig(
        n_components=2, skew=1.2, route=route, use_mesh=False))
    eng = ServingEngine(cfg, EngineConfig(
        n_slots=2, prompt_len=64, max_new_tokens=2, policy="fixed",
        fixed_budget=1, impl="xla"), backend=backend)
    eng.reset()
    from repro.serve.engine import make_requests
    reqs = make_requests([0.0, 0.0], 64, 2, cfg.vocab, seed=9)
    eng._admit(reqs[0], 0)
    eng._admit(reqs[1], 1)
    counts = np.asarray(eng.cache["counts"])    # (nb, na, B, N, Mp)
    for slot in range(2):
      # Token conservation: M clusters of C tokens each, routed once.
      assert counts[0, 0, slot].sum() == eng.M * cfg.synopsis.cluster_size
      assert (counts[0, 0, slot] > 0).sum() == eng.M
    if route == "rotate":
      # Slot 1's ownership is slot 0's rolled by one component.
      c0 = (counts[0, 0, 0] > 0).sum(-1)
      c1 = (counts[0, 0, 1] > 0).sum(-1)
      assert list(np.roll(c0, 1)) == list(c1)


def test_backend_rejects_bad_configs():
  cfg = get_config("llama3-8b", smoke=True)
  with pytest.raises(ValueError):
    ServingEngine(cfg, EngineConfig(n_slots=1, prompt_len=64,
                                    max_new_tokens=2, impl="xla"),
                  backend=ClusterStepBackend(ClusterConfig(
                      n_components=2, alloc="nope")))
  with pytest.raises(ValueError):
    # more components than the corpus has clusters (M = 64/16 = 4)
    ServingEngine(cfg, EngineConfig(n_slots=1, prompt_len=64,
                                    max_new_tokens=2, impl="xla"),
                  backend=ClusterStepBackend(ClusterConfig(
                      n_components=8, use_mesh=False)))


# -- corpus cache on the cluster tier ----------------------------------------

def test_cache_shared_arena_shards_identically():
  """A cache hit maps the shared arena into its slot lane through the
  same jitted scatter+write a private build uses: one corpus admitted to
  slot 0 (miss) and slot 1 (hit) yields bit-identical per-component
  lanes, and both match a cache-off engine's two private builds."""
  from repro.serve import kv_cache as kvc
  from repro.serve.engine import CacheConfig, make_requests
  cfg = get_config("llama3-8b", smoke=True)
  Cs = cfg.synopsis.cluster_size
  lanes = {}
  for cache_on in (True, False):
    backend = ClusterStepBackend(ClusterConfig(
        n_components=2, skew=1.2, seed=0, use_mesh=False))
    eng = ServingEngine(cfg, EngineConfig(
        n_slots=2, prompt_len=64, max_new_tokens=2, policy="fixed",
        fixed_budget=1, impl="xla",
        cache=CacheConfig(capacity=8, delta_unit=Cs) if cache_on
        else None), backend=backend)
    eng.reset()
    reqs = make_requests([0.0, 0.0], 64, 2, cfg.vocab, seed=9)
    reqs[1].prompt = reqs[0].prompt.copy()       # the same corpus twice
    eng._admit(reqs[0], 0)
    eng._admit(reqs[1], 1)
    if cache_on:
      st = eng.corpus_cache.stats()
      assert st["misses"] == 1 and st["hits"] == 1
      assert eng.prefills == 1                   # slot 1 skipped prefill
    lanes[cache_on] = {name: np.asarray(eng.cache[name])
                       for name in kvc.ARENA_LEAVES if name in eng.cache}
  for name in lanes[True]:
    # Within the cache-on engine: the hit-mapped lane == the built lane.
    np.testing.assert_array_equal(lanes[True][name][:, :, 0],
                                  lanes[True][name][:, :, 1], err_msg=name)
    # Across engines: the shared arena scatters exactly like a private
    # build (the cache stores pre-scatter canonical state).
    np.testing.assert_array_equal(lanes[True][name], lanes[False][name],
                                  err_msg=name)


def test_cache_with_crashed_component_recovery():
  """A shard whose state came from a shared cache arena rides the same
  recovery ladder as a private one: with a component crashed the whole
  window and a 100%-repeat trace, availability stays 100%, the dead
  shard falls back to stage-1, and the repeats still hit the cache."""
  from repro.serve.engine import CacheConfig
  from repro.serve.resilience import FaultSpec
  cfg = get_config("llama3-8b", smoke=True)
  backend = ClusterStepBackend(ClusterConfig(
      n_components=2, replicas=1, seed=0, use_mesh=False,
      faults=FaultSpec(crash=((0, 1),), seed=5)))
  eng = ServingEngine(cfg, EngineConfig(
      n_slots=2, prompt_len=64, max_new_tokens=2, deadline_ms=60.0,
      policy="accuracytrader", impl="xla",
      cache=CacheConfig(capacity=8,
                        delta_unit=cfg.synopsis.cluster_size)),
      backend=backend)
  s = run_open_loop(eng, rate_per_s=30.0, duration_s=0.4, seed=3,
                    zipf_corpora=1)
  assert s["n"] > 0
  assert s["availability_pct"] == 100.0
  assert s["cache_hits"] > 0 and s["cache_misses"] == 1.0
  assert backend.fault_stats["stage1_fallbacks"] > 0
  assert backend.fault_stats["dropped"] == 0


# -- shard_map execution (multi-device, subprocess) --------------------------

_SHARDED_PROG = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import numpy as np
import jax, jax.numpy as jnp
from repro.dist.topology import ComponentTopology, make_component_mesh
from repro.serve.cluster import make_cluster_attention, MODE_FULL, MODE_STAGE1

B, Hkv, G, D, S, C = 2, 2, 2, 16, 256, 16
H, M = Hkv * G, S // C
ks = jax.random.split(jax.random.PRNGKey(0), 8)
q = jax.random.normal(ks[0], (B, H, D), jnp.float32)
cache = {
    "k": jax.random.normal(ks[1], (B, Hkv, S, D), jnp.float32),
    "v": jax.random.normal(ks[2], (B, Hkv, S, D), jnp.float32),
    "recent_k": jax.random.normal(ks[3], (B, Hkv, 16, D), jnp.float32),
    "recent_v": jax.random.normal(ks[4], (B, Hkv, 16, D), jnp.float32),
    "recent_len": jnp.full((B,), 5, jnp.int32),
    "counts": jnp.full((B, M), float(C)),
}
cache["k_syn"] = cache["k"].reshape(B, Hkv, M, C, D).mean(3)
cache["v_syn"] = cache["v"].reshape(B, Hkv, M, C, D).mean(3)
kd = jax.random.normal(ks[5], (B, Hkv, 1, D), jnp.float32)
sm = float(1.0 / np.sqrt(D))

def scatter(cache, topo):
    Mp = topo.m_max
    out = {k: cache[k] for k in ("recent_k", "recent_v", "recent_len")}
    for name, unit in (("k", C), ("v", C), ("k_syn", 1), ("v_syn", 1)):
        parts = []
        for c in range(topo.n_components):
            off, cnt = topo.offsets[c] * unit, topo.counts[c] * unit
            sl = cache[name][:, :, off:off + cnt]
            if Mp * unit - cnt:
                sl = jnp.pad(sl, [(0, 0), (0, 0), (0, Mp * unit - cnt),
                                  (0, 0)])
            parts.append(sl)
        out[name] = jnp.stack(parts, axis=2)
    parts = []
    for c in range(topo.n_components):
        sl = cache["counts"][:, topo.offsets[c]:topo.offsets[c]
                             + topo.counts[c]]
        if Mp - topo.counts[c]:
            sl = jnp.pad(sl, [(0, 0), (0, Mp - topo.counts[c])])
        parts.append(sl)
    out["counts"] = jnp.stack(parts, axis=1)
    return out

res = {}
for name, n, skew, alloc in [("u_topk", 8, 0.0, "topk"),
                             ("z_mass", 8, 1.1, "mass")]:
    topo = ComponentTopology.plan(M, n, skew)
    mesh = make_component_mesh(n)
    assert mesh is not None
    csl = scatter(cache, topo)
    mode = np.full((n,), MODE_FULL); mode[1] = MODE_STAGE1
    csl["fe_mode"] = jnp.asarray(mode, jnp.int32)
    sharded = make_cluster_attention(topo, alloc=alloc, mesh=mesh)
    stacked = make_cluster_attention(topo, alloc=alloc, mesh=None)
    got = jax.jit(lambda q, c, s: sharded(
        q, c, i_max=4, cluster_size=C, sm_scale=sm, self_kv=s,
        impl="xla")[0])(q, csl, (kd, kd))
    want, _ = stacked(q, csl, i_max=4, cluster_size=C, sm_scale=sm,
                      self_kv=(kd, kd), impl="xla")
    res[name] = float(np.abs(np.asarray(got) - np.asarray(want)).max())
print("RESULT:" + json.dumps(res))
"""


@pytest.mark.slow
@pytest.mark.subprocess
def test_sharded_cluster_equals_stacked():
  """The shard_map execution over 8 placeholder devices (one per
  component) must equal the stacked single-device execution — incl. a
  skewed partition with padded shards and a partial-gather mode vector."""
  import json
  import os
  import subprocess
  import sys
  env = dict(os.environ)
  env["PYTHONPATH"] = "src"
  p = subprocess.run([sys.executable, "-c", _SHARDED_PROG],
                     capture_output=True, text=True, env=env, timeout=600,
                     cwd=os.path.dirname(os.path.dirname(__file__)))
  assert p.returncode == 0, p.stderr[-3000:]
  line = [l for l in p.stdout.splitlines() if l.startswith("RESULT:")][0]
  res = json.loads(line[len("RESULT:"):])
  for k, err in res.items():
    assert err < 1e-5, (k, res)


_CACHE_SHARDED_PROG = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import numpy as np
from repro.configs.registry import get_config
from repro.serve import kv_cache as kvc
from repro.serve.cluster import ClusterConfig, ClusterStepBackend
from repro.serve.engine import (CacheConfig, EngineConfig, ServingEngine,
                                make_requests)

cfg = get_config("llama3-8b", smoke=True)
Cs = cfg.synopsis.cluster_size
res = {}
for name, mesh in (("mesh", True), ("stacked", False)):
    backend = ClusterStepBackend(ClusterConfig(
        n_components=8, seed=0, use_mesh=mesh))
    eng = ServingEngine(cfg, EngineConfig(
        n_slots=2, prompt_len=128, max_new_tokens=2, policy="fixed",
        fixed_budget=1, impl="xla",
        cache=CacheConfig(capacity=8, delta_unit=Cs)), backend=backend)
    eng.reset()
    reqs = make_requests([0.0, 0.0], 128, 2, cfg.vocab, seed=9)
    reqs[1].prompt = reqs[0].prompt.copy()
    eng._admit(reqs[0], 0)     # miss: private build, scattered to 8 shards
    eng._admit(reqs[1], 1)     # hit: shared arena, same scatter+write
    st = eng.corpus_cache.stats()
    assert st["misses"] == 1 and st["hits"] == 1, st
    res[name] = max(
        float(np.abs(np.asarray(eng.cache[l]).astype(np.float32)[:, :, 0]
                     - np.asarray(eng.cache[l]).astype(np.float32)[:, :, 1]
                     ).max())
        for l in kvc.ARENA_LEAVES if l in eng.cache)
print("RESULT:" + json.dumps(res))
"""


@pytest.mark.slow
@pytest.mark.subprocess
def test_cache_shared_arena_shards_identically_sharded():
  """The shard_map (8 placeholder devices) and stacked executions both
  write a cache-hit's shared arena bit-identically to the private build
  it deduplicates — the slot-1 lane equals the slot-0 lane exactly."""
  import json
  import os
  import subprocess
  import sys
  env = dict(os.environ)
  env["PYTHONPATH"] = "src"
  p = subprocess.run([sys.executable, "-c", _CACHE_SHARDED_PROG],
                     capture_output=True, text=True, env=env, timeout=600,
                     cwd=os.path.dirname(os.path.dirname(__file__)))
  assert p.returncode == 0, p.stderr[-3000:]
  line = [l for l in p.stdout.splitlines() if l.startswith("RESULT:")][0]
  res = json.loads(line[len("RESULT:"):])
  for k, err in res.items():
    assert err == 0.0, (k, res)
