"""The latency-control plane (DESIGN.md §10): predictor laws (quantile
monotone in its percentile and bracketed by its window; the high-quantile
prediction brackets the EWMA on heavy-tailed samples), budget-allocation
recirculation (conserves the total, never exceeds caps, dominates
cap-and-drop), DeadlineBudgetPolicy dispatch + hedged gather modes,
replica topology laws, the cluster backend's hedged accounting and
draw-determinism, and engine xla-vs-interpret token parity through the
refactored policy path."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.control import (MODE_DROP, MODE_FULL, MODE_STAGE1,
                           AffinePredictor, BudgetController,
                           DeadlineBudgetPolicy, EwmaPredictor,
                           QuantilePredictor, allocate_budget,
                           make_predictor)
from repro.dist.topology import ComponentTopology

# -- predictors --------------------------------------------------------------


def test_make_predictor_specs():
  assert isinstance(make_predictor("affine"), AffinePredictor)
  assert isinstance(make_predictor("ewma"), EwmaPredictor)
  q = make_predictor("quantile:95")
  assert isinstance(q, QuantilePredictor) and q.pct == 95.0
  assert make_predictor("quantile").pct == 90.0
  with pytest.raises(ValueError):
    make_predictor("nope")
  with pytest.raises(ValueError):
    make_predictor("ewma:0.5")      # only quantile takes a :arg
  with pytest.raises(ValueError):
    make_predictor("affine:95")
  with pytest.raises(ValueError):
    QuantilePredictor(pct=120.0)


def test_predictor_fallbacks_and_tables():
  for p in (EwmaPredictor(prior_ms=7.0), QuantilePredictor(prior_ms=7.0)):
    assert p.predict(4) == 7.0            # prior before any observation
    p.observe(4, 10.0)
    assert p.predict(4) == 10.0
    assert p.predict(64) == 10.0          # nearest-bucket fallback
    assert set(p.table()) == {4}
  e = EwmaPredictor(beta=0.3)
  e.observe(2, 10.0)
  e.observe(2, 20.0)
  assert e.predict(2) == pytest.approx(0.7 * 10.0 + 0.3 * 20.0)
  a = AffinePredictor(base=2.0, slope=0.5)
  a.observe(3, 3.5)
  assert set(a.table()) == {3}
  assert a.predict(0) == pytest.approx(a.table()[3] - 3 * a.slope)


def test_quantile_monotone_in_window():
  """The quantile prediction is monotone in the targeted percentile and
  bracketed by the window's min/max; a sliding window forgets."""
  rng = np.random.default_rng(0)
  q = QuantilePredictor(pct=90.0, window=32)
  xs = rng.lognormal(1.0, 1.0, 200)
  for x in xs:
    q.observe(1, float(x))
  win = xs[-32:]
  preds = [q.predict(1, pct=p) for p in np.linspace(0, 100, 21)]
  assert all(b >= a for a, b in zip(preds, preds[1:]))    # monotone in pct
  assert preds[0] == pytest.approx(win.min())
  assert preds[-1] == pytest.approx(win.max())
  assert win.min() <= q.predict(1) <= win.max()
  # Sliding window: flooding with a new level moves the estimate there.
  for _ in range(32):
    q.observe(1, 100.0)
  assert q.predict(1) == pytest.approx(100.0)


def test_quantile_brackets_ewma_on_heavy_tails():
  """On heavy-tailed samples the p90 quantile predictor sits above the
  EWMA and the p10 below — the bracket that makes percentile-targeted
  deadlines conservative exactly when step times straggle."""
  rng = np.random.default_rng(1)
  hi = QuantilePredictor(pct=90.0, window=256)
  lo = QuantilePredictor(pct=10.0, window=256)
  mid = EwmaPredictor(beta=0.1)
  for _ in range(256):
    x = float(rng.lognormal(0.0, 1.5))        # heavy tail
    for p in (hi, lo, mid):
      p.observe(2, x)
  assert lo.predict(2) < mid.predict(2) < hi.predict(2)


# -- allocation + recirculation ---------------------------------------------


def test_recirculation_conserves_and_respects_caps():
  rng = np.random.default_rng(2)
  for it in range(60):
    n = int(rng.integers(2, 9))
    caps = rng.integers(1, 9, (1, n))
    total = int(rng.integers(0, caps.sum() + 4))
    mass = rng.uniform(0.0, 10.0, (1, n))
    mass[0, rng.integers(0, n)] *= 10.0       # concentrate -> caps bind
    if it % 3 == 1:
      # Zero-mass components (f32 exp underflow on far-from-max scores)
      # must still absorb recirculated residue — the capacity round.
      mass[0, : rng.integers(1, n)] = 0.0
    if it % 7 == 0:
      mass[0, :] = 0.0                        # fully degenerate
    out = np.asarray(allocate_budget(
        jnp.asarray(mass), total, jnp.asarray(caps)))[0]
    legacy = np.asarray(allocate_budget(
        jnp.asarray(mass), total, jnp.asarray(caps),
        recirculate=False))[0]
    assert (out >= 0).all() and (out <= caps[0]).all()
    # Conservation: recirculation spends the whole budget (up to capsum).
    assert out.sum() == min(total, caps.sum()), (mass, caps, total, out)
    # Dominance: never allocates less anywhere-summed than cap-and-drop.
    assert out.sum() >= legacy.sum()
    assert (legacy <= caps[0]).all() and legacy.sum() <= total
  # The exact zero-mass non-conservation case the N-round loop got wrong.
  out = np.asarray(allocate_budget(
      jnp.asarray([[5.0, 0.0]]), 6, jnp.asarray([[1, 10]])))[0]
  assert list(out) == [1, 5]


def test_recirculation_monotone_in_mass():
  rng = np.random.default_rng(3)
  for _ in range(20):
    mass = rng.uniform(0.1, 10.0, (1, 6))
    caps = np.full((1, 6), 4)
    out = np.asarray(allocate_budget(
        jnp.asarray(mass), 12, jnp.asarray(caps)))[0]
    order = np.argsort(mass[0])
    assert (np.diff(out[order]) >= 0).all(), (mass, out)


def test_recirculation_routes_stranded_budget():
  # Hot component's cap binds at 2; the 5 clusters the legacy allocator
  # strands land on the unsaturated components, ∝ mass.
  mass = jnp.asarray([[10.0, 1.0, 1.0]])
  caps = jnp.asarray([[2, 8, 8]])
  out = np.asarray(allocate_budget(mass, 9, caps))[0]
  legacy = np.asarray(allocate_budget(mass, 9, caps,
                                      recirculate=False))[0]
  assert list(legacy) == [2, 1, 1]            # cap-and-drop strands 5
  assert out.sum() == 9 and out[0] == 2 and (out[1:] <= 8).all()


def test_allocate_budget_all_saturated_and_faulted():
  """Degenerate cap sets from mode-aware fault gating (DESIGN.md §11):
  all caps zero (every component STAGE1/DROP), the zero-cap subset
  holding ALL the mass, budgets at and above capsum — the three fixed
  recirculation rounds must terminate and conserve
  ``sum(alloc) == min(total, sum(caps))``."""
  cases = [
      ([[1.0, 2.0, 3.0]], 7, [[0, 0, 0]]),     # all faulted
      ([[0.0, 0.0, 0.0]], 7, [[0, 0, 0]]),     # all faulted, zero mass
      ([[10.0, 5.0, 0.0, 0.0]], 6, [[0, 0, 4, 4]]),  # mass on dead comps
      ([[1.0, 1.0, 1.0]], 100, [[2, 3, 4]]),   # total > capsum pins caps
      ([[0.5, 0.5]], 5, [[2, 3]]),             # exact saturation
  ]
  for mass, total, caps in cases:
    for recirc in (True, False):
      out = np.asarray(allocate_budget(
          jnp.asarray(mass), total, jnp.asarray(caps),
          recirculate=recirc))[0]
      assert (out >= 0).all() and (out <= np.asarray(caps)[0]).all()
      if recirc:
        assert out.sum() == min(total, int(np.sum(caps))), \
            (mass, total, caps, out)
  rng = np.random.default_rng(5)
  for _ in range(40):
    n = int(rng.integers(2, 9))
    caps = rng.integers(0, 5, (1, n))
    caps[0, rng.random(n) < 0.5] = 0           # heavy fault gating
    total = int(rng.integers(0, caps.sum() + 6))
    mass = rng.uniform(0.0, 10.0, (1, n))
    out = np.asarray(allocate_budget(jnp.asarray(mass), total,
                                     jnp.asarray(caps)))[0]
    assert (out <= caps[0]).all()
    assert out.sum() == min(total, caps.sum())


# -- policy ------------------------------------------------------------------


def test_bucketed_predictor_cold_start_ramps():
  """A cold EWMA/quantile controller must not trust the nearest-bucket
  fallback for untried budgets (it makes the biggest bucket look as
  cheap as the smallest): budgets ramp one bucket past the largest
  tried, however loose the deadline.  The affine model extrapolates
  soundly and is exempt."""
  buckets = (0, 2, 4, 8)
  for pred in (EwmaPredictor(), QuantilePredictor()):
    ctrl = BudgetController(pred, buckets=buckets, i_max_cap=8)
    seq = []
    for _ in range(5):
      b = ctrl.budget_for(1e9)
      seq.append(b)
      ctrl.observe(b, 1.0)
    assert seq == [0, 2, 4, 8, 8], seq
  aff = BudgetController(AffinePredictor(base=1.0, slope=0.1),
                         buckets=buckets, i_max_cap=8)
  assert aff.budget_for(1e9) == 8        # extrapolating model: no ramp


def test_budget_controller_generic_over_predictors():
  buckets = (0, 1, 2, 4, 8, 16, 32)
  for pred in (AffinePredictor(base=2.0, slope=1.0),
               EwmaPredictor(), QuantilePredictor(pct=90.0)):
    ctrl = BudgetController(pred, buckets=buckets, i_max_cap=32)
    for b, lat in [(0, 2.0), (2, 4.0), (4, 6.0), (8, 10.0), (16, 18.0)]:
      ctrl.observe(b, lat)
    budgets = [ctrl.budget_for(d) for d in np.linspace(0.0, 40.0, 100)]
    assert budgets == sorted(budgets)         # monotone in deadline
    assert budgets[0] == buckets[0]
    assert budgets[-1] >= 16


def test_policy_dispatch_and_validation():
  mk = lambda p: DeadlineBudgetPolicy(
      policy=p, buckets=(0, 2, 4, 8), i_max_cap=8,
      predictor=AffinePredictor(base=1.0, slope=1.0), fixed_budget=2)
  assert mk("basic").budget_for(0.0) == 8
  assert mk("partial").budget_for(1e9) == 8
  assert mk("fixed").budget_for(0.0) == 2
  at = mk("accuracytrader")
  assert at.budget_for(100.0) == 8 and at.budget_for(0.0) == 0
  assert at.budget_for(100.0, queue_delay=98.0) <= 2
  with pytest.raises(ValueError):
    mk("nope")


def test_gather_modes_hedging():
  t_pred = np.array([1.0, 50.0, 50.0, 2.0])
  t_hedge = np.array([1.0, 3.0, 60.0, 2.0])
  at = DeadlineBudgetPolicy(policy="accuracytrader", buckets=(0, 4),
                            i_max_cap=4)
  # No replicas: stragglers fall back to stage 1.
  mode, hedged = at.gather_modes(t_pred, 10.0)
  assert list(mode) == [MODE_FULL, MODE_STAGE1, MODE_STAGE1, MODE_FULL]
  assert not hedged.any()
  # Hedged: component 1's replica makes the deadline -> FULL; component
  # 2 misses on both paths -> stage 1 still stands in.
  mode, hedged = at.gather_modes(t_pred, 10.0, t_hedge)
  assert list(mode) == [MODE_FULL, MODE_FULL, MODE_STAGE1, MODE_FULL]
  assert list(hedged) == [False, True, True, False]
  pe = DeadlineBudgetPolicy(policy="partial", buckets=(0, 4), i_max_cap=4)
  mode, _ = pe.gather_modes(t_pred, 10.0, t_hedge)
  assert list(mode) == [MODE_FULL, MODE_FULL, MODE_DROP, MODE_FULL]
  # basic: always a full gather, but the hedge mask still prices reissues.
  ba = DeadlineBudgetPolicy(policy="basic", buckets=(0, 4), i_max_cap=4)
  mode, hedged = ba.gather_modes(t_pred, 10.0, t_hedge)
  assert list(mode) == [MODE_FULL] * 4 and hedged.sum() == 2


# -- replica topology --------------------------------------------------------


def test_topology_replica_laws():
  topo = ComponentTopology.plan(16, 4, skew=0.7, replicas=2)
  assert topo.replicas == 2
  owners = topo.replica_owners()
  assert owners.shape == (4, 2)
  assert (owners[:, 0] == np.arange(4)).all()       # col 0 = primary
  assert (owners[:, 1] == (np.arange(4) + 1) % 4).all()
  assert topo.replica_owner(3, 1) == 0              # ring wraps
  for c in range(4):
    assert topo.replica_owner(c, 1) != c            # never self-hedge
  with pytest.raises(ValueError):
    topo.replica_owner(0, 2)
  with pytest.raises(ValueError):
    ComponentTopology.plan(16, 4, replicas=5)


# -- cluster backend: hedged accounting + determinism ------------------------


@pytest.fixture(scope="module")
def hedged_engine():
  from repro.configs.registry import get_config
  from repro.serve.cluster import ClusterConfig, ClusterStepBackend
  from repro.serve.engine import EngineConfig, ServingEngine
  cfg = get_config("llama3-8b", smoke=True)
  backend = ClusterStepBackend(ClusterConfig(
      n_components=2, replicas=2, seed=0, use_mesh=False,
      interference=0.5, straggler_prob=0.0))
  eng = ServingEngine(cfg, EngineConfig(
      n_slots=1, prompt_len=64, max_new_tokens=2, deadline_ms=60.0,
      policy="accuracytrader", impl="xla"), backend=backend)
  return eng, backend


def test_plan_step_draws_are_deterministic(hedged_engine):
  _, backend = hedged_engine
  backend.reseed(7)
  p1 = backend.plan_step(2, 5.0)
  backend.reseed(7)
  p2 = backend.plan_step(2, 5.0)
  np.testing.assert_array_equal(p1.noise, p2.noise)
  np.testing.assert_array_equal(p1.noise2, p2.noise2)
  np.testing.assert_array_equal(p1.mode, p2.mode)
  np.testing.assert_array_equal(p1.hedged, p2.hedged)
  backend.reseed(8)
  p3 = backend.plan_step(2, 5.0)
  assert not np.array_equal(p1.noise, p3.noise)


def test_hedged_account_takes_earlier_completion(hedged_engine):
  """A hedged shard's completion is the min of the primary and the
  replica's (own + reissued work) path — never later than unhedged."""
  eng, backend = hedged_engine
  backend.reseed(3)
  # An impossible step deadline flags every component; with R=2 each is
  # hedged and (accuracytrader) falls back to stage-1 only if BOTH paths
  # are predicted to miss.
  plan = backend.plan_step(1, 1e-6)
  assert plan.hedged.all()
  st = {"fe_cover": np.ones((1, 1, 2)),
        "fe_mass": np.full((1, 1, 2), 0.5)}
  info = backend.account(1, 10.0, plan, st, warming=True)
  # Rebuild the unhedged completion from the same draws.
  unhedged = backend.account(
      1, 10.0,
      type(plan)(fe_mode=plan.fe_mode, mode=plan.mode, noise=plan.noise,
                 noise2=plan.noise2, hedged=np.zeros(2, bool),
                 b_est=plan.b_est, deadline_ms=plan.deadline_ms),
      st, warming=True)
  full = plan.mode == MODE_FULL
  assert (np.asarray(info["comp_ms"])[full]
          <= np.asarray(unhedged["comp_ms"])[full] + 1e-12).all()
  assert info["parallel_ms"] <= unhedged["parallel_ms"] + 1e-12
  assert info["hedged"] == 2
  # Physical consistency: a reissue queues behind the replica's own
  # shard, whose completion is priced with the SAME noise[j] draw — the
  # hedge can never finish before the machine it runs on is free.
  u = backend._units(np.ones(2))
  j = backend.replica_of
  t_hedge = backend._hedge_time(10.0, u, u.sum(), plan.noise, plan.noise2)
  own = 10.0 * u * plan.noise / u.sum()
  assert (t_hedge >= own[j] - 1e-12).all()


def test_hedged_engine_end_to_end(hedged_engine):
  from repro.serve.engine import run_open_loop
  eng, backend = hedged_engine
  s = run_open_loop(eng, rate_per_s=30.0, duration_s=0.3, seed=5)
  assert s["n"] > 0
  for r in eng.completed:
    assert 0.0 <= r.accuracy <= 1.0
  assert backend.predictor.table()


def test_engine_token_parity_through_policy_path():
  """xla vs interpret through the refactored DeadlineBudgetPolicy path:
  an unloaded accuracytrader run always refines everything (budget = M
  regardless of measured wall times), so tokens must match exactly."""
  from repro.configs.registry import get_config
  from repro.serve.engine import EngineConfig, ServingEngine, make_requests
  cfg = get_config("llama3-8b", smoke=True)
  toks, budgets = {}, {}
  for impl in ("xla", "interpret"):
    eng = ServingEngine(cfg, EngineConfig(
        n_slots=2, prompt_len=32, max_new_tokens=2,
        policy="accuracytrader", deadline_ms=1e6, impl=impl,
        predictor="quantile:90"))
    reqs = make_requests([0.0, 0.0, 4.0], 32, 2, cfg.vocab, seed=11)
    eng.run(reqs)
    # Cold-start slow-start: budgets ramp up the buckets and reach M
    # (the deadline is unbounded), identically on both impls.
    assert max(b for r in reqs for b in r.budgets) == eng.M
    budgets[impl] = [r.budgets for r in sorted(reqs, key=lambda r: r.rid)]
    toks[impl] = [r.tokens for r in sorted(reqs, key=lambda r: r.rid)]
  assert budgets["xla"] == budgets["interpret"]
  assert toks["xla"] == toks["interpret"]
