"""Core synopsis/engine tests incl. hypothesis property tests."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
  from hypothesis import given, settings, strategies as st
except ImportError:      # property-based tests skip when hypothesis absent
  class st:  # noqa: N801 — decoration-time stand-in for `strategies`
    @staticmethod
    def integers(lo, hi):
      return None

  def given(*_strategies):
    return pytest.mark.skip(reason="hypothesis not installed")

  def settings(*a, **k):
    return lambda f: f

from repro.core import cluster as cl
from repro.core import engine as eng
from repro.core import synopsis as syn


def _data(n=256, v=24, seed=0, density=0.5):
  k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
  data = jax.random.normal(k1, (n, v))
  mask = (jax.random.uniform(k2, (n, v)) < density).astype(jnp.float32)
  return data, mask


class TestCluster:
  def test_balanced_kd_is_permutation(self):
    coords, _ = cl.pca_project(_data()[0], 3)
    perm = cl.balanced_kd_cluster(coords, 8)
    assert sorted(np.asarray(perm).tolist()) == list(range(256))

  def test_morton_is_permutation(self):
    coords, _ = cl.pca_project(_data()[0], 3)
    perm = cl.morton_cluster(coords, 8)
    assert sorted(np.asarray(perm).tolist()) == list(range(256))

  def test_kd_groups_similar_points(self):
    # two well-separated blobs must not share clusters
    a = jax.random.normal(jax.random.PRNGKey(0), (64, 8)) + 10.0
    b = jax.random.normal(jax.random.PRNGKey(1), (64, 8)) - 10.0
    data = jnp.concatenate([a, b])
    coords, _ = cl.pca_project(data, 3)
    perm = cl.balanced_kd_cluster(coords, 2)
    first = set(np.asarray(perm[:64]).tolist())
    assert first == set(range(64)) or first == set(range(64, 128))

  def test_pca_projects_variance(self):
    # structured data: one dominant direction must be found
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    direction = jax.random.normal(k1, (1, 24))
    data = (jax.random.normal(k2, (256, 1)) * 5.0) @ direction \
        + 0.1 * jax.random.normal(jax.random.PRNGKey(2), (256, 24))
    coords, proj = cl.pca_project(data, 3)
    assert coords.shape == (256, 3)
    assert proj.shape == (24, 3)
    # top component captures nearly all the variance
    total = float(jnp.sum(jnp.var(data - data.mean(0), axis=0)))
    assert float(jnp.var(coords[:, 0])) > 0.9 * total

  def test_assign_to_nearest(self):
    centers = jnp.array([[0.0, 0], [10, 10]])
    pts = jnp.array([[1.0, 1], [9, 9]])
    assert np.asarray(cl.assign_to_nearest(pts, centers)).tolist() == [0, 1]


class TestSynopsis:
  def test_build_invariants(self):
    data, mask = _data()
    s = syn.build(data, 16, mask=mask)
    assert int(s.counts.sum()) == 256
    mi = np.asarray(s.member_idx)
    rc = np.asarray(s.row_cluster)
    seen = set()
    for c in range(16):
      mem = mi[c][mi[c] >= 0]
      assert len(mem) == int(s.counts[c])
      assert not (set(mem.tolist()) & seen)
      seen |= set(mem.tolist())
      assert all(rc[r] == c for r in mem)
    assert seen == set(range(256))

  def test_centroid_is_masked_mean(self):
    data, mask = _data()
    s = syn.build(data, 16, mask=mask)
    mi = np.asarray(s.member_idx)[3]
    mem = mi[mi >= 0]
    d, k = np.asarray(data)[mem], np.asarray(mask)[mem]
    w = k.sum(0)
    exp = np.where(w > 0, (d * k).sum(0) / np.maximum(w, 1), 0)
    np.testing.assert_allclose(np.asarray(s.centroids)[3], exp,
                               rtol=1e-5, atol=1e-5)

  def test_update_changed_touches_only_affected(self):
    data, mask = _data()
    s = syn.build(data, 16, mask=mask)
    data2 = data.at[10].set(50.0)
    s2 = syn.update_changed(s, data2, mask, jnp.array([10]))
    c = int(s.row_cluster[10])
    diff = np.abs(np.asarray(s2.centroids) - np.asarray(s.centroids)).sum(1)
    assert diff[c] > 0
    assert np.all(diff[np.arange(16) != c] == 0)

  def test_update_changed_matches_rebuild_aggregation(self):
    data, mask = _data()
    s = syn.build(data, 16, mask=mask)
    data2 = data.at[10].set(5.0).at[77].set(-3.0)
    s2 = syn.update_changed(s, data2, mask, jnp.array([10, 77]))
    # recompute affected centroid from scratch
    c = int(s.row_cluster[10])
    mi = np.asarray(s.member_idx)[c]
    mem = mi[mi >= 0]
    d, k = np.asarray(data2)[mem], np.asarray(mask)[mem]
    w = k.sum(0)
    exp = np.where(w > 0, (d * k).sum(0) / np.maximum(w, 1), 0)
    np.testing.assert_allclose(np.asarray(s2.centroids)[c], exp,
                               rtol=1e-5, atol=1e-5)

  def test_insert_running_mean(self):
    data, mask = _data()
    s = syn.build(data, 16, mask=mask)
    new = jax.random.normal(jax.random.PRNGKey(9), (4, 24))
    data2 = jnp.concatenate([data, new])
    mask2 = jnp.concatenate([mask, jnp.ones((4, 24))])
    s_grown = dataclasses.replace(
        s, row_cluster=jnp.concatenate([s.row_cluster,
                                        jnp.full((4,), -1, jnp.int32)]))
    s2 = syn.insert(s_grown, data2, mask2, jnp.arange(256, 260))
    assert int(s2.counts.sum()) == 260
    assert not bool(syn.needs_rebuild(s2, headroom=0))

  @settings(max_examples=10, deadline=None)
  @given(st.integers(2, 8), st.integers(0, 4))
  def test_property_counts_preserved(self, log_m, seed):
    m = 2 ** log_m
    data, mask = _data(n=128, v=12, seed=seed)
    s = syn.build(data, min(m, 16), mask=mask)
    assert int(s.counts.sum()) == 128
    # balanced: counts differ by at most 1
    counts = np.asarray(s.counts)
    assert counts.max() - counts.min() <= 1


def _score_fn(q, cents, w):
  return jnp.zeros((2,)), -jnp.sum((cents - q[None]) ** 2, axis=1)


def _refine_fn(carry, rows, msk):
  return carry + jnp.array([jnp.sum(rows * msk), jnp.sum(msk)])


class TestEngine:
  def test_full_budget_equals_exact(self):
    data, mask = _data(n=128, v=12)
    s = syn.build(data, 8, mask=mask)
    q = data[5]
    res = eng.approximate_process(q, s, data, mask, score_fn=_score_fn,
                                  refine_fn=_refine_fn, i_max=8)
    exact = eng.exact_process(q, data, mask, init=jnp.zeros((2,)),
                              refine_fn=_refine_fn)
    np.testing.assert_allclose(np.asarray(res.result), np.asarray(exact),
                               rtol=1e-4)

  def test_modes_agree(self):
    data, mask = _data(n=128, v=12)
    s = syn.build(data, 8, mask=mask)
    q = data[5]
    a = eng.approximate_process(q, s, data, mask, score_fn=_score_fn,
                                refine_fn=_refine_fn, i_max=3,
                                mode="iterative")
    b = eng.approximate_process(q, s, data, mask, score_fn=_score_fn,
                                refine_fn=_refine_fn, i_max=3,
                                mode="vectorized")
    np.testing.assert_allclose(np.asarray(a.result), np.asarray(b.result),
                               rtol=1e-4)

  def test_selected_are_top_ranked(self):
    data, mask = _data(n=128, v=12)
    s = syn.build(data, 8, mask=mask)
    res = eng.approximate_process(data[5], s, data, mask,
                                  score_fn=_score_fn,
                                  refine_fn=_refine_fn, i_max=3)
    order = np.argsort(-np.asarray(res.scores))
    assert set(np.asarray(res.selected).tolist()) == set(order[:3].tolist())

  @settings(max_examples=10, deadline=None)
  @given(st.integers(0, 4))
  def test_property_coverage_monotone(self, seed):
    """More budget -> refinement covers a superset of data points."""
    data, mask = _data(n=128, v=12, seed=seed)
    s = syn.build(data, 8, mask=mask)
    q = data[seed]
    covered = []
    for b in (1, 2, 4, 8):
      r = eng.approximate_process(q, s, data, mask, score_fn=_score_fn,
                                  refine_fn=_refine_fn, i_max=b)
      covered.append(set(np.asarray(r.selected).tolist()))
    assert covered[0] <= covered[1] <= covered[2] <= covered[3]


class TestDeadline:
  def test_budget_shrinks_with_queue(self):
    from repro.core.deadline import BudgetController, LatencyModel
    c = BudgetController(LatencyModel(base=2.0, slope=1.0),
                         buckets=(0, 1, 2, 4, 8, 16, 32))
    assert c.budget_for(40.0, 0.0) >= c.budget_for(40.0, 30.0)
    assert c.budget_for(40.0, 100.0) == 0

  def test_calibration_converges(self):
    from repro.core.deadline import LatencyModel
    m = LatencyModel(base=5.0, slope=5.0, alpha=0.2)
    rng = np.random.default_rng(0)
    for _ in range(500):
      b = int(rng.integers(0, 20))
      m.observe(b, 2.0 + 0.5 * b + rng.normal(0, 0.05))
    assert abs(m.base - 2.0) < 0.5
    assert abs(m.slope - 0.5) < 0.2
