"""Corpus-cache core + delta-replay tests (DESIGN.md §12).

Property tests follow the repo pattern: hypothesis drives them where
installed; a seeded-random equivalent of each property always runs, so
the invariants are enforced on every host either way.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
  from hypothesis import given, settings, strategies as st
except ImportError:      # property-based tests skip when hypothesis absent
  class st:  # noqa: N801 — decoration-time stand-in for `strategies`
    @staticmethod
    def integers(lo, hi):
      return None

  def given(*_strategies):
    return pytest.mark.skip(reason="hypothesis not installed")

  def settings(*a, **k):
    return lambda f: f

from repro.configs.registry import get_config
from repro.models import common as cm
from repro.models import transformer as tf
from repro.serve import corpus_cache as cc
from repro.serve import kv_cache as kvc
from repro.serve import prefill as pf
from repro.serve import synopsis_kv as skv


def _arena(seed=0, n=4):
  """Tiny numpy stand-in arena: the cache core only touches leaf nbytes."""
  rng = np.random.default_rng(seed)
  return {name: rng.normal(size=(n,)).astype(np.float32)
          for name in kvc.ARENA_LEAVES}


def _tokens(rng, lo=1, hi=64):
  return rng.integers(0, 512, rng.integers(lo, hi), dtype=np.int32)


class TestCacheCore:
  def test_key_content_addressing(self):
    t = np.arange(8, dtype=np.int32)
    assert cc.corpus_key(t) == cc.corpus_key(t.copy())
    assert cc.corpus_key(t) != cc.corpus_key(t + 1)
    # Same tokens under a different model/config fingerprint are a
    # DIFFERENT corpus.
    assert cc.corpus_key(t, "a") != cc.corpus_key(t, "b")
    # Length is part of the hash input (no prefix collision).
    assert cc.corpus_key(t[:4]) != cc.corpus_key(t)

  def test_disabled_is_noop(self):
    cache = cc.CorpusCache(cc.CacheConfig())          # capacity 0
    assert not cache.enabled
    assert cache.lookup(np.arange(4, dtype=np.int32)) == ("miss", None)
    assert cache.stats()["hits"] == cache.stats()["misses"] == 0
    with pytest.raises(ValueError):
      cache.publish(np.arange(4, dtype=np.int32), _arena(), None)

  def test_hit_miss_and_publish_converge(self):
    cache = cc.CorpusCache(cc.CacheConfig(capacity=4))
    t = np.arange(8, dtype=np.int32)
    assert cache.lookup(t)[0] == "miss"
    e1 = cache.publish(t, _arena(), None)
    assert e1.refcount == 1
    kind, e = cache.lookup(t)
    assert kind == "hit" and e is e1
    # A concurrent miss publishing the same corpus pins the existing
    # entry instead of duplicating the arena.
    e2 = cache.publish(t, _arena(seed=9), None)
    assert e2 is e1 and e1.refcount == 2
    assert cache.stats()["entries"] == 1

  def test_prefix_extension_lookup(self):
    cache = cc.CorpusCache(cc.CacheConfig(capacity=4, delta_unit=4))
    rng = np.random.default_rng(0)
    t = rng.integers(0, 512, 16, dtype=np.int32)
    cache.publish(t[:8], _arena(), None)
    cache.publish(t[:4], _arena(1), None)
    kind, e = cache.lookup(t)
    assert kind == "extend"
    # Longest strict prefix wins (8 over 4).
    assert e.tokens.shape[0] == 8
    assert np.array_equal(e.tokens, t[:8])
    # Extension length must divide delta_unit; 16-8=8 ok, but a
    # 14-token corpus (ext 6) must miss.
    assert cache.lookup(t[:14])[0] == "miss"
    # Exact-only mode (delta_unit=0) never returns extend.
    exact = cc.CorpusCache(cc.CacheConfig(capacity=4))
    exact.publish(t[:8], _arena(), None)
    assert exact.lookup(t)[0] == "miss"

  def _drive(self, rng, n_ops=200, capacity=3, n_corpora=6, map_count=1):
    """Random admit/retire interleaving; returns nothing — asserts the
    refcount-conservation and no-live-eviction invariants throughout.
    ``map_count`` is the fleet tier's R replica mappings per admission
    (DESIGN.md §14): each slot pins the arena R times and releases R at
    retirement, so one replica's retirement can never free an arena
    another replica row still reads."""
    cache = cc.CorpusCache(cc.CacheConfig(capacity=capacity))
    pool = [np.arange(i + 1, dtype=np.int32) for i in range(n_corpora)]
    live = []                                    # keys pinned by "slots"
    for _ in range(n_ops):
      published = False
      if live and rng.integers(0, 2):
        cache.release(live.pop(rng.integers(0, len(live))),   # retire
                      map_count)
      else:
        t = pool[rng.integers(0, n_corpora)]                  # admit
        kind, e = cache.lookup(t)
        if kind == "hit":
          cache.acquire(e, map_count)
        else:
          e = cache.publish(t, _arena(int(t.shape[0])), None)
          if map_count > 1:     # publish holds the first replica mapping
            cache.acquire(e, map_count - 1)
          published = True
        live.append(e.key)
      # Refcount conservation: each entry's refcount equals exactly the
      # live slot mappings that hold it (x map_count replica rows);
      # total refs == live slots x map_count.
      expect = {}
      for k in live:
        expect[k] = expect.get(k, 0) + map_count
      for k, n in expect.items():
        assert k in cache.entries, "live-ref entry was evicted"
        assert cache.entries[k].refcount == n
      assert sum(e.refcount for e in cache.entries.values()) \
          == len(live) * map_count
      # Capacity: eviction runs at publish time, so right after one the
      # cache is either within capacity or wholly pinned (no victims).
      if published and len(cache.entries) > capacity:
        assert all(e.refcount > 0 for e in cache.entries.values())
    # Draining every slot re-converges under capacity.
    for k in live:
      cache.release(k, map_count)
    cache.publish(np.full((99,), 7, np.int32), _arena(99), None)
    assert len(cache.entries) <= capacity

  def test_refcount_conservation_seeded(self):
    for seed in range(8):
      self._drive(np.random.default_rng(seed))

  def test_refcount_conservation_replicated(self):
    # Fleet tier (R > 1): R pins per admission, R releases per retire —
    # the same conservation law at every interleaving point.
    for seed in range(6):
      self._drive(np.random.default_rng(seed),
                  map_count=2 + seed % 2)          # R in {2, 3}
    # Partial release of a replicated mapping is a caller bug the cache
    # must reject, not absorb: releasing MORE pins than an entry holds
    # raises instead of going negative.
    cache = cc.CorpusCache(cc.CacheConfig(capacity=2))
    e = cache.publish(np.arange(3, dtype=np.int32), _arena(), None)
    cache.acquire(e, 2)                             # R=3 mapping
    with pytest.raises(ValueError):
      cache.release(e.key, 4)
    assert e.refcount == 3                          # reject left it intact

  @settings(max_examples=25, deadline=None)
  @given(st.integers(0, 10_000))
  def test_refcount_conservation_hypothesis(self, seed):
    self._drive(np.random.default_rng(seed))

  @settings(max_examples=15, deadline=None)
  @given(st.integers(0, 10_000))
  def test_refcount_conservation_replicated_hypothesis(self, seed):
    self._drive(np.random.default_rng(seed), map_count=2 + seed % 3)

  def test_no_eviction_of_live_refs(self):
    cache = cc.CorpusCache(cc.CacheConfig(capacity=2))
    entries = [cache.publish(np.arange(i + 1, dtype=np.int32),
                             _arena(i), None) for i in range(4)]
    # Every entry pinned: capacity overshoots, nothing evicted.
    assert len(cache.entries) == 4
    assert cache.stats()["evictions"] == 0
    # Release the two oldest; the next publish evicts exactly those
    # (LRU over refcount-zero only).
    cache.release(entries[0].key)
    cache.release(entries[1].key)
    cache.publish(np.arange(9, dtype=np.int32), _arena(9), None)
    assert entries[0].key not in cache.entries
    assert entries[1].key not in cache.entries
    assert entries[2].key in cache.entries
    assert cache.stats()["evictions"] == 2

  def test_release_unpinned_raises(self):
    cache = cc.CorpusCache(cc.CacheConfig(capacity=2))
    e = cache.publish(np.arange(3, dtype=np.int32), _arena(), None)
    cache.release(e.key)
    with pytest.raises(ValueError):
      cache.release(e.key)

  def test_capacity_bytes(self):
    a = _arena(n=4)                    # 5 leaves * 16 B = 80 B
    nbytes = kvc.arena_nbytes(a)
    cache = cc.CorpusCache(cc.CacheConfig(capacity=10,
                                          capacity_bytes=2 * nbytes))
    ents = [cache.publish(np.arange(i + 1, dtype=np.int32), _arena(i, 4),
                          None) for i in range(3)]
    for e in ents:
      cache.release(e.key)
    cache.publish(np.arange(9, dtype=np.int32), _arena(9, 4), None)
    assert cache.nbytes <= 2 * nbytes


@pytest.fixture(scope="module")
def f32_cfg():
  # The smoke config is bf16; the 1e-5 delta-parity contract is an f32
  # statement (bf16 resolution is ~1e-2).
  return dataclasses.replace(get_config("llama3-8b", smoke=True),
                             dtype=jnp.float32)


@pytest.fixture(scope="module")
def f32_params(f32_cfg):
  params, _ = cm.split(tf.init_model(jax.random.PRNGKey(0), f32_cfg))
  return params


class TestDeltaReplay:
  def test_supports_delta_gates_archs(self, f32_cfg):
    assert cc.supports_delta(f32_cfg)
    assert not cc.supports_delta(get_config("jamba-v0.1-52b", smoke=True))

  def test_delta_replay_matches_full_rebuild(self, f32_cfg, f32_params):
    """Prefix arena + KV-delta replay == the full-prefix build, to 1e-5
    f32: the extend step's KV for the extension tokens must match a full
    prefill's, and growing the arena from either KV source must agree —
    so a delta-replayed admission serves the same corpus state a
    from-scratch admission would."""
    cfg, params = f32_cfg, f32_params
    S, P = 64, 32                       # 2 + 2 clusters (C=16, kd wants 2^k)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, S), 0, cfg.vocab)
    prefill = pf.make_prefill_step(cfg, impl="xla")
    logits_full, cache_full = prefill(params, toks)
    _, cache_pre = prefill(params, toks[:, :P])
    arena = skv.build(cache_pre, cfg, impl="xla")

    extend = pf.make_extend_step(cfg, impl="xla")
    logits_ext, (k_new, v_new) = extend(params, toks[:, P:], arena["k"],
                                        arena["v"], jnp.int32(P))
    # The delta prefill's KV and last-token logits match the full prefill
    # (permutation-invariant softmax over the sorted prefix KV).
    ref_k = cache_full["k"][:, :, :, :, P:]
    ref_v = cache_full["v"][:, :, :, :, P:]
    assert float(jnp.max(jnp.abs(k_new - ref_k))) < 1e-5
    assert float(jnp.max(jnp.abs(v_new - ref_v))) < 1e-5
    assert float(jnp.max(jnp.abs(logits_ext - logits_full))) < 1e-4

    # Growing the arena from the delta KV == growing it from the full
    # prefill's KV slice (the from-scratch reference for the suffix
    # clusters, identical clustering inputs up to 1e-5).
    got = skv.extend_synopsis(arena, k_new, v_new, cfg, impl="xla")
    want = skv.extend_synopsis(arena, ref_k, ref_v, cfg, impl="xla")
    for name in kvc.ARENA_LEAVES:
      if name not in got:            # scale leaves: quantized arenas only
        continue
      err = float(jnp.max(jnp.abs(got[name].astype(jnp.float32)
                                  - want[name].astype(jnp.float32))))
      assert err < 1e-5, (name, err)
    assert int(got["pos"][0]) == S

  def test_extend_synopsis_shapes_and_counts(self, f32_cfg, f32_params):
    cfg, params = f32_cfg, f32_params
    C = cfg.synopsis.cluster_size
    prefill = pf.make_prefill_step(cfg, impl="xla")
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, 64), 0, cfg.vocab)
    _, cache_pre = prefill(params, toks[:, :32])
    arena = skv.build(cache_pre, cfg, impl="xla")
    extend = pf.make_extend_step(cfg, impl="xla")
    _, (k_new, v_new) = extend(params, toks[:, 32:], arena["k"],
                               arena["v"], jnp.int32(32))
    out = skv.extend_synopsis(arena, k_new, v_new, cfg, impl="xla")
    assert out["k"].shape[4] == 64
    assert out["k_syn"].shape[4] == 64 // C
    assert out["counts"].shape[3] == 64 // C
    # Every appended cluster holds exactly C originals (balanced splits).
    assert np.allclose(np.asarray(out["counts"]), C)
    # The prefix half of the arena is untouched (shared-immutable).
    assert np.array_equal(np.asarray(out["k"][:, :, :, :, :32]),
                          np.asarray(arena["k"]))
