"""Validate the analytic cost model against XLA cost_analysis on a
loop-free (single-block, unscanned-equivalent) module, and check the
roofline HLO collective parser on known programs."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import costmodel as cmod
from repro.analysis import roofline as rl
from repro.configs.registry import get_config
from repro.configs.shapes import ShapeSpec


def test_flops_match_xla_on_loop_free_mlp():
  """Our 2*M*N*K convention == XLA's on a plain matmul chain."""
  def f(w1, w2, x):
    return jnp.sum(jnp.tanh(x @ w1) @ w2)

  w1 = jax.ShapeDtypeStruct((256, 512), jnp.float32)
  w2 = jax.ShapeDtypeStruct((512, 128), jnp.float32)
  x = jax.ShapeDtypeStruct((64, 256), jnp.float32)
  comp = jax.jit(f).lower(w1, w2, x).compile()
  ca = comp.cost_analysis()
  if isinstance(ca, list):
    ca = ca[0]
  expect = 2 * 64 * 256 * 512 + 2 * 64 * 512 * 128
  assert abs(ca["flops"] - expect) / expect < 0.05


def test_cell_cost_scales_with_shape():
  cfg = get_config("llama3-8b")
  tr = ShapeSpec("t", 4096, 256, "train")
  tr2 = ShapeSpec("t", 4096, 512, "train")
  a = cmod.cell_cost(cfg, tr, "n/a").flops_global
  b = cmod.cell_cost(cfg, tr2, "n/a").flops_global
  assert abs(b / a - 2.0) < 0.01            # linear in batch


def test_train_flops_close_to_8nd():
  """Dense 8B at 4k: train flops ~= 8*N*D (fwd+bwd+remat) + attention."""
  cfg = get_config("llama3-8b")
  tr = ShapeSpec("t", 4096, 256, "train")
  D = 256 * 4096
  n = cfg.param_count() - cfg.vocab * cfg.d_model * 2
  got = cmod.cell_cost(cfg, tr, "n/a").flops_global
  lo, hi = 8 * n * D, 8 * n * D * 1.8       # attention quad < 80% extra
  assert lo * 0.9 < got < hi, (got / (8 * n * D))


def test_decode_synopsis_cheaper_than_exact():
  cfg = get_config("llama3-8b")
  dec = ShapeSpec("d", 32768, 128, "decode")
  ex = cmod.cell_cost(cfg, dec, "exact")
  syn = cmod.cell_cost(cfg, dec, "synopsis")
  assert syn.flops_global < ex.flops_global
  assert syn.bytes_global < ex.bytes_global


def test_moe_flops_use_active_experts():
  ds = get_config("deepseek-v2-236b")
  tr = ShapeSpec("t", 4096, 256, "train")
  got = cmod.cell_cost(ds, tr, "n/a").flops_global
  n_active = ds.param_count(active=True) - ds.vocab * ds.d_model * 2
  n_total = ds.param_count() - ds.vocab * ds.d_model * 2
  D = 256 * 4096
  assert got < 8 * n_total * D * 0.5        # far below dense-all-experts
  assert got > 6 * n_active * D * 0.9


class TestCollectiveParser:
  def _compile(self, f, *args):
    return jax.jit(f).lower(*args).compile().as_text()

  def test_psum_counted_with_trip_count(self):
    import os
    if jax.device_count() < 2:
      pytest.skip("needs >1 device")

  def test_split_computations(self):
    txt = self._compile(lambda x: jnp.sum(x ** 2), jnp.ones((8, 8)))
    comps = rl._split_computations(txt)
    assert len(comps) >= 1

  def test_trip_count_from_scan(self):
    def f(x):
      def body(c, _):
        return c * 1.001 + 1.0, None
      y, _ = jax.lax.scan(body, x, None, length=17)
      return y
    txt = self._compile(f, jnp.float32(1.0))
    mults = rl._comp_multipliers(txt)
    assert 17 in mults.values() or 18 in mults.values(), mults


def test_memory_summary_keys():
  comp = jax.jit(lambda x: x @ x.T).lower(
      jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
  mem = rl.memory_summary(comp)
  assert mem["peak_bytes_per_device"] >= 0
  assert "temp_size_in_bytes" in mem


def test_roofline_terms():
  r = rl.Roofline(flops_per_device=197e12, bytes_per_device=819e9,
                  coll_bytes_per_device=50e9, chips=256,
                  model_flops=197e12 * 256 / 2)
  assert abs(r.compute_s - 1.0) < 1e-9
  assert abs(r.memory_s - 1.0) < 1e-9
  assert abs(r.collective_s - 1.0) < 1e-9
  assert r.useful_flops_ratio == 0.5
