"""Distribution layer: logical sharding rules + an end-to-end mini dry-run
on 8 in-process placeholder devices (subprocess, so the main test process
keeps its single-device backend)."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
from jax.sharding import PartitionSpec as P


def test_rules_divisibility_fallback():
  import jax
  from repro.dist import sharding as shd
  # no devices needed: build an abstract mesh via Mesh of 1 device is not
  # enough to test 16-way divisibility; use mesh_axes_for's pure logic via
  # a fake mesh-shape mapping.
  class FakeMesh:
    shape = {"data": 16, "model": 16}
  spec = shd.mesh_axes_for(("embed", "heads", None), FakeMesh(),
                           shd.rules_dict(), shape=(576, 9, 64))
  assert spec == P(None, None, None)       # 9 heads can't split 16 ways
  spec = shd.mesh_axes_for(("embed", "heads", None), FakeMesh(),
                           shd.rules_dict(), shape=(576, 32, 64))
  assert spec == P(None, "model", None)


def test_rules_no_double_use():
  from repro.dist import sharding as shd
  class FakeMesh:
    shape = {"data": 4, "model": 4}
  # both dims want 'model': only the first gets it
  spec = shd.mesh_axes_for(("heads", "ff"), FakeMesh(), shd.rules_dict(),
                           shape=(16, 16))
  assert spec == P("model", None)


def test_long_rules_spread_kv_over_two_axes():
  from repro.dist import sharding as shd
  class FakeMesh:
    shape = {"data": 16, "model": 16}
  spec = shd.mesh_axes_for(
      ("layers", None, "batch", "kv_heads", "kv_seq", None), FakeMesh(),
      shd.LONG_RULES, shape=(32, 1, 1, 8, 524288, 128))
  assert spec[4] == ("data", "model")


MINI = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np
    import jax, jax.numpy as jnp
    from jax.sharding import Mesh
    from repro.configs.registry import get_config
    from repro.dist import sharding as shd
    from repro.models import common as cm, transformer as tf
    from repro.serve import kv_cache as kvc
    from repro.serve.serve_step import make_serve_step
    from repro.train.optimizer import OptConfig
    from repro.train.train_step import make_train_step

    mesh = Mesh(np.array(jax.devices()).reshape(2, 2, 2),
                ("pod", "data", "model"))
    out = {}
    for arch in ["llama3-8b", "deepseek-v2-236b", "jamba-v0.1-52b"]:
        cfg = get_config(arch, smoke=True)
        cap = {}
        def init_fn(key):
            p, a = cm.split(tf.init_model(key, cfg)); cap["a"] = a; return p
        p_sds = jax.eval_shape(init_fn, jax.random.PRNGKey(0))
        axes = cap["a"]
        with shd.use_mesh(mesh, shd.TRAIN_RULES):
            st = {"params": jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), p_sds)}
            st["opt"] = {"m": st["params"], "v": st["params"],
                         "step": jax.ShapeDtypeStruct((), jnp.int32)}
            st["err"] = st["params"]
            sa = {"params": axes,
                  "opt": {"m": axes, "v": axes, "step": ()}, "err": axes}
            b_sds = {"tokens": jax.ShapeDtypeStruct((8, 64), jnp.int32),
                     "labels": jax.ShapeDtypeStruct((8, 64), jnp.int32)}
            ba = {k: ("batch", None) for k in b_sds}
            in_sh = (shd.tree_shardings(sa, mesh, shd.TRAIN_RULES, st),
                     shd.tree_shardings(ba, mesh, shd.TRAIN_RULES, b_sds))
            step = make_train_step(cfg, OptConfig(), microbatches=2,
                                   compress_pods=True, mesh=mesh)
            c = jax.jit(step, in_shardings=in_sh,
                        out_shardings=(in_sh[0], None)
                        ).lower(st, b_sds).compile()
            out[arch + ":train"] = True
        with shd.use_mesh(mesh, shd.SERVE_RULES):
            B, S = 8, 64
            cs = kvc.cache_specs(cfg, B, S, synopsis=True)
            ca = kvc.cache_axes(cfg, B, S, synopsis=True)
            pb = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(s.shape, cfg.dtype), p_sds)
            in_sh = (shd.tree_shardings(axes, mesh, shd.SERVE_RULES, pb),
                     shd.tree_shardings(ca, mesh, shd.SERVE_RULES, cs),
                     shd.named_sharding(("batch", None), mesh,
                                        shd.SERVE_RULES, (B, 1)))
            sstep = make_serve_step(cfg, mode="synopsis", i_max=2)
            c = jax.jit(sstep, in_shardings=in_sh).lower(
                pb, cs, jax.ShapeDtypeStruct((B, 1), jnp.int32)).compile()
            out[arch + ":serve"] = True
    print("RESULT:" + json.dumps(out))
""")


@pytest.mark.slow
@pytest.mark.subprocess
def test_mini_multipod_dryrun():
  env = dict(os.environ)
  env["PYTHONPATH"] = "src"
  p = subprocess.run([sys.executable, "-c", MINI], capture_output=True,
                     text=True, env=env, timeout=900,
                     cwd=os.path.dirname(os.path.dirname(__file__)))
  assert p.returncode == 0, p.stderr[-3000:]
  line = [l for l in p.stdout.splitlines() if l.startswith("RESULT:")][0]
  res = json.loads(line[len("RESULT:"):])
  assert all(res.values()) and len(res) == 6
