"""Docs integrity: README/DESIGN exist, and every `DESIGN.md §N`
citation in the source tree resolves to a real §N heading in DESIGN.md
(the section numbers are API — docstrings anchor to them)."""
import pathlib
import re

ROOT = pathlib.Path(__file__).resolve().parents[1]


def test_readme_and_design_exist():
  assert (ROOT / "README.md").is_file()
  assert (ROOT / "DESIGN.md").is_file()


def test_readme_covers_entrypoints():
  txt = (ROOT / "README.md").read_text()
  for needle in ("python -m pytest -x -q", "repro.launch.serve",
                 "examples/quickstart.py", "benchmarks.run",
                 "DESIGN.md", "EXPERIMENTS.md"):
    assert needle in txt, f"README.md missing {needle!r}"


def _design_headings():
  txt = (ROOT / "DESIGN.md").read_text()
  return set(re.findall(r"^#{1,6}\s*§(\d+)\b", txt, re.M))


def _design_refs():
  refs = {}
  dirs = ["src", "tests", "benchmarks", "examples"]
  for d in dirs:
    for p in (ROOT / d).rglob("*.py"):
      for n in re.findall(r"DESIGN\.md\s*§(\d+)", p.read_text()):
        refs.setdefault(n, []).append(str(p.relative_to(ROOT)))
  return refs


def test_design_has_sections():
  headings = _design_headings()
  assert headings, "DESIGN.md has no §N headings"
  # The anchors the codebase has always cited, plus the control plane
  # (§10: predictors, recirculation, hedged replica gather) and the
  # corpus cache (§12: content addressing, CoW split, delta replay).
  assert {"3", "5", "10", "12", "13"} <= headings


def test_docstring_design_refs_resolve():
  headings = _design_headings()
  refs = _design_refs()
  assert refs, "expected at least one DESIGN.md §N citation in the code"
  dangling = {n: files for n, files in refs.items() if n not in headings}
  assert not dangling, f"dangling DESIGN.md § references: {dangling}"
