"""Continuous-batching serving engine (DESIGN.md §8): slot admit/retire
invariants, deadline->budget monotonicity, xla-vs-interpret parity through
the full engine loop, the budget-0 stage-1 floor, and the measured-latency
delegation into the discrete-event simulator."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.core.deadline import BudgetController, LatencyModel
from repro.serve import synopsis_kv as skv
from repro.serve.engine import (CacheConfig, EngineConfig, EngineRequest,
                                MeasuredStepBackend, ServingEngine,
                                make_requests, make_zipf_requests,
                                run_open_loop)
from repro.serving.latency import ComponentModel
from repro.serving.service import ScatterGatherService, ServiceConfig

N_SLOTS, PROMPT, NEW = 2, 64, 4


@pytest.fixture(scope="module")
def cfg():
  return get_config("llama3-8b", smoke=True)


@pytest.fixture(scope="module")
def engine(cfg):
  return ServingEngine(cfg, EngineConfig(
      n_slots=N_SLOTS, prompt_len=PROMPT, max_new_tokens=NEW,
      deadline_ms=60.0, policy="accuracytrader", impl="xla"))


def _deterministic_requests(cfg, arrivals):
  return make_requests(arrivals, PROMPT, NEW, cfg.vocab, seed=7)


def test_slot_admit_retire_invariants(cfg, engine):
  engine.reset()
  reqs = _deterministic_requests(cfg, [0.0, 0.0, 0.0, 2.0, 2.0, 250.0])
  engine.run(reqs)

  assert len(engine.completed) == len(reqs)
  admits = {r: [] for r in range(len(reqs))}
  occupied = {}
  for kind, rid, slot, t in engine.events:
    assert 0 <= slot < N_SLOTS
    if kind == "admit":
      assert slot not in occupied, "admit into an occupied slot"
      occupied[slot] = rid
      admits[rid].append(t)
    else:
      assert occupied.get(slot) == rid, "retire of a non-resident request"
      del occupied[slot]
    assert len(occupied) <= N_SLOTS
  assert not occupied, "every admitted request retires"
  for r in reqs:
    assert len(admits[r.rid]) == 1, "each request admitted exactly once"
    assert r.admit_ms >= r.arrival_ms      # no time travel
    assert r.finish_ms > r.admit_ms
    assert len(r.tokens) == NEW + 1        # prefill token + NEW decodes
    assert len(r.budgets) == NEW
    assert 0.0 <= r.accuracy <= 1.0
  # The late arrival found an idle engine: it queued for ~no time.
  late = next(r for r in reqs if r.arrival_ms == 250.0)
  assert late.queue_ms < 50.0


def test_budget_monotone_in_deadline(cfg, engine):
  # Controller law (deterministic): tighter deadline => never more
  # clusters, whatever the calibrated model says.
  ctrl = BudgetController(LatencyModel(base=2.0, slope=0.5),
                          buckets=engine.buckets, i_max_cap=engine.M)
  for b, lat in [(0, 2.0), (2, 3.1), (4, 4.2), (4, 4.0), (2, 3.0)]:
    ctrl.observe(b, lat)
  budgets = [ctrl.budget_for(d) for d in np.linspace(0.0, 50.0, 200)]
  assert budgets == sorted(budgets)
  assert budgets[0] == engine.buckets[0]

  # Through the engine loop: a tight deadline's mean budget never exceeds
  # a loose one's on the same trace.
  means = {}
  for deadline in (2.0, 500.0):
    engine.reset()
    engine.ecfg.deadline_ms = deadline
    engine.run(_deterministic_requests(cfg, [0.0, 1.0, 2.0, 3.0]))
    means[deadline] = np.mean([b for b, _, _ in engine.step_log])
  engine.ecfg.deadline_ms = 60.0
  assert means[2.0] <= means[500.0]
  assert means[500.0] == engine.M          # unloaded loose run refines all


def test_xla_interpret_token_parity(cfg):
  toks = {}
  for impl in ("xla", "interpret"):
    eng = ServingEngine(cfg, EngineConfig(
        n_slots=2, prompt_len=32, max_new_tokens=2, policy="fixed",
        fixed_budget=1, impl=impl))
    reqs = make_requests([0.0, 0.0, 4.0], 32, 2, cfg.vocab, seed=11)
    eng.run(reqs)
    toks[impl] = [r.tokens for r in sorted(reqs, key=lambda r: r.rid)]
  assert toks["xla"] == toks["interpret"]


def test_admission_overlap_token_parity(cfg):
  """Overlapping admission with resident decode changes dispatch order,
  never results: tokens match the serial-admission engine exactly, and
  slot invariants hold (each arrival admitted once, lanes cycle)."""
  toks = {}
  for overlap in (True, False):
    eng = ServingEngine(cfg, EngineConfig(
        n_slots=2, prompt_len=32, max_new_tokens=2, policy="fixed",
        fixed_budget=1, impl="xla", overlap_admission=overlap))
    reqs = make_requests([0.0, 0.0, 1.0, 2.0, 3.0], 32, 2, cfg.vocab,
                         seed=13)
    eng.run(reqs)
    toks[overlap] = [r.tokens for r in sorted(reqs, key=lambda r: r.rid)]
    occupied = {}
    for kind, rid, slot, _ in eng.events:
      if kind == "admit":
        assert slot not in occupied
        occupied[slot] = rid
      else:
        assert occupied.pop(slot) == rid
    assert not occupied
    for r in reqs:
      assert len(r.tokens) == 3 and r.admit_ms >= r.arrival_ms
  assert toks[True] == toks[False]


def test_stage1_always_produced_at_budget_zero(cfg):
  eng = ServingEngine(cfg, EngineConfig(
      n_slots=2, prompt_len=PROMPT, max_new_tokens=NEW, policy="fixed",
      fixed_budget=0, impl="xla"))
  reqs = _deterministic_requests(cfg, [0.0, 0.0, 1.0])
  eng.run(reqs)
  floor = eng.accuracy_fn(0.0)
  for r in reqs:
    assert r.budgets == [0] * NEW
    assert len(r.tokens) == NEW + 1        # a result ALWAYS comes back
    assert all(0 <= t < cfg.vocab for t in r.tokens)
    assert r.accuracy == pytest.approx(floor)
  s = eng.summary()
  assert s["accuracy_loss_pct"] == pytest.approx(100.0 * (1.0 - floor))


def test_append_recent_slots_per_slot_positions():
  nb, na, B, H, R, D = 1, 1, 3, 1, 4, 2
  cache = {
      "recent_k": jnp.zeros((nb, na, B, H, R, D)),
      "recent_v": jnp.zeros((nb, na, B, H, R, D)),
      "recent_len": jnp.array([0, 2, 3], jnp.int32),
  }
  delta = jnp.arange(B, dtype=jnp.float32).reshape(1, 1, B, 1, 1, 1) + 1.0
  delta = jnp.broadcast_to(delta, (nb, na, B, H, 1, D))
  active = jnp.array([True, False, True])
  out = skv.append_recent_slots(cache, delta, 2.0 * delta, active)
  rk = np.asarray(out["recent_k"])[0, 0, :, 0, :, 0]          # (B, R)
  np.testing.assert_allclose(rk[0], [1.0, 0, 0, 0])           # slot 0 @ 0
  np.testing.assert_allclose(rk[1], [0, 0, 0, 0])             # inactive
  np.testing.assert_allclose(rk[2], [0, 0, 0, 3.0])           # slot 2 @ 3
  np.testing.assert_array_equal(np.asarray(out["recent_len"]), [1, 2, 4])
  np.testing.assert_allclose(np.asarray(out["recent_v"])[0, 0, 2, 0, 3, 0],
                             6.0)
  # Full ring: neither writes nor advances.
  out2 = skv.append_recent_slots(out, delta, delta,
                                 jnp.array([False, False, True]))
  assert int(out2["recent_len"][2]) == 4
  np.testing.assert_allclose(np.asarray(out2["recent_k"]),
                             np.asarray(out["recent_k"]))


def test_partial_drops_at_deadline_and_frees_lane(cfg):
  """Partial execution sheds a request still resident at its deadline:
  the lane frees mid-flight and the skipped result scores 0."""
  eng = ServingEngine(cfg, EngineConfig(
      n_slots=1, prompt_len=PROMPT, max_new_tokens=NEW, deadline_ms=1.0,
      policy="partial", impl="xla"))
  reqs = _deterministic_requests(cfg, [0.0, 0.0, 0.0])
  eng.run(reqs)
  assert len(eng.completed) == len(reqs)      # dropped, not stuck
  for r in reqs:
    assert r.accuracy == 0.0                  # all missed the 1 ms deadline
    assert len(r.tokens) < NEW + 1            # decode abandoned mid-flight
  occupied = set()
  for kind, rid, slot, _ in eng.events:       # lanes still cycle cleanly
    occupied.add(slot) if kind == "admit" else occupied.discard(slot)
  assert not occupied


def test_hybrid_ssm_state_advances_per_step():
  """Regression: decode must write conv/ssd deltas back per slot — with
  the states frozen at prefill, one and two decode steps would leave
  identical SSM state."""
  jcfg = get_config("jamba-v0.1-52b", smoke=True)
  states = {}
  for n_new in (1, 2):
    eng = ServingEngine(jcfg, EngineConfig(
        n_slots=1, prompt_len=64, max_new_tokens=n_new, policy="fixed",
        fixed_budget=1, impl="xla"))
    eng.run(make_requests([0.0], 64, n_new, jcfg.vocab, seed=3))
    states[n_new] = np.asarray(eng.cache["ssd_state"])
  assert not np.allclose(states[1], states[2])


def test_measured_backend_feeds_simulator(engine):
  backend = MeasuredStepBackend(engine, iters=1, full_items=100)
  assert set(backend.table) == set(engine.buckets)
  assert all(v > 0 for v in backend.table.values())
  # Simulator budgets (out of full_items=100) rescale onto engine buckets
  # (out of M) instead of collapsing onto the top bucket.
  assert backend.step_ms(200) == backend.table[engine.buckets[-1]]
  assert backend.step_ms(0) == backend.table[0]
  mid = min(engine.buckets, key=lambda b: abs(b - 0.5 * engine.M))
  assert backend.step_ms(50) == backend.table[mid]

  # The component queue serves in exactly the measured time when asked.
  comp = ComponentModel(seed=0, interference=0.0, straggler_prob=0.0)
  done = comp.submit(10.0, 5, service_ms=7.5)
  assert done == pytest.approx(17.5)

  svc = ScatterGatherService(
      ServiceConfig(n_components=8, technique="accuracytrader",
                    deadline_ms=100.0, seed=0),
      step_backend=backend)
  s = svc.run_open_loop(20.0, 1.0)
  assert s["n"] > 0 and s["p999"] > 0.0
  assert 0.0 <= s["accuracy_loss_pct"] <= 100.0


def test_run_open_loop_summary_fields(engine):
  s = run_open_loop(engine, rate_per_s=30.0, duration_s=0.3, seed=5)
  for k in ("p50", "p99", "p999", "accuracy_loss_pct",
            "deadline_miss_pct", "mean_budget", "queue_p99", "steps"):
    assert k in s
  assert s["n"] == len(engine.completed)


def _zipf_trace(cfg, n=8, n_corpora=3, seed=17):
  return make_zipf_requests([float(2 * i) for i in range(n)], 32, 2,
                            cfg.vocab, n_corpora=n_corpora, seed=seed)


def test_cache_token_and_loss_parity(cfg):
  """The corpus cache is a pure latency optimisation: a Zipf-repeated
  trace produces identical per-request tokens and loss with the cache on
  vs off, under both the xla and interpret kernels — and with it on,
  every repeat hits, so prefills == cache misses == unique corpora."""
  C = cfg.synopsis.cluster_size
  results = {}
  for impl in ("xla", "interpret"):
    for cache_on in (True, False):
      eng = ServingEngine(cfg, EngineConfig(
          n_slots=2, prompt_len=32, max_new_tokens=2, policy="fixed",
          fixed_budget=1, impl=impl,
          cache=CacheConfig(capacity=8, delta_unit=C) if cache_on
          else None))
      reqs = _zipf_trace(cfg)
      eng.run(reqs)
      s = eng.summary()
      results[(impl, cache_on)] = (
          [r.tokens for r in sorted(reqs, key=lambda r: r.rid)],
          s["accuracy_loss_pct"], s)
  toks0, loss0, _ = results[("xla", False)]
  for toks, loss, _ in results.values():
    assert toks == toks0
    assert loss == loss0
  uniq = len({r.prompt.tobytes() for r in _zipf_trace(cfg)})
  _, _, s_on = results[("xla", True)]
  assert s_on["prefills"] == s_on["cache_misses"] == uniq
  assert s_on["cache_hits"] == len(toks0) - uniq
  assert s_on["cache_hit_rate"] == pytest.approx(1.0 - uniq / len(toks0))


def test_cache_delta_replay_admission(cfg):
  """A corpus strictly prefix-extending a cached entry replays only the
  KV delta: no full prefill, the extended corpus is itself published,
  and a repeat of it is an exact hit."""
  C = cfg.synopsis.cluster_size
  eng = ServingEngine(cfg, EngineConfig(
      n_slots=1, prompt_len=PROMPT, max_new_tokens=2, policy="fixed",
      fixed_budget=1, impl="xla",
      cache=CacheConfig(capacity=8, delta_unit=C)))
  reqs = make_requests([0.0], PROMPT, 2, cfg.vocab, seed=21)
  prefix = reqs[0].prompt[:PROMPT // 2]          # 2 kd clusters (C=16)
  logits, c1 = eng._prefill(eng.params, jnp.asarray(prefix)[None])
  first = jnp.argmax(logits, -1).astype(jnp.int32)
  eng.corpus_cache.publish(prefix, eng._build(c1), first)

  eng.run(reqs)
  st = eng.corpus_cache.stats()
  assert st["delta_hits"] == 1 and st["misses"] == 0
  assert eng.prefills == 0                       # no full prefill ran
  assert len(reqs[0].tokens) == 3
  assert st["entries"] == 2                      # prefix + extended corpus

  eng.run(make_requests([0.0], PROMPT, 2, cfg.vocab, seed=21))
  st = eng.corpus_cache.stats()
  assert st["hits"] == 1 and st["delta_hits"] == 1 and eng.prefills == 0


def test_cache_disabled_is_control_arm(cfg):
  """capacity=0 (and cache=None) is a true no-op: identical tokens and
  deterministic summary fields, and no cache_* keys leak into the
  summary — so --no-cache benches a clean control arm."""
  outs = {}
  for name, cache in (("none", None), ("zero", CacheConfig(capacity=0))):
    eng = ServingEngine(cfg, EngineConfig(
        n_slots=2, prompt_len=32, max_new_tokens=2, policy="fixed",
        fixed_budget=1, impl="xla", cache=cache))
    reqs = _zipf_trace(cfg, n=4, n_corpora=2, seed=19)
    eng.run(reqs)
    outs[name] = ([r.tokens for r in sorted(reqs, key=lambda r: r.rid)],
                  eng.summary())
  toks_none, s_none = outs["none"]
  toks_zero, s_zero = outs["zero"]
  assert toks_none == toks_zero
  assert set(s_none) == set(s_zero)
  assert not any(k.startswith("cache_") for k in s_none)
  for k in ("prefills", "served_n", "accuracy_loss_pct", "n"):
    assert s_none[k] == s_zero[k]


def test_cache_survives_reset_windows(cfg):
  """Entries persist across measurement windows (reset() drops pins and
  counters, not arenas — warm state is the point); the second window of
  an identical trace runs at 100% hit rate with zero prefills."""
  C = cfg.synopsis.cluster_size
  eng = ServingEngine(cfg, EngineConfig(
      n_slots=2, prompt_len=32, max_new_tokens=2, policy="fixed",
      fixed_budget=1, impl="xla",
      cache=CacheConfig(capacity=8, delta_unit=C)))
  eng.run(_zipf_trace(cfg, n=4, n_corpora=1, seed=23))
  assert eng.corpus_cache.stats()["misses"] == 1
  eng.reset()
  assert eng.corpus_cache.stats() == {
      "hits": 0, "misses": 0, "delta_hits": 0, "evictions": 0,
      "entries": 1, "bytes": eng.corpus_cache.nbytes, "hit_rate": 0.0}
  eng.run(_zipf_trace(cfg, n=4, n_corpora=1, seed=23))
  st = eng.corpus_cache.stats()
  assert st["hits"] == 4 and st["misses"] == 0 and eng.prefills == 0


def test_engine_rejects_inapplicable_configs(cfg):
  with pytest.raises(ValueError):
    ServingEngine(get_config("mamba2-370m", smoke=True),
                  EngineConfig(prompt_len=64))   # no KV cache to synopsize
  with pytest.raises(ValueError):
    ServingEngine(cfg, EngineConfig(prompt_len=65))  # not cluster-aligned
  with pytest.raises(ValueError):
    ServingEngine(cfg, EngineConfig(
        prompt_len=64, max_new_tokens=cfg.synopsis.recent + 1))
