"""Online accuracy estimation + ε-or-deadline contracts (DESIGN.md §13):
coverage-profile laws, raw-loss estimator properties, isotonic
calibration quality (rank correlation gated on a seeded engine
workload), ε=0 exact-path parity, error_bounded's freed-budget
conservation and ε compliance, deadline_with_bound's band coverage,
xla-vs-interpret parity through both contracts, gain-allocation
conservation, and the run_open_loop seed-role split (the seed-reuse
bug class)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.control import (AccuracyEstimator, DeadlineBudgetPolicy,
                           calibration_pairs, coverage_profile,
                           isotonic_fit, spearman)
from repro.serve.cluster import (ClusterConfig, ClusterStepBackend,
                                 gain_budgets, gain_rank)
from repro.serve.engine import (EngineConfig, EngineRequest, ServingEngine,
                                make_requests, run_open_loop)

N_SLOTS, PROMPT, NEW = 2, 64, 4


@pytest.fixture(scope="module")
def cfg():
  return get_config("llama3-8b", smoke=True)


# -- coverage profile (the raw signal) ---------------------------------------

def _toy_scores(seed=0, B=2, Hkv=2, M=8):
  rng = np.random.default_rng(seed)
  scores = jnp.asarray(rng.normal(size=(B, Hkv, M)), jnp.float32)
  counts = jnp.asarray(rng.integers(1, 20, size=(B, M)), jnp.float32)
  return scores, counts


def test_coverage_profile_laws():
  scores, counts = _toy_scores()
  p = np.asarray(coverage_profile(scores, counts))
  assert p.shape == (2, 8 + 1)
  assert np.allclose(p[:, 0], 0.0)
  assert np.allclose(p[:, -1], 1.0, atol=1e-5)
  assert (np.diff(p, axis=-1) >= -1e-6).all()     # cumulative mass
  assert ((0.0 <= p) & (p <= 1.0 + 1e-6)).all()
  # Softmax shift invariance: a constant added to every score is the
  # same distribution, hence the same profile.
  p2 = np.asarray(coverage_profile(scores + 7.5, counts))
  assert np.allclose(p, p2, atol=1e-5)


def test_coverage_profile_orders_by_refinement_rank():
  # With counts equal, a dominant top score must cover most of the mass
  # in the first step of the refinement order.
  scores = jnp.asarray([[[5.0, 0.0, 0.0, 0.0]]], jnp.float32)
  counts = jnp.ones((1, 4), jnp.float32)
  p = np.asarray(coverage_profile(scores, counts))[0]
  assert p[1] > 0.9


def test_raw_loss_properties():
  est = AccuracyEstimator(floor=0.07)
  scores, counts = _toy_scores(seed=1)
  prof = np.asarray(coverage_profile(scores, counts))[0]
  M = prof.shape[-1] - 1
  losses = [est.raw_loss(prof, b) for b in range(M + 1)]
  assert losses[0] == pytest.approx(est.floor)     # stage-1 floor
  assert losses[-1] == pytest.approx(0.0, abs=1e-5)
  assert all(a >= b - 1e-9 for a, b in zip(losses, losses[1:]))
  assert all(0.0 <= v <= 1.0 for v in losses)
  assert est.spread_from_profile(prof, M) == pytest.approx(0.0)
  assert est.spread_from_profile(prof, 0) >= 0.0


# -- calibration units -------------------------------------------------------

def test_spearman_units():
  assert spearman([1, 2, 3, 4], [10, 20, 30, 40]) == pytest.approx(1.0)
  assert spearman([1, 2, 3, 4], [4, 3, 2, 1]) == pytest.approx(-1.0)
  assert abs(spearman([1, 2, 3, 4], [1, 1, 1, 1])) <= 1.0


def test_isotonic_fit_is_monotone_and_mean_preserving():
  x = np.array([1.0, 2.0, 3.0, 4.0])
  y = np.array([1.0, 3.0, 2.0, 4.0])
  kx, ky = isotonic_fit(x, y)
  assert (np.diff(ky) >= -1e-12).all()
  # PAVA pools the violating pair to its mean.
  fit = np.interp([2.0, 3.0], kx, ky)
  assert fit[0] == pytest.approx(2.5) and fit[1] == pytest.approx(2.5)


def test_estimator_fit_predict_and_band():
  rng = np.random.default_rng(3)
  raw = rng.uniform(0.0, 0.07, size=200)
  meas = np.clip(raw * 1.5 + 0.01 + rng.normal(0, 0.002, 200), 0, 1)
  est = AccuracyEstimator(floor=0.07, conf=0.9)
  train, test = slice(0, 100), slice(100, 200)
  stats = est.fit(raw[train], meas[train])
  assert stats["spearman"] > 0.9
  assert est.calibrated
  # Band coverage on the held-out half is near the stated confidence.
  cover = np.mean([lo - 1e-9 <= m <= hi + 1e-9
                   for r, m in zip(raw[test], meas[test])
                   for lo, hi in [est.band(r)]])
  assert cover >= est.conf - 0.1


def test_calibration_pairs_filters_unserved():
  def req(raw, acc, shed=False, dropped=False):
    r = EngineRequest(rid=0, arrival_ms=0.0,
                      prompt=np.zeros(4, np.int32), max_new_tokens=1)
    r.est_raw = list(raw)
    r.accuracy = acc
    r.shed_admission = shed
    r.dropped = dropped
    return r
  raws, meas = calibration_pairs([
      req([0.02, 0.04], 0.97),
      req([0.01], 0.99, shed=True),     # never served: excluded
      req([0.01], 0.50, dropped=True),  # shed mid-flight: excluded
      req([], 0.95)])                   # no telemetry: excluded
  assert raws == [pytest.approx(0.03)]
  assert meas == [pytest.approx(0.03)]


def test_bucket_for_epsilon_laws():
  est = AccuracyEstimator(floor=0.07)
  prof = np.linspace(0.0, 1.0, 9)
  buckets = (0, 1, 2, 4, 8)
  # ε <= 0 demands exactness no estimate can certify: full refinement.
  assert est.bucket_for_epsilon(prof, buckets, 0.0) == 8
  assert est.bucket_for_epsilon(prof, buckets, -1.0) == 8
  # ε at/above the stage-1 floor: stage 1 alone suffices.
  assert est.bucket_for_epsilon(prof, buckets, 0.07) == 0
  # Monotone: a looser ε never needs more budget.
  eps = [0.001, 0.005, 0.02, 0.05, 0.08]
  need = [est.bucket_for_epsilon(prof, buckets, e) for e in eps]
  assert need == sorted(need, reverse=True)


def test_policy_contract_dispatch():
  est = AccuracyEstimator(floor=0.07)
  pol = DeadlineBudgetPolicy(policy="basic", buckets=(0, 1, 2, 4),
                             i_max_cap=4, contract="error_bounded",
                             epsilon=0.07, estimator=est)
  prof = np.linspace(0.0, 1.0, 5)
  granted, base = pol.budget_for_contract(50.0, profiles=[prof])
  assert base == 4 and granted == 0          # ε = floor: stage 1 alone
  assert granted <= base
  # No profiles yet (cold step): the deadline decision stands.
  assert pol.budget_for_contract(50.0) == (4, 4)
  # deadline contract never deviates from the base.
  pol2 = DeadlineBudgetPolicy(policy="basic", buckets=(0, 1, 2, 4),
                              i_max_cap=4)
  assert pol2.budget_for_contract(50.0, profiles=[prof]) == (4, 4)
  with pytest.raises(ValueError):
    DeadlineBudgetPolicy(policy="basic", buckets=(0,), i_max_cap=0,
                         contract="nope")
  with pytest.raises(ValueError):
    DeadlineBudgetPolicy(policy="basic", buckets=(0,), i_max_cap=0,
                         contract="error_bounded")   # estimator missing


# -- engine integration ------------------------------------------------------

def _requests(cfg, arrivals, seed=7):
  return make_requests(arrivals, PROMPT, NEW, cfg.vocab, seed=seed)


@pytest.fixture(scope="module")
def fitted(cfg):
  """One shared estimator fit from fixed-budget calibration arms — the
  bench's phase 1, in miniature.  Returns (estimator, fit stats,
  per-arm engines' completed requests)."""
  est = AccuracyEstimator()
  raws, meas = [], []
  for ai, b in enumerate((0, 1, 2, 4)):
    eng = ServingEngine(cfg, EngineConfig(
        n_slots=N_SLOTS, prompt_len=PROMPT, max_new_tokens=NEW,
        deadline_ms=1e6, policy="fixed", fixed_budget=b,
        contract="deadline_with_bound", impl="xla", seed=3),
        estimator=est)
    run_open_loop(eng, rate_per_s=30.0, duration_s=0.3,
                  seed=3000 + ai, service_seed=3500 + ai)
    r, m = calibration_pairs(eng.completed)
    raws += r
    meas += m
  stats = est.fit(raws, meas)
  return est, stats, (raws, meas)


def test_calibration_rank_correlation_gate(fitted):
  """The raw online estimate must RANK measured loss on a real seeded
  workload — the same gate CI applies to BENCH_accuracy.json."""
  est, stats, (raws, meas) = fitted
  assert stats["n"] >= 8                      # isotonic, not affine
  assert stats["spearman"] >= 0.8
  # The calibrated prediction is monotone in the raw signal.
  xs = np.linspace(0.0, est.floor, 50)
  ys = est.predict(xs)
  assert (np.diff(ys) >= -1e-12).all()


def test_error_bounded_eps0_reproduces_exact_path(cfg):
  """ε=0 demands exactness: the contract must grant full refinement on
  every step and reproduce the deadline-contract tokens exactly."""
  toks = {}
  for contract in ("deadline", "error_bounded"):
    eng = ServingEngine(cfg, EngineConfig(
        n_slots=N_SLOTS, prompt_len=PROMPT, max_new_tokens=NEW,
        deadline_ms=1e6, policy="basic", contract=contract, epsilon=0.0,
        impl="xla"))
    reqs = _requests(cfg, [0.0, 0.0, 5.0])
    eng.run(reqs)
    toks[contract] = [r.tokens for r in sorted(reqs, key=lambda r: r.rid)]
    if contract == "error_bounded":
      assert all(b == eng.M for b, _, _ in eng.step_log)
      assert all(f == 0 for f in eng._freed_log)
  assert toks["deadline"] == toks["error_bounded"]


def test_error_bounded_frees_budget_and_meets_epsilon(cfg, fitted):
  """The tentpole behavior: with a calibrated estimator, error_bounded
  answers early (freeing budget) while realized loss stays within
  ε + tolerance; granted + freed == base on every step (the
  conservation law, test_control.py's recirculation idiom)."""
  est, _, _ = fitted
  eps = 0.02
  eng = ServingEngine(cfg, EngineConfig(
      n_slots=N_SLOTS, prompt_len=PROMPT, max_new_tokens=NEW,
      deadline_ms=1e6, policy="basic", contract="error_bounded",
      epsilon=eps, impl="xla"), estimator=est)
  s = run_open_loop(eng, rate_per_s=30.0, duration_s=0.4,
                    seed=4000, service_seed=4500)
  assert s["served_n"] > 0
  assert s["accuracy_loss_pct"] / 100.0 <= eps + 0.01
  assert s["freed_budget_mean"] > 0.0          # answered early somewhere
  # Conservation: base (policy="basic" always grants M) splits exactly
  # into granted + freed, step by step.
  assert len(eng._freed_log) == len(eng.step_log)
  for (granted, _, _), freed in zip(eng.step_log, eng._freed_log):
    assert granted + freed == eng.M
    assert freed >= 0


def test_deadline_with_bound_band_coverage(cfg, fitted):
  """Bands fit on window 1 must cover fresh windows' measured loss at
  (near) the stated confidence.  The fresh windows span the same budget
  mix the calibration saw — band validity is distributional, and a
  single-budget window shifts the conditional (raw ~0.002 occurs under
  both b=1's loss and b=2's ~0 loss; see EXPERIMENTS.md §Accuracy).
  The gate is conf - binomial slack at this sample size."""
  est, _, _ = fitted
  covered, n = 0, 0
  for wi, b in enumerate((1, 2)):
    eng = ServingEngine(cfg, EngineConfig(
        n_slots=N_SLOTS, prompt_len=PROMPT, max_new_tokens=NEW,
        deadline_ms=1e6, policy="fixed", fixed_budget=b,
        contract="deadline_with_bound", impl="xla"), estimator=est)
    run_open_loop(eng, rate_per_s=30.0, duration_s=0.4,
                  seed=5000 + wi, service_seed=5500 + wi)
    for r in eng.completed:
      if r.est_raw and not r.shed_admission and not r.dropped:
        assert 0.0 <= r.band_lo <= r.band_hi <= 1.0
        assert r.band_lo <= r.pred_loss <= r.band_hi
        m = 1.0 - r.accuracy
        covered += r.band_lo - 1e-9 <= m <= r.band_hi + 1e-9
        n += 1
  assert n >= 10
  assert covered / n >= est.conf - 0.15


def test_contract_token_parity_xla_vs_interpret(cfg):
  """Both contracts produce identical tokens under the xla and interpret
  kernels (deterministic budget choices: ε=0.08 >= floor always grants
  the smallest bucket; deadline_with_bound's budgets come from the fixed
  policy)."""
  for contract, extra in (
      ("error_bounded", dict(policy="basic", epsilon=0.08)),
      ("deadline_with_bound", dict(policy="fixed", fixed_budget=1))):
    toks = {}
    for impl in ("xla", "interpret"):
      eng = ServingEngine(cfg, EngineConfig(
          n_slots=2, prompt_len=32, max_new_tokens=2, deadline_ms=1e6,
          contract=contract, impl=impl, **extra))
      reqs = make_requests([0.0, 0.0, 4.0], 32, 2, cfg.vocab, seed=11)
      eng.run(reqs)
      toks[impl] = [r.tokens for r in sorted(reqs, key=lambda r: r.rid)]
      assert all(r.est_raw for r in reqs)      # telemetry ran
    assert toks["xla"] == toks["interpret"]


# -- gain allocation (cluster frontend) --------------------------------------

def test_gain_rank_conserves_and_respects_validity():
  rng = np.random.default_rng(9)
  B, Hkv, N, Mp = 2, 2, 3, 4
  sc = rng.normal(size=(B, Hkv, N, Mp)).astype(np.float32)
  # Invalidate a per-component tail (padded slots).
  valid = np.array([4, 2, 3])
  for c in range(N):
    sc[:, :, c, valid[c]:] = -1e30
  counts = rng.integers(1, 9, size=(B, N, Mp)).astype(np.float32)
  for c in range(N):
    counts[:, c, valid[c]:] = 0.0
  i_max = 6
  gsel = np.asarray(gain_rank(jnp.asarray(sc), jnp.asarray(counts), i_max))
  bud = np.asarray(gain_budgets(jnp.asarray(gsel), Mp, N))
  n_valid = int(valid.sum())
  assert bud.shape == (B, Hkv, N)
  # Conservation: exactly min(i_max, n_valid) clusters selected...
  assert (bud.sum(-1) == min(i_max, n_valid)).all()
  # ...never more than a component's valid clusters...
  assert (bud <= valid[None, None, :]).all()
  # ...and never a padded slot.
  flat_valid = {c * Mp + j for c in range(N) for j in range(valid[c])}
  assert {int(g) for g in gsel.ravel() if g >= 0} <= flat_valid


def test_gain_rank_prefers_count_biased_mass():
  # Equal scores, one cluster with far more members: gain ranks it first.
  sc = jnp.zeros((1, 1, 2, 2), jnp.float32)
  counts = jnp.asarray([[[1.0, 1.0], [1.0, 50.0]]], jnp.float32)
  gsel = np.asarray(gain_rank(sc, counts, 1))
  assert int(gsel[0, 0, 0]) == 3               # component 1, slot 1


def test_cluster_gain_alloc_end_to_end(cfg):
  backend = ClusterStepBackend(ClusterConfig(
      n_components=2, seed=0, use_mesh=False, alloc="gain"))
  eng = ServingEngine(cfg, EngineConfig(
      n_slots=2, prompt_len=64, max_new_tokens=2, deadline_ms=1e6,
      policy="accuracytrader", contract="error_bounded", epsilon=0.05,
      impl="xla"), backend=backend)
  s = run_open_loop(eng, rate_per_s=20.0, duration_s=0.3, seed=6,
                    service_seed=60)
  assert s["served_n"] > 0
  assert all(r.est_raw for r in eng.completed if not r.shed_admission)
  assert 0.0 <= s["accuracy_loss_pct"] <= 100.0


# -- seed-role split (the seed-reuse bug class) ------------------------------

def test_run_open_loop_service_seed_splits_rng_roles(cfg):
  """Two sweep arms sharing an arrival seed but given distinct
  service_seeds must see the IDENTICAL arrival trace under independent
  service-side noise draws — the regression for seeds shared across
  sweep arms."""
  def run(service_seed):
    backend = ClusterStepBackend(ClusterConfig(
        n_components=2, seed=0, use_mesh=False, alloc="gain",
        interference=0.3, straggler_prob=0.2))
    eng = ServingEngine(cfg, EngineConfig(
        n_slots=2, prompt_len=64, max_new_tokens=2, deadline_ms=60.0,
        policy="accuracytrader", impl="xla"), backend=backend)
    draws = []
    orig = backend._draw_noise
    backend._draw_noise = lambda: (draws.append(orig()), draws[-1])[1]
    run_open_loop(eng, rate_per_s=20.0, duration_s=0.3, seed=8,
                  service_seed=service_seed)
    arrivals = sorted(r.arrival_ms for r in eng.completed)
    prompts = [r.prompt.tolist() for r in
               sorted(eng.completed, key=lambda r: r.rid)]
    return arrivals, prompts, draws
  arr_a, pr_a, dr_a = run(100)
  arr_b, pr_b, dr_b = run(200)
  assert arr_a == arr_b and pr_a == pr_b       # same arrival trace
  assert dr_a and dr_b
  assert not np.allclose(dr_a[0], dr_b[0])     # independent service noise
  # And the legacy coupling (service_seed=None -> seed) reproduces.
  arr_c, _, dr_c = run(None)
  arr_d, _, dr_d = run(None)
  assert arr_c == arr_d
  assert np.allclose(dr_c[0], dr_d[0])
