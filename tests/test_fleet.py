"""Fleet tier tests (DESIGN.md §14): the ("replica", "component") 2-D
mesh with materialized replica shards.

The load-bearing property: every replica copy is bit-identical to its
primary shard (the materializing write is pure data movement —
`kv_cache.replicate_leaf` ring-rotations of ONE scattered arena), and
the selection-aware gather folds partials in fixed shard order — so the
step output CANNOT depend on which holder serves each shard.  We pin
that exactly (`np.array_equal`, not allclose) on the stacked path and,
in a subprocess with 8 placeholder devices, on the real 2-D shard_map
execution.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.control import MODE_FULL, MODE_STAGE1
from repro.dist.topology import (ComponentTopology, plan_2d, select_replica)
from repro.serve import kv_cache as kvc
from repro.serve.cluster import ClusterConfig, ClusterStepBackend
from repro.serve.engine import (CacheConfig, EngineConfig, ServingEngine,
                                make_requests, run_open_loop)
from repro.serve.fleet import FleetConfig, FleetStepBackend, \
    make_fleet_attention


# -- 2-D placement laws ------------------------------------------------------

def test_plan_2d_grid_laws():
  topo = plan_2d(16, 5, 3, skew=0.7)
  N, R = topo.n_components, topo.replicas
  grid = topo.shard_grid()                       # (R, N) shard at (r, j)
  # Row 0 is the identity (the 1-D cluster layout); row r is row 0
  # rolled right by r.
  assert list(grid[0]) == list(range(N))
  for r in range(R):
    assert np.array_equal(grid[r], np.roll(grid[0], r))
    # Every row is a full partition: all N shards present once.
    assert sorted(grid[r]) == list(range(N))
  # shard_at inverts replica_owner: the r-th copy of shard c lives at
  # column replica_owner(c, r), and that coordinate holds shard c.
  for c in range(N):
    for r in range(R):
      j = topo.replica_owner(c, r)
      assert topo.shard_at(r, j) == c
      assert grid[r, j] == c
  # The R holders of any shard are R *distinct* components.
  owners = topo.replica_owners()
  assert owners.shape == (N, R)
  for c in range(N):
    assert len(set(owners[c].tolist())) == R


def test_plan_rejects_replicas_over_components():
  # The --replicas x --cluster composition bug: R > N would wrap ring
  # copies back onto their own primary.  plan() must reject it BEFORE
  # any layout is built, naming both CLI flags.
  with pytest.raises(ValueError, match=r"--replicas <= +--cluster"):
    ComponentTopology.plan(16, 3, replicas=4)
  with pytest.raises(ValueError, match="replicas"):
    plan_2d(16, 2, 5)
  with pytest.raises(ValueError):
    plan_2d(16, 2, 0)                            # R >= 1 is a grid dim


def test_select_replica_policy():
  t = np.array([[5.0, 1.0, 2.0],
                [1.0, 1.0, 9.0]])
  sel = select_replica(t)
  assert sel.dtype == np.int32
  # Fastest holder per shard; exact ties break to the primary (row 0).
  assert list(sel) == [1, 0, 0]
  # A dead holder is never selected even when fastest.
  alive = np.array([[True, True, True],
                    [False, True, True]])
  assert list(select_replica(t, alive)) == [0, 0, 0]
  # A shard with NO live holder is an error, not a silent fallback.
  alive[:, 2] = False
  with pytest.raises(ValueError, match="no live holder"):
    select_replica(t, alive)
  with pytest.raises(ValueError):
    select_replica(t[0])                         # must be (R, N)


def test_replicate_leaf_materializes_grid():
  # replicate_leaf's row r must hold, at column j, a BIT-IDENTICAL copy
  # of primary shard shard_at(r, j) — the data-movement half of the
  # fleet tier's bit-identity story.
  topo = plan_2d(12, 4, 3)
  x = jnp.asarray(np.random.default_rng(0).normal(
      size=(2, 3, 4, 5)).astype(np.float32))     # component axis 2, N=4
  out = np.asarray(kvc.replicate_leaf(x, topo.replicas, axis=2))
  assert out.shape == (2, 3, 3, 4, 5)            # (. . R N .)
  grid = topo.shard_grid()
  xn = np.asarray(x)
  for r in range(topo.replicas):
    for j in range(topo.n_components):
      assert np.array_equal(out[:, :, r, j], xn[:, :, grid[r, j]])


# -- selection invariance: the gather result cannot depend on fe_replica ----

def _synthetic_fleet_cache(topo, *, B=2, Hkv=2, C=16, D=16, seed=0):
  """A dense synthetic corpus scattered to the fleet layout: cluster-tier
  scatter per leaf, then the replica stack — exactly the engine's
  materializing write, minus the slot axes."""
  M = topo.m_total
  ks = jax.random.split(jax.random.PRNGKey(seed), 8)
  S = M * C
  cache = {
      "k": jax.random.normal(ks[0], (B, Hkv, S, D), jnp.float32),
      "v": jax.random.normal(ks[1], (B, Hkv, S, D), jnp.float32),
      "recent_k": jax.random.normal(ks[2], (B, Hkv, 16, D), jnp.float32),
      "recent_v": jax.random.normal(ks[3], (B, Hkv, 16, D), jnp.float32),
      "recent_len": jnp.full((B,), 5, jnp.int32),
      "counts": jnp.full((B, M), float(C)),
  }
  cache["k_syn"] = cache["k"].reshape(B, Hkv, M, C, D).mean(3)
  cache["v_syn"] = cache["v"].reshape(B, Hkv, M, C, D).mean(3)
  Mp = topo.m_max
  out = {k: cache[k] for k in ("recent_k", "recent_v", "recent_len")}
  for name, unit in (("k", C), ("v", C), ("k_syn", 1), ("v_syn", 1)):
    parts = []
    for c in range(topo.n_components):
      off, cnt = topo.offsets[c] * unit, topo.counts[c] * unit
      sl = cache[name][:, :, off:off + cnt]
      if Mp * unit - cnt:
        sl = jnp.pad(sl, [(0, 0), (0, 0), (0, Mp * unit - cnt), (0, 0)])
      parts.append(sl)
    out[name] = kvc.replicate_leaf(jnp.stack(parts, axis=2),
                                   topo.replicas, axis=2)
  parts = []
  for c in range(topo.n_components):
    sl = cache["counts"][:, topo.offsets[c]:topo.offsets[c]
                         + topo.counts[c]]
    if Mp - topo.counts[c]:
      sl = jnp.pad(sl, [(0, 0), (0, Mp - topo.counts[c])])
    parts.append(sl)
  out["counts"] = kvc.replicate_leaf(jnp.stack(parts, axis=1),
                                     topo.replicas, axis=1)
  kd = jax.random.normal(ks[4], (B, Hkv, 1, D), jnp.float32)
  q = jax.random.normal(ks[5], (B, Hkv * 2, D), jnp.float32)
  return q, out, (kd, kd), C, D


@pytest.mark.parametrize("skew,alloc", [(0.0, "mass"), (1.1, "topk")])
def test_stacked_gather_invariant_to_selection(skew, alloc):
  """Whatever fe_replica says — including mixed FULL/STAGE1 modes and a
  skewed padded partition — the stacked fleet gather equals the
  all-primary gather EXACTLY (np.array_equal, zero ulps)."""
  topo = plan_2d(16, 4, 3, skew=skew)
  N, R = topo.n_components, topo.replicas
  q, csl, self_kv, C, D = _synthetic_fleet_cache(topo, seed=int(skew * 10))
  attn = make_fleet_attention(topo, alloc=alloc, mesh=None)
  sm = float(1.0 / np.sqrt(D))
  mode = np.full((N,), MODE_FULL)
  mode[1] = MODE_STAGE1

  def run(sel):
    c = dict(csl)
    c["fe_mode"] = jnp.asarray(mode, jnp.int32)
    c["fe_replica"] = jnp.asarray(sel, jnp.int32)
    out, aux = attn(q, c, i_max=4, cluster_size=C, sm_scale=sm,
                    self_kv=self_kv, impl="xla")
    return np.asarray(out), np.asarray(aux["fe_cover"])

  ref_out, ref_cover = run(np.zeros(N, np.int32))
  rng = np.random.default_rng(7)
  for _ in range(4):
    sel = rng.integers(0, R, N).astype(np.int32)
    got_out, got_cover = run(sel)
    assert np.array_equal(got_out, ref_out), sel
    assert np.array_equal(got_cover, ref_cover), sel


# -- engine integration ------------------------------------------------------

@pytest.fixture(scope="module")
def fleet_engine():
  cfg = get_config("llama3-8b", smoke=True)
  backend = FleetStepBackend(FleetConfig(
      n_components=2, replicas=2, seed=0, use_mesh=False))
  eng = ServingEngine(cfg, EngineConfig(
      n_slots=2, prompt_len=64, max_new_tokens=3, deadline_ms=60.0,
      policy="accuracytrader", impl="xla"), backend=backend)
  return eng, backend


def test_fleet_engine_end_to_end(fleet_engine):
  eng, backend = fleet_engine
  assert backend.replica_mappings == 2
  assert eng._map_count == 2
  # Every arena leaf grew the R axis; counts at (nb, na, B, R, N, Mp).
  assert eng.cache["counts"].shape[3] == 2
  assert eng.cache["k"].shape[4] == 2
  s = run_open_loop(eng, rate_per_s=30.0, duration_s=0.4, seed=5)
  assert s["n"] > 0 and s["n"] == len(eng.completed)
  for r in eng.completed:
    assert 0.0 <= r.accuracy <= 1.0
    assert all(0.0 <= a <= 1.0 for a in r.step_acc)
  assert backend.predictor.table()


def test_fleet_rejects_resilience_knobs():
  from repro.serve.resilience import FaultSpec
  cfg = get_config("llama3-8b", smoke=True)
  with pytest.raises(ValueError, match="non-resilient"):
    ServingEngine(cfg, EngineConfig(
        n_slots=1, prompt_len=64, max_new_tokens=2, impl="xla"),
        backend=FleetStepBackend(FleetConfig(
            n_components=2, replicas=2, use_mesh=False,
            faults=FaultSpec(crash_rate=0.1))))


def test_fleet_admission_pins_arena_per_replica():
  """One admission maps the arena onto R replica rows and holds R pins
  (miss AND hit paths), so retiring one replica's mapping can never free
  an arena another replica still reads; retirement releases all R."""
  cfg = get_config("llama3-8b", smoke=True)
  Cs = cfg.synopsis.cluster_size
  backend = FleetStepBackend(FleetConfig(
      n_components=2, replicas=2, seed=0, use_mesh=False))
  eng = ServingEngine(cfg, EngineConfig(
      n_slots=2, prompt_len=64, max_new_tokens=2, policy="fixed",
      fixed_budget=1, impl="xla",
      cache=CacheConfig(capacity=4, delta_unit=Cs)), backend=backend)
  eng.reset()
  reqs = make_requests([0.0, 0.0], 64, 2, cfg.vocab, seed=9)
  reqs[1].prompt = reqs[0].prompt.copy()
  eng._admit(reqs[0], 0)                     # miss: publish + R-1 extra pins
  entry = eng.corpus_cache.entries[eng._slot_entry[0]]
  assert entry.refcount == 2                 # R mappings for one slot
  eng._admit(reqs[1], 1)                     # hit: R more pins
  assert entry.refcount == 4
  # The replicated slot lanes the write produced are bit-identical per
  # (replica, shard) coordinate to the primary row.
  topo = backend.topo
  grid = topo.shard_grid()
  for leaf in kvc.ARENA_LEAVES:
    if leaf not in eng.cache:        # scale leaves: quantized arenas only
      continue
    x = np.asarray(eng.cache[leaf])
    ax = 3 if leaf == "counts" else 4        # replica axis after (nb,na,B[,H])
    x = np.moveaxis(x, (ax, ax + 1), (0, 1))  # (R, N, ...)
    assert abs(x).sum() > 0                  # the write really landed
    for r in range(topo.replicas):
      for j in range(topo.n_components):
        assert np.array_equal(x[r, j], x[0, grid[r, j]]), (leaf, r, j)
  eng._retire(0)                             # releases slot 0's R pins
  assert entry.refcount == 2
  eng._retire(1)
  assert entry.refcount == 0                 # unpinned, evictable


def test_fleet_never_worse_than_modelled_hedge():
  """The deterministic accounting gate, in miniature: under the SAME
  seeds and draws, the fleet's realized per-step parallel time (every
  shard at its earliest materialized holder) is <= the cluster tier's
  modelled-hedge time — and EQUAL when the cluster hedges every shard
  (deadline ~ 0 forces reissue everywhere; R=2 rows price identically)."""
  cfg = get_config("llama3-8b", smoke=True)

  def mk(backend):
    eng = ServingEngine(cfg, EngineConfig(
        n_slots=1, prompt_len=64, max_new_tokens=2, policy="basic",
        impl="xla"), backend=backend)
    return eng, backend

  _, fb = mk(FleetStepBackend(FleetConfig(
      n_components=2, replicas=2, seed=0, use_mesh=False)))
  _, cb = mk(ClusterStepBackend(ClusterConfig(
      n_components=2, replicas=2, seed=0, use_mesh=False)))
  for deadline, must_equal in ((1e-6, True), (4.0, False)):
    fb.reseed(1234)
    cb.reseed(1234)
    worse = equal = 0
    for _ in range(32):
      pf = fb.plan_step(1, deadline)
      pc = cb.plan_step(1, deadline)
      af = fb.account(1, 10.0, pf, {}, warming=True)
      ac = cb.account(1, 10.0, pc, {}, warming=True)
      assert af["parallel_ms"] <= ac["parallel_ms"] + 1e-9
      worse += af["parallel_ms"] > ac["parallel_ms"] + 1e-9
      equal += abs(af["parallel_ms"] - ac["parallel_ms"]) <= 1e-9
    assert worse == 0
    if must_equal:                 # all-hedged: identical pricing
      assert equal == 32


# -- shard_map execution (multi-device, subprocess) --------------------------

_FLEET_SHARDED_PROG = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import numpy as np
import jax, jax.numpy as jnp
from repro.control import MODE_FULL, MODE_STAGE1
from repro.dist.topology import make_fleet_mesh, plan_2d
from repro.serve.fleet import make_fleet_attention
from tests.test_fleet import _synthetic_fleet_cache

topo = plan_2d(16, 4, 2, skew=1.1)       # R=2 x N=4 on 8 devices
N, R = topo.n_components, topo.replicas
mesh = make_fleet_mesh(N, R)
assert mesh is not None
q, csl, self_kv, C, D = _synthetic_fleet_cache(topo, seed=3)
sm = float(1.0 / np.sqrt(D))
mode = np.full((N,), MODE_FULL); mode[2] = MODE_STAGE1
sharded = make_fleet_attention(topo, alloc="mass", mesh=mesh)
stacked = make_fleet_attention(topo, alloc="mass", mesh=None)

def run(attn, sel):
    c = dict(csl)
    c["fe_mode"] = jnp.asarray(mode, jnp.int32)
    c["fe_replica"] = jnp.asarray(sel, jnp.int32)
    out, aux = attn(q, c, i_max=4, cluster_size=C, sm_scale=sm,
                    self_kv=self_kv, impl="xla")
    return np.asarray(out), np.asarray(aux["fe_cover"])

rng = np.random.default_rng(11)
ref_out, ref_cover = run(stacked, np.zeros(N, np.int32))
err = 0.0
for _ in range(3):
    sel = rng.integers(0, R, N).astype(np.int32)
    got_out, got_cover = run(sharded, sel)
    err = max(err, float(np.abs(got_out - ref_out).max()),
              float(np.abs(got_cover - ref_cover).max()))
print("RESULT:" + json.dumps({"err": err}))
"""


@pytest.mark.slow
@pytest.mark.subprocess
def test_sharded_fleet_equals_stacked_bitwise():
  """The 2-D shard_map execution (8 placeholder devices = R2 x N4 mesh)
  must equal the stacked all-primary gather EXACTLY — the replica copies
  are bit-identical and both paths fold in fixed shard order, so the
  tolerance is zero, not epsilon."""
  import json
  import subprocess
  import sys
  env = dict(os.environ)
  env["PYTHONPATH"] = "src:" + os.path.dirname(os.path.dirname(__file__))
  p = subprocess.run([sys.executable, "-c", _FLEET_SHARDED_PROG],
                     capture_output=True, text=True, env=env, timeout=600,
                     cwd=os.path.dirname(os.path.dirname(__file__)))
  assert p.returncode == 0, p.stderr[-3000:]
  line = [l for l in p.stdout.splitlines() if l.startswith("RESULT:")][0]
  assert json.loads(line[len("RESULT:"):])["err"] == 0.0
