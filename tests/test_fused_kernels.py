"""Fused-kernel parity: the single-pass score+synopsis kernel and the
decremental block-gather epilogue (Pallas interpret mode) must reproduce
the unfused ref.py composition (synopsis_score_ref + masked
flash_decode_ref + block_gather_attention_ref + merges), including padded
``selected = -1`` entries, the log(count) bias, softcap, and the
recent/self extras.  Plus: the serve step itself must agree between
``impl="pallas"`` (interpret) and ``impl="xla"`` on float32."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

SHAPES = [
    # (B, Hkv, G, D, S, C)
    (1, 1, 1, 128, 512, 64),
    (2, 4, 2, 128, 2048, 128),
    (2, 2, 8, 64, 1024, 128),
]


def _mk(shape, dtype=jnp.float32, seed=0, centroids=True):
  B, Hkv, G, D, S, C = shape
  H, M = Hkv * G, S // C
  ks = jax.random.split(jax.random.PRNGKey(seed), 6)
  q = jax.random.normal(ks[0], (B, H, D), dtype)
  k = jax.random.normal(ks[1], (B, Hkv, S, D), dtype)
  v = jax.random.normal(ks[2], (B, Hkv, S, D), dtype)
  if centroids:
    k_syn = k.reshape(B, Hkv, M, C, D).mean(3)
    v_syn = v.reshape(B, Hkv, M, C, D).mean(3)
  else:
    k_syn = jax.random.normal(ks[3], (B, Hkv, M, D), dtype)
    v_syn = jax.random.normal(ks[4], (B, Hkv, M, D), dtype)
  counts = jnp.full((B, M), float(C), jnp.float32)
  return q, k, v, k_syn, v_syn, counts


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("cap", [None, 30.0])
def test_fused_stage1_matches_score_plus_decode_refs(shape, cap):
  """One fused pass == score kernel + count-biased flash decode."""
  q, _, _, k_syn, v_syn, counts = _mk(shape)
  sm = float(1.0 / np.sqrt(q.shape[-1]))
  cbias = ops.count_bias(counts)
  scores, (o, m, l) = ops.synopsis_stage1(
      q, k_syn, v_syn, counts, sm_scale=sm, cap=cap, impl="interpret")
  want_scores = ref.synopsis_score_ref(q, k_syn, sm_scale=sm)
  bias = jnp.broadcast_to(cbias[:, None, :],
                          (q.shape[0], k_syn.shape[1], k_syn.shape[2]))
  want = ref.flash_decode_ref(q, k_syn, v_syn, bias, sm_scale=sm, cap=cap)
  np.testing.assert_allclose(np.asarray(scores), np.asarray(want_scores),
                             rtol=2e-5, atol=2e-5)
  for g, w in zip((o, m, l), want):
    np.testing.assert_allclose(np.asarray(g, np.float32),
                               np.asarray(w, np.float32),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("i_max", [1, 4])
def test_fused_pipeline_matches_unfused_composition(shape, i_max):
  """merge(stage1, stage2-with-decrement) == the unfused masked-bias
  composition (the paper algebra as ops.synopsis_attention computes it)."""
  q, k, v, k_syn, v_syn, counts = _mk(shape)
  sm = float(1.0 / np.sqrt(q.shape[-1]))
  want = ops.synopsis_attention(q, k, v, k_syn, v_syn, counts,
                                i_max=i_max, sm_scale=sm, impl="xla")
  for impl in ("xla", "interpret"):
    got = ops.synopsis_attention_fused(q, k, v, k_syn, v_syn, counts,
                                       i_max=i_max, sm_scale=sm, impl=impl)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_fused_full_budget_is_exact():
  q, k, v, k_syn, v_syn, counts = _mk(SHAPES[1])
  M = counts.shape[1]
  sm = float(1.0 / np.sqrt(q.shape[-1]))
  want = ref.exact_attention_ref(q, k, v, sm_scale=sm)
  for impl in ("xla", "interpret"):
    got = ops.synopsis_attention_fused(q, k, v, k_syn, v_syn, counts,
                                       i_max=M, sm_scale=sm, impl=impl)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("cap", [None, 20.0])
def test_stage2_padded_selected_and_counts_bias(cap):
  """Decremental stage 2 with -1 padding == masked-bias references on the
  same selection; the counts bias must weight exactly the subtracted
  centroid terms (wrong counts => mismatch vs masked composition)."""
  B, Hkv, G, D, S, C = 2, 2, 2, 64, 1024, 128
  q, k, v, k_syn, v_syn, _ = _mk((B, Hkv, G, D, S, C), centroids=False)
  M = S // C
  counts = jnp.asarray(
      np.random.default_rng(0).integers(1, C + 1, (B, M)), jnp.float32)
  sm = float(1.0 / np.sqrt(D))
  # Distinct ids per (b, h) — like lax.top_k produces (a duplicate would
  # double-subtract its centroid; selection sets are sets by contract).
  perm = jnp.stack([
      jnp.stack([jax.random.permutation(jax.random.PRNGKey(6 + 7 * b + h),
                                        M)[:5] for h in range(Hkv)])
      for b in range(B)]).astype(jnp.int32)
  sel = perm.at[:, :, -1].set(-1)         # padded entry
  ks = jax.random.split(jax.random.PRNGKey(9), 3)
  ek = jax.random.normal(ks[0], (B, Hkv, 16, D), jnp.float32)
  ev = jax.random.normal(ks[1], (B, Hkv, 16, D), jnp.float32)
  eb = jnp.where(jnp.arange(16)[None, :] < 9, 0.0, ops.NEG_INF)
  eb = jnp.broadcast_to(eb, (B, 16))

  # Fused: stage1 over all centroids, stage2 decrements the selection.
  _, p_syn = ops.synopsis_stage1(q, k_syn, v_syn, counts, sm_scale=sm,
                                 cap=cap, impl="interpret")
  p_ref = ops.refine_stage2(q, k, v, sel, k_syn, v_syn, counts,
                            cluster_size=C, sm_scale=sm, cap=cap,
                            impl="interpret", extras=(ek, ev, eb))
  got = ops.merge_partials(p_syn, p_ref)

  # Unfused masked-bias reference on the same selection.
  sel_onehot = jnp.any(
      jax.nn.one_hot(sel, M, dtype=jnp.bool_)
      & (sel >= 0)[..., None], axis=2)
  syn_bias = jnp.where(sel_onehot, ops.NEG_INF,
                       ops.count_bias(counts)[:, None, :])
  w_syn = ref.flash_decode_ref(q, k_syn, v_syn, syn_bias, sm_scale=sm,
                               cap=cap)
  # block_gather ref has no cap: fold the tokens via flash ref with a
  # selection bias over the full cache instead.
  starts = jnp.maximum(sel, 0) * C
  idx = (starts[..., None] + jnp.arange(C)).reshape(B, Hkv, -1)
  valid = jnp.repeat(sel >= 0, C, axis=-1)
  btok = jnp.zeros((B, Hkv, S), jnp.bool_)
  bidx = jnp.where(valid, idx, 0)
  btok = jax.vmap(jax.vmap(lambda m, i, va: m.at[i].max(va)))(
      btok, bidx, valid)
  tok_bias = jnp.where(btok, 0.0, ops.NEG_INF)
  w_tok = ref.flash_decode_ref(q, k, v, tok_bias, sm_scale=sm, cap=cap)
  w_ext = ref.flash_decode_ref(q, ek, ev, jnp.broadcast_to(
      eb[:, None, :], (B, Hkv, 16)), sm_scale=sm, cap=cap)
  want = ref.merge_partials(ref.merge_partials(w_syn, w_tok), w_ext)

  np.testing.assert_allclose(np.asarray(got[0]), np.asarray(want[0]),
                             rtol=5e-5, atol=5e-5)


def test_refine_stage2_valid_mask_matches_minus_one_padding():
  """The sharded path's ownership mask == literal -1 padding."""
  B, Hkv, G, D, S, C = 2, 2, 2, 64, 512, 64
  q, k, v, k_syn, v_syn, counts = _mk((B, Hkv, G, D, S, C))
  sm = float(1.0 / np.sqrt(D))
  M = S // C
  sel = jax.random.randint(jax.random.PRNGKey(3), (B, Hkv, 4), 0,
                           M).astype(jnp.int32)
  valid = jax.random.bernoulli(jax.random.PRNGKey(4), 0.5, sel.shape)
  a = ops.refine_stage2(q, k, v, sel, k_syn, v_syn, counts,
                        cluster_size=C, sm_scale=sm, impl="interpret",
                        valid=valid)
  b = ops.refine_stage2(q, k, v, jnp.where(valid, sel, -1), k_syn, v_syn,
                        counts, cluster_size=C, sm_scale=sm,
                        impl="interpret")
  for x, y in zip(a, b):
    np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=1e-6,
                               atol=1e-6)


def test_serve_step_pallas_interpret_matches_xla_float32():
  """The whole serve step (layer scan included) agrees between the Pallas
  kernels (interpret) and the XLA reference path on float32."""
  from repro.configs.registry import get_config
  from repro.models import common as cm
  from repro.models import transformer as tf
  from repro.serve import synopsis_kv as skv
  from repro.serve.prefill import make_prefill_step
  from repro.serve.serve_step import make_serve_step

  cfg = get_config("llama3-8b", smoke=True)
  cfg = dataclasses.replace(cfg, dtype=jnp.float32)
  B, S = 2, 128
  params, _ = cm.split(tf.init_model(jax.random.PRNGKey(0), cfg))
  params = jax.tree.map(lambda p: p.astype(cfg.dtype), params)
  tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
  _, cache = jax.jit(make_prefill_step(cfg))(params, tokens)
  syn_cache = jax.jit(lambda c: skv.build(c, cfg))(cache)
  nt = jax.random.randint(jax.random.PRNGKey(2), (B, 1), 0, cfg.vocab)

  for mode, cache_ in (("synopsis", syn_cache), ("exact", cache)):
    lg_x, st_x = jax.jit(make_serve_step(cfg, mode=mode, i_max=2,
                                         impl="xla"))(params, cache_, nt)
    lg_p, st_p = jax.jit(make_serve_step(cfg, mode=mode, i_max=2,
                                         impl="interpret"))(params, cache_,
                                                            nt)
    np.testing.assert_allclose(np.asarray(lg_p), np.asarray(lg_x),
                               rtol=1e-5, atol=1e-5)
    for kk in st_x:
      # deltas of layer n depend on layer n-1's attention output, so
      # f32 noise propagates across the scan — keep the logits bound.
      np.testing.assert_allclose(np.asarray(st_p[kk], np.float32),
                                 np.asarray(st_x[kk], np.float32),
                                 rtol=1e-5, atol=1e-5)


def test_serve_step_no_materialized_gather():
  """Acceptance guard: serve_step must not define/use the materialized
  cluster-gather helper anymore (the Pallas path streams blocks; the XLA
  gather lives behind the ops facade)."""
  import inspect
  from repro.serve import serve_step as ss
  src = inspect.getsource(ss)
  assert "_gather_clusters" not in src
  assert not hasattr(ss, "_gather_clusters")
