"""Per-kernel shape/dtype sweeps: Pallas interpret mode vs ref.py oracle."""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

SHAPES = [
    # (B, Hkv, G, D, S, C)
    (1, 1, 1, 128, 512, 64),
    (2, 4, 2, 128, 2048, 128),
    (2, 2, 8, 64, 1024, 128),
    (4, 8, 4, 128, 1024, 64),
]
DTYPES = [jnp.float32, jnp.bfloat16]


def _mk(shape, dtype, seed=0):
  B, Hkv, G, D, S, C = shape
  H, M = Hkv * G, S // C
  ks = jax.random.split(jax.random.PRNGKey(seed), 6)
  q = jax.random.normal(ks[0], (B, H, D), dtype)
  k = jax.random.normal(ks[1], (B, Hkv, S, D), dtype)
  v = jax.random.normal(ks[2], (B, Hkv, S, D), dtype)
  k_syn = jax.random.normal(ks[3], (B, Hkv, M, D), dtype)
  v_syn = jax.random.normal(ks[4], (B, Hkv, M, D), dtype)
  counts = jnp.full((B, M), float(C), jnp.float32)
  return q, k, v, k_syn, v_syn, counts


def _tol(dtype):
  return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
      dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("shape,dtype",
                         list(itertools.product(SHAPES, DTYPES)))
def test_flash_decode_vs_ref(shape, dtype):
  q, k, v, *_ = _mk(shape, dtype)
  sm = float(1.0 / np.sqrt(q.shape[-1]))
  bias = jax.random.normal(jax.random.PRNGKey(5),
                           (q.shape[0], k.shape[1], k.shape[2]))
  for b in (None, bias):
    got = ops._decode(q, k, v, b, sm, "interpret")
    want = ref.flash_decode_ref(q, k, v, b, sm_scale=sm)
    for g, w in zip(got, want):
      np.testing.assert_allclose(np.asarray(g, np.float32),
                                 np.asarray(w, np.float32), **_tol(dtype))


@pytest.mark.parametrize("shape,dtype",
                         list(itertools.product(SHAPES, DTYPES)))
def test_synopsis_score_vs_ref(shape, dtype):
  q, _, _, k_syn, _, _ = _mk(shape, dtype)
  sm = float(1.0 / np.sqrt(q.shape[-1]))
  got = ops._scores(q, k_syn, sm, "interpret")
  want = ref.synopsis_score_ref(q, k_syn, sm_scale=sm)
  np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                             **_tol(dtype))


@pytest.mark.parametrize("shape,dtype",
                         list(itertools.product(SHAPES, DTYPES)))
def test_block_gather_vs_ref(shape, dtype):
  B, Hkv, G, D, S, C = shape
  q, k, v, *_ = _mk(shape, dtype)
  M = S // C
  sel = jax.random.randint(jax.random.PRNGKey(6), (B, Hkv, min(5, M)), 0,
                           M).astype(jnp.int32)
  sel = sel.at[:, :, -1].set(-1)          # padded entry
  sm = float(1.0 / np.sqrt(D))
  got = ops._gather(q, k, v, sel, C, sm, "interpret")
  want = ref.block_gather_attention_ref(q, k, v, sel, cluster_size=C,
                                        sm_scale=sm)
  for g, w in zip(got, want):
    np.testing.assert_allclose(np.asarray(g, np.float32),
                               np.asarray(w, np.float32), **_tol(dtype))


@pytest.mark.parametrize("shape", SHAPES[:2])
def test_synopsis_attention_full_budget_is_exact(shape):
  q, k, v, _, _, counts = _mk(shape, jnp.float32)
  M = counts.shape[1]
  C = k.shape[2] // M
  # true centroids (means) so the synopsis is consistent with the data
  k_syn = k.reshape(*k.shape[:2], M, C, -1).mean(3)
  v_syn = v.reshape(*v.shape[:2], M, C, -1).mean(3)
  sm = float(1.0 / np.sqrt(q.shape[-1]))
  out = ops.synopsis_attention(q, k, v, k_syn, v_syn, counts, i_max=M,
                               sm_scale=sm, impl="xla")
  want = ref.exact_attention_ref(q, k, v, sm_scale=sm)
  np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                             rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("i_max", [0, 1, 4])
def test_synopsis_attention_xla_matches_interpret(i_max):
  shape = SHAPES[1]
  q, k, v, k_syn, v_syn, counts = _mk(shape, jnp.float32)
  sm = float(1.0 / np.sqrt(q.shape[-1]))
  if i_max == 0:
    i_max = 1   # kernels need >= 1 selected block
  a = ops.synopsis_attention(q, k, v, k_syn, v_syn, counts, i_max=i_max,
                             sm_scale=sm, impl="xla")
  b = ops.synopsis_attention(q, k, v, k_syn, v_syn, counts, i_max=i_max,
                             sm_scale=sm, impl="interpret")
  np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                             rtol=2e-5, atol=2e-5)


def test_merge_partials_associative():
  ks = jax.random.split(jax.random.PRNGKey(0), 9)
  parts = []
  for i in range(3):
    o = jax.random.normal(ks[3 * i], (2, 4, 8))
    m = jax.random.normal(ks[3 * i + 1], (2, 4))
    l = jax.random.uniform(ks[3 * i + 2], (2, 4)) + 0.1
    parts.append((o, m, l))
  ab_c = ref.merge_partials(ref.merge_partials(parts[0], parts[1]),
                            parts[2])
  a_bc = ref.merge_partials(parts[0],
                            ref.merge_partials(parts[1], parts[2]))
  for x, y in zip(ab_c, a_bc):
    np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=1e-5,
                               atol=1e-5)


def test_merge_partials_equals_joint_softmax():
  """Splitting a key set and merging partials == one softmax."""
  ks = jax.random.split(jax.random.PRNGKey(1), 3)
  q = jax.random.normal(ks[0], (2, 4, 32))
  k = jax.random.normal(ks[1], (2, 2, 64, 32))
  v = jax.random.normal(ks[2], (2, 2, 64, 32))
  whole = ref.flash_decode_ref(q, k, v)
  left = ref.flash_decode_ref(q, k[:, :, :40], v[:, :, :40])
  right = ref.flash_decode_ref(q, k[:, :, 40:], v[:, :, 40:])
  merged = ref.merge_partials(left, right)
  for g, w in zip(merged, whole):
    np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-5,
                               atol=1e-5)
