"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes + no NaNs (assignment requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config, list_archs
from repro.configs.shapes import SHAPES, input_specs
from repro.models import common as cm
from repro.models import transformer as tf
from repro.serve import kv_cache as kvc
from repro.serve.prefill import make_prefill_step
from repro.serve.serve_step import make_serve_step
from repro.train.optimizer import OptConfig
from repro.train.train_step import init_train_state, make_train_step

ARCHS = list_archs()
B, S = 2, 32


def _batch(cfg, key=jax.random.PRNGKey(0)):
  tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
  labels = jnp.roll(tokens, -1, axis=1)
  fe = None
  if cfg.frontend == "vision_stub":
    fe = jnp.ones((B, cfg.frontend_tokens, cfg.frontend_dim), jnp.bfloat16)
  if cfg.encoder is not None:
    fe = jnp.ones((B, cfg.encoder.source_len, cfg.frontend_dim),
                  jnp.bfloat16)
  return tokens, labels, fe


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
  cfg = get_config(arch, smoke=True)
  params, _ = cm.split(tf.init_model(jax.random.PRNGKey(0), cfg))
  tokens, labels, fe = _batch(cfg)
  h, aux = tf.hidden_states(params, cfg, tokens, fe)
  text = S + (cfg.frontend_tokens if cfg.frontend == "vision_stub" else 0)
  assert h.shape == (B, text, cfg.d_model)
  assert np.isfinite(np.asarray(h, np.float32)).all()


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_finite(arch):
  cfg = get_config(arch, smoke=True)
  opt_cfg = OptConfig(total_steps=10)
  state, _ = init_train_state(jax.random.PRNGKey(0), cfg, opt_cfg)
  tokens, labels, fe = _batch(cfg)
  batch = {"tokens": tokens, "labels": labels}
  if fe is not None:
    batch["frontend_embeds"] = fe
  step = jax.jit(make_train_step(cfg, opt_cfg))
  state2, metrics = step(state, batch)
  assert np.isfinite(float(metrics["loss"]))
  assert np.isfinite(float(metrics["grad_norm"]))
  # params actually changed
  d0 = jax.tree.leaves(state["params"])[0]
  d1 = jax.tree.leaves(state2["params"])[0]
  assert not np.allclose(np.asarray(d0), np.asarray(d1))


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_exact_finite(arch):
  cfg = get_config(arch, smoke=True)
  params, _ = cm.split(tf.init_model(jax.random.PRNGKey(0), cfg))
  params = jax.tree.map(lambda p: p.astype(cfg.dtype), params)
  cache = kvc.init_cache(cfg, B, 64, synopsis=False)
  step = jax.jit(make_serve_step(cfg, mode="exact"))
  logits, new_state = step(params, cache,
                           jnp.zeros((B, 1), jnp.int32))
  assert logits.shape == (B, cfg.vocab)
  assert np.isfinite(np.asarray(logits, np.float32)).all()
  assert int(new_state["pos"][0]) == 65


@pytest.mark.parametrize("arch", [a for a in ARCHS if a != "mamba2-370m"])
def test_decode_synopsis_finite(arch):
  cfg = get_config(arch, smoke=True)
  params, _ = cm.split(tf.init_model(jax.random.PRNGKey(0), cfg))
  params = jax.tree.map(lambda p: p.astype(cfg.dtype), params)
  cache = kvc.init_cache(cfg, B, 64, synopsis=True)
  step = jax.jit(make_serve_step(cfg, mode="synopsis", i_max=2))
  logits, _ = step(params, cache, jnp.zeros((B, 1), jnp.int32))
  assert logits.shape == (B, cfg.vocab)
  assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize("arch", ["llama3-8b", "whisper-medium",
                                  "jamba-v0.1-52b", "deepseek-v2-236b"])
def test_prefill_emits_cache(arch):
  cfg = get_config(arch, smoke=True)
  params, _ = cm.split(tf.init_model(jax.random.PRNGKey(0), cfg))
  params = jax.tree.map(lambda p: p.astype(cfg.dtype), params)
  tokens, _, fe = _batch(cfg)
  logits, cache = jax.jit(make_prefill_step(cfg))(params, tokens, fe)
  assert logits.shape == (B, cfg.vocab)
  na = kvc.n_attn_positions(cfg)
  if na:
    text = S + (cfg.frontend_tokens
                if cfg.frontend == "vision_stub" else 0)
    assert cache["k"].shape[0] == cfg.n_blocks
    assert cache["k"].shape[4] == text
  if kvc.n_ssm_positions(cfg):
    assert "ssd_state" in cache


def test_full_configs_match_assignment():
  expect = {
      "command-r-plus-104b": (64, 12288, 96, 8, 33792, 256000),
      "gemma2-2b": (26, 2304, 8, 4, 9216, 256000),
      "llama3-8b": (32, 4096, 32, 8, 14336, 128256),
      "smollm-135m": (30, 576, 9, 3, 1536, 49152),
      "pixtral-12b": (40, 5120, 32, 8, 14336, 131072),
      "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536),
      "deepseek-v2-236b": (60, 5120, 128, 128, 0, 102400),
      "arctic-480b": (35, 7168, 56, 8, 4864, 32000),
      "whisper-medium": (24, 1024, 16, 16, 4096, 51865),
      "mamba2-370m": (48, 1024, 1, 1, 0, 50280),
  }
  for arch, (L, d, H, Hkv, ff, V) in expect.items():
    c = get_config(arch)
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (L, d, H, Hkv, ff, V), arch
  assert get_config("deepseek-v2-236b").moe.num_experts == 160
  assert get_config("deepseek-v2-236b").moe.top_k == 6
  assert get_config("deepseek-v2-236b").mla.kv_lora_rank == 512
  assert get_config("arctic-480b").moe.num_experts == 128
  assert get_config("jamba-v0.1-52b").moe.num_experts == 16
  assert get_config("mamba2-370m").ssm.d_state == 128


def test_shapes_table():
  assert SHAPES["train_4k"].seq_len == 4096
  assert SHAPES["train_4k"].global_batch == 256
  assert SHAPES["prefill_32k"].global_batch == 32
  assert SHAPES["decode_32k"].global_batch == 128
  assert SHAPES["long_500k"].seq_len == 524288
  cfg = get_config("llama3-8b")
  sp = input_specs(cfg, SHAPES["train_4k"])
  assert sp["tokens"].shape == (256, 4096)
