"""Prefill-side kernel parity (Pallas interpret mode vs XLA references):

* flash-prefill attention vs the chunked causal GQA oracle — causal mask
  edges, softcap, sliding window, GQA head ratios (G = 1/2/4/8), and
  ragged final blocks (S not a multiple of block_q/block_k);
* the fused synopsis-build (permute + segment-mean) kernel vs the
  take_along_axis -> reshape-mean chain, including the full
  ``synopsis_kv.build`` / ``absorb_recent`` paths and the end-to-end
  prefill step.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_prefill import flash_prefill

TOL = dict(rtol=1e-5, atol=1e-5)

PREFILL_SHAPES = [
    # (B, S, Hkv, G, D) — S=192/100 exercise the ragged final block
    # against block_q=block_k=128 (and S < block for 100).
    (1, 128, 1, 1, 64),
    (2, 192, 2, 4, 64),
    (1, 100, 2, 2, 128),
    (2, 256, 4, 1, 128),
    (1, 256, 1, 8, 64),
]


def _mk_prefill(shape, seed=0):
  B, S, Hkv, G, D = shape
  H = Hkv * G
  ks = jax.random.split(jax.random.PRNGKey(seed), 3)
  q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
  k = jax.random.normal(ks[1], (B, S, Hkv, D), jnp.float32)
  v = jax.random.normal(ks[2], (B, S, Hkv, D), jnp.float32)
  return q, k, v, float(1.0 / np.sqrt(D))


@pytest.mark.parametrize("shape", PREFILL_SHAPES)
@pytest.mark.parametrize("cap", [None, 30.0])
def test_flash_prefill_matches_ref(shape, cap):
  q, k, v, sm = _mk_prefill(shape)
  got = flash_prefill(q, k, v, sm_scale=sm, cap=cap, block_q=128,
                      block_k=128, interpret=True)
  want = ref.flash_prefill_ref(q, k, v, sm_scale=sm, cap=cap)
  np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)


@pytest.mark.parametrize("window", [32, 64])
def test_flash_prefill_sliding_window(window):
  q, k, v, sm = _mk_prefill((2, 192, 2, 2, 64))
  got = flash_prefill(q, k, v, sm_scale=sm, window=window, block_q=64,
                      block_k=64, interpret=True)
  want = ref.flash_prefill_ref(q, k, v, sm_scale=sm, window=window)
  np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)


def test_flash_prefill_causal_edges():
  """Row 0 attends only to itself; row i to keys [0, i] — checked against
  a per-row numpy oracle at a size where blocks split mid-sequence."""
  q, k, v, sm = _mk_prefill((1, 48, 1, 2, 64))
  got = np.asarray(flash_prefill(q, k, v, sm_scale=sm, block_q=32,
                                 block_k=32, interpret=True))
  qn, kn, vn = (np.asarray(x, np.float64) for x in (q, k, v))
  B, S, H, D = qn.shape
  for i in range(S):
    logits = np.einsum("hd,khd->hk", qn[0, i], kn[0, :i + 1]) * sm
    p = np.exp(logits - logits.max(-1, keepdims=True))
    p /= p.sum(-1, keepdims=True)
    want = np.einsum("hk,khd->hd", p, vn[0, :i + 1])
    np.testing.assert_allclose(got[0, i], want, rtol=1e-5, atol=1e-5)
  # Row 0 == v[0] exactly (softmax over a single key; both query heads of
  # the group see the same single KV row).
  np.testing.assert_allclose(
      got[0, 0], np.broadcast_to(vn[0, 0], got[0, 0].shape),
      rtol=1e-5, atol=1e-5)


def test_prefill_attention_facade_impl_parity():
  q, k, v, sm = _mk_prefill((2, 160, 2, 2, 64))
  want = ops.prefill_attention(q, k, v, sm_scale=sm, cap=20.0, impl="xla")
  got = ops.prefill_attention(q, k, v, sm_scale=sm, cap=20.0,
                              impl="interpret")
  np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)


# ---------------------------------------------------------------------------
# Synopsis build (fused permute + segment-mean)
# ---------------------------------------------------------------------------

def _mk_cache(N=2, Hkv=2, S=128, D=64, seed=1):
  ks = jax.random.split(jax.random.PRNGKey(seed), 3)
  k = jax.random.normal(ks[0], (N, Hkv, S, D), jnp.float32)
  v = jax.random.normal(ks[1], (N, Hkv, S, D), jnp.float32)
  perm = jnp.stack([jax.random.permutation(jax.random.fold_in(ks[2], n), S)
                    for n in range(N)]).astype(jnp.int32)
  return k, v, perm


@pytest.mark.parametrize("C", [32, 64])
def test_synopsis_build_matches_unfused_chain(C):
  """Fused kernel == the take_along_axis -> reshape-mean chain (the exact
  math the previous synopsis_kv.build ran)."""
  k, v, perm = _mk_cache()
  N, Hkv, S, D = k.shape
  M = S // C
  idx = jnp.broadcast_to(perm[:, None, :, None], (N, Hkv, S, 1))
  ks_want = jnp.take_along_axis(k, idx, axis=2)
  vs_want = jnp.take_along_axis(v, idx, axis=2)
  ksyn_want = ks_want.reshape(N, Hkv, M, C, D).mean(3)
  vsyn_want = vs_want.reshape(N, Hkv, M, C, D).mean(3)
  cnt_want = jnp.full((N, M), float(C), jnp.float32)
  for impl in ("xla", "interpret"):
    got = ops.synopsis_build(k, v, perm, cluster_size=C, impl=impl)
    for g, w in zip(got, (ks_want, vs_want, ksyn_want, vsyn_want,
                          cnt_want)):
      np.testing.assert_allclose(np.asarray(g), np.asarray(w), **TOL)


def test_synopsis_build_identity_perm_is_reshape_mean():
  """absorb_recent's usage: identity permutation == plain segment mean."""
  k, v, _ = _mk_cache(N=1, S=64)
  C = 16
  ident = jnp.broadcast_to(jnp.arange(64, dtype=jnp.int32), (1, 64))
  ks, vs, ksyn, vsyn, _ = ops.synopsis_build(k, v, ident, cluster_size=C,
                                             impl="interpret")
  np.testing.assert_allclose(np.asarray(ks), np.asarray(k), **TOL)
  np.testing.assert_allclose(
      np.asarray(ksyn), np.asarray(k.reshape(1, 2, 4, C, 64).mean(3)),
      **TOL)
  np.testing.assert_allclose(
      np.asarray(vsyn), np.asarray(v.reshape(1, 2, 4, C, 64).mean(3)),
      **TOL)


def _smoke_cfg():
  from repro.configs.registry import get_config
  cfg = get_config("llama3-8b", smoke=True)
  return dataclasses.replace(cfg, dtype=jnp.float32)


def _prefill_cache(cfg, B=2, S=64):
  from repro.models import common as cm
  from repro.models import transformer as tf
  from repro.serve.prefill import make_prefill_step
  params, _ = cm.split(tf.init_model(jax.random.PRNGKey(0), cfg))
  params = jax.tree.map(lambda p: p.astype(cfg.dtype), params)
  tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
  caches = {}
  logits = {}
  for impl in ("xla", "interpret"):
    logits[impl], caches[impl] = jax.jit(
        make_prefill_step(cfg, impl=impl))(params, tokens)
  return params, logits, caches


def test_prefill_step_impl_parity():
  """The whole prefill step (layer scan included) agrees between the
  Pallas kernels (interpret) and the XLA reference path on float32."""
  cfg = _smoke_cfg()
  _, logits, caches = _prefill_cache(cfg)
  np.testing.assert_allclose(np.asarray(logits["interpret"]),
                             np.asarray(logits["xla"]), **TOL)
  for kk in caches["xla"]:
    np.testing.assert_allclose(
        np.asarray(caches["interpret"][kk], np.float32),
        np.asarray(caches["xla"][kk], np.float32), **TOL)


def test_build_and_absorb_impl_parity():
  """synopsis_kv.build / absorb_recent agree across impls on the same
  prefilled cache (clustering is shared; the aggregation path differs)."""
  from repro.serve import synopsis_kv as skv
  cfg = _smoke_cfg()
  _, _, caches = _prefill_cache(cfg)
  cache = caches["xla"]
  syn = {impl: jax.jit(lambda c, im=impl: skv.build(c, cfg, impl=im))(cache)
         for impl in ("xla", "interpret")}
  for kk in syn["xla"]:
    np.testing.assert_allclose(
        np.asarray(syn["interpret"][kk], np.float32),
        np.asarray(syn["xla"][kk], np.float32), err_msg=kk, **TOL)

  # Fill the recent ring buffer, then absorb it on both impls.
  filled = syn["xla"]
  nb, na, B, Hkv, R, D = filled["recent_k"].shape
  rk = jax.random.normal(jax.random.PRNGKey(7), (nb, na, B, Hkv, R, D),
                         jnp.float32)
  rv = jax.random.normal(jax.random.PRNGKey(8), (nb, na, B, Hkv, R, D),
                         jnp.float32)
  filled = {**filled, "recent_k": rk, "recent_v": rv,
            "recent_len": jnp.full((B,), R, jnp.int32)}
  out = {impl: jax.jit(lambda c, im=impl: skv.absorb_recent(
      c, cfg, impl=im))(filled) for impl in ("xla", "interpret")}
  assert out["xla"]["k_syn"].shape[4] > syn["xla"]["k_syn"].shape[4]
  for kk in out["xla"]:
    np.testing.assert_allclose(
        np.asarray(out["interpret"][kk], np.float32),
        np.asarray(out["xla"][kk], np.float32), err_msg=kk, **TOL)
