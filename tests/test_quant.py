"""Quantized synopsis tests (DESIGN.md §15): round-trip error bounds,
bit-exact interpret-vs-XLA build parity (deterministic round-to-nearest
— no stochastic rounding precisely so distinct lowerings agree on the
encoded integers), quantized stage-1/stage-2 kernel parity including
selected=-1 pads, the e2e fused deviation bound at full refinement
coverage, the cache-struct/arena plumbing of the scale leaves, corpus
fingerprint separation, the engine accuracy floor, and fleet R=2
refcount conservation with a quantized arena.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.kernels import ops, quant, ref
from repro.serve import corpus_cache as cc
from repro.serve import kv_cache as kvc
from repro.serve.engine import (CacheConfig, EngineConfig, ServingEngine,
                                make_requests)


def _quant_cfg(cfg, spec):
  return dataclasses.replace(
      cfg, synopsis=dataclasses.replace(cfg.synopsis, quant=spec))


# -- quant.py core -----------------------------------------------------------

def test_parse_qconfig_specs():
  qc = quant.parse_qconfig(None)
  assert qc.kind == "none" and not qc.enabled and not qc.sorted_kv
  assert quant.parse_qconfig("none") == qc
  qc = quant.parse_qconfig("int8")
  assert qc.kind == "int8" and qc.enabled and not qc.sorted_kv
  assert qc.spec == "int8"
  qc = quant.parse_qconfig("int8+kv")
  assert qc.kind == "int8" and qc.enabled and qc.sorted_kv
  assert qc.spec == "int8+kv"
  # Idempotent on QuantConfig, and exhaustive over QSPECS.
  assert quant.parse_qconfig(qc) == qc
  for spec in quant.QSPECS:
    assert quant.parse_qconfig(spec).spec == spec
  with pytest.raises(ValueError, match="quant"):
    quant.parse_qconfig("int4")


def test_roundtrip_bound_int8():
  rng = np.random.default_rng(0)
  x = jnp.asarray(rng.normal(size=(3, 5, 8, 64)).astype(np.float32) * 7)
  q, s = quant.quantize_rows(x, "int8")
  assert q.dtype == jnp.int8 and s.shape == x.shape[:-1]
  back = quant.dequantize_rows(q, s)
  # Symmetric absmax round-to-nearest: per-element error <= scale/2.
  err = np.abs(np.asarray(back) - np.asarray(x))
  bound = np.asarray(s)[..., None] * 0.5 + 1e-6
  assert (err <= bound).all()
  # Block quantization (one scale per C-row block) with C rows.
  qb, sb = quant.quantize_rows(x, "int8", block=4)
  assert sb.shape == x.shape[:-2] + (x.shape[-2] // 4,)
  backb = quant.dequantize_rows(qb, sb, block=4)
  sb_rows = np.repeat(np.asarray(sb), 4, axis=-1)
  errb = np.abs(np.asarray(backb) - np.asarray(x))
  assert (errb <= sb_rows[..., None] * 0.5 + 1e-6).all()


def test_roundtrip_zero_rows_exact():
  x = jnp.zeros((2, 4, 16), jnp.float32)
  q, s = quant.quantize_rows(x, "int8")
  assert not np.asarray(q).any() and not np.asarray(s).any()
  assert not np.asarray(quant.dequantize_rows(q, s)).any()


@pytest.mark.skipif(not quant.fp8_supported(), reason="no fp8 dtype")
def test_roundtrip_bound_fp8():
  rng = np.random.default_rng(1)
  x = jnp.asarray(rng.normal(size=(2, 8, 32)).astype(np.float32))
  q, s = quant.quantize_rows(x, "fp8")
  assert q.dtype == quant.qdtype("fp8")
  back = quant.dequantize_rows(q, s)
  # fp8-e4m3 keeps ~3 mantissa bits: relative row error well under 10%.
  dev = (np.linalg.norm(np.asarray(back) - np.asarray(x))
         / np.linalg.norm(np.asarray(x)))
  assert dev < 0.1, dev


# -- kernel parity -----------------------------------------------------------

def _toy(S=256, B=2, Hkv=2, G=2, D=64, C=32, seed=0):
  ks = jax.random.split(jax.random.PRNGKey(seed), 3)
  q = jax.random.normal(ks[0], (B, Hkv * G, D), jnp.float32)
  k = jax.random.normal(ks[1], (B, Hkv, S, D), jnp.float32)
  v = jax.random.normal(ks[2], (B, Hkv, S, D), jnp.float32)
  perm = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
  return q, k, v, perm


def test_build_quant_parity_interpret_vs_xla():
  """The interpret-mode segment-build kernel and the XLA reference must
  encode BIT-IDENTICAL integers (deterministic rounding), with scales
  equal to float roundoff."""
  q, k, v, perm = _toy()
  a_x = ops.synopsis_build(k, v, perm, cluster_size=32, impl="xla",
                           qconfig="int8+kv")
  a_i = ops.synopsis_build(k, v, perm, cluster_size=32, impl="interpret",
                           qconfig="int8+kv")
  assert set(a_x) == set(a_i)
  for name in ("k", "v", "k_syn", "v_syn"):
    assert a_x[name].dtype == jnp.int8
    assert np.array_equal(np.asarray(a_x[name]), np.asarray(a_i[name])), name
  for name in quant.SCALE_LEAVES:
    np.testing.assert_allclose(np.asarray(a_x[name]), np.asarray(a_i[name]),
                               atol=1e-6, err_msg=name)
  # counts identical, and the syn-only spec emits no KV scales.
  np.testing.assert_allclose(np.asarray(a_x["counts"]),
                             np.asarray(a_i["counts"]))
  a_syn = ops.synopsis_build(k, v, perm, cluster_size=32, impl="xla",
                             qconfig="int8")
  assert "k_scale" not in a_syn and a_syn["k"].dtype == jnp.float32


def test_stage_kernels_quant_parity_with_pads():
  """Quantized stage-1 + stage-2 interpret-vs-XLA parity, with a
  selection that includes -1 pads (the budget under-fill case) and
  non-uniform counts driving the count bias."""
  q, k, v, perm = _toy(seed=3)
  arena = ops.synopsis_build(k, v, perm, cluster_size=32, impl="xla",
                             qconfig="int8+kv")
  B, Hkv, M = arena["k_syn"].shape[:3]
  counts = arena["counts"] + jnp.arange(M, dtype=jnp.float32)[None]
  sm = float(1 / np.sqrt(q.shape[-1]))
  syn_scales = (arena["k_syn_scale"], arena["v_syn_scale"])
  kv_scales = (arena["k_scale"], arena["v_scale"])

  outs = {}
  for impl in ("xla", "interpret"):
    sc, p1 = ops.synopsis_stage1(q, arena["k_syn"], arena["v_syn"], counts,
                                 sm_scale=sm, impl=impl,
                                 syn_scales=syn_scales)
    sel = jnp.tile(jnp.asarray([[3, 0, 5, -1, -1]], jnp.int32)[None],
                   (B, Hkv, 1))
    p2 = ops.refine_stage2(q, arena["k"], arena["v"], sel, arena["k_syn"],
                           arena["v_syn"], counts, cluster_size=32,
                           sm_scale=sm, impl=impl, syn_scales=syn_scales,
                           kv_scales=kv_scales)
    out, _, _ = ops.merge_partials(p1, p2)
    outs[impl] = (np.asarray(sc), np.asarray(out))
  np.testing.assert_allclose(outs["xla"][0], outs["interpret"][0],
                             atol=2e-5, rtol=1e-5)
  np.testing.assert_allclose(outs["xla"][1], outs["interpret"][1],
                             atol=2e-5, rtol=1e-5)


def test_fused_e2e_quant_deviation_bound():
  """At full refinement coverage (i_max = M) the quantized arm's output
  deviation vs the f32 arm is pure rounding noise — inside the ~7%
  stage-1 floor with a wide margin."""
  q, k, v, perm = _toy(S=512, seed=7)
  C, M = 32, 512 // 32
  sm = float(1 / np.sqrt(q.shape[-1]))
  k_s, v_s, k_syn, v_syn, counts = ops.synopsis_build(
      k, v, perm, cluster_size=C, impl="xla")
  arena = ops.synopsis_build(k, v, perm, cluster_size=C, impl="xla",
                             qconfig="int8+kv")
  o_f = ops.synopsis_attention_fused(q, k_s, v_s, k_syn, v_syn, counts,
                                     i_max=M, sm_scale=sm, impl="xla")
  o_q = ops.synopsis_attention_fused(
      q, arena["k"], arena["v"], arena["k_syn"], arena["v_syn"],
      arena["counts"], arena["k_syn_scale"], arena["v_syn_scale"],
      arena["k_scale"], arena["v_scale"], i_max=M, sm_scale=sm, impl="xla")
  dev = (np.linalg.norm(np.asarray(o_q) - np.asarray(o_f))
         / np.linalg.norm(np.asarray(o_f)))
  assert dev < 0.07, dev
  # Control arm: all-None scales are the pre-quantization code path.
  o_n = ops.synopsis_attention_fused(q, k_s, v_s, k_syn, v_syn, counts,
                                     None, None, None, None,
                                     i_max=M, sm_scale=sm, impl="xla")
  assert np.array_equal(np.asarray(o_n), np.asarray(o_f))


def test_quant_ref_matches_plain_ref_when_unscaled():
  """Passing all-ones scales through the scale-aware reference must
  reproduce the unscaled reference exactly (the dequant hooks are
  multiplicative identities)."""
  q, k, v, perm = _toy(seed=11)
  k_s, v_s, k_syn, v_syn, counts = ops.synopsis_build(
      k, v, perm, cluster_size=32, impl="xla")
  B, Hkv, M = k_syn.shape[:3]
  ones = jnp.ones((B, Hkv, M), jnp.float32)
  sm = 0.125
  base = ref.fused_synopsis_score_attention_ref(
      q, k_syn, v_syn, jnp.log(jnp.maximum(counts, 1.0)), sm_scale=sm)
  scaled = ref.fused_synopsis_score_attention_ref(
      q, k_syn, v_syn, jnp.log(jnp.maximum(counts, 1.0)), sm_scale=sm,
      k_scale=ones, v_scale=ones)
  for a, b in zip(jax.tree.leaves(base), jax.tree.leaves(scaled)):
    assert np.array_equal(np.asarray(a), np.asarray(b))


# -- serve-layer plumbing ----------------------------------------------------

def test_cache_struct_quant_leaves():
  cfg = get_config("llama3-8b", smoke=True)
  S = 2 * cfg.synopsis.cluster_size
  base = kvc.cache_struct(cfg, 2, S, synopsis=True)
  assert "k_syn_scale" not in base
  st = kvc.cache_struct(_quant_cfg(cfg, "int8"), 2, S, synopsis=True)
  assert st["k_syn"][1] == jnp.int8 and st["v_syn"][1] == jnp.int8
  assert st["k"][1] == cfg.dtype          # syn-only: corpus KV native
  assert st["k_syn_scale"][1] == jnp.float32 and "k_scale" not in st
  assert st["k_syn_scale"][0] == st["k_syn"][0][:-1]
  stkv = kvc.cache_struct(_quant_cfg(cfg, "int8+kv"), 2, S, synopsis=True)
  assert stkv["k"][1] == jnp.int8
  assert stkv["k_scale"][0] == stkv["k_syn_scale"][0]
  # arena_nbytes counts whatever scale leaves are present.
  arena = {name: jnp.zeros(st[name][0], st[name][1])
           for name in kvc.ARENA_LEAVES if name in st}
  per_scale = int(np.prod(st["k_syn_scale"][0])) * 4
  base_arena = {name: jnp.zeros(base[name][0], base[name][1])
                for name in kvc.ARENA_LEAVES if name in base}
  assert (kvc.arena_nbytes(arena) - 2 * per_scale
          < kvc.arena_nbytes(base_arena))


def test_corpus_fingerprint_separates_quant():
  cfg = get_config("llama3-8b", smoke=True)
  fps = {spec: cc.corpus_fingerprint(_quant_cfg(cfg, spec), "xla", 64, 0)
         for spec in ("none", "int8", "int8+kv")}
  assert len(set(fps.values())) == 3, fps
  # Same tokens under different quant specs must hash to different keys.
  t = np.arange(16, dtype=np.int32)
  keys = {cc.corpus_key(t, fp) for fp in fps.values()}
  assert len(keys) == 3


def test_engine_quant_accuracy_floor():
  """The e2e serving contract: int8 and int8+kv arms run the same smoke
  trace as quant=none and keep the engine's own exact-vs-served accuracy
  loss inside the ~7% stage-1 floor."""
  cfg = get_config("llama3-8b", smoke=True)
  ecfg = EngineConfig(n_slots=2, prompt_len=64, max_new_tokens=4,
                      deadline_ms=60.0, policy="accuracytrader", impl="xla")
  loss = {}
  for spec in ("none", "int8", "int8+kv"):
    eng = ServingEngine(_quant_cfg(cfg, spec), ecfg)
    s = eng.run(make_requests([0.0, 0.001, 0.002, 0.003], 64, 4,
                              cfg.vocab, seed=7))
    assert len(eng.completed) == 4
    assert all(len(r.tokens) == 1 + 4 for r in eng.completed)
    loss[spec] = s["accuracy_loss_pct"]
  assert loss["int8"] <= loss["none"] + 7.0, loss
  assert loss["int8+kv"] <= loss["none"] + 7.0, loss


def test_engine_quant_kv_disables_delta_replay():
  cfg = get_config("llama3-8b", smoke=True)
  Cs = cfg.synopsis.cluster_size
  ecfg = EngineConfig(n_slots=2, prompt_len=64, max_new_tokens=2,
                      impl="xla", cache=CacheConfig(capacity=4,
                                                    delta_unit=Cs))
  assert ServingEngine(_quant_cfg(cfg, "int8"), ecfg)._delta_ok
  assert not ServingEngine(_quant_cfg(cfg, "int8+kv"), ecfg)._delta_ok


def test_fleet_quant_refcount_and_replication():
  """Mirror of test_fleet_admission_pins_arena_per_replica with an
  int8+kv arena: R pins per admission conserve across hit/retire, and
  every replica lane — quantized tables AND scale leaves — stays
  bit-identical to its primary shard."""
  from repro.serve.fleet import FleetConfig, FleetStepBackend
  cfg = _quant_cfg(get_config("llama3-8b", smoke=True), "int8+kv")
  Cs = cfg.synopsis.cluster_size
  backend = FleetStepBackend(FleetConfig(
      n_components=2, replicas=2, seed=0, use_mesh=False))
  eng = ServingEngine(cfg, EngineConfig(
      n_slots=2, prompt_len=64, max_new_tokens=2, policy="fixed",
      fixed_budget=1, impl="xla",
      cache=CacheConfig(capacity=4, delta_unit=Cs)), backend=backend)
  eng.reset()
  reqs = make_requests([0.0, 0.0], 64, 2, cfg.vocab, seed=9)
  reqs[1].prompt = reqs[0].prompt.copy()
  eng._admit(reqs[0], 0)
  entry = eng.corpus_cache.entries[eng._slot_entry[0]]
  assert entry.refcount == 2
  # The published arena carries the quantized dtypes + scale leaves.
  assert entry.arena["k_syn"].dtype == jnp.int8
  for name in quant.SCALE_LEAVES:
    assert name in entry.arena, name
  eng._admit(reqs[1], 1)
  assert entry.refcount == 4
  topo = backend.topo
  grid = topo.shard_grid()
  seen_scale = 0
  for leaf in kvc.ARENA_LEAVES:
    if leaf not in eng.cache:
      continue
    seen_scale += leaf in quant.SCALE_LEAVES
    x = np.asarray(eng.cache[leaf])
    ax = 3 if leaf == "counts" else 4
    x = np.moveaxis(x, (ax, ax + 1), (0, 1))
    assert np.abs(x.astype(np.float64)).sum() > 0, leaf
    for r in range(topo.replicas):
      for j in range(topo.n_components):
        assert np.array_equal(x[r, j], x[0, grid[r, j]]), (leaf, r, j)
  assert seen_scale == len(quant.SCALE_LEAVES)
  eng._retire(0)
  assert entry.refcount == 2
  eng._retire(1)
  assert entry.refcount == 0
