"""Fault-and-overload resilience layer (DESIGN.md §11): seed-determinism
and off-by-default no-op of the fault plan, the recovery ladder's bounds
(bounded backoff retries, legacy-hedge equivalence at K=1, dead shards
never marked FULL), the crash -> stage-1 accuracy floor on the real
cluster backend (accuracy degrades, availability never), the queue-aware
predictive admission policy (EDF/least-slack ordering, SLO classes,
token-bucket rates, shed-at-admission burning zero prefill), and the
simulator's fault/shed round-trip."""
import numpy as np
import pytest

from repro.control import (MODE_DROP, MODE_FULL, MODE_STAGE1,
                           AdmissionConfig, AdmissionPolicy,
                           DeadlineBudgetPolicy, RetryPolicy, SLOClass,
                           TokenBucket, parse_slo_classes, plan_recovery,
                           realized_recovery)
from repro.serve.resilience import (FaultPlan, FaultSpec,
                                    parse_fault_spec)

# -- fault plan --------------------------------------------------------------


def test_fault_spec_validation():
  with pytest.raises(ValueError):
    FaultSpec(crash_rate=1.5)
  with pytest.raises(ValueError):
    FaultSpec(stall_rate=-0.1)
  with pytest.raises(ValueError):
    FaultSpec(crash=((-1, 0),))


def test_fault_plan_disabled_is_noop():
  """`FaultPlan(None, n)` must be indistinguishable from no fault model:
  every step is alive and clean, and `enabled` gates every fault branch
  in the backends (the off-by-default property)."""
  plan = FaultPlan(None, 5)
  assert not plan.enabled
  for step in (0, 3, 1000, 7):          # arbitrary order, arbitrary steps
    st = plan.at(step)
    assert st.clean and st.alive.all() and (st.slow == 1.0).all()
  plan.reseed(99)
  assert plan.at(0).clean
  assert parse_fault_spec(None) is None
  assert parse_fault_spec("") is None
  assert parse_fault_spec("none") is None


def test_fault_plan_seed_deterministic():
  spec = FaultSpec(crash_rate=0.1, stall_rate=0.2, slow_rate=0.1,
                   down_steps=3, seed=7)
  a, b = FaultPlan(spec, 6), FaultPlan(spec, 6)
  a.reseed(11)
  b.reseed(11)
  for step in range(40):
    sa, sb = a.at(step), b.at(step)
    np.testing.assert_array_equal(sa.alive, sb.alive)
    np.testing.assert_array_equal(sa.slow, sb.slow)
  # Query order cannot shift the schedule: a fresh plan read backwards
  # sees the same world.
  c = FaultPlan(spec, 6)
  c.reseed(11)
  for step in reversed(range(40)):
    np.testing.assert_array_equal(c.at(step).alive, a.at(step).alive)
  # A different window seed is a different fault world.
  d = FaultPlan(spec, 6)
  d.reseed(12)
  assert any(not np.array_equal(d.at(s).alive, a.at(s).alive)
             or not np.array_equal(d.at(s).slow, a.at(s).slow)
             for s in range(40))


def test_fault_plan_crash_schedule_and_revival():
  # Scheduled crash: dead exactly from its step, forever by default.
  p = FaultPlan(FaultSpec(crash=((3, 1),), seed=0), 4)
  assert p.at(2).alive.all()
  for s in (3, 4, 50):
    assert not p.at(s).alive[1] and p.at(s).alive[[0, 2, 3]].all()
  # down_steps bounds the outage: dead for exactly that many steps.
  p = FaultPlan(FaultSpec(crash=((3, 1),), down_steps=2, seed=0), 4)
  assert p.at(2).alive[1] and not p.at(3).alive[1] \
      and not p.at(4).alive[1] and p.at(5).alive[1]


def test_parse_fault_spec():
  sp = parse_fault_spec("crash=1@8+3@20,stall_rate=0.05,slow_scale=6,"
                        "down_steps=4,seed=3")
  assert sp.crash == ((8, 1), (20, 3))
  assert sp.stall_rate == 0.05 and sp.slow_scale == 6.0
  assert sp.down_steps == 4 and sp.seed == 3
  with pytest.raises(ValueError):
    parse_fault_spec("bogus_key=1")


# -- recovery ladder ---------------------------------------------------------


def test_retry_delays_monotone_and_bounded():
  pol = RetryPolicy(max_retries=4, backoff_base=0.5, backoff_mult=2.0)
  t = np.array([10.0, 20.0])
  d = np.asarray(pol.delays(t))
  assert d.shape == (4, 2)                    # one row per retry 0..K-1
  assert (d[0] == 0.0).all()                  # retry 0 = immediate hedge
  assert (np.diff(d[1:], axis=0) > 0).all()   # exponential backoff
  np.testing.assert_allclose(d[1], 0.5 * t)
  np.testing.assert_allclose(d[2], 1.0 * t)
  np.testing.assert_allclose(d[3], 2.0 * t)
  with pytest.raises(ValueError):
    RetryPolicy(max_retries=-1)
  with pytest.raises(ValueError):
    RetryPolicy(backoff_mult=0.0)


def test_plan_recovery_matches_legacy_hedge_at_k1():
  """With one zero-delay retry and everything alive, the recovery ladder
  IS the legacy hedged gather — same modes, retry mask == hedge mask."""
  rng = np.random.default_rng(4)
  for policy in ("accuracytrader", "partial", "basic"):
    pol = DeadlineBudgetPolicy(policy=policy, buckets=(0, 4), i_max_cap=4)
    for _ in range(50):
      n = int(rng.integers(2, 8))
      t_pred = rng.uniform(0.1, 30.0, n)
      t_hedge = rng.uniform(0.1, 30.0, n)
      ddl = float(rng.uniform(1.0, 20.0))
      mode_l, hedged = pol.gather_modes(t_pred, ddl, t_hedge)
      mode_r, retries, _ = plan_recovery(
          policy, t_pred, ddl, t_retry=t_hedge[None, :])
      np.testing.assert_array_equal(mode_l, mode_r)
      np.testing.assert_array_equal(hedged, retries > 0)
      done_l = np.where(hedged, np.minimum(t_pred, t_hedge), t_pred)
      done_r = realized_recovery(t_pred, t_hedge[None, :], retries)
      np.testing.assert_allclose(done_l, done_r)


def test_recovery_retries_bounded_by_policy_cap():
  rng = np.random.default_rng(5)
  for k in (0, 1, 3):
    t_pred = rng.uniform(5.0, 50.0, 6)
    t_retry = rng.uniform(5.0, 50.0, (k, 6)) if k else None
    _, retries, _ = plan_recovery("accuracytrader", t_pred, 1.0,
                                  t_retry=t_retry)
    assert (retries <= k).all() and (retries >= 0).all()


def test_recovery_ladder_dead_paths():
  t_pred = np.array([5.0, 5.0, 5.0])
  t_retry = np.array([[6.0, 6.0, 6.0]])
  alive = np.array([True, False, False])
  retry_alive = np.array([[True, True, False]])
  # accuracytrader: dead primary + live replica -> FULL via retry; dead
  # both -> terminal stage-1 fallback (accuracy, never availability).
  mode, retries, eff = plan_recovery("accuracytrader", t_pred, 10.0,
                                     t_retry=t_retry, alive=alive,
                                     retry_alive=retry_alive)
  assert list(mode) == [MODE_FULL, MODE_FULL, MODE_STAGE1]
  assert list(retries) == [0, 1, 1]
  # partial: no synopsis to stand in -> the dead shard is dropped.
  mode, _, _ = plan_recovery("partial", t_pred, 10.0, t_retry=t_retry,
                             alive=alive, retry_alive=retry_alive)
  assert list(mode) == [MODE_FULL, MODE_FULL, MODE_DROP]
  # A dead shard is never FULL even under an infinite (warming) deadline.
  mode, _, _ = plan_recovery("accuracytrader", t_pred, np.inf,
                             alive=np.array([False, True, True]))
  assert mode[0] == MODE_STAGE1 and (mode[1:] == MODE_FULL).all()


def test_realized_recovery_only_prices_dispatched_retries():
  t_real = np.array([10.0, 10.0])
  t_retry = np.array([[1.0, 1.0]])
  done = realized_recovery(t_real, t_retry, np.array([1, 0]))
  np.testing.assert_allclose(done, [1.0, 10.0])
  # A dead primary contributes nothing: only its dispatched retry does.
  done = realized_recovery(t_real, t_retry, np.array([1, 1]),
                           alive=np.array([False, True]),
                           retry_alive=np.array([[True, True]]))
  np.testing.assert_allclose(done, [1.0, 1.0])


# -- admission policy --------------------------------------------------------


def test_slo_parse_and_validation():
  cs = parse_slo_classes("interactive:80@60/8,batch:400")
  assert [c.name for c in cs] == ["interactive", "batch"]
  assert cs[0].deadline_ms == 80.0 and cs[0].rate_per_s == 60.0 \
      and cs[0].burst == 8.0
  assert cs[1].deadline_ms == 400.0 and np.isinf(cs[1].rate_per_s)
  assert parse_slo_classes(None) == ()
  with pytest.raises(ValueError):
    parse_slo_classes("noclassdeadline")
  with pytest.raises(ValueError):
    SLOClass("x", -1.0)
  with pytest.raises(ValueError):
    AdmissionConfig(order="lifo")
  with pytest.raises(ValueError):
    AdmissionConfig(classes=(SLOClass("a", 1.0), SLOClass("a", 2.0)))


def test_token_bucket_refill():
  b = TokenBucket(rate_per_s=10.0, burst=2.0)    # 1 token / 100 ms
  assert b.take(0.0) and b.take(0.0)             # burst of 2
  assert not b.take(0.0)
  assert not b.take(50.0)                        # half a token refilled
  assert b.take(100.0)                           # one token back
  assert not b.take(100.0)


def test_admission_ordering_keys():
  classes = (SLOClass("fast", 10.0), SLOClass("slow", 100.0))
  pol = AdmissionPolicy(AdmissionConfig(order="edf", classes=classes),
                        default_deadline_ms=50.0,
                        demand_fn=lambda req: 5.0)

  class R:
    def __init__(self, rid, arrival, slo):
      self.rid, self.arrival_ms, self.slo = rid, arrival, slo
      self.deadline_ms = None

  late_fast = R(0, 8.0, "fast")      # abs deadline 18
  early_slow = R(1, 0.0, "slow")     # abs deadline 100
  assert pol.deadline_for(late_fast) == 10.0
  assert pol.deadline_for(R(2, 0.0, "nope")) == 50.0   # unknown -> default
  # EDF: the later-arriving interactive request goes first.
  assert pol.key(late_fast, 0.0) < pol.key(early_slow, 0.0)
  # FIFO: arrival order wins.
  fifo = AdmissionPolicy(AdmissionConfig(order="fifo", classes=classes),
                         50.0, lambda req: 5.0)
  assert fifo.key(early_slow, 0.0) < fifo.key(late_fast, 0.0)
  # Least slack equals EDF at constant demand; explicit deadline wins.
  r = R(3, 0.0, "slow")
  r.deadline_ms = 7.0
  assert pol.deadline_for(r) == 7.0


def test_predicted_dead_margin():
  pol = AdmissionPolicy(AdmissionConfig(order="edf", shed=True,
                                        shed_margin=1.0),
                        default_deadline_ms=20.0,
                        demand_fn=lambda req: 15.0)

  class R:
    rid, arrival_ms, slo, deadline_ms = 0, 0.0, "default", None

  assert not pol.predicted_dead(R(), now_ms=0.0)      # 15 <= 20
  assert pol.predicted_dead(R(), now_ms=10.0)         # 25 > 20
  lax = AdmissionPolicy(AdmissionConfig(order="edf", shed=True,
                                        shed_margin=2.0),
                        20.0, lambda req: 15.0)
  assert not lax.predicted_dead(R(), now_ms=10.0)     # 25 <= 40
  off = AdmissionPolicy(AdmissionConfig(order="edf", shed=False),
                        20.0, lambda req: 1e9)
  assert not off.predicted_dead(R(), now_ms=0.0)


# -- engine: EDF/shed + SLO classes ------------------------------------------


def _mini_engine(admission):
  from repro.configs.registry import get_config
  from repro.serve.engine import EngineConfig, ServingEngine
  cfg = get_config("llama3-8b", smoke=True)
  return ServingEngine(cfg, EngineConfig(
      n_slots=2, prompt_len=32, max_new_tokens=2, deadline_ms=200.0,
      policy="accuracytrader", impl="xla", admission=admission))


def test_edf_shed_never_sheds_feasible_low_load():
  """At a trickle rate every request is feasible — predictive shedding
  must admit all of them, serve them in full, and burn prefill only on
  served requests; the FIFO-ordered run serves the identical set."""
  from repro.serve.engine import run_open_loop
  served = {}
  for order in ("edf", "fifo"):
    eng = _mini_engine(AdmissionConfig(order=order, shed=True))
    s = run_open_loop(eng, rate_per_s=4.0, duration_s=0.5, seed=9)
    assert s["shed_admission_n"] == 0
    assert s["served_n"] == s["n"] == len(eng.completed)
    assert s["prefills"] == s["served_n"]
    served[order] = sorted(r.rid for r in eng.completed
                           if not r.shed_admission)
  assert served["edf"] == served["fifo"]


def test_per_class_slo_stats_sum_to_aggregate():
  from repro.serve.engine import run_open_loop
  classes = (SLOClass("interactive", 80.0), SLOClass("batch", 400.0))
  eng = _mini_engine(AdmissionConfig(order="edf", shed=True,
                                     classes=classes))
  s = run_open_loop(eng, rate_per_s=60.0, duration_s=0.5, seed=9,
                    slo_of=lambda rid: classes[rid % 2].name)
  assert set(s["classes"]) == {"interactive", "batch"}
  for key in ("n", "served_n", "shed_admission_n", "goodput_n"):
    assert sum(c[key] for c in s["classes"].values()) == s[key], key
  # Every shed request has zero token budget spent on it.
  for r in eng.completed:
    if r.shed_admission:
      assert r.tokens == [] and r.accuracy == 0.0 and r.dropped


# -- cluster backend: crash -> stage-1 floor ---------------------------------


@pytest.fixture(scope="module")
def faulted_engine():
  """N=2, no replicas, component 1 crashed from step 0: the recovery
  ladder's only path for its shard is the stage-1 synopsis fallback."""
  from repro.configs.registry import get_config
  from repro.serve.cluster import ClusterConfig, ClusterStepBackend
  from repro.serve.engine import EngineConfig, ServingEngine
  cfg = get_config("llama3-8b", smoke=True)
  backend = ClusterStepBackend(ClusterConfig(
      n_components=2, replicas=1, seed=0, use_mesh=False,
      interference=0.3, straggler_prob=0.0,
      faults=FaultSpec(crash=((0, 1),), seed=5)))
  eng = ServingEngine(cfg, EngineConfig(
      n_slots=1, prompt_len=64, max_new_tokens=2, deadline_ms=60.0,
      policy="accuracytrader", impl="xla"), backend=backend)
  return eng, backend


def test_crash_costs_accuracy_never_availability(faulted_engine):
  """The tentpole invariant: with a component crashed the whole window,
  accuracy is bounded by the stage-1 floor (~7 % of that shard's mass)
  and availability stays 100 % — no step drops any shard's answer."""
  from repro.serve.engine import run_open_loop
  eng, backend = faulted_engine
  s = run_open_loop(eng, rate_per_s=20.0, duration_s=0.4, seed=3)
  assert s["n"] > 0
  assert s["availability_pct"] == 100.0
  assert s["accuracy_loss_pct"] <= 7.0 + 1e-6
  assert backend.fault_stats["stage1_fallbacks"] > 0
  assert backend.fault_stats["dropped"] == 0
  # Per-step floor: every shard answers at least its stage-1 synopsis
  # (the dead one via the terminal fallback, live ones possibly at
  # budget 0), so no step ever scores below concentration(0).
  floor = backend.accuracy_fn(0.0)
  for r in eng.completed:
    for a in r.step_acc:
      assert a >= floor - 1e-9


def test_fault_world_deterministic_across_reseed(faulted_engine):
  _, backend = faulted_engine
  backend.reseed(21)
  p1 = [backend.plan_step(1, 5.0) for _ in range(3)]
  # plan_step does not advance the fault clock (account does) — advance
  # it by hand so the three plans see steps 0, 1, 2.
  backend.reseed(21)
  p2 = [backend.plan_step(1, 5.0) for _ in range(3)]
  for a, b in zip(p1, p2):
    np.testing.assert_array_equal(a.alive, b.alive)
    np.testing.assert_array_equal(a.slow, b.slow)
    np.testing.assert_array_equal(a.mode, b.mode)
    np.testing.assert_array_equal(a.noise, b.noise)
  assert not p1[0].alive[1] and p1[0].alive[0]


# -- simulator round-trip ----------------------------------------------------


def test_simulator_fault_roundtrip():
  from repro.serving.service import ScatterGatherService, ServiceConfig
  fs = FaultSpec(crash=((0, 2),), seed=3)
  kw = dict(n_components=8, seed=1, deadline_ms=100.0)
  at = ScatterGatherService(ServiceConfig(faults=fs, **kw))
  r_at = at.run_open_loop(40.0, 1.5)
  assert r_at["availability_pct"] == 100.0
  assert r_at["accuracy_loss_pct"] < 7.0
  basic = ScatterGatherService(ServiceConfig(technique="basic", faults=fs,
                                             **kw))
  r_b = basic.run_open_loop(40.0, 1.5)
  assert r_b["availability_pct"] < 100.0        # lost shard
  assert r_b["p99"] >= 3.0 * kw["deadline_ms"] - 1e-6   # stalls
  # Ring replica serves the dead shard: loss below the R=1 fallback.
  rep = ScatterGatherService(ServiceConfig(faults=fs, replicas=2, **kw))
  r_rep = rep.run_open_loop(40.0, 1.5)
  assert r_rep["availability_pct"] == 100.0
  assert r_rep["accuracy_loss_pct"] < r_at["accuracy_loss_pct"]


def test_simulator_shed_is_noop_at_low_load():
  from repro.serving.service import ScatterGatherService, ServiceConfig
  a = ScatterGatherService(ServiceConfig(n_components=8, seed=1))
  b = ScatterGatherService(ServiceConfig(n_components=8, seed=1,
                                         shed=True))
  ra = a.run_open_loop(5.0, 1.0)
  rb = b.run_open_loop(5.0, 1.0)
  assert rb["shed_pct"] == 0.0
  assert ra["p99"] == rb["p99"]         # identical draws, identical world
  # Overload: shedding engages and keeps served latency bounded.
  c = ScatterGatherService(ServiceConfig(n_components=8, seed=1,
                                         shed=True, deadline_ms=10.0))
  rc = c.run_open_loop(2000.0, 0.5)
  assert rc["shed_pct"] > 0.0
