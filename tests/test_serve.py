"""Serving-path integration: prefill -> synopsis build -> decode, plus the
AccuracyTrader accuracy/budget trade and incremental synopsis updates."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.models import common as cm
from repro.models import transformer as tf
from repro.serve import kv_cache as kvc
from repro.serve import synopsis_kv as skv
from repro.serve.prefill import make_prefill_step
from repro.serve.serve_step import make_serve_step

B, S = 2, 128


@pytest.fixture(scope="module")
def llama():
  cfg = get_config("llama3-8b", smoke=True)
  params, _ = cm.split(tf.init_model(jax.random.PRNGKey(0), cfg))
  params = jax.tree.map(lambda p: p.astype(cfg.dtype), params)
  tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
  _, cache = jax.jit(make_prefill_step(cfg))(params, tokens)
  syn_cache = jax.jit(lambda c: skv.build(c, cfg))(cache)
  return cfg, params, cache, syn_cache


def test_synopsis_full_budget_equals_exact(llama):
  cfg, params, cache, syn_cache = llama
  M = S // cfg.synopsis.cluster_size
  nt = jax.random.randint(jax.random.PRNGKey(2), (B, 1), 0, cfg.vocab)
  lg_e, _ = jax.jit(make_serve_step(cfg, mode="exact"))(params, cache, nt)
  lg_s, _ = jax.jit(make_serve_step(cfg, mode="synopsis", i_max=M))(
      params, syn_cache, nt)
  np.testing.assert_allclose(np.asarray(lg_s, np.float32),
                             np.asarray(lg_e, np.float32),
                             rtol=5e-2, atol=5e-2)


def test_accuracy_improves_with_budget(llama):
  cfg, params, cache, syn_cache = llama
  M = S // cfg.synopsis.cluster_size
  nt = jax.random.randint(jax.random.PRNGKey(3), (B, 1), 0, cfg.vocab)
  lg_e, _ = jax.jit(make_serve_step(cfg, mode="exact"))(params, cache, nt)
  p_e = jax.nn.softmax(lg_e.astype(jnp.float32), -1)
  errs = []
  for i_max in (0, M // 2, M):
    lg, _ = jax.jit(make_serve_step(cfg, mode="synopsis", i_max=i_max))(
        params, syn_cache, nt)
    p = jax.nn.softmax(lg.astype(jnp.float32), -1)
    errs.append(float(0.5 * jnp.abs(p - p_e).sum(-1).mean()))
  assert errs[-1] < 1e-3                   # full budget == exact
  assert errs[0] >= errs[1] - 1e-4         # more budget, no worse


def test_synopsis_centroids_are_cluster_means(llama):
  cfg, params, cache, syn_cache = llama
  C = cfg.synopsis.cluster_size
  k = np.asarray(syn_cache["k"], np.float32)
  ks = np.asarray(syn_cache["k_syn"], np.float32)
  nb, na, b, h, s, d = k.shape
  got = k.reshape(nb, na, b, h, s // C, C, d).mean(5)
  np.testing.assert_allclose(ks, got, rtol=2e-2, atol=2e-2)


def test_synopsis_preserves_token_set(llama):
  cfg, params, cache, syn_cache = llama
  # the permuted cache holds exactly the same rows as the original
  k0 = np.asarray(cache["k"], np.float32)[0, 0, 0, 0]
  k1 = np.asarray(syn_cache["k"], np.float32)[0, 0, 0, 0]
  s0 = np.sort(k0.sum(-1))
  s1 = np.sort(k1.sum(-1))
  np.testing.assert_allclose(s0, s1, rtol=1e-3, atol=1e-3)


def test_append_and_absorb_recent(llama):
  cfg, params, cache, syn_cache = llama
  C = cfg.synopsis.cluster_size
  R = cfg.synopsis.recent
  nb = cfg.n_blocks
  na = kvc.n_attn_positions(cfg)
  Hkv, Dk = syn_cache["k"].shape[3], syn_cache["k"].shape[5]
  cur = syn_cache
  for i in range(R):
    kd = jnp.full((nb, na, B, Hkv, 1, Dk), float(i), cfg.dtype)
    cur = skv.append_recent(cur, kd, kd)
  assert int(cur["recent_len"][0]) == R
  absorbed = skv.absorb_recent(cur, cfg)
  assert absorbed["k"].shape[4] == S + R
  assert absorbed["k_syn"].shape[4] == (S + R) // C
  assert int(absorbed["recent_len"][0]) == 0
  # new centroids = means of the absorbed recents
  newc = np.asarray(absorbed["k_syn"], np.float32)[0, 0, 0, 0, S // C:]
  exp = np.asarray(cur["recent_k"], np.float32)[0, 0, 0, 0].reshape(
      R // C, C, -1).mean(1)
  np.testing.assert_allclose(newc, exp, rtol=2e-2, atol=2e-2)


def test_decode_then_absorb_consistency(llama):
  """Tokens attended via the recent buffer before absorb must still be
  attended (via synopsis clusters) after absorb."""
  cfg, params, cache, syn_cache = llama
  R = cfg.synopsis.recent
  step = jax.jit(make_serve_step(cfg, mode="synopsis", i_max=2))
  cur = syn_cache
  tok = jnp.zeros((B, 1), jnp.int32)
  for _ in range(R):
    lg, st = step(params, cur, tok)
    cur = skv.append_recent(cur, st["k_delta"], st["v_delta"])
    cur["pos"] = st["pos"]
  lg_before, _ = step(params, cur, tok)
  absorbed = skv.absorb_recent(cur, cfg)
  M2 = absorbed["k_syn"].shape[4]
  lg_after, _ = jax.jit(make_serve_step(cfg, mode="synopsis", i_max=M2))(
      params, absorbed, tok)
  # full-budget after absorb == exact over all S+R tokens; the before
  # variant (budget 2 + recent exact) should be a coarse version of it.
  p1 = jax.nn.softmax(lg_before.astype(jnp.float32), -1)
  p2 = jax.nn.softmax(lg_after.astype(jnp.float32), -1)
  tv = float(0.5 * jnp.abs(p1 - p2).sum(-1).mean())
  assert tv < 0.5
