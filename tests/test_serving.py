"""Serving runtime: scatter-gather service, three techniques, paper-shaped
behaviour (AccuracyTrader holds tail latency under load; partial execution
loses accuracy under load), plus the CF/search apps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serving.apps import (CFRecommender, SearchEngine, movielens_like,
                                webpages_like)
from repro.serving.latency import ComponentModel, TailTracker
from repro.serving.service import Request, ScatterGatherService, ServiceConfig
from repro.serving.workload import (SOGOU_HOURLY, canonical_hour, hour_rate,
                                    hour_trace, hour_trend, poisson_arrivals)


def _run(tech, rate, seed=0, duration=4.0, deadline=100.0):
  svc = ScatterGatherService(ServiceConfig(
      n_components=24, technique=tech, deadline_ms=deadline, seed=seed))
  return svc.run_open_loop(rate, duration)


def test_tail_tracker():
  t = TailTracker()
  for v in range(1, 1001):
    t.observe(float(v))
  assert abs(t.p(50) - 500.5) < 2
  assert t.p(99.9) > 990


def test_component_queueing():
  c = ComponentModel(seed=1, interference=0.0, straggler_prob=0.0)
  t1 = c.submit(0.0, 10)
  t2 = c.submit(0.0, 10)
  assert t2 > t1                      # FIFO queue builds up


def test_component_vector_service_and_work_scale():
  # Per-component measured service vectors: each component indexes its
  # own entry by comp_id (the cluster tier's export format).
  vec = np.asarray([3.0, 5.0, 9.0])
  for cid, want in [(0, 3.0), (1, 5.0), (2, 9.0), (4, 5.0)]:  # mod len
    c = ComponentModel(seed=1, comp_id=cid, interference=0.0,
                       straggler_prob=0.0)
    assert c.submit(0.0, 7, service_ms=vec) == pytest.approx(want)
  # Scalars keep working, and work_scale multiplies (hot component).
  c = ComponentModel(seed=1, interference=0.0, straggler_prob=0.0,
                     work_scale=2.0)
  assert c.submit(0.0, 7, service_ms=4.0) == pytest.approx(8.0)


def test_zipf_skew_makes_hot_components_slower():
  """ServiceConfig.skew: low-rank components own more of the corpus and
  serve slower — the service's tail follows the hottest component."""
  cfg = dict(n_components=12, technique="basic", deadline_ms=100.0, seed=3)
  uni = ScatterGatherService(ServiceConfig(**cfg, skew=0.0))
  hot = ScatterGatherService(ServiceConfig(**cfg, skew=1.2))
  scales = [c.work_scale for c in hot.components]
  assert scales[0] > 1.0 > scales[-1]          # rank 0 is the hot one
  assert all(c.work_scale == 1.0 for c in uni.components)
  su = uni.run_open_loop(20, 4.0)
  sh = hot.run_open_loop(20, 4.0)
  assert sh["p999"] > su["p999"]               # straggler-dominated tail


def test_accuracytrader_tail_stable_under_load():
  light = _run("accuracytrader", 20)
  heavy = _run("accuracytrader", 100)
  basic_heavy = _run("basic", 100)
  # paper Table 1 shape: basic explodes under load, AccuracyTrader doesn't
  assert basic_heavy["p999"] > 5 * heavy["p999"]
  assert heavy["p999"] < 20 * light["p999"]


def test_partial_execution_loses_accuracy_under_load():
  p_light = _run("partial", 20)
  p_heavy = _run("partial", 100)
  at_heavy = _run("accuracytrader", 100)
  # paper Table 2 shape
  assert p_heavy["accuracy_loss_pct"] > p_light["accuracy_loss_pct"]
  assert at_heavy["accuracy_loss_pct"] < p_heavy["accuracy_loss_pct"]


def test_reissue_helps_light_load_only():
  b = _run("basic", 20, duration=8.0)
  r = _run("reissue", 20, duration=8.0)
  assert r["p999"] <= b["p999"] * 1.1
  r_heavy = _run("reissue", 100)
  at_heavy = _run("accuracytrader", 100)
  assert at_heavy["p999"] < r_heavy["p999"]


def test_exact_techniques_have_no_accuracy_loss():
  assert _run("basic", 40)["accuracy_loss_pct"] == 0.0
  assert _run("reissue", 40)["accuracy_loss_pct"] == 0.0


def test_workload_traces():
  assert len(SOGOU_HOURLY) == 24
  tr = hour_trace(9, sessions=60)
  assert len(tr) == 60
  assert tr[-5:].mean() > tr[:5].mean()       # hour 9 increases
  tr24 = hour_trace(24, sessions=60)
  assert tr24[-5:].mean() < tr24[:5].mean()   # hour 24 decreases


def test_workload_hour_convention_endpoints():
  """Hour 24 (the 1-based name for midnight) and hour 0 are the same
  hour: one canonical index, one rate, one trend, one trace."""
  assert canonical_hour(0) == canonical_hour(24) == 0
  assert hour_rate(24) == hour_rate(0) == SOGOU_HOURLY[0]
  assert hour_trend(24) == hour_trend(0) == "decreasing"
  np.testing.assert_array_equal(hour_trace(24, sessions=30),
                                hour_trace(0, sessions=30))
  # 0-based indexing end to end: the Fig-7a peak sits at 21:00.
  assert hour_rate(21) == max(SOGOU_HOURLY) == 90
  assert hour_trend(9) == "increasing"
  assert hour_trend(23) == "decreasing"


def test_poisson_arrivals():
  arr = poisson_arrivals(100.0, 2.0, seed=0)
  assert (np.diff(arr) > 0).all() and arr[0] >= 0
  assert arr[-1] < 2000.0
  assert 100 < len(arr) < 320                 # ~200 expected


class TestApps:
  def test_cf_budget_converges_to_exact(self):
    r, m = movielens_like(512, 300, density=0.3, seed=1)
    rec = CFRecommender(r, m, num_clusters=16)
    q_full, qm_full = r[7], m[7]
    rated = np.where(np.asarray(qm_full) > 0)[0]
    test = rated[:10]
    qm = qm_full.at[jnp.asarray(test)].set(0.0)
    q = q_full * qm
    items = jnp.asarray(test)
    exact = np.asarray(rec.predict_exact(q, qm, items))
    errs = []
    for b in (0, 4, 16):
      pred = np.asarray(rec.predict(q, qm, items, b))
      errs.append(np.abs(pred - exact).mean())
    assert errs[2] < 0.05                     # full budget ~= exact
    assert errs[2] <= errs[0] + 1e-6

  def test_search_accuracy_monotone_in_budget(self):
    docs = webpages_like(1024, 256, seed=2)
    se = SearchEngine(docs, num_clusters=32)
    qv = docs[10]
    a = [np.mean([se.accuracy(docs[i * 37 % 1024], b) for i in range(8)])
         for b in (2, 8, 32)]
    assert a[2] >= a[1] >= a[0] - 0.05
    assert a[2] == 1.0                        # full budget == exact

  def test_search_ranked_sections_concentrate(self):
    """Fig 4(b): first ranked decile holds more true-top-10 than last."""
    docs = webpages_like(2048, 256, seed=3)
    se = SearchEngine(docs, num_clusters=32)
    rng = np.random.default_rng(0)
    first = last = 0
    for qi in range(12):
      qv = docs[rng.integers(0, 2048)]
      scores = np.asarray(se.syn.centroids @ qv)
      order = np.argsort(-scores)
      rank = np.empty_like(order)
      rank[order] = np.arange(len(order))
      top = np.asarray(se.search_exact(qv))
      sec = rank[np.asarray(se.syn.row_cluster)[top]] * 10 // 32
      first += int((sec == 0).sum())
      last += int((sec >= 8).sum())
    assert first > 3 * max(last, 1)
