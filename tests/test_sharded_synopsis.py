"""The production scatter-gather synopsis attention (shard_map over the
sequence axes — EXPERIMENTS.md §Perf cell 1 it.2) must produce the same
numbers as the single-device reference path.  Runs on 8 in-process
placeholder devices in a subprocess."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

PROG = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np
    import jax, jax.numpy as jnp
    from jax.sharding import Mesh
    from repro.dist import sharding as shd
    from repro.serve.serve_step import (sharded_synopsis_attention,
                                        synopsis_decode_attention)

    mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
    B, Hkv, G, D, S, C = 4, 2, 2, 32, 512, 32
    H, M = Hkv * G, S // C
    ks = jax.random.split(jax.random.PRNGKey(0), 8)
    q = jax.random.normal(ks[0], (B, H, D), jnp.float32)
    cache = {
        "k": jax.random.normal(ks[1], (B, Hkv, S, D), jnp.float32),
        "v": jax.random.normal(ks[2], (B, Hkv, S, D), jnp.float32),
        "recent_k": jax.random.normal(ks[5], (B, Hkv, 16, D), jnp.float32),
        "recent_v": jax.random.normal(ks[6], (B, Hkv, 16, D), jnp.float32),
        "recent_len": jnp.full((B,), 7, jnp.int32),
        "counts": jnp.full((B, M), float(C)),
    }
    cache["k_syn"] = cache["k"].reshape(B, Hkv, M, C, D).mean(3)
    cache["v_syn"] = cache["v"].reshape(B, Hkv, M, C, D).mean(3)
    kd = jax.random.normal(ks[7], (B, Hkv, 1, D), jnp.float32)
    sm = float(1.0 / np.sqrt(D))

    ref = synopsis_decode_attention(
        q, cache, i_max=4, cluster_size=C, sm_scale=sm, self_kv=(kd, kd))

    with shd.use_mesh(mesh, shd.SERVE_RULES):
        got = jax.jit(lambda q, c, s: sharded_synopsis_attention(
            q, c, i_max=4, cluster_size=C, sm_scale=sm, self_kv=s,
            seq_axes=("model",)))(q, cache, (kd, kd))
    err = float(np.abs(np.asarray(got) - np.asarray(ref)).max())

    # and with the long_500k 2-axis layout
    with shd.use_mesh(mesh, shd.LONG_RULES):
        got2 = jax.jit(lambda q, c, s: sharded_synopsis_attention(
            q, c, i_max=4, cluster_size=C, sm_scale=sm, self_kv=s,
            seq_axes=("data", "model")))(q, cache, (kd, kd))
    err2 = float(np.abs(np.asarray(got2) - np.asarray(ref)).max())
    print("RESULT:" + json.dumps({"err": err, "err2": err2}))
""")


@pytest.mark.slow
@pytest.mark.subprocess
def test_sharded_equals_reference():
  env = dict(os.environ)
  env["PYTHONPATH"] = "src"
  p = subprocess.run([sys.executable, "-c", PROG], capture_output=True,
                     text=True, env=env, timeout=600,
                     cwd=os.path.dirname(os.path.dirname(__file__)))
  assert p.returncode == 0, p.stderr[-3000:]
  line = [l for l in p.stdout.splitlines() if l.startswith("RESULT:")][0]
  res = json.loads(line[len("RESULT:"):])
  assert res["err"] < 2e-4, res
  assert res["err2"] < 2e-4, res
