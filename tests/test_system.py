"""End-to-end behaviour: the full AccuracyTrader story on one model —
prefill, synopsis creation, budgeted decode whose accuracy/latency trade
moves the right way, incremental update, and the serving layer driving
budgets from deadlines (paper Algorithm 1 + §4 behaviours)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.core.deadline import BudgetController, LatencyModel
from repro.models import common as cm
from repro.models import transformer as tf
from repro.serve import synopsis_kv as skv
from repro.serve.prefill import make_prefill_step
from repro.serve.serve_step import make_serve_step
from repro.serving.service import ScatterGatherService, ServiceConfig


def test_end_to_end_accuracy_latency_tradeoff():
  cfg = get_config("llama3-8b", smoke=True)
  key = jax.random.PRNGKey(0)
  params, _ = cm.split(tf.init_model(key, cfg))
  params = jax.tree.map(lambda p: p.astype(cfg.dtype), params)
  B, S = 2, 128
  prompt = jax.random.randint(key, (B, S), 0, cfg.vocab)
  _, cache = jax.jit(make_prefill_step(cfg))(params, prompt)
  syn_cache = jax.jit(lambda c: skv.build(c, cfg))(cache)
  M = S // cfg.synopsis.cluster_size

  nt = jax.random.randint(jax.random.PRNGKey(9), (B, 1), 0, cfg.vocab)
  lg_exact, _ = jax.jit(make_serve_step(cfg, mode="exact"))(
      params, cache, nt)
  p_exact = jax.nn.softmax(lg_exact.astype(jnp.float32), -1)

  # budget sweep: "rows touched" is the latency proxy, TV-dist the
  # accuracy loss; endpoints must be (cheap, approximate) -> (full, exact)
  rows, errs = [], []
  for i_max in (0, M // 2, M):
    lg, _ = jax.jit(make_serve_step(cfg, mode="synopsis", i_max=i_max))(
        params, syn_cache, nt)
    p = jax.nn.softmax(lg.astype(jnp.float32), -1)
    errs.append(float(0.5 * jnp.abs(p - p_exact).sum(-1).mean()))
    rows.append(M + i_max * cfg.synopsis.cluster_size)
  assert rows[0] < rows[-1]
  assert errs[-1] < 1e-3
  assert errs[0] > errs[-1]


def test_deadline_budget_closed_loop():
  """The controller learns the latency model and meets deadlines."""
  ctrl = BudgetController(LatencyModel(base=1.0, slope=1.0, alpha=0.1),
                          buckets=(0, 1, 2, 4, 8, 16, 32), i_max_cap=32)
  rng = np.random.default_rng(0)
  true_base, true_slope = 3.0, 0.9
  misses = 0
  for step in range(400):
    b = ctrl.budget_for(deadline=20.0)
    lat = true_base + true_slope * b + rng.normal(0, 0.1)
    ctrl.observe(b, lat)
    if step > 200 and lat > 20.0:
      misses += 1
  assert misses < 10
  # converged budget should use most of the deadline
  b = ctrl.budget_for(deadline=20.0)
  assert 8 <= b <= 32


def test_service_reproduces_paper_orderings():
  """Table 1/2 orderings at heavy load, in one shot."""
  res = {}
  for tech in ("basic", "reissue", "partial", "accuracytrader"):
    svc = ScatterGatherService(ServiceConfig(
        n_components=16, technique=tech, deadline_ms=100.0, seed=1))
    res[tech] = svc.run_open_loop(80.0, 4.0)
  # latency: AT << reissue << basic (heavy load)
  assert res["accuracytrader"]["p999"] < res["reissue"]["p999"]
  assert res["reissue"]["p999"] <= res["basic"]["p999"] * 1.2
  # accuracy: AT loss << partial loss
  assert (res["accuracytrader"]["accuracy_loss_pct"]
          < res["partial"]["accuracy_loss_pct"])
