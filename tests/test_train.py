"""Training substrate: optimizer, data determinism, checkpoint/restart,
loss decrease, gradient compression error feedback."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.train import checkpoint as ck
from repro.train import compression as comp
from repro.train.data import DataConfig, TokenStream
from repro.train.optimizer import (OptConfig, adamw_update, global_norm,
                                   init_opt_state, schedule)
from repro.train.train_step import init_train_state, make_train_step


def test_schedule_warmup_and_decay():
  cfg = OptConfig(lr=1e-3, warmup_steps=10, total_steps=100)
  assert float(schedule(cfg, jnp.int32(0))) == 0.0
  assert abs(float(schedule(cfg, jnp.int32(10))) - 1e-3) < 1e-9
  assert float(schedule(cfg, jnp.int32(100))) < 2e-4


def test_adamw_moves_toward_minimum():
  cfg = OptConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                  total_steps=2000)
  params = {"w": jnp.array([5.0])}
  opt = init_opt_state(params)
  for _ in range(150):
    grads = {"w": 2 * params["w"]}        # d/dw w^2
    params, opt, _ = adamw_update(grads, opt, params, cfg)
  assert abs(float(params["w"][0])) < 0.3


def test_grad_clip():
  cfg = OptConfig(clip_norm=1.0, warmup_steps=0)
  params = {"w": jnp.zeros((4,))}
  opt = init_opt_state(params)
  _, _, m = adamw_update({"w": jnp.full((4,), 100.0)}, opt, params, cfg)
  assert float(m["grad_norm"]) > 100


def test_data_deterministic_and_resumable():
  cfg = DataConfig(vocab=1000, seq_len=32, global_batch=4, seed=7)
  a = TokenStream(cfg)
  b = TokenStream(cfg)
  xa, ya = a.batch_at(5)
  xb, yb = b.batch_at(5)
  np.testing.assert_array_equal(xa, xb)
  np.testing.assert_array_equal(ya, yb)
  b.load_state_dict(a.state_dict())
  assert b.step == a.step
  # labels are next-token shifted
  np.testing.assert_array_equal(xa[:, 1:], ya[:, :-1])


def test_loss_decreases_tiny_model():
  cfg = get_config("smollm-135m", smoke=True)
  opt_cfg = OptConfig(lr=3e-3, warmup_steps=2, total_steps=30)
  state, _ = init_train_state(jax.random.PRNGKey(0), cfg, opt_cfg)
  data = TokenStream(DataConfig(cfg.vocab, 64, 8, seed=3))
  step = jax.jit(make_train_step(cfg, opt_cfg))
  losses = []
  for i in range(12):
    t, l = data.batch_at(i % 2)           # small fixed set -> must fit
    _, metrics = step(state, {"tokens": jnp.asarray(t),
                              "labels": jnp.asarray(l)})
    state, metrics = step(state, {"tokens": jnp.asarray(t),
                                  "labels": jnp.asarray(l)})
    losses.append(float(metrics["loss"]))
  assert losses[-1] < losses[0] - 0.3, losses


def test_microbatching_matches_full_batch():
  cfg = get_config("llama3-8b", smoke=True)
  opt_cfg = OptConfig(warmup_steps=0, total_steps=10)
  state, _ = init_train_state(jax.random.PRNGKey(0), cfg, opt_cfg)
  data = TokenStream(DataConfig(cfg.vocab, 32, 8, seed=1))
  t, l = data.batch_at(0)
  batch = {"tokens": jnp.asarray(t), "labels": jnp.asarray(l)}
  s1, m1 = jax.jit(make_train_step(cfg, opt_cfg, microbatches=1))(
      state, batch)
  s2, m2 = jax.jit(make_train_step(cfg, opt_cfg, microbatches=4))(
      state, batch)
  p1 = jax.tree.leaves(s1["params"])[0]
  p2 = jax.tree.leaves(s2["params"])[0]
  # bf16 forward + different accumulation order => ~1e-3 relative grad
  # noise, amplified by Adam's scale-invariant update where v is tiny.
  np.testing.assert_allclose(np.asarray(p1), np.asarray(p2), rtol=1e-2,
                             atol=1e-4)


def test_checkpoint_roundtrip(tmp_path):
  tree = {"a": {"b": jnp.arange(6.0).reshape(2, 3)},
          "step": jnp.int32(7)}
  ck.save(str(tmp_path), 7, tree, extras={"data": {"step": 7}})
  got, step, extras = ck.restore(str(tmp_path))
  assert step == 7
  assert extras["data"]["step"] == 7
  np.testing.assert_array_equal(np.asarray(got["a"]["b"]),
                                np.asarray(tree["a"]["b"]))


def test_checkpoint_atomic_and_latest(tmp_path):
  tree = {"w": jnp.zeros((2,))}
  ck.save(str(tmp_path), 1, tree)
  ck.save(str(tmp_path), 5, tree)
  assert ck.latest_step(str(tmp_path)) == 5
  # a stale tmp dir must not confuse restore
  os.makedirs(tmp_path / "step_00000009.tmp", exist_ok=True)
  assert ck.latest_step(str(tmp_path)) == 5


def test_async_checkpointer(tmp_path):
  c = ck.AsyncCheckpointer()
  c.save_async(str(tmp_path), 3, {"w": jnp.ones((4,))})
  c.wait()
  got, step, _ = ck.restore(str(tmp_path))
  assert step == 3


def test_train_restart_resumes_identically(tmp_path):
  """Fault tolerance: kill-and-restore reproduces the uninterrupted run."""
  cfg = get_config("smollm-135m", smoke=True)
  opt_cfg = OptConfig(warmup_steps=0, total_steps=20)
  data = TokenStream(DataConfig(cfg.vocab, 32, 4, seed=5))
  step = jax.jit(make_train_step(cfg, opt_cfg))

  def run(state, a, b):
    for i in range(a, b):
      t, l = data.batch_at(i)
      state, m = step(state, {"tokens": jnp.asarray(t),
                              "labels": jnp.asarray(l)})
    return state, m

  state0, _ = init_train_state(jax.random.PRNGKey(0), cfg, opt_cfg)
  ref_state, ref_m = run(state0, 0, 6)

  state1, _ = init_train_state(jax.random.PRNGKey(0), cfg, opt_cfg)
  state1, _ = run(state1, 0, 3)
  ck.save(str(tmp_path), 3, state1)
  restored, s, _ = ck.restore(str(tmp_path))
  assert s == 3
  got_state, got_m = run(restored, 3, 6)
  np.testing.assert_allclose(float(got_m["loss"]), float(ref_m["loss"]),
                             rtol=1e-5)


def test_compression_error_feedback_unbiased():
  """Sum over steps of (compressed update + carried error) == true sum."""
  g = jnp.asarray(np.random.default_rng(0).normal(0, 1, (64,)), jnp.float32)
  err = jnp.zeros_like(g)
  total = jnp.zeros_like(g)
  for _ in range(50):
    g32 = g + err
    q, scale = comp._quantise(g32)
    deq = q.astype(jnp.float32) * scale
    err = g32 - deq
    total = total + deq
  np.testing.assert_allclose(np.asarray(total + err),
                             np.asarray(g * 50), rtol=1e-3, atol=1e-3)
